"""Benchmark orchestrator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Select subsets with
``python -m benchmarks.run --only table1,fig2,roofline,kernels``.
Scale with --fast (CI) / default (paper-shaped, minutes on CPU).
"""

from __future__ import annotations

import argparse
import json
import sys


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default="table1,fig2,semi,roofline,kernels")
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()
    which = set(args.only.split(","))

    rows = []
    if "table1" in which:
        from benchmarks import table1_rates
        rows += table1_rates.run(
            iters=200 if args.fast else 600,
            seeds=(0,) if args.fast else (0, 1, 2),
        )
    if "fig2" in which:
        from benchmarks import fig2_cnn_grid
        rows += fig2_cnn_grid.run(
            n=6 if args.fast else 10,
            iters=40 if args.fast else 120,
            n_data=1500 if args.fast else 4000,
        )
        if not args.fast:  # Fig 3: n=30 grid
            rows += fig2_cnn_grid.run(
                n=30, alphas=(0.05, 0.1), iters=120, n_data=4000,
            )
    if "semi" in which:
        from benchmarks import semi_async
        rows += semi_async.run(
            iters=200 if args.fast else 400,
            seeds=(0,) if args.fast else (0, 1),
        )
    if "roofline" in which:
        from benchmarks import roofline
        rows += roofline.run("single")
    if "kernels" in which:
        from benchmarks import kernels_bench
        rows += kernels_bench.run()

    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']:.5f}")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()

"""Roofline benchmark: reads the dry-run artifacts (experiments/dryrun/*.json)
and emits the per-(arch x shape) roofline terms — compute / memory /
collective seconds, dominant bottleneck, and useful-FLOPs ratio.

Run the dry-run first:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all
"""

from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def run(mesh: str = "single") -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*_{mesh}.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            rows.append({
                "name": f"roofline/{rec['arch']}/{rec['shape']}",
                "us_per_call": -1.0,
                "derived": -1.0,
                "extra": {"status": rec.get("status"),
                          "reason": rec.get("reason", rec.get("error", ""))[:120]},
            })
            continue
        rl = rec["roofline"]
        dom = max(("t_compute_s", "t_memory_s", "t_collective_s"),
                  key=lambda k: rl[k])
        rows.append({
            "name": f"roofline/{rec['arch']}/{rec['shape']}",
            # dominant term in microseconds = the step-time lower bound
            "us_per_call": 1e6 * rl[dom],
            "derived": rl["useful_ratio"],
            "extra": {
                "bottleneck": rl["bottleneck"],
                "t_compute_s": rl["t_compute_s"],
                "t_memory_s": rl["t_memory_s"],
                "t_collective_s": rl["t_collective_s"],
                "temp_bytes_per_dev": rec["memory"]["temp_bytes"],
                "compile_s": rec["t_compile_s"],
            },
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']:.4f}")

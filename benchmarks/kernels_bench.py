"""Kernel micro-benchmarks.

On CPU the Pallas kernels run in interpret mode, so wall-times are NOT
hardware-representative; the ``derived`` column therefore reports the
ANALYTIC HBM-traffic ratio (XLA path bytes / kernel path bytes) — the
quantity that determines the TPU speedup for these memory-bound ops —
plus interpret-mode allclose max-error vs. the oracle as a correctness pulse.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.ops import dude_update, flash_attention, flash_decode

F32 = 4


def _time(fn, *args, reps=3):
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def run() -> list[dict]:
    rows = []
    key = jax.random.PRNGKey(0)

    # --- dude_update: fused streaming op ---------------------------------
    n, P = 8, 1 << 14
    ks = jax.random.split(key, 8)
    fresh = jax.random.normal(ks[0], (n, P))
    gw = jax.random.normal(ks[1], (n, P)).astype(jnp.bfloat16)
    infl = jax.random.normal(ks[2], (n, P)).astype(jnp.bfloat16)
    gbar = jax.random.normal(ks[3], (P,))
    w = jax.random.normal(ks[4], (P,))
    cm = jax.random.bernoulli(ks[5], 0.5, (n,))
    sm = jax.random.bernoulli(ks[6], 0.5, (n,))
    t = _time(lambda *a: dude_update(*a, eta=0.1, interpret=True),
              cm, sm, fresh, gw, infl, gbar, w)
    out = dude_update(cm, sm, fresh, gw, infl, gbar, w, eta=0.1, interpret=True)
    rb, *_ = ref.dude_update_ref(gbar, gw, infl, fresh, sm, cm, n)
    err = float(jnp.max(jnp.abs(out[2] - rb)))
    # XLA unfused: ~9 passes over the streams; kernel: 1 read + 1 write each
    xla_bytes = 9 * (2 * n * P * 2 + 2 * P * F32)
    kern_bytes = 2 * (2 * n * P * 2 + n * P * F32 + 2 * P * F32)
    rows.append({
        "name": "kernels/dude_update/fusion_ratio",
        "us_per_call": 1e6 * t,
        "derived": xla_bytes / kern_bytes,
        "extra": {"allclose_err": err},
    })

    # --- flash attention: S^2 HBM traffic removal ------------------------
    B, S, H, K, hd = 1, 256, 4, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, hd))
    kk = jax.random.normal(ks[1], (B, S, K, hd))
    v = jax.random.normal(ks[2], (B, S, K, hd))
    t = _time(lambda *a: flash_attention(*a, blk_q=64, blk_k=64,
                                         interpret=True), q, kk, v)
    o = flash_attention(q, kk, v, blk_q=64, blk_k=64, interpret=True)
    err = float(jnp.max(jnp.abs(o - ref.flash_attention_ref(q, kk, v))))
    io_bytes = (2 * B * S * H * hd + 2 * B * S * K * hd) * F32
    xla_bytes = io_bytes + 2 * B * H * S * S * F32  # materialized scores r+w
    rows.append({
        "name": "kernels/flash_attention/hbm_ratio",
        "us_per_call": 1e6 * t,
        "derived": xla_bytes / io_bytes,
        "extra": {"allclose_err": err},
    })

    # --- flash decode: window skip ----------------------------------------
    Sc, W = 2048, 256
    kc = jax.random.normal(ks[1], (B, Sc, K, hd))
    vc = jax.random.normal(ks[2], (B, Sc, K, hd))
    qd = jax.random.normal(ks[0], (B, 1, H, hd))
    t = _time(lambda *a: flash_decode(*a, window=W, blk_s=256, interpret=True),
              qd, kc, vc, jnp.int32(Sc))
    o = flash_decode(qd, kc, vc, Sc, window=W, blk_s=256, interpret=True)
    # full-cache read vs window-only blocks
    rows.append({
        "name": "kernels/flash_decode/window_skip_ratio",
        "us_per_call": 1e6 * t,
        "derived": Sc / W,
        "extra": {},
    })
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']:.3f}")

"""Kernel micro-benchmarks.

On CPU the Pallas kernels run in interpret mode, so wall-times are NOT
hardware-representative; the ``derived`` column therefore reports the
ANALYTIC HBM-traffic ratio (XLA path bytes / kernel path bytes) — the
quantity that determines the TPU speedup for these memory-bound ops —
plus interpret-mode allclose max-error vs. the oracle as a correctness pulse.

``--backend {reference,indexed,pallas,all}`` additionally sweeps the
ServerEngine round over the selected backends on IDENTICAL inputs at several
(n, P) points — unsharded, and (whenever more than one device is visible,
e.g. under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``) P-axis
sharded over all devices — reporting per-backend round latency and the max
|g_bar| error vs. the reference backend, so the fusion win is measured, not
asserted.

The fused round+apply path (flat-state training) is swept separately:
backend x optimizer (sgd/momentum/adamw) on identical inputs, unsharded and
— with >1 device — P-axis sharded, with max |params| error vs. the
reference-backend flat apply as the correctness pulse.

The session-dispatch sweep times ``api.Trainer.step`` (the one-object
session facade) against the raw prejitted flat step on the identical state
and batch: ``derived`` is facade time / raw time, proving the facade adds
no per-step overhead beyond Python dispatch noise.

The arrival-throughput sweep (async runtime, docs/async.md) times one
server iteration of the per-arrival hot path — ``engine.commit`` + flat
optimizer apply, the AsyncRunner's jitted step — against the masked-step
baseline that expresses the same single arrival as a full ``round_apply``
with one-hot masks (streaming all ``[n, P]`` slabs for one worker's
commit).  Rows report arrivals/sec; ``derived`` is the runner-step
throughput over the masked baseline's.  A full-loop row measures the
``AsyncRunner`` end to end (host event loop + DeviceQueue included) on a
toy gradient.

The unravel sweep (TP-native param feed, docs/engine.md) compares the two
``params_layout`` paths on a real architecture's param shardings over a
(data, model) host mesh: ``replicated`` all-gathers the flat ``[P]`` master
vector onto every device before slicing leaves out, ``tp`` runs the
ppermute ring exchange that feeds each leaf straight from the P-shards.
Rows report measured call time plus the plan's analytic per-device peak
live bytes, ring/gather bytes moved, and the max per-leaf gather bound;
``derived`` for the tp rows is the footprint ratio (replicated full-vector
bytes / tp peak bytes).  Correctness pulse: max error vs. the eager
(placement-free) oracle — 0.0 = bit-for-bit.

The commit-format sweep (compressed slabs, docs/engine.md) prices the
``commit_format`` choices — f32 / int8_ef / topk_ef — on the per-arrival
hot path at several (n, P) points: analytic wire bytes per commit and
resident ``[n, P]`` slab bytes (the HBM win), measured arrivals/sec (the
quantize/dequantize cost), and the max |g_bar| error vs. the f32 engine
checked against the tile-wise quantization bound.

The sparse-transport sweep (docs/engine.md "Sparse commit transport")
prices the ``topk_ef`` SparseRow wire format against the dense topk_ef row
on structurally sparse gradients (a fixed number of touched 128-lane
tiles): actual wire bytes per commit (O(k * tiles_touched) vs O(P)),
measured server-side fold and worker-side encode throughput, and a bitwise
|g_bar| pulse — the sparse scatter-fold must equal the dense commit
bit-for-bit.

The transport sweep (docs/async.md "Multi-host transport") prices the
framed wire hop itself: the same 2-link hosted run (HostRunner + two
run_worker client threads, full protocol incl. handshake/snapshots/
heartbeats) over in-proc queues vs real loopback sockets — arrivals/sec
and framed byte totals each way; the in-proc row is the protocol-only
ceiling, the delta is the OS socket cost.

``--json-out`` (default ``benchmarks/BENCH_9.json``) writes every row as
machine-readable JSON — backend x (n, P) x sharded/unsharded, the
round+apply grid, the session-dispatch rows, the arrival-throughput rows,
the commit-format rows, the sparse-transport rows, the transport rows,
and the unravel rows — so the perf trajectory is tracked across PRs.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import BACKENDS, DuDeEngine
from repro.core.flatten import make_flat_spec
from repro.kernels import ref
from repro.kernels.ops import dude_update, flash_attention, flash_decode
from repro.optim import flat_adamw, flat_momentum_sgd, flat_sgd
from repro.sharding import flat_train_state_shardings

F32 = 4

ENGINE_POINTS = ((8, 1 << 12), (16, 1 << 14), (64, 1 << 16))

FLAT_OPTS = {
    "sgd": flat_sgd(0.05),
    "momentum": flat_momentum_sgd(0.05),
    "adamw": flat_adamw(0.01, weight_decay=0.01),
}


def _time(fn, *args, reps=3):
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def engine_sweep(backends=BACKENDS, points=ENGINE_POINTS,
                 commit_frac: float = 0.25, sharded: bool = False) -> list[dict]:
    """Time one ServerEngine round per backend on identical random inputs.

    ``derived`` reports the ANALYTIC HBM-traffic ratio of each backend's
    round vs. the reference masked sweep (~9 unfused passes over the five
    streams, per the seed's estimate): reference is the baseline (1.0), the
    fused pallas kernel does one read + one write per stream (2 passes =>
    4.5x), and the indexed backend — given the static active-set bound
    ``index_width = k`` the benchmark wires in, matching the Bernoulli mask
    density — touches only ~(4k+2)P elements twice.

    ``sharded=True`` runs the same rounds mesh-native: EngineState P-axis
    sharded over ALL visible devices, shard_map round (requires >1 device).
    """
    mesh = None
    ndev = 1
    if sharded:
        ndev = jax.device_count()
        if ndev < 2:
            raise ValueError("sharded sweep needs >1 device "
                             "(set --xla_force_host_platform_device_count)")
        mesh = jax.make_mesh((ndev,), ("p",))
    rows = []
    key = jax.random.PRNGKey(42)
    for n, P in points:
        spec = make_flat_spec(jnp.zeros((P,)), mesh_axis_size=ndev)
        ks = jax.random.split(jax.random.fold_in(key, n * P), 5)
        fresh = jax.random.normal(ks[0], (n, P))
        sm = jax.random.bernoulli(ks[1], commit_frac, (n,))
        cm = jax.random.bernoulli(ks[2], commit_frac, (n,))
        # static bound on |C_t| for the indexed backend (the schedule knows
        # this in real runs; here the masks are concrete)
        k = max(1, int(np.sum(np.asarray(sm))), int(np.sum(np.asarray(cm))))
        ref_gbar = None
        for backend in backends:
            eng = DuDeEngine(spec=spec, n_workers=n, backend=backend,
                             index_width=k if backend == "indexed" else None,
                             mesh=mesh, axis_name="p" if mesh else None)
            # pre-populate buffers so the round moves real data
            state = eng.init()._replace(
                g_workers=jax.random.normal(ks[3], (n, P)),
                inflight=jax.random.normal(ks[4], (n, P)),
            )
            if mesh is not None:
                state = jax.device_put(state, eng.shardings())
            step = jax.jit(lambda s, f, a, b, e=eng: e.round(s, f, a, b))
            t = _time(lambda s, f, a, b: step(s, f, a, b)[1],
                      state, fresh, sm, cm)
            _, gbar = step(state, fresh, sm, cm)
            extra = {}
            if backend == "reference":
                ref_gbar = gbar
                extra["gbar_err_vs_reference"] = 0.0
            elif ref_gbar is not None:
                extra["gbar_err_vs_reference"] = float(
                    jnp.max(jnp.abs(gbar - ref_gbar)))
            # one full pass over the five streams (fresh + 2 slabs + gbar x2)
            full = (3 * n + 2) * P * F32
            traffic = {
                "reference": 9 * full,          # the unfused baseline itself
                "pallas": 2 * full,             # one read + one write each
                "indexed": 2 * (4 * k + 2) * P * F32,  # k-row gather/scatter
            }[backend]
            tag = "sharded" if sharded else "unsharded"
            rows.append({
                "name": f"engine/round/{backend}/n{n}_P{P}/{tag}",
                "backend": backend, "n": n, "P": spec.padded_size,
                "sharded": sharded, "devices": ndev,
                "us_per_call": 1e6 * t,
                "derived": 9 * full / traffic,
                "extra": extra,
            })
    return rows


def round_apply_sweep(backends=BACKENDS, opts=tuple(FLAT_OPTS),
                      point=(16, 1 << 14), commit_frac: float = 0.25,
                      sharded: bool = False) -> list[dict]:
    """Time the FUSED round+apply (flat-state training hot path) per
    backend x optimizer on identical inputs.

    The round streams the [n, P] slabs; the apply adds the [P] master
    params plus 0/1/2 slot slabs, all in one pass (one shard_map; the
    pallas backend folds the slot math into the kernel).  ``derived`` is
    the analytic traffic ratio of the UNFUSED baseline (round + separate
    optimizer apply re-reading g_bar/params/slots) over the fused pass.
    Correctness pulse: max |params| error vs. the reference backend.
    """
    mesh = None
    ndev = 1
    if sharded:
        ndev = jax.device_count()
        if ndev < 2:
            raise ValueError("sharded sweep needs >1 device")
        mesh = jax.make_mesh((ndev,), ("p",))
    n, P = point
    rows = []
    key = jax.random.PRNGKey(7)
    spec = make_flat_spec(jnp.zeros((P,)), mesh_axis_size=ndev)
    ks = jax.random.split(key, 6)
    fresh = jax.random.normal(ks[0], (n, P))
    sm = jax.random.bernoulli(ks[1], commit_frac, (n,))
    cm = jax.random.bernoulli(ks[2], commit_frac, (n,))
    w0 = jax.random.normal(ks[5], (spec.padded_size,))
    # static active-set bound for the indexed backend, as in engine_sweep
    k = max(1, int(np.sum(np.asarray(sm))), int(np.sum(np.asarray(cm))))
    for opt_name in opts:
        fopt = FLAT_OPTS[opt_name]
        n_slots = len(jax.tree.leaves(fopt.init_slots(w0)))
        ref_w = None
        for backend in backends:
            eng = DuDeEngine(spec=spec, n_workers=n, backend=backend,
                             index_width=k if backend == "indexed" else None,
                             mesh=mesh, axis_name="p" if mesh else None)
            state = eng.init()._replace(
                g_workers=jax.random.normal(ks[3], (n, spec.padded_size)),
                inflight=jax.random.normal(ks[4], (n, spec.padded_size)),
            )
            w, ost = w0, fopt.init(w0)
            if mesh is not None:
                state = jax.device_put(state, eng.shardings())
                sh = flat_train_state_shardings(spec, mesh, ("p",), ost)
                w = jax.device_put(w, sh.params)
                ost = jax.device_put(ost, sh.opt)
            step = jax.jit(lambda s, f, a, b, w, o, e=eng, fo=fopt:
                           e.round_apply(s, f, a, b, w, o, fo))
            t = _time(lambda s, f, a, b, w, o: step(s, f, a, b, w, o)[2],
                      state, fresh, sm, cm, w, ost)
            _, _, w_new, _ = step(state, fresh, sm, cm, w, ost)
            extra = {}
            if backend == "reference":
                ref_w = w_new
                extra["w_err_vs_reference"] = 0.0
            elif ref_w is not None:
                extra["w_err_vs_reference"] = float(
                    jnp.max(jnp.abs(w_new - ref_w)))
            Pp = spec.padded_size
            # fused: one read + one write of every stream; unfused: the
            # ~9-pass round plus an apply re-reading g_bar/w/slots
            fused = 2 * ((3 * n + 2) * Pp + (1 + n_slots) * Pp) * F32
            unfused = (9 * (3 * n + 2) * Pp
                       + 2 * (2 + 2 * n_slots) * Pp) * F32
            tag = "sharded" if sharded else "unsharded"
            rows.append({
                "name": f"engine/round_apply/{backend}/{opt_name}/"
                        f"n{n}_P{Pp}/{tag}",
                "backend": backend, "optimizer": opt_name,
                "n": n, "P": Pp, "sharded": sharded, "devices": ndev,
                "us_per_call": 1e6 * t,
                "derived": unfused / fused,
                "extra": extra,
            })
    return rows


def session_dispatch_rows(algos=("dude", "fedbuff"), rounds: int = 30
                          ) -> list[dict]:
    """Time ``Trainer.step`` vs the raw prejitted flat step (same state,
    same batch): the session facade must be pure dispatch (ratio ~1)."""
    import jax.numpy as jnp  # noqa: F811 (explicit for the tiny config)
    from repro.api import Trainer, TrainerConfig
    from repro.models.config import ModelConfig

    cfg = ModelConfig(
        name="bench-lm", arch_type="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=64,
        dtype=jnp.float32, remat=False, attn_chunk=16, n_workers=4,
    )
    n = cfg.n_workers
    key = jax.random.PRNGKey(0)
    batch = {
        "tokens": jax.random.randint(key, (n, 2, 32), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (n, 2, 32), 0, cfg.vocab_size),
    }
    sm = cm = jnp.ones(n, bool)
    rows = []
    for algo in algos:
        # facade path: the session object owns state + jit cache
        t = Trainer.create(TrainerConfig(arch=cfg, algo=algo, lr=0.01))
        t.step(batch, sm, cm)  # compile + warm
        t0 = time.perf_counter()
        for _ in range(rounds):
            t.step(batch, sm, cm)
        jax.block_until_ready(t.state)
        facade = (time.perf_counter() - t0) / rounds

        # raw path: identical jitted step, state threaded by hand
        t2 = Trainer.create(TrainerConfig(arch=cfg, algo=algo, lr=0.01))
        raw = jax.jit(t2.step_fn, donate_argnums=(0,))
        state = t2.state
        state, _ = raw(state, batch, sm, cm)  # compile + warm
        t0 = time.perf_counter()
        for _ in range(rounds):
            state, _ = raw(state, batch, sm, cm)
        jax.block_until_ready(state)
        rawt = (time.perf_counter() - t0) / rounds

        rows.append({
            "name": f"session/trainer_step_dispatch/{algo}",
            "algo": algo, "rounds": rounds,
            "us_per_call": 1e6 * facade,
            "derived": facade / rawt,      # facade overhead ratio (~1.0)
            "extra": {"raw_us_per_call": 1e6 * rawt},
        })
    return rows


def arrival_throughput_rows(points=((8, 1 << 14), (64, 1 << 16)),
                            loop_iters: int = 200) -> list[dict]:
    """Arrivals/sec of the async hot path vs the masked-step baseline.

    Per (n, P): the AsyncRunner's jitted arrival step (O(P): commit one
    worker's gradient + flat sgd apply) against a one-hot-masked
    ``round_apply`` (O(nP): the round-mode way to express one arrival,
    streaming every worker slab).  ``derived`` = runner arrivals/sec over
    masked arrivals/sec — the structural win of arrival granularity grows
    linearly in n.  Correctness pulse: with the arriving gradient latched
    in the inflight row, the one-hot round's g_bar equals commit's.
    Plus one end-to-end loop row: ``AsyncRunner.run`` arrivals/sec on a toy
    gradient, host event loop + DeviceQueue included.
    """
    from repro.core.algos import make_async_algo
    from repro.optim import FlatOptState
    from repro.runtime import FixedArrivals
    from repro.runtime.runner import AsyncRunner

    rows = []
    key = jax.random.PRNGKey(11)
    fopt = FLAT_OPTS["sgd"]
    for n, P in points:
        spec = make_flat_spec(jnp.zeros((P,)))
        eng = DuDeEngine(spec=spec, n_workers=n)
        algo = make_async_algo("dude", eng)
        ks = jax.random.split(jax.random.fold_in(key, n * P), 4)
        grad = jax.random.normal(ks[0], (spec.padded_size,))
        state = eng.init()._replace(
            g_workers=jax.random.normal(ks[1], (n, spec.padded_size)),
            inflight=jax.random.normal(ks[2], (n, spec.padded_size)))
        w0 = jax.random.normal(ks[3], (spec.padded_size,))
        ost = fopt.init(w0)
        worker = jnp.int32(1)

        @jax.jit
        def astep(srv, w, o, wk, g, algo=algo, fopt=fopt):
            srv, d = algo.arrival(srv, wk, g)
            t = o.step + 1
            w, sl = fopt.update(w, d, o.slots, t)
            return srv, w, FlatOptState(t, sl)

        t_arr = _time(lambda s, w, o, wk, g: astep(s, w, o, wk, g)[1],
                      state, w0, ost, worker, grad, reps=10)

        # masked-step baseline: same single arrival as a one-hot round
        onehot = jnp.zeros((n,), bool).at[1].set(True)
        fresh = jnp.broadcast_to(grad, (n, spec.padded_size))
        rstep = jax.jit(lambda s, f, a, b, w, o, e=eng, fo=fopt:
                        e.round_apply(s, f, a, b, w, o, fo))
        t_msk = _time(lambda s, f, a, b, w, o: rstep(s, f, a, b, w, o)[2],
                      state, fresh, onehot, onehot, w0, ost, reps=10)

        # correctness pulse: latch grad into the inflight row, then the
        # one-hot commit fold equals the per-arrival commit
        latched = state._replace(
            inflight=state.inflight.at[1].set(grad))
        _, g_commit = eng.commit(state, worker, grad)
        _, g_round = eng.round(latched, fresh, jnp.zeros((n,), bool), onehot)
        err = float(jnp.max(jnp.abs(g_commit - g_round)))
        rows.append({
            "name": f"runtime/arrival_throughput/commit_apply/n{n}_P{P}",
            "n": n, "P": spec.padded_size,
            "us_per_call": 1e6 * t_arr,
            "derived": t_msk / t_arr,   # runner-step speedup over masked
            "extra": {"arrivals_per_s": 1.0 / t_arr,
                      "masked_arrivals_per_s": 1.0 / t_msk,
                      "gbar_err_vs_round": err},
        })

    # end-to-end loop: host scheduling + DeviceQueue + grad included
    n, P0 = 8, 1 << 10
    tree = jnp.zeros((P0,))
    spec = make_flat_spec(tree)
    eng = DuDeEngine(spec=spec, n_workers=n)
    runner = AsyncRunner(eng, "dude", FLAT_OPTS["sgd"],
                         lambda p, b, k: (jnp.sum(p * b), p - b))
    st = runner.init_state(tree)
    sample = lambda i, rng: jnp.full((spec.padded_size,), float(i % 3))

    def loop_once():
        return runner.run(FixedArrivals(np.ones(n)), loop_iters, sample, st,
                          record_every=10 ** 9).state.params

    loop_once()  # compile/warm
    t0 = time.perf_counter()
    jax.block_until_ready(loop_once())
    t_loop = (time.perf_counter() - t0) / loop_iters
    rows.append({
        "name": f"runtime/arrival_throughput/runner_loop/n{n}_P{P0}",
        "n": n, "P": spec.padded_size,
        "us_per_call": 1e6 * t_loop,
        "derived": 1.0 / t_loop,        # arrivals/sec, loop included
        "extra": {"arrivals_per_s": 1.0 / t_loop, "iters": loop_iters},
    })

    # sparse-transport loop: the same end-to-end run over SparseRow commits.
    # The counters make the transport accountable: wire_bytes is what the
    # arrivals actually shipped, snap_encodes/snap_reuses expose the
    # delivery-side encode cache (the init zero-delta is encoded once and
    # shared by all n workers; every applying delivery re-encodes).
    eng_s = DuDeEngine(spec=spec, n_workers=n, commit_format="topk_ef",
                       sparse_meta=True)
    runner_s = AsyncRunner(eng_s, "dude", FLAT_OPTS["sgd"],
                           lambda p, b, k: (jnp.sum(p * b), p - b))
    st_s = runner_s.init_state(tree)

    def loop_sparse():
        return runner_s.run(FixedArrivals(np.ones(n)), loop_iters, sample,
                            st_s, record_every=10 ** 9)

    jax.block_until_ready(loop_sparse().state.params)  # compile/warm
    t0 = time.perf_counter()
    res = loop_sparse()
    jax.block_until_ready(res.state.params)
    t_sloop = (time.perf_counter() - t0) / loop_iters
    rows.append({
        "name": f"runtime/arrival_throughput/runner_loop_sparse/n{n}_P{P0}",
        "n": n, "P": spec.padded_size,
        "us_per_call": 1e6 * t_sloop,
        "derived": 1.0 / t_sloop,       # arrivals/sec, loop included
        "extra": {"arrivals_per_s": 1.0 / t_sloop, "iters": loop_iters,
                  "wire_rows": res.wire_rows, "wire_bytes": res.wire_bytes,
                  "wire_bytes_per_arrival":
                      res.wire_bytes / max(1, res.wire_rows),
                  "snap_encodes": res.snap_encodes,
                  "snap_reuses": res.snap_reuses},
    })
    return rows


def transport_sweep(n: int = 4, P0: int = 1 << 10,
                    total_iters: int = 40) -> list[dict]:
    """The framed multi-host hop: in-proc queues vs real loopback sockets.

    The same 2-link hosted run (``HostRunner.serve`` + two ``run_worker``
    client threads, topk_ef sparse snapshots, f32 commits) over
    ``InProcTransport.pair()`` and over connected ``socket.socketpair()``
    ends — arrivals/sec with the full protocol (handshake, snapshots,
    commits, heartbeats) and the framed byte totals each way.  The delta
    between the two rows is the OS socket cost; the in-proc row is the
    protocol-only ceiling.
    """
    import socket
    import threading

    from repro.runtime.hostloop import HostRunner, run_worker
    from repro.runtime.runner import AsyncRunner
    from repro.runtime.transport import InProcTransport, SocketTransport

    tree = jnp.zeros((P0,))
    spec = make_flat_spec(tree)
    grad_fn = lambda p, b, k: (jnp.sum(p * b), p - b)
    sample = lambda i, rng: jnp.full((spec.padded_size,), float(i % 3))
    groups = [tuple(range(n // 2)), tuple(range(n // 2, n))]

    def hosted_run(make_pair):
        eng = DuDeEngine(spec=spec, n_workers=n, commit_format="topk_ef",
                         sparse_meta=True)
        runner = AsyncRunner(eng, "dude", FLAT_OPTS["sgd"], grad_fn)
        pairs = [make_pair() for _ in range(2)]
        threads = [threading.Thread(
            target=lambda i=i: run_worker(lambda: pairs[i][1], groups[i],
                                          grad_fn, sample, spec,
                                          poll_s=0.02),
            daemon=True) for i in range(2)]
        for t in threads:
            t.start()
        host = HostRunner(runner, heartbeat_s=2.0, dead_after_s=10.0,
                          poll_s=0.01)
        t0 = time.perf_counter()
        res = host.serve([p[0] for p in pairs], total_iters,
                         runner.init_state(tree), seed=0,
                         record_every=10 ** 9)
        dt = time.perf_counter() - t0
        for t in threads:
            t.join(timeout=10)
        return res, dt

    def sock_pair():
        a, b = socket.socketpair()
        return (SocketTransport(a, timeout=10.0),
                SocketTransport(b, timeout=10.0))

    rows = []
    for label, make_pair in (("inproc", InProcTransport.pair),
                             ("socket", sock_pair)):
        res, dt = hosted_run(make_pair)
        per = dt / max(1, res.stats.iters)
        rows.append({
            "name": f"runtime/transport/{label}/n{n}_P{P0}",
            "n": n, "P": spec.padded_size,
            "us_per_call": 1e6 * per,
            "derived": 1.0 / per,       # arrivals/sec, wire included
            "extra": {"arrivals_per_s": 1.0 / per,
                      "iters": res.stats.iters,
                      "wire_sent": res.wire_sent,
                      "wire_recv": res.wire_recv,
                      "commit_bytes_per_arrival":
                          res.wire_recv / max(1, res.stats.iters)},
        })
    return rows


def sparse_transport_sweep(points=((8, 1 << 14), (64, 1 << 16)),
                           tiles_touched: int = 32) -> list[dict]:
    """SparseRow vs dense topk_ef commit transport on structurally sparse
    gradients (docs/engine.md "Sparse commit transport").

    Per (n, P), every worker's gradient touches the SAME ``tiles_touched``
    of the ``P/128`` tiles (a stable hot set — structured sparsity).  The
    shared set matters: the commit stream's error-feedback residual is one
    ``[P]`` vector, so each encode target touches the UNION of all
    previously committed tiles; a per-worker random set would grow that
    union past any fixed cap within a few commits.  The sparse engine's
    cap is ``2 * tiles_touched`` (headroom for the clear-set re-listing of
    previously touched tiles), which the shared hot set never overflows —
    keeping the pulse bitwise.

    * ``wire_bytes_sparse`` / ``wire_bytes_dense`` — actual bytes of one
      commit on the wire: ``sparse_wire_nbytes`` of the encoded row
      (``cap * (2k + 8) + 4``, O(k * tiles_touched)) vs the dense topk_ef
      row (``(2k + 4) * P/128``, O(P)); ``derived`` is the reduction;
    * ``fold_arrivals_per_s`` — the server-side hot path (``sparse_fold`` +
      flat sgd apply, touched tiles only) vs ``dense_arrivals_per_s``
      (dense ``commit`` + apply, streaming the whole row);
    * ``encode_us`` — the worker-side ``encode_sparse_commit`` cost;
    * ``gbar_err_vs_dense`` — max |g_bar| difference after one commit per
      worker, lockstep sparse vs dense.  MUST be exactly 0.0: the sparse
      fold runs the identical elementwise update on gathered lanes and
      scatter-sets the result, so it is bitwise equal to the dense commit.
    """
    from repro.core.algos import make_async_algo
    from repro.core.compression import sparse_wire_nbytes
    from repro.optim import FlatOptState

    rows = []
    key = jax.random.PRNGKey(31)
    fopt = FLAT_OPTS["sgd"]
    rng = np.random.default_rng(5)
    for n, P in points:
        spec = make_flat_spec(jnp.zeros((P,)))
        Pp = spec.padded_size
        dense = DuDeEngine(spec=spec, n_workers=n, commit_format="topk_ef")
        T = dense.codec.n_tiles(Pp)
        touch = min(tiles_touched, T)
        cap = min(2 * touch, T)
        sparse = DuDeEngine(spec=spec, n_workers=n, commit_format="topk_ef",
                            sparse_meta=True, sparse_cap=cap)
        # structurally sparse gradients: one shared hot-tile set (see above)
        k_commit = min(n, 8)
        ks = jax.random.split(jax.random.fold_in(key, n * P), 2)
        g_full = np.asarray(jax.random.normal(ks[0], (k_commit, Pp)))
        mask = np.zeros((T,), bool)
        mask[rng.choice(T, touch, replace=False)] = True
        gs = jnp.asarray(g_full * np.repeat(mask, dense.codec.tile))

        # dense hot path: commit + flat sgd apply (the runner's step)
        algo = make_async_algo("dude", dense)
        w0 = jax.random.normal(ks[1], (Pp,))
        ost = fopt.init(w0)

        @jax.jit
        def dstep(srv, w, o, wk, g, algo=algo, fopt=fopt):
            srv, d = algo.arrival(srv, wk, g)
            t = o.step + 1
            w, sl = fopt.update(w, d, o.slots, t)
            return srv, w, FlatOptState(t, sl)

        dst = dense.init()
        t_dense = _time(lambda s, w, o, wk, g: dstep(s, w, o, wk, g)[1],
                        dst, w0, ost, jnp.int32(1), gs[1 % k_commit],
                        reps=10)

        # sparse split: worker-side encode, server-side fold + apply
        enc = jax.jit(sparse.encode_sparse_commit)
        sst = sparse.init()
        t_enc = _time(lambda s, wk, g: enc(s, wk, g)[1].vals,
                      sst, jnp.int32(1), gs[1 % k_commit], reps=10)
        sst1, wire = enc(sst, jnp.int32(1), gs[1 % k_commit])

        @jax.jit
        def sstep(srv, w, o, wk, row, sparse=sparse, fopt=fopt):
            srv, d = sparse.sparse_fold(srv, wk, row)
            t = o.step + 1
            w, sl = fopt.update(w, d, o.slots, t)
            return srv, w, FlatOptState(t, sl)

        t_fold = _time(lambda s, w, o, wk, r: sstep(s, w, o, wk, r)[1],
                       sst1, w0, ost, jnp.int32(1), wire, reps=10)

        # bitwise pulse: one commit per worker, lockstep dense vs sparse
        dcommit = jax.jit(dense.commit)
        sfold = jax.jit(sparse.sparse_fold)
        d_st, s_st = dense.init(), sparse.init()
        err = 0.0
        for i in range(k_commit):
            d_st, g_d = dcommit(d_st, jnp.int32(i), gs[i])
            s_st, row = enc(s_st, jnp.int32(i), gs[i])
            s_st, g_s = sfold(s_st, jnp.int32(i), row)
            err = max(err, float(jnp.max(jnp.abs(g_d - g_s))))

        wire_sparse = sparse_wire_nbytes(row)
        wire_dense = dense.codec.commit_wire_bytes(Pp)
        rows.append({
            "name": f"compression/sparse_transport/n{n}_P{Pp}"
                    f"_touch{touch}_cap{cap}",
            "n": n, "P": Pp, "tiles": T,
            "tiles_touched": touch, "cap": cap,
            "us_per_call": 1e6 * t_fold,
            "derived": wire_dense / wire_sparse,   # wire-byte reduction
            "extra": {
                "wire_bytes_sparse": wire_sparse,
                "wire_bytes_dense": wire_dense,
                "wire_bytes_sparse_analytic":
                    sparse.codec.commit_wire_bytes(Pp, tiles_touched=cap),
                "fold_arrivals_per_s": 1.0 / t_fold,
                "dense_arrivals_per_s": 1.0 / t_dense,
                "fold_vs_dense": t_dense / t_fold,
                "encode_us": 1e6 * t_enc,
                "gbar_err_vs_dense": err,
            },
        })
    return rows


def commit_format_sweep(points=((8, 1 << 14), (64, 1 << 16))) -> list[dict]:
    """Compressed-slab commit formats vs f32 on the per-arrival hot path.

    Per (n, P) x ``commit_format`` (docs/engine.md "Compressed slabs"):

    * ``bytes_per_arrival`` — the analytic wire payload of ONE commit
      (``CommitCodec.commit_wire_bytes``): f32 moves ``4P``; int8_ef moves
      ``P + 4P/128`` (payload + per-tile scales, ~3.9x less); topk_ef moves
      ``(2k + 4) * P/128`` (k int8 values + k in-tile indices + scale per
      tile);
    * ``slab_bytes`` — resident bytes of one ``[n, P]`` worker slab plus its
      scale slab (``CommitCodec.slab_bytes``; the engine keeps two such
      slabs, stored + in-flight — same ratio);
    * ``arrivals_per_s`` — measured throughput of the jitted arrival step
      (``engine.commit`` + flat sgd apply, the AsyncRunner hot path), so the
      quantize/dequantize math is priced in, not assumed free;
    * ``gbar_err_vs_f32`` — max |g_bar| error against the f32 engine after
      one commit per worker on identical gradients, with the tile-wise
      quantization bound (``quant_bound``) it must respect for int8_ef
      (top-k drops lanes into EF, so its one-shot error is bounded by the
      dropped mass, not the quantization step).

    ``derived`` is the slab-residency reduction (f32 slab bytes / this
    format's).
    """
    from repro.core.algos import make_async_algo
    from repro.core.compression import COMMIT_FORMATS
    from repro.optim import FlatOptState

    rows = []
    key = jax.random.PRNGKey(23)
    fopt = FLAT_OPTS["sgd"]
    for n, P in points:
        spec = make_flat_spec(jnp.zeros((P,)))
        Pp = spec.padded_size
        ks = jax.random.split(jax.random.fold_in(key, n * P), 3)
        grad = jax.random.normal(ks[0], (Pp,))
        w0 = jax.random.normal(ks[1], (Pp,))
        # one distinct gradient per worker for the correctness pulse
        k_commit = min(n, 8)
        gs = jax.random.normal(ks[2], (k_commit, Pp))
        f32_t = None
        f32_gbar = None
        for fmt in COMMIT_FORMATS:
            eng = DuDeEngine(spec=spec, n_workers=n, commit_format=fmt)
            codec = eng.codec
            algo = make_async_algo("dude", eng)
            state = eng.init()
            ost = fopt.init(w0)

            @jax.jit
            def astep(srv, w, o, wk, g, algo=algo, fopt=fopt):
                srv, d = algo.arrival(srv, wk, g)
                t = o.step + 1
                w, sl = fopt.update(w, d, o.slots, t)
                return srv, w, FlatOptState(t, sl)

            t_arr = _time(lambda s, w, o, wk, g: astep(s, w, o, wk, g)[1],
                          state, w0, ost, jnp.int32(1), grad, reps=10)

            # correctness pulse: one commit per worker, vs the f32 engine
            st = state
            commit = jax.jit(eng.commit)
            for i in range(k_commit):
                st, gbar = commit(st, jnp.int32(i), gs[i])
            extra = {
                "arrivals_per_s": 1.0 / t_arr,
                "bytes_per_arrival": codec.commit_wire_bytes(Pp),
                "slab_bytes": codec.slab_bytes(n, Pp),
            }
            if fmt == "f32":
                f32_t, f32_gbar = t_arr, gbar
                extra["gbar_err_vs_f32"] = 0.0
            else:
                extra["gbar_err_vs_f32"] = float(
                    jnp.max(jnp.abs(gbar - f32_gbar)))
                # lane-wise bound: mean over committed rows of each row's
                # per-tile quantization bound (uncommitted rows are 0 = 0)
                bound = sum(np.repeat(np.asarray(codec.quant_bound(gs[i])),
                                      codec.tile) for i in range(k_commit)) / n
                extra["quant_bound_max"] = float(bound.max())
                extra["gbar_err_within_bound"] = (
                    fmt != "int8_ef"
                    or bool((np.abs(np.asarray(gbar - f32_gbar))
                             <= bound + 1e-7).all()))
                extra["bytes_reduction_vs_f32"] = (
                    4 * Pp / codec.commit_wire_bytes(Pp))
                extra["slab_reduction_vs_f32"] = (
                    4 * n * Pp / codec.slab_bytes(n, Pp))
                extra["arrivals_per_s_vs_f32"] = f32_t / t_arr
            rows.append({
                "name": f"compression/commit_format/{fmt}/n{n}_P{Pp}",
                "format": fmt, "n": n, "P": Pp,
                "us_per_call": 1e6 * t_arr,
                "derived": 4 * n * Pp / codec.slab_bytes(n, Pp),
                "extra": extra,
            })
    return rows


def unravel_sweep(arch: str = "qwen2_0_5b", shape=(2, 4),
                  n_workers: int | None = None) -> list[dict]:
    """Replicated vs TP-native param exchange on a (data, model) host mesh.

    Both directions are swept — ``unravel`` ([P] shards -> TP-layout leaves,
    the forward feed) and ``ravel_stacked`` (TP-layout grad leaves ->
    [n, P] slab shards, the reverse path) — on the real ``param_shardings``
    of ``arch``'s smoke config, so the per-leaf exchange plan exercises
    genuine Megatron-TP layouts (embedding, fused-QKV-like kernels, norms).
    """
    from jax.sharding import NamedSharding, PartitionSpec
    from repro.configs import get_config
    from repro.models import lm_init
    from repro.sharding import (
        flat_slab_shardings, flat_vec_sharding, param_shardings,
    )

    d, m = shape
    if jax.device_count() < d * m:
        print(f"# unravel sweep skipped: needs {d * m} devices")
        return []
    mesh = jax.sharding.Mesh(
        np.asarray(jax.devices()[: d * m]).reshape(d, m), ("data", "model"))
    axes = ("data", "model")
    cfg = get_config(arch).smoke()
    n = n_workers or cfg.n_workers
    params = lm_init(jax.random.PRNGKey(0), cfg)
    spec = make_flat_spec(params, mesh_axis_size=d * m)
    p_sh = param_shardings(jax.eval_shape(lambda: params), mesh)
    plan = spec.tp_plan(mesh, p_sh, axes=axes)

    flat = jax.device_put(spec.ravel(params),
                          flat_vec_sharding(spec, mesh, axes))
    repl_sh = NamedSharding(mesh, PartitionSpec())
    unravel_repl = jax.jit(lambda f: spec.unravel(
        jax.lax.with_sharding_constraint(f, repl_sh)))
    unravel_tp = jax.jit(lambda f: spec.unravel_sharded(f, mesh, plan=plan))

    oracle = jax.tree.leaves(unravel_repl(flat))
    err = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                    - b.astype(jnp.float32))))
              for a, b in zip(jax.tree.leaves(unravel_tp(flat)), oracle))

    k = plan.k
    repl_gather = plan.full_vector_bytes * (k - 1) // k  # all-gather payload
    footprint = {  # per-device peak live bytes for the params feed
        "replicated": plan.full_vector_bytes,
        "tp": plan.peak_bytes,
    }
    moved = {"replicated": repl_gather, "tp": plan.ring_bytes}
    rows = []
    for layout, fn in (("replicated", unravel_repl), ("tp", unravel_tp)):
        t = _time(lambda f: jax.tree.leaves(fn(f))[0], flat)
        rows.append({
            "name": f"exchange/unravel/{layout}/{arch}_{d}x{m}",
            "layout": layout, "P": spec.padded_size, "devices": d * m,
            "us_per_call": 1e6 * t,
            "derived": plan.full_vector_bytes / footprint[layout],
            "extra": {
                "peak_live_bytes_per_device": footprint[layout],
                "exchange_bytes_per_device": moved[layout],
                "max_leaf_gather_bytes": plan.max_leaf_segment_bytes(),
                "err_vs_replicated": 0.0 if layout == "replicated" else err,
            },
        })

    # reverse path: TP-layout stacked grads -> [n, P] slab shards.  Each
    # layout is fed ITS OWN natural input placement (the replicated path's
    # grads come out of a replicated-params forward; the tp path's out of a
    # TP forward), and both are checked against the placement-free eager
    # oracle — letting GSPMD auto-partition the ravel from TP-placed leaves
    # is not only O(nP) per device, it MISCOMPILES on this jax version
    # (reshape+concat over mixed 2-D-sharded operands returns permuted
    # rows; the explicit shard_map ring sidesteps the partitioner).
    stree = jax.tree.map(
        lambda x: jnp.stack([x * (i + 1) for i in range(n)]), params)
    want = spec.ravel_stacked(stree)  # eager oracle, placement-free
    g_sh = spec.treedef.unflatten(
        [NamedSharding(mesh, PartitionSpec(None, *lf.entries))
         for lf in plan.leaves])
    stree_tp = jax.device_put(stree, g_sh)
    stree_repl = jax.device_put(stree, NamedSharding(mesh, PartitionSpec()))
    slab_sh = flat_slab_shardings(
        jax.ShapeDtypeStruct((n, spec.padded_size), jnp.float32),
        spec, mesh, axes)
    ravel_repl = jax.jit(lambda t: jax.lax.with_sharding_constraint(
        spec.ravel_stacked(t), slab_sh))
    ravel_tp = jax.jit(lambda t: spec.ravel_stacked_sharded(
        t, mesh, plan=plan))
    for layout, fn, inp in (("replicated", ravel_repl, stree_repl),
                            ("tp", ravel_tp, stree_tp)):
        rerr = float(jnp.max(jnp.abs(fn(inp) - want)))
        t = _time(fn, inp)
        full = n * plan.full_vector_bytes
        peak = full if layout == "replicated" else n * plan.peak_bytes
        rows.append({
            "name": f"exchange/ravel_stacked/{layout}/{arch}_{d}x{m}",
            "layout": layout, "P": spec.padded_size, "n": n,
            "devices": d * m, "us_per_call": 1e6 * t,
            "derived": full / peak,
            "extra": {"err_vs_oracle": rerr},
        })
    return rows


def scenario_grid_rows(iters: int = 150,
                       dropout_rates=(0.0, 0.2),
                       hets=(1.0, 5.0),
                       algos=("dude", "dude_hinge", "dude_poly",
                              "vanilla_asgd")) -> list[dict]:
    """BENCH_10 scenario grid: dropout-rate x heterogeneity x staleness
    rule, end-to-end through ``AsyncRunner`` under a ``ClientStateProcess``
    (mid-round dropout + reconnect-from-stale-snapshot).  Each cell runs the
    N-worker closed-form quadratic so ``derived`` is the exact
    ||grad F||^2 at the final iterate — a convergence-quality number, not a
    timing — while ``us_per_call`` keeps the loop's arrival latency and
    ``extra`` records tau_max plus the trace's dropout telemetry."""
    from repro.optim import flat_sgd
    from repro.runtime import ClientStateProcess, FixedArrivals
    from repro.runtime.runner import AsyncRunner

    n, P = 8, 64
    rows = []
    for het in hets:
        rng = np.random.default_rng(17)
        A = np.stack([np.diag(rng.uniform(0.5, 2.0, P)) for _ in range(n)])
        b = np.stack([rng.normal(size=P) * het for _ in range(n)])
        Abar, bbar = A.mean(axis=0), b.mean(axis=0)
        Aj = jnp.asarray(A, jnp.float32)
        bj = jnp.asarray(b, jnp.float32)

        def grad_fn(params, batch, key, Aj=Aj, bj=bj):
            Ai, bi = Aj[batch], bj[batch]
            g = Ai @ params - bi + 0.05 * jax.random.normal(key, (P,))
            return 0.5 * params @ Ai @ params - bi @ params, g

        sample_fn = (lambda i, rng_: jnp.int32(i))

        for drop in dropout_rates:
            for name in algos:
                eng = DuDeEngine(spec=make_flat_spec(jnp.zeros(P)),
                                 n_workers=n)
                runner = AsyncRunner(eng, name, flat_sgd(0.03), grad_fn)
                st = runner.init_state(jnp.zeros(P))
                proc = ClientStateProcess(
                    FixedArrivals(np.linspace(0.6, 2.0, n)),
                    seed=23, dropout_rate=drop,
                    reconnect_mean=1.0 if drop else None)
                t0 = time.perf_counter()
                res = runner.run(proc, iters, sample_fn, st, seed=0,
                                 record_every=10 ** 9)
                jax.block_until_ready(res.state.params)
                t_loop = (time.perf_counter() - t0) / iters
                w = np.asarray(eng.spec.unravel(res.state.params))
                stats = res.stats.trace.event_stats()
                rows.append({
                    "name": f"scenario_grid/het{het}/drop{drop}/{name}",
                    "n": n, "P": eng.spec.padded_size,
                    "us_per_call": 1e6 * t_loop,
                    "derived": float(np.sum((Abar @ w - bbar) ** 2)),
                    "extra": {"tau_max": int(res.tau_max),
                              "arrivals_per_s": 1.0 / t_loop,
                              "dropouts": stats.get("dropouts", 0),
                              "outage_time": stats.get("outage_time", 0.0)},
                })
    return rows


def run(backend: str = "all") -> list[dict]:
    backends = BACKENDS if backend == "all" else (backend,)
    rows = engine_sweep(backends)
    rows += round_apply_sweep(backends)
    rows += session_dispatch_rows()
    rows += arrival_throughput_rows()
    rows += scenario_grid_rows()
    rows += commit_format_sweep()
    rows += sparse_transport_sweep()
    rows += transport_sweep()
    if jax.device_count() > 1:
        rows += engine_sweep(backends, sharded=True)
        rows += round_apply_sweep(backends, sharded=True)
        rows += unravel_sweep()
    else:
        print("# sharded engine + unravel sweeps skipped: 1 device "
              "(set XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    key = jax.random.PRNGKey(0)

    # --- dude_update: fused streaming op ---------------------------------
    n, P = 8, 1 << 14
    ks = jax.random.split(key, 8)
    fresh = jax.random.normal(ks[0], (n, P))
    gw = jax.random.normal(ks[1], (n, P)).astype(jnp.bfloat16)
    infl = jax.random.normal(ks[2], (n, P)).astype(jnp.bfloat16)
    gbar = jax.random.normal(ks[3], (P,))
    w = jax.random.normal(ks[4], (P,))
    cm = jax.random.bernoulli(ks[5], 0.5, (n,))
    sm = jax.random.bernoulli(ks[6], 0.5, (n,))
    t = _time(lambda *a: dude_update(*a, eta=0.1, interpret=True),
              cm, sm, fresh, gw, infl, gbar, w)
    out = dude_update(cm, sm, fresh, gw, infl, gbar, w, eta=0.1, interpret=True)
    rb, *_ = ref.dude_update_ref(gbar, gw, infl, fresh, sm, cm, n)
    err = float(jnp.max(jnp.abs(out[2] - rb)))
    # XLA unfused: ~9 passes over the streams; kernel: 1 read + 1 write each
    xla_bytes = 9 * (2 * n * P * 2 + 2 * P * F32)
    kern_bytes = 2 * (2 * n * P * 2 + n * P * F32 + 2 * P * F32)
    rows.append({
        "name": "kernels/dude_update/fusion_ratio",
        "us_per_call": 1e6 * t,
        "derived": xla_bytes / kern_bytes,
        "extra": {"allclose_err": err},
    })

    # --- flash attention: S^2 HBM traffic removal ------------------------
    B, S, H, K, hd = 1, 256, 4, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, hd))
    kk = jax.random.normal(ks[1], (B, S, K, hd))
    v = jax.random.normal(ks[2], (B, S, K, hd))
    t = _time(lambda *a: flash_attention(*a, blk_q=64, blk_k=64,
                                         interpret=True), q, kk, v)
    o = flash_attention(q, kk, v, blk_q=64, blk_k=64, interpret=True)
    err = float(jnp.max(jnp.abs(o - ref.flash_attention_ref(q, kk, v))))
    io_bytes = (2 * B * S * H * hd + 2 * B * S * K * hd) * F32
    xla_bytes = io_bytes + 2 * B * H * S * S * F32  # materialized scores r+w
    rows.append({
        "name": "kernels/flash_attention/hbm_ratio",
        "us_per_call": 1e6 * t,
        "derived": xla_bytes / io_bytes,
        "extra": {"allclose_err": err},
    })

    # --- flash decode: window skip ----------------------------------------
    Sc, W = 2048, 256
    kc = jax.random.normal(ks[1], (B, Sc, K, hd))
    vc = jax.random.normal(ks[2], (B, Sc, K, hd))
    qd = jax.random.normal(ks[0], (B, 1, H, hd))
    t = _time(lambda *a: flash_decode(*a, window=W, blk_s=256, interpret=True),
              qd, kc, vc, jnp.int32(Sc))
    o = flash_decode(qd, kc, vc, Sc, window=W, blk_s=256, interpret=True)
    # full-cache read vs window-only blocks
    rows.append({
        "name": "kernels/flash_decode/window_skip_ratio",
        "us_per_call": 1e6 * t,
        "derived": Sc / W,
        "extra": {},
    })
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", default="all",
                    choices=list(BACKENDS) + ["all"],
                    help="ServerEngine backend(s) to sweep")
    ap.add_argument("--json-out", default="benchmarks/BENCH_10.json",
                    help="write rows as machine-readable JSON here "
                         "('' disables)")
    args = ap.parse_args()
    rows = run(backend=args.backend)
    for r in rows:
        extra = r.get("extra") or {}
        tail = "".join(f",{k}={v:.3g}" for k, v in extra.items())
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']:.3f}{tail}")
    if args.json_out:
        os.makedirs(os.path.dirname(args.json_out) or ".", exist_ok=True)
        with open(args.json_out, "w") as f:
            json.dump({
                "pr": 10,
                "device_count": jax.device_count(),
                "platform": jax.default_backend(),
                "rows": rows,
            }, f, indent=1)
        print(f"# wrote {len(rows)} rows to {args.json_out}")

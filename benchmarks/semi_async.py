"""Ablation for the paper's semi-asynchronous variant (§3): sweep the number
of completions |C_t| = c the server waits for per model update.

The paper's claim: tau_max^(c) = tau_max / c — waiting for more workers cuts
the model delay proportionally (at the cost of throughput), interpolating
between fully-async DuDe (c=1) and sync-flavored aggregation (c=n).
``derived`` = final E||grad F||^2; extras record tau_max and sim wall-clock.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import make_algo, simulate, truncated_normal_speeds

N, P = 8, 10


def run(iters: int = 400, seeds=(0, 1)) -> list[dict]:
    rng = np.random.default_rng(0)
    A = [np.diag(rng.uniform(0.5, 2.0, P)) for _ in range(N)]
    b = [rng.normal(size=P) * 5.0 for _ in range(N)]
    Abar, bbar = sum(A) / N, sum(b) / N

    def grad_fn(params, batch, key):
        Ai, bi = batch
        return (0.5 * params @ Ai @ params - bi @ params,
                Ai @ params - bi + 0.05 * jax.random.normal(key, (P,)))

    def sample_fn(i, rng_):
        return (jnp.asarray(A[i], jnp.float32), jnp.asarray(b[i], jnp.float32))

    rows = []
    for c in (1, 2, 4, 8):
        gsq, taus, times, wall = [], [], [], []
        for seed in seeds:
            speeds = truncated_normal_speeds(N, std=5.0, seed=seed + 3)
            algo = make_algo("dude_semi", N, c=c) if c > 1 else \
                make_algo("dude_asgd", N)
            t0 = time.perf_counter()
            res = simulate(algo, speeds, grad_fn, sample_fn, jnp.zeros(P),
                           lr=0.03, total_iters=iters // c + 50,
                           record_every=10_000, seed=seed)
            wall.append(time.perf_counter() - t0)
            w = np.asarray(res.params)
            gsq.append(float(np.sum((Abar @ w - bbar) ** 2)))
            taus.append(res.tau_max)
            times.append(res.times[-1] if len(res.times) else float("nan"))
        rows.append({
            "name": f"semi_async/dude_c{c}",
            "us_per_call": 1e6 * float(np.mean(wall)) / iters,
            "derived": float(np.mean(gsq)),
            "extra": {"tau_max": float(np.mean(taus))},
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']:.5f},"
              f"tau={r['extra']['tau_max']}")

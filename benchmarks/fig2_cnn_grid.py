"""Benchmark for paper Figures 2 & 3: CNN classification under Dirichlet
label skew and heterogeneous worker speeds.

Grid: alpha x std (Fig 2: n=10, alpha in {0.1, 0.5}; Fig 3: n=30, alpha in
{0.05, 0.1}), std in {1, 5}.  The y-axes are training loss and test accuracy
against simulated wall-clock — reproduced here at reduced scale (CPU): the
class-Gaussian CIFAR-like dataset preserves the Dirichlet-skew phenomenon the
figures measure (data substitution noted in DESIGN.md §6).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import make_algo, simulate, truncated_normal_speeds
from repro.data import class_gaussian_images, dirichlet_partition, make_sample_fn
from repro.models.cnn import cnn_accuracy, cnn_init, cnn_loss

ALGOS = ("dude_asgd", "vanilla_asgd", "uniform_asgd", "sync_sgd", "fedbuff")


def run(n: int = 10, alphas=(0.1, 0.5), stds=(1.0, 5.0), iters: int = 120,
        seeds=(0,), n_data: int = 4000, batch: int = 32) -> list[dict]:
    x, y = class_gaussian_images(n=n_data, seed=0)
    xe, ye = jnp.asarray(x[:512]), jnp.asarray(y[:512])

    def grad_fn(params, b, key):
        return jax.value_and_grad(cnn_loss)(params, b)

    rows = []
    for alpha in alphas:
        for std in stds:
            for name in ALGOS:
                accs, losses, wall = [], [], []
                for seed in seeds:
                    shards = dirichlet_partition(y, n, alpha, seed=seed)
                    snp = make_sample_fn(x, y, shards, batch, seed=seed)

                    def sample_fn(i, rng):
                        b = snp(i, rng)
                        return {"x": jnp.asarray(b["x"]),
                                "y": jnp.asarray(b["y"])}

                    speeds = truncated_normal_speeds(n, std=std, seed=seed + 5)
                    t0 = time.perf_counter()
                    res = simulate(
                        make_algo(name, n), speeds, grad_fn, sample_fn,
                        cnn_init(jax.random.PRNGKey(seed)), lr=0.01,
                        total_iters=iters, record_every=10_000, seed=seed,
                    )
                    wall.append(time.perf_counter() - t0)
                    accs.append(float(cnn_accuracy(res.params, xe, ye)))
                    losses.append(
                        float(cnn_loss(res.params, {"x": xe, "y": ye}))
                    )
                rows.append({
                    "name": f"fig2/n{n}/a{alpha}/std{std}/{name}",
                    "us_per_call": 1e6 * float(np.mean(wall)) / iters,
                    "derived": float(np.mean(accs)),
                    "extra": {"loss": float(np.mean(losses))},
                })
    return rows


def run_scenarios(n: int = 10, alphas=(0.1, 0.5), dropout_rates=(0.0, 0.2),
                  iters: int = 120, seeds=(0,), n_data: int = 4000,
                  batch: int = 32) -> list[dict]:
    """Client-state scenario grid (PR 10): dropout-rate x label-skew alpha,
    DuDe vs vanilla ASGD, each run under a ``ClientStateProcess`` with
    mid-round dropout + reconnect and skew-correlated availability (the
    most label-skewed shards are also the flakiest clients).  ``derived`` is
    test accuracy; ``extra`` carries the trace's client-state telemetry so
    the benchmark records how much chaos each run actually absorbed."""
    from repro.data import label_distribution
    from repro.runtime import (ClientStateProcess, FixedArrivals,
                               SkewAvailability)

    x, y = class_gaussian_images(n=n_data, seed=0)
    xe, ye = jnp.asarray(x[:512]), jnp.asarray(y[:512])

    def grad_fn(params, b, key):
        return jax.value_and_grad(cnn_loss)(params, b)

    rows = []
    for alpha in alphas:
        for drop in dropout_rates:
            for name in ("dude_asgd", "vanilla_asgd"):
                accs, losses, wall, stats = [], [], [], []
                for seed in seeds:
                    shards = dirichlet_partition(y, n, alpha, seed=seed)
                    snp = make_sample_fn(x, y, shards, batch, seed=seed)

                    def sample_fn(i, rng):
                        b = snp(i, rng)
                        return {"x": jnp.asarray(b["x"]),
                                "y": jnp.asarray(b["y"])}

                    dist = label_distribution(y, shards)
                    skew = dist.max(axis=1)
                    skew = (skew - skew.min()) / max(
                        1e-9, float(np.ptp(skew)))
                    speeds = truncated_normal_speeds(n, std=1.0,
                                                     seed=seed + 5)
                    proc = ClientStateProcess(
                        FixedArrivals(np.asarray(speeds.times)),
                        seed=seed + 21, dropout_rate=drop,
                        reconnect_mean=2.0 if drop else None,
                        availability=SkewAvailability(skew))
                    t0 = time.perf_counter()
                    res = simulate(
                        make_algo(name, n), speeds, grad_fn, sample_fn,
                        cnn_init(jax.random.PRNGKey(seed)), lr=0.01,
                        total_iters=iters, record_every=10_000, seed=seed,
                        arrivals=proc,
                    )
                    wall.append(time.perf_counter() - t0)
                    accs.append(float(cnn_accuracy(res.params, xe, ye)))
                    losses.append(
                        float(cnn_loss(res.params, {"x": xe, "y": ye})))
                    stats.append(res.trace.event_stats())
                rows.append({
                    "name": f"fig2scenario/n{n}/a{alpha}/drop{drop}/{name}",
                    "us_per_call": 1e6 * float(np.mean(wall)) / iters,
                    "derived": float(np.mean(accs)),
                    "extra": {
                        "loss": float(np.mean(losses)),
                        "dropouts": float(np.mean(
                            [s["dropouts"] for s in stats])),
                        "wait_time": float(np.mean(
                            [s["wait_time"] for s in stats])),
                        "outage_time": float(np.mean(
                            [s["outage_time"] for s in stats])),
                    },
                })
    return rows


if __name__ == "__main__":
    for r in run() + run_scenarios():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']:.4f}")


def run_timed(n: int = 10, alphas=(0.1,), stds=(1.0, 5.0),
              time_budget_rounds: int = 40, seeds=(0,), n_data: int = 4000,
              batch: int = 32) -> list[dict]:
    """Paper-faithful comparison axis: EQUAL SIMULATED WALL-CLOCK for every
    algorithm (the paper's Fig 2/3 x-axis), instead of equal server
    iterations.  Budget = time_budget_rounds * max(s_i), i.e. what sync SGD
    needs for that many rounds; async algorithms get their natural multiple
    of updates within it."""
    import time as _time
    x, y = class_gaussian_images(n=n_data, seed=0)
    xe, ye = jnp.asarray(x[:512]), jnp.asarray(y[:512])

    def grad_fn(params, b, key):
        return jax.value_and_grad(cnn_loss)(params, b)

    rows = []
    for alpha in alphas:
        for std in stds:
            speeds0 = truncated_normal_speeds(n, std=std, seed=5, floor=0.25)
            budget = time_budget_rounds * float(np.max(speeds0.times))
            for name in ALGOS:
                accs, wall = [], []
                for seed in seeds:
                    shards = dirichlet_partition(y, n, alpha, seed=seed)
                    snp = make_sample_fn(x, y, shards, batch, seed=seed)

                    def sample_fn(i, rng):
                        b = snp(i, rng)
                        return {"x": jnp.asarray(b["x"]),
                                "y": jnp.asarray(b["y"])}

                    t0 = _time.perf_counter()
                    res = simulate(
                        make_algo(name, n), speeds0, grad_fn, sample_fn,
                        cnn_init(jax.random.PRNGKey(seed)), lr=0.01,
                        total_iters=10_000_000, max_time=budget,
                        record_every=10_000, seed=seed,
                    )
                    wall.append(_time.perf_counter() - t0)
                    accs.append(float(cnn_accuracy(res.params, xe, ye)))
                rows.append({
                    "name": f"fig2timed/n{n}/a{alpha}/std{std}/{name}",
                    "us_per_call": 1e6 * float(np.mean(wall)),
                    "derived": float(np.mean(accs)),
                    "extra": {},
                })
    return rows

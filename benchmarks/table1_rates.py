"""Benchmark for paper Table 1: empirical convergence of all 7 algorithms on
a synthetic heterogeneous problem with closed-form gradients.

Measures E||grad F||^2 after a fixed budget of simulated wall-clock time (the
x-axis the paper uses), at two heterogeneity levels.  Verifies the table's
qualitative ordering: DuDe reaches stationarity regardless of zeta; vanilla /
uniform / shuffled ASGD plateau at a zeta-dependent bias; sync SGD is unbiased
but straggler-bound.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ALGO_NAMES, make_algo, simulate, truncated_normal_speeds

N, P = 8, 10


def _problem(het, seed=0):
    rng = np.random.default_rng(seed)
    A = [np.diag(rng.uniform(0.5, 2.0, P)) for _ in range(N)]
    b = [rng.normal(size=P) * het for _ in range(N)]
    Abar, bbar = sum(A) / N, sum(b) / N

    def grad_fn(params, batch, key):
        Ai, bi = batch
        g = Ai @ params - bi + 0.05 * jax.random.normal(key, (P,))
        return 0.5 * params @ Ai @ params - bi @ params, g

    def sample_fn(i, rng_):
        return (jnp.asarray(A[i], jnp.float32), jnp.asarray(b[i], jnp.float32))

    def grad_norm_sq(w):
        w = np.asarray(w)
        return float(np.sum((Abar @ w - bbar) ** 2))

    return grad_fn, sample_fn, grad_norm_sq


def run(iters: int = 600, seeds=(0, 1, 2)) -> list[dict]:
    rows = []
    for het in (1.0, 5.0):
        for name in ALGO_NAMES:
            gsqs, wall, n_grads = [], [], []
            for seed in seeds:
                grad_fn, sample_fn, gnsq = _problem(het, seed)
                speeds = truncated_normal_speeds(N, std=1.0, seed=seed + 10)
                t0 = time.perf_counter()
                res = simulate(make_algo(name, N), speeds, grad_fn, sample_fn,
                               jnp.zeros(P), lr=0.03, total_iters=iters,
                               record_every=10_000, seed=seed)
                wall.append(time.perf_counter() - t0)
                gsqs.append(gnsq(res.params))
                n_grads.append(res.n_grads)
            rows.append({
                "name": f"table1/{name}/het{het}",
                "us_per_call": 1e6 * float(np.mean(wall)) / iters,
                "derived": float(np.mean(gsqs)),
                "extra": {"grad_norm_sq_std": float(np.std(gsqs)),
                          "n_grads": int(np.mean(n_grads))},
            })
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']:.5f}")

"""Benchmark for paper Table 1: empirical convergence of all 7 algorithms on
a synthetic heterogeneous problem with closed-form gradients.

Measures E||grad F||^2 after a fixed budget of simulated wall-clock time (the
x-axis the paper uses), at two heterogeneity levels.  Verifies the table's
qualitative ordering: DuDe reaches stationarity regardless of zeta; vanilla /
uniform / shuffled ASGD plateau at a zeta-dependent bias; sync SGD is unbiased
but straggler-bound.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ALGO_NAMES, make_algo, simulate, truncated_normal_speeds
from repro.core.engine import DuDeEngine
from repro.core.flatten import make_flat_spec

N, P = 8, 10


def _problem(het, seed=0):
    rng = np.random.default_rng(seed)
    A = [np.diag(rng.uniform(0.5, 2.0, P)) for _ in range(N)]
    b = [rng.normal(size=P) * het for _ in range(N)]
    Abar, bbar = sum(A) / N, sum(b) / N

    def grad_fn(params, batch, key):
        Ai, bi = batch
        g = Ai @ params - bi + 0.05 * jax.random.normal(key, (P,))
        return 0.5 * params @ Ai @ params - bi @ params, g

    def sample_fn(i, rng_):
        return (jnp.asarray(A[i], jnp.float32), jnp.asarray(b[i], jnp.float32))

    def grad_norm_sq(w):
        w = np.asarray(w)
        return float(np.sum((Abar @ w - bbar) ** 2))

    return grad_fn, sample_fn, grad_norm_sq


def run(iters: int = 600, seeds=(0, 1, 2)) -> list[dict]:
    rows = []
    for het in (1.0, 5.0):
        for name in ALGO_NAMES:
            gsqs, wall, n_grads = [], [], []
            for seed in seeds:
                grad_fn, sample_fn, gnsq = _problem(het, seed)
                speeds = truncated_normal_speeds(N, std=1.0, seed=seed + 10)
                t0 = time.perf_counter()
                res = simulate(make_algo(name, N), speeds, grad_fn, sample_fn,
                               jnp.zeros(P), lr=0.03, total_iters=iters,
                               record_every=10_000, seed=seed)
                wall.append(time.perf_counter() - t0)
                gsqs.append(gnsq(res.params))
                n_grads.append(res.n_grads)
            rows.append({
                "name": f"table1/{name}/het{het}",
                "us_per_call": 1e6 * float(np.mean(wall)) / iters,
                "derived": float(np.mean(gsqs)),
                "extra": {"grad_norm_sq_std": float(np.std(gsqs)),
                          "n_grads": int(np.mean(n_grads))},
            })
    return rows


def run_scenarios(iters: int = 400, seeds=(0, 1),
                  dropout_rates=(0.0, 0.3),
                  rules=("dude", "dude_hinge", "dude_poly",
                         "vanilla_asgd")) -> list[dict]:
    """Scenario extension of Table 1 (PR 10): dropout-rate x staleness-rule
    on the same closed-form quadratic, driven through ``AsyncRunner`` (the
    staleness-adaptive family only exists at arrival granularity).  Dropout
    with reconnect-from-stale-snapshot inflates tau, which is exactly the
    regime the hinge/poly weights are built for; ``derived`` is the exact
    ||grad F||^2 oracle at the final iterate."""
    from repro.optim import flat_sgd
    from repro.runtime import ClientStateProcess, FixedArrivals
    from repro.runtime.runner import AsyncRunner

    rows = []
    for het in (1.0, 5.0):
        for drop in dropout_rates:
            for name in rules:
                gsqs, wall, taus = [], [], []
                for seed in seeds:
                    grad_fn, sample_fn, gnsq = _problem(het, seed)
                    speeds = truncated_normal_speeds(N, std=1.0,
                                                    seed=seed + 10)
                    eng = DuDeEngine(spec=make_flat_spec(jnp.zeros(P)),
                                     n_workers=N)
                    runner = AsyncRunner(eng, name, flat_sgd(0.03), grad_fn)
                    st = runner.init_state(jnp.zeros(P))
                    proc = ClientStateProcess(
                        FixedArrivals(np.asarray(speeds.times)),
                        seed=seed + 31, dropout_rate=drop,
                        reconnect_mean=1.0 if drop else None)
                    t0 = time.perf_counter()
                    res = runner.run(proc, iters, sample_fn, st, seed=seed,
                                     record_every=10_000)
                    wall.append(time.perf_counter() - t0)
                    gsqs.append(gnsq(eng.spec.unravel(res.state.params)))
                    taus.append(res.tau_max)
                rows.append({
                    "name": f"table1scenario/{name}/het{het}/drop{drop}",
                    "us_per_call": 1e6 * float(np.mean(wall)) / iters,
                    "derived": float(np.mean(gsqs)),
                    "extra": {"grad_norm_sq_std": float(np.std(gsqs)),
                              "tau_max": int(np.max(taus))},
                })
    return rows


if __name__ == "__main__":
    for r in run() + run_scenarios():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']:.5f}")

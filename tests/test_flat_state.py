"""Flat-state training acceptance tests.

The FlatTrainState path keeps master params and optimizer slots in the
engine's segment-range ``[P]`` slab layout and fuses the DuDe round with the
optimizer apply (``DuDeEngine.round_apply``).  This file proves:

* flat-vs-pytree optimizer equivalence: N steps of the flat apply on raveled
  state match the pytree apply bit-for-bit after unravel, for
  sgd/momentum/adamw on all three engine backends (and sharded on the
  8-device mesh);
* the full flat train step matches the pytree train step bit-for-bit on
  params after 5 rounds;
* the sharded ``round_apply`` moves ZERO bytes (no collective ops in the
  compiled HLO), and the compiled flat train step contains exactly ONE
  params-shaped ``f32[P]`` all-gather — the single gather feeding the
  forward;
* optimizer slot shardings match the corresponding param shardings for all
  three optimizers (AdamW's ``m/``/``v/`` path prefixes must not skew the
  name-pattern rules);
* checkpoints: bf16 leaves round-trip through the uint16 npz encoding, the
  flat state round-trips with its spec manifest, and flat <-> legacy pytree
  checkpoints convert in both directions.

Multi-device tests follow the test_engine_sharded.py pattern: skipped below
8 devices and re-run by ``test_flat_sharded_suite_subprocess`` under
``--xla_force_host_platform_device_count=8``; CI also runs this file
in-process on the 8-device host mesh.
"""

import os
import re
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import NDEV, collective_counts, multidevice, p_mesh
from repro.core.engine import BACKENDS, DuDeEngine
from repro.core.flatten import make_flat_spec
from repro.optim import adamw, flat_twin, momentum_sgd, sgd

OPTIMIZERS = {
    "sgd": lambda: sgd(0.05),
    "momentum": lambda: momentum_sgd(0.05, beta=0.9, nesterov=True),
    "adamw": lambda: adamw(0.01, weight_decay=0.1),
}


def _tree(rng):
    return {
        "w": jnp.asarray(rng.normal(size=(13, 17)), jnp.float32),
        "emb": jnp.asarray(rng.normal(size=(4, 3, 9)), jnp.float32),
        "b": jnp.asarray(rng.normal(size=5), jnp.float32),
    }


def _zpad(spec, x):
    return x.at[..., spec.size:].set(0)


def _small_cfg():
    from repro.models.config import ModelConfig
    return ModelConfig(
        name="flat-test-lm", arch_type="dense", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=64,
        dtype=jnp.float32, remat=False, attn_chunk=16, n_workers=4,
    )


# ------------------------------------ flat == pytree optimizer equivalence


@pytest.mark.parametrize("opt_name", list(OPTIMIZERS))
@pytest.mark.parametrize("backend", BACKENDS)
def test_flat_apply_matches_pytree_apply(backend, opt_name):
    """round_apply (flat params + slots) == round + unravel + pytree apply,
    bit-for-bit over 6 steps, for every backend x optimizer."""
    rng = np.random.default_rng(0)
    tree = _tree(rng)
    spec = make_flat_spec(tree)
    n, P = 5, spec.padded_size
    popt = OPTIMIZERS[opt_name]()
    fopt = flat_twin(popt)
    eng = DuDeEngine(spec=spec, n_workers=n, backend=backend, interpret=True)
    st = eng.init()._replace(
        g_workers=_zpad(spec, jnp.asarray(rng.normal(size=(n, P)), jnp.float32)),
        inflight=_zpad(spec, jnp.asarray(rng.normal(size=(n, P)), jnp.float32)))
    st2 = st
    w = spec.ravel(tree)
    params = tree
    ost = fopt.init(w)
    post = popt.init(params)

    @jax.jit
    def flat_step(st, f, a, b, w, ost):
        return eng.round_apply(st, f, a, b, w, ost, fopt)

    @jax.jit
    def tree_step(st, f, a, b, params, post):
        st, g = eng.round(st, f, a, b)
        params, post = popt.apply(params, spec.unravel(g), post)
        return st, g, params, post

    for t in range(6):
        fresh = _zpad(spec, jnp.asarray(rng.normal(size=(n, P)), jnp.float32))
        sm = jnp.asarray(rng.random(n) < 0.5)
        cm = jnp.asarray(rng.random(n) < 0.5)
        st, g, w, ost = flat_step(st, fresh, sm, cm, w, ost)
        st2, g2, params, post = tree_step(st2, fresh, sm, cm, params, post)
    np.testing.assert_array_equal(np.asarray(g), np.asarray(g2))
    back = spec.unravel(w)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(params[k]),
                                      err_msg=f"{opt_name}/{backend}/{k}")
    # pad lanes are a fixed point of every apply rule
    assert float(jnp.max(jnp.abs(w[spec.size:]))) == 0.0
    assert int(ost.step) == 6 == int(post.step)


@pytest.mark.parametrize("opt_name", list(OPTIMIZERS))
def test_flat_train_step_matches_pytree(opt_name):
    """Acceptance: the flat train step and a hand-rolled PYTREE reference
    (vmapped backward -> engine.round -> unravel -> pytree opt.apply —
    exactly the retired tuple step's math) agree bit-for-bit on params
    after 5 train steps on a small LM config."""
    from repro.core import DuDeConfig
    from repro.launch.steps import (TrainOptions, init_flat_train_state,
                                    make_engine, make_train_step)
    from repro.models import lm_init, loss_fn

    cfg = _small_cfg()
    n = cfg.n_workers
    popt = OPTIMIZERS[opt_name]()
    dude_cfg = DuDeConfig(n, jnp.float32)
    options = TrainOptions()
    engine = make_engine(cfg, None, dude_cfg, options)
    params = lm_init(jax.random.PRNGKey(0), cfg)
    opt_state = popt.init(params)
    dude_state = engine.init()
    fstate = init_flat_train_state(engine, popt, params)

    @jax.jit
    def pstep(params, opt_state, dude_state, batch, sm, cm):
        def per_worker(p, wb):
            (_, m), g = jax.value_and_grad(
                lambda q: loss_fn(q, wb, cfg), has_aux=True)(p)
            return g, m["loss"]

        grads, losses = jax.vmap(per_worker, in_axes=(None, 0))(params, batch)
        fresh = engine.spec.ravel_stacked(grads, jnp.float32)
        dude_state, g_flat = engine.round(dude_state, fresh, sm, cm)
        params, opt_state = popt.apply(params, engine.spec.unravel(g_flat),
                                       opt_state)
        return params, opt_state, dude_state, {"loss": jnp.mean(losses)}

    fstep = jax.jit(make_train_step(cfg, None, popt, dude_cfg, engine=engine,
                                    options=options))
    key = jax.random.PRNGKey(1)
    batch = {
        "tokens": jax.random.randint(key, (n, 2, 16), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (n, 2, 16), 0, cfg.vocab_size),
    }
    rng = np.random.default_rng(7)
    for r in range(5):
        sm = jnp.asarray(rng.random(n) < 0.6)
        cm = jnp.asarray(rng.random(n) < 0.6)
        params, opt_state, dude_state, m1 = pstep(
            params, opt_state, dude_state, batch, sm, cm)
        fstate, m2 = fstep(fstate, batch, sm, cm)
    back = engine.spec.unravel(fstate.params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert float(m1["loss"]) == float(m2["loss"])


# ------------------------------------------------- slot sharding satellite


@pytest.mark.parametrize("opt_name", list(OPTIMIZERS))
def test_slot_shardings_match_param_shardings(opt_name):
    """Every optimizer slot must shard exactly like its parameter — on the
    REAL model tree, whose ``groups`` stack lives at the root (so AdamW's
    ``m/``/``v/`` prefixes used to shift the path patterns).  Exercised
    directly on the sharding rules (the retired pytree train state was the
    original consumer; serving/params paths still use them)."""
    from repro.configs import get_config
    from repro.launch.steps import abstract_params
    from repro.sharding import param_shardings, slot_shardings

    cfg = get_config("qwen2_0_5b").smoke()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    opt = OPTIMIZERS[opt_name]()
    params = abstract_params(cfg)
    opt_state = jax.eval_shape(opt.init, params)
    p_sh = param_shardings(params, mesh)
    if not opt_state.slots:
        return
    slot_sh = slot_shardings(params, opt_state.slots, mesh)
    p_struct = jax.tree_util.tree_structure(p_sh)
    if jax.tree_util.tree_structure(slot_sh) == p_struct:
        subtrees = [slot_sh]                      # momentum: params-shaped
    else:
        assert isinstance(slot_sh, dict)          # adamw: {"m", "v"}
        subtrees = list(slot_sh.values())
    p_leaves = jax.tree.leaves(p_sh)
    for sub in subtrees:
        assert jax.tree_util.tree_structure(sub) == p_struct
        for s, p in zip(jax.tree.leaves(sub), p_leaves):
            assert s == p, (s, p)


# --------------------------------------------------------- checkpointing


def test_ckpt_bf16_roundtrip(tmp_path):
    """bf16 leaves survive the uint16 npz encoding: logical dtypes recorded
    once in the manifest, bit-exact values back."""
    from repro.checkpoint import (checkpoint_format, restore_checkpoint,
                                  save_checkpoint)
    rng = np.random.default_rng(0)
    tree = {
        "a": jnp.asarray(rng.normal(size=(7, 3)), jnp.bfloat16),
        "b": jnp.asarray(rng.normal(size=11), jnp.float32),
        "c": jnp.arange(5, dtype=jnp.int32),
    }
    save_checkpoint(str(tmp_path), 3, tree)
    assert checkpoint_format(str(tmp_path)) == "pytree"
    back = restore_checkpoint(str(tmp_path), 3, tree)
    for k in tree:
        assert back[k].dtype == tree[k].dtype
        np.testing.assert_array_equal(
            np.asarray(back[k], np.float32), np.asarray(tree[k], np.float32))


def _flat_state(opt_name="adamw", buffer_dtype=jnp.bfloat16):
    from repro.launch.steps import init_flat_train_state
    rng = np.random.default_rng(1)
    tree = _tree(rng)
    spec = make_flat_spec(tree)
    eng = DuDeEngine(spec=spec, n_workers=3, buffer_dtype=buffer_dtype,
                     interpret=True)
    state = init_flat_train_state(eng, OPTIMIZERS[opt_name](), tree)
    # make the slabs non-trivial so the round-trip means something
    state = state._replace(engine=state.engine._replace(
        g_bar=_zpad(spec, jnp.asarray(rng.normal(size=spec.padded_size),
                                      jnp.float32))))
    return tree, spec, eng, state


def test_ckpt_flat_state_roundtrip(tmp_path):
    """FlatTrainState (incl. bf16 engine slabs) saves with the spec segment
    table in the manifest and restores bit-exactly."""
    from repro.checkpoint import (checkpoint_format, restore_checkpoint,
                                  save_checkpoint)
    _, spec, _, state = _flat_state()
    save_checkpoint(str(tmp_path), 5, state, flat_spec=spec)
    assert checkpoint_format(str(tmp_path)) == "flat"
    back = restore_checkpoint(str(tmp_path), 5, state, flat_spec=spec)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_ckpt_flat_pytree_interop(tmp_path):
    """Legacy pytree checkpoints load into flat runs and vice versa."""
    from repro.checkpoint import (restore_flat_from_pytree,
                                  restore_params_from_flat, save_checkpoint)
    tree, spec, _, state = _flat_state(opt_name="sgd")

    # flat checkpoint -> pytree params
    save_checkpoint(str(tmp_path / "flat"), 1, state, flat_spec=spec)
    params = restore_params_from_flat(str(tmp_path / "flat"), 1, tree)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(params[k]),
                                      np.asarray(tree[k]))

    # legacy pytree checkpoint -> flat state (params slab overwritten)
    tree2 = jax.tree.map(lambda x: x + 1, tree)
    save_checkpoint(str(tmp_path / "tree"), 2, tree2)
    st2 = restore_flat_from_pytree(str(tmp_path / "tree"), 2, state, spec)
    np.testing.assert_array_equal(np.asarray(st2.params),
                                  np.asarray(spec.ravel(tree2)))
    # non-params slabs untouched
    np.testing.assert_array_equal(np.asarray(st2.engine.g_bar),
                                  np.asarray(state.engine.g_bar))


def test_ckpt_flat_refit_mesh_axis_size(tmp_path):
    """A flat checkpoint saved unsharded restores under a shard-aligned spec
    (bigger pad tail): the real prefix is preserved, pads stay zero."""
    from repro.checkpoint import restore_params_from_flat, save_checkpoint
    tree, spec, _, state = _flat_state(opt_name="sgd")
    save_checkpoint(str(tmp_path), 1, state, flat_spec=spec)
    spec8 = make_flat_spec(tree, mesh_axis_size=8)
    assert spec8.padded_size > spec.padded_size
    params = restore_params_from_flat(str(tmp_path), 1, tree)
    np.testing.assert_array_equal(np.asarray(spec8.ravel(params)[:spec.size]),
                                  np.asarray(state.params[:spec.size]))


# ------------------------------------------------------- sharded (8-dev)


@multidevice
@pytest.mark.parametrize("opt_name", list(OPTIMIZERS))
@pytest.mark.parametrize("backend", BACKENDS)
def test_round_apply_sharded_matches_unsharded(backend, opt_name):
    """P-axis sharded round_apply == single-device round_apply, bit-for-bit
    on params, slots, and g_bar."""
    from repro.sharding import flat_train_state_shardings

    rng = np.random.default_rng(3)
    tree = _tree(rng)
    spec = make_flat_spec(tree, mesh_axis_size=NDEV)
    n, P = 4, spec.padded_size
    mesh = p_mesh()
    popt = OPTIMIZERS[opt_name]()
    fopt = flat_twin(popt)
    kw = dict(spec=spec, n_workers=n, backend=backend, interpret=True)
    eng_u = DuDeEngine(**kw)
    eng_s = DuDeEngine(**kw, mesh=mesh, axis_name="p")
    su = eng_u.init()._replace(
        g_workers=_zpad(spec, jnp.asarray(rng.normal(size=(n, P)), jnp.float32)),
        inflight=_zpad(spec, jnp.asarray(rng.normal(size=(n, P)), jnp.float32)))
    w = spec.ravel(tree)
    ost = fopt.init(w)
    sh = flat_train_state_shardings(spec, mesh, ("p",), ost)
    ss = jax.device_put(su, eng_s.shardings())
    ws = jax.device_put(w, sh.params)
    osts = jax.device_put(ost, sh.opt)
    fu = jax.jit(lambda s, f, a, b, w, o: eng_u.round_apply(s, f, a, b, w, o, fopt))
    fs = jax.jit(lambda s, f, a, b, w, o: eng_s.round_apply(s, f, a, b, w, o, fopt))
    for t in range(4):
        fresh = _zpad(spec, jnp.asarray(rng.normal(size=(n, P)), jnp.float32))
        sm = jnp.asarray(rng.random(n) < 0.5)
        cm = jnp.asarray(rng.random(n) < 0.5)
        su, gu, w, ost = fu(su, fresh, sm, cm, w, ost)
        ss, gs, ws, osts = fs(ss, fresh, sm, cm, ws, osts)
    np.testing.assert_array_equal(np.asarray(gu), np.asarray(gs))
    np.testing.assert_array_equal(np.asarray(w), np.asarray(ws))
    for a, b in zip(jax.tree.leaves(ost), jax.tree.leaves(osts)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@multidevice
@pytest.mark.parametrize("backend", BACKENDS)
def test_sharded_round_apply_moves_no_bytes(backend):
    """Round + slot update + param step are all elementwise on P: the
    compiled sharded round_apply must contain ZERO collective ops — this is
    the 'no all-gather/all-reduce between the engine round and the param
    update' acceptance criterion, enforced structurally (one shard_map)."""
    rng = np.random.default_rng(5)
    spec = make_flat_spec(_tree(rng), mesh_axis_size=NDEV)
    n = 4
    mesh = p_mesh()
    fopt = flat_twin(OPTIMIZERS["adamw"]())
    eng = DuDeEngine(spec=spec, n_workers=n, backend=backend,
                     interpret=True, mesh=mesh, axis_name="p")
    state = eng.init()
    w = jax.device_put(jnp.zeros(eng.P), eng.shardings().g_bar)
    ost = fopt.init(w)
    fresh = jax.device_put(jnp.ones((n, eng.P)), eng.shardings().g_workers)
    ones = jnp.ones(n, bool)
    hlo = jax.jit(lambda s, f, a, b, w, o: eng.round_apply(s, f, a, b, w, o, fopt)
                  ).lower(state, fresh, ones, ones, w, ost
                          ).compile().as_text()
    counts = {k: v for k, v in collective_counts(hlo).items() if v}
    assert not counts, counts


@multidevice
def test_flat_train_step_single_params_allgather():
    """The compiled flat train step on a 2x4 data x model mesh contains
    exactly ONE params-shaped f32[P] all-gather — the single gather feeding
    the forward — and runs finite."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_config
    from repro.core.dude import DuDeConfig
    from repro.launch.steps import (TrainOptions, abstract_train_state,
                                    init_flat_train_state, make_engine,
                                    make_train_step)
    from repro.models import lm_init

    cfg = get_config("qwen2_0_5b").smoke()
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    n = cfg.n_workers
    dude_cfg = DuDeConfig(n, jnp.float32)
    opt = momentum_sgd(0.05)
    options = TrainOptions()
    with mesh:
        engine = make_engine(cfg, mesh, dude_cfg, options)
        st_shapes, st_sh = abstract_train_state(cfg, mesh, opt, dude_cfg,
                                                options=options)
        step = jax.jit(make_train_step(cfg, mesh, opt, dude_cfg,
                                       options=options, engine=engine))
        key = jax.random.PRNGKey(1)
        b_sh = NamedSharding(mesh, P(None, "data", None))
        batch = {
            "tokens": jax.device_put(
                jax.random.randint(key, (n, 4, 32), 0, cfg.vocab_size), b_sh),
            "labels": jax.device_put(
                jax.random.randint(key, (n, 4, 32), 0, cfg.vocab_size), b_sh),
        }
        ones = jnp.ones(n, bool)
        hlo = step.lower(st_shapes, batch, ones, ones).compile().as_text()
        # exactly one all-gather producing the full [P] master vector
        agp = re.findall(rf"f32\[{engine.P}\]\S* all-gather\(", hlo)
        assert len(agp) == 1, (engine.P, len(agp))
        state = init_flat_train_state(engine, opt,
                                      lm_init(jax.random.PRNGKey(0), cfg))
        for _ in range(2):
            state, metrics = step(state, batch, ones, ones)
        assert np.isfinite(float(metrics["loss"]))


# ------------------------------------------------------ subprocess driver


def test_flat_sharded_suite_subprocess():
    """Run the in-process multidevice tests above on 8 host-platform devices
    (they are skipped in a default single-device session)."""
    if jax.device_count() >= NDEV:
        pytest.skip("already multi-device in-process")
    repo = Path(__file__).resolve().parent.parent
    env = {
        **os.environ,
        "PYTHONPATH": "src",
        "JAX_PLATFORMS": "cpu",
        "XLA_FLAGS": (os.environ.get("XLA_FLAGS", "")
                      + f" --xla_force_host_platform_device_count={NDEV}"
                      ).strip(),
    }
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-p", "no:cacheprovider",
         str(Path(__file__).resolve()),
         # only the tests the single-device skip guard deferred
         "-k", "(sharded or allgather) and not subprocess"],
        capture_output=True, text=True, timeout=540, env=env, cwd=repo,
    )
    assert r.returncode == 0, r.stdout[-4000:] + r.stderr[-2000:]
    assert "skipped" not in r.stdout.splitlines()[-1], r.stdout[-500:]

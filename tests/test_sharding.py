"""Sharding-layer tests.

Spec-level checks run in-process; the compile-level check (train_step lowers
and runs on a real multi-device mesh) runs in a SUBPROCESS because the
device-count override must be set before jax initializes (the main pytest
process stays single-device for the smoke tests)."""

import json
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_production_mesh  # noqa: F401 (import check)
from repro.sharding.specs import param_spec


class _FakeMesh:
    shape = {"data": 16, "model": 16}


def test_param_spec_rules():
    mesh = _FakeMesh()
    assert param_spec("stack/groups/0/attn/wq/kernel", (4096, 4096), mesh) == \
        P("data", "model")
    assert param_spec("stack/groups/0/attn/wo/kernel", (4096, 4096), mesh) == \
        P("model", "data")
    assert param_spec("embed/embedding", (32000, 4096), mesh) == P("model", "data")
    assert param_spec("stack/groups/0/moe/wup", (64, 2048, 1024), mesh) == \
        P("model", "data", None)
    # indivisible dims are dropped, not crashed
    assert param_spec("x/attn/wq/kernel", (33, 47), mesh) == P(None, None)
    # stacked group leaves get a leading None
    assert param_spec("stack/groups/0/mlp/up/kernel", (24, 896, 4864), mesh,
                      stacked=True) == P(None, "data", "model")
    # norm scales replicate
    assert param_spec("stack/groups/0/ln1/scale", (4096,), mesh) == P(None)


SUBPROCESS_PROG = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, json
from repro.configs import get_config
from repro.core.dude import DuDeConfig
from repro.launch.steps import make_engine, make_train_step, train_batch_specs, abstract_train_state
from repro.models import lm_init
from repro.optim import sgd
import numpy as np

cfg = get_config("qwen2_0_5b").smoke()
mesh = jax.make_mesh((2, 4), ("data", "model"))
n = cfg.n_workers
dude_cfg = DuDeConfig(n, jnp.float32)
with mesh:
    st_shapes, st_sh = abstract_train_state(cfg, mesh, dude_cfg=dude_cfg)
    engine = make_engine(cfg, mesh, dude_cfg)
    opt = sgd(0.01)
    step = make_train_step(cfg, mesh, opt, dude_cfg=dude_cfg, engine=engine)
    # real (non-abstract) flat state, P-axis sharded by init_flat_train_state
    from repro.launch.steps import init_flat_train_state
    state = init_flat_train_state(engine, opt,
                                  lm_init(jax.random.PRNGKey(0), cfg))
    assert state.params.sharding == st_sh.params
    key = jax.random.PRNGKey(1)
    S = 64
    batch = {
        "tokens": jax.random.randint(key, (n, 2, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (n, 2, S), 0, cfg.vocab_size),
    }
    ones = jnp.ones(n, bool)
    jitted = jax.jit(step)
    for _ in range(3):
        state, metrics = jitted(state, batch, ones, ones)
    loss = float(metrics["loss"])
    finite = bool(jnp.isfinite(loss))
    print(json.dumps({"loss": loss, "finite": finite,
                      "ndev": jax.device_count()}))
"""


def test_train_step_runs_on_multidevice_mesh():
    r = subprocess.run(
        [sys.executable, "-c", SUBPROCESS_PROG],
        capture_output=True, text=True, timeout=560,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
        cwd="/root/repo",
    )
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["ndev"] == 8
    assert out["finite"]

"""End-to-end system behaviour: the paper's protocol training real models.

1. Event-driven (mode A): a small CNN on Dirichlet-partitioned class-Gaussian
   images — DuDe-ASGD improves accuracy under extreme heterogeneity where
   vanilla ASGD degrades (paper Fig. 2, miniature).
2. Round-based SPMD (mode B): a small transformer LM trained with the DuDe
   train_step under a heterogeneous-speed schedule — loss decreases.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    DuDeConfig, make_algo, make_round_schedule, simulate,
    truncated_normal_speeds,
)
from repro.data import class_gaussian_images, dirichlet_partition, make_sample_fn
from repro.launch.steps import make_engine, make_train_step
from repro.models import lm_init
from repro.models.cnn import cnn_accuracy, cnn_init, cnn_loss
from repro.models.config import ModelConfig
from repro.optim import sgd


def test_cnn_dude_beats_vanilla_under_heterogeneity():
    n = 6
    x, y = class_gaussian_images(n=2400, seed=0)
    shards = dirichlet_partition(y, n, alpha=0.05, seed=0)
    sample_fn_np = make_sample_fn(x, y, shards, batch=32, seed=0)

    def sample_fn(i, rng):
        b = sample_fn_np(i, rng)
        return {"x": jnp.asarray(b["x"]), "y": jnp.asarray(b["y"])}

    def grad_fn(params, batch, key):
        loss, g = jax.value_and_grad(cnn_loss)(params, batch)
        return loss, g

    params0 = cnn_init(jax.random.PRNGKey(0))
    speeds = truncated_normal_speeds(n, std=1.0, seed=1)
    xe, ye = jnp.asarray(x[:512]), jnp.asarray(y[:512])

    accs = {}
    for name in ("dude_asgd", "vanilla_asgd"):
        # paper's step-size range is {0.001, 0.005, 0.01} (§5)
        res = simulate(make_algo(name, n), speeds, grad_fn, sample_fn,
                       params0, lr=0.01, total_iters=300, record_every=1000)
        accs[name] = float(cnn_accuracy(res.params, xe, ye))
    # with alpha=0.05 each worker is ~single-class; vanilla overweights fast
    # workers' classes.  DuDe must beat chance and at least match vanilla
    # (the full-scale comparison lives in benchmarks/fig2_cnn_grid.py).
    assert accs["dude_asgd"] > 0.14, accs
    assert accs["dude_asgd"] >= accs["vanilla_asgd"] - 0.02, accs


def test_apply_period_mirrors_device_flag():
    """The simulator counts server iterations from the host-side
    ``apply_period`` mirror instead of bool(applied)-syncing per arrival —
    the mirror must agree with the device flag for every algorithm."""
    from repro.core import make_algo
    like = {"w": jnp.zeros(8)}
    for name, kw in (("fedbuff", {}), ("dude_semi", {"c": 2}),
                     ("dude_asgd", {}), ("vanilla_asgd", {})):
        algo = make_algo(name, 4, **kw)
        state = algo.init_state(like)
        params = like
        pending = 0
        for t in range(9):
            g = {"w": jnp.full(8, float(t))}
            state, params, applied = algo.on_gradient(
                state, jnp.int32(t % 4), g, params, 0.1)
            pending += 1
            host = pending >= algo.apply_period
            if host:
                pending = 0
            assert bool(applied) == host, (name, t)


def test_spmd_train_loop_loss_decreases():
    cfg = ModelConfig(
        name="tiny", arch_type="dense", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=64, dtype=jnp.float32,
        remat=False, attn_chunk=16, n_workers=4,
    )
    from repro.launch.steps import init_flat_train_state
    n = cfg.n_workers
    key = jax.random.PRNGKey(0)
    params = lm_init(key, cfg)
    opt = sgd(0.05)
    dude_cfg = DuDeConfig(n, jnp.float32)
    engine = make_engine(cfg, None, dude_cfg)
    state = init_flat_train_state(engine, opt, params)
    step = jax.jit(make_train_step(cfg, None, opt, dude_cfg, engine=engine))

    speeds = truncated_normal_speeds(n, std=1.0, seed=2)
    sch = make_round_schedule(speeds, rounds=30)

    # learnable structure: every worker sees shifted arithmetic sequences
    def batch_for_round(r):
        base = jnp.arange(24) + r
        toks = jnp.stack([(base + i) % cfg.vocab_size for i in range(n)])
        toks = toks[:, None, :]  # [n, b=1, S]
        labels = jnp.concatenate([toks[..., 1:], toks[..., :1]], axis=-1)
        return {"tokens": toks, "labels": labels}

    losses = []
    for r in range(sch.rounds):
        state, metrics = step(
            state, batch_for_round(r),
            jnp.asarray(sch.start[r]), jnp.asarray(sch.commit[r]),
        )
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses

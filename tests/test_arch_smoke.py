"""Per-architecture smoke tests (deliverable f): every assigned arch's REDUCED
variant (<=2 period-lengths of layers, d_model<=512, <=4 experts) runs one
forward + one train step on CPU; output shapes and finiteness asserted."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.core.dude import DuDeConfig
from repro.launch.steps import make_engine, make_train_step
from repro.models import forward, lm_init, loss_fn, param_count
from repro.models.stubs import make_prefix_embeddings, token_shape
from repro.optim import sgd


def _smoke_batch(cfg, key, B=2, S=32, worker_dim=None):
    S_total = S + cfg.num_prefix_tokens
    ts = token_shape(cfg, B, S_total)
    lab_shape = (B, S_total) + ((cfg.num_codebooks,) if cfg.num_codebooks > 1 else ())
    if worker_dim:
        ts = (worker_dim,) + ts
        lab_shape = (worker_dim,) + lab_shape
    batch = {
        "tokens": jax.random.randint(key, ts, 0, cfg.vocab_size),
        "labels": jax.random.randint(key, lab_shape, 0, cfg.vocab_size),
    }
    if cfg.frontend:
        pe = make_prefix_embeddings(key, cfg, B)
        if worker_dim:
            pe = jnp.broadcast_to(pe[None], (worker_dim,) + pe.shape)
        batch["prefix_emb"] = pe
    return batch, S_total


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes(arch):
    cfg = get_config(arch).smoke()
    assert cfg.d_model <= 512 and (not cfg.num_experts or cfg.num_experts <= 4)
    key = jax.random.PRNGKey(0)
    params = lm_init(key, cfg)
    batch, S_total = _smoke_batch(cfg, key)
    logits, aux = forward(params, batch, cfg)
    if cfg.num_codebooks > 1:
        assert logits.shape == (2, S_total, cfg.num_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (2, S_total, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    """One full DuDe train step (mode B, flat train state) on CPU: loss
    finite, params move, no NaNs anywhere in the updated state."""
    from repro.launch.steps import init_flat_train_state
    cfg = get_config(arch).smoke()
    key = jax.random.PRNGKey(1)
    params = lm_init(key, cfg)
    n = cfg.n_workers
    dude_cfg = DuDeConfig(n, jnp.float32)
    opt = sgd(0.01)
    engine = make_engine(cfg, None, dude_cfg)
    state = init_flat_train_state(engine, opt, params)
    step = jax.jit(make_train_step(cfg, None, opt, dude_cfg, engine=engine))
    batch, _ = _smoke_batch(cfg, key, B=1, S=16, worker_dim=n)
    ones = jnp.ones(n, bool)
    state2, metrics = step(state, batch, ones, ones)
    assert bool(jnp.isfinite(metrics["loss"])), arch
    # second round commits the latched gradients -> params must move
    state3, m2 = step(state2, batch, ones, ones)
    moved = float(jnp.sum(jnp.abs(state3.params - state2.params)))
    assert moved > 0, arch
    assert bool(jnp.all(jnp.isfinite(state3.params))), arch
    for leaf in jax.tree.leaves(engine.spec.unravel(state3.params)):
        assert bool(jnp.all(jnp.isfinite(leaf))), arch


def test_param_count_full_configs():
    """Full configs hit their nameplate scale (abstract, no allocation)."""
    from repro.launch.costs import param_counts
    expect = {
        "qwen1_5_110b": (95e9, 130e9),
        "kimi_k2_1t_a32b": (0.9e12, 1.2e12),
        "qwen2_0_5b": (0.3e9, 0.65e9),
        "starcoder2_3b": (2.5e9, 3.5e9),
        "olmoe_1b_7b": (5e9, 8e9),
        "xlstm_1_3b": (1.0e9, 2.3e9),
        "zamba2_2_7b": (2.2e9, 3.4e9),
        "qwen3_1_7b": (1.2e9, 2.2e9),
        "musicgen_large": (2.5e9, 4.0e9),  # musicgen-large card: 3.3B
        "llava_next_mistral_7b": (6e9, 8e9),
    }
    for arch, (lo, hi) in expect.items():
        n = param_counts(get_config(arch))["total"]
        assert lo <= n <= hi, (arch, n)

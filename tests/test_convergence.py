"""Convergence behaviour on heterogeneous quadratics (paper Table 1 claims).

Closed-form problem: F_i(w) = 0.5 w'A_i w - b_i'w with wildly different b_i
(unbounded-heterogeneity proxy).  The paper's claims:
  * DuDe-ASGD converges to a stationary point of F regardless of heterogeneity
    (no BDH assumption) — err comparable to synchronous SGD;
  * vanilla ASGD has an asymptotic bias ~ zeta^2 (heterogeneity level);
  * DuDe achieves this with ~n x fewer gradient evaluations than sync SGD in
    the same simulated wall-clock.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_algo, simulate, truncated_normal_speeds

N, P = 4, 6


def _problem(het=3.0, seed=0):
    rng = np.random.default_rng(seed)
    A = [np.diag(rng.uniform(0.5, 2.0, P)) for _ in range(N)]
    b = [rng.normal(size=P) * het for _ in range(N)]
    Abar, bbar = sum(A) / N, sum(b) / N
    wstar = np.linalg.solve(Abar, bbar)

    def grad_fn(params, batch, key):
        Ai, bi = batch
        g = Ai @ params - bi + 0.01 * jax.random.normal(key, (P,))
        loss = 0.5 * params @ Ai @ params - bi @ params
        return loss, g

    def sample_fn(i, rng_):
        return (jnp.asarray(A[i], jnp.float32), jnp.asarray(b[i], jnp.float32))

    return grad_fn, sample_fn, wstar


def _run(name, iters=500, het=3.0, seed=0, **kw):
    grad_fn, sample_fn, wstar = _problem(het, seed)
    speeds = truncated_normal_speeds(N, std=1.0, seed=seed + 1)
    algo = make_algo(name, N, **kw)
    res = simulate(algo, speeds, grad_fn, sample_fn, jnp.zeros(P), lr=0.05,
                   total_iters=iters, record_every=100, seed=seed)
    err = float(np.linalg.norm(np.asarray(res.params) - wstar))
    return err, res


def test_dude_converges_under_heterogeneity():
    err, _ = _run("dude_asgd")
    assert err < 0.05, err


def test_vanilla_asgd_biased_dude_not():
    err_v, _ = _run("vanilla_asgd")
    err_d, _ = _run("dude_asgd")
    # paper: vanilla ASGD stalls at a zeta-proportional bias
    assert err_v > 5 * err_d, (err_v, err_d)


def test_dude_matches_sync_quality_with_fewer_grads():
    err_s, res_s = _run("sync_sgd")
    err_d, res_d = _run("dude_asgd")
    assert err_d < max(2 * err_s, 0.05)
    assert res_d.n_grads <= res_s.n_grads / 2  # async efficiency


def test_bias_grows_with_heterogeneity():
    """Vanilla ASGD's plateau should scale with zeta (Table 1's zeta_max^2
    term); DuDe should be flat."""
    ev1, _ = _run("vanilla_asgd", het=1.0)
    ev5, _ = _run("vanilla_asgd", het=5.0)
    ed5, _ = _run("dude_asgd", het=5.0)
    assert ev5 > ev1
    assert ed5 < 0.1, ed5


def test_dude_robust_to_speed_variance():
    """Paper Fig. 2: DuDe performance is stable as std grows."""
    grad_fn, sample_fn, wstar = _problem()
    for std in (1.0, 5.0):
        speeds = truncated_normal_speeds(N, std=std, seed=7)
        algo = make_algo("dude_asgd", N)
        res = simulate(algo, speeds, grad_fn, sample_fn, jnp.zeros(P), lr=0.05,
                       total_iters=500, record_every=100)
        err = float(np.linalg.norm(np.asarray(res.params) - wstar))
        assert err < 0.1, (std, err)

"""Sharded ServerEngine acceptance tests.

Proves, for all three backends, that a P-axis sharded ``EngineState`` on an
8-device host-platform mesh matches the single-device engine bit-for-bit on
``g_bar`` (and up to buffer-dtype rounding on the slabs), that the sharded
round needs no collective at all, and that the ``constrain_grads`` train
path emits a true reduce-scatter for the gradient->buffer path — not
all-reduce + dynamic-slice.

The in-process tests need >= 8 devices, so on a normal single-device run
they are skipped and ``test_sharded_suite_subprocess`` re-runs them in a
subprocess with ``--xla_force_host_platform_device_count=8`` (the device
count must be set before jax initializes — same trick as test_sharding.py).
CI additionally runs this file in-process under the 8-device override.
"""

import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import NDEV, collective_counts, multidevice, p_mesh
from repro.core.engine import BACKENDS, DuDeEngine
from repro.core.flatten import make_flat_spec

def _tree(rng):
    return {
        "w": jnp.asarray(rng.normal(size=(13, 17)), jnp.float32),
        "emb": jnp.asarray(rng.normal(size=(4, 3, 9)), jnp.float32),
        "b": jnp.asarray(rng.normal(size=5), jnp.float32),
    }


def _engines(backend, buf_dtype, n, mesh):
    spec = make_flat_spec(_tree(np.random.default_rng(0)),
                          mesh_axis_size=NDEV)
    kw = dict(spec=spec, n_workers=n, buffer_dtype=buf_dtype,
              backend=backend, interpret=True)
    return (DuDeEngine(**kw),
            DuDeEngine(**kw, mesh=mesh, axis_name="p"))


# ------------------------------------------------- sharded == unsharded


@multidevice
@pytest.mark.parametrize("buf_dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("backend", BACKENDS)
def test_round_sharded_matches_unsharded(backend, buf_dtype):
    """P-axis sharded round == single-device round: bit-for-bit on g_bar,
    buffer-dtype rounding on the slabs (they agree bitwise here too — the
    round is elementwise on P, so sharding cannot reorder anything)."""
    rng = np.random.default_rng(3)
    n = 5
    mesh = p_mesh()
    eng_u, eng_s = _engines(backend, buf_dtype, n, mesh)
    P = eng_u.P
    assert eng_s.shard_P == P // NDEV
    su = eng_u.init()._replace(
        g_workers=jnp.asarray(rng.normal(size=(n, P)), buf_dtype),
        inflight=jnp.asarray(rng.normal(size=(n, P)), buf_dtype))
    ss = jax.device_put(su, eng_s.shardings())
    step_u, step_s = jax.jit(eng_u.round), jax.jit(eng_s.round)
    for t in range(6):
        fresh = jnp.asarray(rng.normal(size=(n, P)), jnp.float32)
        sm = jnp.asarray(rng.random(n) < 0.5)
        cm = jnp.asarray(rng.random(n) < 0.4)
        su, gu = step_u(su, fresh, sm, cm)
        ss, gs = step_s(ss, fresh, sm, cm)
        np.testing.assert_array_equal(np.asarray(gu), np.asarray(gs))
        for a, b in ((su.g_workers, ss.g_workers),
                     (su.inflight, ss.inflight)):
            np.testing.assert_array_equal(
                np.asarray(a, np.float32), np.asarray(b, np.float32))
        np.testing.assert_array_equal(np.asarray(su.acc_count),
                                      np.asarray(ss.acc_count))


@multidevice
@pytest.mark.parametrize("backend", BACKENDS)
def test_commit_sharded_matches_unsharded(backend):
    rng = np.random.default_rng(5)
    n = 4
    mesh = p_mesh()
    eng_u, eng_s = _engines(backend, jnp.float32, n, mesh)
    P = eng_u.P
    su = eng_u.init()._replace(
        g_workers=jnp.asarray(rng.normal(size=(n, P)), jnp.float32))
    ss = jax.device_put(su, eng_s.shardings())
    cu, cs = jax.jit(eng_u.commit), jax.jit(eng_s.commit)
    for t in range(5):
        g = jnp.asarray(rng.normal(size=P), jnp.float32)
        su, gu = cu(su, jnp.int32(t % n), g)
        ss, gs = cs(ss, jnp.int32(t % n), g)
        np.testing.assert_array_equal(np.asarray(gu), np.asarray(gs))
        np.testing.assert_array_equal(np.asarray(su.g_workers),
                                      np.asarray(ss.g_workers))


@multidevice
@pytest.mark.parametrize("backend", BACKENDS)
def test_sharded_round_moves_no_bytes(backend):
    """The round is elementwise on P (worker-sum local to each P-shard):
    the compiled sharded round must contain ZERO collective ops."""
    n = 4
    mesh = p_mesh()
    _, eng_s = _engines(backend, jnp.float32, n, mesh)
    state = eng_s.init()
    fresh = jax.device_put(jnp.ones((n, eng_s.P), jnp.float32),
                           eng_s.shardings().g_workers)
    ones = jnp.ones(n, bool)
    hlo = jax.jit(eng_s.round).lower(state, fresh, ones, ones
                                     ).compile().as_text()
    counts = {k: v for k, v in collective_counts(hlo).items() if v}
    assert not counts, counts


# ------------------------------------- gradient -> buffer reduce-scatter


@multidevice
def test_constrain_grads_emits_reduce_scatter():
    """With constrain_grads=True the gradient->buffer path must lower to a
    reduce-scatter into the owned P-shard; the unconstrained baseline (and
    everything GSPMD does on its own) emits no reduce-scatter at all.  The
    two variants must agree numerically."""
    from repro.configs import get_config
    from repro.core.dude import DuDeConfig
    from repro.launch.steps import (TrainOptions, make_engine,
                                    make_train_step)
    from repro.models import lm_init
    from repro.optim import sgd
    from jax.sharding import NamedSharding, PartitionSpec as P

    cfg = get_config("qwen2_0_5b").smoke()
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    n = cfg.n_workers
    dude_cfg = DuDeConfig(n, jnp.float32)
    key = jax.random.PRNGKey(1)
    batch = {
        "tokens": jax.random.randint(key, (n, 4, 32), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (n, 4, 32), 0, cfg.vocab_size),
    }
    ones = jnp.ones(n, bool)
    results = {}
    counts = {}
    for constrain in (False, True):
        options = TrainOptions(constrain_grads=constrain)
        with mesh:
            from repro.launch.steps import init_flat_train_state
            engine = make_engine(cfg, mesh, dude_cfg, options)
            opt = sgd(0.01)
            step = jax.jit(make_train_step(cfg, mesh, opt, dude_cfg=dude_cfg,
                                           options=options, engine=engine))
            state = init_flat_train_state(
                engine, opt, lm_init(jax.random.PRNGKey(0), cfg))
            b_sh = NamedSharding(mesh, P(None, "data", None))
            sharded_batch = jax.tree.map(
                lambda x: jax.device_put(x, b_sh), batch)
            hlo = step.lower(state, sharded_batch,
                             ones, ones).compile().as_text()
            counts[constrain] = collective_counts(hlo)
            for _ in range(2):
                state, metrics = step(state, sharded_batch, ones, ones)
            results[constrain] = float(metrics["loss"])
    assert counts[False]["reduce-scatter"] == 0, counts[False]
    assert counts[True]["reduce-scatter"] >= 1, counts[True]
    # fewer all-reduces: the data-axis gradient reduction moved into the
    # reduce-scatter instead of all-reduce + slice
    assert counts[True]["all-reduce"] < counts[False]["all-reduce"], counts
    assert np.isfinite(results[True])
    np.testing.assert_allclose(results[True], results[False], atol=1e-4)


# ------------------------------------------------------ subprocess driver


def test_sharded_suite_subprocess():
    """Run the in-process tests above on 8 host-platform devices (they are
    skipped in a default single-device session)."""
    if jax.device_count() >= NDEV:
        pytest.skip("already multi-device in-process")
    repo = Path(__file__).resolve().parent.parent
    env = {
        **os.environ,
        "PYTHONPATH": "src",
        "JAX_PLATFORMS": "cpu",
        "XLA_FLAGS": (os.environ.get("XLA_FLAGS", "")
                      + f" --xla_force_host_platform_device_count={NDEV}"
                      ).strip(),
    }
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-p", "no:cacheprovider",
         str(Path(__file__).resolve()), "-k", "not subprocess"],
        capture_output=True, text=True, timeout=540, env=env, cwd=repo,
    )
    assert r.returncode == 0, r.stdout[-4000:] + r.stderr[-2000:]
    assert "skipped" not in r.stdout.splitlines()[-1], r.stdout[-500:]

"""Serving-path correctness: prefill + decode_step must reproduce the full
forward logits exactly (per family, including SWA / SSM state caches)."""

import jax
import jax.numpy as jnp
import pytest

from repro.models import (
    decode_step, forward, init_decode_caches, lm_init, prefill,
)
from repro.models.config import ModelConfig
from repro.models.stubs import make_prefix_embeddings


def mk(name, **kw):
    base = dict(name=name, arch_type="dense", num_layers=4, d_model=128,
                num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=128,
                dtype=jnp.float32, remat=False, attn_chunk=16)
    base.update(kw)
    return ModelConfig(**base)


CASES = {
    "dense": mk("dense", qkv_bias=True, qk_norm=True),
    "swa": mk("swa", sliding_window=8),
    "moe": mk("moe", arch_type="moe", block_pattern=("moe",), num_experts=4,
              experts_per_tok=2, moe_d_ff=64, capacity_factor=8.0),
    "xlstm": mk("xlstm", arch_type="ssm", block_pattern=("mlstm", "slstm"),
                ssm_state=16),
    "zamba": mk("zamba", arch_type="hybrid",
                block_pattern=("mamba", "mamba_shared_attn"), ssm_state=16),
    "audio": mk("audio", arch_type="audio", num_codebooks=4, vocab_size=64),
    "vlm": mk("vlm", arch_type="vlm", frontend="vision", frontend_dim=48,
              num_prefix_tokens=4),
    "unrolled": mk("unrolled", scan_layers=False),
}


@pytest.mark.parametrize("family", list(CASES))
def test_decode_matches_forward(family):
    cfg = CASES[family]
    key = jax.random.PRNGKey(0)
    B, S = 2, 16
    params = lm_init(key, cfg)
    s_text = S - cfg.num_prefix_tokens
    tshape = (B, s_text) + ((cfg.num_codebooks,) if cfg.num_codebooks > 1 else ())
    toks = jax.random.randint(key, tshape, 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.frontend:
        batch["prefix_emb"] = make_prefix_embeddings(key, cfg, B)
    logits_full, _ = forward(params, batch, cfg)

    Sp = s_text - 4
    caches = init_decode_caches(cfg, B, S, dtype=jnp.float32)
    pb = dict(batch)
    pb["tokens"] = toks[:, :Sp]
    lg, caches = prefill(params, pb, caches, cfg)
    off = cfg.num_prefix_tokens
    errs = [float(jnp.max(jnp.abs(lg[:, 0] - logits_full[:, off + Sp - 1])))]
    for t in range(Sp, s_text):
        lg, caches = decode_step(params, toks[:, t:t + 1], caches, off + t, cfg)
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - logits_full[:, off + t]))))
    assert max(errs) < 2e-3, (family, errs)


def test_swa_decode_uses_window():
    """With use_window=True, tokens beyond the stacked receptive field
    (num_layers * window) must not influence the decode logits."""
    cfg = mk("swa", sliding_window=2, num_layers=2)  # receptive field = 4
    key = jax.random.PRNGKey(2)
    params = lm_init(key, cfg)
    B, S = 1, 12
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    toks2 = toks.at[:, 0:1].set((toks[:, 0:1] + 7) % cfg.vocab_size)

    def run(tk):
        caches = init_decode_caches(cfg, B, S, dtype=jnp.float32)
        _, caches = prefill(params, {"tokens": tk[:, :-1]}, caches, cfg,
                            use_window=True)
        lg, _ = decode_step(params, tk[:, -1:], caches, S - 1, cfg,
                            use_window=True)
        return lg

    d = float(jnp.max(jnp.abs(run(toks) - run(toks2))))
    assert d < 1e-4, d

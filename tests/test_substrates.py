"""Substrate tests: data pipeline, optimizers, checkpointing, baselines."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ALGO_NAMES, make_algo, simulate, truncated_normal_speeds
from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.data import (
    ShardIterator, class_gaussian_images, dirichlet_partition,
    label_distribution, make_sample_fn, make_token_sampler,
)
from repro.optim import adamw, momentum_sgd, sgd


# ------------------------------------------------------------------- data

def test_dirichlet_alpha_controls_skew():
    _, labels = class_gaussian_images(n=3000, seed=0)
    lo = dirichlet_partition(labels, 8, alpha=0.05, seed=1)
    hi = dirichlet_partition(labels, 8, alpha=100.0, seed=1)

    def skew(shards):
        d = label_distribution(labels, shards)
        return float(np.mean(np.max(d, axis=1)))

    assert skew(lo) > skew(hi) + 0.2  # low alpha -> near-single-class workers


def test_shard_iterator_epochs():
    it = ShardIterator(np.arange(10), batch=4, seed=0)
    seen = np.concatenate([it.next_indices() for _ in range(5)])
    # every element appears exactly twice per 20 draws
    vals, counts = np.unique(seen, return_counts=True)
    np.testing.assert_array_equal(vals, np.arange(10))
    assert counts.sum() == 20


def test_token_sampler_heterogeneous():
    sample = make_token_sampler(4, vocab=64, seq_len=16, batch=8,
                                heterogeneity=3.0, seed=0)
    rng = np.random.default_rng(0)
    b0 = sample(0, rng)["tokens"].ravel()
    b1 = sample(1, rng)["tokens"].ravel()
    h0 = np.bincount(b0, minlength=64) / b0.size
    h1 = np.bincount(b1, minlength=64) / b1.size
    assert np.abs(h0 - h1).sum() > 0.3  # distributions genuinely differ


# ------------------------------------------------------------------- optim

@pytest.mark.parametrize("opt_fn", [
    lambda: sgd(0.1), lambda: momentum_sgd(0.01), lambda: adamw(0.2),
])
def test_optimizers_descend_quadratic(opt_fn):
    opt = opt_fn()
    params = {"w": jnp.full((4,), 5.0)}
    state = opt.init(params)
    for _ in range(150):
        g = {"w": 2 * params["w"]}
        params, state = opt.apply(params, g, state)
    assert float(jnp.sum(params["w"] ** 2)) < 5e-2


# -------------------------------------------------------------- checkpoint

def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "b": {"c": jnp.ones((4,), jnp.bfloat16)},
    }
    d = save_checkpoint(str(tmp_path), 7, tree)
    assert latest_step(str(tmp_path)) == 7
    back = restore_checkpoint(str(tmp_path), None, tree)
    np.testing.assert_array_equal(np.asarray(back["a"]), np.asarray(tree["a"]))
    assert back["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    tree = {"a": jnp.zeros((2,))}
    save_checkpoint(str(tmp_path), 0, tree)
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), 0, {"a": jnp.zeros((3,))})


# --------------------------------------------------------------- baselines

def test_all_baselines_descend():
    """Every Table-1 algorithm reduces the objective on an easy quadratic."""
    rng = np.random.default_rng(0)
    P, n = 4, 4
    A = [np.diag(rng.uniform(0.8, 1.2, P)) for _ in range(n)]
    b = [rng.normal(size=P) for _ in range(n)]

    def grad_fn(params, batch, key):
        Ai, bi = batch
        return (0.5 * params @ Ai @ params - bi @ params,
                Ai @ params - bi + 0.001 * jax.random.normal(key, (P,)))

    def sample_fn(i, rng_):
        return (jnp.asarray(A[i], jnp.float32), jnp.asarray(b[i], jnp.float32))

    w0 = jnp.full((P,), 4.0)
    speeds = truncated_normal_speeds(n, std=1.0, seed=3)
    Abar, bbar = sum(A) / n, sum(b) / n

    def F(w):
        w = np.asarray(w)
        return 0.5 * w @ Abar @ w - bbar @ w

    for name in ALGO_NAMES:
        res = simulate(make_algo(name, n), speeds, grad_fn, sample_fn, w0,
                       lr=0.05, total_iters=200, record_every=50)
        assert F(res.params) < F(w0) - 1.0, name

"""TP-native unravel acceptance tests (docs/engine.md, "TP-native unravel").

Proves, on an 8-device (data, model) host mesh, that the ppermute-ring
exchange paths are BIT-FOR-BIT equal to the replicated oracle in both
directions — ``unravel_sharded`` == ``unravel`` on mixed-dtype trees with a
pad tail and leaves straddling P-shard boundaries, and
``ravel_stacked_sharded`` == ``ravel_stacked`` — on handcrafted layouts and
on a real architecture's ``param_shardings``; that the compiled exchange
(and the whole ``params_layout="tp"`` train step) contains NO tensor of
``P`` or more elements while the replicated step does (detector sanity);
and that the tp step tracks the replicated step across optimizer steps for
every engine backend (first-step losses bitwise equal — the forward from
TP shards is deterministic — params to tight tolerance thereafter, since
GSPMD regroups the backward matmul reductions when params enter sharded).

The in-process tests need >= 8 devices, so on a single-device run they are
skipped and ``test_tp_suite_subprocess`` re-runs them under
``--xla_force_host_platform_device_count=8`` (same driver pattern as
test_engine_sharded.py).  CI additionally runs this file in-process under
the 8-device override.
"""

import os
import subprocess
import sys
import warnings
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from conftest import NDEV, collective_counts, multidevice
from repro.core.flatten import make_flat_spec

N_STACK = 3  # worker dim for the reverse-path tests


def dm_mesh():
    """The (data=2, model=4) mesh the TP suite runs on (8 devices)."""
    return jax.make_mesh((2, 4), ("data", "model"))


def _tree(rng):
    """Mixed-dtype tree exercising every exchange case: a leaf sharded on
    BOTH mesh axes, a stacked leaf, a tiny replicated leaf (odd size => the
    flat vector gets a pad tail), and a bf16 leaf — with leaf boundaries
    falling inside P-shards (W=256 here, 'emb' spans shards 0..2)."""
    return {
        "emb": jnp.asarray(rng.normal(size=(48, 16)), jnp.float32),
        "stk": jnp.asarray(rng.normal(size=(3, 8, 16)), jnp.float32),
        "norm": jnp.asarray(rng.normal(size=(7,)), jnp.float32),
        "b16": jnp.asarray(rng.normal(size=(32, 8)), jnp.float32
                           ).astype(jnp.bfloat16),
    }


def _shardings(mesh):
    return {
        "emb": NamedSharding(mesh, P("model", "data")),
        "stk": NamedSharding(mesh, P(None, "data", "model")),
        "norm": NamedSharding(mesh, P()),
        "b16": NamedSharding(mesh, P("model", None)),
    }


def _spec_plan(mesh):
    from repro.sharding import flat_vec_sharding
    tree = _tree(np.random.default_rng(0))
    spec = make_flat_spec(tree, mesh_axis_size=NDEV)
    plan = spec.tp_plan(mesh, _shardings(mesh), axes=("data", "model"))
    return tree, spec, plan, flat_vec_sharding(spec, mesh, ("data", "model"))


# --------------------------------------------------- exchange == oracle


@multidevice
def test_unravel_sharded_matches_unravel():
    """Forward exchange: P-shards -> TP-layout leaves, bit-for-bit equal to
    slicing the gathered vector, per-leaf dtypes restored (incl. bf16),
    despite the pad tail and shard-straddling leaf boundaries."""
    mesh = dm_mesh()
    tree, spec, plan, vec_sh = _spec_plan(mesh)
    assert spec.padded_size > spec.size  # the pad tail is real
    flat = jax.device_put(spec.ravel(tree), vec_sh)
    got = jax.jit(lambda f: spec.unravel_sharded(f, mesh, plan=plan))(flat)
    want = spec.unravel(spec.ravel(tree))
    for k in tree:
        assert got[k].dtype == want[k].dtype == tree[k].dtype
        np.testing.assert_array_equal(np.asarray(got[k], np.float32),
                                      np.asarray(want[k], np.float32))
    # cast=False keeps the slab dtype (the raw path the forward may use)
    raw = jax.jit(lambda f: spec.unravel_sharded(
        f, mesh, plan=plan, cast=False))(flat)
    assert all(x.dtype == jnp.float32 for x in jax.tree.leaves(raw))


@multidevice
def test_ravel_stacked_sharded_matches_ravel_stacked():
    """Reverse exchange: TP-layout stacked leaves -> [n, P] slab shards,
    bit-for-bit (pure scatters of disjoint positions — signed zeros and all),
    pad lanes zero."""
    mesh = dm_mesh()
    tree, spec, plan, _ = _spec_plan(mesh)
    stree = jax.tree.map(
        lambda x: jnp.stack([x * (i + 1) for i in range(N_STACK)]), tree)
    # oracle BEFORE placement: eager ravel of TP-placed leaves would round-
    # trip through the GSPMD partitioner, which miscompiles reshape+concat
    # over mixed 2-D-sharded operands on this jax version (the bug the
    # shard_map ring sidesteps)
    want = spec.ravel_stacked(stree)
    stree = jax.device_put(stree, {
        k: NamedSharding(mesh, P(None, *sh.spec))
        for k, sh in _shardings(mesh).items()})
    got = jax.jit(lambda t: spec.ravel_stacked_sharded(
        t, mesh, plan=plan))(stree)
    assert got.shape == (N_STACK, spec.padded_size)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert not np.any(np.asarray(got)[:, spec.size:])  # pads stay zero


@multidevice
def test_exchange_bitexact_real_arch():
    """Both directions on a real architecture's ``param_shardings`` (the
    Megatron-TP layouts the train step actually feeds): still bit-for-bit."""
    from repro.configs import get_config
    from repro.models import lm_init
    from repro.sharding import flat_vec_sharding, param_shardings

    cfg = get_config("qwen2_0_5b").smoke()
    mesh = dm_mesh()
    params = lm_init(jax.random.PRNGKey(0), cfg)
    spec = make_flat_spec(params, mesh_axis_size=NDEV)
    p_sh = param_shardings(jax.eval_shape(lambda: params), mesh)
    plan = spec.tp_plan(mesh, p_sh, axes=("data", "model"))

    flat = jax.device_put(spec.ravel(params),
                          flat_vec_sharding(spec, mesh, ("data", "model")))
    got = jax.jit(lambda f: spec.unravel_sharded(f, mesh, plan=plan))(flat)
    want = spec.unravel(spec.ravel(params))
    for (ka, a), (_, b) in zip(
            jax.tree_util.tree_leaves_with_path(got),
            jax.tree_util.tree_leaves_with_path(want)):
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            err_msg=jax.tree_util.keystr(ka))

    stree = jax.tree.map(
        lambda x: jnp.stack([x * (i + 1) for i in range(N_STACK)]), params)
    got = jax.jit(lambda t: spec.ravel_stacked_sharded(
        t, mesh, plan=plan))(stree)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(spec.ravel_stacked(stree)))


# ------------------------------------------------- the memory contract


@multidevice
def test_unravel_hlo_no_full_p_tensor():
    """The compiled forward exchange must contain NO tensor of >= P
    elements (each device only ever holds its window + the circulating one
    + its TP blocks) and must move data via collective-permute, not
    all-gather.  The replicated oracle DOES materialize a full [P] buffer —
    detector sanity."""
    from repro.launch.hlo_analysis import full_p_tensors

    mesh = dm_mesh()
    tree, spec, plan, vec_sh = _spec_plan(mesh)
    flat = jax.device_put(spec.ravel(tree), vec_sh)

    hlo_tp = jax.jit(lambda f: spec.unravel_sharded(f, mesh, plan=plan)
                     ).lower(flat).compile().as_text()
    assert full_p_tensors(hlo_tp, spec.padded_size) == []
    counts = collective_counts(hlo_tp)
    assert counts["collective-permute"] >= 1, counts
    assert counts["all-gather"] == 0, counts

    repl = NamedSharding(mesh, P())
    hlo_repl = jax.jit(lambda f: spec.unravel(
        jax.lax.with_sharding_constraint(f, repl))
    ).lower(flat).compile().as_text()
    assert full_p_tensors(hlo_repl, spec.padded_size) != []


@multidevice
def test_tp_plan_analytics():
    """The plan's analytic memory story: per-device peak is O(P/k + blocks),
    strictly below the replicated O(P) footprint, and every per-leaf gather
    is bounded by that leaf's segment (never P)."""
    mesh = dm_mesh()
    _, spec, plan, _ = _spec_plan(mesh)
    assert plan.k == NDEV
    assert plan.window == spec.padded_size // NDEV
    assert plan.full_vector_bytes == 4 * spec.padded_size
    assert plan.peak_bytes < plan.full_vector_bytes
    assert plan.ring_bytes == (plan.k - 1) * plan.window_bytes
    seg_bytes = plan.max_leaf_segment_bytes()
    assert 0 < seg_bytes <= 4 * max(spec.sizes)
    for lf in plan.leaves:
        assert lf.block_size * 4 <= 4 * spec.sizes[lf.index]


# ----------------------------------------------------- full train step


def _run_steps(cfg, mesh, layout, backend, batch, n_steps=3):
    from repro.core.dude import DuDeConfig
    from repro.launch.steps import (TrainOptions, init_flat_train_state,
                                    make_engine, make_train_step)
    from repro.models import lm_init
    from repro.optim import sgd

    n = cfg.n_workers
    dude_cfg = DuDeConfig(n, jnp.float32)
    options = TrainOptions(params_layout=layout, backend=backend)
    ones = jnp.ones(n, bool)
    with mesh:
        engine = make_engine(cfg, mesh, dude_cfg, options)
        opt = sgd(0.01)
        step = jax.jit(make_train_step(cfg, mesh, opt, dude_cfg=dude_cfg,
                                       options=options, engine=engine))
        state = init_flat_train_state(
            engine, opt, lm_init(jax.random.PRNGKey(0), cfg))
        b_sh = NamedSharding(mesh, P(None, "data", None))
        sb = jax.tree.map(lambda x: jax.device_put(x, b_sh), batch)
        hlo = step.lower(state, sb, ones, ones).compile().as_text()
        losses = []
        for _ in range(n_steps):
            state, metrics = step(state, sb, ones, ones)
            losses.append(float(metrics["loss"]))
    return np.asarray(state.params), losses, hlo, engine.P


@multidevice
@pytest.mark.parametrize("backend", ["reference", "indexed", "pallas"])
def test_tp_step_matches_replicated(backend):
    """params_layout='tp' vs 'replicated' on the full train step, per
    engine backend: the first-step losses are BITWISE equal (the forward
    fed from TP shards is deterministic given identical params); after a
    few optimizer steps params agree to tight tolerance — not bitwise,
    because GSPMD partitions the backward matmul contractions differently
    when params enter TP-sharded (partial-K + psum reorders the reduction).
    The tp step's HLO must hold no full-[P] tensor; the replicated step's
    must (the memory claim is about the layout, not the backend)."""
    from repro.configs import get_config
    from repro.launch.hlo_analysis import full_p_tensors

    cfg = get_config("qwen2_0_5b").smoke()
    mesh = dm_mesh()
    n = cfg.n_workers
    key = jax.random.PRNGKey(1)
    batch = {
        "tokens": jax.random.randint(key, (n, 4, 32), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (n, 4, 32), 0, cfg.vocab_size),
    }
    p_repl, l_repl, hlo_repl, engP = _run_steps(
        cfg, mesh, "replicated", backend, batch)
    p_tp, l_tp, hlo_tp, _ = _run_steps(cfg, mesh, "tp", backend, batch)

    assert l_tp[0] == l_repl[0]          # bitwise: same params, det. forward
    np.testing.assert_allclose(l_tp, l_repl, rtol=2e-2)
    np.testing.assert_allclose(p_tp, p_repl, atol=5e-3, rtol=1e-3)

    assert full_p_tensors(hlo_tp, engP) == []
    assert collective_counts(hlo_tp)["collective-permute"] >= 2  # both rings
    assert full_p_tensors(hlo_repl, engP) != []


# -------------------------------------------- plumbing and validation


def test_params_layout_validation():
    """Misconfiguration fails loudly at construction time, not trace time."""
    from repro.api import ConfigError, TrainerConfig
    from repro.launch.steps import TrainOptions, make_train_step
    from repro.configs import get_config

    with pytest.raises(ValueError, match="params_layout"):
        TrainOptions(params_layout="bogus")
    with pytest.raises(ConfigError, match="params_layout"):
        TrainerConfig(arch="qwen2_0_5b", smoke=True, params_layout="nope")
    with pytest.raises(ConfigError, match="needs a mesh"):
        TrainerConfig(arch="qwen2_0_5b", smoke=True, params_layout="tp")
    cfg = get_config("qwen2_0_5b").smoke()
    with pytest.raises(ValueError, match="mesh-native engine"):
        make_train_step(cfg, mesh=None,
                        options=__import__("repro.launch.steps",
                                           fromlist=["TrainOptions"]
                                           ).TrainOptions(params_layout="tp"))


def test_engine_tp_plan_needs_mesh():
    from repro.core.engine import DuDeEngine

    eng = DuDeEngine.for_tree({"w": jnp.zeros(4)}, 2)
    with pytest.raises(ValueError, match="mesh"):
        eng.tp_plan({"w": None})


@multidevice
def test_tp_plan_cached_and_validated():
    """Same (spec, mesh, shardings) -> the SAME plan object (the exchange
    plan is static geometry, built once); a leaf sharded on an axis outside
    the P-axis group is rejected."""
    from repro.sharding import flat_to_tp_plan

    mesh = dm_mesh()
    tree, spec, plan, _ = _spec_plan(mesh)
    again = flat_to_tp_plan(spec, mesh, _shardings(mesh),
                            axes=("data", "model"))
    assert again is plan
    with pytest.raises(ValueError, match="outside"):
        flat_to_tp_plan(spec, mesh, _shardings(mesh), axes=("data",))


@multidevice
def test_segment_cache_memoized():
    """Satellite: ``shard_segments`` is memoized per spec instance and the
    memo returns the identical tuple."""
    tree = _tree(np.random.default_rng(0))
    spec = make_flat_spec(tree, mesh_axis_size=NDEV)
    first = spec.shard_segments(3)
    assert spec.shard_segments(3) is first


def test_warn_unsplittable_names_leaf_once():
    """Satellite: the constrain_grads fallback warns ONCE per (shapes, D)
    key, naming the offending leaf shape."""
    from repro.launch.steps import _WARNED_UNSPLITTABLE, _warn_unsplittable

    _WARNED_UNSPLITTABLE.clear()
    batch = {"tokens": jnp.zeros((4, 3, 8)), "labels": jnp.zeros((4, 4, 8))}
    with pytest.warns(RuntimeWarning, match=r"\(4, 3, 8\)"):
        _warn_unsplittable(batch, 2)
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # a second warn would raise
        _warn_unsplittable(batch, 2)
    with pytest.warns(RuntimeWarning):   # new key => new warning
        _warn_unsplittable(batch, 4)


# ------------------------------------------------------ subprocess driver


def test_tp_suite_subprocess():
    """Run the in-process tests above on 8 host-platform devices (they are
    skipped in a default single-device session)."""
    if jax.device_count() >= NDEV:
        pytest.skip("already multi-device in-process")
    repo = Path(__file__).resolve().parent.parent
    env = {
        **os.environ,
        "PYTHONPATH": "src",
        "JAX_PLATFORMS": "cpu",
        "XLA_FLAGS": (os.environ.get("XLA_FLAGS", "")
                      + f" --xla_force_host_platform_device_count={NDEV}"
                      ).strip(),
    }
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-p", "no:cacheprovider",
         str(Path(__file__).resolve()), "-k", "not subprocess"],
        capture_output=True, text=True, timeout=540, env=env, cwd=repo,
    )
    assert r.returncode == 0, r.stdout[-4000:] + r.stderr[-2000:]
    assert "skipped" not in r.stdout.splitlines()[-1], r.stdout[-500:]

"""Sparse commit transport (docs/engine.md "Sparse commit transport").

* ``topk_mask`` determinism: exactly k survivors per 128-lane tile, ties
  broken toward the LOWER lane index, identical under jit — the regression
  suite for the documented selection rule;
* a hypothesis property: ``SparseRow`` encode/decode round-trips the dense
  ``(q, scale)`` pair bit-exactly for random touched-tile patterns, pad
  tails and caps (overflow keeps the lowest tile ids and drops the rest);
* bitwise equivalence: ``commit_sparse`` (encode -> SparseRow -> fold) ==
  the dense ``commit`` on g_bar / EF / payload slabs / decoded rows, and
  the sparse_meta round == the plain topk_ef round on all three backends,
  sharded and unsharded;
* the acceptance-criterion HLO check: the compiled ``sparse_fold`` contains
  ZERO dense >= P-element compute ops (state slabs only pass through
  parameters/tuples/scatters), while the dense commit contains many;
* the indexed backend's structured ``drops`` counter and its
  ``engine_drops`` surfacing in ``Trainer.step`` metrics;
* AsyncRunner sparse transport: bitwise equal to the dense topk_ef run on
  the same arrival schedule, with wire/snapshot-cache counters accounted;
* checkpoint back-compat: touched-tile bitmaps synthesized from the stored
  payload slabs when restoring a pre-sparse checkpoint.
"""

import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import NDEV, multidevice, p_mesh
from repro.core.compression import (
    CommitCodec, sparse_decode, sparse_decode_q, sparse_encode,
    sparse_wire_nbytes, topk_mask, touched_tiles,
)
from repro.core.engine import BACKENDS, DuDeEngine
from repro.core.flatten import make_flat_spec
from repro.optim import adamw, flat_twin, sgd


def _tree(rng):
    return {
        "w": jnp.asarray(rng.normal(size=(13, 17)), jnp.float32),
        "emb": jnp.asarray(rng.normal(size=(4, 3, 9)), jnp.float32),
        "b": jnp.asarray(rng.normal(size=5), jnp.float32),
    }


def _zpad(spec, x):
    return x.at[..., spec.size:].set(0)


# --------------------------------------------- topk_mask determinism rule


def test_topk_mask_tie_break_lowest_lane():
    """Equal-magnitude ties keep the LOWER lane index — the documented rule,
    on full-tile ties, threshold ties, and sign-mixed ties."""
    # all 128 lanes tie: survivors are exactly lanes 0..k-1
    out = np.asarray(topk_mask(jnp.ones(128), 4))
    assert (out[:4] == 1).all() and not out[4:].any()
    # ties at the k-th threshold: 5 wins, then the first two 4s
    x = jnp.zeros(128).at[0].set(5.0).at[jnp.arange(1, 7)].set(4.0)
    out = np.asarray(topk_mask(x, 3))
    assert set(np.flatnonzero(out)) == {0, 1, 2}
    # |x| decides, sign does not: -1/+1 alternating all tie
    x = jnp.where(jnp.arange(128) % 2 == 0, -1.0, 1.0)
    out = np.asarray(topk_mask(x, 5))
    assert list(np.flatnonzero(out)) == [0, 1, 2, 3, 4]
    np.testing.assert_array_equal(out[:5], np.asarray(x[:5]))
    # per-tile independence: a second tile with its own tie set
    x2 = jnp.concatenate([x, jnp.zeros(128).at[120:].set(2.0)])
    out2 = np.asarray(topk_mask(x2, 5))
    np.testing.assert_array_equal(out2[:128], out)
    assert list(np.flatnonzero(out2[128:])) == [120, 121, 122, 123, 124]


def test_topk_mask_exact_k_and_jit_eager_agree():
    """EXACTLY k survivors per tile on dense inputs, and the jitted lowering
    picks the identical survivor set as eager (both bit-pure max/min/where
    sweeps)."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(np.sign(rng.normal(size=512))
                    * (0.5 + rng.random(512)), jnp.float32)
    for k in (1, 7, 16):
        out = np.asarray(topk_mask(x, k))
        assert ((out != 0).reshape(4, 128).sum(-1) == k).all()
        np.testing.assert_array_equal(
            out, np.asarray(jax.jit(topk_mask, static_argnums=(1,))(x, k)))
    # an all-zero tile stays all-zero (the k kept lanes hold zeros)
    assert not np.asarray(topk_mask(jnp.zeros(128), 8)).any()


# ------------------------------------------ SparseRow roundtrip property

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dev dependency
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @settings(max_examples=40, deadline=None)
    @given(
        tiles=st.integers(1, 6),
        k=st.sampled_from([4, 8, 16]),
        cap=st.integers(1, 6),
        frac=st.floats(0.0, 1.0),
        pad=st.integers(0, 100),
        mag=st.floats(1e-4, 1e4),
        seed=st.integers(0, 10_000),
    )
    def test_sparse_row_roundtrip_property(tiles, k, cap, frac, pad, mag,
                                           seed):
        """``sparse_encode`` / ``sparse_decode_q`` round-trip the dense
        ``(q, scale)`` pair bit-exactly whenever the touched set fits
        ``cap``; on overflow the lowest tile ids are kept and the rest
        dropped.  Random touched patterns, spec-style zero pad tails, and
        every cap, including cap < tiles."""
        cap = min(cap, tiles)
        rng = np.random.default_rng(seed)
        P = tiles * 128
        x = np.asarray(rng.normal(size=P) * mag, np.float32)
        keep = rng.random(tiles) < frac
        x *= np.repeat(keep, 128)
        if pad:  # flat-spec pad tail: trailing lanes are structurally zero
            x[P - min(pad, P):] = 0.0
        codec = CommitCodec(format="topk_ef", topk=k)
        q, s = codec.encode(jnp.asarray(x))
        row = sparse_encode(q, s, cap, k)
        t_ids = np.flatnonzero(np.asarray(touched_tiles(q)))
        assert int(row.count) == min(len(t_ids), cap)
        live = np.asarray(row.tiles)[: int(row.count)]
        np.testing.assert_array_equal(live, t_ids[:cap])   # ascending ids
        assert (np.asarray(row.tiles)[int(row.count):] == tiles).all()
        q2, s2 = sparse_decode_q(row, P)
        dec2 = np.asarray(sparse_decode(row, P))
        if len(t_ids) <= cap:   # full fidelity: bitwise inverse
            np.testing.assert_array_equal(np.asarray(q2), np.asarray(q))
            np.testing.assert_array_equal(np.asarray(s2), np.asarray(s))
            np.testing.assert_array_equal(
                dec2, np.asarray(codec.decode(q, s)))
        else:                   # overflow: carried tiles exact, rest zero
            m = np.repeat(np.isin(np.arange(tiles), live), 128)
            np.testing.assert_array_equal(np.asarray(q2)[m],
                                          np.asarray(q)[m])
            assert not np.asarray(q2)[~m].any()
            np.testing.assert_array_equal(
                dec2[m], np.asarray(codec.decode(q, s))[m])
            assert not dec2[~m].any()


# ------------------------------------- bitwise sparse == dense equivalence


def test_commit_sparse_matches_dense_commit_bitwise():
    """Lockstep over 24 commits: encode -> SparseRow -> scatter-fold equals
    the dense ``commit`` BITWISE on g_bar, the EF residual, the int8 payload
    slab, and the decoded rows (stale scales on never-listed tiles are
    decode-invisible)."""
    rng = np.random.default_rng(0)
    n = 4
    tree = {"w": jnp.zeros(700)}
    dense = DuDeEngine.for_tree(tree, n_workers=n, commit_format="topk_ef",
                                interpret=True)
    sparse = DuDeEngine.for_tree(tree, n_workers=n, commit_format="topk_ef",
                                 interpret=True, sparse_meta=True)
    d_st, s_st = dense.init(), sparse.init()
    dcommit = jax.jit(dense.commit)
    scommit = jax.jit(sparse.commit_sparse)
    decode = jax.jit(dense.codec.decode)
    for t in range(24):
        w = int(rng.integers(n))
        g = _zpad(dense.spec,
                  jnp.asarray(rng.normal(size=dense.P) * 2.0, jnp.float32))
        d_st, g_d = dcommit(d_st, jnp.int32(w), g)
        s_st, g_s = scommit(s_st, jnp.int32(w), g)
        np.testing.assert_array_equal(np.asarray(g_d), np.asarray(g_s))
        np.testing.assert_array_equal(np.asarray(d_st.ef),
                                      np.asarray(s_st.ef))
        np.testing.assert_array_equal(np.asarray(d_st.g_workers),
                                      np.asarray(s_st.g_workers))
        np.testing.assert_array_equal(
            np.asarray(decode(d_st.g_workers, d_st.gw_scale)),
            np.asarray(decode(s_st.g_workers, s_st.gw_scale)))
        # the sparse invariant: bitmap == touched tiles of the payload rows
        np.testing.assert_array_equal(
            np.asarray(s_st.gw_touched, bool),
            np.asarray(touched_tiles(s_st.g_workers)))


def test_cap_overflow_reenters_ef_bitwise():
    """A cap smaller than the touched set degrades gracefully: the EF
    invariant ``dec(row) + ef' == g + ef`` holds BITWISE per commit (dropped
    tiles re-enter whole), and the slab row always equals the row's own
    decode."""
    rng = np.random.default_rng(5)
    n = 3
    eng = DuDeEngine.for_tree({"w": jnp.zeros(900)}, n_workers=n,
                              commit_format="topk_ef", interpret=True,
                              sparse_meta=True, sparse_cap=2)
    assert eng.cap_tiles == 2 < eng.n_tiles
    st = eng.init()
    enc = jax.jit(eng.encode_sparse_commit)
    fold = jax.jit(eng.sparse_fold)
    for t in range(9):
        w = jnp.int32(t % n)
        g = _zpad(eng.spec,
                  jnp.asarray(rng.normal(size=eng.P), jnp.float32))
        ef_old = st.ef
        st, row = enc(st, w, g)
        assert int(row.count) <= 2
        dec = sparse_decode(row, eng.P)
        np.testing.assert_array_equal(np.asarray(dec + st.ef),
                                      np.asarray(g + ef_old))
        st, _ = fold(st, w, row)
        q2, _ = sparse_decode_q(row, eng.P)
        np.testing.assert_array_equal(np.asarray(st.g_workers[t % n]),
                                      np.asarray(q2))


def _engines(backend, n, spec, mesh=None, sparse=False):
    kw = dict(spec=spec, n_workers=n, backend=backend, interpret=True,
              commit_format="topk_ef")
    if sparse:
        kw.update(sparse_meta=True)
    if mesh is not None:
        kw.update(mesh=mesh, axis_name="p")
    return DuDeEngine(**kw)


def _run_rounds(eng, fopt, spec, steps=4, seed=3, shardings=None):
    rng = np.random.default_rng(seed)
    n, P = eng.n_workers, spec.padded_size
    st = eng.init()
    w = jnp.zeros(P, jnp.float32).at[:spec.size].set(
        jnp.asarray(rng.normal(size=spec.size), jnp.float32))
    ost = fopt.init(w)
    if shardings is not None:
        sh_state, sh_w, sh_opt = shardings
        st = jax.device_put(st, sh_state)
        w = jax.device_put(w, sh_w)
        ost = jax.device_put(ost, sh_opt)
    step = jax.jit(lambda s, f, a, b, w, o:
                   eng.round_apply(s, f, a, b, w, o, fopt))
    outs = []
    for t in range(steps):
        fresh = _zpad(spec, jnp.asarray(rng.normal(size=(n, P)) * 2.0,
                                        jnp.float32))
        sm = jnp.asarray(rng.random(n) < 0.6)
        cm = jnp.asarray(rng.random(n) < 0.5)
        st, gbar, w, ost = step(st, fresh, sm, cm, w, ost)
        outs.append((st, gbar, w, ost))
    return outs


def _assert_outs_equal(a, b):
    for (sa, ga, wa, oa), (sb, gb, wb, ob) in zip(a, b):
        da, db = sa._asdict(), sb._asdict()
        assert set(da) == set(db)
        for k in da:  # fields absent (None) on either side don't compare
            if da[k] is None or db[k] is None:
                continue
            np.testing.assert_array_equal(
                np.asarray(da[k], np.float32), np.asarray(db[k], np.float32),
                err_msg=f"EngineState.{k}")
        for la, lb in zip(jax.tree.leaves((ga, wa, oa)),
                          jax.tree.leaves((gb, wb, ob))):
            np.testing.assert_array_equal(
                np.asarray(la, np.float32), np.asarray(lb, np.float32))


@pytest.mark.parametrize("backend", BACKENDS)
def test_sparse_round_matches_plain_topk_round(backend):
    """The touched-tile round of a sparse_meta engine reproduces the plain
    topk_ef round BITWISE on every shared leaf (g_bar, slabs, scales, EF,
    params, adamw slots) on all three backends, and maintains the
    bitmap == touched_tiles(slab) invariant."""
    spec = make_flat_spec(_tree(np.random.default_rng(0)))
    fopt = flat_twin(adamw(0.01, weight_decay=0.1))
    plain = _run_rounds(_engines(backend, 4, spec), fopt, spec)
    got = _run_rounds(_engines(backend, 4, spec, sparse=True), fopt, spec)
    _assert_outs_equal(plain, got)
    for stt, _, _, _ in got:
        np.testing.assert_array_equal(
            np.asarray(stt.gw_touched, bool),
            np.asarray(touched_tiles(stt.g_workers)))
        np.testing.assert_array_equal(
            np.asarray(stt.in_touched, bool),
            np.asarray(touched_tiles(stt.inflight)))


@multidevice
@pytest.mark.parametrize("backend", BACKENDS)
def test_sparse_round_sharded_matches_unsharded(backend):
    """P-axis sharded sparse_meta round_apply == single-device, bit-for-bit
    including the ``[n, P/128]`` touched-tile bitmaps."""
    from repro.sharding import flat_train_state_shardings

    spec = make_flat_spec(_tree(np.random.default_rng(0)),
                          mesh_axis_size=NDEV)
    mesh = p_mesh()
    fopt = flat_twin(adamw(0.01, weight_decay=0.1))
    eng_u = _engines(backend, 4, spec, sparse=True)
    eng_s = _engines(backend, 4, spec, mesh=mesh, sparse=True)
    sh = flat_train_state_shardings(spec, mesh, ("p",), fopt.init(
        jnp.zeros(spec.padded_size)), server_like=eng_s.state_shapes())
    outs_u = _run_rounds(eng_u, fopt, spec)
    outs_s = _run_rounds(eng_s, fopt, spec,
                         shardings=(eng_s.shardings(), sh.params, sh.opt))
    _assert_outs_equal(outs_u, outs_s)


@multidevice
def test_sparse_fold_sharded_matches_unsharded():
    """The mesh-native fold (replicated wire row, each P-shard folds only
    its own tiles via the global->local id shift) == the single-device fold
    bitwise, across shard-boundary-straddling touched sets."""
    rng = np.random.default_rng(2)
    n = 4
    tree = {"w": jnp.zeros(NDEV * 256)}
    spec = make_flat_spec(tree, mesh_axis_size=NDEV)
    eng_u = DuDeEngine(spec=spec, n_workers=n, commit_format="topk_ef",
                       interpret=True, sparse_meta=True)
    eng_s = DuDeEngine(spec=spec, n_workers=n, commit_format="topk_ef",
                       interpret=True, sparse_meta=True,
                       mesh=p_mesh(), axis_name="p")
    st_u, st_s = eng_u.init(), jax.device_put(eng_s.init(),
                                              eng_s.shardings())
    enc = jax.jit(eng_u.encode_sparse_commit)
    fold_u, fold_s = jax.jit(eng_u.sparse_fold), jax.jit(eng_s.sparse_fold)
    for t in range(2 * n):
        w = jnp.int32(t % n)
        g = _zpad(spec, jnp.asarray(rng.normal(size=spec.padded_size),
                                    jnp.float32))
        st_u, row = enc(st_u, w, g)
        st_s = st_s._replace(ef=jnp.asarray(st_u.ef))  # sender-side state
        st_u, gb_u = fold_u(st_u, w, row)
        st_s, gb_s = fold_s(st_s, w, row)
        np.testing.assert_array_equal(np.asarray(gb_u), np.asarray(gb_s))
        for k in ("g_workers", "gw_scale", "gw_touched"):
            np.testing.assert_array_equal(
                np.asarray(getattr(st_u, k), np.float32),
                np.asarray(getattr(st_s, k), np.float32), err_msg=k)


# --------------------------------------- acceptance: no dense [P] compute


def test_sparse_fold_hlo_zero_dense_p_compute():
    """The compiled ``sparse_fold`` computes NO dense >= P-element array:
    the [P]/[n, P] state slabs only pass through parameters, tuples, copies
    and scatter writes.  The dense ``commit`` on the same engine shape is
    the positive control — it computes dozens."""
    from repro.launch.hlo_analysis import dense_p_compute_ops

    tree = {"w": jnp.zeros((64, 128)), "b": jnp.zeros(320)}
    eng = DuDeEngine.for_tree(tree, 4, commit_format="topk_ef",
                              sparse_meta=True, sparse_cap=8)
    dense = DuDeEngine.for_tree(tree, 4, commit_format="topk_ef")
    st = eng.init()
    g = jnp.zeros((eng.P,), jnp.float32)
    _, row = jax.jit(eng.encode_sparse_commit)(st, jnp.int32(0), g)
    hlo = jax.jit(eng.sparse_fold).lower(st, jnp.int32(0), row
                                         ).compile().as_text()
    assert dense_p_compute_ops(hlo, eng.P) == []
    hlo_d = jax.jit(dense.commit).lower(dense.init(), jnp.int32(0), g
                                        ).compile().as_text()
    assert len(dense_p_compute_ops(hlo_d, eng.P)) > 5  # the check has teeth


# ------------------------------------------- indexed drops counter surface


def test_indexed_drops_counter_accumulates():
    """|C_t| or |S_t| beyond ``index_width`` increments the structured
    ``drops`` counter by the exact overflow, accumulating across rounds."""
    spec = make_flat_spec({"w": jnp.zeros(300)})
    eng = DuDeEngine(spec=spec, n_workers=4, backend="indexed",
                     index_width=1, index_check="off", interpret=True)
    st = eng.init()
    assert int(st.drops) == 0
    fresh = jnp.ones((4, eng.P), jnp.float32)
    step = jax.jit(eng.round)
    sm = jnp.asarray([True, True, False, False])   # 2 starts  -> +1
    cm = jnp.asarray([True, True, True, False])    # 3 commits -> +2
    st, _ = step(st, fresh, sm, cm)
    assert int(st.drops) == 3
    st, _ = step(st, fresh, jnp.zeros(4, bool), cm)
    assert int(st.drops) == 5
    # reference backend carries no counter at all
    ref = DuDeEngine(spec=spec, n_workers=4, interpret=True)
    assert ref.init().drops is None


def test_trainer_step_surfaces_engine_drops_metric():
    """``Trainer.step`` metrics expose ``engine_drops`` on indexed-backend
    sessions (and omit it elsewhere) — the structured twin of the in-graph
    debug warning."""
    from repro.api import Trainer, TrainerConfig
    from repro.models.config import ModelConfig

    cfg = ModelConfig(
        name="drops-lm", arch_type="dense", num_layers=1, d_model=32,
        num_heads=2, num_kv_heads=1, d_ff=64, vocab_size=32,
        dtype=jnp.float32, remat=False, attn_chunk=16, n_workers=3,
    )
    key = jax.random.PRNGKey(0)
    batch = {
        "tokens": jax.random.randint(key, (3, 1, 16), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (3, 1, 16), 0, cfg.vocab_size),
    }
    ones = jnp.ones(3, bool)
    t = Trainer.create(TrainerConfig(arch=cfg, lr=0.01,
                                     server_backend="indexed"))
    m = t.step(batch, ones, ones)
    assert float(m["engine_drops"]) == 0.0  # full width never drops
    t2 = Trainer.create(TrainerConfig(arch=cfg, lr=0.01))
    assert "engine_drops" not in t2.step(batch, ones, ones)


# --------------------------------------------------- config validation


def test_sparse_transport_config_validation():
    from repro.api import ConfigError, TrainerConfig

    with pytest.raises(ConfigError, match="topk_ef"):
        TrainerConfig(arch="qwen2_0_5b", smoke=True, sparse_transport=True)
    with pytest.raises(ConfigError, match="sparse_transport"):
        TrainerConfig(arch="qwen2_0_5b", smoke=True,
                      commit_format="topk_ef", sparse_cap=4)
    with pytest.raises(ValueError, match="topk_ef"):
        DuDeEngine(spec=make_flat_spec({"w": jnp.zeros(300)}), n_workers=2,
                   commit_format="int8_ef", sparse_meta=True)
    TrainerConfig(arch="qwen2_0_5b", smoke=True, commit_format="topk_ef",
                  sparse_transport=True, sparse_cap=2)  # valid combination


# ------------------------------------------- AsyncRunner sparse transport


def test_runner_sparse_transport_bitwise_and_counters():
    """The sparse-transport AsyncRunner run is BITWISE identical to the
    dense topk_ef run on the same arrival schedule — params, engine slabs,
    losses — and its counters account the transport: one SparseRow per
    arrival at the engine-cap wire size, one snapshot encode per applying
    delivery plus the init zero-delta shared by all n workers."""
    from repro.runtime import ExponentialArrivals
    from repro.runtime.runner import AsyncRunner

    rng = np.random.default_rng(0)
    n, total = 4, 60
    tree = {"w": jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)}
    targets = jnp.asarray(rng.normal(size=(n, 8, 16)), jnp.float32)

    def sample_fn(i, host_rng):
        return {"i": jnp.int32(i),
                "noise": jnp.asarray(host_rng.normal(size=(8, 16)),
                                     jnp.float32)}

    def grad_fn(params, batch, key):
        def loss(p):
            t = targets[batch["i"]] + 0.05 * batch["noise"]
            return 0.5 * jnp.sum((p["w"] - t) ** 2)
        return jax.value_and_grad(loss)(params)

    outs = {}
    for name, sparse in (("dense", False), ("sparse", True)):
        eng = DuDeEngine.for_tree(tree, n_workers=n,
                                  commit_format="topk_ef", interpret=True,
                                  sparse_meta=sparse)
        runner = AsyncRunner(eng, "dude", sgd(0.05), grad_fn)
        assert runner._sparse == sparse
        outs[name] = (eng, runner.run(
            ExponentialArrivals(n, seed=1), total, sample_fn,
            runner.init_state(tree), seed=0, record_every=10))
    eng_s, res_s = outs["sparse"]
    _, res_d = outs["dense"]
    np.testing.assert_array_equal(np.asarray(res_s.state.params),
                                  np.asarray(res_d.state.params))
    np.testing.assert_array_equal(np.asarray(res_s.state.engine.g_bar),
                                  np.asarray(res_d.state.engine.g_bar))
    np.testing.assert_array_equal(np.asarray(res_s.state.engine.g_workers),
                                  np.asarray(res_d.state.engine.g_workers))
    np.testing.assert_array_equal(res_s.losses, res_d.losses)
    # transport accounting: payload_bytes is the analytic row bytes,
    # wire_bytes the framed (prefix + header + padding) socket bytes
    assert res_d.wire_rows == res_d.wire_bytes == res_d.payload_bytes == 0
    assert res_s.wire_rows == total
    cap, k = eng_s.cap_tiles, eng_s.codec.topk
    assert res_s.payload_bytes == total * (cap * (2 * k + 8) + 4)
    st0 = eng_s.init()
    _, row = jax.jit(eng_s.encode_sparse_commit)(
        st0, jnp.int32(0), jnp.zeros(eng_s.P))
    assert res_s.payload_bytes == total * sparse_wire_nbytes(row)
    from repro.runtime.transport import commit_frame_nbytes, pack_arrays
    manifest, payload = pack_arrays([np.asarray(x) for x in row])
    assert len(payload) == sparse_wire_nbytes(row)
    # every framed commit strictly exceeds its payload; the exact total is
    # the sum of per-(worker, job) header sizes over the recorded arrivals
    jobs = {}
    framed = 0
    for w in np.asarray(res_s.trace.worker):
        j = jobs.get(int(w), 0)
        jobs[int(w)] = j + 1
        framed += commit_frame_nbytes(int(w), j, manifest, len(payload))
    assert res_s.wire_bytes == framed > res_s.payload_bytes
    # snapshot-encode cache: the init zero-delta is encoded once and shared
    # n ways; every applying delivery afterwards sees fresh params
    assert res_s.snap_encodes >= 1
    assert res_s.snap_reuses >= n - 1
    assert res_s.snap_encodes + res_s.snap_reuses == total + n


# -------------------------------------------- checkpoint touched synthesis


def test_ckpt_sparse_state_roundtrip_and_synthesis(tmp_path):
    """A sparse_meta FlatTrainState checkpoints bit-exactly; restoring a
    PRE-SPARSE checkpoint (dense topk_ef state, no bitmap leaves) into a
    sparse_meta structure synthesizes the touched bitmaps from the stored
    payload slabs — exactly the engine invariant."""
    from repro.checkpoint import restore_checkpoint, save_checkpoint
    from repro.launch.steps import init_flat_train_state

    rng = np.random.default_rng(4)
    tree = {"w": jnp.asarray(rng.normal(size=(20, 20)), jnp.float32)}
    spec = make_flat_spec(tree)

    def populated(sparse):
        eng = DuDeEngine.for_tree(tree, n_workers=3,
                                  commit_format="topk_ef", interpret=True,
                                  sparse_meta=sparse)
        state = init_flat_train_state(eng, adamw(0.01), tree)
        srv = state.engine
        commit = jax.jit(eng.commit)
        for t in range(6):
            g = _zpad(spec, jnp.asarray(rng.normal(size=eng.P), jnp.float32))
            srv, _ = commit(srv, jnp.int32(t % 3), g)
        return state._replace(engine=srv)

    # roundtrip: bitmaps stored and restored bit-exactly
    state_s = populated(sparse=True)
    assert state_s.engine.gw_touched is not None
    save_checkpoint(str(tmp_path / "s"), 1, state_s, flat_spec=spec)
    back = restore_checkpoint(str(tmp_path / "s"), 1, state_s,
                              flat_spec=spec)
    for a, b in zip(jax.tree.leaves(state_s), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))

    # back-compat: dense checkpoint -> sparse_meta structure
    state_d = populated(sparse=False)
    save_checkpoint(str(tmp_path / "d"), 2, state_d, flat_spec=spec)
    like = populated(sparse=True)
    back = restore_checkpoint(str(tmp_path / "d"), 2, like, flat_spec=spec)
    np.testing.assert_array_equal(np.asarray(back.engine.g_workers),
                                  np.asarray(state_d.engine.g_workers))
    np.testing.assert_array_equal(
        np.asarray(back.engine.gw_touched, bool),
        np.asarray(touched_tiles(state_d.engine.g_workers)))
    np.testing.assert_array_equal(
        np.asarray(back.engine.in_touched, bool),
        np.asarray(touched_tiles(state_d.engine.inflight)))


# ------------------------------------------------------ subprocess driver


def test_sparse_transport_sharded_suite_subprocess():
    """Run the in-process multidevice tests above on 8 host-platform
    devices (they are skipped in a default single-device session)."""
    if jax.device_count() >= NDEV:
        pytest.skip("already multi-device in-process")
    repo = Path(__file__).resolve().parent.parent
    env = {
        **os.environ,
        "PYTHONPATH": "src",
        "JAX_PLATFORMS": "cpu",
        "XLA_FLAGS": (os.environ.get("XLA_FLAGS", "")
                      + f" --xla_force_host_platform_device_count={NDEV}"
                      ).strip(),
    }
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-p", "no:cacheprovider",
         str(Path(__file__).resolve()), "-k", "not subprocess"],
        capture_output=True, text=True, timeout=540, env=env, cwd=repo,
    )
    assert r.returncode == 0, r.stdout[-4000:] + r.stderr[-2000:]
    assert "skipped" not in r.stdout.splitlines()[-1], r.stdout[-500:]

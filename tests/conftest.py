import os
import re

# Keep single-device defaults for smoke tests/benches (the dry-run sets its
# own 512-device override in its own process).  Cap CPU threads for CI noise.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)


# ---- shared scaffolding for the sharded suites (test_engine_sharded.py,
# ---- test_flat_state.py): one copy so the skip guard, the mesh, and the
# ---- zero-collective assertion's op list cannot drift apart.

NDEV = 8

multidevice = pytest.mark.skipif(
    jax.device_count() < NDEV,
    reason=f"needs {NDEV} devices (run under "
           f"XLA_FLAGS=--xla_force_host_platform_device_count={NDEV})")

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")


def collective_counts(hlo: str) -> dict:
    return {op: len(re.findall(op + r"\(", hlo)) for op in COLLECTIVE_OPS}


def p_mesh():
    """The NDEV-device 1-axis ("p") mesh every sharded suite runs on."""
    return jax.make_mesh((NDEV,), ("p",))

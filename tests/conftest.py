import os

# Keep single-device defaults for smoke tests/benches (the dry-run sets its
# own 512-device override in its own process).  Cap CPU threads for CI noise.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)

"""Multi-host transport acceptance tests (docs/async.md, "Multi-host
transport").

* Frame codec: encode/decode roundtrips for f32 commits, int8+scales
  pairs, and SparseRow payloads (zero-touched and cap-saturated rows
  included) are BYTE-exact; ``framed_nbytes``/``commit_frame_nbytes``
  predict the real frame sizes; corrupt prefixes fail loudly.
* Real socket bytes: the same roundtrips through a connected
  ``socket.socketpair`` via ``SocketTransport`` — including a
  hypothesis property sweep over mixed dtypes/shapes when hypothesis is
  installed — plus EOF/timeout semantics on both transport twins.
* ArrivalTrace schema: v2 files carry digests, v1 files (no ``schema``
  key) upgrade in place, unknown versions are rejected.
* Hosted integration: 2-link loopback runs (InProc and socketpair)
  driven by real ``run_worker`` clients replay through the
  single-process ``AsyncRunner`` BIT-FOR-BIT (params, digests, losses,
  times); a mid-run dead worker (EOF and silent-heartbeat variants) is
  detected and the run still completes; a dropped link reconnects
  through ``accept_fn`` and the resumed run still replays bitwise.
"""

import json
import socket
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compression import (SparseRow, commit_digest,
                                    sparse_wire_nbytes)
from repro.core.engine import DuDeEngine
from repro.core.flatten import make_flat_spec
from repro.optim import sgd
from repro.runtime.arrivals import TRACE_SCHEMA, ArrivalTrace, TraceArrivals
from repro.runtime.hostloop import HostRunner, run_worker
from repro.runtime.runner import AsyncRunner
from repro.runtime.transport import (FRAME_ALIGN, InProcTransport,
                                     SocketTransport, TransportClosed,
                                     TransportError, TransportTimeout,
                                     commit_frame_nbytes, commit_header,
                                     decode_frame, encode_frame,
                                     framed_nbytes, pack_arrays,
                                     sparse_row_arrays,
                                     sparse_row_from_arrays, unpack_arrays)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # container without hypothesis
    HAVE_HYPOTHESIS = False

N = 5
LR = 0.05
SEED = 3


# ------------------------------------------------------------------ fixtures

def _tree():
    rng = np.random.default_rng(0)
    return {"w": jnp.asarray(rng.normal(size=(3, 4)), jnp.float32),
            "b": jnp.zeros((5,), jnp.float32)}


_TARGETS = jnp.asarray(np.random.default_rng(1).normal(size=(3, 4)),
                       jnp.float32)


def _sample_fn(i, rng):
    return {"i": np.int32(i), "noise": np.asarray(
        rng.normal(size=(3, 4)), np.float32)}


def _loss(params, batch, key):
    noise = batch["noise"] * 0.01
    return (jnp.sum((params["w"] - _TARGETS + noise) ** 2)
            + jnp.sum(params["b"] ** 2) * 0.1
            + 0.001 * batch["i"].astype(jnp.float32))


def _grad_fn(params, batch, key):
    return jax.value_and_grad(_loss)(params, batch, key)


def make_runner(fmt="topk_ef", cap=None):
    tree = _tree()
    spec = make_flat_spec(tree)
    eng = DuDeEngine.for_tree(tree, n_workers=N, interpret=True,
                              commit_format=fmt,
                              **({"sparse_meta": True, "sparse_cap": cap}
                                 if fmt == "topk_ef" else {}))
    return AsyncRunner(eng, "dude", sgd(LR), _grad_fn), spec, tree


def _sparse_row(cap=4, k=16, count=2, seed=0):
    rng = np.random.default_rng(seed)
    return SparseRow(
        tiles=np.asarray(rng.integers(0, 100, cap), np.int32),
        lanes=np.asarray(rng.integers(0, 128, (cap, k)), np.uint8),
        vals=np.asarray(rng.integers(-127, 128, (cap, k)), np.int8),
        scales=np.asarray(rng.normal(size=cap), np.float32),
        count=np.asarray(count, np.int32),
    )


def _assert_arrays_equal(got, want):
    assert len(got) == len(want)
    for g, w in zip(got, want):
        w = np.asarray(w)
        assert g.dtype == w.dtype.newbyteorder("<") or g.dtype == w.dtype
        assert g.shape == w.shape
        np.testing.assert_array_equal(g, w)


# -------------------------------------------------------------- frame codecs

class TestFraming:
    def test_f32_commit_roundtrip(self):
        g = np.asarray(np.random.default_rng(0).normal(size=257), np.float32)
        frame = encode_frame("commit", commit_header(3, 7, 1.25,
                                                     commit_digest(g)), [g])
        assert len(frame) % FRAME_ALIGN == 0
        msg, used = decode_frame(frame)
        assert used == len(frame)
        assert msg.kind == "commit"
        assert (msg.meta["w"], msg.meta["j"]) == (3, 7)
        assert msg.meta["loss"] == 1.25
        _assert_arrays_equal(msg.arrays, [g])
        assert commit_digest(msg.arrays[0]) == msg.meta["dg"]

    def test_int8_ef_pair_roundtrip(self):
        rng = np.random.default_rng(1)
        q = np.asarray(rng.integers(-127, 128, 384), np.int8)
        s = np.asarray(rng.normal(size=3), np.float32)
        msg, _ = decode_frame(encode_frame("snapshot", {"w": 0, "j": 2},
                                           [q, s]))
        _assert_arrays_equal(msg.arrays, [q, s])

    @pytest.mark.parametrize("count", [0, 2, 4])  # zero-touched .. saturated
    def test_sparse_row_roundtrip(self, count):
        row = _sparse_row(cap=4, count=count)
        arrays = sparse_row_arrays(row)
        manifest, payload = pack_arrays(arrays)
        assert len(payload) == sparse_wire_nbytes(row)
        msg, _ = decode_frame(encode_frame("snapshot", {"w": 1}, arrays))
        got = sparse_row_from_arrays(msg.arrays)
        for f in SparseRow._fields:
            np.testing.assert_array_equal(np.asarray(getattr(got, f)),
                                          np.asarray(getattr(row, f)))

    def test_mixed_dtypes_and_scalars(self):
        arrays = [np.float64([[1.5, -2.0]]), np.int64(7),
                  np.zeros((0, 3), np.float32), np.uint8([255, 0])]
        msg, _ = decode_frame(encode_frame("x", None, arrays))
        _assert_arrays_equal(msg.arrays, arrays)

    def test_framed_nbytes_predicts_real_size(self):
        g = np.ones(100, np.float32)
        manifest, payload = pack_arrays([g])
        meta = commit_header(2, 5, 0.5, commit_digest(g))
        frame = encode_frame("commit", meta, [g])
        assert framed_nbytes("commit", meta, len(payload),
                             manifest) == len(frame)

    def test_commit_frame_nbytes_fixed_width(self):
        # placeholder and real loss/digest headers must be the SAME size
        # for the same ids — the simulated runner's byte accountant
        # depends on it
        row = _sparse_row()
        manifest, payload = pack_arrays(sparse_row_arrays(row))
        want = commit_frame_nbytes(3, 11, manifest, len(payload))
        real = encode_frame(
            "commit", commit_header(3, 11, -1234.567, commit_digest(
                np.ones(5, np.float32))), sparse_row_arrays(row))
        assert len(real) == want

    def test_bad_magic_and_version(self):
        frame = bytearray(encode_frame("ping"))
        bad = b"XX" + bytes(frame[2:])
        with pytest.raises(TransportError, match="magic"):
            decode_frame(bad)
        frame[2] = 250  # absurd protocol version
        with pytest.raises(TransportError, match="protocol v250"):
            decode_frame(bytes(frame))

    def test_partial_frame_is_timeout_not_error(self):
        frame = encode_frame("commit", commit_header(0, 0),
                             [np.ones(64, np.float32)])
        for cut in (0, 3, len(frame) // 2, len(frame) - 1):
            with pytest.raises(TransportTimeout):
                decode_frame(frame[:cut])

    def test_truncated_payload_rejected(self):
        manifest, payload = pack_arrays([np.ones(16, np.float32)])
        with pytest.raises(TransportError, match="truncated"):
            unpack_arrays(manifest, payload[:-8])


# --------------------------------------------------------- malformed frames

def _fuzz_frame(seed=0):
    """A realistic frame to mutilate: header meta + two payload arrays."""
    rng = np.random.default_rng(seed)
    g = np.asarray(rng.normal(size=57), np.float32)
    q = np.asarray(rng.integers(-127, 128, 23), np.int8)
    return encode_frame("commit", commit_header(2, 9, 0.75,
                                                commit_digest(g)), [g, q])


class TestFrameFuzz:
    """decode_frame on adversarial bytes: every malformed input must fail
    with a STRUCTURED protocol error (TransportError for corruption,
    TransportTimeout for incompleteness) — never a raw struct/msgpack/key
    error, and never a hang."""

    def test_every_truncation_is_timeout(self):
        frame = _fuzz_frame()
        for cut in range(len(frame)):
            with pytest.raises(TransportTimeout):
                decode_frame(frame[:cut])

    def test_every_protocol_version_rejected(self):
        frame = bytearray(_fuzz_frame())
        for ver in range(256):
            if ver == frame[2]:
                continue
            bad = bytes(frame[:2]) + bytes([ver]) + bytes(frame[3:])
            with pytest.raises(TransportError, match="protocol"):
                decode_frame(bad)

    def test_unknown_header_codec_rejected(self):
        frame = bytearray(_fuzz_frame())
        for codec in (7, 99, 255):
            bad = bytes(frame[:3]) + bytes([codec]) + bytes(frame[4:])
            with pytest.raises(TransportError, match="codec"):
                decode_frame(bad)

    def test_corrupt_header_bytes_are_structured(self):
        """Flipping bytes inside the msgpack header region must surface as
        TransportError (wrapped parse failure), TransportTimeout (a length
        byte grew the frame), or a silently-still-valid decode — never a
        raw msgpack/KeyError/Unicode exception."""
        frame = _fuzz_frame()
        import struct
        hlen = struct.unpack("!I", frame[4:8])[0]
        for k in range(12, 12 + hlen):
            for flip in (0x00, 0xFF, frame[k] ^ 0x41):
                bad = frame[:k] + bytes([flip]) + frame[k + 1:]
                try:
                    msg, used = decode_frame(bad)
                    assert used <= len(bad)
                except (TransportError, TransportTimeout):
                    pass

    def test_random_byte_flips_never_leak_raw_errors(self):
        frame = _fuzz_frame()
        rng = np.random.default_rng(12345)
        for _ in range(400):
            bad = bytearray(frame)
            for k in rng.integers(0, len(frame), rng.integers(1, 5)):
                bad[int(k)] = int(rng.integers(0, 256))
            try:
                msg, used = decode_frame(bytes(bad))
                assert used <= len(bad)
            except (TransportError, TransportTimeout):
                pass

    def test_random_garbage_never_leaks_raw_errors(self):
        rng = np.random.default_rng(7)
        for _ in range(300):
            blob = rng.integers(0, 256, int(rng.integers(0, 200)),
                                dtype=np.uint8).tobytes()
            try:
                decode_frame(blob)
            except (TransportError, TransportTimeout):
                pass

    def test_lying_payload_length_truncates_structured(self):
        """Shrinking the prefix's payload-length field starves the array
        manifest -> structured 'truncated' TransportError; growing it makes
        the frame incomplete -> TransportTimeout (recv would keep waiting
        until its deadline, never misparse)."""
        import struct
        frame = _fuzz_frame()
        plen = struct.unpack("!I", frame[8:12])[0]
        shrunk = frame[:8] + struct.pack("!I", 8) + frame[12:]
        with pytest.raises(TransportError, match="truncated"):
            decode_frame(shrunk)
        grown = frame[:8] + struct.pack("!I", plen + 4096) + frame[12:]
        with pytest.raises(TransportTimeout):
            decode_frame(grown)

    def test_recv_deadline_on_partial_frame_never_hangs(self):
        """A peer that sends half a frame and goes silent: recv must raise
        TransportTimeout promptly at its deadline (holding the partial
        bytes), not block forever."""
        import time
        a, b = _socketpair_transports()
        try:
            frame = _fuzz_frame()
            a.sock.sendall(frame[: len(frame) // 2])
            t0 = time.monotonic()
            with pytest.raises(TransportTimeout, match="partial"):
                b.recv(timeout=0.2)
            assert time.monotonic() - t0 < 5.0
            # the held bytes are not lost: completing the frame delivers it
            a.sock.sendall(frame[len(frame) // 2:])
            assert b.recv(timeout=2.0).kind == "commit"
        finally:
            a.close()
            b.close()

    def test_mid_payload_disconnect_raises_closed(self):
        """EOF halfway through a frame is a structured TransportClosed, not
        a timeout loop or a misparse."""
        a, b = _socketpair_transports()
        try:
            frame = _fuzz_frame()
            a.sock.sendall(frame[: len(frame) - 7])
            a.close()
            with pytest.raises(TransportClosed):
                b.recv(timeout=2.0)
        finally:
            b.close()

    def test_corrupt_frame_then_valid_frame_on_socket(self):
        """A corrupt frame poisons the stream loudly (recv raises
        TransportError) instead of silently resynchronizing on garbage."""
        a, b = _socketpair_transports()
        try:
            bad = bytearray(_fuzz_frame())
            bad[0] = 0x58  # break the magic
            a.sock.sendall(bytes(bad))
            with pytest.raises(TransportError, match="magic"):
                b.recv(timeout=2.0)
        finally:
            a.close()
            b.close()


if HAVE_HYPOTHESIS:
    class TestFrameFuzzHypothesis:
        @settings(max_examples=120, deadline=None)
        @given(blob=st.binary(min_size=0, max_size=256))
        def test_arbitrary_bytes_fail_structured(self, blob):
            try:
                msg, used = decode_frame(blob)
                assert used <= len(blob)
            except (TransportError, TransportTimeout):
                pass

        @settings(max_examples=80, deadline=None)
        @given(cut=st.integers(0, 10_000), xor=st.integers(1, 255),
               pos=st.integers(0, 10_000))
        def test_single_corruption_fails_structured(self, cut, xor, pos):
            frame = _fuzz_frame()
            pos = pos % len(frame)
            bad = frame[:pos] + bytes([frame[pos] ^ xor]) + frame[pos + 1:]
            bad = bad[: max(1, cut % (len(bad) + 1))]
            try:
                msg, used = decode_frame(bad)
                assert used <= len(bad)
            except (TransportError, TransportTimeout):
                pass


# ------------------------------------------------------------ real transports

def _socketpair_transports(timeout=5.0):
    a, b = socket.socketpair()
    return (SocketTransport(a, timeout=timeout),
            SocketTransport(b, timeout=timeout))


class TestSocketTransport:
    def test_roundtrip_over_real_socket_bytes(self):
        a, b = _socketpair_transports()
        try:
            g = np.asarray(np.random.default_rng(2).normal(size=300),
                           np.float32)
            row = _sparse_row(cap=3, count=1, seed=3)
            a.send("commit", commit_header(1, 4, 2.0, commit_digest(g)), [g])
            a.send("snapshot", {"w": 1, "j": 5}, sparse_row_arrays(row))
            m1 = b.recv(timeout=2.0)
            m2 = b.recv(timeout=2.0)
            _assert_arrays_equal(m1.arrays, [g])
            got = sparse_row_from_arrays(m2.arrays)
            np.testing.assert_array_equal(np.asarray(got.vals),
                                          np.asarray(row.vals))
            assert a.wire_sent == b.wire_recv > 0
        finally:
            a.close()
            b.close()

    def test_eof_raises_closed(self):
        a, b = _socketpair_transports()
        a.close()
        with pytest.raises(TransportClosed):
            b.recv(timeout=1.0)
        b.close()

    def test_timeout_keeps_partial_bytes(self):
        a, b = _socketpair_transports()
        try:
            frame = encode_frame("commit", commit_header(0, 0),
                                 [np.ones(32, np.float32)])
            a.sock.sendall(frame[:10])  # raw partial write
            with pytest.raises(TransportTimeout):
                b.recv(timeout=0.05)
            a.sock.sendall(frame[10:])
            msg = b.recv(timeout=2.0)
            assert msg.kind == "commit"
        finally:
            a.close()
            b.close()


class TestInProcTransport:
    def test_pair_roundtrip_and_counters(self):
        a, b = InProcTransport.pair()
        g = np.arange(12, dtype=np.float32)
        sent = a.send("commit", commit_header(0, 1), [g])
        msg = b.recv(timeout=1.0)
        _assert_arrays_equal(msg.arrays, [g])
        assert a.wire_sent == b.wire_recv == sent

    def test_timeout_then_close_drains_then_eof(self):
        a, b = InProcTransport.pair()
        with pytest.raises(TransportTimeout):
            b.recv(timeout=0.01)
        a.send("ping")
        a.close()
        assert b.recv(timeout=1.0).kind == "ping"  # queued frame survives
        with pytest.raises(TransportClosed):
            b.recv(timeout=1.0)
        with pytest.raises(TransportClosed):
            a.send("ping")


if HAVE_HYPOTHESIS:
    _DTYPES = st.sampled_from([np.float32, np.float64, np.int8, np.uint8,
                               np.int32, np.int64])

    @st.composite
    def _array(draw):
        dt = draw(_DTYPES)
        shape = tuple(draw(st.lists(st.integers(0, 5), min_size=0,
                                    max_size=3)))
        n = int(np.prod(shape, dtype=np.int64)) if shape else 1
        if np.issubdtype(dt, np.floating):
            vals = draw(st.lists(
                st.floats(allow_nan=False, width=32), min_size=n,
                max_size=n))
        else:
            info = np.iinfo(dt)
            vals = draw(st.lists(
                st.integers(int(info.min), int(info.max)), min_size=n,
                max_size=n))
        return np.asarray(vals, dt).reshape(shape)

    class TestHypothesisRoundtrips:
        @settings(max_examples=25, deadline=None)
        @given(arrays=st.lists(_array(), min_size=0, max_size=4),
               meta=st.dictionaries(
                   st.text(min_size=1, max_size=8).filter(
                       lambda s: s not in ("k", "a")),
                   st.integers(-2**31, 2**31 - 1), max_size=4))
        def test_framed_roundtrip_through_socketpair(self, arrays, meta):
            a, b = _socketpair_transports()
            try:
                a.send("x", meta, arrays)
                msg = b.recv(timeout=5.0)
                assert msg.kind == "x"
                assert msg.meta == meta
                _assert_arrays_equal(msg.arrays, arrays)
            finally:
                a.close()
                b.close()


# ------------------------------------------------------------- trace schema

class TestTraceSchema:
    def _trace(self, digests=None):
        return ArrivalTrace(
            n=2, worker=np.asarray([0, 1, 0], np.int32),
            t_dispatch=np.asarray([0.0, 0.0, 1.0]),
            t_arrive=np.asarray([1.0, 2.0, 3.0]),
            digest=digests)

    def test_v2_roundtrip_with_digests(self, tmp_path):
        tr = self._trace(("aa" * 4, "bb" * 4, "cc" * 4))
        path = tr.save(str(tmp_path / "t.json"))
        with open(path) as f:
            assert json.load(f)["schema"] == TRACE_SCHEMA
        back = ArrivalTrace.load(path)
        assert back.digest == tr.digest
        np.testing.assert_array_equal(back.worker, tr.worker)

    def test_v1_upgrades_in_place(self, tmp_path):
        path = tmp_path / "v1.json"
        path.write_text(json.dumps({  # pre-schema file: no "schema" key
            "n": 2, "worker": [1, 0], "t_dispatch": [0.0, 0.0],
            "t_arrive": [1.0, 2.0]}))
        tr = ArrivalTrace.load(str(path))
        assert tr.digest is None
        assert len(tr) == 2 and int(tr.worker[0]) == 1

    def test_unknown_schema_rejected(self, tmp_path):
        path = tmp_path / "future.json"
        path.write_text(json.dumps({
            "schema": TRACE_SCHEMA + 1, "n": 1, "worker": [0],
            "t_dispatch": [0.0], "t_arrive": [1.0]}))
        with pytest.raises(ValueError, match="schema"):
            ArrivalTrace.load(str(path))

    def test_digest_count_mismatch_rejected(self):
        from repro.runtime.arrivals import Arrival
        with pytest.raises(ValueError, match="digests"):
            ArrivalTrace.from_arrivals(
                2, [Arrival(0, 0, 0.0, 1.0)], digests=("aa", "bb"))


# ------------------------------------------------------- hosted integration

def _spawn_workers(pairs, groups, spec, **kw):
    """run_worker client threads, one per link; exceptions captured."""
    stats = [None] * len(groups)
    errors = [None] * len(groups)

    def main(i):
        try:
            stats[i] = run_worker(lambda: pairs[i][1], groups[i],
                                  _grad_fn, _sample_fn, spec,
                                  poll_s=0.05, **kw)
        except TransportError as e:
            errors[i] = e

    threads = [threading.Thread(target=main, args=(i,), daemon=True)
               for i in range(len(groups))]
    for t in threads:
        t.start()
    return threads, stats, errors


def _replay(res, total, fmt="topk_ef"):
    """Replay a hosted run's trace through the single-process runner and
    assert params, digests, losses, and recorded times are all bitwise."""
    runner2, _, tree = make_runner(fmt)
    rep = runner2.run(TraceArrivals(res.trace), total, _sample_fn,
                      runner2.init_state(_tree()), seed=SEED,
                      record_every=10, key_mode="worker",
                      record_digests=True)
    np.testing.assert_array_equal(np.asarray(rep.state.params),
                                  np.asarray(res.state.params))
    assert rep.digests == res.trace.digest
    np.testing.assert_array_equal(rep.losses, res.losses)
    np.testing.assert_array_equal(rep.times, res.times)
    return rep


class TestHostedLoopback:
    TOTAL = 30

    def test_inproc_two_links_replays_bitwise(self):
        runner, spec, tree = make_runner("topk_ef")
        pairs = [InProcTransport.pair() for _ in range(2)]
        threads, stats, errors = _spawn_workers(
            pairs, [(0, 1, 2), (3, 4)], spec)
        host = HostRunner(runner, heartbeat_s=1.0, dead_after_s=3.0,
                          poll_s=0.02)
        res = host.serve([p[0] for p in pairs], self.TOTAL,
                         runner.init_state(tree), seed=SEED, record_every=10)
        for t in threads:
            t.join(timeout=30)
        assert errors == [None, None]
        assert res.stats.iters == self.TOTAL
        assert res.dropouts == 0 and res.dropped_workers == ()
        assert len(res.trace) == self.TOTAL
        assert len(res.trace.digest) == self.TOTAL
        assert sum(s["commits"] for s in stats) >= self.TOTAL
        # server byte totals match what the clients saw
        assert res.wire_recv == sum(s["wire_sent"] for s in stats)
        _replay(res, self.TOTAL)

    def test_socketpair_links_replay_bitwise_int8_ef(self):
        runner, spec, tree = make_runner("int8_ef")
        pairs = [_socketpair_transports() for _ in range(2)]
        threads, stats, errors = _spawn_workers(
            pairs, [(0, 1), (2, 3, 4)], spec)
        host = HostRunner(runner, heartbeat_s=1.0, dead_after_s=3.0,
                          poll_s=0.02)
        res = host.serve([p[0] for p in pairs], self.TOTAL,
                         runner.init_state(tree), seed=SEED, record_every=10)
        for t in threads:
            t.join(timeout=30)
        assert errors == [None, None]
        assert res.stats.iters == self.TOTAL
        _replay(res, self.TOTAL, fmt="int8_ef")

    def test_kill_one_worker_mid_run_completes(self):
        runner, spec, tree = make_runner("topk_ef")
        pairs = [InProcTransport.pair() for _ in range(2)]
        threads, stats, errors = _spawn_workers(
            pairs, [(0, 1, 2), (3, 4)], spec)
        # kill link 1 (workers 3, 4) after 8 applied iterations — the
        # checkpoint hook runs inside the server loop, so the EOF lands
        # deterministically mid-run
        host = HostRunner(runner, heartbeat_s=1.0, dead_after_s=3.0,
                          poll_s=0.02)

        def kill(state, it):
            pairs[1][1].close()

        res = host.serve([p[0] for p in pairs], self.TOTAL,
                         runner.init_state(tree), seed=SEED, record_every=10,
                         checkpoint_every=8, checkpoint_fn=kill)
        for t in threads:
            t.join(timeout=30)
        assert res.stats.iters == self.TOTAL  # survivors finish the run
        assert res.dropouts == 2
        assert res.dropped_workers == (3, 4)
        assert res.reconnects == 0
        # the dead link's client saw the EOF (no reconnect budget)
        assert isinstance(errors[1], TransportClosed)
        _replay(res, self.TOTAL)  # dropout does not break the oracle

    def test_silent_worker_detected_by_heartbeat(self):
        runner, spec, tree = make_runner("topk_ef")
        real = InProcTransport.pair()
        silent = InProcTransport.pair()
        # fast client heartbeat: the live link must stay audibly alive
        # through jit compiles even against the test's 0.6s death clock
        threads, stats, errors = _spawn_workers([real], [(0, 1, 2, 3)], spec,
                                                heartbeat_s=0.2)
        # the silent link says hello for worker 4, then never answers
        # anything — its death must come from the heartbeat clock, not EOF
        # (its 0.6s age-out elapses during the run's first jit compiles,
        # while the live link stays audible through its heartbeat thread)
        silent[1].send("hello", {"workers": [4]})
        host = HostRunner(runner, heartbeat_s=0.2, dead_after_s=0.6,
                          poll_s=0.02)
        res = host.serve([real[0], silent[0]], self.TOTAL,
                         runner.init_state(tree), seed=SEED, record_every=10)
        for t in threads:
            t.join(timeout=30)
        assert errors[0] is None
        assert res.stats.iters == self.TOTAL
        assert res.dropouts == 1 and res.dropped_workers == (4,)
        # the silent client was fully attached (welcomed and dispatched a
        # job) before the heartbeat clock declared it dead; a PING may or
        # may not have fit between first silence and the death threshold
        kinds = []
        while silent[1]._q:
            kinds.append(silent[1].recv(timeout=0).kind)
        assert kinds[:2] == ["welcome", "snapshot"]
        _replay(res, self.TOTAL)

    def test_dropped_link_reconnects_and_resyncs(self):
        runner, spec, tree = make_runner("topk_ef")
        first = InProcTransport.pair()
        second = InProcTransport.pair()
        dials = [first[1], second[1]]   # worker's endpoints, in dial order
        accepts = [second[0]]           # what accept_fn hands the server
        rejoin = []
        stats = [None]
        errors = [None]

        def wmain():
            try:
                stats[0] = run_worker(
                    lambda: dials.pop(0), tuple(range(N)),
                    _grad_fn, _sample_fn, spec, poll_s=0.05,
                    max_reconnects=2, reconnect_backoff_s=0.05)
            except TransportError as e:
                errors[0] = e

        # drop the sole link after 10 applied iterations (the checkpoint
        # hook runs inside the server loop, so the drop is deterministic);
        # the dropped set then makes the server poll accept_fn, which
        # hands it the second pair the reconnecting worker dials
        def kill(state, it):
            if not rejoin:
                rejoin.append(True)
                first[1].close()

        host = HostRunner(runner, heartbeat_s=1.0, dead_after_s=3.0,
                          poll_s=0.02)
        th = threading.Thread(target=wmain, daemon=True)
        th.start()
        res = host.serve([first[0]], self.TOTAL, runner.init_state(tree),
                         seed=SEED, record_every=10,
                         accept_fn=lambda: (accepts.pop(0)
                                            if rejoin and accepts else None),
                         checkpoint_every=10, checkpoint_fn=kill)
        th.join(timeout=30)
        assert errors[0] is None
        assert res.stats.iters == self.TOTAL
        assert res.dropouts == N          # every logical worker dropped...
        assert res.reconnects == N        # ...and every one rejoined
        assert res.dropped_workers == ()  # none still missing at the end
        assert stats[0]["reconnects"] == 1
        _replay(res, self.TOTAL)  # retried in-flight jobs stay bitwise

    def test_routed_algo_rejected(self):
        tree = _tree()
        eng = DuDeEngine.for_tree(tree, n_workers=N, interpret=True)
        routed = AsyncRunner(eng, "uniform_asgd", sgd(LR), _grad_fn)
        with pytest.raises(ValueError, match="greedy"):
            HostRunner(routed)
        with pytest.raises(ValueError, match="worker"):
            routed.session(routed.init_state(tree), _sample_fn,
                           key_mode="worker")

"""DuDe-ASGD core invariants (paper Alg. 1 / §3)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DuDeConfig, dude_commit, dude_init, dude_round,
    make_round_schedule, truncated_normal_speeds, delay_stats,
)


def _rand_tree(rng, shape=(5,)):
    return {
        "w": jnp.asarray(rng.normal(size=shape), jnp.float32),
        "b": jnp.asarray(rng.normal(), jnp.float32),
    }


def test_incremental_equals_full_aggregation():
    """The paper's incremental rule g <- g + (G_new - G_old)/n must equal
    recomputing the full average of stored gradients (algebraic identity)."""
    rng = np.random.default_rng(0)
    n = 5
    cfg = DuDeConfig(n_workers=n)
    st = dude_init(_rand_tree(rng), cfg)
    stored = [jax.tree.map(jnp.zeros_like, _rand_tree(rng)) for _ in range(n)]
    for t in range(40):
        i = int(rng.integers(n))
        g = _rand_tree(rng)
        st, gbar = dude_commit(st, jnp.int32(i), g, cfg)
        stored[i] = g
        full = jax.tree.map(lambda *xs: sum(xs) / n, *stored)
        np.testing.assert_allclose(gbar["w"], full["w"], atol=1e-5)
        np.testing.assert_allclose(gbar["b"], full["b"], atol=1e-5)


def test_round_equals_commit_sequence():
    """Mode B (dude_round with masks) == mode A (dude_commit per worker) when
    the round's commit set is applied worker-by-worker."""
    rng = np.random.default_rng(1)
    n = 4
    cfg = DuDeConfig(n_workers=n)
    st_round = dude_init(_rand_tree(rng), cfg)
    st_seq = dude_init(_rand_tree(rng), cfg)
    latched = [None] * n

    speeds = truncated_normal_speeds(n, std=1.0, seed=2)
    sch = make_round_schedule(speeds, rounds=20)
    for r in range(sch.rounds):
        fresh = [_rand_tree(rng) for _ in range(n)]
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *fresh)
        # mode A: commit the latched gradient of every finishing worker
        for i in np.nonzero(sch.commit[r])[0]:
            st_seq, g_seq = dude_commit(st_seq, jnp.int32(int(i)), latched[i], cfg)
        for i in np.nonzero(sch.start[r])[0]:
            latched[i] = fresh[i]
        # mode B
        st_round, g_round = dude_round(
            st_round, stacked, jnp.asarray(sch.start[r]),
            jnp.asarray(sch.commit[r]), cfg,
        )
        np.testing.assert_allclose(
            st_round.g_bar["w"], st_seq.g_bar["w"], atol=1e-5
        )


def test_reduces_to_sync_sgd():
    """tau_i = 1 for all i (everyone starts+commits every round) => g^t is the
    plain synchronous average of fresh gradients (paper §3)."""
    rng = np.random.default_rng(3)
    n = 4
    cfg = DuDeConfig(n_workers=n)
    st = dude_init(_rand_tree(rng), cfg)
    ones = jnp.ones(n, bool)
    prev = [None]
    for r in range(5):
        fresh = [_rand_tree(rng) for _ in range(n)]
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *fresh)
        st, g = dude_round(st, stacked, ones, ones, cfg)
        # commits apply the gradient latched LAST round => one-round lag
        if prev[0] is not None:
            expect = jax.tree.map(lambda *xs: sum(xs) / n, *prev[0])
            np.testing.assert_allclose(g["w"], expect["w"], atol=1e-5)
        prev[0] = fresh


def test_delay_invariant_tau_ge_d_plus_1():
    """Paper Eq. (4): tau_i(t) >= d_i(t) + 1 on simulated schedules: a
    committed gradient's model is from its start round, data drawn at start,
    so model delay == duration >= 1 and data is fresh to the server."""
    speeds = truncated_normal_speeds(8, std=5.0, seed=4)
    sch = make_round_schedule(speeds, rounds=100)
    start_round = np.full(8, -1)
    for r in range(sch.rounds):
        for i in range(8):
            if sch.commit[r, i]:
                assert start_round[i] >= 0
                tau = r - start_round[i]
                assert tau >= 1  # == d_i + 1 with data drawn at start
                assert tau == sch.duration[i]
            if sch.start[r, i]:
                start_round[i] = r
    stats = delay_stats(sch)
    assert stats["tau_max"] >= 1


def test_accumulate_variant_running_mean():
    rng = np.random.default_rng(5)
    n = 2
    cfg = DuDeConfig(n_workers=n, accumulate=True)
    st = dude_init(_rand_tree(rng), cfg)
    start = jnp.array([True, True])
    none = jnp.array([False, False])
    g1 = [_rand_tree(rng) for _ in range(n)]
    g2 = [_rand_tree(rng) for _ in range(n)]
    st, _ = dude_round(st, jax.tree.map(lambda *x: jnp.stack(x), *g1),
                       start, none, cfg)
    st, _ = dude_round(st, jax.tree.map(lambda *x: jnp.stack(x), *g2),
                       none, none, cfg)
    want = 0.5 * (g1[0]["w"] + g2[0]["w"])
    np.testing.assert_allclose(st.inflight["w"][0], want, atol=1e-5)


def test_buffer_dtype_configurable():
    cfg = DuDeConfig(n_workers=3, buffer_dtype=jnp.bfloat16)
    st = dude_init({"w": jnp.zeros((4,))}, cfg)
    assert st.g_workers["w"].dtype == jnp.bfloat16
    assert st.g_bar["w"].dtype == jnp.float32


def test_indexed_commit_equals_masked_sweep():
    """Beyond-paper §Perf variant: gather/scatter commits must be bit-for-bit
    equivalent to the paper-faithful masked full-buffer sweep."""
    from repro.core.dude import dude_round_indexed, masks_to_indices
    rng = np.random.default_rng(11)
    n = 6
    cfg = DuDeConfig(n_workers=n)
    like = {"w": jnp.zeros((5,))}
    s1 = dude_init(like, cfg)
    s2 = dude_init(like, cfg)
    for t in range(20):
        fresh = {"w": jnp.asarray(rng.normal(size=(n, 5)), jnp.float32)}
        start = rng.random(n) < 0.5
        commit = rng.random(n) < 0.4
        s1, g1 = dude_round(s1, fresh, jnp.asarray(start),
                            jnp.asarray(commit), cfg)
        s2, g2 = dude_round_indexed(
            s2, fresh, jnp.asarray(masks_to_indices(start, n, n)),
            jnp.asarray(masks_to_indices(commit, n, n)), cfg,
        )
        np.testing.assert_allclose(g1["w"], g2["w"], atol=1e-5)
        np.testing.assert_allclose(np.asarray(s1.g_workers["w"]),
                                   np.asarray(s2.g_workers["w"]), atol=1e-5)


def test_semi_async_variant():
    """Paper §3 semi-async: the server waits for c completions per update;
    convergence preserved and model delay shrinks with c (tau^(c)=tau/c)."""
    import jax
    from repro.core import make_algo, simulate
    rng = np.random.default_rng(0)
    n, P = 4, 5
    A = [np.diag(rng.uniform(0.5, 2.0, P)) for _ in range(n)]
    b = [rng.normal(size=P) * 3 for _ in range(n)]
    wstar = np.linalg.solve(sum(A) / n, sum(b) / n)

    def grad_fn(params, batch, key):
        Ai, bi = batch
        return (0.0, Ai @ params - bi + 0.01 * jax.random.normal(key, (P,)))

    def sample_fn(i, rng_):
        return (jnp.asarray(A[i], jnp.float32), jnp.asarray(b[i], jnp.float32))

    speeds = truncated_normal_speeds(n, std=5.0, seed=1)
    errs = {}
    for c in (1, 2, 4):
        algo = make_algo("dude_semi", n, c=c) if c > 1 else \
            make_algo("dude_asgd", n)
        res = simulate(algo, speeds, grad_fn, sample_fn, jnp.zeros(P),
                       lr=0.05, total_iters=300 // c + 60, record_every=10_000)
        errs[c] = float(np.linalg.norm(np.asarray(res.params) - wstar))
    for c, e in errs.items():
        assert e < 0.15, (c, errs)

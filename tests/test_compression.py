"""Compressed-slab codec acceptance tests (docs/engine.md "Compressed slabs").

The int8+EF commit format must change the protocol's *storage*, never its
*semantics*.  This file proves:

* the EF bitwise invariant at the engine level: every compressed commit
  satisfies ``dec + ef' == g + ef`` BIT-FOR-BIT in f32 (Sterbenz exactness,
  core/compression.py), so folding both sides of the identity over a long
  run yields bitwise-identical streams and the telescoped sums agree to
  accumulation roundoff — decoded commits + residual == true commits;
* compressed ``round`` / ``round_apply`` backend equivalence: the pallas
  q-kernel and the indexed twin match the plain-jnp reference oracle
  bit-for-bit (q slabs, scale slabs, g_bar, params, slots), unsharded and
  P-axis sharded on the 8-device mesh.  All comparisons run under one
  ``jax.jit`` per engine — eager XLA compiles ``max|x|/127`` with one more
  ulp of slack than the jitted kernel on rare tiles, so uniform jitting is
  part of the contract;
* int8_ef tracks the f32 engine within the tile-wise quantization bound:
  ``|g_bar_int8 - g_bar_f32| <= mean_i quant_bound(stored row i)`` per lane;
* checkpoints: a compressed FlatTrainState (int8 slabs, ``[n, P/128]``
  scale slabs, ``[P]`` EF residual) round-trips bit-exactly, and restoring
  under a different ``mesh_axis_size`` refits both the P-sized slabs and
  the tile-granular scale slabs;
* the AsyncRunner's delta-encoded worker snapshots drive a full compressed
  run end to end;
* a hypothesis property: codec encode/decode error is bounded per tile for
  every format, dropped top-k lanes decode to exactly zero, zeros encode
  to exactly zeros.

Multi-device tests follow the test_engine_sharded.py pattern: skipped below
8 devices and re-run by ``test_compression_sharded_suite_subprocess`` under
``--xla_force_host_platform_device_count=8``; CI also runs this file
in-process on the 8-device host mesh.
"""

import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import NDEV, multidevice, p_mesh
from repro.core.compression import COMMIT_FORMATS, CommitCodec
from repro.core.engine import BACKENDS, DuDeEngine
from repro.core.flatten import make_flat_spec
from repro.optim import adamw, flat_twin, sgd

COMPRESSED = ("int8_ef", "topk_ef")


def _tree(rng):
    return {
        "w": jnp.asarray(rng.normal(size=(13, 17)), jnp.float32),
        "emb": jnp.asarray(rng.normal(size=(4, 3, 9)), jnp.float32),
        "b": jnp.asarray(rng.normal(size=5), jnp.float32),
    }


def _zpad(spec, x):
    return x.at[..., spec.size:].set(0)


# ------------------------------------------------ EF invariant, engine level


@pytest.mark.parametrize("fmt", COMPRESSED)
def test_commit_ef_long_run_bitwise(fmt):
    """Every compressed commit satisfies ``dec + ef' == g + ef`` bitwise;
    folding both sides identically over 24 commits therefore yields
    bitwise-equal accumulated streams, and the telescoped identity
    ``sum(dec) + ef_final == sum(g)`` holds to f32 accumulation roundoff."""
    rng = np.random.default_rng(0)
    n = 4
    eng = DuDeEngine.for_tree({"w": jnp.zeros(200)}, n_workers=n,
                              commit_format=fmt, interpret=True)
    P, spec = eng.P, eng.spec
    stt = eng.init()
    commit = jax.jit(eng.commit)
    decode = jax.jit(eng.codec.decode)
    lhs = jnp.zeros(P)
    rhs = jnp.zeros(P)
    sum_dec = jnp.zeros(P)
    sum_g = jnp.zeros(P)
    for t in range(24):
        w = int(rng.integers(n))
        g = _zpad(spec, jnp.asarray(rng.normal(size=P) * 3.0, jnp.float32))
        ef_old = stt.ef
        stt, _ = commit(stt, jnp.int32(w), g)
        dec = decode(stt.g_workers[w], stt.gw_scale[w])
        # THE invariant, bitwise, at every single commit
        np.testing.assert_array_equal(np.asarray(dec + stt.ef),
                                      np.asarray(g + ef_old))
        lhs = lhs + (dec + stt.ef)
        rhs = rhs + (g + ef_old)
        sum_dec = sum_dec + dec
        sum_g = sum_g + g
    np.testing.assert_array_equal(np.asarray(lhs), np.asarray(rhs))
    np.testing.assert_allclose(np.asarray(sum_dec + stt.ef),
                               np.asarray(sum_g), atol=1e-4)


@pytest.mark.parametrize("fmt", COMPRESSED)
def test_commit_gbar_is_mean_of_decoded_rows(fmt):
    """Incremental aggregation survives quantization: g_bar tracks the mean
    of the DECODED stored rows (the server folds decoded-new minus
    decoded-old, so there is no re-quantization error)."""
    rng = np.random.default_rng(7)
    n = 5
    eng = DuDeEngine.for_tree({"w": jnp.zeros(300)}, n_workers=n,
                              commit_format=fmt, interpret=True)
    stt = eng.init()
    commit = jax.jit(eng.commit)
    decode = jax.jit(eng.codec.decode)
    for t in range(15):
        g = _zpad(eng.spec,
                  jnp.asarray(rng.normal(size=eng.P), jnp.float32))
        stt, gbar = commit(stt, jnp.int32(t % n), g)
        mean_dec = np.asarray(decode(stt.g_workers, stt.gw_scale)).mean(0)
        np.testing.assert_allclose(np.asarray(gbar), mean_dec, atol=1e-5)


# ------------------------------------- backend equivalence (q oracle twins)


def _engines(backend, fmt, n, spec, mesh=None):
    kw = dict(spec=spec, n_workers=n, backend=backend, interpret=True,
              commit_format=fmt)
    if mesh is not None:
        kw.update(mesh=mesh, axis_name="p")
    return DuDeEngine(**kw)


def _run_rounds(eng, fopt, spec, steps=4, seed=3, shardings=None):
    """Jitted round_apply trajectory from init; returns the final
    (state, g_bar, params, opt_state) stack of every step's outputs."""
    rng = np.random.default_rng(seed)
    n, P = eng.n_workers, spec.padded_size
    st = eng.init()
    w = jnp.zeros(P, jnp.float32).at[:spec.size].set(
        jnp.asarray(rng.normal(size=spec.size), jnp.float32))
    ost = fopt.init(w)
    if shardings is not None:
        sh_state, sh_w, sh_opt = shardings
        st = jax.device_put(st, sh_state)
        w = jax.device_put(w, sh_w)
        ost = jax.device_put(ost, sh_opt)
    step = jax.jit(lambda s, f, a, b, w, o:
                   eng.round_apply(s, f, a, b, w, o, fopt))
    outs = []
    for t in range(steps):
        fresh = _zpad(spec, jnp.asarray(rng.normal(size=(n, P)) * 2.0,
                                        jnp.float32))
        sm = jnp.asarray(rng.random(n) < 0.6)
        cm = jnp.asarray(rng.random(n) < 0.5)
        st, gbar, w, ost = step(st, fresh, sm, cm, w, ost)
        outs.append((st, gbar, w, ost))
    return outs


def _assert_outs_equal(a, b):
    for (sa, ga, wa, oa), (sb, gb, wb, ob) in zip(a, b):
        # engine states are compared field-by-field: backends may carry
        # extra private fields the other side leaves as None (the indexed
        # drops counter) without shifting every later leaf out of register
        da, db = sa._asdict(), sb._asdict()
        assert set(da) == set(db)
        for k in da:
            if da[k] is None or db[k] is None:
                continue
            np.testing.assert_array_equal(
                np.asarray(da[k], np.float32), np.asarray(db[k], np.float32),
                err_msg=f"EngineState.{k}")
        for la, lb in zip(jax.tree.leaves((ga, wa, oa)),
                          jax.tree.leaves((gb, wb, ob))):
            np.testing.assert_array_equal(
                np.asarray(la, np.float32), np.asarray(lb, np.float32))


@pytest.mark.parametrize("fmt", COMPRESSED)
@pytest.mark.parametrize("backend", ["indexed", "pallas"])
def test_round_apply_compressed_backend_matches_reference(backend, fmt):
    """The fused pallas q-kernel and the indexed q-twin reproduce the
    plain-jnp reference oracle bit-for-bit: q slabs, scale slabs, EF, g_bar,
    params, adamw slots — every leaf, every step."""
    spec = make_flat_spec(_tree(np.random.default_rng(0)))
    fopt = flat_twin(adamw(0.01, weight_decay=0.1))
    ref = _run_rounds(_engines("reference", fmt, 4, spec), fopt, spec)
    got = _run_rounds(_engines(backend, fmt, 4, spec), fopt, spec)
    _assert_outs_equal(ref, got)


@multidevice
@pytest.mark.parametrize("fmt", COMPRESSED)
@pytest.mark.parametrize("backend", BACKENDS)
def test_round_apply_compressed_sharded_matches_unsharded(backend, fmt):
    """P-axis sharded compressed round_apply == single-device, bit-for-bit
    on all slabs including the ``[n, P/128]`` scale slabs (tile boundaries
    align with shard boundaries, so per-shard encoding equals global)."""
    from repro.sharding import flat_train_state_shardings

    spec = make_flat_spec(_tree(np.random.default_rng(0)),
                          mesh_axis_size=NDEV)
    mesh = p_mesh()
    fopt = flat_twin(adamw(0.01, weight_decay=0.1))
    eng_u = _engines(backend, fmt, 4, spec)
    eng_s = _engines(backend, fmt, 4, spec, mesh=mesh)
    sh = flat_train_state_shardings(spec, mesh, ("p",), fopt.init(
        jnp.zeros(spec.padded_size)), server_like=eng_s.state_shapes())
    outs_u = _run_rounds(eng_u, fopt, spec)
    outs_s = _run_rounds(eng_s, fopt, spec,
                         shardings=(eng_s.shardings(), sh.params, sh.opt))
    _assert_outs_equal(outs_u, outs_s)


@multidevice
@pytest.mark.parametrize("fmt", COMPRESSED)
def test_sharded_compressed_round_moves_no_bytes(fmt):
    """The compressed round stays elementwise on P — zero collectives in
    the compiled sharded HLO (scales live in their own P/128-sharded slab,
    never gathered)."""
    from conftest import collective_counts
    spec = make_flat_spec(_tree(np.random.default_rng(0)),
                          mesh_axis_size=NDEV)
    eng = _engines("reference", fmt, 4, spec, mesh=p_mesh())
    state = eng.init()
    fresh = jax.device_put(jnp.ones((4, eng.P), jnp.float32),
                           eng.shardings().g_workers)
    ones = jnp.ones(4, bool)
    hlo = jax.jit(eng.round).lower(state, fresh, ones, ones
                                   ).compile().as_text()
    counts = {k: v for k, v in collective_counts(hlo).items() if v}
    assert not counts, counts


def test_int8_ef_round_tracks_f32_within_quant_bound():
    """int8_ef g_bar vs the f32 engine on identical inputs: the error is
    bounded lane-wise by the mean over workers of each stored row's
    tile-wise quantization bound (plus incremental-accumulation slop)."""
    rng = np.random.default_rng(11)
    n = 4
    spec = make_flat_spec(_tree(np.random.default_rng(0)))
    P, T = spec.padded_size, spec.padded_size // 128
    eng_f = _engines("reference", "f32", n, spec)
    eng_c = _engines("reference", "int8_ef", n, spec)
    codec = eng_c.codec
    sf, sc = eng_f.init(), eng_c.init()
    step_f, step_c = jax.jit(eng_f.round), jax.jit(eng_c.round)
    qb = jax.jit(codec.quant_bound)
    stored_b = np.zeros((n, T))   # per-row per-tile bound of STORED rows
    latched_b = np.zeros((n, T))  # ... of latched (inflight) rows
    for t in range(6):
        fresh = _zpad(spec, jnp.asarray(rng.normal(size=(n, P)) * 2.0,
                                        jnp.float32))
        sm = jnp.asarray(rng.random(n) < 0.6)
        cm = jnp.asarray(rng.random(n) < 0.5)
        sf, gf = step_f(sf, fresh, sm, cm)
        sc, gc = step_c(sc, fresh, sm, cm)
        # mirror the round: commit promotes the latched rows, then start
        # latches the fresh ones (each quantized on latch)
        stored_b[np.asarray(cm)] = latched_b[np.asarray(cm)]
        for i in np.flatnonzero(np.asarray(sm)):
            latched_b[i] = np.asarray(qb(fresh[i]))
        bound = np.repeat(stored_b.mean(0), 128) + 1e-5
        err = np.abs(np.asarray(gc) - np.asarray(gf))
        assert (err <= bound).all(), float((err - bound).max())


# ---------------------------------------------- checkpoints with EF slots


def _compressed_state(spec, n=3, fmt="int8_ef", seed=2):
    """A FlatTrainState over a compressed engine with non-trivial slabs
    (a few commits folded in so q/scale/ef all carry real data)."""
    from repro.launch.steps import init_flat_train_state
    rng = np.random.default_rng(seed)
    eng = DuDeEngine(spec=spec, n_workers=n, commit_format=fmt,
                     interpret=True)
    tree = spec.unravel(_zpad(spec, jnp.asarray(
        rng.normal(size=spec.padded_size), jnp.float32)))
    state = init_flat_train_state(eng, adamw(0.01), tree)
    commit = jax.jit(eng.commit)
    srv = state.engine
    for t in range(2 * n):
        g = _zpad(spec, jnp.asarray(rng.normal(size=spec.padded_size),
                                    jnp.float32))
        srv, _ = commit(srv, jnp.int32(t % n), g)
    return eng, state._replace(engine=srv)


def test_ckpt_compressed_state_roundtrip(tmp_path):
    """A compressed FlatTrainState — int8 slabs, scale slabs, EF residual —
    saves with the spec manifest and restores bit-exactly."""
    from repro.checkpoint import (checkpoint_format, restore_checkpoint,
                                  save_checkpoint)
    spec = make_flat_spec(_tree(np.random.default_rng(0)))
    _, state = _compressed_state(spec)
    assert state.engine.ef is not None
    save_checkpoint(str(tmp_path), 5, state, flat_spec=spec)
    assert checkpoint_format(str(tmp_path)) == "flat"
    back = restore_checkpoint(str(tmp_path), 5, state, flat_spec=spec)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_ckpt_compressed_refit_mesh_axis_size(tmp_path):
    """A compressed checkpoint saved unsharded restores under an 8-way
    shard-aligned spec: the P-sized slabs (params, g_bar, ef, int8 rows,
    slots) refit at lane granularity and the ``[n, P/128]`` scale slabs at
    tile granularity; real prefixes survive, new pad tails are zero."""
    from repro.checkpoint import restore_checkpoint, save_checkpoint
    tree = _tree(np.random.default_rng(0))
    spec1 = make_flat_spec(tree)                      # P=384,  3 tiles
    spec8 = make_flat_spec(tree, mesh_axis_size=8)    # P=1024, 8 tiles
    assert spec8.padded_size > spec1.padded_size
    t1 = spec1.padded_size // 128
    eng1, state1 = _compressed_state(spec1)
    save_checkpoint(str(tmp_path), 1, state1, flat_spec=spec1)
    _, like8 = _compressed_state(spec8)
    back = restore_checkpoint(str(tmp_path), 1, like8, flat_spec=spec8)
    size = spec1.size
    np.testing.assert_array_equal(np.asarray(back.params[:size]),
                                  np.asarray(state1.params[:size]))
    srv1, srv8 = state1.engine, back.engine
    np.testing.assert_array_equal(np.asarray(srv8.g_bar[:size]),
                                  np.asarray(srv1.g_bar[:size]))
    np.testing.assert_array_equal(np.asarray(srv8.ef[:size]),
                                  np.asarray(srv1.ef[:size]))
    np.testing.assert_array_equal(np.asarray(srv8.g_workers[:, :size]),
                                  np.asarray(srv1.g_workers[:, :size]))
    assert not np.asarray(srv8.g_workers[:, spec1.padded_size:]).any()
    # scale slabs refit at TILE granularity: all real tiles preserved,
    # new pad-tail tiles zero
    np.testing.assert_array_equal(np.asarray(srv8.gw_scale[:, :t1]),
                                  np.asarray(srv1.gw_scale))
    np.testing.assert_array_equal(np.asarray(srv8.infl_scale[:, :t1]),
                                  np.asarray(srv1.infl_scale))
    assert not np.asarray(srv8.gw_scale[:, t1:]).any()


# --------------------------------------------- AsyncRunner delta snapshots


def test_runner_compressed_delta_snapshots():
    """A full compressed async run: per-arrival int8+EF commits and
    delta-encoded worker snapshots drive a least-squares problem to finite,
    decreasing loss (EF keeps the compressed run unbiased)."""
    from repro.runtime import ExponentialArrivals
    from repro.runtime.runner import AsyncRunner

    rng = np.random.default_rng(0)
    n = 4
    tree = {"w": jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)}
    targets = jnp.asarray(rng.normal(size=(n, 8, 16)), jnp.float32)

    def sample_fn(i, host_rng):
        return {"i": jnp.int32(i),
                "noise": jnp.asarray(host_rng.normal(size=(8, 16)),
                                     jnp.float32)}

    def grad_fn(params, batch, key):
        def loss(p):
            t = targets[batch["i"]] + 0.05 * batch["noise"]
            return 0.5 * jnp.sum((p["w"] - t) ** 2)
        return jax.value_and_grad(loss)(params)

    eng = DuDeEngine.for_tree(tree, n_workers=n, commit_format="int8_ef",
                              interpret=True)
    runner = AsyncRunner(eng, "dude", sgd(0.05), grad_fn)
    assert runner._compressed
    state = runner.init_state(tree)
    out = runner.run(ExponentialArrivals(n, seed=1), 120, sample_fn, state,
                     seed=0, record_every=20)
    assert np.isfinite(out.losses).all()
    assert out.losses[-1] < out.losses[0]
    assert out.n_grads == 120
    # the solution approaches the mean target (the heterogeneous optimum)
    back = eng.spec.unravel(out.state.params)
    err = np.abs(np.asarray(back["w"]) - np.asarray(targets.mean(0))).max()
    assert err < 0.5, err


# ----------------------------------------------- codec roundtrip property

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dev dependency
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(
        fmt=st.sampled_from(COMMIT_FORMATS),
        tiles=st.integers(1, 4),
        mag=st.floats(1e-4, 1e4),
        seed=st.integers(0, 10_000),
    )
    def test_codec_roundtrip_property(fmt, tiles, mag, seed):
        """For every format: encode/decode error on surviving lanes is
        bounded per tile by ``quant_bound``, top-k-dropped lanes decode to
        exactly zero, and the zero vector round-trips to exact zeros."""
        codec = CommitCodec(format=fmt, topk=8)
        P = tiles * 128
        x = jnp.asarray(np.random.default_rng(seed).normal(size=P) * mag,
                        jnp.float32)
        if fmt == "f32":
            # f32 has no quantized encoding; the codec is the identity on
            # the slab (compressed=False) — nothing to round-trip
            assert not codec.compressed
            return
        q, s = codec.encode(x)
        assert q.dtype == jnp.int8 and s.shape == (tiles,)
        dec = codec.decode(q, s)
        surv = np.asarray(codec.sparsify(x))
        err = np.abs(np.asarray(dec) - surv).reshape(tiles, 128)
        bound = np.asarray(codec.quant_bound(x))
        assert (err.max(axis=-1) <= bound + 1e-12).all()
        if fmt == "topk_ef":
            dropped = surv == 0
            assert not np.asarray(dec)[dropped].any()
            assert (np.abs(surv).reshape(tiles, 128) > 0).sum(-1).min() >= 8
        # zeros encode to exact zeros (scale floored, q=0)
        qz, sz = codec.encode(jnp.zeros(P))
        assert not np.asarray(qz).any()
        assert not np.asarray(codec.decode(qz, sz)).any()


# ------------------------------------------------------ subprocess driver


def test_compression_sharded_suite_subprocess():
    """Run the in-process multidevice tests above on 8 host-platform
    devices (they are skipped in a default single-device session)."""
    if jax.device_count() >= NDEV:
        pytest.skip("already multi-device in-process")
    repo = Path(__file__).resolve().parent.parent
    env = {
        **os.environ,
        "PYTHONPATH": "src",
        "JAX_PLATFORMS": "cpu",
        "XLA_FLAGS": (os.environ.get("XLA_FLAGS", "")
                      + f" --xla_force_host_platform_device_count={NDEV}"
                      ).strip(),
    }
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-p", "no:cacheprovider",
         str(Path(__file__).resolve()), "-k", "not subprocess"],
        capture_output=True, text=True, timeout=540, env=env, cwd=repo,
    )
    assert r.returncode == 0, r.stdout[-4000:] + r.stderr[-2000:]
    assert "skipped" not in r.stdout.splitlines()[-1], r.stdout[-500:]

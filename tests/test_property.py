"""Hypothesis property tests on system invariants.

``hypothesis`` is an optional dev dependency (``pip install .[dev]``); the
whole module is skipped when it is absent so the tier-1 suite stays green.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (
    DuDeConfig, dude_commit, dude_init, dude_round,
    make_round_schedule, truncated_normal_speeds,
)
from repro.core.compression import ef_encode, dequantize, quantize
from repro.data import dirichlet_partition, label_distribution

SET = settings(max_examples=25, deadline=None)


@SET
@given(
    n=st.integers(2, 6),
    steps=st.integers(1, 30),
    seed=st.integers(0, 10_000),
)
def test_incremental_aggregation_identity(n, steps, seed):
    """For ANY commit sequence, g_bar == mean of last-committed gradients."""
    rng = np.random.default_rng(seed)
    cfg = DuDeConfig(n_workers=n)
    like = {"w": jnp.zeros(3)}
    stt = dude_init(like, cfg)
    stored = [jax.tree.map(jnp.zeros_like, like) for _ in range(n)]
    for _ in range(steps):
        i = int(rng.integers(n))
        g = {"w": jnp.asarray(rng.normal(size=3), jnp.float32)}
        stt, gbar = dude_commit(stt, jnp.int32(i), g, cfg)
        stored[i] = g
    full = sum(np.asarray(s["w"]) for s in stored) / n
    np.testing.assert_allclose(np.asarray(gbar["w"]), full, atol=1e-4)


@SET
@given(
    n=st.integers(2, 8),
    std=st.floats(0.1, 5.0),
    rounds=st.integers(5, 60),
    seed=st.integers(0, 10_000),
)
def test_schedule_validity(n, std, rounds, seed):
    """Round schedules: jobs tile time with duration >= 1; a commit at r
    implies a start at r - duration; no worker has two open jobs."""
    speeds = truncated_normal_speeds(n, std=std, seed=seed)
    sch = make_round_schedule(speeds, rounds)
    assert sch.start.shape == (rounds, n)
    open_job = np.zeros(n, bool)
    start_at = np.full(n, -1)
    for r in range(rounds):
        for i in range(n):
            if sch.commit[r, i]:
                assert open_job[i]
                assert r - start_at[i] == sch.duration[i] >= 1
                open_job[i] = False
            if sch.start[r, i]:
                assert not open_job[i]
                open_job[i] = True
                start_at[i] = r


@SET
@given(
    n=st.integers(2, 10),
    alpha=st.floats(0.02, 10.0),
    seed=st.integers(0, 1000),
)
def test_dirichlet_partition_valid(n, alpha, seed):
    """Every index assigned exactly once; every worker non-empty; lower alpha
    => more skew (checked in aggregate elsewhere)."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, size=500)
    shards = dirichlet_partition(labels, n, alpha, seed=seed)
    allidx = np.sort(np.concatenate(shards))
    np.testing.assert_array_equal(allidx, np.arange(500))
    assert all(len(s) >= 1 for s in shards)
    dist = label_distribution(labels, shards)
    np.testing.assert_allclose(dist.sum(axis=1), 1.0, atol=1e-6)


@SET
@given(
    shape=st.sampled_from([(8,), (4, 8), (16, 3)]),
    scale=st.floats(1e-3, 1e3),
    seed=st.integers(0, 1000),
)
def test_quantize_bounded_error(shape, scale, seed):
    x = jnp.asarray(
        np.random.default_rng(seed).normal(size=shape) * scale, jnp.float32
    )
    q = quantize(x)
    err = jnp.max(jnp.abs(dequantize(q) - x))
    bound = jnp.max(jnp.abs(x)) / 127.0 + 1e-9
    assert float(err) <= float(bound) * 1.01


@SET
@given(seed=st.integers(0, 1000), steps=st.integers(1, 20))
def test_error_feedback_telescopes(seed, steps):
    """Sum of EF-decoded commits == sum of true values minus final residual
    (the EF-SGD unbiasedness-in-the-limit identity)."""
    rng = np.random.default_rng(seed)
    err = jnp.zeros(6)
    total_true = jnp.zeros(6)
    total_sent = jnp.zeros(6)
    for _ in range(steps):
        x = jnp.asarray(rng.normal(size=6), jnp.float32)
        q, err = ef_encode(x, err)
        total_true = total_true + x
        total_sent = total_sent + dequantize(q)
    np.testing.assert_allclose(
        np.asarray(total_sent + err), np.asarray(total_true), atol=1e-4
    )


@SET
@given(
    n_leaves=st.integers(1, 5),
    mesh_axis_size=st.sampled_from([1, 2, 4, 8]),
    stacked_n=st.integers(1, 4),
    seed=st.integers(0, 10_000),
)
def test_flat_spec_roundtrip_mixed_dtypes(n_leaves, mesh_axis_size,
                                          stacked_n, seed):
    """FlatSpec ravel/unravel is an exact round-trip for ANY mixed-dtype
    tree and shard-aligned padding: per-leaf target dtypes are restored
    (the cast path the flat forward relies on), values survive the f32
    staging exactly (bf16 and small ints embed losslessly in f32), pad
    lanes are zero, and P splits into mesh_axis_size equal lane-aligned
    shards whose segment tables tile every leaf exactly once."""
    from repro.core.flatten import make_flat_spec
    rng = np.random.default_rng(seed)
    dtypes = [jnp.float32, jnp.bfloat16, jnp.int32]
    tree = {}
    for i in range(n_leaves):
        shape = tuple(int(d) for d in rng.integers(1, 6, size=rng.integers(1, 4)))
        dt = dtypes[int(rng.integers(len(dtypes)))]
        if dt == jnp.int32:
            leaf = jnp.asarray(rng.integers(-1000, 1000, size=shape), dt)
        else:
            # bf16 values are exactly f32-representable by construction
            leaf = jnp.asarray(rng.normal(size=shape), jnp.float32).astype(dt)
        tree[f"leaf{i}"] = leaf
    spec = make_flat_spec(tree, mesh_axis_size=mesh_axis_size)
    flat = spec.ravel(tree)
    assert flat.shape == (spec.padded_size,) and flat.dtype == jnp.float32
    assert spec.padded_size % (mesh_axis_size * 128) == 0
    assert not np.any(np.asarray(flat[spec.size:]))  # pads are zero
    back = spec.unravel(flat)
    raw = spec.unravel(flat, cast=False)
    for k, leaf in tree.items():
        assert back[k].dtype == leaf.dtype
        assert raw[k].dtype == jnp.float32   # cast=False keeps slab dtype
        np.testing.assert_array_equal(np.asarray(back[k], np.float32),
                                      np.asarray(leaf, np.float32))
    # stacked variant round-trips too
    stree = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (stacked_n,) + x.shape), tree)
    sback = spec.unravel_stacked(spec.ravel_stacked(stree))
    for k in tree:
        assert sback[k].dtype == tree[k].dtype
        np.testing.assert_array_equal(np.asarray(sback[k], np.float32),
                                      np.asarray(stree[k], np.float32))
    # the shard segment tables tile every leaf exactly once
    covered = {i: 0 for i in range(len(spec.sizes))}
    for s in range(mesh_axis_size):
        lo, hi = spec.shard_ranges()[s]
        assert lo % 128 == 0 and (hi - lo) == spec.shard_size
        for leaf_i, a, b in spec.shard_segments(s):
            assert 0 <= a < b <= spec.sizes[leaf_i]
            covered[leaf_i] += b - a
    leaf_order = sorted(covered)
    assert [covered[i] for i in leaf_order] == list(spec.sizes)


@settings(max_examples=5, deadline=None)
@given(
    n_leaves=st.integers(1, 4),
    stacked_n=st.integers(1, 3),
    seed=st.integers(0, 10_000),
)
def test_tp_exchange_roundtrip_random_layouts(n_leaves, stacked_n, seed):
    """TP-native exchange == replicated oracle for ANY tree and ANY per-leaf
    TP layout on a (2, 4) mesh: ``unravel_sharded`` restores every leaf
    bit-for-bit from the P-shards (non-dividing dims silently drop their
    axis — the ``_fit`` convention — so arbitrary shapes are legal), and
    ``ravel_stacked_sharded`` rebuilds the exact ``[n, P]`` slab.  Few
    examples — each draws two shard_map compiles — but fully random
    geometry."""
    import conftest
    if jax.device_count() < conftest.NDEV:
        pytest.skip(f"needs {conftest.NDEV} devices")
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.flatten import make_flat_spec

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rng = np.random.default_rng(seed)
    dtypes = [jnp.float32, jnp.bfloat16]
    tree, shardings = {}, {}
    axis_menu = [(), ("data",), ("model",), ("data", "model"), ("model", "data")]
    for i in range(n_leaves):
        shape = tuple(int(d) for d in rng.integers(1, 9,
                                                   size=rng.integers(1, 4)))
        dt = dtypes[int(rng.integers(len(dtypes)))]
        leaf = jnp.asarray(rng.normal(size=shape), jnp.float32).astype(dt)
        tree[f"leaf{i}"] = leaf
        # one random axis group on one random dim (or fully replicated)
        entries = [None] * len(shape)
        ax = axis_menu[int(rng.integers(len(axis_menu)))]
        if ax:
            entries[int(rng.integers(len(shape)))] = ax
        shardings[f"leaf{i}"] = NamedSharding(mesh, P(*entries))
    spec = make_flat_spec(tree, mesh_axis_size=8)
    plan = spec.tp_plan(mesh, shardings, axes=("data", "model"))

    back = jax.jit(lambda f: spec.unravel_sharded(f, mesh, plan=plan)
                   )(spec.ravel(tree))
    for k, leaf in tree.items():
        assert back[k].dtype == leaf.dtype
        np.testing.assert_array_equal(np.asarray(back[k], np.float32),
                                      np.asarray(leaf, np.float32))

    stree = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (stacked_n,) + x.shape), tree)
    want = spec.ravel_stacked(stree)   # eager oracle before any placement
    got = jax.jit(lambda t: spec.ravel_stacked_sharded(t, mesh, plan=plan)
                  )(stree)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@SET
@given(
    n=st.integers(2, 5),
    seed=st.integers(0, 500),
)
def test_dude_round_masks_arbitrary(n, seed):
    """dude_round with ARBITRARY mask patterns keeps g_bar == mean of stored
    buffers (the incremental identity at round granularity)."""
    rng = np.random.default_rng(seed)
    cfg = DuDeConfig(n_workers=n)
    like = {"w": jnp.zeros(4)}
    stt = dude_init(like, cfg)
    stored = np.zeros((n, 4))
    latched = np.zeros((n, 4))
    for _ in range(15):
        fresh = rng.normal(size=(n, 4)).astype(np.float32)
        start = rng.random(n) < 0.5
        commit = rng.random(n) < 0.5
        stt, gbar = dude_round(
            stt, {"w": jnp.asarray(fresh)}, jnp.asarray(start),
            jnp.asarray(commit), cfg,
        )
        stored[commit] = latched[commit]
        latched[start] = fresh[start]
        np.testing.assert_allclose(
            np.asarray(gbar["w"]), stored.mean(axis=0), atol=1e-4
        )


@SET
@given(seed=st.integers(0, 300))
def test_compressed_dude_preserves_invariant(seed):
    """Compressed-delta DuDe: g_bar must equal the mean of the (decoded)
    stored buffers at every step — the incremental invariant survives
    quantization exactly because server and worker apply the same decoded
    delta."""
    from repro.core.compression import compressed_commit
    from repro.core.dude import DuDeConfig, dude_init
    import jax
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    n = 3
    cfg = DuDeConfig(n_workers=n)
    like = {"w": jnp.zeros(5)}
    stt = dude_init(like, cfg)
    err = {"w": jnp.zeros((5,))}
    for t in range(12):
        i = int(rng.integers(n))
        g = {"w": jnp.asarray(rng.normal(size=5), jnp.float32)}
        stt, gbar, err = compressed_commit(stt, jnp.int32(i), g, err, cfg)
        mean_buf = np.asarray(stt.g_workers["w"]).astype(np.float32).mean(axis=0)
        np.testing.assert_allclose(np.asarray(gbar["w"]), mean_buf, atol=1e-4)


def test_compressed_dude_converges_quadratic():
    """int8+EF compressed DuDe still reaches the true optimum (EF telescopes);
    the wire payload is 4x smaller than f32 deltas."""
    from repro.core.compression import compressed_commit
    from repro.core.dude import DuDeConfig, dude_init
    import jax
    import jax.numpy as jnp
    rng = np.random.default_rng(0)
    n, P = 4, 6
    A = [np.diag(rng.uniform(0.5, 2.0, P)) for _ in range(n)]
    b = [rng.normal(size=P) * 3 for _ in range(n)]
    wstar = np.linalg.solve(sum(A) / n, sum(b) / n)
    cfg = DuDeConfig(n_workers=n)
    stt = dude_init(jnp.zeros(P), cfg)
    errs = [jnp.zeros(P) for _ in range(n)]
    w = jnp.zeros(P)
    for t in range(600):
        i = t % n
        g = jnp.asarray(A[i] @ np.asarray(w) - b[i], jnp.float32)
        stt, gbar, errs[i] = compressed_commit(stt, jnp.int32(i), g, errs[i], cfg)
        w = w - 0.05 * gbar
    assert np.linalg.norm(np.asarray(w) - wstar) < 0.05

"""Hypothesis property tests on system invariants.

``hypothesis`` is an optional dev dependency (``pip install .[dev]``); the
whole module is skipped when it is absent so the tier-1 suite stays green.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (
    DuDeConfig, dude_commit, dude_init, dude_round,
    make_round_schedule, truncated_normal_speeds,
)
from repro.core.compression import (
    CommitCodec, dequantize, quantize, topk_mask,
)
from repro.data import dirichlet_partition, label_distribution

SET = settings(max_examples=25, deadline=None)


@SET
@given(
    n=st.integers(2, 6),
    steps=st.integers(1, 30),
    seed=st.integers(0, 10_000),
)
def test_incremental_aggregation_identity(n, steps, seed):
    """For ANY commit sequence, g_bar == mean of last-committed gradients."""
    rng = np.random.default_rng(seed)
    cfg = DuDeConfig(n_workers=n)
    like = {"w": jnp.zeros(3)}
    stt = dude_init(like, cfg)
    stored = [jax.tree.map(jnp.zeros_like, like) for _ in range(n)]
    for _ in range(steps):
        i = int(rng.integers(n))
        g = {"w": jnp.asarray(rng.normal(size=3), jnp.float32)}
        stt, gbar = dude_commit(stt, jnp.int32(i), g, cfg)
        stored[i] = g
    full = sum(np.asarray(s["w"]) for s in stored) / n
    np.testing.assert_allclose(np.asarray(gbar["w"]), full, atol=1e-4)


@SET
@given(
    n=st.integers(2, 8),
    std=st.floats(0.1, 5.0),
    rounds=st.integers(5, 60),
    seed=st.integers(0, 10_000),
)
def test_schedule_validity(n, std, rounds, seed):
    """Round schedules: jobs tile time with duration >= 1; a commit at r
    implies a start at r - duration; no worker has two open jobs."""
    speeds = truncated_normal_speeds(n, std=std, seed=seed)
    sch = make_round_schedule(speeds, rounds)
    assert sch.start.shape == (rounds, n)
    open_job = np.zeros(n, bool)
    start_at = np.full(n, -1)
    for r in range(rounds):
        for i in range(n):
            if sch.commit[r, i]:
                assert open_job[i]
                assert r - start_at[i] == sch.duration[i] >= 1
                open_job[i] = False
            if sch.start[r, i]:
                assert not open_job[i]
                open_job[i] = True
                start_at[i] = r


@SET
@given(
    n=st.integers(2, 10),
    alpha=st.floats(0.02, 10.0),
    seed=st.integers(0, 1000),
)
def test_dirichlet_partition_valid(n, alpha, seed):
    """Every index assigned exactly once; every worker non-empty; lower alpha
    => more skew (checked in aggregate elsewhere)."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, size=500)
    shards = dirichlet_partition(labels, n, alpha, seed=seed)
    allidx = np.sort(np.concatenate(shards))
    np.testing.assert_array_equal(allidx, np.arange(500))
    assert all(len(s) >= 1 for s in shards)
    dist = label_distribution(labels, shards)
    np.testing.assert_allclose(dist.sum(axis=1), 1.0, atol=1e-6)


@SET
@given(
    tiles=st.integers(1, 4),
    scale=st.floats(1e-3, 1e3),
    seed=st.integers(0, 1000),
)
def test_quantize_bounded_error(tiles, scale, seed):
    """Tiled int8 quantization error is bounded PER 128-lane TILE: each
    lane's error <= its own tile's scale/2 (plus rounding slack), so a
    large-magnitude tile cannot degrade a small-magnitude one."""
    P = tiles * 128
    x = jnp.asarray(
        np.random.default_rng(seed).normal(size=P) * scale, jnp.float32
    )
    q, s = quantize(x)
    assert q.dtype == jnp.int8 and s.shape == (tiles,)
    err = jnp.abs(dequantize(q, s) - x).reshape(tiles, 128)
    codec = CommitCodec(format="int8_ef")
    bound = codec.quant_bound(x)            # per-tile [T] bound
    assert bound.shape == (tiles,)
    assert bool(jnp.all(jnp.max(err, axis=-1) <= bound))
    # the bound is genuinely per-tile: the pow2 scale sits in
    # [max/127, 2*max/127), so the bound tracks each tile's own max
    raw = np.maximum(np.max(np.abs(np.asarray(x)).reshape(tiles, 128),
                            axis=-1), 1e-12) / 127.0
    b = np.asarray(bound)
    assert (b >= 0.5 * raw).all() and (b <= raw * 1.001).all()
    # scales are exact powers of two (the exactness ingredient)
    assert (np.asarray(s) == np.exp2(np.round(np.log2(np.asarray(s))))).all()


@SET
@given(seed=st.integers(0, 1000), steps=st.integers(1, 20))
def test_error_feedback_telescopes(seed, steps):
    """Sum of EF-decoded commits + final residual == sum of true values
    BITWISE (the Sterbenz-exactness identity dec + ef' == x + ef holds per
    step, so the telescoped sums match to f32 accumulation roundoff)."""
    codec = CommitCodec(format="int8_ef")
    rng = np.random.default_rng(seed)
    ef = jnp.zeros(128)
    total_true = jnp.zeros(128)
    total_sent = jnp.zeros(128)
    for _ in range(steps):
        x = jnp.asarray(rng.normal(size=128), jnp.float32)
        q, s, dec, ef_new = codec.encode_commit(x, ef)
        # per-step bitwise identity: dec + ef' == x + ef
        np.testing.assert_array_equal(
            np.asarray(dec + ef_new), np.asarray(x + ef))
        ef = ef_new
        total_true = total_true + x
        total_sent = total_sent + dec
    np.testing.assert_allclose(
        np.asarray(total_sent + ef), np.asarray(total_true), atol=1e-4
    )


@SET
@given(
    tiles=st.integers(1, 3),
    k=st.integers(1, 8),
    seed=st.integers(0, 1000),
)
def test_topk_mask_keeps_largest(tiles, k, seed):
    """Per-tile top-k mask keeps at least k lanes per tile, every kept lane
    is >= every dropped lane in magnitude, and kept lanes pass through
    unchanged."""
    P = tiles * 128
    x = jnp.asarray(np.random.default_rng(seed).normal(size=P), jnp.float32)
    m = topk_mask(x, k)
    xt = np.asarray(x).reshape(tiles, 128)
    mt = np.asarray(m).reshape(tiles, 128)
    for t in range(tiles):
        kept = np.abs(xt[t])[mt[t] != 0]
        dropped = np.abs(xt[t])[mt[t] == 0]
        assert len(kept) >= k  # ties may keep extras (threshold-based)
        if len(dropped):
            assert kept.min() >= dropped.max()
        np.testing.assert_array_equal(mt[t][mt[t] != 0],
                                      xt[t][mt[t] != 0])


@SET
@given(
    n_leaves=st.integers(1, 5),
    mesh_axis_size=st.sampled_from([1, 2, 4, 8]),
    stacked_n=st.integers(1, 4),
    seed=st.integers(0, 10_000),
)
def test_flat_spec_roundtrip_mixed_dtypes(n_leaves, mesh_axis_size,
                                          stacked_n, seed):
    """FlatSpec ravel/unravel is an exact round-trip for ANY mixed-dtype
    tree and shard-aligned padding: per-leaf target dtypes are restored
    (the cast path the flat forward relies on), values survive the f32
    staging exactly (bf16 and small ints embed losslessly in f32), pad
    lanes are zero, and P splits into mesh_axis_size equal lane-aligned
    shards whose segment tables tile every leaf exactly once."""
    from repro.core.flatten import make_flat_spec
    rng = np.random.default_rng(seed)
    dtypes = [jnp.float32, jnp.bfloat16, jnp.int32]
    tree = {}
    for i in range(n_leaves):
        shape = tuple(int(d) for d in rng.integers(1, 6, size=rng.integers(1, 4)))
        dt = dtypes[int(rng.integers(len(dtypes)))]
        if dt == jnp.int32:
            leaf = jnp.asarray(rng.integers(-1000, 1000, size=shape), dt)
        else:
            # bf16 values are exactly f32-representable by construction
            leaf = jnp.asarray(rng.normal(size=shape), jnp.float32).astype(dt)
        tree[f"leaf{i}"] = leaf
    spec = make_flat_spec(tree, mesh_axis_size=mesh_axis_size)
    flat = spec.ravel(tree)
    assert flat.shape == (spec.padded_size,) and flat.dtype == jnp.float32
    assert spec.padded_size % (mesh_axis_size * 128) == 0
    assert not np.any(np.asarray(flat[spec.size:]))  # pads are zero
    back = spec.unravel(flat)
    raw = spec.unravel(flat, cast=False)
    for k, leaf in tree.items():
        assert back[k].dtype == leaf.dtype
        assert raw[k].dtype == jnp.float32   # cast=False keeps slab dtype
        np.testing.assert_array_equal(np.asarray(back[k], np.float32),
                                      np.asarray(leaf, np.float32))
    # stacked variant round-trips too
    stree = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (stacked_n,) + x.shape), tree)
    sback = spec.unravel_stacked(spec.ravel_stacked(stree))
    for k in tree:
        assert sback[k].dtype == tree[k].dtype
        np.testing.assert_array_equal(np.asarray(sback[k], np.float32),
                                      np.asarray(stree[k], np.float32))
    # the shard segment tables tile every leaf exactly once
    covered = {i: 0 for i in range(len(spec.sizes))}
    for s in range(mesh_axis_size):
        lo, hi = spec.shard_ranges()[s]
        assert lo % 128 == 0 and (hi - lo) == spec.shard_size
        for leaf_i, a, b in spec.shard_segments(s):
            assert 0 <= a < b <= spec.sizes[leaf_i]
            covered[leaf_i] += b - a
    leaf_order = sorted(covered)
    assert [covered[i] for i in leaf_order] == list(spec.sizes)


@settings(max_examples=5, deadline=None)
@given(
    n_leaves=st.integers(1, 4),
    stacked_n=st.integers(1, 3),
    seed=st.integers(0, 10_000),
)
def test_tp_exchange_roundtrip_random_layouts(n_leaves, stacked_n, seed):
    """TP-native exchange == replicated oracle for ANY tree and ANY per-leaf
    TP layout on a (2, 4) mesh: ``unravel_sharded`` restores every leaf
    bit-for-bit from the P-shards (non-dividing dims silently drop their
    axis — the ``_fit`` convention — so arbitrary shapes are legal), and
    ``ravel_stacked_sharded`` rebuilds the exact ``[n, P]`` slab.  Few
    examples — each draws two shard_map compiles — but fully random
    geometry."""
    import conftest
    if jax.device_count() < conftest.NDEV:
        pytest.skip(f"needs {conftest.NDEV} devices")
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.flatten import make_flat_spec

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rng = np.random.default_rng(seed)
    dtypes = [jnp.float32, jnp.bfloat16]
    tree, shardings = {}, {}
    axis_menu = [(), ("data",), ("model",), ("data", "model"), ("model", "data")]
    for i in range(n_leaves):
        shape = tuple(int(d) for d in rng.integers(1, 9,
                                                   size=rng.integers(1, 4)))
        dt = dtypes[int(rng.integers(len(dtypes)))]
        leaf = jnp.asarray(rng.normal(size=shape), jnp.float32).astype(dt)
        tree[f"leaf{i}"] = leaf
        # one random axis group on one random dim (or fully replicated)
        entries = [None] * len(shape)
        ax = axis_menu[int(rng.integers(len(axis_menu)))]
        if ax:
            entries[int(rng.integers(len(shape)))] = ax
        shardings[f"leaf{i}"] = NamedSharding(mesh, P(*entries))
    spec = make_flat_spec(tree, mesh_axis_size=8)
    plan = spec.tp_plan(mesh, shardings, axes=("data", "model"))

    back = jax.jit(lambda f: spec.unravel_sharded(f, mesh, plan=plan)
                   )(spec.ravel(tree))
    for k, leaf in tree.items():
        assert back[k].dtype == leaf.dtype
        np.testing.assert_array_equal(np.asarray(back[k], np.float32),
                                      np.asarray(leaf, np.float32))

    stree = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (stacked_n,) + x.shape), tree)
    want = spec.ravel_stacked(stree)   # eager oracle before any placement
    got = jax.jit(lambda t: spec.ravel_stacked_sharded(t, mesh, plan=plan)
                  )(stree)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@SET
@given(
    n=st.integers(2, 5),
    seed=st.integers(0, 500),
)
def test_dude_round_masks_arbitrary(n, seed):
    """dude_round with ARBITRARY mask patterns keeps g_bar == mean of stored
    buffers (the incremental identity at round granularity)."""
    rng = np.random.default_rng(seed)
    cfg = DuDeConfig(n_workers=n)
    like = {"w": jnp.zeros(4)}
    stt = dude_init(like, cfg)
    stored = np.zeros((n, 4))
    latched = np.zeros((n, 4))
    for _ in range(15):
        fresh = rng.normal(size=(n, 4)).astype(np.float32)
        start = rng.random(n) < 0.5
        commit = rng.random(n) < 0.5
        stt, gbar = dude_round(
            stt, {"w": jnp.asarray(fresh)}, jnp.asarray(start),
            jnp.asarray(commit), cfg,
        )
        stored[commit] = latched[commit]
        latched[start] = fresh[start]
        np.testing.assert_allclose(
            np.asarray(gbar["w"]), stored.mean(axis=0), atol=1e-4
        )


@SET
@given(seed=st.integers(0, 300))
def test_compressed_engine_preserves_invariant(seed):
    """Compressed-slab DuDe engine: g_bar must track the mean of the DECODED
    stored rows at every commit — the incremental invariant survives
    quantization because the server folds decoded-new minus decoded-old."""
    from repro.core.engine import DuDeEngine
    rng = np.random.default_rng(seed)
    n = 3
    eng = DuDeEngine.for_tree({"w": jnp.zeros(130)}, n_workers=n,
                              commit_format="int8_ef")
    stt = eng.init()
    codec = eng.codec
    for t in range(12):
        i = int(rng.integers(n))
        g = eng.spec.ravel(
            {"w": jnp.asarray(rng.normal(size=130), jnp.float32)})
        stt, gbar = eng.commit(stt, jnp.int32(i), g)
        decoded = codec.decode(stt.g_workers, stt.gw_scale)
        mean_buf = np.asarray(decoded).mean(axis=0)
        np.testing.assert_allclose(np.asarray(gbar), mean_buf, atol=1e-4)


def test_compressed_engine_converges_quadratic():
    """int8+EF compressed commits still reach the true optimum of a
    heterogeneous quadratic (EF telescopes); the wire payload is ~3.9x
    smaller than f32 commits."""
    from repro.core.engine import DuDeEngine
    rng = np.random.default_rng(0)
    n, P = 4, 128
    A = [np.diag(rng.uniform(0.5, 2.0, P)) for _ in range(n)]
    b = [rng.normal(size=P) * 3 for _ in range(n)]
    wstar = np.linalg.solve(sum(A) / n, sum(b) / n)
    eng = DuDeEngine.for_tree(jnp.zeros(P), n_workers=n,
                              commit_format="int8_ef")
    stt = eng.init()
    w = jnp.zeros(P)
    commit = jax.jit(eng.commit)
    for t in range(600):
        i = t % n
        g = jnp.asarray(A[i] @ np.asarray(w) - b[i], jnp.float32)
        stt, gbar = commit(stt, jnp.int32(i), g)
        w = w - 0.05 * gbar[:P]
    assert np.linalg.norm(np.asarray(w) - wstar) < 0.05
    # the headline byte accounting: >= 3x reduction on wire and in the slab
    codec = eng.codec
    assert codec.commit_wire_bytes(eng.spec.padded_size) * 3 \
        <= 4 * eng.spec.padded_size
    assert codec.slab_bytes(n, eng.spec.padded_size) * 3 \
        <= 4 * n * eng.spec.padded_size

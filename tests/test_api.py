"""Session-API acceptance tests: one Trainer, one train state, one step
signature; config validation in one place; auto-format checkpoints; the
round-algo registry shared between the production step and the simulator.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    CheckpointPolicy, ConfigError, ServeConfig, ServeSession, Trainer,
    TrainerConfig,
)
from repro.core import ROUND_ALGOS, make_algo, make_round_algo
from repro.core.engine import DuDeEngine
from repro.core.flatten import make_flat_spec
from repro.models.config import ModelConfig
from repro.optim import sgd


def _tiny_cfg(n_workers=4):
    return ModelConfig(
        name="api-test-lm", arch_type="dense", num_layers=1, d_model=32,
        num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=32,
        dtype=jnp.float32, remat=False, attn_chunk=16, n_workers=n_workers,
    )


def _batch(cfg, key=0, b=1, s=16):
    n = cfg.n_workers
    k = jax.random.PRNGKey(key)
    return {
        "tokens": jax.random.randint(k, (n, b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(k, (n, b, s), 0, cfg.vocab_size),
    }


def _tree(rng):
    return {
        "w": jnp.asarray(rng.normal(size=(7, 5)), jnp.float32),
        "b": jnp.asarray(rng.normal(size=11), jnp.float32),
    }


# ----------------------------------------------------- config validation


def test_config_dude_accum_requires_reference_backend():
    """The rule that used to live in argparse: typed error, not ap.error."""
    for backend in ("indexed", "pallas"):
        with pytest.raises(ConfigError, match="dude_accum.*reference"):
            TrainerConfig(arch=_tiny_cfg(), algo="dude_accum",
                          server_backend=backend)
    # reference is fine
    TrainerConfig(arch=_tiny_cfg(), algo="dude_accum",
                  server_backend="reference")
    # and ConfigError is a ValueError, so broad catches still work
    assert issubclass(ConfigError, ValueError)


def test_config_validates_names():
    with pytest.raises(ConfigError, match="unknown algo"):
        TrainerConfig(arch=_tiny_cfg(), algo="sgd_async")
    with pytest.raises(ConfigError, match="unknown server_backend"):
        TrainerConfig(arch=_tiny_cfg(), server_backend="fused")
    with pytest.raises(ConfigError, match="unknown optimizer"):
        TrainerConfig(arch=_tiny_cfg(), optimizer="lion")
    with pytest.raises(ConfigError, match="unknown arch"):
        TrainerConfig(arch="not-a-real-arch")
    with pytest.raises(ConfigError, match="directory"):
        CheckpointPolicy(every=5)


def test_config_accepts_arch_aliases():
    """Every spelling get_config resolves (registry ids AND dashed aliases
    like "qwen2-0.5b") must pass config validation — the drivers fed
    aliases straight to get_config before the session API existed."""
    for name in ("qwen2_0_5b", "qwen2-0.5b"):
        cfg = TrainerConfig(arch=name, smoke=True)
        assert cfg.model_config.name == "qwen2-0.5b"
        ServeConfig(arch=name, smoke=True, max_len=32)


def test_flat_optimizer_shims_removed():
    """PR-4's one-release deprecation window is over: the flat_optimizer=
    keyword and the TrainOptions field are GONE (the flat step is the only
    step), and the default make_train_step builds the flat signature."""
    import dataclasses
    from repro.launch.steps import (
        TrainOptions, init_flat_train_state, make_engine, make_train_step)
    from repro.models import lm_init
    cfg = _tiny_cfg()
    with pytest.raises(TypeError):
        make_train_step(cfg, None, flat_optimizer=True)
    assert "flat_optimizer" not in {
        f.name for f in dataclasses.fields(TrainOptions)}
    # the default step IS the flat one
    engine = make_engine(cfg)
    step = make_train_step(cfg, None, engine=engine)
    state = init_flat_train_state(engine, sgd(0.05),
                                  lm_init(jax.random.PRNGKey(0), cfg))
    ones = jnp.ones(cfg.n_workers, bool)
    state, metrics = jax.jit(step)(state, _batch(cfg), ones, ones)
    assert np.isfinite(float(metrics["loss"]))


# ------------------------------------------- one step signature, all algos


@pytest.mark.parametrize("algo", list(ROUND_ALGOS))
def test_trainer_single_signature_every_algo(algo):
    """Every registry rule — DuDe family AND round baselines — runs through
    the identical ``trainer.step(batch, sm, cm) -> metrics`` call over the
    single FlatTrainState."""
    cfg = _tiny_cfg()
    t = Trainer.create(TrainerConfig(arch=cfg, algo=algo, optimizer="sgd",
                                     lr=0.05))
    ones = jnp.ones(cfg.n_workers, bool)
    batch = _batch(cfg)
    losses = []
    for _ in range(3):
        m = t.step(batch, ones, ones)
        losses.append(float(m["loss"]))
    assert all(np.isfinite(l) for l in losses), (algo, losses)
    assert t.rounds == 3
    # the state is the one canonical FlatTrainState
    assert t.state.params.shape == (t.engine.P,)


def test_fedbuff_gate_holds_optimizer():
    """FedBuff's applied gate: with one committing worker per round and
    buffer_size=3, params must stay EXACTLY put for two rounds and move on
    the third."""
    cfg = _tiny_cfg()
    t = Trainer.create(TrainerConfig(arch=cfg, algo="fedbuff",
                                     fedbuff_buffer_size=3, lr=0.05))
    n = cfg.n_workers
    one = jnp.zeros(n, bool).at[0].set(True)
    batch = _batch(cfg)
    p0 = np.asarray(t.state.params)
    m1 = t.step(batch, one, one)
    m2 = t.step(batch, one, one)
    held = np.asarray(t.state.params)
    m3 = t.step(batch, one, one)
    assert float(m1["applied"]) == 0.0 and float(m2["applied"]) == 0.0
    assert float(m3["applied"]) == 1.0
    np.testing.assert_array_equal(held, p0)           # gate held
    assert np.any(np.asarray(t.state.params) != p0)   # flush applied
    assert int(t.state.opt.step) == 1                 # only flushes count


# ------------------------------------- registry == simulator rule (math)


@pytest.mark.parametrize("name", ["sync_sgd", "mifa"])
def test_round_algo_matches_simulator_rule(name):
    """The production RoundAlgo and the simulator's on_round are the same
    rule: N rounds with identical stacked gradients and masks produce
    bit-identical params (eager, flat sgd vs per-leaf sgd)."""
    rng = np.random.default_rng(0)
    tree = _tree(rng)
    n, lr = 5, 0.07
    spec = make_flat_spec(tree)
    engine = DuDeEngine(spec=spec, n_workers=n, interpret=True)
    algo = make_round_algo(name, engine)
    sim = make_algo(name, n)

    srv = algo.init()
    sim_state = sim.init_state(jax.tree.map(jnp.zeros_like, tree))
    pf = spec.ravel(tree)
    params = tree
    for r in range(4):
        stacked = jax.tree.map(
            lambda x: jnp.asarray(
                rng.normal(size=(n,) + x.shape), jnp.float32), tree)
        mask = jnp.asarray(rng.random(n) < 0.7)
        fresh = spec.ravel_stacked(stacked)
        srv, g, applied = algo.round(srv, fresh, mask, mask)
        assert bool(applied)
        pf = pf - lr * g
        sim_state, params, _ = sim.on_round(sim_state, stacked, mask,
                                            params, lr)
    back = spec.unravel(pf)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(params[k]),
                                      err_msg=f"{name}/{k}")


def test_fedbuff_round_rule_reference():
    """Round-mode FedBuff against a numpy reference: accumulate committing
    rows, flush at buffer_size with the mean over the actual count."""
    rng = np.random.default_rng(1)
    n, P0, bs = 4, 6, 3
    spec = make_flat_spec(jnp.zeros(P0))
    P = spec.padded_size
    engine = DuDeEngine(spec=spec, n_workers=n, interpret=True)
    algo = make_round_algo("fedbuff", engine, buffer_size=bs)
    st = algo.init()
    acc_ref = np.zeros(P, np.float32)
    cnt_ref = 0
    for r in range(6):
        fresh = jnp.asarray(rng.normal(size=(n, P)), jnp.float32)
        cm = jnp.asarray(rng.random(n) < 0.5)
        st, g, applied = algo.round(st, fresh, cm, cm)
        acc_ref = acc_ref + np.sum(np.asarray(fresh)
                                   * np.asarray(cm)[:, None], axis=0)
        cnt_ref += int(np.sum(np.asarray(cm)))
        flush = cnt_ref >= bs
        assert bool(applied) == flush, r
        if flush:
            np.testing.assert_allclose(np.asarray(g),
                                       acc_ref / max(cnt_ref, 1),
                                       rtol=1e-5, atol=1e-6)
            acc_ref[:] = 0.0
            cnt_ref = 0
        np.testing.assert_allclose(np.asarray(st[0]), acc_ref,
                                   rtol=1e-5, atol=1e-6)
        assert int(st[1]) == cnt_ref


# --------------------------------------------------- auto-format restore


def test_trainer_checkpoint_roundtrip_flat(tmp_path):
    """Trainer.save -> Trainer.restore: flat directory auto-dispatches and
    the FULL state (params, slots, server slabs) restores bit-for-bit."""
    cfg = _tiny_cfg()
    config = TrainerConfig(arch=cfg, algo="dude", optimizer="adamw", lr=0.01)
    t = Trainer.create(config)
    ones = jnp.ones(cfg.n_workers, bool)
    for _ in range(2):
        t.step(_batch(cfg), ones, ones)
    t.save(str(tmp_path))
    t2 = Trainer.restore(str(tmp_path), config)
    for a, b in zip(jax.tree.leaves(t.state), jax.tree.leaves(t2.state)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_trainer_restore_resumes_round_counter(tmp_path):
    """Post-resume periodic saves must continue the step sequence: restore
    picks the checkpoint's step up as the session round, so a later save
    never rewinds below (and silently loses to) the restored step."""
    cfg = _tiny_cfg()
    config = TrainerConfig(arch=cfg, algo="dude",
                           checkpoint=CheckpointPolicy(directory=str(tmp_path),
                                                       every=2))
    t = Trainer.create(config)
    ones = jnp.ones(cfg.n_workers, bool)
    for _ in range(4):
        t.step(_batch(cfg), ones, ones)
        t.maybe_save()
    t2 = Trainer.restore(str(tmp_path), config)      # loads step_4
    assert t2.rounds == 4
    t2.step(_batch(cfg), ones, ones)
    t2.step(_batch(cfg), ones, ones)
    assert t2.maybe_save() is not None               # writes step_6, not 2
    from repro.checkpoint import latest_step
    assert latest_step(str(tmp_path)) == 6
    t3 = Trainer.restore(str(tmp_path), config, step=4)
    assert t3.rounds == 4


def test_trainer_restore_legacy_pytree(tmp_path):
    """Trainer.restore on a LEGACY pytree (params-only) directory: the same
    one call auto-dispatches, ravels the params slab bit-for-bit, and keeps
    fresh slots/server state."""
    from repro.checkpoint import save_checkpoint
    from repro.models import lm_init
    cfg = _tiny_cfg()
    params = lm_init(jax.random.PRNGKey(3), cfg)
    save_checkpoint(str(tmp_path), 7, params)      # legacy format
    config = TrainerConfig(arch=cfg, algo="dude")
    t = Trainer.restore(str(tmp_path), config)
    np.testing.assert_array_equal(
        np.asarray(t.state.params),
        np.asarray(t.engine.spec.ravel(params, jnp.float32)))
    assert float(jnp.max(jnp.abs(t.state.engine.g_bar))) == 0.0


def test_restore_params_auto_dispatch(tmp_path):
    """checkpoint.restore_params reads BOTH formats into a params pytree."""
    from repro.checkpoint import restore_params, save_checkpoint
    from repro.launch.steps import init_flat_train_state
    rng = np.random.default_rng(2)
    tree = _tree(rng)
    spec = make_flat_spec(tree)
    eng = DuDeEngine(spec=spec, n_workers=3, interpret=True)
    state = init_flat_train_state(eng, sgd(0.1), tree)
    save_checkpoint(str(tmp_path / "flat"), 1, state, flat_spec=spec)
    save_checkpoint(str(tmp_path / "tree"), 1, tree)
    for d in ("flat", "tree"):
        back = restore_params(str(tmp_path / d), 1, tree)
        for k in tree:
            np.testing.assert_array_equal(np.asarray(back[k]),
                                          np.asarray(tree[k]), err_msg=d)


def test_serve_session_from_trainer_checkpoint(tmp_path):
    """A model trained through Trainer serves from its flat checkpoint with
    no format plumbing: ServeSession.create(ckpt_dir=...)."""
    cfg = _tiny_cfg()
    t = Trainer.create(TrainerConfig(arch=cfg, algo="dude"))
    ones = jnp.ones(cfg.n_workers, bool)
    t.step(_batch(cfg), ones, ones)
    t.save(str(tmp_path))
    s = ServeSession.create(
        ServeConfig(arch=cfg, batch=2, max_len=24, cache_dtype=jnp.float32),
        ckpt_dir=str(tmp_path))
    for a, b in zip(jax.tree.leaves(s.params), jax.tree.leaves(t.params())):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    prompts = {"tokens": jax.random.randint(jax.random.PRNGKey(0), (2, 8),
                                            0, cfg.vocab_size)}
    gen = s.generate(prompts, gen_len=4)
    assert gen.shape == (2, 4)


# ------------------------------------------------------- migration shim


def test_flat_state_from_legacy_tuple():
    """A held pytree-mode (params, opt_state, dude_state) tuple — produced
    by the RETIRED tuple step of an old release — converts once to the
    canonical FlatTrainState and continues through the flat step."""
    from repro.launch.steps import (
        flat_state_from_legacy, make_engine, make_train_step)
    from repro.models import lm_init
    from repro.optim import momentum_sgd
    cfg = _tiny_cfg()
    opt = momentum_sgd(0.05)
    engine = make_engine(cfg)
    params = lm_init(jax.random.PRNGKey(0), cfg)
    ones = jnp.ones(cfg.n_workers, bool)
    # re-enact one old-style tuple update by hand (the retired step was
    # exactly: engine.round -> unravel -> pytree opt.apply)
    rng = np.random.default_rng(0)
    fresh = jnp.asarray(rng.normal(size=(cfg.n_workers, engine.P)),
                        jnp.float32)
    dude_state, g_flat = engine.round(engine.init(), fresh, ones, ones)
    params, opt_state = opt.apply(params, engine.spec.unravel(g_flat),
                                  opt.init(params))
    state = flat_state_from_legacy(engine, opt, params, opt_state, dude_state)
    np.testing.assert_array_equal(
        np.asarray(state.params),
        np.asarray(engine.spec.ravel(params, jnp.float32)))
    np.testing.assert_array_equal(
        np.asarray(state.opt.slots),
        np.asarray(engine.spec.ravel(opt_state.slots, jnp.float32)))
    fstep = jax.jit(make_train_step(cfg, None, opt, engine=engine))
    state, metrics = fstep(state, _batch(cfg), ones, ones)
    assert np.isfinite(float(metrics["loss"]))


# --------------------------------------------------- lowering / dryrun


def test_trainer_abstract_input_specs_and_lower():
    """input_specs covers the full step signature and the session lowers
    with its shardings (the dryrun path, in miniature)."""
    cfg = _tiny_cfg()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for algo in ("dude", "fedbuff"):
        session = Trainer.abstract(TrainerConfig(arch=cfg, algo=algo,
                                                 mesh=mesh))
        shapes, shardings = session.input_specs("train_4k")
        assert len(shapes) == 4 and len(shardings) == 4
        st = shapes[0]
        assert st.params.shape == (session.engine.P,)
        compiled = session.lower("train_4k").compile()
        assert compiled.cost_analysis() is not None


def test_abstract_session_has_no_state():
    t = Trainer.abstract(TrainerConfig(arch=_tiny_cfg()))
    assert t.state is None
    with pytest.raises(ConfigError, match="abstract"):
        t.step(_batch(_tiny_cfg()), jnp.ones(4, bool), jnp.ones(4, bool))


def test_flat_step_serves_baseline_algos_directly():
    """With the pytree fork retired, make_train_step hands ANY registry
    rule the same flat signature — no DuDe-only carve-out left."""
    from repro.launch.steps import (
        init_flat_train_state, make_engine, make_train_step)
    from repro.models import lm_init
    cfg = _tiny_cfg()
    engine = make_engine(cfg)
    algo = make_round_algo("mifa", engine)
    step = make_train_step(cfg, None, sgd(0.05), engine=engine, algo=algo)
    state = init_flat_train_state(engine, sgd(0.05),
                                  lm_init(jax.random.PRNGKey(0), cfg),
                                  algo=algo)
    ones = jnp.ones(cfg.n_workers, bool)
    state, metrics = jax.jit(step)(state, _batch(cfg), ones, ones)
    assert np.isfinite(float(metrics["loss"]))
    assert state.engine.shape == (cfg.n_workers, engine.P)  # mifa memory

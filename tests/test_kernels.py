"""Pallas kernel validation: shape/dtype sweeps against the ref.py oracles,
executed in interpret mode on CPU (kernel bodies run in Python)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.ops import dude_update, flash_attention, flash_decode

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("n,P,tile", [(2, 64, 32), (4, 128, 128), (8, 96, 32)])
@pytest.mark.parametrize("buf_dtype", [jnp.float32, jnp.bfloat16])
def test_dude_update_sweep(n, P, tile, buf_dtype):
    ks = jax.random.split(jax.random.fold_in(KEY, n * P), 8)
    fresh = jax.random.normal(ks[0], (n, P))
    gw = jax.random.normal(ks[1], (n, P)).astype(buf_dtype)
    infl = jax.random.normal(ks[2], (n, P)).astype(buf_dtype)
    gbar = jax.random.normal(ks[3], (P,))
    w = jax.random.normal(ks[4], (P,))
    cm = jax.random.bernoulli(ks[5], 0.5, (n,))
    sm = jax.random.bernoulli(ks[6], 0.5, (n,))
    gw2, infl2, gbar2, w2 = dude_update(cm, sm, fresh, gw, infl, gbar, w,
                                        eta=0.1, tile=tile, interpret=True)
    rb, rgw, rinfl = ref.dude_update_ref(gbar, gw, infl, fresh, sm, cm, n)
    tol = 1e-5 if buf_dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(gbar2, rb, atol=tol)
    np.testing.assert_allclose(np.asarray(gw2, np.float32),
                               np.asarray(rgw.astype(gw.dtype), np.float32), atol=0)
    np.testing.assert_allclose(np.asarray(infl2, np.float32),
                               np.asarray(rinfl.astype(infl.dtype), np.float32),
                               atol=0)
    np.testing.assert_allclose(w2, w - 0.1 * rb, atol=tol)


@pytest.mark.parametrize("B,S,H,K,hd,blk", [
    (1, 128, 4, 4, 32, 64),    # MHA, even blocks
    (2, 200, 4, 2, 32, 64),    # GQA, ragged tail
    (1, 96, 8, 1, 16, 32),     # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, S, H, K, hd, blk, dtype):
    ks = jax.random.split(jax.random.fold_in(KEY, S * H), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, K, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, K, hd), dtype)
    o = flash_attention(q, k, v, blk_q=blk, blk_k=blk, interpret=True)
    oref = ref.flash_attention_ref(q, k, v)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(oref, np.float32), atol=tol)


@pytest.mark.parametrize("window", [16, 48])
def test_flash_attention_sliding_window(window):
    B, S, H, K, hd = 1, 160, 4, 2, 32
    ks = jax.random.split(jax.random.fold_in(KEY, window), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, K, hd))
    v = jax.random.normal(ks[2], (B, S, K, hd))
    o = flash_attention(q, k, v, window=window, blk_q=32, blk_k=32,
                        interpret=True)
    oref = ref.flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(oref), atol=1e-5)


@pytest.mark.parametrize("B,S,H,K,hd,blk,length", [
    (2, 256, 4, 2, 32, 64, 200),
    (1, 128, 8, 8, 16, 32, 128),
    (1, 512, 8, 2, 64, 128, 3),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_sweep(B, S, H, K, hd, blk, length, dtype):
    ks = jax.random.split(jax.random.fold_in(KEY, S + length), 3)
    q = jax.random.normal(ks[0], (B, 1, H, hd), dtype)
    kc = jax.random.normal(ks[1], (B, S, K, hd), dtype)
    vc = jax.random.normal(ks[2], (B, S, K, hd), dtype)
    o = flash_decode(q, kc, vc, length, blk_s=blk, interpret=True)
    oref = ref.flash_decode_ref(q, kc, vc, length)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(oref, np.float32), atol=tol)


def test_flash_matches_model_attention_path():
    """Kernel agrees with the model's chunked-scan attention (the XLA path it
    replaces on TPU)."""
    from repro.models.attention import attention_chunked
    B, S, H, K, hd = 1, 96, 4, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, K, hd))
    v = jax.random.normal(ks[2], (B, S, K, hd))
    o_kernel = flash_attention(q, k, v, blk_q=32, blk_k=32, interpret=True)
    o_model = attention_chunked(q, k, v, chunk=16)
    np.testing.assert_allclose(np.asarray(o_kernel), np.asarray(o_model),
                               atol=1e-5)

"""Client-state scenario acceptance tests (docs/async.md, "Client-state
scenarios").

* Chaos replay: every scenario kind (dropout, reconnect, partial
  gradients, sin/lognormal/skew availability, full chaos) drives BOTH the
  event-driven simulator and the production AsyncRunner to BIT-IDENTICAL
  parameters — fresh identical processes agree, and a recorded v3
  ``ArrivalTrace`` replays through either harness (params + digests).
* Loop invariants under every scenario: arrivals stay time-ordered, the
  ``max_in_flight`` bound is respected (and ``max_in_flight=1`` forces
  ``tau == 1``), events align one-per-arrival, permanent dropout
  terminates the run instead of hanging it.
* Trace schema v3: events survive a save/load roundtrip exactly, v2
  files upgrade in place (``events is None``), unknown schemas and
  mismatched event counts are rejected.
* Staleness-adaptive rules: s(τ) ∈ (0, 1], monotone non-increasing,
  all rules agree at τ = 0 (hypothesis-property-swept when hypothesis is
  installed, deterministically otherwise); the flat-slab ``dude_hinge``
  arrival matches a numpy reference bitwise; ``dude_const`` IS ``dude``;
  the sharded staleness arrival step compiles to ZERO collectives.
* ``make_scenario`` / ``make_arrivals`` / ``TrainerConfig`` reject
  unknown kinds, unknown options and invalid values with the typed
  ``ConfigError``.
* Convergence regression (``-m slow``, nightly CI): under a
  label-skew-correlated availability scenario DuDe's final loss beats
  vanilla ASGD by a seeded margin on the class-Gaussian CNN problem.

Multi-device tests follow the test_runtime.py pattern: skipped below 8
devices and re-run by ``test_scenarios_sharded_suite_subprocess`` under
``--xla_force_host_platform_device_count=8``; CI also runs this file
in-process on the 8-device host mesh.
"""

import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import NDEV, collective_counts, multidevice, p_mesh
from repro.api.config import ConfigError
from repro.core import make_algo, simulate, truncated_normal_speeds
from repro.core.algos import (HINGE_A, HINGE_B, POLY_A, STALENESS_ASYNC,
                              STALENESS_RULES, make_async_algo,
                              staleness_weight)
from repro.core.engine import DuDeEngine
from repro.core.flatten import make_flat_spec
from repro.optim import sgd
from repro.runtime import (
    ArrivalTrace, ClientEvent, ClientStateProcess, FixedArrivals,
    LognormalAvailability, SinAvailability, SkewAvailability, TraceArrivals,
    make_arrivals, make_scenario,
)
from repro.runtime.arrivals import TRACE_SCHEMA, SCENARIO_KINDS, Arrival
from repro.runtime.runner import AsyncRunner

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # container without hypothesis
    HAVE_HYPOTHESIS = False

N = 5
LR = 0.05
SEED = 3
TOTAL = 30


def _tree():
    rng = np.random.default_rng(0)
    return {"w": jnp.asarray(rng.normal(size=(3, 4)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=5), jnp.float32)}


_TARGETS = jnp.asarray(np.random.default_rng(42).normal(size=(N, 3, 4)),
                       jnp.float32)


def _sample_fn(i, rng):
    return {"i": jnp.int32(i),
            "noise": jnp.asarray(rng.normal(size=(3, 4)), jnp.float32)}


def _loss(p, batch):
    t = _TARGETS[batch["i"]] + 0.1 * batch["noise"]
    return 0.5 * jnp.sum((p["w"] - t) ** 2) + 0.5 * jnp.sum(p["b"] ** 2)


def _grad_fn(params, batch, key):
    loss, g = jax.value_and_grad(_loss)(params, batch)
    return loss, g


def _sim(name, process, total=TOTAL):
    speeds = truncated_normal_speeds(N, std=1.0, seed=1)
    return simulate(make_algo(name, N), speeds, _grad_fn, _sample_fn,
                    _tree(), lr=LR, total_iters=total, seed=SEED,
                    record_every=10, arrivals=process)


def _runner(algo, process, total=TOTAL, mesh=None, max_in_flight=None,
            record_digests=False):
    tree = _tree()
    spec = make_flat_spec(tree, mesh_axis_size=NDEV if mesh else 1)
    eng = DuDeEngine(spec=spec, n_workers=N, interpret=True, mesh=mesh,
                     axis_name="p" if mesh else None)
    runner = AsyncRunner(eng, algo, sgd(LR), _grad_fn,
                         max_in_flight=max_in_flight)
    state = runner.init_state(tree)
    out = runner.run(process, total, _sample_fn, state, seed=SEED,
                     record_every=10, record_digests=record_digests)
    return eng, out


# Every scenario kind as explicit ClientStateProcess kwargs (so tests can
# construct the identical process repeatedly).  "reconnect" stresses the
# dropout/reconnect cycle harder than the factory default.
SCENARIOS = {
    "dropout": dict(dropout_rate=0.25, reconnect_mean=1.5),
    "reconnect": dict(dropout_rate=0.5, reconnect_mean=0.5),
    "partial": dict(partial_min=0.3),
    "sin": dict(availability=SinAvailability(period=6.0, slot=0.25)),
    "lognormal": dict(availability=LognormalAvailability(sigma=1.2, seed=7)),
    "skew": dict(availability=SkewAvailability(np.linspace(0.0, 1.0, N))),
    "chaos": dict(dropout_rate=0.15, reconnect_mean=1.0, partial_min=0.5,
                  responsiveness_sigma=0.4,
                  availability=SinAvailability(period=6.0)),
}


def _proc(kind):
    return ClientStateProcess(FixedArrivals(np.linspace(0.7, 1.9, N)),
                              seed=11, **SCENARIOS[kind])


# ------------------------------------------------- simulator <-> runner


@pytest.mark.parametrize("kind", sorted(SCENARIOS))
def test_scenario_sim_runner_bitwise(kind):
    """THE chaos acceptance criterion: under every client-state scenario a
    fresh-process runner run, a fresh-process simulator run, and a runner
    replay of the simulator's recorded v3 trace all produce BIT-IDENTICAL
    parameters (scenario outcomes depend only on (seed, worker, job), and
    completeness scaling commutes with ravel)."""
    res = _sim("dude_asgd", _proc(kind))
    assert res.trace.events is not None
    assert len(res.trace.events) == len(res.trace)

    for process in (_proc(kind), TraceArrivals(res.trace)):
        eng, out = _runner("dude", process)
        back = eng.spec.unravel(out.state.params)
        for k, leaf in res.params.items():
            np.testing.assert_array_equal(
                np.asarray(back[k]), np.asarray(leaf),
                err_msg=f"{kind}/{type(process).__name__}/{k}")
        assert out.tau_max == res.tau_max
        assert out.n_grads == res.n_grads
        np.testing.assert_array_equal(out.trace.worker, res.trace.worker)
        np.testing.assert_allclose(out.trace.t_arrive, res.trace.t_arrive)
        got = [e.to_row() for e in out.trace.events]
        want = [e.to_row() for e in res.trace.events]
        assert got == want


def test_scenario_routed_replay_bitwise():
    """A routed discipline under chaos still replays bitwise (the routing
    rng draw order is part of the recorded semantics)."""
    res = _sim("uniform_asgd", _proc("chaos"))
    eng, out = _runner("uniform_asgd", TraceArrivals(res.trace))
    back = eng.spec.unravel(out.state.params)
    for k, leaf in res.params.items():
        np.testing.assert_array_equal(np.asarray(back[k]), np.asarray(leaf))


def test_runner_self_replay_digests_staleness_chaos():
    """dude_hinge under full chaos: the runner replaying its own recorded
    trace reproduces params, per-arrival commit digests, losses and times
    bitwise — staleness damping and partial-gradient scaling included."""
    eng, out = _runner("dude_hinge", _proc("chaos"), record_digests=True)
    assert out.digests is not None and len(out.digests) == out.n_grads
    eng2, rep = _runner("dude_hinge", TraceArrivals(out.trace),
                        record_digests=True)
    np.testing.assert_array_equal(np.asarray(rep.state.params),
                                  np.asarray(out.state.params))
    assert rep.digests == out.digests
    np.testing.assert_array_equal(rep.losses, out.losses)
    np.testing.assert_array_equal(rep.times, out.times)


@multidevice
@pytest.mark.parametrize("algo", ["dude", "dude_hinge"])
def test_scenario_sharded_replay_bitwise(algo):
    """Chaos runs replay bit-for-bit with the engine P-axis sharded on the
    8-device mesh: commit and the staleness mix are elementwise on P (the
    worker-row gather slices the replicated n axis shard-locally)."""
    eng, out = _runner(algo, _proc("chaos"))
    eng_s, out_s = _runner(algo, TraceArrivals(out.trace), mesh=p_mesh())
    back = eng.spec.unravel(out.state.params)
    back_s = eng_s.spec.unravel(out_s.state.params)
    for k in back:
        np.testing.assert_array_equal(np.asarray(back_s[k]),
                                      np.asarray(back[k]),
                                      err_msg=f"{algo}/{k}")
    assert out_s.tau_max == out.tau_max


@multidevice
def test_staleness_arrival_step_zero_collective_hlo_sharded():
    """The staleness-damped arrival step on the sharded engine compiles to
    ZERO collectives: s(τ) is scalar math and the g_workers[w] gather is
    along the replicated worker axis, so the mix never crosses shards."""
    mesh = p_mesh()
    tree = _tree()
    spec = make_flat_spec(tree, mesh_axis_size=NDEV)
    eng = DuDeEngine(spec=spec, n_workers=N, interpret=True, mesh=mesh,
                     axis_name="p")
    runner = AsyncRunner(eng, "dude_hinge", sgd(LR), _grad_fn)
    state = runner.init_state(tree)
    gflat = runner._ravel(jax.tree.map(jnp.ones_like, tree))
    hlo = runner._step.lower(state, jnp.int32(1), gflat,
                             jnp.int32(6)).compile().as_text()
    counts = {k: v for k, v in collective_counts(hlo).items() if v}
    assert not counts, f"staleness arrival step has collectives: {counts}"


# ----------------------------------------------------- loop invariants


@pytest.mark.parametrize("kind", sorted(SCENARIOS))
def test_scenario_loop_invariants(kind):
    """Arrivals stay time-ordered with positive durations, events align
    one-per-arrival, and the in-flight bound holds under every scenario."""
    eng, out = _runner("dude", _proc(kind), max_in_flight=3)
    tr = out.trace
    assert out.stats.iters == TOTAL
    assert np.all(np.diff(tr.t_arrive) >= 0)
    assert np.all(tr.t_arrive > tr.t_dispatch)
    assert len(tr.events) == len(tr)
    assert out.stats.max_in_flight <= 3
    for e in tr.events:
        assert 0.0 < e.completeness <= 1.0
        assert e.drops >= 0 and e.wait >= 0.0 and e.outage >= 0.0
    stats = tr.event_stats()
    assert stats["events"] == len(tr)
    if kind in ("dropout", "reconnect", "chaos"):
        assert stats["dropouts"] > 0 and stats["outage_time"] > 0.0
    if kind in ("partial", "chaos"):
        assert stats["partial_jobs"] > 0
        assert stats["mean_completeness"] < 1.0
        lo = SCENARIOS[kind].get("partial_min", SCENARIOS["partial"]["partial_min"])
        assert all(e.completeness >= lo for e in tr.events)
    if kind in ("sin", "lognormal", "skew", "chaos"):
        assert stats["wait_time"] > 0.0


def test_serial_in_flight_staleness_ceiling():
    """max_in_flight=1 serializes the fleet, so staleness is bounded by the
    warmup: a worker's FIRST job still carries the initial version-0 model
    (at most N iterations old by the time it runs); every later job computes
    on the freshest model (tau = 1).  The ceiling is therefore N, and an
    unbounded run can exceed it."""
    eng, out = _runner("dude", _proc("chaos"), max_in_flight=1)
    assert out.stats.max_in_flight == 1
    assert 1 <= out.tau_max <= N
    assert out.stats.iters == TOTAL


def test_permanent_dropout_terminates_run():
    """reconnect_mean=None kills a dropped worker mid-compute (infinite
    duration); the loop finishes the survivors and stops instead of
    hanging — and the truncated trace still replays bitwise."""
    proc = ClientStateProcess(FixedArrivals(np.ones(N)), seed=2,
                              dropout_rate=0.5, reconnect_mean=None)
    eng, out = _runner("dude", proc, total=200)
    assert out.stats.iters < 200          # the fleet died before the target
    assert out.stats.iters == len(out.trace) > 0
    eng2, rep = _runner("dude", TraceArrivals(out.trace), total=200)
    np.testing.assert_array_equal(np.asarray(rep.state.params),
                                  np.asarray(out.state.params))


# ---------------------------------------------------- trace schema v3


class TestTraceSchemaV3:
    def _chaos_trace(self):
        return _sim("dude_asgd", _proc("chaos")).trace

    def test_v3_roundtrip_preserves_events(self, tmp_path):
        tr = self._chaos_trace()
        path = tr.save(str(tmp_path / "t.json"))
        with open(path) as f:
            d = json.load(f)
        assert d["schema"] == TRACE_SCHEMA == 3
        assert len(d["events"]) == len(tr)
        back = ArrivalTrace.load(path)
        assert [e.to_row() for e in back.events] == \
               [e.to_row() for e in tr.events]
        # completeness survives JSON exactly (it is an exact float32)
        for e in back.events:
            assert e.completeness == float(np.float32(e.completeness))
        assert back.event_stats() == tr.event_stats()

    def test_v2_file_upgrades_without_events(self, tmp_path):
        path = tmp_path / "v2.json"
        path.write_text(json.dumps({
            "schema": 2, "n": 2, "worker": [0, 1],
            "t_dispatch": [0.0, 0.0], "t_arrive": [1.0, 2.0],
            "digest": ["aa" * 4, "bb" * 4]}))
        tr = ArrivalTrace.load(str(path))
        assert tr.events is None
        assert tr.event_stats() == {}
        assert tr.digest == ("aa" * 4, "bb" * 4)

    def test_future_schema_rejected(self, tmp_path):
        path = tmp_path / "v9.json"
        path.write_text(json.dumps({
            "schema": TRACE_SCHEMA + 1, "n": 1, "worker": [0],
            "t_dispatch": [0.0], "t_arrive": [1.0]}))
        with pytest.raises(ValueError, match="schema"):
            ArrivalTrace.load(str(path))

    def test_event_count_mismatch_rejected(self):
        with pytest.raises(ValueError, match="events"):
            ArrivalTrace.from_arrivals(
                2, [Arrival(0, 0, 0.0, 1.0)],
                events=[ClientEvent(), ClientEvent()])


# ------------------------------------------------------ factory errors


class TestFactoryValidation:
    def test_unknown_scenario_kind(self):
        with pytest.raises(ConfigError, match="unknown scenario kind"):
            make_scenario("blackout", FixedArrivals(np.ones(N)))

    def test_unknown_scenario_option(self):
        with pytest.raises(ConfigError, match="unknown option"):
            make_scenario("dropout", FixedArrivals(np.ones(N)),
                          dropout_prob=0.5)

    def test_invalid_scenario_value(self):
        with pytest.raises(ConfigError, match="dropout_rate"):
            make_scenario("dropout", FixedArrivals(np.ones(N)),
                          dropout_rate=1.5)
        with pytest.raises(ConfigError, match="partial_min"):
            make_scenario("partial", FixedArrivals(np.ones(N)),
                          partial_min=0.0)

    def test_none_is_identity(self):
        base = FixedArrivals(np.ones(N))
        assert make_scenario("none", base) is base
        with pytest.raises(ConfigError, match="unknown option"):
            make_scenario("none", base, dropout_rate=0.1)

    def test_every_kind_builds(self):
        base = FixedArrivals(np.ones(N))
        for kind in SCENARIO_KINDS:
            proc = make_scenario(kind, base, seed=1)
            assert proc.n == N

    def test_make_arrivals_unknown_kind(self):
        with pytest.raises(ConfigError, match="unknown arrival kind"):
            make_arrivals("poisson", N)

    def test_make_arrivals_invalid_values(self):
        with pytest.raises(ConfigError, match="fixed"):
            make_arrivals("fixed", N, times=[-1.0] * N)
        with pytest.raises(ConfigError, match="trace"):
            make_arrivals("trace", N)

    def test_config_error_is_value_error(self):
        assert issubclass(ConfigError, ValueError)

    def test_trainer_config_scenario_knobs(self):
        from repro.api import TrainerConfig
        from repro.models.config import ModelConfig
        cfg = ModelConfig(
            name="scenario-test-lm", arch_type="dense", num_layers=1,
            d_model=32, num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=32,
            dtype=jnp.float32, remat=False, attn_chunk=16, n_workers=4)
        for kind in SCENARIO_KINDS:
            TrainerConfig(arch=cfg, algo="dude", scenario=kind)
        with pytest.raises(ConfigError, match="unknown scenario"):
            TrainerConfig(arch=cfg, scenario="blackout")
        TrainerConfig(arch=cfg, algo="dude_hinge")
        with pytest.raises(ConfigError, match="f32"):
            TrainerConfig(arch=cfg, algo="dude_hinge",
                          commit_format="int8_ef")


# --------------------------------------------------- staleness weights


def _weight(rule, tau):
    return float(staleness_weight(rule, jnp.int32(tau)))


TAUS = [0, 1, 2, 3, 4, 5, 6, 8, 16, 64, 1000]


class TestStalenessWeights:
    @pytest.mark.parametrize("rule", STALENESS_RULES)
    def test_in_unit_interval_and_monotone(self, rule):
        ws = [_weight(rule, t) for t in TAUS]
        assert all(0.0 < w <= 1.0 for w in ws)
        assert all(a >= b for a, b in zip(ws, ws[1:]))

    @pytest.mark.parametrize("rule", STALENESS_RULES)
    def test_rules_agree_at_tau_zero(self, rule):
        assert _weight(rule, 0) == 1.0

    def test_known_values(self):
        assert HINGE_A == 10.0 and HINGE_B == 4.0 and POLY_A == 0.5
        assert _weight("hinge", 4) == 1.0
        assert _weight("hinge", 5) == pytest.approx(0.1)
        np.testing.assert_allclose(_weight("poly", 3), 0.5)

    def test_unknown_rule_rejected(self):
        with pytest.raises(ValueError, match="staleness rule"):
            staleness_weight("cosine", jnp.int32(1))

    def test_flat_slab_rule_matches_numpy_reference_bitwise(self):
        """Two staleness-damped commits through the engine == the same
        arithmetic in numpy float32 (mix, delta-fold, division by n) —
        no hidden fusion or reassociation in the compiled arrival rule."""
        tree = {"w": jnp.zeros((7,), jnp.float32)}
        spec = make_flat_spec(tree)
        eng = DuDeEngine(spec=spec, n_workers=3, interpret=True)
        algo = make_async_algo("dude_hinge", eng)
        state = algo.init_fn()
        P = eng.P
        rng = np.random.default_rng(9)
        g1 = np.asarray(rng.normal(size=P), np.float32)
        g2 = np.asarray(rng.normal(size=P), np.float32)
        w, n = 1, np.float32(3)

        state, _ = algo.arrival(state, w, jnp.asarray(g1), tau=2)
        state, gbar = algo.arrival(state, w, jnp.asarray(g2), tau=6)

        s1 = np.float32(_weight("hinge", 2))   # = 1.0 (below the knee)
        s2 = np.float32(_weight("hinge", 6))
        eff1 = s1 * g1 + (np.float32(1.0) - s1) * np.zeros(P, np.float32)
        bar1 = (eff1 - np.float32(0.0)) / n
        eff2 = s2 * g2 + (np.float32(1.0) - s2) * eff1
        bar2 = bar1 + (eff2 - eff1) / n
        np.testing.assert_array_equal(np.asarray(state.g_workers[w]), eff2)
        np.testing.assert_array_equal(np.asarray(state.g_bar), bar2)
        np.testing.assert_array_equal(np.asarray(gbar), bar2)

    def test_dude_const_is_dude_bitwise(self):
        """s(τ) = 1 collapses the staleness family onto plain DuDe — a full
        chaos run under each produces identical parameters."""
        eng_a, out_a = _runner("dude", _proc("chaos"))
        eng_b, out_b = _runner("dude_const", _proc("chaos"))
        np.testing.assert_array_equal(np.asarray(out_a.state.params),
                                      np.asarray(out_b.state.params))

    def test_staleness_rejects_compressed_slab(self):
        tree = _tree()
        eng = DuDeEngine.for_tree(tree, n_workers=N, interpret=True,
                                  commit_format="int8_ef")
        with pytest.raises(ValueError, match="f32"):
            make_async_algo("dude_poly", eng)
        assert sorted(STALENESS_ASYNC) == ["dude_const", "dude_hinge",
                                           "dude_poly"]


if HAVE_HYPOTHESIS:
    class TestStalenessHypothesis:
        @settings(max_examples=60, deadline=None)
        @given(rule=st.sampled_from(STALENESS_RULES),
               tau=st.integers(0, 100_000))
        def test_weight_in_unit_interval(self, rule, tau):
            w = _weight(rule, tau)
            assert 0.0 < w <= 1.0

        @settings(max_examples=60, deadline=None)
        @given(rule=st.sampled_from(STALENESS_RULES),
               tau=st.integers(0, 10_000), step=st.integers(1, 100))
        def test_weight_monotone_non_increasing(self, rule, tau, step):
            assert _weight(rule, tau) >= _weight(rule, tau + step)

        @settings(max_examples=20, deadline=None)
        @given(tau=st.integers(0, 1000))
        def test_hinge_matches_numpy_formula(self, tau):
            want = (1.0 if tau <= HINGE_B
                    else min(1.0, float(np.float32(1.0) / np.float32(
                        np.float32(HINGE_A) * np.float32(tau - HINGE_B)))))
            assert _weight("hinge", tau) == pytest.approx(want, rel=1e-6)


# ----------------------------------------------- convergence regression


@pytest.mark.slow
def test_dude_beats_vanilla_under_label_skew_scenario():
    """Convergence regression (nightly): Dirichlet label-skew partition of
    the class-Gaussian images AND skew-correlated availability — the rare
    labels live on the flakiest clients.  The model is an UNDERPARAMETERIZED
    softmax on pooled features, so the balanced optimum is contested between
    workers: vanilla ASGD's stationary point is the arrival-rate-weighted
    optimum (biased toward the always-online shards), while DuDe's
    dual-delayed average weighs every worker equally regardless of how
    rarely it arrives.  Judged on the BALANCED full-dataset loss, DuDe must
    beat vanilla by a seeded margin (calibrated: observed ~0.08 at the
    pinned seeds, asserted at half that)."""
    from repro.data import (class_gaussian_images, dirichlet_partition,
                            label_distribution, make_sample_fn)

    n, total = 8, 2000
    x, y = class_gaussian_images(n=1024, seed=0)
    shards = dirichlet_partition(y, n, alpha=0.1, seed=0)
    sample_fn = make_sample_fn(x, y, shards, batch=32, seed=0)

    def feats(xb):  # [B,32,32,3] -> [B,48]: 8x8 average pool per channel
        xb = xb.reshape(xb.shape[0], 4, 8, 4, 8, 3).mean(axis=(2, 4))
        return xb.reshape(xb.shape[0], -1)

    def loss_fn(p, batch):
        logits = feats(jnp.asarray(batch["x"], jnp.float32)) @ p["w"] + p["b"]
        lp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(lp, batch["y"][:, None],
                                             axis=-1))

    params0 = {"w": jnp.zeros((48, 10), jnp.float32),
               "b": jnp.zeros((10,), jnp.float32)}

    def grad_fn(params, batch, key):
        return jax.value_and_grad(loss_fn)(params, batch)

    # the recorded metric must be BALANCED (loss over the full dataset):
    # the running train EMA only sees the batches of whoever is online,
    # which is exactly the bias this scenario induces
    eval_batch = {"x": x, "y": y}
    eval_fn = jax.jit(lambda p: loss_fn(p, eval_batch))

    # availability anti-correlated with label coverage: the most
    # label-skewed shards (distribution peaked on one class) get the
    # lowest online probability
    dist = label_distribution(y, shards)          # [n, n_classes]
    skew = dist.max(axis=1)                       # peaked shard = skewed data
    skew = (skew - skew.min()) / max(1e-9, float(np.ptp(skew)))
    speeds = truncated_normal_speeds(n, std=1.0, seed=1)

    def run(name):
        proc = ClientStateProcess(
            FixedArrivals(np.asarray(speeds.times)), seed=5,
            availability=SkewAvailability(skew, beta=0.9, slot=2.0))
        return simulate(make_algo(name, n), speeds, grad_fn, sample_fn,
                        params0, lr=0.05, total_iters=total, seed=SEED,
                        record_every=250, eval_fn=eval_fn, arrivals=proc)

    dude = run("dude_asgd")
    vanilla = run("vanilla_asgd")
    assert np.isfinite(dude.losses[-1]) and np.isfinite(vanilla.losses[-1])
    # DuDe leads at EVERY record point, not just the last
    assert np.all(np.asarray(dude.losses) < np.asarray(vanilla.losses))
    assert dude.losses[-1] < vanilla.losses[-1] - 0.04, (
        f"dude {dude.losses[-1]:.4f} vs vanilla {vanilla.losses[-1]:.4f}")


# ------------------------------------------------------ subprocess driver


def test_scenarios_sharded_suite_subprocess():
    """Run the in-process multidevice tests above on 8 host-platform devices
    (they are skipped in a default single-device session)."""
    if jax.device_count() >= NDEV:
        pytest.skip("already multi-device in-process")
    repo = Path(__file__).resolve().parent.parent
    env = {
        **os.environ,
        "PYTHONPATH": "src",
        "JAX_PLATFORMS": "cpu",
        "XLA_FLAGS": (os.environ.get("XLA_FLAGS", "")
                      + f" --xla_force_host_platform_device_count={NDEV}"
                      ).strip(),
    }
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-p", "no:cacheprovider",
         str(Path(__file__).resolve()),
         "-k", "sharded and not subprocess"],
        capture_output=True, text=True, timeout=540, env=env, cwd=repo,
    )
    assert r.returncode == 0, r.stdout[-4000:] + r.stderr[-2000:]
    assert "skipped" not in r.stdout.splitlines()[-1], r.stdout[-500:]

"""ServerEngine invariants: flat layout round-trips and the three backends
(reference / indexed / pallas-interpret) agree on random pytrees, masks, and
buffer dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DuDeConfig, dude_commit, dude_init, dude_round
from repro.core.dude import masks_to_indices
from repro.core.engine import BACKENDS, DuDeEngine, masks_to_indices_jnp
from repro.core.flatten import make_flat_spec

TREES = {
    "vector": lambda rng: {"w": jnp.asarray(rng.normal(size=7), jnp.float32)},
    "mixed": lambda rng: {
        "w": jnp.asarray(rng.normal(size=(3, 5)), jnp.float32),
        "b": jnp.asarray(rng.normal(), jnp.float32),
        "emb": jnp.asarray(rng.normal(size=(2, 2, 2)), jnp.float32),
    },
}


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


# ---------------------------------------------------------------- flatten


@pytest.mark.parametrize("tree_kind", list(TREES))
def test_flatten_round_trip(tree_kind):
    rng = np.random.default_rng(0)
    tree = TREES[tree_kind](rng)
    spec = make_flat_spec(tree)
    assert spec.padded_size % 128 == 0
    flat = spec.ravel(tree)
    assert flat.shape == (spec.padded_size,)
    # padding is zero-filled
    np.testing.assert_array_equal(np.asarray(flat[spec.size:]), 0.0)
    back = spec.unravel(flat)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, atol=0),
                 tree, back)


def test_flatten_round_trip_stacked():
    rng = np.random.default_rng(1)
    n = 4
    stacked = _stack([TREES["mixed"](rng) for _ in range(n)])
    spec = make_flat_spec(TREES["mixed"](rng))
    flat = spec.ravel_stacked(stacked)
    assert flat.shape == (n, spec.padded_size)
    back = spec.unravel_stacked(flat)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, atol=0),
                 stacked, back)


def test_flatten_spec_cached():
    rng = np.random.default_rng(2)
    t1, t2 = TREES["mixed"](rng), TREES["mixed"](rng)
    assert make_flat_spec(t1) is make_flat_spec(t2)


def test_masks_to_indices_jnp_matches_host():
    rng = np.random.default_rng(3)
    for n in (1, 4, 9):
        for _ in range(20):
            mask = rng.random(n) < 0.5
            host = masks_to_indices(mask, n, n)
            traced = np.asarray(masks_to_indices_jnp(jnp.asarray(mask), n))
            np.testing.assert_array_equal(np.sort(host), np.sort(traced))


# --------------------------------------------------- backend equivalence


@pytest.mark.parametrize("tree_kind", list(TREES))
@pytest.mark.parametrize("buf_dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,seed", [(2, 0), (5, 1), (8, 2)])
def test_backend_equivalence(tree_kind, buf_dtype, n, seed):
    """reference == indexed == pallas(interpret=True) over many random rounds
    with arbitrary mask patterns — the tentpole's contract."""
    rng = np.random.default_rng(seed)
    cfg = DuDeConfig(n_workers=n, buffer_dtype=buf_dtype)
    mk = TREES[tree_kind]
    states = {b: dude_init(mk(rng), cfg) for b in BACKENDS}
    for t in range(12):
        fresh = _stack([mk(rng) for _ in range(n)])
        start = jnp.asarray(rng.random(n) < 0.5)
        commit = jnp.asarray(rng.random(n) < 0.4)
        outs = {}
        for b in BACKENDS:
            states[b], outs[b] = dude_round(
                states[b], fresh, start, commit, cfg,
                backend=b, interpret=True if b == "pallas" else None)
        for b in ("indexed", "pallas"):
            jax.tree.map(
                lambda x, y: np.testing.assert_allclose(
                    np.asarray(x, np.float32), np.asarray(y, np.float32),
                    atol=1e-5),
                outs[b], outs["reference"])
            jax.tree.map(
                lambda x, y: np.testing.assert_allclose(
                    np.asarray(x, np.float32), np.asarray(y, np.float32),
                    atol=1e-5),
                states[b], states["reference"])


@pytest.mark.parametrize("backend", BACKENDS)
def test_commit_equals_one_worker_round(backend):
    """dude_commit(j, g) == a one-worker dude_round pair: latch g at round r
    (start = onehot(j)), commit it at round r+1 (commit = onehot(j)).
    g_bar and g_workers must match exactly."""
    rng = np.random.default_rng(7)
    n = 4
    cfg = DuDeConfig(n_workers=n)
    mk = TREES["mixed"]
    st_commit = dude_init(mk(rng), cfg)
    st_round = dude_init(mk(rng), cfg)
    zeros = jnp.zeros(n, bool)
    for t in range(8):
        j = int(rng.integers(n))
        g = mk(rng)
        onehot = jnp.asarray(np.arange(n) == j)
        st_commit, gbar = dude_commit(st_commit, jnp.int32(j), g, cfg)
        broadcast = _stack([g for _ in range(n)])
        st_round, _ = dude_round(st_round, broadcast, onehot, zeros, cfg,
                                 backend=backend, interpret=True)
        st_round, gbar_r = dude_round(st_round, broadcast, zeros, onehot, cfg,
                                      backend=backend, interpret=True)
        jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, atol=1e-5),
                     gbar, gbar_r)
        jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, atol=1e-5),
                     st_commit.g_workers, st_round.g_workers)


# ----------------------------------------------------- engine-level API


@pytest.mark.parametrize("backend", BACKENDS)
def test_fused_apply_matches_separate_sgd(backend):
    """round(params=w, eta) == round() followed by w - eta * g_bar for every
    backend (the pallas backend folds the apply into the fused pass)."""
    rng = np.random.default_rng(9)
    n, eta = 3, 0.05
    spec = make_flat_spec(jnp.zeros((200,)))
    eng = DuDeEngine(spec=spec, n_workers=n, backend=backend, interpret=True)
    P = spec.padded_size
    state = eng.init()._replace(
        g_workers=jnp.asarray(rng.normal(size=(n, P)), jnp.float32),
        inflight=jnp.asarray(rng.normal(size=(n, P)), jnp.float32),
    )
    fresh = jnp.asarray(rng.normal(size=(n, P)), jnp.float32)
    sm = jnp.asarray(rng.random(n) < 0.5)
    cm = jnp.asarray(rng.random(n) < 0.5)
    w = jnp.asarray(rng.normal(size=P), jnp.float32)
    st1, gbar, w_new = eng.round(state, fresh, sm, cm, params=w, eta=eta)
    st2, gbar2 = eng.round(state, fresh, sm, cm)
    np.testing.assert_allclose(gbar, gbar2, atol=1e-6)
    np.testing.assert_allclose(w_new, w - eta * gbar2, atol=1e-6)


def test_indexed_width_bound_matches_reference():
    """index_width = k (a static bound on the active set) must not change
    results as long as no round exceeds k active workers."""
    rng = np.random.default_rng(13)
    n, k = 8, 3
    spec = make_flat_spec(jnp.zeros((100,)))
    P = spec.padded_size
    eng_ref = DuDeEngine(spec=spec, n_workers=n)
    eng_idx = DuDeEngine(spec=spec, n_workers=n, backend="indexed",
                         index_width=k)
    s_ref, s_idx = eng_ref.init(), eng_idx.init()
    for t in range(10):
        fresh = jnp.asarray(rng.normal(size=(n, P)), jnp.float32)
        sm = np.zeros(n, bool)
        cm = np.zeros(n, bool)
        sm[rng.choice(n, size=rng.integers(0, k + 1), replace=False)] = True
        cm[rng.choice(n, size=rng.integers(0, k + 1), replace=False)] = True
        s_ref, g_ref = eng_ref.round(s_ref, fresh, jnp.asarray(sm),
                                     jnp.asarray(cm))
        s_idx, g_idx = eng_idx.round(s_idx, fresh, jnp.asarray(sm),
                                     jnp.asarray(cm))
        np.testing.assert_allclose(g_idx, g_ref, atol=1e-5)
        np.testing.assert_allclose(s_idx.inflight, s_ref.inflight, atol=1e-5)
    with pytest.raises(ValueError, match="index_width"):
        DuDeEngine(spec=spec, n_workers=n, index_width=n + 1)


def test_indexed_overflow_warns_and_drops(capfd):
    """index_width overflow: valid indices sort first, so the LOWEST worker
    indices win and the excess commits are dropped — behavior pinned here —
    and the in-graph jax.debug guard must announce the drop."""
    rng = np.random.default_rng(21)
    n, k = 8, 2
    spec = make_flat_spec(jnp.zeros((64,)))
    P = spec.padded_size
    eng = DuDeEngine(spec=spec, n_workers=n, backend="indexed", index_width=k)
    ref_eng = DuDeEngine(spec=spec, n_workers=n)
    state = eng.init()._replace(
        g_workers=jnp.asarray(rng.normal(size=(n, P)), jnp.float32),
        inflight=jnp.asarray(rng.normal(size=(n, P)), jnp.float32))
    fresh = jnp.asarray(rng.normal(size=(n, P)), jnp.float32)
    none = jnp.zeros(n, bool)
    over = jnp.asarray(np.isin(np.arange(n), [1, 4, 6]))  # 3 commits > k=2
    _, gbar = jax.jit(eng.round)(state, fresh, none, over)
    jax.effects_barrier()
    warned = capfd.readouterr()
    assert "DROPPED" in warned.out + warned.err, (warned.out, warned.err)
    # pinned semantics: only the k lowest active indices commit
    kept = jnp.asarray(np.isin(np.arange(n), [1, 4]))
    _, gbar_ref = ref_eng.round(state, fresh, none, kept)
    np.testing.assert_allclose(gbar, gbar_ref, atol=1e-6)
    # no overflow -> no warning
    ok = jnp.asarray(np.isin(np.arange(n), [3]))
    capfd.readouterr()
    jax.jit(eng.round)(state, fresh, none, ok)
    jax.effects_barrier()
    quiet = capfd.readouterr()
    assert "DROPPED" not in quiet.out + quiet.err


def test_indexed_overflow_warning_text(capfd):
    rng = np.random.default_rng(22)
    n, k = 6, 2
    spec = make_flat_spec(jnp.zeros((32,)))
    eng = DuDeEngine(spec=spec, n_workers=n, backend="indexed", index_width=k)
    fresh = jnp.asarray(rng.normal(size=(n, spec.padded_size)), jnp.float32)
    over = jnp.asarray(np.arange(n) < 3)
    jax.jit(eng.round)(eng.init(), fresh, over, over)
    jax.effects_barrier()
    cap = capfd.readouterr()
    assert "DROPPED" in cap.out + cap.err, (cap.out, cap.err)


def test_indexed_overflow_checkify_raises():
    from jax.experimental import checkify
    rng = np.random.default_rng(23)
    n, k = 6, 2
    spec = make_flat_spec(jnp.zeros((32,)))
    eng = DuDeEngine(spec=spec, n_workers=n, backend="indexed",
                     index_width=k, index_check="checkify")
    fresh = jnp.asarray(rng.normal(size=(n, spec.padded_size)), jnp.float32)
    none = jnp.zeros(n, bool)
    checked = checkify.checkify(lambda s, f, a, b: eng.round(s, f, a, b))
    err, _ = checked(eng.init(), fresh, none, jnp.asarray(np.arange(n) < 3))
    with pytest.raises(Exception, match="index_width"):
        err.throw()
    err, _ = checked(eng.init(), fresh, none, jnp.asarray(np.arange(n) < 2))
    err.throw()  # within the bound: no error


def test_round_indexed_acc_count_matches_round():
    """round() and round_indexed() must agree on the FULL state — including
    acc_count, which the seed's round_indexed left untouched."""
    rng = np.random.default_rng(24)
    n = 6
    spec = make_flat_spec(jnp.zeros((100,)))
    P = spec.padded_size
    eng = DuDeEngine(spec=spec, n_workers=n, backend="indexed")
    s_mask, s_idx = eng.init(), eng.init()
    for t in range(8):
        fresh = jnp.asarray(rng.normal(size=(n, P)), jnp.float32)
        sm = rng.random(n) < 0.5
        cm = rng.random(n) < 0.4
        s_mask, g1 = eng.round(s_mask, fresh, jnp.asarray(sm), jnp.asarray(cm))
        s_idx, g2 = eng.round_indexed(
            s_idx, fresh,
            jnp.asarray(masks_to_indices(sm, n, n)),
            jnp.asarray(masks_to_indices(cm, n, n)))
        np.testing.assert_allclose(g1, g2, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(s_mask.acc_count),
                                      np.asarray(s_idx.acc_count))
        assert int(s_mask.step) == int(s_idx.step)


def test_round_indexed_accumulate_raises():
    spec = make_flat_spec(jnp.zeros((8,)))
    eng = DuDeEngine(spec=spec, n_workers=2, accumulate=True)
    st = eng.init()
    with pytest.raises(ValueError, match="accumulate"):
        eng.round_indexed(st, jnp.zeros((2, spec.padded_size)),
                          jnp.zeros(2, jnp.int32), jnp.zeros(2, jnp.int32))


def test_accumulate_requires_reference_backend():
    spec = make_flat_spec(jnp.zeros((8,)))
    with pytest.raises(ValueError, match="accumulate"):
        DuDeEngine(spec=spec, n_workers=2, accumulate=True, backend="pallas")
    with pytest.raises(ValueError, match="backend"):
        DuDeEngine(spec=spec, n_workers=2, backend="nope")


def test_engine_under_jit_and_grad_dtype():
    """Engine round jits cleanly and accepts non-f32 fresh gradients."""
    spec = make_flat_spec(jnp.zeros((150,)))
    eng = DuDeEngine(spec=spec, n_workers=2, buffer_dtype=jnp.bfloat16)
    P = spec.padded_size
    state = eng.init()
    fresh = jnp.ones((2, P), jnp.bfloat16)
    ones = jnp.ones(2, bool)
    step = jax.jit(eng.round)
    state, _ = step(state, fresh, ones, ones)     # latch
    state, gbar = step(state, fresh, ones, ones)  # commit
    np.testing.assert_allclose(gbar, np.ones(P), atol=1e-2)
    assert state.g_workers.dtype == jnp.bfloat16
    assert int(state.step) == 2

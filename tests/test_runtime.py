"""Async-runtime acceptance tests (docs/async.md).

* Trace-replay determinism: the production AsyncRunner replaying a
  simulator run's recorded ArrivalTrace reproduces the simulator's
  parameters BIT-FOR-BIT for dude and all three ASGD routing disciplines —
  unsharded, and with the engine P-axis sharded on the 8-device mesh
  (the flat arrival step is elementwise on P, so sharding cannot change a
  single bit).
* The simulator replays its own trace bit-for-bit (routing rng parity).
* Bounded in-flight depth: the event loop never exceeds ``max_in_flight``
  dispatched-but-unarrived jobs, and still completes the run.
* Straggler ordering under the exponential process: arrivals are time-
  ordered and a 100x-slower worker arrives rarely.
* DeviceQueue double buffering, ArrivalTrace persistence, registry
  validation, and a Trainer.run_async end-to-end smoke.

Multi-device tests follow the test_flat_state.py pattern: skipped below 8
devices and re-run by ``test_runtime_sharded_suite_subprocess`` under
``--xla_force_host_platform_device_count=8``; CI also runs this file
in-process on the 8-device host mesh.
"""

import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import NDEV, multidevice, p_mesh
from repro.core import make_algo, simulate, truncated_normal_speeds
from repro.core.algos import ASYNC_ALGOS, make_async_algo
from repro.core.engine import DuDeEngine
from repro.core.flatten import make_flat_spec
from repro.optim import sgd
from repro.runtime import (
    ArrivalTrace, ExponentialArrivals, FixedArrivals, TraceArrivals,
    drive_arrivals, make_arrivals,
)
from repro.runtime.runner import AsyncRunner, DeviceQueue

N = 5
LR = 0.05
SEED = 3

# runner algo name -> simulator algo name (same discipline)
DISCIPLINES = {
    "dude": "dude_asgd",
    "vanilla_asgd": "vanilla_asgd",
    "uniform_asgd": "uniform_asgd",
    "shuffled_asgd": "shuffled_asgd",
}


def _tree():
    rng = np.random.default_rng(0)
    return {"w": jnp.asarray(rng.normal(size=(3, 4)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=5), jnp.float32)}


_TARGETS = jnp.asarray(np.random.default_rng(42).normal(size=(N, 3, 4)),
                       jnp.float32)


def _sample_fn(i, rng):
    return {"i": jnp.int32(i),
            "noise": jnp.asarray(rng.normal(size=(3, 4)), jnp.float32)}


def _loss(p, batch):
    t = _TARGETS[batch["i"]] + 0.1 * batch["noise"]
    return 0.5 * jnp.sum((p["w"] - t) ** 2) + 0.5 * jnp.sum(p["b"] ** 2)


def _grad_fn(params, batch, key):
    loss, g = jax.value_and_grad(_loss)(params, batch)
    return loss, g


def _sim(name, total=40, **kw):
    speeds = truncated_normal_speeds(N, std=1.0, seed=1)
    return simulate(make_algo(name, N), speeds, _grad_fn, _sample_fn,
                    _tree(), lr=LR, total_iters=total, seed=SEED,
                    record_every=10, **kw)


def _runner(algo, process, total=40, mesh=None):
    tree = _tree()
    spec = make_flat_spec(tree, mesh_axis_size=NDEV if mesh else 1)
    eng = DuDeEngine(spec=spec, n_workers=N, interpret=True, mesh=mesh,
                     axis_name="p" if mesh else None)
    runner = AsyncRunner(eng, algo, sgd(LR), _grad_fn)
    state = runner.init_state(tree)
    out = runner.run(process, total, _sample_fn, state, seed=SEED,
                     record_every=10)
    return eng, out


# -------------------------------------------------- trace-replay equivalence


@pytest.mark.parametrize("algo", list(DISCIPLINES))
def test_runner_trace_replay_matches_simulator(algo):
    """THE acceptance criterion: AsyncRunner on a recorded arrival trace
    reproduces the simulator's parameters bit-for-bit (flat slab math ==
    pytree math, one shared event loop, one shared jitted grad_fn)."""
    res = _sim(DISCIPLINES[algo])
    eng, out = _runner(algo, TraceArrivals(res.trace))
    back = eng.spec.unravel(out.state.params)
    for k, leaf in res.params.items():
        np.testing.assert_array_equal(np.asarray(back[k]), np.asarray(leaf),
                                      err_msg=f"{algo}/{k}")
    assert out.tau_max == res.tau_max
    assert out.n_grads == res.n_grads
    # instrumentation parity: both record the RAW arriving gradient's norm
    np.testing.assert_allclose(out.gnorms, res.grad_norms, rtol=1e-6)
    # and the replay's own trace re-enacts the source schedule
    np.testing.assert_array_equal(out.trace.worker, res.trace.worker)
    np.testing.assert_allclose(out.trace.t_arrive, res.trace.t_arrive)


@multidevice
@pytest.mark.parametrize("algo", list(DISCIPLINES))
def test_runner_trace_replay_matches_simulator_sharded(algo):
    """Same bit-for-bit equivalence with the engine P-axis sharded over the
    8-device mesh: per-arrival commit + flat apply are elementwise on P, so
    the sharded runner cannot differ from the unsharded simulator."""
    res = _sim(DISCIPLINES[algo])
    eng, out = _runner(algo, TraceArrivals(res.trace), mesh=p_mesh())
    back = eng.spec.unravel(out.state.params)
    for k, leaf in res.params.items():
        np.testing.assert_array_equal(np.asarray(back[k]), np.asarray(leaf),
                                      err_msg=f"{algo}/{k}")
    assert out.tau_max == res.tau_max


@pytest.mark.parametrize("algo", ["dude_asgd", "uniform_asgd",
                                  "shuffled_asgd"])
def test_simulator_self_replay(algo):
    """simulate(arrivals=TraceArrivals(own trace)) is bit-identical — the
    routing rng draws are part of the replayed semantics."""
    res = _sim(algo)
    res2 = _sim(algo, arrivals=TraceArrivals(res.trace))
    for k in res.params:
        np.testing.assert_array_equal(np.asarray(res.params[k]),
                                      np.asarray(res2.params[k]))
    assert res2.tau_max == res.tau_max


# ------------------------------------------------------- in-flight bounding


def _count_loop(process, total, route=None, rng=None, max_in_flight=None):
    seen = []

    def on_arrival(view):
        seen.append(view.worker)
        return True

    def deliver(w):
        pass

    stats = drive_arrivals(process, total, on_arrival, deliver, route=route,
                           rng=rng, max_in_flight=max_in_flight)
    return seen, stats


def test_bounded_in_flight_invariant():
    """With max_in_flight=k the loop never has more than k jobs computing,
    still completes the requested iterations, the pending FIFO keeps EVERY
    worker participating (no starvation at the bound), and the bound is
    tight (an unbounded run saturates all n workers)."""
    proc = FixedArrivals(np.linspace(0.5, 2.0, 6))
    seen, stats = _count_loop(proc, 40, max_in_flight=2)
    assert stats.max_in_flight <= 2
    assert stats.iters == 40 and len(seen) == 40
    assert set(seen) == set(range(6)), "bound must rotate, not starve"
    proc.reset()
    _, unbounded = _count_loop(proc, 40)
    assert unbounded.max_in_flight == 6


def test_bounded_in_flight_reduces_staleness_pressure():
    """The in-flight bound caps CONCURRENT jobs, not per-job tau (a
    straggler's job still ages while other slots recycle) — but fewer
    simultaneously stale jobs means the bounded run's tau_max cannot
    exceed the unbounded run's on the same fleet."""
    proc = FixedArrivals(np.asarray([1.0, 1.1, 1.3, 1.7, 2.9, 5.0]))
    _, free = _count_loop(proc, 60)
    proc.reset()
    _, tight = _count_loop(proc, 60, max_in_flight=2)
    assert tight.max_in_flight <= 2 < free.max_in_flight
    assert tight.tau_max <= free.tau_max
    # and the non-guarantee is real: one extreme straggler can age
    # arbitrarily while the fast slot turns over under the bound
    strag = FixedArrivals(np.asarray([50.0, 1.0]))
    _, s = _count_loop(strag, 60, max_in_flight=2)
    assert s.tau_max > 2


def test_routed_respects_in_flight_bound():
    rng = np.random.default_rng(0)
    proc = ExponentialArrivals(6, mean=1.0, seed=5)
    seen, stats = _count_loop(proc, 50, route="uniform", rng=rng,
                              max_in_flight=3)
    assert stats.max_in_flight <= 3
    assert stats.iters == 50


# ------------------------------------------------- exponential stragglers


def test_straggler_ordering_exponential():
    """A 25x-slower worker under the exponential process: arrivals stay
    globally time-ordered, the straggler arrives (far) less often, and its
    jobs overlap many faster arrivals (large observed staleness)."""
    means = np.asarray([25.0, 1.0, 1.0, 1.0, 1.0])
    proc = ExponentialArrivals(5, mean=means, seed=7)
    seen, stats = _count_loop(proc, 400)
    t = stats.trace.t_arrive
    assert np.all(np.diff(t) >= 0), "arrivals must be time-ordered"
    counts = np.bincount(stats.trace.worker, minlength=5)
    assert counts[0] <= counts[1:].min() / 5, counts
    assert counts[0] >= 1  # the straggler does eventually arrive
    # straggler jobs span many server iterations
    assert stats.tau_max > 20


def test_exponential_durations_heavy_tail():
    proc = ExponentialArrivals(1, mean=1.0, seed=0)
    d = np.asarray([proc.duration(0) for _ in range(2000)])
    assert 0.9 < d.mean() < 1.1
    assert d.max() > 4.0  # the straggler tail exists


# ------------------------------------------------------- trace persistence


def test_trace_save_load_roundtrip(tmp_path):
    res = _sim("dude_asgd", total=25)
    p = str(tmp_path / "trace.json")
    res.trace.save(p)
    back = ArrivalTrace.load(p)
    np.testing.assert_array_equal(back.worker, res.trace.worker)
    np.testing.assert_allclose(back.t_dispatch, res.trace.t_dispatch)
    np.testing.assert_allclose(back.t_arrive, res.trace.t_arrive)
    # and the loaded trace drives a bit-identical replay
    res2 = _sim("dude_asgd", total=25,
                arrivals=make_arrivals("trace", N, trace=p))
    for k in res.params:
        np.testing.assert_array_equal(np.asarray(res.params[k]),
                                      np.asarray(res2.params[k]))


def test_make_arrivals_validation(tmp_path):
    with pytest.raises(ValueError, match="unknown arrival kind"):
        make_arrivals("poisson", 4)
    with pytest.raises(ValueError, match="needs a trace path"):
        make_arrivals("trace", 4)
    res = _sim("vanilla_asgd", total=10)
    p = str(tmp_path / "t.json")
    res.trace.save(p)
    with pytest.raises(ValueError, match="workers"):
        make_arrivals("trace", N + 1, trace=p)


# --------------------------------------------------------- device queue


def test_device_queue_bounds_host_ahead():
    q = DeviceQueue(depth=2)
    for i in range(10):
        q.push(jnp.full((4,), i))
        assert len(q) <= 2
    assert q.waits == 8
    q.flush()
    assert len(q) == 0
    with pytest.raises(ValueError):
        DeviceQueue(depth=0)


# ------------------------------------------------------- registry plumbing


def test_async_algo_registry_validation():
    spec = make_flat_spec(_tree())
    eng = DuDeEngine(spec=spec, n_workers=N, interpret=True)
    with pytest.raises(ValueError, match="unknown async algo"):
        make_async_algo("sync_sgd", eng)
    acc = DuDeEngine(spec=spec, n_workers=N, accumulate=True,
                     interpret=True)
    with pytest.raises(ValueError, match="accumulate"):
        make_async_algo("dude", acc)
    for name in ASYNC_ALGOS:
        algo = make_async_algo(name, eng)
        # greedy scheduling everywhere except the two routed disciplines
        assert (algo.route is None) == (
            name not in ("uniform_asgd", "shuffled_asgd"))


def test_runner_rejects_mismatched_process():
    spec = make_flat_spec(_tree())
    eng = DuDeEngine(spec=spec, n_workers=N, interpret=True)
    runner = AsyncRunner(eng, "dude", sgd(LR), _grad_fn)
    state = runner.init_state(_tree())
    with pytest.raises(ValueError, match="n_workers"):
        runner.run(FixedArrivals(np.ones(N + 1)), 5, _sample_fn, state)


# ------------------------------------------------------ Trainer.run_async


def _tiny_cfg():
    from repro.models.config import ModelConfig
    return ModelConfig(
        name="runtime-test-lm", arch_type="dense", num_layers=1, d_model=32,
        num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=32,
        dtype=jnp.float32, remat=False, attn_chunk=16, n_workers=4,
    )


def test_trainer_run_async_smoke():
    """End-to-end: an arrival-only algo trains through Trainer.run_async
    (and rejects the round step), advancing the session state/rounds."""
    from repro.api import ConfigError, Trainer, TrainerConfig
    cfg = _tiny_cfg()
    t = Trainer.create(TrainerConfig(arch=cfg, algo="vanilla_asgd",
                                     lr=0.05, seed=1))
    with pytest.raises(ConfigError, match="arrival-granularity"):
        t.step({}, jnp.ones(4, bool), jnp.ones(4, bool))
    key = jax.random.PRNGKey(0)

    def sample_fn(i, rng):
        toks = jax.random.randint(jax.random.fold_in(key, i), (1, 16),
                                  0, cfg.vocab_size)
        return {"tokens": toks, "labels": toks}

    p0 = np.asarray(t.state.params)
    res = t.run_async("exp", 12, sample_fn, record_every=4)
    assert t.rounds == 12 == res.stats.iters
    assert np.all(np.isfinite(res.losses))
    assert np.any(np.asarray(t.state.params) != p0)
    assert int(t.state.opt.step) == 12
    # dude runs BOTH granularities on one session state
    t2 = Trainer.create(TrainerConfig(arch=cfg, algo="dude", lr=0.05))
    t2.run_async("fixed", 4, sample_fn)
    ones = jnp.ones(4, bool)
    m = t2.step(_round_batch(cfg, key), ones, ones)
    assert np.isfinite(float(m["loss"]))
    assert t2.rounds == 5


def _round_batch(cfg, key):
    n = cfg.n_workers
    toks = jax.random.randint(key, (n, 1, 16), 0, cfg.vocab_size)
    return {"tokens": toks, "labels": toks}


def test_trainer_config_async_knobs():
    from repro.api import ConfigError, TrainerConfig
    cfg = _tiny_cfg()
    for name in ASYNC_ALGOS:
        TrainerConfig(arch=cfg, algo=name)
    with pytest.raises(ConfigError, match="unknown algo"):
        TrainerConfig(arch=cfg, algo="poisson_sgd")
    with pytest.raises(ConfigError, match="max_in_flight"):
        TrainerConfig(arch=cfg, max_in_flight=0)
    with pytest.raises(ConfigError, match="arrival_queue_depth"):
        TrainerConfig(arch=cfg, arrival_queue_depth=0)


# ------------------------------------------------------ subprocess driver


def test_runtime_sharded_suite_subprocess():
    """Run the in-process multidevice tests above on 8 host-platform devices
    (they are skipped in a default single-device session)."""
    if jax.device_count() >= NDEV:
        pytest.skip("already multi-device in-process")
    repo = Path(__file__).resolve().parent.parent
    env = {
        **os.environ,
        "PYTHONPATH": "src",
        "JAX_PLATFORMS": "cpu",
        "XLA_FLAGS": (os.environ.get("XLA_FLAGS", "")
                      + f" --xla_force_host_platform_device_count={NDEV}"
                      ).strip(),
    }
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-p", "no:cacheprovider",
         str(Path(__file__).resolve()),
         "-k", "sharded and not subprocess"],
        capture_output=True, text=True, timeout=540, env=env, cwd=repo,
    )
    assert r.returncode == 0, r.stdout[-4000:] + r.stderr[-2000:]
    assert "skipped" not in r.stdout.splitlines()[-1], r.stdout[-500:]

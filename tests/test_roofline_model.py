"""Validate the analytic cost model (launch/costs.py) against XLA's compiled
cost_analysis on scan-free reduced configs — and document WHY the analytic
model exists (cost_analysis counts lax.scan bodies once)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.costs import forward_flops, model_flops_6nd, param_counts
from repro.launch.hlo_analysis import cost_analysis_dict as _cost_analysis
from repro.models import forward, lm_init
from repro.models.config import ModelConfig


def test_scan_bodies_counted_once():
    """The reason the roofline uses an analytic model: XLA's cost_analysis
    counts a 10-trip scan body once (~1/10 the unrolled count)."""
    def f_scan(x, w):
        def step(c, _):
            return c @ w, None
        c, _ = jax.lax.scan(step, x, None, length=10)
        return c

    def f_unrolled(x, w):
        for _ in range(10):
            x = x @ w
        return x

    xs = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    fl_scan = _cost_analysis(jax.jit(f_scan).lower(xs, xs).compile())["flops"]
    fl_unr = _cost_analysis(
        jax.jit(f_unrolled).lower(xs, xs).compile())["flops"]
    assert fl_unr > 8 * fl_scan


def _reduced(name="dense", **kw):
    base = dict(name=name, arch_type="dense", num_layers=2, d_model=256,
                num_heads=4, num_kv_heads=2, d_ff=512, vocab_size=512,
                dtype=jnp.float32, remat=False, scan_layers=False,
                attn_chunk=1 << 30)  # single chunk => no inner scan
    base.update(kw)
    return ModelConfig(**base)


@pytest.mark.parametrize("cfgkw", [
    {},
    {"num_kv_heads": 4},
])
def test_forward_flops_matches_xla(cfgkw):
    """On a scan-free config, analytic forward FLOPs within 25% of XLA's
    count (XLA adds elementwise/softmax ops the model books as epsilon)."""
    cfg = _reduced(**cfgkw)
    B, S = 2, 128
    params = jax.eval_shape(lambda: lm_init(jax.random.PRNGKey(0), cfg))
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}

    compiled = (
        jax.jit(lambda p, b: forward(p, b, cfg)[0])
        .lower(params, batch).compile()
    )
    xla_flops = _cost_analysis(compiled)["flops"]

    # analytic model at the same shape
    import repro.launch.costs as costs
    spec = {"seq_len": S, "global_batch": B, "kind": "prefill"}
    costs_shapes = dict(costs.INPUT_SHAPES)
    costs.INPUT_SHAPES["__test__"] = spec
    try:
        ours = forward_flops(cfg, "__test__")["total"]
    finally:
        costs.INPUT_SHAPES.clear()
        costs.INPUT_SHAPES.update(costs_shapes)
    ratio = ours / xla_flops
    assert 0.75 < ratio < 1.3, (ours, xla_flops, ratio)


def test_param_counts_sane():
    cfg = _reduced()
    pc = param_counts(cfg)
    # embedding 512x256 x2 (tie off) + 2 layers x (attn ~ 4*d^2*...)
    assert pc["total"] > 2 * 512 * 256
    assert pc["active"] == pc["total"]  # dense: all params active


def test_moe_active_params_lt_total():
    cfg = _reduced(
        name="moe", arch_type="moe", block_pattern=("moe",), num_experts=8,
        experts_per_tok=2, moe_d_ff=128,
    )
    pc = param_counts(cfg)
    assert pc["active"] < pc["total"]


def test_model_flops_6nd_ordering():
    """decode FLOPs << prefill FLOPs for the same arch (1 token vs S)."""
    from repro.configs import get_config
    cfg = get_config("qwen2_0_5b")
    assert model_flops_6nd(cfg, "decode_32k") < model_flops_6nd(cfg, "prefill_32k")

"""End-to-end driver: train a multi-million-parameter transformer LM with
DuDe-ASGD for a few hundred rounds on heterogeneous token data.

This wraps the production launcher (repro.launch.train) at a CPU-feasible
scale; on a TPU mesh the same launcher runs the full configs (see
launch/dryrun.py for the 16x16 / 2x16x16 lowering proof).  Pass --big to
train a ~100M-param model (minutes/round on CPU; the default ~5M model does
a few hundred rounds in minutes).

  PYTHONPATH=src python examples/train_dude_transformer.py [--big]
"""

import argparse
import sys

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--big", action="store_true",
                    help="~100M params (slow on CPU)")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--algo", default="dude",
                    help="any core.algos registry rule (dude, dude_accum, "
                         "sync_sgd, mifa, fedbuff) — all run the same "
                         "session step")
    args, _ = ap.parse_known_args()

    if args.big:
        # qwen2-0.5b at full width, 4 layers: ~100M params
        argv = [
            "--arch", "qwen2_0_5b", "--rounds", str(args.rounds or 200),
            "--seq-len", "128", "--per-worker-batch", "1",
            "--lr", "0.02", "--heterogeneity", "2.0", "--speed-std", "1.0",
        ]
        import dataclasses
        import repro.configs as C
        cfg = C.get_config("qwen2_0_5b")
        cfg = dataclasses.replace(
            cfg, num_layers=4, n_workers=4, remat=False,
        )
        # monkey-patch the registry entry for this run
        import repro.configs.qwen2_0_5b as q
        q.CONFIG = cfg
    else:
        argv = [
            "--arch", "qwen2_0_5b", "--smoke", "--rounds",
            str(args.rounds or 300), "--seq-len", "64",
            "--per-worker-batch", "2", "--lr", "0.05",
            "--heterogeneity", "2.0",
        ]

    sys.argv = [sys.argv[0]] + argv + ["--algo", args.algo]
    train_mod.main()


if __name__ == "__main__":
    main()

"""The paper's core phenomenon, isolated: on arbitrarily heterogeneous data,
vanilla ASGD converges to the WRONG point; DuDe-ASGD converges to the true
stationary point at async speed.

Each worker i holds F_i(w) = 0.5 w'A_i w - b_i'w with very different b_i
(heterogeneity zeta is effectively unbounded).  We report distance to the
exact minimizer of F = mean(F_i) and simulated wall-clock.

  PYTHONPATH=src python examples/heterogeneous_quadratic.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ALGO_NAMES, make_algo, simulate, truncated_normal_speeds

N, P, HET = 8, 10, 5.0
rng = np.random.default_rng(0)
A = [np.diag(rng.uniform(0.5, 2.0, P)) for _ in range(N)]
b = [rng.normal(size=P) * HET for _ in range(N)]
wstar = np.linalg.solve(sum(A) / N, sum(b) / N)


def grad_fn(params, batch, key):
    Ai, bi = batch
    g = Ai @ params - bi + 0.05 * jax.random.normal(key, (P,))
    return 0.5 * params @ Ai @ params - bi @ params, g


def sample_fn(i, rng_):
    return (jnp.asarray(A[i], jnp.float32), jnp.asarray(b[i], jnp.float32))


speeds = truncated_normal_speeds(N, std=5.0, seed=1)  # extreme stragglers
print(f"worker speeds: {np.round(speeds.times, 2)}")
print(f"{'algorithm':<16} {'|w-w*|':>8} {'sim-time':>9} {'#grads':>7} {'tau_max':>8}")
for name in ALGO_NAMES:
    res = simulate(make_algo(name, N), speeds, grad_fn, sample_fn,
                   jnp.zeros(P), lr=0.03, total_iters=800, record_every=1000)
    err = float(np.linalg.norm(np.asarray(res.params) - wstar))
    t = res.times[-1] if len(res.times) else float("nan")
    print(f"{name:<16} {err:8.4f} {t:9.1f} {res.n_grads:7d} {res.tau_max:8d}")

print("\nDuDe-ASGD matches sync SGD's solution with a fraction of the "
      "gradients and wall-clock; vanilla/uniform ASGD stall at a "
      "heterogeneity-proportional bias (paper Table 1).")

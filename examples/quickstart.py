"""Quickstart: DuDe-ASGD through the one-object session API, in ~30 lines.

Trains a tiny transformer LM with the paper's dual-delayed semi-asynchronous
protocol (mode B): 4 workers with heterogeneous speeds, per-worker data
skew, incremental server aggregation.  ``Trainer`` owns the single flat
train state (master params + optimizer slots + server slabs in one
segment-range ``[P]`` layout) and the one step signature; swap
``algo="dude"`` for any registry rule (``sync_sgd`` / ``mifa`` /
``fedbuff``) to run a Table-1 baseline through the same engine path.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import Trainer, TrainerConfig
from repro.core import delay_stats, make_round_schedule, truncated_normal_speeds
from repro.data import make_token_sampler
from repro.models.config import ModelConfig

cfg = ModelConfig(
    name="quickstart-lm", arch_type="dense", num_layers=2, d_model=128,
    num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=256,
    dtype=jnp.float32, remat=False, attn_chunk=32, n_workers=4,
)

trainer = Trainer.create(TrainerConfig(arch=cfg, algo="dude",
                                       optimizer="sgd", lr=0.05))

# heterogeneous speeds (paper §5: s_i ~ TN(1, std)) -> round schedule
speeds = truncated_normal_speeds(cfg.n_workers, std=1.0, seed=1)
schedule = make_round_schedule(speeds, rounds=60)
print("speeds:", np.round(speeds.times, 2), delay_stats(schedule))

# heterogeneous data: each worker draws from its own token distribution
sampler = make_token_sampler(cfg.n_workers, cfg.vocab_size, seq_len=32,
                             batch=2, heterogeneity=2.0, seed=0)
rng = np.random.default_rng(0)

for r in range(schedule.rounds):
    per = [sampler(i, rng) for i in range(cfg.n_workers)]
    batch = {k: jnp.asarray(np.stack([p[k] for p in per])) for k in per[0]}
    m = trainer.step(batch, schedule.start[r], schedule.commit[r])
    if r % 10 == 0:
        print(f"round {r:3d}  loss {float(m['loss']):.4f}")

params = trainer.params()  # unraveled pytree view, e.g. for eval/serving
print("trained params:", trainer.param_count(), "scalars in",
      len(jax.tree.leaves(params)), "leaves")

# --- the same session, event-driven (mode A, docs/async.md): one server
# --- iteration per gradient ARRIVAL instead of per masked round; 'dude'
# --- lives in both registries, so it continues on the same train state.
from repro.runtime import ExponentialArrivals  # noqa: E402

res = trainer.run_async(
    ExponentialArrivals(cfg.n_workers, mean=speeds.times, seed=2),
    total_iters=40,
    sample_fn=lambda i, rng: {k: jnp.asarray(v)
                              for k, v in sampler(i, rng).items()},
    record_every=10,
)
print(f"async: {res.stats.arrivals} arrivals, tau_max={res.tau_max}, "
      f"loss {res.losses[-1]:.4f} (trace of {len(res.trace)} events "
      "recorded — replayable bit-for-bit)")

"""Quickstart: DuDe-ASGD in ~40 lines.

Trains a tiny transformer LM with the paper's dual-delayed semi-asynchronous
protocol (mode B): 4 workers with heterogeneous speeds, per-worker data
skew, incremental server aggregation.

  PYTHONPATH=src python examples/quickstart.py

The production driver additionally offers flat-state training, which keeps
master params + optimizer slots in the engine's flat [P] layout and fuses
the round with the optimizer apply (zero-collective on a mesh):

  PYTHONPATH=src python -m repro.launch.train --arch qwen2_0_5b --smoke \
      --rounds 50 --seq-len 64 --per-worker-batch 2 --flat-optimizer
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (DuDeConfig, delay_stats,
                        make_round_schedule, truncated_normal_speeds)
from repro.data import make_token_sampler
from repro.launch.steps import make_engine, make_train_step
from repro.models import lm_init
from repro.models.config import ModelConfig
from repro.optim import sgd

cfg = ModelConfig(
    name="quickstart-lm", arch_type="dense", num_layers=2, d_model=128,
    num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=256,
    dtype=jnp.float32, remat=False, attn_chunk=32, n_workers=4,
)

params = lm_init(jax.random.PRNGKey(0), cfg)
opt = sgd(0.05)
opt_state = opt.init(params)
dude_cfg = DuDeConfig(cfg.n_workers, jnp.float32)
engine = make_engine(cfg, None, dude_cfg)   # flat [P]/[n, P] server state
dude_state = engine.init()
step = jax.jit(make_train_step(cfg, None, opt, dude_cfg, engine=engine))

# heterogeneous speeds (paper §5: s_i ~ TN(1, std)) -> round schedule
speeds = truncated_normal_speeds(cfg.n_workers, std=1.0, seed=1)
schedule = make_round_schedule(speeds, rounds=60)
print("speeds:", np.round(speeds.times, 2), delay_stats(schedule))

# heterogeneous data: each worker draws from its own token distribution
sampler = make_token_sampler(cfg.n_workers, cfg.vocab_size, seq_len=32,
                             batch=2, heterogeneity=2.0, seed=0)
rng = np.random.default_rng(0)

for r in range(schedule.rounds):
    per = [sampler(i, rng) for i in range(cfg.n_workers)]
    batch = {k: jnp.asarray(np.stack([p[k] for p in per])) for k in per[0]}
    params, opt_state, dude_state, m = step(
        params, opt_state, dude_state, batch,
        jnp.asarray(schedule.start[r]), jnp.asarray(schedule.commit[r]))
    if r % 10 == 0:
        print(f"round {r:3d}  loss {float(m['loss']):.4f}")
print("done — dual-delayed aggregated gradient, zero straggler stalls.")

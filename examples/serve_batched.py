"""Batched serving example: prefill a batch of prompts and decode tokens with
the production serve_step (KV caches, GQA flash-decode math, SWA support).

  PYTHONPATH=src python examples/serve_batched.py [--arch qwen3_1_7b]
"""

import argparse
import sys

from repro.launch import serve as serve_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_0_5b")
    args, _ = ap.parse_known_args()
    sys.argv = [sys.argv[0], "--arch", args.arch, "--smoke",
                "--batch", "4", "--prompt-len", "24", "--gen-len", "12"]
    serve_mod.main()


if __name__ == "__main__":
    main()

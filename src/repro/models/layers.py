"""Shared neural-net layers (pure JAX, no flax).

Parameters are plain nested dicts; every layer is a pair of functions
``init_*(key, ...) -> params`` and ``apply`` (inline).  Computation dtype is
configurable (bf16 by default at scale); parameters are stored in f32 unless
the caller casts.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

Pytree = Any


# ----------------------------------------------------------------- init utils

def dense_init(key, d_in: int, d_out: int, *, bias: bool = False, scale: float | None = None):
    scale = scale if scale is not None else (1.0 / jnp.sqrt(d_in))
    p = {"kernel": jax.random.normal(key, (d_in, d_out), jnp.float32) * scale}
    if bias:
        p["bias"] = jnp.zeros((d_out,), jnp.float32)
    return p


def dense(p, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["kernel"].astype(x.dtype)
    if "bias" in p:
        y = y + p["bias"].astype(x.dtype)
    return y


def embedding_init(key, vocab: int, d: int):
    return {"embedding": jax.random.normal(key, (vocab, d), jnp.float32) * 0.02}


def embed(p, ids: jnp.ndarray, dtype) -> jnp.ndarray:
    return jnp.take(p["embedding"], ids, axis=0).astype(dtype)


# ----------------------------------------------------------------------- norm

def rmsnorm_init(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"]).astype(x.dtype)


def layernorm_init(d: int):
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(p, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(x.dtype)


# ------------------------------------------------------------------------ mlp

def mlp_init(key, d: int, d_ff: int, *, gated: bool = True):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"up": dense_init(k1, d, d_ff), "down": dense_init(k2, d_ff, d)}
    if gated:
        p["gate"] = dense_init(k3, d, d_ff)
    return p


def mlp(p, x: jnp.ndarray) -> jnp.ndarray:
    h = dense(p["up"], x)
    if "gate" in p:
        h = jax.nn.silu(dense(p["gate"], x)) * h
    else:
        h = jax.nn.gelu(h)
    return dense(p["down"], h)


# ----------------------------------------------------------------------- rope

def rope_frequencies(head_dim: int, theta: float = 1e4) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 1e4) -> jnp.ndarray:
    """x: [..., S, H, hd]; positions: [..., S] (broadcastable)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)  # [hd/2]
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # [..., S, 1, hd/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)

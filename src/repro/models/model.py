"""Language-model wrapper: embeddings, layer stack, head, loss, and the three
entry points the launcher lowers (train forward, prefill, decode step).

Batch dict convention (all entry points):
  tokens      [B, S_text]            int32  (musicgen: [B, S_text, n_codebooks])
  labels      [B, S_total]           int32, -1 = masked (train only)
  prefix_emb  [B, P, frontend_dim]   float  (vlm/audio only; stub output)

For frontend archs the effective sequence is [prefix_emb ; tokens] with total
length P + S_text; positions are absolute over the total sequence.
"""

from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense, dense_init, embed, embedding_init, rmsnorm, rmsnorm_init
from .transformer import stack_apply, stack_caches, stack_init

Pytree = Any
ShardHook = Callable[[jnp.ndarray, str], jnp.ndarray]
_id_hook: ShardHook = lambda x, name: x


def lm_init(key, cfg: ModelConfig) -> Pytree:
    k_emb, k_stack, k_head, k_proj = jax.random.split(key, 4)
    params: dict = {"stack": stack_init(k_stack, cfg), "ln_f": rmsnorm_init(cfg.d_model)}
    if cfg.num_codebooks > 1:
        keys = jax.random.split(k_emb, cfg.num_codebooks)
        params["embed"] = [embedding_init(k, cfg.vocab_size, cfg.d_model) for k in keys]
        hkeys = jax.random.split(k_head, cfg.num_codebooks)
        params["head"] = [dense_init(k, cfg.d_model, cfg.vocab_size, scale=0.02)
                          for k in hkeys]
    else:
        params["embed"] = embedding_init(k_emb, cfg.vocab_size, cfg.d_model)
        if not cfg.tie_embeddings:
            params["head"] = dense_init(k_head, cfg.d_model, cfg.vocab_size, scale=0.02)
    if cfg.frontend:
        params["frontend_proj"] = dense_init(k_proj, cfg.frontend_dim, cfg.d_model)
    return params


def _embed_tokens(params, tokens, cfg: ModelConfig) -> jnp.ndarray:
    if cfg.num_codebooks > 1:
        parts = [embed(params["embed"][c], tokens[..., c], cfg.dtype)
                 for c in range(cfg.num_codebooks)]
        return sum(parts)
    return embed(params["embed"], tokens, cfg.dtype)


def _head(params, x, cfg: ModelConfig) -> jnp.ndarray:
    x32 = x
    if cfg.num_codebooks > 1:
        return jnp.stack(
            [dense(params["head"][c], x32) for c in range(cfg.num_codebooks)], axis=-2
        )  # [B, S, n_cb, V]
    if cfg.tie_embeddings:
        return x32 @ params["embed"]["embedding"].T.astype(x32.dtype)
    return dense(params["head"], x32)


def _inputs_to_h(params, batch, cfg: ModelConfig) -> jnp.ndarray:
    h = _embed_tokens(params, batch["tokens"], cfg)
    if cfg.frontend:
        pe = dense(params["frontend_proj"], batch["prefix_emb"].astype(cfg.dtype))
        h = jnp.concatenate([pe, h], axis=1)
    return h


def forward(
    params: Pytree,
    batch: dict,
    cfg: ModelConfig,
    *,
    shard: ShardHook = _id_hook,
    use_window: bool = False,
):
    """Full-sequence forward.  Returns (logits_f32, aux_loss)."""
    h = _inputs_to_h(params, batch, cfg)
    B, S = h.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    h = shard(h, "act_resid")
    h, _, aux = stack_apply(params["stack"], h, positions, cfg,
                            shard=shard, use_window=use_window)
    h = rmsnorm(params["ln_f"], h, cfg.norm_eps)
    logits = _head(params, h, cfg).astype(jnp.float32)
    return shard(logits, "logits"), aux


def _masked_ce(logits: jnp.ndarray, labels: jnp.ndarray):
    """Returns (sum of -log p over unmasked labels, count)."""
    mask = (labels >= 0).astype(jnp.float32)
    lp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(lp, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    return -jnp.sum(ll * mask), jnp.sum(mask)


def loss_fn(
    params: Pytree,
    batch: dict,
    cfg: ModelConfig,
    *,
    shard: ShardHook = _id_hook,
) -> tuple[jnp.ndarray, dict]:
    """Next-token cross-entropy with -1-masked labels (+ MoE aux).

    With ``cfg.ce_chunk > 0`` (and a single codebook) the LM head + CE run in
    sequence chunks inside a checkpointed scan: the [T, V] logits tensor is
    never materialized (fwd OR bwd) — the §Perf memory-term optimization for
    large-vocab training (see EXPERIMENTS §Perf T2).
    """
    labels = batch["labels"]
    if cfg.ce_chunk and cfg.num_codebooks == 1:
        h = _inputs_to_h(params, batch, cfg)
        B, S = h.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        h = shard(h, "act_resid")
        h, _, aux = stack_apply(params["stack"], h, positions, cfg, shard=shard)
        h = rmsnorm(params["ln_f"], h, cfg.norm_eps)
        C = cfg.ce_chunk
        nc = -(-S // C)
        pad = nc * C - S
        if pad:
            h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
            labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
        hc = h.reshape(B, nc, C, -1).transpose(1, 0, 2, 3)
        lc = labels.reshape(B, nc, C).transpose(1, 0, 2)

        def chunk_loss(carry, inp):
            hs, ls = inp
            logits = _head(params, hs, cfg).astype(jnp.float32)
            logits = shard(logits, "logits")
            s, c = _masked_ce(logits, ls)
            tot, cnt = carry
            return (tot + s, cnt + c), None

        (tot, cnt), _ = jax.lax.scan(
            jax.checkpoint(chunk_loss), (jnp.zeros(()), jnp.zeros(())), (hc, lc)
        )
        loss = tot / jnp.maximum(cnt, 1.0)
    else:
        logits, aux = forward(params, batch, cfg, shard=shard)
        s, c = _masked_ce(logits, labels)
        loss = s / jnp.maximum(c, 1.0)
    total = loss + aux
    return total, {"loss": loss, "aux": aux}


# --------------------------------------------------------------------- decode

def init_decode_caches(cfg: ModelConfig, batch: int, max_len: int,
                       dtype=jnp.bfloat16) -> Pytree:
    return stack_caches(cfg, batch, max_len, dtype)


def prefill(
    params: Pytree,
    batch: dict,
    caches: Pytree,
    cfg: ModelConfig,
    *,
    shard: ShardHook = _id_hook,
    use_window: bool = False,
):
    """Process a prompt, filling caches.  Returns (last_logits, caches)."""
    h = _inputs_to_h(params, batch, cfg)
    B, S = h.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    h, caches, _ = stack_apply(
        params["stack"], h, positions, cfg,
        caches=caches, cache_index=0, shard=shard, use_window=use_window,
    )
    h = rmsnorm(params["ln_f"], h[:, -1:], cfg.norm_eps)
    logits = _head(params, h, cfg).astype(jnp.float32)
    return logits, caches


def decode_step(
    params: Pytree,
    tokens: jnp.ndarray,  # [B, 1] (musicgen: [B, 1, n_cb])
    caches: Pytree,
    index,                # scalar: position of this token
    cfg: ModelConfig,
    *,
    shard: ShardHook = _id_hook,
    use_window: bool = False,
):
    """One serving step: one new token against the cache.  Returns
    (logits [B,1,(n_cb,)V], new_caches)."""
    h = _embed_tokens(params, tokens, cfg)
    B = h.shape[0]
    positions = jnp.broadcast_to(jnp.asarray(index)[None, None], (B, 1))
    h, caches, _ = stack_apply(
        params["stack"], h, positions, cfg,
        caches=caches, cache_index=index, shard=shard, use_window=use_window,
    )
    h = rmsnorm(params["ln_f"], h, cfg.norm_eps)
    logits = _head(params, h, cfg).astype(jnp.float32)
    return logits, caches


def param_count(params: Pytree) -> int:
    return sum(x.size for x in jax.tree.leaves(params))

"""The paper's experiment model: a small CNN with two convolutional layers
for 10-class image classification (paper §5: CIFAR-10, CNN with two conv
layers).  Pure JAX; used by the Fig-2/3 reproduction benchmarks and examples.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Pytree = Any


def cnn_init(key, n_classes: int = 10, ch_in: int = 3) -> Pytree:
    k1, k2, k3, k4 = jax.random.split(key, 4)

    def conv(k, h, w, cin, cout):
        fan = h * w * cin
        return {
            "kernel": jax.random.normal(k, (h, w, cin, cout), jnp.float32)
            * jnp.sqrt(2.0 / fan),
            "bias": jnp.zeros((cout,), jnp.float32),
        }

    def fc(k, din, dout):
        return {
            "kernel": jax.random.normal(k, (din, dout), jnp.float32)
            * jnp.sqrt(2.0 / din),
            "bias": jnp.zeros((dout,), jnp.float32),
        }

    return {
        "conv1": conv(k1, 5, 5, ch_in, 32),
        "conv2": conv(k2, 5, 5, 32, 64),
        "fc1": fc(k3, 8 * 8 * 64, 128),
        "fc2": fc(k4, 128, n_classes),
    }


def _conv2d(p, x, stride=1):
    y = jax.lax.conv_general_dilated(
        x, p["kernel"], (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + p["bias"]


def _maxpool(x, k=2):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, k, k, 1), "VALID"
    )


def cnn_apply(params: Pytree, x: jnp.ndarray) -> jnp.ndarray:
    """x: [B, 32, 32, C] -> logits [B, n_classes]."""
    h = _maxpool(jax.nn.relu(_conv2d(params["conv1"], x)))
    h = _maxpool(jax.nn.relu(_conv2d(params["conv2"], h)))
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ params["fc1"]["kernel"] + params["fc1"]["bias"])
    return h @ params["fc2"]["kernel"] + params["fc2"]["bias"]


def cnn_loss(params: Pytree, batch: dict) -> jnp.ndarray:
    logits = cnn_apply(params, batch["x"])
    lp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(lp, batch["y"][:, None], axis=-1))


def cnn_accuracy(params: Pytree, x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean(jnp.argmax(cnn_apply(params, x), axis=-1) == y)

"""Model configuration shared by model code and the per-arch config files."""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None      # default d_model // num_heads

    # attention options
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e4
    sliding_window: Optional[int] = None  # enables long_500k for dense archs
    attn_chunk: int = 512

    # block pattern: one *period* of layer kinds, cycled num_layers/period times
    # kinds: attn | moe | mamba | mamba_shared_attn | mlstm | slstm
    block_pattern: Tuple[str, ...] = ("attn",)
    # layers prepended before the periodic stack (e.g. kimi's dense layer 0)
    prefix_layers: Tuple[str, ...] = ()

    # moe
    num_experts: int = 0
    experts_per_tok: int = 0
    moe_d_ff: int = 0
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    dense_d_ff: int = 0                 # d_ff for 'attn' layers in MoE models
    mlp_gated: bool = True              # SwiGLU (False: GELU 2-matrix MLP)

    # ssm
    ssm_state: int = 64

    # frontend stub (vlm / audio): precomputed embeddings prepended to tokens
    frontend: Optional[str] = None      # vision | audio
    frontend_dim: int = 0
    num_prefix_tokens: int = 0
    num_codebooks: int = 1              # musicgen: 4 EnCodec codebooks

    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: Any = jnp.bfloat16

    # runtime knobs
    scan_layers: bool = True
    remat: bool = True
    # §Perf: compute the LM head + cross-entropy in sequence chunks inside a
    # checkpointed scan — never materializes [T, V] logits (0 = off).
    ce_chunk: int = 0

    # DuDe / distribution defaults for this arch (overridable at launch)
    n_workers: int = 16
    dude_buffer_dtype: Any = jnp.bfloat16

    # citation for the assigned-architecture pool
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def period(self) -> int:
        return len(self.block_pattern)

    @property
    def n_groups(self) -> int:
        n = self.num_layers - len(self.prefix_layers)
        assert n % self.period == 0, (
            f"{self.name}: {n} periodic layers not divisible by period {self.period}"
        )
        return n // self.period

    def supports_long_decode(self) -> bool:
        """Sub-quadratic decode: SSM/hybrid natively; attention via SWA."""
        if any(k in ("mamba", "mamba_shared_attn", "mlstm", "slstm")
               for k in self.block_pattern):
            return True
        return self.sliding_window is not None

    def smoke(self) -> "ModelConfig":
        """Reduced variant of the same family for CPU smoke tests."""
        period = len(self.block_pattern)
        return dataclasses.replace(
            self,
            num_layers=max(2, period) + len(self.prefix_layers),
            d_model=256,
            num_heads=4,
            num_kv_heads=max(1, min(self.num_kv_heads, 2)),
            head_dim=64,
            d_ff=512,
            dense_d_ff=512 if self.dense_d_ff else 0,
            vocab_size=512,
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            experts_per_tok=min(self.experts_per_tok, 2) if self.experts_per_tok else 0,
            moe_d_ff=128 if self.moe_d_ff else 0,
            ssm_state=16,
            sliding_window=64 if self.sliding_window else None,
            attn_chunk=32,
            num_prefix_tokens=8 if self.num_prefix_tokens else 0,
            frontend_dim=32 if self.frontend_dim else 0,
            dtype=jnp.float32,
            scan_layers=True,
            remat=False,
            n_workers=4,
        )

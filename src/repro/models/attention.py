"""Grouped-query attention with RoPE, qk-norm, QKV bias, sliding window,
KV caches, and memory-bounded chunked softmax.

Three execution paths:
  * ``attention_ref``      — naive O(S^2) materialized scores (tests/oracles).
  * ``attention_chunked``  — online-softmax over KV chunks via ``lax.scan``;
                             mathematically identical, O(S * chunk) memory.
                             This is the default training/prefill path and the
                             jnp counterpart of the Pallas flash kernel.
  * ``decode_attend``      — single-token attention against a cache
                             (flash-decode math; optional sliding window).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from .layers import apply_rope, dense, dense_init, rmsnorm, rmsnorm_init

Pytree = Any
ShardHook = Callable[[jnp.ndarray, str], jnp.ndarray]
_id_hook: ShardHook = lambda x, name: x

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e4
    sliding_window: Optional[int] = None
    chunk: int = 512


def attention_init(key, cfg: AttnConfig) -> Pytree:
    kq, kk, kv, ko = jax.random.split(key, 4)
    H, K, hd, d = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, cfg.d_model
    p = {
        "wq": dense_init(kq, d, H * hd, bias=cfg.qkv_bias),
        "wk": dense_init(kk, d, K * hd, bias=cfg.qkv_bias),
        "wv": dense_init(kv, d, K * hd, bias=cfg.qkv_bias),
        "wo": dense_init(ko, H * hd, d),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd)
        p["k_norm"] = rmsnorm_init(hd)
    return p


def _project_qkv(p, x, positions, cfg: AttnConfig, shard: ShardHook):
    B, S, _ = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = dense(p["wq"], x).reshape(B, S, H, hd)
    k = dense(p["wk"], x).reshape(B, S, K, hd)
    v = dense(p["wv"], x).reshape(B, S, K, hd)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = shard(q, "act_heads")
    k = shard(k, "act_kv")
    v = shard(v, "act_kv")
    return q, k, v


def _expand_kv(k: jnp.ndarray, groups: int) -> jnp.ndarray:
    """[B, S, K, hd] -> [B, S, K*groups, hd] by repetition (GQA)."""
    return jnp.repeat(k, groups, axis=2)


# --------------------------------------------------------------- naive oracle

def attention_ref(q, k, v, *, causal: bool = True, window: Optional[int] = None,
                  q_offset: int = 0):
    """q [B,Sq,H,hd], k/v [B,Sk,K,hd]. Materializes full scores (tests only)."""
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    kx = _expand_kv(k, H // K)
    vx = _expand_kv(v, H // K)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kx.astype(jnp.float32))
    scores = scores / jnp.sqrt(jnp.float32(hd))
    qpos = jnp.arange(Sq)[:, None] + q_offset
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, vx.astype(jnp.float32))
    return out.astype(q.dtype)


# ------------------------------------------------------ chunked online softmax

def attention_chunked(q, k, v, *, causal: bool = True,
                      window: Optional[int] = None, chunk: int = 512):
    """Flash-style online softmax over KV chunks (pure jnp + lax.scan).

    Memory is O(Sq * chunk) per step instead of O(Sq * Sk).  Exactly equal to
    ``attention_ref`` up to float associativity.
    """
    B, Sq, H, hd = q.shape
    Sk, K = k.shape[1], k.shape[2]
    chunk = min(chunk, Sk)
    n_chunks = -(-Sk // chunk)
    pad = n_chunks * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    groups = H // K
    q32 = q.astype(jnp.float32) / jnp.sqrt(jnp.float32(hd))
    kc = k.reshape(B, n_chunks, chunk, K, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, K, hd).transpose(1, 0, 2, 3, 4)
    qpos = jnp.arange(Sq)[:, None]

    def step(carry, inp):
        m, l, acc = carry  # [B,H,Sq], [B,H,Sq], [B,Sq,H,hd]
        ci, kci, vci = inp
        kx = _expand_kv(kci, groups).astype(jnp.float32)  # [B,chunk,H,hd]
        vx = _expand_kv(vci, groups).astype(jnp.float32)
        s = jnp.einsum("bqhd,bkhd->bhqk", q32, kx)  # [B,H,Sq,chunk]
        kpos = ci * chunk + jnp.arange(chunk)[None, :]
        mask = kpos <= (Sk - 1)  # padding mask
        mask = jnp.broadcast_to(mask, (Sq, chunk))
        if causal:
            mask = mask & (kpos <= qpos)
        if window is not None:
            mask = mask & (kpos > qpos - window)
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        pweights = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + jnp.sum(pweights, axis=-1)
        pv = jnp.einsum("bhqk,bkhd->bqhd", pweights, vx)
        acc_new = acc * alpha.transpose(0, 2, 1)[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, Sq, H, hd), jnp.float32)
    # checkpoint the body: the backward recomputes score tiles per chunk
    # instead of saving [n_chunks, B, H, Sq, chunk] — flash-attention-style
    # O(Sq * chunk) memory in both passes.
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(step), (m0, l0, a0), (jnp.arange(n_chunks), kc, vc)
    )
    out = acc / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]
    return out.astype(q.dtype)


# ------------------------------------------------------------------- KV cache

def init_cache(batch: int, max_len: int, num_kv_heads: int, head_dim: int,
               dtype=jnp.bfloat16) -> Pytree:
    return {
        "k": jnp.zeros((batch, max_len, num_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, max_len, num_kv_heads, head_dim), dtype),
    }


def update_cache(cache: Pytree, k: jnp.ndarray, v: jnp.ndarray, index) -> Pytree:
    """Write [B, S_new, K, hd] at position ``index`` (traced scalar ok).

    Single-token decode uses a position-mask ``where`` instead of
    dynamic_update_slice: with the cache sequence-sharded over the ``model``
    axis, a dynamic-index update forces GSPMD into 'involuntary full
    rematerialization' (the whole cache replicated per device — measured at
    1.4 TB/device for qwen3 decode_32k, EXPERIMENTS §Perf iteration D1).
    The mask form is elementwise, so every shard updates locally.
    """
    if k.shape[1] == 1:
        pos = jnp.arange(cache["k"].shape[1])
        hit = (pos == index)[None, :, None, None]
        k_new = jnp.where(hit, k.astype(cache["k"].dtype), cache["k"])
        v_new = jnp.where(hit, v.astype(cache["v"].dtype), cache["v"])
        return {"k": k_new, "v": v_new}
    k_new = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                         (0, index, 0, 0))
    v_new = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                         (0, index, 0, 0))
    return {"k": k_new, "v": v_new}


def decode_attend(q, cache, length, *, window: Optional[int] = None):
    """Single(-few)-token attention against the cache.

    q: [B, 1, H, hd]; cache k/v: [B, Smax, K, hd]; ``length`` = #valid
    positions (the new token's position is length-1 after the cache update).
    Sliding window masks keys <= length-1-window.  Reads the full cache and
    masks — the Pallas flash_decode kernel and the window-slice optimization
    in §Perf avoid the wasted reads.
    """
    B, Sq, H, hd = q.shape
    K = cache["k"].shape[2]
    G = H // K
    qg = q.reshape(B, Sq, K, G, hd)
    # Grouped einsum: never materializes the GQA-expanded or upcast cache.
    s = jnp.einsum(
        "bqkgd,bskd->bkgqs", qg, cache["k"],
        preferred_element_type=jnp.float32,
    ) / jnp.sqrt(jnp.float32(hd))
    kpos = jnp.arange(cache["k"].shape[1])[None, :]
    qpos = (length - Sq) + jnp.arange(Sq)[:, None]
    mask = kpos <= qpos
    if window is not None:
        mask = mask & (kpos > qpos - window)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bkgqs,bskd->bqkgd", w.astype(cache["v"].dtype), cache["v"],
        preferred_element_type=jnp.float32,
    )
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


# ----------------------------------------------------------- full attn module

def attention_apply(
    p: Pytree,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    cfg: AttnConfig,
    *,
    cache: Optional[Pytree] = None,
    cache_index=None,
    shard: ShardHook = _id_hook,
    use_window: bool = False,
):
    """Self-attention block body.  Returns (out, new_cache)."""
    window = cfg.sliding_window if use_window else None
    q, k, v = _project_qkv(p, x, positions, cfg, shard)
    if cache is None:
        out = attention_chunked(q, k, v, causal=True, window=window,
                                chunk=cfg.chunk)
        new_cache = None
    else:
        cache = update_cache(cache, k, v, cache_index)
        length = cache_index + x.shape[1]
        out = decode_attend(q, cache, length, window=window)
        new_cache = cache
    B, S = x.shape[:2]
    out = out.reshape(B, S, cfg.num_heads * cfg.head_dim)
    out = dense(p["wo"], out)
    return shard(out, "act_resid"), new_cache

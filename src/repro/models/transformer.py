"""Block assembly: dense / MoE / SSM / hybrid layer kinds, composed in a
periodic pattern and executed with ``lax.scan`` over period groups.

Scanning over *groups* (one period of heterogeneous layers per group) keeps
the HLO size O(period) instead of O(num_layers) — required to compile 80-layer
configs on the CPU-hosted dry-run — while supporting mixed-kind stacks like
zamba2 (5×mamba + 1×mamba+shared-attention per period) and xLSTM (7×mLSTM +
1×sLSTM per period).  Weights for shared blocks (zamba2's attention) are
closure constants, not scanned.
"""

from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from .attention import (
    AttnConfig,
    attention_apply,
    attention_init,
    init_cache as attn_init_cache,
)
from .config import ModelConfig
from .layers import mlp, mlp_init, rmsnorm, rmsnorm_init
from .moe import MoEConfig, moe_apply, moe_init
from .ssm import (
    Mamba2Config, MLSTMConfig, SLSTMConfig,
    mamba2_apply, mamba2_init, mamba2_init_state,
    mlstm_apply, mlstm_init, mlstm_init_state,
    slstm_apply, slstm_init, slstm_init_state,
)

Pytree = Any
ShardHook = Callable[[jnp.ndarray, str], jnp.ndarray]
_id_hook: ShardHook = lambda x, name: x


def attn_cfg(cfg: ModelConfig) -> AttnConfig:
    return AttnConfig(
        d_model=cfg.d_model, num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads, head_dim=cfg.hd,
        qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm,
        rope_theta=cfg.rope_theta, sliding_window=cfg.sliding_window,
        chunk=cfg.attn_chunk,
    )


def moe_cfg(cfg: ModelConfig) -> MoEConfig:
    return MoEConfig(
        d_model=cfg.d_model, num_experts=cfg.num_experts,
        experts_per_tok=cfg.experts_per_tok, d_ff=cfg.moe_d_ff,
        capacity_factor=cfg.capacity_factor,
        num_shared_experts=cfg.num_shared_experts,
    )


def mamba_cfg(cfg: ModelConfig) -> Mamba2Config:
    return Mamba2Config(d_model=cfg.d_model, d_state=cfg.ssm_state)


def mlstm_cfg(cfg: ModelConfig) -> MLSTMConfig:
    return MLSTMConfig(d_model=cfg.d_model, num_heads=cfg.num_heads)


def slstm_cfg(cfg: ModelConfig) -> SLSTMConfig:
    return SLSTMConfig(d_model=cfg.d_model, num_heads=cfg.num_heads)


# ------------------------------------------------------------------ one block

def block_init(key, kind: str, cfg: ModelConfig) -> Pytree:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d = cfg.d_model
    if kind == "attn":
        d_ff = cfg.dense_d_ff or cfg.d_ff
        return {
            "ln1": rmsnorm_init(d), "attn": attention_init(k1, attn_cfg(cfg)),
            "ln2": rmsnorm_init(d),
            "mlp": mlp_init(k2, d, d_ff, gated=cfg.mlp_gated),
        }
    if kind == "moe":
        return {
            "ln1": rmsnorm_init(d), "attn": attention_init(k1, attn_cfg(cfg)),
            "ln2": rmsnorm_init(d), "moe": moe_init(k2, moe_cfg(cfg)),
        }
    if kind == "mamba":
        return {"ln1": rmsnorm_init(d), "mamba": mamba2_init(k1, mamba_cfg(cfg))}
    if kind == "mamba_shared_attn":
        # shared attention/MLP weights are NOT here (passed separately, reused
        # at every occurrence — zamba2's shared transformer block); this block
        # owns only its mamba and norms.
        return {
            "ln1": rmsnorm_init(d), "mamba": mamba2_init(k1, mamba_cfg(cfg)),
            "ln2": rmsnorm_init(d), "ln3": rmsnorm_init(d),
        }
    if kind == "mlstm":
        return {"ln1": rmsnorm_init(d), "mlstm": mlstm_init(k1, mlstm_cfg(cfg))}
    if kind == "slstm":
        return {"ln1": rmsnorm_init(d), "slstm": slstm_init(k1, slstm_cfg(cfg))}
    raise ValueError(f"unknown block kind {kind!r}")


def make_block_cache(kind: str, cfg: ModelConfig, batch: int, max_len: int,
                     dtype=jnp.bfloat16) -> Pytree:
    if kind in ("attn", "moe"):
        return attn_init_cache(batch, max_len, cfg.num_kv_heads, cfg.hd, dtype)
    if kind == "mamba":
        return mamba2_init_state(batch, mamba_cfg(cfg), jnp.float32)
    if kind == "mamba_shared_attn":
        return {
            "mamba": mamba2_init_state(batch, mamba_cfg(cfg), jnp.float32),
            "attn": attn_init_cache(batch, max_len, cfg.num_kv_heads, cfg.hd, dtype),
        }
    if kind == "mlstm":
        return mlstm_init_state(batch, mlstm_cfg(cfg), jnp.float32)
    if kind == "slstm":
        return slstm_init_state(batch, slstm_cfg(cfg), jnp.float32)
    raise ValueError(kind)


def block_apply(
    params: Pytree,
    kind: str,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    cfg: ModelConfig,
    *,
    shared_attn: Optional[Pytree] = None,
    cache: Optional[Pytree] = None,
    cache_index=None,
    shard: ShardHook = _id_hook,
    use_window: bool = False,
):
    """Residual block.  Returns (x, new_cache)."""
    acfg = attn_cfg(cfg)
    if kind in ("attn", "moe"):
        h, new_cache = attention_apply(
            params["attn"], rmsnorm(params["ln1"], x, cfg.norm_eps), positions,
            acfg, cache=cache, cache_index=cache_index, shard=shard,
            use_window=use_window,
        )
        x = x + h
        if kind == "attn":
            x = x + mlp(params["mlp"], rmsnorm(params["ln2"], x, cfg.norm_eps))
            return x, new_cache, jnp.zeros((), jnp.float32)
        y, aux = moe_apply(params["moe"], rmsnorm(params["ln2"], x, cfg.norm_eps),
                           moe_cfg(cfg))
        return x + y, new_cache, aux

    zero_aux = jnp.zeros((), jnp.float32)
    if kind == "mamba":
        h, st = mamba2_apply(params["mamba"], rmsnorm(params["ln1"], x, cfg.norm_eps),
                             mamba_cfg(cfg), init_state=cache)
        return x + h, st, zero_aux
    if kind == "mamba_shared_attn":
        mcache = cache["mamba"] if cache is not None else None
        acache = cache["attn"] if cache is not None else None
        h, mst = mamba2_apply(params["mamba"], rmsnorm(params["ln1"], x, cfg.norm_eps),
                              mamba_cfg(cfg), init_state=mcache)
        x = x + h
        h2, ast = attention_apply(
            shared_attn["attn"], rmsnorm(params["ln2"], x, cfg.norm_eps), positions,
            acfg, cache=acache, cache_index=cache_index, shard=shard,
            use_window=use_window,
        )
        x = x + h2
        x = x + mlp(shared_attn["mlp"], rmsnorm(params["ln3"], x, cfg.norm_eps))
        new_cache = {"mamba": mst, "attn": ast} if cache is not None else None
        return x, new_cache, zero_aux
    if kind == "mlstm":
        h, st = mlstm_apply(params["mlstm"], rmsnorm(params["ln1"], x, cfg.norm_eps),
                            mlstm_cfg(cfg), init_state=cache)
        return x + h, st, zero_aux
    if kind == "slstm":
        h, st = slstm_apply(params["slstm"], rmsnorm(params["ln1"], x, cfg.norm_eps),
                            slstm_cfg(cfg), init_state=cache)
        return x + h, st, zero_aux
    raise ValueError(kind)


# ------------------------------------------------------------------ the stack

def stack_init(key, cfg: ModelConfig) -> Pytree:
    """Stacked parameters: for each period position, leaves have a leading
    [n_groups] dim; prefix layers and shared blocks are unstacked."""
    params: dict = {"prefix": [], "groups": [], "shared_attn": None}
    keys = jax.random.split(key, 2 + len(cfg.prefix_layers) + cfg.period)
    ki = 0
    for kind in cfg.prefix_layers:
        params["prefix"].append(block_init(keys[ki], kind, cfg))
        ki += 1
    for pi, kind in enumerate(cfg.block_pattern):
        gkeys = jax.random.split(keys[ki], cfg.n_groups)
        stacked = jax.vmap(lambda k: block_init(k, kind, cfg))(gkeys)
        params["groups"].append(stacked)
        ki += 1
    if "mamba_shared_attn" in cfg.block_pattern:
        ka, km = jax.random.split(keys[ki])
        params["shared_attn"] = {
            "attn": attention_init(ka, attn_cfg(cfg)),
            "mlp": mlp_init(km, cfg.d_model, cfg.d_ff),
        }
    return params


def stack_caches(cfg: ModelConfig, batch: int, max_len: int,
                 dtype=jnp.bfloat16) -> Pytree:
    """Cache pytree matching stack_init's structure."""
    caches: dict = {"prefix": [], "groups": []}
    for kind in cfg.prefix_layers:
        caches["prefix"].append(make_block_cache(kind, cfg, batch, max_len, dtype))
    for kind in cfg.block_pattern:
        one = make_block_cache(kind, cfg, batch, max_len, dtype)
        stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (cfg.n_groups,) + x.shape), one
        )
        caches["groups"].append(stacked)
    return caches


def stack_apply(
    params: Pytree,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    cfg: ModelConfig,
    *,
    caches: Optional[Pytree] = None,
    cache_index=None,
    shard: ShardHook = _id_hook,
    use_window: bool = False,
):
    """Run the full layer stack.  Returns (x, new_caches, aux_loss)."""
    aux_total = jnp.zeros((), jnp.float32)
    new_prefix = []
    for i, kind in enumerate(cfg.prefix_layers):
        c = caches["prefix"][i] if caches is not None else None
        x, nc, aux = block_apply(
            params["prefix"][i], kind, x, positions, cfg,
            shared_attn=params["shared_attn"], cache=c, cache_index=cache_index,
            shard=shard, use_window=use_window,
        )
        new_prefix.append(nc)
        aux_total = aux_total + aux

    shared = params["shared_attn"]

    def group_fn(x, group_params, group_caches):
        aux_g = jnp.zeros((), jnp.float32)
        new_caches = []
        for pi, kind in enumerate(cfg.block_pattern):
            c = group_caches[pi] if group_caches is not None else None
            x, nc, aux = block_apply(
                group_params[pi], kind, x, positions, cfg,
                shared_attn=shared, cache=c, cache_index=cache_index,
                shard=shard, use_window=use_window,
            )
            new_caches.append(nc)
            aux_g = aux_g + aux
        return x, new_caches, aux_g

    if cfg.remat:
        group_fn = jax.checkpoint(group_fn, static_argnums=())

    if cfg.scan_layers:
        if caches is None:
            def scan_body_nc(carry, gp):
                xc, aux = carry
                xc, _, aux_g = group_fn(xc, gp, None)
                return (xc, aux + aux_g), None

            (x, aux_total), _ = jax.lax.scan(
                scan_body_nc, (x, aux_total), params["groups"]
            )
            new_groups = None
        else:
            def scan_body(carry, scanned):
                xc, aux = carry
                gp, gc = scanned
                xc, nc, aux_g = group_fn(xc, gp, gc)
                return (xc, aux + aux_g), nc

            (x, aux_total), new_groups = jax.lax.scan(
                scan_body, (x, aux_total), (params["groups"], caches["groups"])
            )
    else:
        new_groups = [] if caches is not None else None
        for g in range(cfg.n_groups):
            gp = jax.tree.map(lambda a: a[g], params["groups"])
            gc = (
                jax.tree.map(lambda a: a[g], caches["groups"])
                if caches is not None else None
            )
            x, nc, aux_g = group_fn(x, gp, gc)
            aux_total = aux_total + aux_g
            if caches is not None:
                new_groups.append(nc)
        if caches is not None:
            new_groups = jax.tree.map(lambda *xs: jnp.stack(xs), *new_groups)

    new_caches = (
        {"prefix": new_prefix, "groups": new_groups} if caches is not None else None
    )
    return x, new_caches, aux_total

"""Modality-frontend stubs (the one sanctioned carve-out).

For [vlm] and [audio] architectures the assignment specifies the transformer
backbone only; the vision encoder (ViT/SigLIP + anyres tiling) and the audio
codec (EnCodec) are stubbed: ``input_specs()`` provides precomputed patch /
conditioning embeddings of the right shape, and the model owns only the
projector into d_model.  The stub generators below produce deterministic
pseudo-embeddings for smoke tests and examples.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig


def prefix_embedding_shape(cfg: ModelConfig, batch: int) -> tuple:
    return (batch, cfg.num_prefix_tokens, cfg.frontend_dim)


def make_prefix_embeddings(key, cfg: ModelConfig, batch: int,
                           dtype=jnp.float32) -> jnp.ndarray:
    """Deterministic stand-in for frontend outputs (smoke tests/examples).

    vision: SigLIP-style patch embeddings for anyres tiles (llava-next).
    audio:  conditioning-frame embeddings (musicgen text/melody prefix).
    """
    if not cfg.frontend:
        raise ValueError(f"{cfg.name} has no frontend")
    shape = prefix_embedding_shape(cfg, batch)
    return jax.random.normal(key, shape, dtype) * 0.02


def token_shape(cfg: ModelConfig, batch: int, seq_len: int) -> tuple:
    """Shape of the token ids consumed by the backbone for a *total*
    sequence length ``seq_len`` (prefix tokens are embeddings, not ids)."""
    s_text = seq_len - cfg.num_prefix_tokens
    assert s_text > 0, f"{cfg.name}: seq {seq_len} <= prefix {cfg.num_prefix_tokens}"
    if cfg.num_codebooks > 1:
        return (batch, s_text, cfg.num_codebooks)
    return (batch, s_text)

"""Model substrate: composable pure-JAX definitions for all assigned
architecture families (dense / MoE / SSM / hybrid / VLM / audio backbones)."""

from .config import ModelConfig
from .model import (
    decode_step,
    forward,
    init_decode_caches,
    lm_init,
    loss_fn,
    param_count,
    prefill,
)

__all__ = [
    "ModelConfig", "lm_init", "forward", "loss_fn", "prefill", "decode_step",
    "init_decode_caches", "param_count",
]

"""Mixture-of-Experts FFN: token-choice top-k routing with capacity,
scatter/gather dispatch, load-balance auxiliary loss.

Dispatch is scatter-based (positions-in-expert via cumsum over slot one-hots)
rather than the O(T*E*C) one-hot-einsum formulation — at 384 experts
(kimi-k2) the dense dispatch tensor would not fit HBM.  Experts are stacked on
a leading dim that shards over the ``model`` mesh axis (expert parallelism);
the scatter/gather across token (data) and expert (model) shardings is what
XLA lowers to all-to-all — the MoE collective term in §Roofline.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from .layers import dense, dense_init

Pytree = Any


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    num_experts: int
    experts_per_tok: int
    d_ff: int              # per-expert hidden dim
    capacity_factor: float = 1.25
    num_shared_experts: int = 0
    aux_coef: float = 0.01


def moe_init(key, cfg: MoEConfig) -> Pytree:
    kr, ku, kg, kd, ks = jax.random.split(key, 5)
    E, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    scale = 1.0 / jnp.sqrt(d)

    def stack(k, shape, sc):
        return jax.random.normal(k, shape, jnp.float32) * sc

    p = {
        "router": dense_init(kr, d, E, scale=0.02),
        "wup": stack(ku, (E, d, f), scale),
        "wgate": stack(kg, (E, d, f), scale),
        "wdown": stack(kd, (E, f, d), 1.0 / jnp.sqrt(f)),
    }
    if cfg.num_shared_experts:
        p["shared"] = {
            "up": dense_init(jax.random.fold_in(ks, 0), d, f * cfg.num_shared_experts),
            "gate": dense_init(jax.random.fold_in(ks, 1), d, f * cfg.num_shared_experts),
            "down": dense_init(jax.random.fold_in(ks, 2), f * cfg.num_shared_experts, d),
        }
    return p


def moe_apply(p: Pytree, x: jnp.ndarray, cfg: MoEConfig):
    """x: [B, S, d].  Returns (y, aux_loss)."""
    B, S, d = x.shape
    T = B * S
    E, k = cfg.num_experts, cfg.experts_per_tok
    xf = x.reshape(T, d)

    logits = dense(p["router"], xf.astype(jnp.float32))  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, topi = jax.lax.top_k(probs, k)  # [T, k]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    # Load-balance auxiliary loss (Switch-style): E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=0)  # router prob mass per expert
    ce = jnp.zeros((E,), jnp.float32)

    capacity = max(1, int(cfg.capacity_factor * T * k / E))

    # Position of each (token, slot) within its expert's capacity buffer.
    pos_list, keep_list, oh_sum = [], [], jnp.zeros((T, E), jnp.float32)
    for j in range(k):
        oh = jax.nn.one_hot(topi[:, j], E, dtype=jnp.float32)  # [T, E]
        prior = jnp.sum(oh_sum, axis=0, keepdims=True)  # tokens already placed
        pos_in_e = jnp.cumsum(oh, axis=0) - 1.0 + prior  # [T, E]
        pos = jnp.sum(oh * pos_in_e, axis=-1)  # [T]
        keep = pos < capacity
        pos_list.append(pos.astype(jnp.int32))
        keep_list.append(keep)
        oh_sum = oh_sum + oh
        ce = ce + jnp.mean(oh, axis=0)
    aux = cfg.aux_coef * E * jnp.sum((ce / k) * me)

    # Scatter tokens into per-expert buffers [E, C, d].
    buf = jnp.zeros((E, capacity, d), x.dtype)
    for j in range(k):
        contrib = jnp.where(keep_list[j][:, None], xf, 0)
        buf = buf.at[topi[:, j], pos_list[j]].add(contrib, mode="drop")

    # Expert FFN (SwiGLU), batched over experts.
    h = jnp.einsum("ecd,edf->ecf", buf, p["wup"].astype(buf.dtype))
    g = jnp.einsum("ecd,edf->ecf", buf, p["wgate"].astype(buf.dtype))
    h = jax.nn.silu(g) * h
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["wdown"].astype(h.dtype))

    # Gather back and combine with gates.
    y = jnp.zeros((T, d), jnp.float32)
    for j in range(k):
        picked = out_buf[topi[:, j], pos_list[j]]  # [T, d]
        w = jnp.where(keep_list[j], gate_vals[:, j], 0.0)
        y = y + w[:, None] * picked.astype(jnp.float32)

    if "shared" in p:
        sh = p["shared"]
        hs = jax.nn.silu(dense(sh["gate"], xf)) * dense(sh["up"], xf)
        y = y + dense(sh["down"], hs).astype(jnp.float32)

    return y.reshape(B, S, d).astype(x.dtype), aux

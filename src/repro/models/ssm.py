"""State-space / recurrent sequence mixers: Mamba2 (SSD) and xLSTM.

* Mamba2 uses the chunkwise-parallel SSD form (matmul-rich intra-chunk +
  ``lax.scan`` carrying the inter-chunk state) — TPU-friendly: the quadratic
  intra-chunk part maps to the MXU, the scan carries only [B,H,P,N] state.
* xLSTM's mLSTM (matrix memory) and sLSTM (scalar memory, recurrent gates) use
  exact per-step ``lax.scan`` recurrences with log-space gate stabilization.

Each mixer exposes ``*_init``, ``*_apply`` (full sequence, returns final state)
and ``*_step`` (single-token decode against a state cache), so decode shapes
(`decode_32k`, `long_500k`) run with O(state) memory — the sub-quadratic path
required for long-context decode.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from .layers import dense, dense_init, rmsnorm, rmsnorm_init

Pytree = Any


# =============================================================== Mamba2 (SSD)

@dataclasses.dataclass(frozen=True)
class Mamba2Config:
    d_model: int
    d_state: int = 64          # N
    head_dim: int = 64         # P
    expand: int = 2
    conv_width: int = 4
    chunk: int = 128

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def num_heads(self) -> int:
        return self.d_inner // self.head_dim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.d_state


def mamba2_init(key, cfg: Mamba2Config) -> Pytree:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    di, N, H = cfg.d_inner, cfg.d_state, cfg.num_heads
    in_dim = 2 * di + 2 * N + H  # z, x, B, C, dt
    return {
        "in_proj": dense_init(k1, cfg.d_model, in_dim),
        "conv": jax.random.normal(k2, (cfg.conv_width, cfg.conv_dim), jnp.float32)
        * (1.0 / jnp.sqrt(cfg.conv_width)),
        "conv_bias": jnp.zeros((cfg.conv_dim,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": rmsnorm_init(di),
        "out_proj": dense_init(k3, di, cfg.d_model),
    }


def _split_in_proj(zxbcdt, cfg: Mamba2Config):
    di, N, H = cfg.d_inner, cfg.d_state, cfg.num_heads
    z = zxbcdt[..., :di]
    xin = zxbcdt[..., di : 2 * di]
    B_ = zxbcdt[..., 2 * di : 2 * di + N]
    C_ = zxbcdt[..., 2 * di + N : 2 * di + 2 * N]
    dt = zxbcdt[..., 2 * di + 2 * N :]
    return z, xin, B_, C_, dt


def _causal_conv(x, kernel, bias):
    """Depthwise causal conv. x: [B,S,C]; kernel: [W,C]."""
    W = kernel.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(
        xp[:, i : i + x.shape[1], :] * kernel[i].astype(x.dtype) for i in range(W)
    )
    return jax.nn.silu(out + bias.astype(x.dtype))


def mamba2_apply(p, x, cfg: Mamba2Config, *, init_state: Optional[Pytree] = None):
    """x: [B,S,d]. Returns (y [B,S,d], final_state {conv, ssm})."""
    B, S, _ = x.shape
    H, P, N, Q = cfg.num_heads, cfg.head_dim, cfg.d_state, cfg.chunk
    zxbcdt = dense(p["in_proj"], x)
    z, xin, B_, C_, dt_raw = _split_in_proj(zxbcdt, cfg)

    conv_in = jnp.concatenate([xin, B_, C_], axis=-1)
    if init_state is not None:
        conv_in_full = jnp.concatenate([init_state["conv"].astype(conv_in.dtype), conv_in], axis=1)
    else:
        conv_in_full = conv_in
    conv_out = _causal_conv(conv_in_full, p["conv"], p["conv_bias"])
    conv_out = conv_out[:, -S:]
    xin = conv_out[..., : cfg.d_inner]
    B_ = conv_out[..., cfg.d_inner : cfg.d_inner + N]
    C_ = conv_out[..., cfg.d_inner + N :]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # [B,S,H]
    A = -jnp.exp(p["A_log"])  # [H], negative
    dA = dt * A  # [B,S,H]

    xh = xin.reshape(B, S, H, P).astype(jnp.float32)
    B32, C32 = B_.astype(jnp.float32), C_.astype(jnp.float32)

    # pad to multiple of chunk
    nq = -(-S // Q)
    pad = nq * Q - S
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B32 = jnp.pad(B32, ((0, 0), (0, pad), (0, 0)))
        C32 = jnp.pad(C32, ((0, 0), (0, pad), (0, 0)))

    def chunkify(a):
        return a.reshape((B, nq, Q) + a.shape[2:]).transpose(1, 0, 2, *range(3, a.ndim + 1))

    xc, dAc, dtc = chunkify(xh), chunkify(dA), chunkify(dt)
    Bc, Cc = chunkify(B32), chunkify(C32)

    def chunk_step(h, inp):
        xq, dAq, dtq, Bq, Cq = inp  # [B,Q,...]
        cum = jnp.cumsum(dAq, axis=1)  # [B,Q,H]
        # intra-chunk quadratic part
        li = cum[:, :, None, :]  # i
        lj = cum[:, None, :, :]  # j
        tri = jnp.tril(jnp.ones((Q, Q), bool))
        decay = jnp.exp(jnp.where(tri[None, :, :, None], li - lj, -jnp.inf))
        cb = jnp.einsum("bin,bjn->bij", Cq, Bq)  # [B,Q,Q]
        scores = cb[..., None] * decay * dtq[:, None, :, :]  # [B,i,j,H]
        y_diag = jnp.einsum("bijh,bjhp->bihp", scores, xq)
        # inter-chunk: contribution of carried state
        y_inter = jnp.einsum("bin,bhpn,bih->bihp", Cq, h, jnp.exp(cum))
        # new state
        wj = jnp.exp(cum[:, -1:, :] - cum) * dtq  # [B,Q,H]
        dstate = jnp.einsum("bjh,bjhp,bjn->bhpn", wj, xq, Bq)
        h_new = jnp.exp(cum[:, -1, :])[:, :, None, None] * h + dstate
        return h_new, y_diag + y_inter

    h0 = (
        init_state["ssm"].astype(jnp.float32)
        if init_state is not None
        else jnp.zeros((B, H, P, N), jnp.float32)
    )
    h_final, yc = jax.lax.scan(jax.checkpoint(chunk_step), h0, (xc, dAc, dtc, Bc, Cc))
    y = yc.transpose(1, 0, 2, 3, 4).reshape(B, nq * Q, H, P)[:, :S]
    y = y + xh[:, :S] * p["D"][None, None, :, None]
    y = y.reshape(B, S, cfg.d_inner).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z))
    out = dense(p["out_proj"], y)
    conv_tail_src = conv_in_full
    conv_state = conv_tail_src[:, -(cfg.conv_width - 1):, :].astype(jnp.float32)
    state = {"conv": conv_state, "ssm": h_final}
    return out, state


def mamba2_init_state(batch: int, cfg: Mamba2Config, dtype=jnp.float32) -> Pytree:
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.conv_dim), dtype),
        "ssm": jnp.zeros((batch, cfg.num_heads, cfg.head_dim, cfg.d_state), dtype),
    }


def mamba2_step(p, x, state, cfg: Mamba2Config):
    """Single-token decode. x: [B,1,d]. Returns (y [B,1,d], new_state)."""
    y, new_state = mamba2_apply(p, x, cfg, init_state=state)
    return y, new_state


# ================================================================ xLSTM mLSTM

@dataclasses.dataclass(frozen=True)
class MLSTMConfig:
    d_model: int
    num_heads: int
    expand: int = 2
    conv_width: int = 4

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def head_dim(self) -> int:
        return self.d_inner // self.num_heads


def mlstm_init(key, cfg: MLSTMConfig) -> Pytree:
    ks = jax.random.split(key, 8)
    di, H, hd = cfg.d_inner, cfg.num_heads, cfg.head_dim

    def blockdiag(k):  # xLSTM's block-diagonal (per-head) q/k/v projections
        return jax.random.normal(k, (H, hd, hd), jnp.float32) / jnp.sqrt(hd)

    return {
        "up": dense_init(ks[0], cfg.d_model, 2 * di),
        "conv": jax.random.normal(ks[1], (cfg.conv_width, di), jnp.float32)
        * (1.0 / jnp.sqrt(cfg.conv_width)),
        "conv_bias": jnp.zeros((di,), jnp.float32),
        "wq": blockdiag(ks[2]),
        "wk": blockdiag(ks[3]),
        "wv": blockdiag(ks[4]),
        "wi": dense_init(ks[5], di, cfg.num_heads),
        "wf": dense_init(ks[6], di, cfg.num_heads),
        "norm": rmsnorm_init(di),
        "down": dense_init(ks[7], di, cfg.d_model),
    }


def _blockdiag_apply(w, x, H, hd):
    """x [B,S,di] -> per-head projection [B,S,H,hd]."""
    xh = x.reshape(x.shape[0], x.shape[1], H, hd)
    return jnp.einsum("bshd,hde->bshe", xh, w.astype(x.dtype))


def mlstm_init_state(batch: int, cfg: MLSTMConfig, dtype=jnp.float32) -> Pytree:
    H, hd = cfg.num_heads, cfg.head_dim
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.d_inner), dtype),
        "C": jnp.zeros((batch, H, hd, hd), dtype),
        "n": jnp.zeros((batch, H, hd), dtype),
        "m": jnp.full((batch, H), -1e30, dtype),
    }


def _mlstm_cell(carry, qkvif):
    """One recurrence step. Shapes per t: q,k,v [B,H,hd]; i,f [B,H]."""
    C, n, m = carry
    q, k, v, ig, fg = qkvif
    logf = jax.nn.log_sigmoid(fg)  # [B,H]
    m_new = jnp.maximum(logf + m, ig)
    i_p = jnp.exp(ig - m_new)[..., None]
    f_p = jnp.exp(logf + m - m_new)[..., None]
    n_new = f_p * n + i_p * k
    C_new = f_p[..., None] * C + i_p[..., None] * (v[..., :, None] * k[..., None, :])
    num = jnp.einsum("bhij,bhj->bhi", C_new, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhj,bhj->bh", n_new, q)), 1.0)
    h = num / den[..., None]
    return (C_new, n_new, m_new), h


def _mlstm_chunkwise(q, k, v, ig, fg, carry0, chunk: int):
    """Chunkwise-parallel mLSTM, exactly equal to the per-step recurrence.

    Per chunk with b_i = cumsum(logsigmoid(f)) and a_j = logi_j - b_j:
      m_i   = max(b_i + m_in, max_{j<=i}(b_i - b_j + logi_j))   (== per-step m)
      num_i = sum_{j<=i} e^{b_i-b_j+logi_j-m_i} (k_j.q_i) v_j
              + e^{b_i+m_in-m_i} C_in q_i
      den_i = same with k_j -> scalar and n_in
      h_i   = num_i / max(|den_i|, 1)
    Carries (C, n, m) are per *chunk*, which is what makes 4k-token training
    memory-feasible (the per-step form would save [B,H,hd,hd] per token for
    the backward pass).
    """
    B, S, H, hd = q.shape
    Q = min(chunk, S)
    nq = -(-S // Q)
    pad = nq * Q - S
    if pad:
        zf = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        q, k, v = zf(q), zf(k), zf(v)
        # pad steps must be no-ops on the carried state: i = -inf (inject
        # nothing), logsigmoid(f=30) ~= 0 (no decay, stabilizer unchanged).
        ig = jnp.pad(ig, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
        fg = jnp.pad(fg, ((0, 0), (0, pad), (0, 0)), constant_values=30.0)

    def chunkify(a):
        return a.reshape((B, nq, Q) + a.shape[2:]).transpose(
            1, 0, 2, *range(3, a.ndim + 1)
        )

    qc, kc, vc = chunkify(q), chunkify(k), chunkify(v)
    igc, fgc = chunkify(ig), chunkify(fg)

    def chunk_step(carry, inp):
        C_in, n_in, m_in = carry  # [B,H,hd,hd], [B,H,hd], [B,H]
        qq, kk, vv, ii, ff = inp  # [B,Q,...]
        logf = jax.nn.log_sigmoid(ff)  # [B,Q,H]
        b = jnp.cumsum(logf, axis=1)
        # pairwise decay: D_ij = b_i - b_j + logi_j for j <= i
        Dij = b[:, :, None, :] - b[:, None, :, :] + ii[:, None, :, :]
        tri = jnp.tril(jnp.ones((Q, Q), bool))
        Dij = jnp.where(tri[None, :, :, None], Dij, -jnp.inf)
        m_intra = jnp.max(Dij, axis=2)  # [B,Q,H]
        m_i = jnp.maximum(b + m_in[:, None, :], m_intra)
        w_intra = jnp.exp(Dij - m_i[:, :, None, :])  # [B,i,j,H]
        w_inter = jnp.exp(b + m_in[:, None, :] - m_i)  # [B,Q,H]
        qk = jnp.einsum("bihd,bjhd->bijh", qq, kk)
        num = jnp.einsum("bijh,bjhd->bihd", w_intra * qk, vv)
        num = num + jnp.einsum("bqh,bhij,bqhj->bqhi", w_inter, C_in, qq)
        den = jnp.einsum("bijh->bih", w_intra * qk)
        den = den + jnp.einsum("bqh,bhj,bqhj->bqh", w_inter, n_in, qq)
        h = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
        # end-of-chunk state
        bQ = b[:, -1, :]  # [B,H]
        m_out = jnp.maximum(
            bQ + m_in, jnp.max(bQ[:, None, :] - b + ii, axis=1)
        )
        w_state = jnp.exp(bQ[:, None, :] - b + ii - m_out[:, None, :])  # [B,Q,H]
        C_out = (
            jnp.exp(bQ + m_in - m_out)[:, :, None, None] * C_in
            + jnp.einsum("bjh,bjhi,bjhd->bhid", w_state, vv, kk)
        )
        n_out = (
            jnp.exp(bQ + m_in - m_out)[:, :, None] * n_in
            + jnp.einsum("bjh,bjhd->bhd", w_state, kk)
        )
        return (C_out, n_out, m_out), h

    (C, n, m), hs = jax.lax.scan(
        jax.checkpoint(chunk_step), carry0, (qc, kc, vc, igc, fgc)
    )
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, nq * Q, H, hd)[:, :S]
    return h, (C, n, m)


def mlstm_apply(p, x, cfg: MLSTMConfig, *, init_state: Optional[Pytree] = None,
                chunk: int = 256):
    B, S, _ = x.shape
    H, hd = cfg.num_heads, cfg.head_dim
    up = dense(p["up"], x)
    xi, z = jnp.split(up, 2, axis=-1)
    if init_state is not None:
        xi_full = jnp.concatenate([init_state["conv"].astype(xi.dtype), xi], axis=1)
    else:
        xi_full = xi
    xc = _causal_conv(xi_full, p["conv"], p["conv_bias"])[:, -S:]
    q = _blockdiag_apply(p["wq"], xc, H, hd).astype(jnp.float32)
    k = _blockdiag_apply(p["wk"], xc, H, hd).astype(jnp.float32) / jnp.sqrt(
        jnp.float32(hd)
    )
    v = _blockdiag_apply(p["wv"], xi, H, hd).astype(jnp.float32)
    ig = dense(p["wi"], xc).astype(jnp.float32)  # [B,S,H]
    fg = dense(p["wf"], xc).astype(jnp.float32)

    if init_state is not None:
        carry0 = (
            init_state["C"].astype(jnp.float32),
            init_state["n"].astype(jnp.float32),
            init_state["m"].astype(jnp.float32),
        )
    else:
        carry0 = (
            jnp.zeros((B, H, hd, hd), jnp.float32),
            jnp.zeros((B, H, hd), jnp.float32),
            jnp.full((B, H), -1e30, jnp.float32),
        )
    if S == 1:
        seq = (
            q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
            v.transpose(1, 0, 2, 3), ig.transpose(1, 0, 2),
            fg.transpose(1, 0, 2),
        )
        (C, n, m), hs = jax.lax.scan(_mlstm_cell, carry0, seq)
        h = hs.transpose(1, 0, 2, 3)
    else:
        h, (C, n, m) = _mlstm_chunkwise(q, k, v, ig, fg, carry0, chunk)
    h = h.reshape(B, S, cfg.d_inner).astype(x.dtype)
    h = rmsnorm(p["norm"], h) * jax.nn.silu(z)
    out = dense(p["down"], h)
    state = {
        "conv": xi_full[:, -(cfg.conv_width - 1):, :].astype(jnp.float32),
        "C": C, "n": n, "m": m,
    }
    return out, state


def mlstm_step(p, x, state, cfg: MLSTMConfig):
    return mlstm_apply(p, x, cfg, init_state=state)


# ================================================================ xLSTM sLSTM

@dataclasses.dataclass(frozen=True)
class SLSTMConfig:
    d_model: int
    num_heads: int

    @property
    def head_dim(self) -> int:
        return self.d_model // self.num_heads


def slstm_init(key, cfg: SLSTMConfig) -> Pytree:
    ks = jax.random.split(key, 10)
    d, H, hd = cfg.d_model, cfg.num_heads, cfg.head_dim
    ff = int(8 * d / 3 / 64) * 64 or 64

    def rec(k):  # block-diagonal (head-wise) recurrent weights
        return jax.random.normal(k, (H, hd, hd), jnp.float32) * (1.0 / jnp.sqrt(hd))

    return {
        "wi": dense_init(ks[0], d, d), "ri": rec(ks[1]),
        "wf": dense_init(ks[2], d, d), "rf": rec(ks[3]),
        "wz": dense_init(ks[4], d, d), "rz": rec(ks[5]),
        "wo": dense_init(ks[6], d, d), "ro": rec(ks[7]),
        "norm": rmsnorm_init(d),
        "ff_up": dense_init(ks[8], d, 2 * ff),
        "ff_down": dense_init(ks[9], ff, d),
    }


def slstm_init_state(batch: int, cfg: SLSTMConfig, dtype=jnp.float32) -> Pytree:
    d, H = cfg.d_model, cfg.num_heads
    return {
        "c": jnp.zeros((batch, d), dtype),
        "n": jnp.zeros((batch, d), dtype),
        "h": jnp.zeros((batch, d), dtype),
        "m": jnp.full((batch, H), -1e30, dtype),
    }


def _slstm_cell(p, cfg: SLSTMConfig, carry, gates_t):
    c, n, h, m = carry  # [B,d],[B,d],[B,d],[B,H]
    gi, gf, gz, go = gates_t  # each [B,d] (input contributions)
    B = c.shape[0]
    H, hd = cfg.num_heads, cfg.head_dim
    hh = h.reshape(B, H, hd)

    def recur(r, x):
        return jnp.einsum("bhi,hij->bhj", x, r).reshape(B, H * hd)

    i_raw = gi + recur(p["ri"], hh)
    f_raw = gf + recur(p["rf"], hh)
    z_raw = gz + recur(p["rz"], hh)
    o_raw = go + recur(p["ro"], hh)
    # per-head stabilizer (max over head units of log gates)
    logf = jax.nn.log_sigmoid(f_raw).reshape(B, H, hd)
    logi = i_raw.reshape(B, H, hd)
    m_new = jnp.maximum(
        jnp.max(logf, axis=-1) + m, jnp.max(logi, axis=-1)
    )  # [B,H]
    i_p = jnp.exp(logi - m_new[..., None]).reshape(B, H * hd)
    f_p = jnp.exp(logf + (m - m_new)[..., None]).reshape(B, H * hd)
    c_new = f_p * c + i_p * jnp.tanh(z_raw)
    n_new = f_p * n + i_p
    h_new = jax.nn.sigmoid(o_raw) * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new), h_new


def slstm_apply(p, x, cfg: SLSTMConfig, *, init_state: Optional[Pytree] = None):
    B, S, d = x.shape
    gi = dense(p["wi"], x).astype(jnp.float32)
    gf = dense(p["wf"], x).astype(jnp.float32)
    gz = dense(p["wz"], x).astype(jnp.float32)
    go = dense(p["wo"], x).astype(jnp.float32)
    if init_state is not None:
        carry0 = tuple(
            init_state[k].astype(jnp.float32) for k in ("c", "n", "h", "m")
        )
    else:
        z0 = jnp.zeros((B, d), jnp.float32)
        carry0 = (z0, z0, z0, jnp.full((B, cfg.num_heads), -1e30, jnp.float32))
    seq = tuple(a.transpose(1, 0, 2) for a in (gi, gf, gz, go))
    (c, n, h, m), hs = jax.lax.scan(
        lambda ca, g: _slstm_cell(p, cfg, ca, g), carry0, seq
    )
    y = hs.transpose(1, 0, 2).astype(x.dtype)
    y = rmsnorm(p["norm"], y)
    u, g = jnp.split(dense(p["ff_up"], y), 2, axis=-1)
    y = dense(p["ff_down"], jax.nn.silu(g) * u)
    state = {"c": c, "n": n, "h": h, "m": m}
    return y, state


def slstm_step(p, x, state, cfg: SLSTMConfig):
    return slstm_apply(p, x, cfg, init_state=state)

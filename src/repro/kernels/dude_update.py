"""Pallas TPU kernel: fused DuDe-ASGD server round on flat parameter tiles.

The server hot loop (paper Alg. 1 lines 4-6 + the semi-async variant) is a
pure streaming op over Theta(n * p) buffer state: per round it must
  commit:  g_bar += sum_i cm_i * (inflight_i - G~_i) / n ;  G~_i <- inflight_i
  latch:   inflight_i <- fresh_i  (where start_i)
  apply:   w <- w - eta * g^t      (plus optimizer slot streams, see below)
Arithmetic intensity is O(1) flops/byte => HBM-bandwidth-bound, so the win is
FUSION: one pass over the streams instead of the ~9 separate elementwise
HLO ops XLA emits, plus no intermediate materialization.

The apply is not limited to plain SGD: ``dude_round_apply_pallas`` streams
the optimizer slot slabs (momentum ``m``, AdamW ``{m, v}`` — flat ``[P]``
vectors in the same segment-range layout as ``g_bar``) through the same
single pass, computing the slot update and the parameter step tile-by-tile.
The optimizer math mirrors ``optim.transforms.FlatOptimizer.update``
op-for-op, so the fused path is bit-exact against the unfused flat apply.
AdamW's bias corrections depend only on the (replicated) step counter, so
the caller computes them once and passes two scalars in.

Grid: 1-D over tiles of the flattened parameter vector.  Each program
instance owns a [n_workers, TILE] slab of the stacked buffers and a [TILE]
slice of g_bar/params/slots in VMEM.  TILE defaults to 2048 lanes x 8
sublanes f32 = 64 KiB per stream — all streams resident fit easily in VMEM
while keeping the DMA pipeline deep.

Compressed slabs (``dude_round_apply_q_pallas``): when the engine's
``commit_format`` is ``int8_ef``/``topk_ef`` the worker slabs are stored as
int8 payloads + per-128-lane-tile f32 scale rows (``core/compression.py``).
The quantized kernel streams q-rows and scale rows through the same single
pass, dequantizing both slabs in VMEM, folding the commit delta in f32,
copying committed rows quantized (no re-quantization), and quantizing the
fresh latch rows in-kernel — cutting the dominant slab traffic ~4x.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = 16384  # f32 elements per program instance per stream row

# slot streams per optimizer kind: () | ("m",) | ("m", "v")
SLOT_STREAMS = {"sgd": 0, "momentum": 1, "adamw": 2}


def _opt_apply(g, w_ref, slot_refs, bc_ref, w_out, slot_outs,
               kind: str, hp: dict):
    """Fused optimizer tail shared by the f32 and quantized round kernels.

    Mirrors ``optim.transforms.FlatOptimizer.update`` op-for-op so the fused
    path stays bit-exact against the unfused flat apply.
    """
    w = w_ref[...]
    if kind == "sgd":
        w_out[...] = w - hp["lr"] * g
    elif kind == "momentum":
        (m_ref,) = slot_refs
        m = hp["beta"] * m_ref[...] + g
        d = hp["beta"] * m + g if hp["nesterov"] else m
        w_out[...] = w - hp["lr"] * d
        slot_outs[0][...] = m
    elif kind == "adamw":
        m_ref, v_ref = slot_refs
        b1, b2 = hp["b1"], hp["b2"]
        m = b1 * m_ref[...] + (1 - b1) * g
        v = b2 * v_ref[...] + (1 - b2) * jnp.square(g)
        bc = bc_ref[...]
        bc1, bc2 = bc[0], bc[1]
        step = (m / bc1) / (jnp.sqrt(v / bc2) + hp["eps"]) \
            + hp["weight_decay"] * w
        w_out[...] = w - hp["lr"] * step
        slot_outs[0][...] = m
        slot_outs[1][...] = v
    else:
        raise ValueError(f"unknown optimizer kind {kind!r}")


def _round_apply_kernel(*refs, n_workers: int, kind: str, hp: tuple):
    """One [*, TILE] tile: DuDe round + fused optimizer apply.

    refs layout (in): cm[n], sm[n], fresh[n,T], gw[n,T], infl[n,T], gbar[T],
    w[T], slots*[T], (bc[2] for adamw); (out): gw, infl, gbar, w, slots*.
    """
    hp = dict(hp)
    n_slots = SLOT_STREAMS[kind]
    n_in = 7 + n_slots + (1 if kind == "adamw" else 0)
    (cm_ref, sm_ref, fresh_ref, gw_ref, infl_ref, gbar_ref, w_ref,
     *rest_in) = refs[:n_in]
    gw_out, infl_out, gbar_out, w_out, *slot_outs = refs[n_in:]

    cm = cm_ref[...].astype(jnp.float32)  # [n]
    sm = sm_ref[...]                       # [n] bool
    fresh = fresh_ref[...].astype(jnp.float32)   # [n, T]
    gw = gw_ref[...].astype(jnp.float32)         # [n, T]
    infl = infl_ref[...].astype(jnp.float32)     # [n, T]
    gbar = gbar_ref[...]                          # [T] f32

    delta = cm[:, None] * (infl - gw)
    g = gbar + jnp.sum(delta, axis=0) / n_workers
    gw_new = jnp.where(cm[:, None] > 0, infl, gw)
    infl_new = jnp.where(sm[:, None], fresh, infl)

    gw_out[...] = gw_new.astype(gw_out.dtype)
    infl_out[...] = infl_new.astype(infl_out.dtype)
    gbar_out[...] = g

    slot_refs = rest_in[:n_slots]
    bc_ref = rest_in[n_slots] if kind == "adamw" else None
    _opt_apply(g, w_ref, slot_refs, bc_ref, w_out, slot_outs, kind, hp)


def _round_apply_q_kernel(*refs, n_workers: int, kind: str, hp: tuple,
                          fmt: str, topk: int):
    """Quantized-slab twin of ``_round_apply_kernel``.

    The ``[n, T]`` worker slabs arrive as int8 payloads plus per-128-lane-tile
    f32 scale rows ``[n, T/128]``; dequantization of both slabs and the int8
    latch quantization of the fresh rows are fused into the same single pass.
    Committed rows copy the *quantized* in-flight payload (q + scale) verbatim
    — no re-quantization — so the incremental invariant
    ``g_bar == mean_i dec(g_workers[i])`` is preserved exactly.  The codec
    math is the shared ``core.compression`` ops, so this kernel is
    bit-identical to the plain-jnp reference/indexed twins.

    refs layout (in): cm[n], sm[n], fresh[n,T], gw_q[n,T]i8, gw_s[n,T/128],
    in_q[n,T]i8, in_s[n,T/128], gbar[T], w[T], slots*[T], (bc[2] for adamw);
    (out): gw_q, gw_s, in_q, in_s, gbar, w, slots*.
    """
    from ..core.compression import dequantize, quantize, topk_mask

    hp = dict(hp)
    n_slots = SLOT_STREAMS[kind]
    n_in = 9 + n_slots + (1 if kind == "adamw" else 0)
    (cm_ref, sm_ref, fresh_ref, gwq_ref, gws_ref, inq_ref, ins_ref,
     gbar_ref, w_ref, *rest_in) = refs[:n_in]
    (gwq_out, gws_out, inq_out, ins_out, gbar_out, w_out,
     *slot_outs) = refs[n_in:]

    cm = cm_ref[...].astype(jnp.float32)  # [n]
    sm = sm_ref[...]                       # [n] bool
    fresh = fresh_ref[...].astype(jnp.float32)   # [n, T]
    gwq, gws = gwq_ref[...], gws_ref[...]
    inq, ins = inq_ref[...], ins_ref[...]
    gbar = gbar_ref[...]                          # [T] f32

    gw = dequantize(gwq, gws)
    infl = dequantize(inq, ins)
    delta = cm[:, None] * (infl - gw)
    g = gbar + jnp.sum(delta, axis=0) / n_workers

    commit = cm[:, None] > 0
    gwq_out[...] = jnp.where(commit, inq, gwq)
    gws_out[...] = jnp.where(commit, ins, gws)

    latch = topk_mask(fresh, topk) if fmt == "topk_ef" else fresh
    qf, sf = quantize(latch)
    inq_out[...] = jnp.where(sm[:, None], qf, inq)
    ins_out[...] = jnp.where(sm[:, None], sf, ins)
    gbar_out[...] = g

    slot_refs = rest_in[:n_slots]
    bc_ref = rest_in[n_slots] if kind == "adamw" else None
    _opt_apply(g, w_ref, slot_refs, bc_ref, w_out, slot_outs, kind, hp)


def dude_round_apply_pallas(
    commit_mask: jnp.ndarray,   # [n] bool
    start_mask: jnp.ndarray,    # [n] bool
    fresh: jnp.ndarray,         # [n, P] fresh gradients (live model)
    g_workers: jnp.ndarray,     # [n, P] buffer dtype
    inflight: jnp.ndarray,      # [n, P] buffer dtype
    g_bar: jnp.ndarray,         # [P] f32
    w: jnp.ndarray,             # [P] f32 flat master params
    slots: tuple = (),          # optimizer slot slabs, each [P] f32
    bias_corr: jnp.ndarray | None = None,  # [2] f32 (adamw only)
    *,
    kind: str = "sgd",
    hp: tuple = (("lr", 0.0),),
    tile: int = DEFAULT_TILE,
    interpret: bool = False,
):
    """Fused round + optimizer apply.  Returns
    ``(g_workers', inflight', g_bar', w', slots')``."""
    n, P = fresh.shape
    assert g_workers.shape == (n, P) and inflight.shape == (n, P)
    assert g_bar.shape == (P,) and w.shape == (P,)
    n_slots = SLOT_STREAMS[kind]
    assert len(slots) == n_slots, (kind, len(slots))
    assert all(s.shape == (P,) for s in slots)
    assert (bias_corr is not None) == (kind == "adamw")
    tile = min(tile, P)
    assert P % tile == 0, f"P={P} % tile={tile}"
    grid = (P // tile,)

    row = pl.BlockSpec((n, tile), lambda i: (0, i))
    vec = pl.BlockSpec((tile,), lambda i: (i,))
    mask = pl.BlockSpec((n,), lambda i: (0,))
    sc2 = pl.BlockSpec((2,), lambda i: (0,))

    in_specs = [mask, mask, row, row, row, vec, vec] + [vec] * n_slots
    args = [commit_mask.astype(jnp.float32), start_mask, fresh, g_workers,
            inflight, g_bar, w] + list(slots)
    if kind == "adamw":
        in_specs.append(sc2)
        args.append(bias_corr.astype(jnp.float32))

    kernel = functools.partial(_round_apply_kernel, n_workers=n, kind=kind,
                               hp=tuple(hp))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[row, row, vec, vec] + [vec] * n_slots,
        out_shape=[
            jax.ShapeDtypeStruct((n, P), g_workers.dtype),
            jax.ShapeDtypeStruct((n, P), inflight.dtype),
            jax.ShapeDtypeStruct((P,), jnp.float32),
            jax.ShapeDtypeStruct((P,), w.dtype),
        ] + [jax.ShapeDtypeStruct((P,), jnp.float32)] * n_slots,
        interpret=interpret,
    )(*args)
    gw_new, infl_new, gbar_new, w_new = out[:4]
    return gw_new, infl_new, gbar_new, w_new, tuple(out[4:])


def dude_round_apply_q_pallas(
    commit_mask: jnp.ndarray,   # [n] bool
    start_mask: jnp.ndarray,    # [n] bool
    fresh: jnp.ndarray,         # [n, P] f32 fresh gradients (live model)
    gw_q: jnp.ndarray,          # [n, P] int8 committed-gradient payload
    gw_scale: jnp.ndarray,      # [n, P/128] f32 per-tile scales
    in_q: jnp.ndarray,          # [n, P] int8 in-flight payload
    in_scale: jnp.ndarray,      # [n, P/128] f32
    g_bar: jnp.ndarray,         # [P] f32
    w: jnp.ndarray,             # [P] f32 flat master params
    slots: tuple = (),          # optimizer slot slabs, each [P] f32
    bias_corr: jnp.ndarray | None = None,  # [2] f32 (adamw only)
    *,
    kind: str = "sgd",
    hp: tuple = (("lr", 0.0),),
    fmt: str = "int8_ef",
    topk: int = 16,
    tile: int = DEFAULT_TILE,
    interpret: bool = False,
):
    """Fused round + apply over quantized slabs.  Returns
    ``(gw_q', gw_scale', in_q', in_scale', g_bar', w', slots')``.

    Streams the int8 q-rows and their f32 scale rows through the same 1-D
    tile grid as the f32 kernel; each program instance additionally owns a
    ``[n, tile/128]`` slice of both scale slabs.  ``tile`` must be a multiple
    of the 128-lane scale granularity (engine tiles always are).
    """
    from ..core.compression import TILE as QTILE

    n, P = fresh.shape
    t = P // QTILE
    assert gw_q.shape == (n, P) and in_q.shape == (n, P)
    assert gw_scale.shape == (n, t) and in_scale.shape == (n, t)
    assert g_bar.shape == (P,) and w.shape == (P,)
    n_slots = SLOT_STREAMS[kind]
    assert len(slots) == n_slots, (kind, len(slots))
    assert (bias_corr is not None) == (kind == "adamw")
    tile = min(tile, P)
    assert P % tile == 0 and tile % QTILE == 0, f"P={P} tile={tile}"
    grid = (P // tile,)

    row = pl.BlockSpec((n, tile), lambda i: (0, i))
    srow = pl.BlockSpec((n, tile // QTILE), lambda i: (0, i))
    vec = pl.BlockSpec((tile,), lambda i: (i,))
    mask = pl.BlockSpec((n,), lambda i: (0,))
    sc2 = pl.BlockSpec((2,), lambda i: (0,))

    in_specs = [mask, mask, row, row, srow, row, srow, vec, vec] \
        + [vec] * n_slots
    args = [commit_mask.astype(jnp.float32), start_mask,
            fresh.astype(jnp.float32), gw_q, gw_scale, in_q, in_scale,
            g_bar, w] + list(slots)
    if kind == "adamw":
        in_specs.append(sc2)
        args.append(bias_corr.astype(jnp.float32))

    kernel = functools.partial(_round_apply_q_kernel, n_workers=n, kind=kind,
                               hp=tuple(hp), fmt=fmt, topk=topk)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[row, srow, row, srow, vec, vec] + [vec] * n_slots,
        out_shape=[
            jax.ShapeDtypeStruct((n, P), jnp.int8),
            jax.ShapeDtypeStruct((n, t), jnp.float32),
            jax.ShapeDtypeStruct((n, P), jnp.int8),
            jax.ShapeDtypeStruct((n, t), jnp.float32),
            jax.ShapeDtypeStruct((P,), jnp.float32),
            jax.ShapeDtypeStruct((P,), w.dtype),
        ] + [jax.ShapeDtypeStruct((P,), jnp.float32)] * n_slots,
        interpret=interpret,
    )(*args)
    return out[0], out[1], out[2], out[3], out[4], out[5], tuple(out[6:])


def _round_apply_sparse_kernel(*refs, n_workers: int, kind: str, hp: tuple,
                               topk: int):
    """Touched-tile-gated twin of ``_round_apply_q_kernel`` (topk_ef only).

    A precomputed per-block activity flag (``blk``, from the engine's
    touched-tile bitmaps: does any committing row hold nonzero payload in
    any 128-lane tile of this block?) gates the expensive part — the dual
    dequantization and the commit fold — behind ``lax.cond``.  Inactive
    blocks pass ``g_bar`` and the committed payload through untouched, which
    is value-identical to the dense kernel: untouched tiles decode to exact
    +0.0 (and ``g_bar`` entries are never -0.0 — they are only ever produced
    by ``x + delta`` chains from a +0.0 init).  Everything whose result is
    NOT recoverable from the bitmaps stays dense: the fresh latch (arbitrary
    new values), the scale-row copies (stale scales are decode-invisible but
    not bitwise-invisible, and they are 1/128 of the payload), the bitmap
    updates, and the optimizer tail.

    refs layout (in): cm[n], sm[n], blk[1], fresh[n,T], gw_q[n,T]i8,
    gw_s[n,T/128], gw_t[n,T/128]i8, in_q[n,T]i8, in_s[n,T/128],
    in_t[n,T/128]i8, gbar[T], w[T], slots*[T], (bc[2] for adamw);
    (out): gw_q, gw_s, gw_t, in_q, in_s, in_t, gbar, w, slots*.
    """
    from ..core.compression import (
        dequantize, quantize, topk_mask, touched_tiles,
    )

    hp = dict(hp)
    n_slots = SLOT_STREAMS[kind]
    n_in = 12 + n_slots + (1 if kind == "adamw" else 0)
    (cm_ref, sm_ref, blk_ref, fresh_ref, gwq_ref, gws_ref, gwt_ref,
     inq_ref, ins_ref, int_ref, gbar_ref, w_ref, *rest_in) = refs[:n_in]
    (gwq_out, gws_out, gwt_out, inq_out, ins_out, int_out, gbar_out,
     w_out, *slot_outs) = refs[n_in:]

    cm = cm_ref[...].astype(jnp.float32)  # [n]
    sm = sm_ref[...]                       # [n] bool
    active = blk_ref[...][0] != 0
    fresh = fresh_ref[...].astype(jnp.float32)   # [n, T]
    gwq, gws, gwt = gwq_ref[...], gws_ref[...], gwt_ref[...]
    inq, ins, int_ = inq_ref[...], ins_ref[...], int_ref[...]
    gbar = gbar_ref[...]                          # [T] f32
    commit = cm[:, None] > 0

    def fold(_):
        gw = dequantize(gwq, gws)
        infl = dequantize(inq, ins)
        g = gbar + jnp.sum(cm[:, None] * (infl - gw), axis=0) / n_workers
        return g, jnp.where(commit, inq, gwq)

    def skip(_):
        return gbar, gwq

    g, gwq_new = jax.lax.cond(active, fold, skip, None)

    gwq_out[...] = gwq_new
    gws_out[...] = jnp.where(commit, ins, gws)
    gwt_out[...] = jnp.where(commit, int_, gwt)

    latch = topk_mask(fresh, topk)
    qf, sf = quantize(latch)
    inq_out[...] = jnp.where(sm[:, None], qf, inq)
    ins_out[...] = jnp.where(sm[:, None], sf, ins)
    int_out[...] = jnp.where(sm[:, None],
                             touched_tiles(qf).astype(int_.dtype), int_)
    gbar_out[...] = g

    slot_refs = rest_in[:n_slots]
    bc_ref = rest_in[n_slots] if kind == "adamw" else None
    _opt_apply(g, w_ref, slot_refs, bc_ref, w_out, slot_outs, kind, hp)


def dude_round_apply_sparse_pallas(
    commit_mask: jnp.ndarray,   # [n] bool
    start_mask: jnp.ndarray,    # [n] bool
    blk: jnp.ndarray,           # [P/tile] i32 per-block commit activity
    fresh: jnp.ndarray,         # [n, P] f32 fresh gradients (live model)
    gw_q: jnp.ndarray,          # [n, P] int8 committed-gradient payload
    gw_scale: jnp.ndarray,      # [n, P/128] f32 per-tile scales
    gw_touched: jnp.ndarray,    # [n, P/128] int8 touched-tile bitmap
    in_q: jnp.ndarray,          # [n, P] int8 in-flight payload
    in_scale: jnp.ndarray,      # [n, P/128] f32
    in_touched: jnp.ndarray,    # [n, P/128] int8
    g_bar: jnp.ndarray,         # [P] f32
    w: jnp.ndarray,             # [P] f32 flat master params
    slots: tuple = (),          # optimizer slot slabs, each [P] f32
    bias_corr: jnp.ndarray | None = None,  # [2] f32 (adamw only)
    *,
    kind: str = "sgd",
    hp: tuple = (("lr", 0.0),),
    topk: int = 16,
    tile: int = DEFAULT_TILE,
    interpret: bool = False,
):
    """Fused round + apply over quantized slabs, folding ONLY the blocks a
    committing row touches (``topk_ef`` + touched-tile bitmaps).  Returns
    ``(gw_q', gw_scale', gw_touched', in_q', in_scale', in_touched',
    g_bar', w', slots')`` — bit-for-bit ``dude_round_apply_q_pallas`` with
    ``fmt="topk_ef"`` on the shared streams."""
    from ..core.compression import TILE as QTILE

    n, P = fresh.shape
    t = P // QTILE
    assert gw_q.shape == (n, P) and in_q.shape == (n, P)
    assert gw_scale.shape == (n, t) and in_scale.shape == (n, t)
    assert gw_touched.shape == (n, t) and in_touched.shape == (n, t)
    assert g_bar.shape == (P,) and w.shape == (P,)
    n_slots = SLOT_STREAMS[kind]
    assert len(slots) == n_slots, (kind, len(slots))
    assert (bias_corr is not None) == (kind == "adamw")
    tile = min(tile, P)
    assert P % tile == 0 and tile % QTILE == 0, f"P={P} tile={tile}"
    grid = (P // tile,)
    assert blk.shape == (P // tile,), (blk.shape, grid)

    row = pl.BlockSpec((n, tile), lambda i: (0, i))
    srow = pl.BlockSpec((n, tile // QTILE), lambda i: (0, i))
    vec = pl.BlockSpec((tile,), lambda i: (i,))
    mask = pl.BlockSpec((n,), lambda i: (0,))
    one = pl.BlockSpec((1,), lambda i: (i,))
    sc2 = pl.BlockSpec((2,), lambda i: (0,))

    in_specs = [mask, mask, one, row, row, srow, srow, row, srow, srow,
                vec, vec] + [vec] * n_slots
    args = [commit_mask.astype(jnp.float32), start_mask,
            blk.astype(jnp.int32), fresh.astype(jnp.float32),
            gw_q, gw_scale, gw_touched, in_q, in_scale, in_touched,
            g_bar, w] + list(slots)
    if kind == "adamw":
        in_specs.append(sc2)
        args.append(bias_corr.astype(jnp.float32))

    kernel = functools.partial(_round_apply_sparse_kernel, n_workers=n,
                               kind=kind, hp=tuple(hp), topk=topk)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[row, srow, srow, row, srow, srow, vec, vec]
        + [vec] * n_slots,
        out_shape=[
            jax.ShapeDtypeStruct((n, P), jnp.int8),
            jax.ShapeDtypeStruct((n, t), jnp.float32),
            jax.ShapeDtypeStruct((n, t), gw_touched.dtype),
            jax.ShapeDtypeStruct((n, P), jnp.int8),
            jax.ShapeDtypeStruct((n, t), jnp.float32),
            jax.ShapeDtypeStruct((n, t), in_touched.dtype),
            jax.ShapeDtypeStruct((P,), jnp.float32),
            jax.ShapeDtypeStruct((P,), w.dtype),
        ] + [jax.ShapeDtypeStruct((P,), jnp.float32)] * n_slots,
        interpret=interpret,
    )(*args)
    return (out[0], out[1], out[2], out[3], out[4], out[5], out[6], out[7],
            tuple(out[8:]))


def dude_update_pallas(
    commit_mask: jnp.ndarray,   # [n] bool
    start_mask: jnp.ndarray,    # [n] bool
    fresh: jnp.ndarray,         # [n, P] fresh gradients (live model)
    g_workers: jnp.ndarray,     # [n, P] buffer dtype
    inflight: jnp.ndarray,      # [n, P] buffer dtype
    g_bar: jnp.ndarray,         # [P] f32
    w: jnp.ndarray,             # [P] f32 params
    *,
    eta: float,
    tile: int = DEFAULT_TILE,
    interpret: bool = False,
):
    """Historical fold-in-SGD entry point; the ``kind="sgd"`` case of
    ``dude_round_apply_pallas``.  Returns (g_workers', inflight', g_bar', w')."""
    gw, infl, gbar, w_new, _ = dude_round_apply_pallas(
        commit_mask, start_mask, fresh, g_workers, inflight, g_bar, w,
        kind="sgd", hp=(("lr", eta),), tile=tile, interpret=interpret,
    )
    return gw, infl, gbar, w_new

"""Pallas TPU kernel: fused DuDe-ASGD server round on flat parameter tiles.

The server hot loop (paper Alg. 1 lines 4-6 + the semi-async variant) is a
pure streaming op over Theta(n * p) buffer state: per round it must
  commit:  g_bar += sum_i cm_i * (inflight_i - G~_i) / n ;  G~_i <- inflight_i
  latch:   inflight_i <- fresh_i  (where start_i)
  apply:   w <- w - eta * g_bar
Arithmetic intensity is O(1) flops/byte => HBM-bandwidth-bound, so the win is
FUSION: one pass over the five streams instead of the ~9 separate elementwise
HLO ops XLA emits, plus no intermediate materialization.

Grid: 1-D over tiles of the flattened parameter vector.  Each program
instance owns a [n_workers, TILE] slab of the stacked buffers and a [TILE]
slice of g_bar/params in VMEM.  TILE defaults to 2048 lanes x 8 sublanes
f32 = 64 KiB per stream — five streams resident fit easily in 128 MiB VMEM
while keeping the DMA pipeline deep.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = 16384  # f32 elements per program instance per stream row


def _dude_kernel(cm_ref, sm_ref, fresh_ref, gw_ref, infl_ref, gbar_ref,
                 w_ref, gw_out, infl_out, gbar_out, w_out, *, n_workers: int,
                 eta: float):
    cm = cm_ref[...].astype(jnp.float32)  # [n]
    sm = sm_ref[...]                       # [n] bool
    fresh = fresh_ref[...].astype(jnp.float32)   # [n, T]
    gw = gw_ref[...].astype(jnp.float32)         # [n, T]
    infl = infl_ref[...].astype(jnp.float32)     # [n, T]
    gbar = gbar_ref[...]                          # [T] f32

    delta = cm[:, None] * (infl - gw)
    gbar_new = gbar + jnp.sum(delta, axis=0) / n_workers
    gw_new = jnp.where(cm[:, None] > 0, infl, gw)
    infl_new = jnp.where(sm[:, None], fresh, infl)

    gw_out[...] = gw_new.astype(gw_out.dtype)
    infl_out[...] = infl_new.astype(infl_out.dtype)
    gbar_out[...] = gbar_new
    w_out[...] = w_ref[...] - jnp.float32(eta) * gbar_new


def dude_update_pallas(
    commit_mask: jnp.ndarray,   # [n] bool
    start_mask: jnp.ndarray,    # [n] bool
    fresh: jnp.ndarray,         # [n, P] fresh gradients (live model)
    g_workers: jnp.ndarray,     # [n, P] buffer dtype
    inflight: jnp.ndarray,      # [n, P] buffer dtype
    g_bar: jnp.ndarray,         # [P] f32
    w: jnp.ndarray,             # [P] f32 params
    *,
    eta: float,
    tile: int = DEFAULT_TILE,
    interpret: bool = False,
):
    """Returns (g_workers', inflight', g_bar', w')."""
    n, P = fresh.shape
    assert g_workers.shape == (n, P) and inflight.shape == (n, P)
    assert g_bar.shape == (P,) and w.shape == (P,)
    tile = min(tile, P)
    assert P % tile == 0, f"P={P} % tile={tile}"
    grid = (P // tile,)

    row = pl.BlockSpec((n, tile), lambda i: (0, i))
    vec = pl.BlockSpec((tile,), lambda i: (i,))
    mask = pl.BlockSpec((n,), lambda i: (0,))

    kernel = functools.partial(_dude_kernel, n_workers=n, eta=eta)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[mask, mask, row, row, row, vec, vec],
        out_specs=[row, row, vec, vec],
        out_shape=[
            jax.ShapeDtypeStruct((n, P), g_workers.dtype),
            jax.ShapeDtypeStruct((n, P), inflight.dtype),
            jax.ShapeDtypeStruct((P,), jnp.float32),
            jax.ShapeDtypeStruct((P,), w.dtype),
        ],
        interpret=interpret,
    )(commit_mask.astype(jnp.float32), start_mask, fresh, g_workers,
      inflight, g_bar, w)

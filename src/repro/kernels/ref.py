"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def dude_update_ref(g_bar, g_workers, inflight, fresh, start_mask, commit_mask,
                    n_workers: int):
    """Fused DuDe round on ONE flat parameter tensor.

    g_bar     [P]     f32
    g_workers [n, P]  buffer dtype
    inflight  [n, P]  buffer dtype
    fresh     [n, P]  gradient of the live model per worker
    masks     [n]     bool
    Returns (g_bar', g_workers', inflight').  Semantics == core.dude.dude_round.
    """
    cm = commit_mask[:, None].astype(jnp.float32)
    infl32 = inflight.astype(jnp.float32)
    gw32 = g_workers.astype(jnp.float32)
    delta = cm * (infl32 - gw32)
    g_bar_new = g_bar + jnp.sum(delta, axis=0) / n_workers
    gw_new = jnp.where(commit_mask[:, None], infl32.astype(g_workers.dtype),
                       g_workers)
    infl_new = jnp.where(start_mask[:, None],
                         fresh.astype(inflight.dtype), inflight)
    return g_bar_new, gw_new, infl_new


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None):
    """q [B,Sq,H,hd], k/v [B,Sk,K,hd] (GQA).  Full materialized softmax."""
    B, Sq, H, hd = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, Sq, K, G, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.float32(hd))
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, hd).astype(q.dtype)


def flash_decode_ref(q, k_cache, v_cache, length):
    """q [B,1,H,hd]; k/v_cache [B,S,K,hd]; attends to positions < length."""
    B, _, H, hd = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    qg = q.reshape(B, 1, K, G, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_cache.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.float32(hd))
    valid = jnp.arange(S)[None, :] < length
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", w, v_cache.astype(jnp.float32))
    return o.reshape(B, 1, H, hd).astype(q.dtype)

"""Pallas TPU kernel: flash-decode — one query token against a long KV cache.

Grid: (batch, kv_heads, num_seq_blocks); the seq axis is sequential, carrying
(m, l, acc) for the G=H/K query heads of this kv head in VMEM scratch.
Blocks past ``length`` are skipped entirely (no DMA-wasted FLOPs), and with a
sliding window only ~window/blk_s blocks do work — the optimization the pure
XLA path can't express (it reads and masks the whole cache).  Memory per
step: O(length * hd) cache reads, the decode roofline's dominant term.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
                   blk_s: int, window: Optional[int], scale: float,
                   n_blocks: int):
    js = pl.program_id(2)

    @pl.when(js == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    length = len_ref[0]
    s_start = js * blk_s
    live = s_start < length
    if window is not None:
        live = jnp.logical_and(live, s_start + blk_s > length - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0, :, :].astype(jnp.float32) * scale   # [G, hd]
        k = k_ref[0, :, 0, :].astype(jnp.float32)           # [blk_s, hd]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = q @ k.T                                          # [G, blk_s]
        pos = s_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = pos < length
        if window is not None:
            mask &= pos >= length - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_sc[...] = l_sc[...] * alpha + jnp.sum(p, axis=-1)
        acc_sc[...] = acc_sc[...] * alpha[:, None] + p @ v
        m_sc[...] = m_new

    @pl.when(js == n_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_sc[...], 1e-30)
        o_ref[0, 0, :, :] = (acc_sc[...] / l[:, None]).astype(o_ref.dtype)


def flash_decode_pallas(
    q: jnp.ndarray,        # [B, 1, H, hd]
    k_cache: jnp.ndarray,  # [B, S, K, hd]
    v_cache: jnp.ndarray,
    length,                # scalar int: #valid cache positions
    *,
    window: Optional[int] = None,
    blk_s: int = 512,
    interpret: bool = False,
):
    B, _, H, hd = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    blk_s = min(blk_s, S)
    assert S % blk_s == 0, f"S={S} % blk_s={blk_s}"
    nb = S // blk_s
    qg = q.reshape(B, KV, G, hd)
    length_arr = jnp.asarray(length, jnp.int32).reshape(1)

    kernel = functools.partial(
        _decode_kernel, blk_s=blk_s, window=window,
        scale=1.0 / (hd ** 0.5), n_blocks=nb,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, KV, nb),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, G, hd), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, blk_s, 1, hd), lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((1, blk_s, 1, hd), lambda b, h, j: (b, j, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        interpret=interpret,
    )(length_arr, qg, k_cache, v_cache)
    return out.reshape(B, 1, H, hd)

"""Pallas TPU kernel: causal (optionally sliding-window) flash attention
with GQA, online softmax, and VMEM-tiled block processing.

Grid: (batch, q_heads, num_q_blocks, num_kv_blocks) — the last axis is
sequential on TPU, so running (m, l, acc) live in VMEM scratch across kv
blocks.  Block shapes default to 128x128 (MXU-aligned); KV blocks fully
above the causal diagonal are skipped with ``pl.when`` (no FLOPs, halving
work vs. the XLA masked path).  HBM traffic is O(S * hd) per head — the
[S, S] score matrix never leaves VMEM, which is the memory-roofline win
recorded in EXPERIMENTS §Perf.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
                  blk_q: int, blk_k: int, seq_k: int, causal: bool,
                  window: Optional[int], scale: float, n_kv_blocks: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q_start = iq * blk_q
    k_start = ik * blk_k

    # causal / window block-level skip: fully-masked KV blocks do no work
    live = jnp.bool_(True)
    if causal:
        live = jnp.logical_and(live, k_start <= q_start + blk_q - 1)
    if window is not None:
        live = jnp.logical_and(live, k_start + blk_k - 1 > q_start - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale  # [blk_q, hd]
        k = k_ref[0, :, 0, :].astype(jnp.float32)          # [blk_k, hd]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = q @ k.T  # [blk_q, blk_k]
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < seq_k
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_sc[...] = l_sc[...] * alpha + jnp.sum(p, axis=-1)
        acc_sc[...] = acc_sc[...] * alpha[:, None] + p @ v
        m_sc[...] = m_new

    @pl.when(ik == n_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_sc[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_sc[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray,  # [B, Sq, H, hd]
    k: jnp.ndarray,  # [B, Sk, K, hd]
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    blk_q: int = 128,
    blk_k: int = 128,
    interpret: bool = False,
):
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    blk_q = min(blk_q, Sq)
    blk_k = min(blk_k, Sk)
    pad_q = (-Sq) % blk_q
    pad_k = (-Sk) % blk_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    nq = (Sq + pad_q) // blk_q
    nk = (Sk + pad_k) // blk_k

    kernel = functools.partial(
        _flash_kernel, blk_q=blk_q, blk_k=blk_k, seq_k=Sk, causal=causal,
        window=window, scale=1.0 / (hd ** 0.5), n_kv_blocks=nk,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, blk_q, 1, hd), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, blk_k, 1, hd), lambda b, h, i, j: (b, j, h // G, 0)),
            pl.BlockSpec((1, blk_k, 1, hd), lambda b, h, i, j: (b, j, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, 1, hd), lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq + pad_q, H, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q,), jnp.float32),      # running max m
            pltpu.VMEM((blk_q,), jnp.float32),      # running denom l
            pltpu.VMEM((blk_q, hd), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :Sq]

"""Jitted public wrappers for the Pallas kernels.

On CPU (this container) kernels execute in ``interpret=True`` mode — the
kernel body runs in Python for correctness validation against ``ref.py``;
on TPU the same code lowers through Mosaic.  The ``interpret`` default
auto-detects the backend.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .dude_update import dude_update_pallas
from .flash_attention import flash_attention_pallas
from .flash_decode import flash_decode_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("eta", "tile", "interpret"))
def dude_update(commit_mask, start_mask, fresh, g_workers, inflight, g_bar, w,
                *, eta: float, tile: int = 16384,
                interpret: Optional[bool] = None):
    itp = _default_interpret() if interpret is None else interpret
    return dude_update_pallas(
        commit_mask, start_mask, fresh, g_workers, inflight, g_bar, w,
        eta=eta, tile=tile, interpret=itp,
    )


@partial(jax.jit, static_argnames=("causal", "window", "blk_q", "blk_k",
                                   "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, blk_q: int = 128,
                    blk_k: int = 128, interpret: Optional[bool] = None):
    itp = _default_interpret() if interpret is None else interpret
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, blk_q=blk_q, blk_k=blk_k,
        interpret=itp,
    )


@partial(jax.jit, static_argnames=("window", "blk_s", "interpret"))
def flash_decode(q, k_cache, v_cache, length, *, window: Optional[int] = None,
                 blk_s: int = 512, interpret: Optional[bool] = None):
    itp = _default_interpret() if interpret is None else interpret
    return flash_decode_pallas(
        q, k_cache, v_cache, length, window=window, blk_s=blk_s, interpret=itp,
    )

from .ckpt import (
    checkpoint_format,
    latest_step,
    restore_checkpoint,
    restore_flat_from_pytree,
    restore_params,
    restore_params_from_flat,
    restore_train_state,
    save_checkpoint,
    spec_manifest,
)

__all__ = [
    "save_checkpoint", "restore_checkpoint", "latest_step",
    "checkpoint_format", "restore_params", "restore_train_state",
    "restore_params_from_flat", "restore_flat_from_pytree", "spec_manifest",
]

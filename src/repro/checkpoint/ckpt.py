"""Checkpointing: pytree -> npz payload + msgpack manifest.

Layout:  <dir>/step_<N>/arrays.npz  (leaf i -> "a<i>")
         <dir>/step_<N>/manifest.msgpack  (treedef repr, paths, shapes, dtypes)

Arrays are gathered to host (fine for CPU and for per-host sharded saves —
a real multi-host deployment would write per-process shards; the manifest
format already records logical paths so that extension is local to save/load).
"""

from __future__ import annotations

import os
import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

Pytree = Any


def _paths_and_leaves(tree: Pytree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    paths = []
    for path, leaf in flat:
        parts = []
        for p in path:
            parts.append(str(getattr(p, "key", getattr(p, "idx", p))))
        paths.append("/".join(parts))
    return paths, [l for _, l in flat]


def save_checkpoint(directory: str, step: int, tree: Pytree) -> str:
    d = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(d, exist_ok=True)
    paths, leaves = _paths_and_leaves(tree)
    arrays = {}
    dtypes = []
    for i, l in enumerate(leaves):
        a = np.asarray(jax.device_get(l))
        dtypes.append(str(a.dtype))
        if str(a.dtype) == "bfloat16":  # numpy can't serialize ml_dtypes
            a = a.view(np.uint16)
        arrays[f"a{i}"] = a
    np.savez(os.path.join(d, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "paths": paths,
        "shapes": [list(a.shape) for a in arrays.values()],
        "dtypes": dtypes,
    }
    with open(os.path.join(d, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    return d


def restore_checkpoint(directory: str, step: Optional[int], like: Pytree) -> Pytree:
    """Restore into the structure of ``like`` (validates paths/shapes)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    data = np.load(os.path.join(d, "arrays.npz"))
    paths, leaves = _paths_and_leaves(like)
    if paths != manifest["paths"]:
        raise ValueError("checkpoint structure mismatch")
    flat, treedef = jax.tree_util.tree_flatten(like)
    out = []
    for i, ref in enumerate(flat):
        arr = data[f"a{i}"]
        if manifest["dtypes"][i] == "bfloat16":
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        if list(arr.shape) != list(ref.shape):
            raise ValueError(f"shape mismatch at {paths[i]}: {arr.shape} vs {ref.shape}")
        out.append(jnp.asarray(arr, dtype=ref.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m:
            steps.append(int(m.group(1)))
    return max(steps) if steps else None

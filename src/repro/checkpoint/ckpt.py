"""Checkpointing: pytree -> npz payload + msgpack manifest.

Layout:  <dir>/step_<N>/arrays.npz  (leaf i -> "a<i>")
         <dir>/step_<N>/manifest.msgpack  (treedef repr, paths, shapes,
         dtypes, format, optional flat-spec segment table)

Arrays are gathered to host (fine for CPU and for per-host sharded saves —
a real multi-host deployment would write per-process shards; the manifest
format already records logical paths so that extension is local to save/load).

Logical dtypes: numpy's npz cannot serialize ``ml_dtypes`` (bfloat16), so
bf16 leaves are stored as ``uint16`` views.  The manifest's ``dtypes`` entry
always records the LOGICAL per-leaf dtype; the uint16 round-trip lives in
exactly one encode/decode pair (``_encode_array`` / ``_decode_array``).

Flat-state checkpoints: ``save_checkpoint(..., flat_spec=spec)`` marks the
checkpoint ``format: "flat"`` and embeds the spec's segment table
(``spec_manifest``) so a restore can (a) validate the layout, (b) refit the
padded ``[P]`` slabs when the restoring mesh has a different
``mesh_axis_size`` (the real ``size`` prefix is invariant; only the pad tail
changes), and (c) convert between flat and legacy pytree checkpoints:
``restore_params_from_flat`` unravels a flat checkpoint's master params into
a param pytree, ``restore_flat_from_pytree`` ravels a legacy params
checkpoint into a ``FlatTrainState`` — so existing checkpoints keep loading
in either direction.
"""

from __future__ import annotations

import os
import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

Pytree = Any

PARAMS_PATH = ".params"  # FlatTrainState master-params leaf in a flat ckpt


# ------------------------------------------------- logical-dtype encoding

def _encode_array(a: np.ndarray) -> tuple[np.ndarray, str]:
    """Host array -> (npz-serializable array, logical dtype string)."""
    dt = str(a.dtype)
    if dt == "bfloat16":  # numpy can't serialize ml_dtypes
        return a.view(np.uint16), dt
    return a, dt


def _decode_array(a: np.ndarray, logical_dtype: str) -> np.ndarray:
    """Inverse of ``_encode_array``: restore the logical dtype view."""
    if logical_dtype == "bfloat16":
        import ml_dtypes
        return a.view(ml_dtypes.bfloat16)
    return a


# --------------------------------------------------------- spec manifest

def spec_manifest(spec) -> dict:
    """Serializable segment table of a ``core.flatten.FlatSpec``."""
    return {
        "sizes": list(spec.sizes),
        "offsets": list(spec.offsets),
        "shapes": [list(s) for s in spec.shapes],
        "dtypes": [str(np.dtype(d)) for d in spec.dtypes],
        "size": spec.size,
        "padded_size": spec.padded_size,
        "mesh_axis_size": spec.mesh_axis_size,
    }


def _check_spec_compatible(stored: dict, spec) -> None:
    """The stored layout must describe the same leaves in the same order;
    only the pad tail (``padded_size`` / ``mesh_axis_size``) may differ."""
    want = spec_manifest(spec)
    for k in ("sizes", "offsets", "shapes", "dtypes", "size"):
        if stored.get(k) != want[k]:
            raise ValueError(
                f"flat checkpoint segment table mismatch at {k!r}: "
                f"stored {stored.get(k)!r} != expected {want[k]!r}")


def _refit_flat(arr: np.ndarray, old_p: int, new_p: int, real: int) -> np.ndarray:
    """Resize the trailing padded-P dim ``old_p -> new_p`` keeping the real
    ``[:real]`` prefix (pad lanes are zeros by construction)."""
    if old_p == new_p:
        return arr
    out = np.zeros(arr.shape[:-1] + (new_p,), arr.dtype)
    out[..., :real] = arr[..., :real]
    return out


# ------------------------------------------------------------ save / load

def _paths_and_leaves(tree: Pytree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    paths = []
    for path, leaf in flat:
        parts = []
        for p in path:
            parts.append(str(getattr(p, "key", getattr(p, "idx", p))))
        paths.append("/".join(parts))
    return paths, [l for _, l in flat]


def save_checkpoint(directory: str, step: int, tree: Pytree,
                    flat_spec=None) -> str:
    d = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(d, exist_ok=True)
    paths, leaves = _paths_and_leaves(tree)
    arrays = {}
    dtypes = []
    for i, l in enumerate(leaves):
        a, dt = _encode_array(np.asarray(jax.device_get(l)))
        dtypes.append(dt)
        arrays[f"a{i}"] = a
    np.savez(os.path.join(d, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "paths": paths,
        "shapes": [list(a.shape) for a in arrays.values()],
        "dtypes": dtypes,
        "format": "flat" if flat_spec is not None else "pytree",
    }
    if flat_spec is not None:
        manifest["flat_spec"] = spec_manifest(flat_spec)
    with open(os.path.join(d, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    return d


def _step_dir(directory: str, step: Optional[int]) -> str:
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    return os.path.join(directory, f"step_{step:08d}")


def _load(directory: str, step: Optional[int]):
    d = _step_dir(directory, step)
    with open(os.path.join(d, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    data = np.load(os.path.join(d, "arrays.npz"))
    return manifest, data


def checkpoint_format(directory: str, step: Optional[int] = None) -> str:
    """``"flat"`` | ``"pytree"`` (checkpoints predating the field are
    pytree)."""
    manifest, _ = _load(directory, step)
    return manifest.get("format", "pytree")


# EngineState leaves that may be absent from an older checkpoint and are
# derivable from what IS stored: the sparse-transport touched-tile bitmaps
# (recomputed from the int8 payload slabs — the engine invariant is exactly
# "bitmap == touched_tiles(q row)") and the indexed backend's drop counter
# (restarts from zero).  Leaf name -> name of the payload slab it derives
# from (None = zeros).
_SYNTHESIZABLE = {"gw_touched": "g_workers", "in_touched": "inflight",
                  "drops": None}


def _leaf_name(path: str) -> str:
    """Last path component, without the NamedTuple-field dot prefix
    (``".engine/.gw_touched" -> "gw_touched"``)."""
    return path.rsplit("/", 1)[-1].lstrip(".")


def _synthesize(path: str, ref, by_path: dict) -> np.ndarray:
    """Build a missing synthesizable leaf from its restored source slab."""
    name = _leaf_name(path)
    src_name = _SYNTHESIZABLE[name]
    if src_name is None:
        return np.zeros(ref.shape, np.dtype(ref.dtype))
    tail = path.rsplit("/", 1)[-1]
    src_path = (path[: len(path) - len(tail)]
                + tail[: len(tail) - len(name)] + src_name)
    src = by_path.get(src_path)
    if src is None:
        raise ValueError(
            f"cannot synthesize {path}: {src_path} not in checkpoint")
    t = ref.shape[-1]
    tiles = src.reshape(src.shape[:-1] + (t, src.shape[-1] // t))
    return np.any(tiles != 0, axis=-1).astype(np.int8)


def restore_checkpoint(directory: str, step: Optional[int], like: Pytree,
                       flat_spec=None) -> Pytree:
    """Restore into the structure of ``like`` (validates paths/shapes).

    With ``flat_spec`` given and a flat checkpoint whose segment table
    matches, padded ``[..., P]`` slabs saved under a different
    ``mesh_axis_size`` are refitted to the current padded size.  Leaves of
    ``like`` missing from an older checkpoint are tolerated when derivable
    (``_SYNTHESIZABLE``): sparse-transport touched bitmaps are recomputed
    from the restored payload slabs, the drop counter restarts at zero.
    """
    manifest, data = _load(directory, step)
    paths, leaves = _paths_and_leaves(like)
    stored = {p: i for i, p in enumerate(manifest["paths"])}
    missing = [p for p in paths if p not in stored]
    if list(stored) != [p for p in paths if p in stored] or any(
            _leaf_name(p) not in _SYNTHESIZABLE for p in missing):
        raise ValueError("checkpoint structure mismatch")
    stored_spec = manifest.get("flat_spec")
    refits = []
    if flat_spec is not None and stored_spec is not None:
        _check_spec_compatible(stored_spec, flat_spec)
        old_p, new_p = stored_spec["padded_size"], flat_spec.padded_size
        size = stored_spec["size"]
        refits.append((old_p, new_p, size))
        # Compressed-format scale slabs are [..., P/128] (one f32 scale per
        # 128-lane tile, core/compression.py): refit them at tile
        # granularity.  The real prefix is the tiles overlapping [0, size);
        # pad-tail tiles hold zero scales by construction.
        from ..core.flatten import PAD_MULTIPLE
        if old_p % PAD_MULTIPLE == 0 and new_p % PAD_MULTIPLE == 0:
            refits.append((old_p // PAD_MULTIPLE, new_p // PAD_MULTIPLE,
                           -(-size // PAD_MULTIPLE)))
    flat, treedef = jax.tree_util.tree_flatten(like)
    by_path = {}
    for i, ref in enumerate(flat):
        p = paths[i]
        if p not in stored:
            continue
        j = stored[p]
        arr = _decode_array(data[f"a{j}"], manifest["dtypes"][j])
        for refit in refits:
            if (arr.ndim >= 1 and arr.shape[-1] == refit[0]
                    and tuple(ref.shape[:-1]) == arr.shape[:-1]
                    and ref.shape[-1] == refit[1]):
                arr = _refit_flat(arr, *refit)
                break
        if list(arr.shape) != list(ref.shape):
            raise ValueError(f"shape mismatch at {p}: {arr.shape} vs {ref.shape}")
        by_path[p] = arr
    out = [jnp.asarray(_synthesize(paths[i], ref, by_path)
                       if paths[i] not in stored else by_path[paths[i]],
                       dtype=ref.dtype)
           for i, ref in enumerate(flat)]
    return jax.tree_util.tree_unflatten(treedef, out)


# ------------------------------------------------- auto-format dispatch
#
# The one-call restore surface the session API (``api.Trainer`` /
# ``api.ServeSession``) uses: read the manifest's ``format`` field and pick
# the right of the four low-level entry points, so callers never fork on
# flat vs. legacy-pytree directories.

def restore_train_state(directory: str, step: Optional[int], like, spec):
    """Restore a ``FlatTrainState`` from EITHER checkpoint format.

    * ``"flat"`` — the full state (master params, optimizer slots, server
      slabs) restores bit-for-bit, with pad-tail refit across
      ``mesh_axis_size`` changes;
    * ``"pytree"`` — a legacy params-only checkpoint: the master-params slab
      is raveled in, slots/server state keep ``like``'s (fresh) values.
    """
    if checkpoint_format(directory, step) == "flat":
        return restore_checkpoint(directory, step, like, flat_spec=spec)
    return restore_flat_from_pytree(directory, step, like, spec)


def restore_params(directory: str, step: Optional[int],
                   params_like: Pytree) -> Pytree:
    """Restore a params PYTREE from either checkpoint format: unravels the
    master-params slab of a flat checkpoint, or loads a legacy pytree
    checkpoint directly."""
    if checkpoint_format(directory, step) == "flat":
        return restore_params_from_flat(directory, step, params_like)
    return restore_checkpoint(directory, step, params_like)


# ------------------------------------------- flat <-> pytree conversion

def restore_params_from_flat(directory: str, step: Optional[int],
                             params_like: Pytree) -> Pytree:
    """Master params of a FLAT checkpoint, unraveled into the pytree layout
    of ``params_like`` — a pytree-mode run resuming from a flat-mode run."""
    from ..core.flatten import make_flat_spec
    manifest, data = _load(directory, step)
    stored_spec = manifest.get("flat_spec")
    if manifest.get("format") != "flat" or stored_spec is None:
        raise ValueError("not a flat checkpoint; use restore_checkpoint")
    spec = make_flat_spec(params_like)
    _check_spec_compatible(stored_spec, spec)
    try:
        i = manifest["paths"].index(PARAMS_PATH)
    except ValueError:
        raise ValueError(
            f"flat checkpoint has no {PARAMS_PATH!r} leaf "
            f"(paths: {manifest['paths'][:4]}...)") from None
    flat = _decode_array(data[f"a{i}"], manifest["dtypes"][i])
    # unravel reads only offsets below spec.size (validated equal above), so
    # the stored pad tail needs no refit regardless of mesh_axis_size
    return spec.unravel(jnp.asarray(flat))


def restore_flat_from_pytree(directory: str, step: Optional[int],
                             like, spec):
    """A LEGACY params-pytree checkpoint, raveled into the flat layout —
    a flat-mode run resuming from a pytree-mode run.

    ``like`` is the freshly initialized ``FlatTrainState``; only its master
    params are overwritten (the legacy checkpoint carries no flat optimizer
    slots or engine slabs).
    """
    sds = jax.ShapeDtypeStruct
    params_like = jax.tree_util.tree_unflatten(
        spec.treedef, [sds(s, d) for s, d in zip(spec.shapes, spec.dtypes)])
    params = restore_checkpoint(directory, step, params_like)
    pf = spec.ravel(params, jnp.float32)
    return like._replace(params=jax.device_put(pf, like.params.sharding))


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m:
            steps.append(int(m.group(1)))
    return max(steps) if steps else None

"""Baseline distributed SGD algorithms from the paper's Table 1.

Each algorithm is expressed as a *server update rule* consumed by the
event-driven simulator (``core/simulator.py``).  All rules are pure functions
jitted once; scheduling semantics (who computes when, who receives models)
live in the simulator's per-discipline drivers.

Implemented (paper Table 1):
  * Synchronous SGD            [Khaled & Richtarik 2023]  — round-based
  * MIFA (no local updates)    [Gu et al. 2021]           — round-based, full agg
  * FedBuff                    [Nguyen et al. 2022]       — semi-async, partial agg
  * Vanilla ASGD               [Mishchenko et al. 2022]   — fully async
  * Uniform ASGD               [Koloskova et al. 2022]    — async + random routing
  * Shuffled ASGD              [Islamov et al. 2024]      — async + shuffled routing
  * DuDe-ASGD (this paper)     — fully async, full aggregation, dual delays
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from .dude import DuDeConfig, DuDeState, dude_commit, dude_init

Pytree = Any

__all__ = ["ServerAlgo", "make_algo", "ALGO_NAMES"]

ALGO_NAMES = (
    "sync_sgd",
    "mifa",
    "fedbuff",
    "vanilla_asgd",
    "uniform_asgd",
    "shuffled_asgd",
    "dude_asgd",
)


def _sgd_apply(params: Pytree, direction: Pytree, lr: float) -> Pytree:
    return jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype), params, direction)


@dataclasses.dataclass
class ServerAlgo:
    """A server-side update rule.

    ``scheduling`` tells the simulator which event-loop discipline to use:
      * "greedy"   — worker restarts immediately on the freshest model
                     (vanilla ASGD, DuDe-ASGD, FedBuff workers)
      * "routed"   — server routes each new model to a sampled worker's queue
                     (Uniform / Shuffled ASGD)
      * "rounds"   — synchronous rounds (sync SGD, MIFA)
    """

    name: str
    scheduling: str
    init_state: Callable[[Pytree], Any]
    # (state, worker, grad, params, lr) -> (state, new_params, applied: bool)
    on_gradient: Callable[..., tuple]
    # rounds discipline only: (state, grads [n,...] or dict, mask, params, lr)
    on_round: Optional[Callable[..., tuple]] = None
    route: Optional[str] = None  # "uniform" | "shuffled"


# ---------------------------------------------------------------- sync / MIFA


def _make_sync(n: int) -> ServerAlgo:
    def init_state(grad_like):
        return ()

    def on_round(state, stacked_grads, mask, params, lr):
        # mask is all-ones for sync SGD; average of fresh gradients.
        g = jax.tree.map(lambda g: jnp.mean(g, axis=0), stacked_grads)
        return state, _sgd_apply(params, g, lr)

    return ServerAlgo("sync_sgd", "rounds", init_state, None, on_round=on_round)


def _make_mifa(n: int) -> ServerAlgo:
    """MIFA w/o local updates: per-worker gradient memory, rounds with
    partial participation; absent workers contribute their stale entry."""

    def init_state(grad_like):
        return jax.tree.map(lambda x: jnp.zeros((n,) + x.shape, x.dtype), grad_like)

    def on_round(memory, stacked_grads, mask, params, lr):
        m = mask.reshape((-1,) + (1,) * 0)

        def upd(mem, g):
            mm = mask.reshape((-1,) + (1,) * (g.ndim - 1))
            return jnp.where(mm, g, mem)

        memory = jax.tree.map(upd, memory, stacked_grads)
        g = jax.tree.map(lambda mem: jnp.mean(mem, axis=0), memory)
        return memory, _sgd_apply(params, g, lr)

    return ServerAlgo("mifa", "rounds", init_state, None, on_round=on_round)


# ------------------------------------------------------------------- FedBuff


def _make_fedbuff(n: int, buffer_size: int = 4) -> ServerAlgo:
    """FedBuff with K=1 local step: buffer ``buffer_size`` deltas, then apply
    their mean.  State = (accumulated delta sum, count)."""

    def init_state(grad_like):
        acc = jax.tree.map(jnp.zeros_like, grad_like)
        return (acc, jnp.zeros((), jnp.int32))

    def on_gradient(state, worker, grad, params, lr):
        acc, cnt = state
        acc = jax.tree.map(lambda a, g: a + g, acc, grad)
        cnt = cnt + 1

        def flush(_):
            g = jax.tree.map(lambda a: a / buffer_size, acc)
            new_params = _sgd_apply(params, g, lr)
            zero = jax.tree.map(jnp.zeros_like, acc)
            return (zero, jnp.zeros((), jnp.int32)), new_params, jnp.array(True)

        def hold(_):
            return (acc, cnt), params, jnp.array(False)

        return jax.lax.cond(cnt >= buffer_size, flush, hold, None)

    return ServerAlgo("fedbuff", "greedy", init_state, on_gradient)


# ------------------------------------------------------- asynchronous family


def _make_vanilla(n: int) -> ServerAlgo:
    def init_state(grad_like):
        return ()

    def on_gradient(state, worker, grad, params, lr):
        return state, _sgd_apply(params, grad, lr), jnp.array(True)

    return ServerAlgo("vanilla_asgd", "greedy", init_state, on_gradient)


def _make_routed(n: int, route: str) -> ServerAlgo:
    algo = _make_vanilla(n)
    name = "uniform_asgd" if route == "uniform" else "shuffled_asgd"
    return dataclasses.replace(algo, name=name, scheduling="routed", route=route)


def _make_dude(n: int, buffer_dtype=jnp.float32) -> ServerAlgo:
    cfg = DuDeConfig(n_workers=n, buffer_dtype=buffer_dtype)

    def init_state(grad_like):
        return dude_init(grad_like, cfg)

    def on_gradient(state: DuDeState, worker, grad, params, lr):
        state, g = dude_commit(state, worker, grad, cfg)
        return state, _sgd_apply(params, g, lr), jnp.array(True)

    return ServerAlgo("dude_asgd", "greedy", init_state, on_gradient)


def _make_dude_semi(n: int, c: int = 2, buffer_dtype=jnp.float32) -> ServerAlgo:
    """Semi-asynchronous DuDe (paper §3): the server folds every arriving
    delta into g~ immediately (incremental aggregation) but only updates the
    global model once |C_t| = c deltas have arrived — trading wait time for
    smaller tau_max^(c) = tau_max / c."""
    cfg = DuDeConfig(n_workers=n, buffer_dtype=buffer_dtype)

    def init_state(grad_like):
        return (dude_init(grad_like, cfg), jnp.zeros((), jnp.int32))

    def on_gradient(state, worker, grad, params, lr):
        dude_state, pending = state
        dude_state, g = dude_commit(dude_state, worker, grad, cfg)
        pending = pending + 1

        def flush(_):
            return ((dude_state, jnp.zeros((), jnp.int32)),
                    _sgd_apply(params, g, lr), jnp.array(True))

        def hold(_):
            return ((dude_state, pending), params, jnp.array(False))

        return jax.lax.cond(pending >= c, flush, hold, None)

    return ServerAlgo(f"dude_semi_c{c}", "greedy", init_state, on_gradient)


def make_algo(name: str, n: int, **kw) -> ServerAlgo:
    if name == "sync_sgd":
        return _make_sync(n)
    if name == "mifa":
        return _make_mifa(n)
    if name == "fedbuff":
        return _make_fedbuff(n, **kw)
    if name == "vanilla_asgd":
        return _make_vanilla(n)
    if name == "uniform_asgd":
        return _make_routed(n, "uniform")
    if name == "shuffled_asgd":
        return _make_routed(n, "shuffled")
    if name == "dude_asgd":
        return _make_dude(n, **kw)
    if name == "dude_semi":
        return _make_dude_semi(n, **kw)
    raise ValueError(f"unknown algorithm {name!r}; options: {ALGO_NAMES} + dude_semi")

"""Baseline distributed SGD algorithms from the paper's Table 1.

Each algorithm is expressed as a *server update rule* consumed by the
event-driven simulator (``core/simulator.py``).  All rules are pure functions
jitted once; scheduling semantics (who computes when, who receives models)
live in the simulator's per-discipline drivers.

Since the ServerEngine refactor every stateful rule keeps its server memory
in the flat layout of ``core/flatten.py`` — DuDe state is a ``DuDeEngine``
``EngineState`` (padded ``[P]``/``[n, P]`` slabs), MIFA's gradient memory a
flat ``[n, P]`` slab, FedBuff's accumulator a flat ``[P]`` vector.  Gradients
are raveled once on arrival and the aggregated direction unraveled once for
the parameter update; everything in between is a single-buffer streaming op.

Since the session-API redesign the rule MATH lives once, in
``core/algos.py`` (``sync_direction`` / ``mifa_update`` / ``fedbuff_fold``
and the ``RoundAlgo`` registry the production train step runs mesh-native);
this module only wraps those cores into the per-arrival / per-round
callbacks the event-driven simulator schedules.  Since the async-runtime
redesign the SCHEDULING is shared too: the ``route`` markers here are
consumed by the one event loop in ``runtime/loop.py``, and the async
disciplines exist as first-class ``AsyncAlgo`` rules (``algos.ASYNC_ALGOS``)
that the production ``runtime.AsyncRunner`` drives on flat state —
docs/async.md covers both.

Implemented (paper Table 1):
  * Synchronous SGD            [Khaled & Richtarik 2023]  — round-based
  * MIFA (no local updates)    [Gu et al. 2021]           — round-based, full agg
  * FedBuff                    [Nguyen et al. 2022]       — semi-async, partial agg
  * Vanilla ASGD               [Mishchenko et al. 2022]   — fully async
  * Uniform ASGD               [Koloskova et al. 2022]    — async + random routing
  * Shuffled ASGD              [Islamov et al. 2024]      — async + shuffled routing
  * DuDe-ASGD (this paper)     — fully async, full aggregation, dual delays
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from .algos import fedbuff_fold, mifa_update, sync_direction
from .engine import DuDeEngine
from .flatten import make_flat_spec

Pytree = Any

__all__ = ["ServerAlgo", "make_algo", "ALGO_NAMES"]

ALGO_NAMES = (
    "sync_sgd",
    "mifa",
    "fedbuff",
    "vanilla_asgd",
    "uniform_asgd",
    "shuffled_asgd",
    "dude_asgd",
)


def _sgd_apply(params: Pytree, direction: Pytree, lr: float) -> Pytree:
    return jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype), params, direction)


@dataclasses.dataclass
class ServerAlgo:
    """A server-side update rule.

    ``scheduling`` tells the simulator which event-loop discipline to use:
      * "greedy"   — worker restarts immediately on the freshest model
                     (vanilla ASGD, DuDe-ASGD, FedBuff workers)
      * "routed"   — server routes each new model to a sampled worker's queue
                     (Uniform / Shuffled ASGD)
      * "rounds"   — synchronous rounds (sync SGD, MIFA)
    """

    name: str
    scheduling: str
    init_state: Callable[[Pytree], Any]
    # (state, worker, grad, params, lr) -> (state, new_params, applied: bool)
    on_gradient: Callable[..., tuple]
    # rounds discipline only:
    # (state, grads [n,...], mask, params, lr) -> (state, new_params, direction)
    on_round: Optional[Callable[..., tuple]] = None
    route: Optional[str] = None  # "uniform" | "shuffled"
    # rounds discipline: per-round worker participation probability
    participate_p: float = 1.0
    # Host-side mirror of on_gradient's ``applied`` flag: the model update
    # fires on every ``apply_period``-th gradient arrival (1 = every arrival;
    # FedBuff = buffer_size, semi-async DuDe = c).  Lets the simulator's
    # event loop count server iterations WITHOUT a device round-trip per
    # arrival (``bool(applied)`` would block on the async dispatch queue).
    apply_period: int = 1


# ---------------------------------------------------------------- sync / MIFA


def _make_sync(n: int) -> ServerAlgo:
    box = {}

    def init_state(grad_like):
        box["spec"] = make_flat_spec(grad_like)
        return ()

    def on_round(state, stacked_grads, mask, params, lr):
        # mask is all-ones for sync SGD; average of fresh gradients
        # (algos.sync_direction, the same core the production step runs).
        spec = box["spec"]
        g = spec.unravel(sync_direction(spec.ravel_stacked(stacked_grads),
                                        mask))
        return state, _sgd_apply(params, g, lr), g

    return ServerAlgo("sync_sgd", "rounds", init_state, None, on_round=on_round)


def _make_mifa(n: int) -> ServerAlgo:
    """MIFA w/o local updates: per-worker gradient memory (one flat [n, P]
    slab), rounds with partial participation; absent workers contribute their
    stale entry.  The memory update is ``algos.mifa_update``."""
    box = {}

    def init_state(grad_like):
        spec = box["spec"] = make_flat_spec(grad_like)
        return jnp.zeros((n, spec.padded_size), jnp.float32)

    def on_round(memory, stacked_grads, mask, params, lr):
        spec = box["spec"]
        memory, g_flat = mifa_update(memory, spec.ravel_stacked(stacked_grads),
                                     mask)
        g = spec.unravel(g_flat)
        return memory, _sgd_apply(params, g, lr), g

    return ServerAlgo("mifa", "rounds", init_state, None, on_round=on_round,
                      participate_p=0.8)


# ------------------------------------------------------------------- FedBuff


def _make_fedbuff(n: int, buffer_size: int = 4) -> ServerAlgo:
    """FedBuff with K=1 local step: buffer ``buffer_size`` deltas in one flat
    [P] accumulator, then apply their mean.  The fold/flush rule is
    ``algos.fedbuff_fold`` with k=1 (one arrival at a time), so the count at
    flush is exactly ``buffer_size`` and the buffered mean divides by it, as
    in the paper."""
    box = {}

    def init_state(grad_like):
        spec = box["spec"] = make_flat_spec(grad_like)
        return (jnp.zeros((spec.padded_size,), jnp.float32),
                jnp.zeros((), jnp.int32))

    def on_gradient(state, worker, grad, params, lr):
        spec = box["spec"]
        acc, cnt, g_flat, applied = fedbuff_fold(
            state[0], state[1], spec.ravel(grad), jnp.int32(1), buffer_size)

        def flush(_):
            return _sgd_apply(params, spec.unravel(g_flat), lr)

        new_params = jax.lax.cond(applied, flush, lambda _: params, None)
        return (acc, cnt), new_params, applied

    return ServerAlgo("fedbuff", "greedy", init_state, on_gradient,
                      apply_period=buffer_size)


# ------------------------------------------------------- asynchronous family


def _make_vanilla(n: int) -> ServerAlgo:
    def init_state(grad_like):
        return ()

    def on_gradient(state, worker, grad, params, lr):
        return state, _sgd_apply(params, grad, lr), jnp.array(True)

    return ServerAlgo("vanilla_asgd", "greedy", init_state, on_gradient)


def _make_routed(n: int, route: str) -> ServerAlgo:
    algo = _make_vanilla(n)
    name = "uniform_asgd" if route == "uniform" else "shuffled_asgd"
    return dataclasses.replace(algo, name=name, scheduling="routed", route=route)


def _make_dude(n: int, buffer_dtype=jnp.float32,
               backend: str = "reference") -> ServerAlgo:
    box = {}

    def init_state(grad_like):
        eng = box["eng"] = DuDeEngine.for_tree(
            grad_like, n, buffer_dtype=buffer_dtype, backend=backend)
        return eng.init()

    def on_gradient(state, worker, grad, params, lr):
        eng = box["eng"]
        state, g_flat = eng.commit(state, worker, eng.spec.ravel(grad))
        g = eng.spec.unravel(g_flat)
        return state, _sgd_apply(params, g, lr), jnp.array(True)

    return ServerAlgo("dude_asgd", "greedy", init_state, on_gradient)


def _make_dude_semi(n: int, c: int = 2, buffer_dtype=jnp.float32,
                    backend: str = "reference") -> ServerAlgo:
    """Semi-asynchronous DuDe (paper §3): the server folds every arriving
    delta into g~ immediately (incremental aggregation) but only updates the
    global model once |C_t| = c deltas have arrived — trading wait time for
    smaller tau_max^(c) = tau_max / c."""
    box = {}

    def init_state(grad_like):
        eng = box["eng"] = DuDeEngine.for_tree(
            grad_like, n, buffer_dtype=buffer_dtype, backend=backend)
        return (eng.init(), jnp.zeros((), jnp.int32))

    def on_gradient(state, worker, grad, params, lr):
        eng = box["eng"]
        dude_state, pending = state
        dude_state, g_flat = eng.commit(dude_state, worker,
                                        eng.spec.ravel(grad))
        pending = pending + 1

        def flush(_):
            g = eng.spec.unravel(g_flat)
            return ((dude_state, jnp.zeros((), jnp.int32)),
                    _sgd_apply(params, g, lr), jnp.array(True))

        def hold(_):
            return ((dude_state, pending), params, jnp.array(False))

        return jax.lax.cond(pending >= c, flush, hold, None)

    return ServerAlgo(f"dude_semi_c{c}", "greedy", init_state, on_gradient,
                      apply_period=c)


def make_algo(name: str, n: int, **kw) -> ServerAlgo:
    if name == "sync_sgd":
        return _make_sync(n)
    if name == "mifa":
        return _make_mifa(n)
    if name == "fedbuff":
        return _make_fedbuff(n, **kw)
    if name == "vanilla_asgd":
        return _make_vanilla(n)
    if name == "uniform_asgd":
        return _make_routed(n, "uniform")
    if name == "shuffled_asgd":
        return _make_routed(n, "shuffled")
    if name == "dude_asgd":
        return _make_dude(n, **kw)
    if name == "dude_semi":
        return _make_dude_semi(n, **kw)
    raise ValueError(f"unknown algorithm {name!r}; options: {ALGO_NAMES} + dude_semi")

"""Flat-slab commit codec: tiled int8 + error feedback over ``[P]`` vectors.

DuDe-ASGD's server memory is Theta(n * P): one stored gradient per worker plus
one in-flight gradient per worker.  At 100B+ parameter scale the ``[n, P]``
slab dominates HBM, and every per-arrival commit moves a full-precision row.
This module provides the storage/wire format that cuts both ~4x while keeping
the dual-delay protocol exactly intact:

* ``quantize`` / ``dequantize`` — symmetric int8 with a **per-128-lane-tile**
  f32 scale: the smallest POWER OF TWO >= ``max|x_t| / 127``.  One scale per
  tile, never per tensor: a single scale across a full ``[P]`` slab would
  collapse the precision of small segments.  128 lanes is the engine's pad
  granularity (``flatten.PAD_MULTIPLE``), so tile boundaries always align
  with P-axis shard boundaries and per-shard encoding equals global
  encoding.  Power-of-two scales cost at most one extra bit of error
  (error <= scale/2 <= max|x_t|/127) and make ``q * scale`` / ``x / scale``
  EXACT in f32 — the decode value cannot shift under compiler fusion (XLA
  contracts ``q*scale`` into neighboring subtractions as an FMA; with an
  exact product the contraction is value-identical).
* ``topk_mask`` — per-tile magnitude top-k sparsifier, applied *before*
  quantization so the top-k format shares all int8 storage and kernel
  machinery (dropped values re-enter through error feedback).
* ``CommitCodec`` — the format object carried by ``DuDeEngine``.  Its
  ``encode_commit`` implements the error-feedback commit: the codec quantizes
  ``target = g + ef`` and stores the *quantized row itself* in the slab, so
  the server's ``g_workers`` row is bit-identical to what was decoded into
  ``g_bar`` — the incremental-aggregation invariant
  ``g_bar == mean_i dec(g_workers[i])`` holds exactly, with zero
  re-quantization error.

EF bitwise invariant.  With ``(q, s) = quantize(target)`` and
``dec = dequantize(q, s)``, the new residual ``ef' = target - dec`` satisfies
``dec + ef' == target`` **bitwise** in f32.  Two ingredients: (1) ``dec`` is
the EXACT real product ``q * s`` (power-of-two scale — no multiply rounding,
so even an FMA-contracted ``target - q*s`` computes the same value); (2) the
subtraction ``target - dec`` is itself exact — when ``q == 0`` trivially
(``dec == 0``), and when ``|q| >= 1`` ``target`` and ``dec`` are within a
factor of 2 of each other (``|target - dec| <= s/2 <= |dec|/2``), so the
Sterbenz lemma applies.  Hence ``dec ⊕ ef' == g ⊕ ef`` (f32 adds) holds
bit-for-bit — the decoded stream plus residual telescopes to the true stream
with no float slop.  Tested in ``tests/test_compression.py``.
"""

from __future__ import annotations

import dataclasses

from jax import lax
import jax.numpy as jnp

from .flatten import PAD_MULTIPLE

__all__ = [
    "COMMIT_FORMATS", "TILE", "CommitCodec",
    "quantize", "dequantize", "topk_mask", "ef_encode", "ef_decode",
]

TILE = PAD_MULTIPLE  # 128 lanes per scale tile — the engine pad granularity

COMMIT_FORMATS = ("f32", "int8_ef", "topk_ef")

_SCALE_FLOOR = 1e-12


def _tiles(x: jnp.ndarray, tile: int) -> jnp.ndarray:
    """View ``[..., P]`` (P % tile == 0) as ``[..., P//tile, tile]``."""
    if x.shape[-1] % tile:
        raise ValueError(
            f"trailing dim {x.shape[-1]} is not a multiple of tile={tile}"
        )
    return x.reshape(x.shape[:-1] + (x.shape[-1] // tile, tile))


def _pow2_ceil(x: jnp.ndarray) -> jnp.ndarray:
    """Smallest power of two >= x (x strictly positive, normal f32).

    Bit-level and branch-free: adding ``0x007FFFFF`` carries into the
    exponent iff any mantissa bit is set, and masking to the exponent field
    clears the mantissa — exact powers of two pass through unchanged.  No
    libm (``log2``/``exp2``) rounding anywhere, so eager, jit, and the
    Pallas kernel all agree bit-for-bit.
    """
    bits = lax.bitcast_convert_type(x, jnp.int32)
    return lax.bitcast_convert_type((bits + 0x007FFFFF) & 0x7F800000,
                                    jnp.float32)


def _tile_scale(xt: jnp.ndarray) -> jnp.ndarray:
    """Per-tile quantization scale of ``[..., T, tile]`` tiles: the smallest
    POWER OF TWO >= ``max|tile| / 127`` (floored at 1e-12 so all-zero tiles
    encode to q=0)."""
    raw = jnp.maximum(jnp.max(jnp.abs(xt), axis=-1), _SCALE_FLOOR) / 127.0
    return _pow2_ceil(raw)


def quantize(x: jnp.ndarray, tile: int = TILE) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Tiled symmetric int8: ``[..., P] -> (q int8 [..., P], scale f32 [..., P//tile])``.

    Each 128-lane tile gets its own f32 scale: the smallest power of two
    >= ``max|tile| / 127`` (floored at 1e-12 so all-zero tiles encode to
    q=0).  A power-of-two scale costs at most one extra bit of quantization
    error (error <= scale/2 <= max|tile|/127) and buys EXACTNESS: ``q/scale``
    divides and ``q*scale`` multiplies without rounding, so ``dequantize`` is
    bit-deterministic under any compiler fusion (an FMA contraction of
    ``q*scale`` into a neighboring subtract cannot change the value) — the
    foundation of the bitwise EF invariant (module docstring).  The trailing
    dim must be a multiple of ``tile`` — engine slabs always are; pad shorter
    vectors with zeros first (zero lanes quantize to zero exactly).
    """
    xt = _tiles(x.astype(jnp.float32), tile)
    scale = _tile_scale(xt)
    q = jnp.clip(jnp.round(xt / scale[..., None]), -127, 127).astype(jnp.int8)
    return q.reshape(x.shape), scale


def dequantize(q: jnp.ndarray, scale: jnp.ndarray,
               tile: int = TILE) -> jnp.ndarray:
    """Inverse of :func:`quantize`: ``q [..., P], scale [..., P//tile] -> f32 [..., P]``."""
    qt = _tiles(q.astype(jnp.float32), tile)
    return (qt * scale[..., None]).reshape(q.shape)


def topk_mask(x: jnp.ndarray, k: int, tile: int = TILE) -> jnp.ndarray:
    """Zero all but the ``k`` largest-|x| lanes of each 128-lane tile.

    Threshold-based: lanes with ``|x| >= (k-th largest |x| in tile)`` survive,
    so exact-magnitude ties may keep a few extra lanes (measure-zero for
    continuous gradients).  Implemented as k-1 vectorized max-suppression
    sweeps instead of a sort so the identical op sequence lowers inside the
    Pallas kernel and the plain-jnp oracle.
    """
    if not 1 <= k <= tile:
        raise ValueError(f"topk k={k} must be in [1, {tile}]")
    a = jnp.abs(_tiles(x.astype(jnp.float32), tile))
    cur = a
    for _ in range(k - 1):
        m = jnp.max(cur, axis=-1, keepdims=True)
        cur = jnp.where(cur >= m, -jnp.inf, cur)
    thresh = jnp.max(cur, axis=-1, keepdims=True)
    keep = (a >= thresh).reshape(x.shape)
    return jnp.where(keep, x, jnp.zeros_like(x))


def ef_encode(x: jnp.ndarray, err: jnp.ndarray,
              tile: int = TILE) -> tuple[tuple[jnp.ndarray, jnp.ndarray], jnp.ndarray]:
    """Quantize ``x + err`` and return ``((q, scale), new_err)``."""
    target = x.astype(jnp.float32) + err
    q, scale = quantize(target, tile)
    new_err = target - dequantize(q, scale, tile)
    return (q, scale), new_err


def ef_decode(q: jnp.ndarray, scale: jnp.ndarray,
              tile: int = TILE) -> jnp.ndarray:
    return dequantize(q, scale, tile)


@dataclasses.dataclass(frozen=True)
class CommitCodec:
    """Commit/storage format for the flat engine's ``[n, P]`` slabs.

    ``f32``      — today's format: full-precision rows, no EF slot.
    ``int8_ef``  — tiled symmetric int8 rows + per-tile f32 scales, with a
                   ``[P]`` error-feedback residual on the commit stream.
    ``topk_ef``  — per-tile magnitude top-k applied before int8 quantization;
                   same slab layout (the int8 payload is mostly zeros, the
                   wire payload is k values + k in-tile indices per tile).
    """

    format: str = "f32"
    tile: int = TILE
    topk: int = 16  # survivors per tile (topk_ef only)

    def __post_init__(self):
        if self.format not in COMMIT_FORMATS:
            raise ValueError(
                f"commit_format {self.format!r} not in {COMMIT_FORMATS}"
            )
        if not 1 <= self.topk <= self.tile:
            raise ValueError(f"topk={self.topk} must be in [1, {self.tile}]")

    @property
    def compressed(self) -> bool:
        return self.format != "f32"

    def n_tiles(self, p: int) -> int:
        if p % self.tile:
            raise ValueError(f"P={p} not a multiple of tile={self.tile}")
        return p // self.tile

    # ------------------------------------------------------------- codec ops

    def sparsify(self, x: jnp.ndarray) -> jnp.ndarray:
        """The pre-quantization lane filter (identity except topk_ef)."""
        if self.format == "topk_ef":
            return topk_mask(x, self.topk, self.tile)
        return x

    def encode(self, x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
        """``[..., P] -> (q, scale)`` (sparsify then tiled int8)."""
        return quantize(self.sparsify(x), self.tile)

    def decode(self, q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
        return dequantize(q, scale, self.tile)

    def encode_commit(
        self, g: jnp.ndarray, ef: jnp.ndarray
    ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """Error-feedback commit encode of one ``[P]`` gradient row.

        Returns ``(q, scale, dec, ef_new)`` where ``dec = decode(q, scale)``
        and ``dec + ef_new == g + ef`` bitwise (see module docstring).
        """
        target = g.astype(jnp.float32) + ef
        q, scale = self.encode(target)
        dec = self.decode(q, scale)
        return q, scale, dec, target - dec

    def quant_bound(self, x: jnp.ndarray) -> jnp.ndarray:
        """Per-tile worst-case |dequantize(quantize(x)) - x| bound: scale/2 + slop.

        Rounding to the nearest int8 level is off by at most ``scale/2`` per
        lane — exactly, because the power-of-two scale makes the divide and
        multiply exact; the small extra term covers the one case where the
        floored ``max/127`` rounds a hair low and a max-magnitude lane clips
        at 127.  Since ``scale < 2 * max|tile|/127``, the bound is at most
        the classic ``max|tile|/127`` (+ slop).  (For ``topk_ef`` this bounds
        the error on *surviving* lanes; dropped lanes carry their full value
        into EF.)
        """
        xs = self.sparsify(x)
        scale = _tile_scale(_tiles(xs.astype(jnp.float32), self.tile))
        return scale * (0.5 + 4.0 * jnp.finfo(jnp.float32).eps * 127.0)

    # ----------------------------------------------------------- byte models

    def commit_wire_bytes(self, p: int) -> int:
        """Bytes one per-arrival commit moves over the (future) wire."""
        t = self.n_tiles(p)
        if self.format == "f32":
            return 4 * p
        if self.format == "int8_ef":
            return p + 4 * t               # int8 payload + f32 scale per tile
        # topk_ef: k (value int8 + in-tile index uint8) per tile + scales
        return t * 2 * self.topk + 4 * t

    def slab_bytes(self, n: int, p: int) -> int:
        """Resident bytes of one ``[n, P]`` worker slab (+ its scale slab)."""
        if self.format == "f32":
            return 4 * n * p
        return n * p + 4 * n * self.n_tiles(p)

"""Beyond-paper: compressed DuDe buffers with error feedback.

DuDe-ASGD's server memory is Theta(n * p): one stored gradient per worker plus
one in-flight gradient per worker.  At 100B+ parameter scale this term
dominates HBM (see EXPERIMENTS §Dry-run).  We add a per-tensor symmetric int8
codec with error feedback: the quantization residual of each commit is carried
into the next commit of the same worker, so the *long-run* aggregate direction
is unbiased (standard EF-SGD argument layered on DuDe's incremental rule).

This changes nothing about the dual-delay protocol — only the storage format
of G~_i / in-flight buffers — and is recorded separately from the
paper-faithful baseline in EXPERIMENTS §Perf.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Pytree = Any

__all__ = ["QTensor", "quantize", "dequantize", "ef_encode", "ef_decode"]


class QTensor(NamedTuple):
    q: jnp.ndarray      # int8 payload
    scale: jnp.ndarray  # f32 scalar per tensor


def quantize(x: jnp.ndarray) -> QTensor:
    x = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return QTensor(q=q, scale=scale)


def dequantize(qt: QTensor) -> jnp.ndarray:
    return qt.q.astype(jnp.float32) * qt.scale


def ef_encode(x: jnp.ndarray, err: jnp.ndarray) -> tuple[QTensor, jnp.ndarray]:
    """Quantize ``x + err`` and return the new residual."""
    target = x.astype(jnp.float32) + err
    qt = quantize(target)
    new_err = target - dequantize(qt)
    return qt, new_err


def ef_decode(qt: QTensor) -> jnp.ndarray:
    return dequantize(qt)


def tree_quantize(tree: Pytree) -> Pytree:
    return jax.tree.map(quantize, tree)


def tree_dequantize(tree: Pytree) -> Pytree:
    return jax.tree.map(dequantize, tree, is_leaf=lambda x: isinstance(x, QTensor))


# ------------------------------------------------------ compressed DuDe delta

def compressed_commit(state, worker, grad, err_tree, cfg):
    """Beyond-paper: worker-side int8+EF compression of the DuDe delta.

    The paper's worker message is delta = G_new - G~_worker (Fig. 1).  Here the
    worker quantizes delta with error feedback (residual kept locally), and the
    server applies the DECODED delta to both g_bar and its copy of G~_worker —
    server and worker buffers stay bit-identical, so the incremental-
    aggregation invariant is preserved exactly, while the wire payload drops
    4x (int8 vs f32).  Returns (new_state, g_bar, new_err_tree).
    """
    import jax
    import jax.numpy as jnp

    n = cfg.n_workers

    def upd(gbar, gw, g, err):
        g = g.astype(jnp.float32)
        old = jax.lax.dynamic_index_in_dim(gw, worker, axis=0, keepdims=False)
        delta = g - old.astype(jnp.float32)
        qt, new_err = ef_encode(delta, err)
        dec = dequantize(qt)
        gbar = gbar + dec / n
        new_row = old.astype(jnp.float32) + dec
        gw = jax.lax.dynamic_update_index_in_dim(
            gw, new_row.astype(gw.dtype), worker, axis=0
        )
        return gbar, gw, new_err

    flat_bar, treedef = jax.tree.flatten(state.g_bar)
    flat_gw = treedef.flatten_up_to(state.g_workers)
    flat_g = treedef.flatten_up_to(grad)
    flat_err = treedef.flatten_up_to(err_tree)
    nb, nw, ne = [], [], []
    for b, w, g, e in zip(flat_bar, flat_gw, flat_g, flat_err):
        b2, w2, e2 = upd(b, w, g, e)
        nb.append(b2)
        nw.append(w2)
        ne.append(e2)
    new_state = state._replace(
        g_bar=jax.tree.unflatten(treedef, nb),
        g_workers=jax.tree.unflatten(treedef, nw),
        step=state.step + 1,
    )
    return new_state, new_state.g_bar, jax.tree.unflatten(treedef, ne)

"""Flat-slab commit codec: tiled int8 + error feedback over ``[P]`` vectors.

DuDe-ASGD's server memory is Theta(n * P): one stored gradient per worker plus
one in-flight gradient per worker.  At 100B+ parameter scale the ``[n, P]``
slab dominates HBM, and every per-arrival commit moves a full-precision row.
This module provides the storage/wire format that cuts both ~4x while keeping
the dual-delay protocol exactly intact:

* ``quantize`` / ``dequantize`` — symmetric int8 with a **per-128-lane-tile**
  f32 scale: the smallest POWER OF TWO >= ``max|x_t| / 127``.  One scale per
  tile, never per tensor: a single scale across a full ``[P]`` slab would
  collapse the precision of small segments.  128 lanes is the engine's pad
  granularity (``flatten.PAD_MULTIPLE``), so tile boundaries always align
  with P-axis shard boundaries and per-shard encoding equals global
  encoding.  Power-of-two scales cost at most one extra bit of error
  (error <= scale/2 <= max|x_t|/127) and make ``q * scale`` / ``x / scale``
  EXACT in f32 — the decode value cannot shift under compiler fusion (XLA
  contracts ``q*scale`` into neighboring subtractions as an FMA; with an
  exact product the contraction is value-identical).
* ``topk_mask`` — per-tile magnitude top-k sparsifier, applied *before*
  quantization so the top-k format shares all int8 storage and kernel
  machinery (dropped values re-enter through error feedback).  Selection is
  DETERMINISTIC: exactly ``k`` lanes survive per tile, magnitude ties broken
  toward the lower lane index — the same op sequence lowers identically
  under XLA and inside the Pallas kernel, so every backend picks the same
  survivors bit-for-bit.
* ``SparseRow`` — the index-carrying wire format of one ``topk_ef`` row:
  per-touched-tile survivor lane indices (uint8) + int8 values + f32
  power-of-two scales + an i32 touched-tile index list with a live count.
  A commit or snapshot delta then costs O(k * tiles_touched) bytes on the
  wire and in slab writes instead of O(P) — ``sparse_encode`` /
  ``sparse_decode`` round-trip bit-exactly against the dense ``(q, scale)``
  pair, and ``CommitCodec.sparse_encode_commit`` preserves the EF invariant
  by decoding *what the row actually carries* (tiles dropped by the static
  capacity re-enter through error feedback, like top-k dropped lanes).
* ``CommitCodec`` — the format object carried by ``DuDeEngine``.  Its
  ``encode_commit`` implements the error-feedback commit: the codec quantizes
  ``target = g + ef`` and stores the *quantized row itself* in the slab, so
  the server's ``g_workers`` row is bit-identical to what was decoded into
  ``g_bar`` — the incremental-aggregation invariant
  ``g_bar == mean_i dec(g_workers[i])`` holds exactly, with zero
  re-quantization error.

EF bitwise invariant.  With ``(q, s) = quantize(target)`` and
``dec = dequantize(q, s)``, the new residual ``ef' = target - dec`` satisfies
``dec + ef' == target`` **bitwise** in f32.  Two ingredients: (1) ``dec`` is
the EXACT real product ``q * s`` (power-of-two scale — no multiply rounding,
so even an FMA-contracted ``target - q*s`` computes the same value); (2) the
subtraction ``target - dec`` is itself exact — when ``q == 0`` trivially
(``dec == 0``), and when ``|q| >= 1`` ``target`` and ``dec`` are within a
factor of 2 of each other (``|target - dec| <= s/2 <= |dec|/2``), so the
Sterbenz lemma applies.  Hence ``dec ⊕ ef' == g ⊕ ef`` (f32 adds) holds
bit-for-bit — the decoded stream plus residual telescopes to the true stream
with no float slop.  Tested in ``tests/test_compression.py``.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import NamedTuple, Optional

import numpy as np
from jax import lax
import jax.numpy as jnp

from .flatten import PAD_MULTIPLE

__all__ = [
    "COMMIT_FORMATS", "TILE", "CommitCodec", "SparseRow",
    "quantize", "dequantize", "topk_mask", "ef_encode", "ef_decode",
    "touched_tiles", "sparse_encode", "sparse_decode_q", "sparse_decode",
    "sparse_wire_nbytes", "zero_tile_scale", "commit_digest",
]

TILE = PAD_MULTIPLE  # 128 lanes per scale tile — the engine pad granularity

COMMIT_FORMATS = ("f32", "int8_ef", "topk_ef")

_SCALE_FLOOR = 1e-12


def _tiles(x: jnp.ndarray, tile: int) -> jnp.ndarray:
    """View ``[..., P]`` (P % tile == 0) as ``[..., P//tile, tile]``."""
    if x.shape[-1] % tile:
        raise ValueError(
            f"trailing dim {x.shape[-1]} is not a multiple of tile={tile}"
        )
    return x.reshape(x.shape[:-1] + (x.shape[-1] // tile, tile))


def _pow2_ceil(x: jnp.ndarray) -> jnp.ndarray:
    """Smallest power of two >= x (x strictly positive, normal f32).

    Bit-level and branch-free: adding ``0x007FFFFF`` carries into the
    exponent iff any mantissa bit is set, and masking to the exponent field
    clears the mantissa — exact powers of two pass through unchanged.  No
    libm (``log2``/``exp2``) rounding anywhere, so eager, jit, and the
    Pallas kernel all agree bit-for-bit.
    """
    bits = lax.bitcast_convert_type(x, jnp.int32)
    return lax.bitcast_convert_type((bits + 0x007FFFFF) & 0x7F800000,
                                    jnp.float32)


def _tile_scale(xt: jnp.ndarray) -> jnp.ndarray:
    """Per-tile quantization scale of ``[..., T, tile]`` tiles: the smallest
    POWER OF TWO >= ``max|tile| / 127`` (floored at 1e-12 so all-zero tiles
    encode to q=0)."""
    raw = jnp.maximum(jnp.max(jnp.abs(xt), axis=-1), _SCALE_FLOOR) / 127.0
    return _pow2_ceil(raw)


def quantize(x: jnp.ndarray, tile: int = TILE) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Tiled symmetric int8: ``[..., P] -> (q int8 [..., P], scale f32 [..., P//tile])``.

    Each 128-lane tile gets its own f32 scale: the smallest power of two
    >= ``max|tile| / 127`` (floored at 1e-12 so all-zero tiles encode to
    q=0).  A power-of-two scale costs at most one extra bit of quantization
    error (error <= scale/2 <= max|tile|/127) and buys EXACTNESS: ``q/scale``
    divides and ``q*scale`` multiplies without rounding, so ``dequantize`` is
    bit-deterministic under any compiler fusion (an FMA contraction of
    ``q*scale`` into a neighboring subtract cannot change the value) — the
    foundation of the bitwise EF invariant (module docstring).  The trailing
    dim must be a multiple of ``tile`` — engine slabs always are; pad shorter
    vectors with zeros first (zero lanes quantize to zero exactly).
    """
    xt = _tiles(x.astype(jnp.float32), tile)
    scale = _tile_scale(xt)
    q = jnp.clip(jnp.round(xt / scale[..., None]), -127, 127).astype(jnp.int8)
    return q.reshape(x.shape), scale


def dequantize(q: jnp.ndarray, scale: jnp.ndarray,
               tile: int = TILE) -> jnp.ndarray:
    """Inverse of :func:`quantize`: ``q [..., P], scale [..., P//tile] -> f32 [..., P]``."""
    qt = _tiles(q.astype(jnp.float32), tile)
    return (qt * scale[..., None]).reshape(q.shape)


def topk_mask(x: jnp.ndarray, k: int, tile: int = TILE) -> jnp.ndarray:
    """Zero all but the ``k`` largest-|x| lanes of each 128-lane tile.

    Deterministic selection rule: EXACTLY ``k`` lanes survive per tile — the
    ``k`` largest by ``|x|``, with equal-magnitude ties broken toward the
    LOWER lane index.  The historical threshold sweep (``|x| >= k-th
    largest``) could keep extra lanes on exact ties and, worse, pick
    different survivors under XLA vs the Pallas lowering; this version runs
    ``k`` max-then-lowest-index selection sweeps built only from
    max/min/compare/where — ops that lower bit-identically everywhere — so
    the survivor set is a pure function of the tile values on every backend.
    The exact-k invariant is also what lets ``SparseRow`` carry a fixed
    ``k``-slot survivor list per touched tile with no overflow.
    """
    if not 1 <= k <= tile:
        raise ValueError(f"topk k={k} must be in [1, {tile}]")
    a = jnp.abs(_tiles(x.astype(jnp.float32), tile))
    lane = lax.broadcasted_iota(jnp.int32, a.shape, a.ndim - 1)
    cur = a
    keep = jnp.zeros(a.shape, bool)
    for _ in range(k):
        m = jnp.max(cur, axis=-1, keepdims=True)
        cand = jnp.where(cur == m, lane, tile)   # lowest lane among maxima
        sel = jnp.min(cand, axis=-1, keepdims=True)
        hit = lane == sel
        keep = keep | hit
        cur = jnp.where(hit, -jnp.inf, cur)
    keep = keep.reshape(x.shape)
    return jnp.where(keep, x, jnp.zeros_like(x))


def ef_encode(x: jnp.ndarray, err: jnp.ndarray,
              tile: int = TILE) -> tuple[tuple[jnp.ndarray, jnp.ndarray], jnp.ndarray]:
    """Quantize ``x + err`` and return ``((q, scale), new_err)``."""
    target = x.astype(jnp.float32) + err
    q, scale = quantize(target, tile)
    new_err = target - dequantize(q, scale, tile)
    return (q, scale), new_err


def ef_decode(q: jnp.ndarray, scale: jnp.ndarray,
              tile: int = TILE) -> jnp.ndarray:
    return dequantize(q, scale, tile)


# --------------------------------------------------- sparse wire transport

def zero_tile_scale() -> jnp.ndarray:
    """The scale every all-zero tile quantizes to: ``pow2_ceil(1e-12/127)``.

    ``quantize`` floors ``max|tile|`` at ``_SCALE_FLOOR``, so a zero tile
    always encodes to ``(q=0, scale=zero_tile_scale())`` — deterministic,
    which is what lets ``sparse_decode_q`` reconstruct the dense scale row
    bit-exactly without shipping scales for untouched tiles.
    """
    return _pow2_ceil(jnp.float32(_SCALE_FLOOR / 127.0))


class SparseRow(NamedTuple):
    """Index-carrying wire encoding of ONE ``topk_ef`` row.

    Static capacity ``cap`` touched-tile slots (the leading dim of every
    field), each carrying up to ``k`` survivors.  Live slots list their
    128-lane tile id in ascending order; pad slots use the out-of-range
    sentinel ``tiles == n_tiles(P)`` and pad survivor entries inside a live
    tile use ``lanes == 128`` — both are dropped by ``mode="drop"``
    scatters, so decode never needs the live count (it rides along for byte
    accounting and tests).  Wire cost is ``cap * (2k + 8) + 4`` bytes —
    O(k * tiles_touched) once ``cap`` is sized to the touched set, vs
    O(P) for the dense ``(q, scale)`` pair.
    """

    tiles: jnp.ndarray   # i32 [cap]     touched tile ids, ascending; pad = T
    lanes: jnp.ndarray   # u8  [cap, k]  in-tile survivor lane; pad = 128
    vals: jnp.ndarray    # i8  [cap, k]  survivor int8 payload; pad = 0
    scales: jnp.ndarray  # f32 [cap]     per-touched-tile pow-2 scale; pad = 0
    count: jnp.ndarray   # i32 []        live slots (<= cap)


def sparse_wire_nbytes(row: SparseRow) -> int:
    """Actual bytes of one ``SparseRow`` on the wire (static, cap-sized)."""
    return sum(int(x.size) * x.dtype.itemsize for x in row)


def commit_digest(*arrays) -> str:
    """Canonical 8-hex-char digest of a commit's payload arrays.

    CRC32 over each array's little-endian bytes, tagged with dtype and shape
    so byte-identical buffers of different layouts cannot collide by
    accident.  This is the per-arrival integrity stamp the multi-host
    transport sends with every commit and the trace records (schema >= 2):
    a replay recomputing the same gradients produces the same digests, so a
    digest mismatch localizes WHICH arrival diverged (or which frame was
    corrupted in flight) instead of only failing the final-params check.
    Accepts jax or numpy arrays (device arrays are pulled to host — call it
    on values the host already owns on hot paths).
    """
    crc = 0
    for x in arrays:
        a = np.asarray(x)
        a = a.astype(a.dtype.newbyteorder("<"), copy=False)
        tag = f"{a.dtype.str}{a.shape}".encode()
        crc = zlib.crc32(np.ascontiguousarray(a).tobytes(), zlib.crc32(tag, crc))
    return f"{crc & 0xFFFFFFFF:08x}"


def touched_tiles(q: jnp.ndarray, tile: int = TILE) -> jnp.ndarray:
    """Per-tile any-nonzero bitmap: ``q [..., P] -> bool [..., P//tile]``."""
    return jnp.any(_tiles(q, tile) != 0, axis=-1)


def sparse_encode(q: jnp.ndarray, scale: jnp.ndarray, cap: int, k: int,
                  include: Optional[jnp.ndarray] = None,
                  tile: int = TILE) -> SparseRow:
    """Dense ``(q int8 [P], scale f32 [P//tile])`` -> ``SparseRow``.

    A tile is listed iff it has any nonzero payload lane, or ``include``
    (an optional ``[P//tile]`` bool) marks it — the caller's "clear set":
    tiles the receiver currently holds nonzero for this row and that must
    be explicitly overwritten with zeros.  Tiles beyond the static ``cap``
    are dropped lowest-tile-id-first-kept; callers recover the loss through
    error feedback (``CommitCodec.sparse_encode_commit`` decodes what the
    row actually carries).  Requires <= ``k`` nonzero lanes per tile
    (``topk_mask``'s exact-k rule guarantees it); extra lanes are dropped.
    """
    t = q.shape[-1] // tile
    if not 1 <= cap <= t:
        raise ValueError(f"sparse cap={cap} outside [1, {t}]")
    qt = _tiles(q, tile)                                    # [T, tile]
    touched = jnp.any(qt != 0, axis=-1)
    if include is not None:
        touched = touched | include.astype(bool)
    slot = jnp.where(touched, jnp.cumsum(touched.astype(jnp.int32)) - 1, cap)
    slot = jnp.minimum(slot, cap)              # overflow tiles -> dropped
    tids = jnp.arange(t, dtype=jnp.int32)
    tiles = jnp.full((cap,), t, jnp.int32).at[slot].set(tids, mode="drop")
    count = jnp.minimum(jnp.sum(touched.astype(jnp.int32)), cap)

    live = tiles < t
    src = jnp.minimum(tiles, t - 1)            # clamp pads for a safe gather
    qrow = jnp.where(live[:, None], qt[src], jnp.int8(0))   # [cap, tile]
    srow = jnp.where(live, scale[src], jnp.float32(0.0))    # [cap]

    nz = qrow != 0
    lidx = lax.broadcasted_iota(jnp.int32, nz.shape, 1)
    rows = lax.broadcasted_iota(jnp.int32, nz.shape, 0)
    lslot = jnp.where(nz, jnp.cumsum(nz.astype(jnp.int32), axis=-1) - 1, k)
    lanes = jnp.full((cap, k), tile, jnp.uint8).at[rows, lslot].set(
        lidx.astype(jnp.uint8), mode="drop")
    vals = jnp.zeros((cap, k), jnp.int8).at[rows, lslot].set(
        qrow, mode="drop")
    return SparseRow(tiles, lanes, vals, srow, count)


def sparse_decode_q(row: SparseRow, p: int,
                    tile: int = TILE) -> tuple[jnp.ndarray, jnp.ndarray]:
    """``SparseRow -> (q int8 [P], scale f32 [P//tile])`` — the dense pair.

    Bit-exact inverse of ``sparse_encode`` whenever the touched set fit in
    ``cap`` and each tile had <= k survivors: unlisted tiles come back as
    ``(q=0, scale=zero_tile_scale())``, exactly what ``quantize`` emits for
    a zero tile.  Oracle/test path — the engine's slab fold scatters the
    row directly instead (``DuDeEngine.sparse_fold``).
    """
    t = p // tile
    cap, k = row.lanes.shape
    rows = lax.broadcasted_iota(jnp.int32, (cap, k), 0)
    tile_img = jnp.zeros((cap, tile), jnp.int8).at[
        rows, row.lanes.astype(jnp.int32)].set(row.vals, mode="drop")
    qt = jnp.zeros((t, tile), jnp.int8).at[row.tiles].set(
        tile_img, mode="drop")
    scale = jnp.full((t,), zero_tile_scale(), jnp.float32).at[row.tiles].set(
        row.scales, mode="drop")
    return qt.reshape(p), scale


def sparse_decode(row: SparseRow, p: int, tile: int = TILE) -> jnp.ndarray:
    """``SparseRow -> f32 [P]`` decoded values, via a direct survivor
    scatter (``val * scale`` is exact — power-of-two scales), with no dense
    int8 intermediate."""
    t = p // tile
    dec = (row.vals.astype(jnp.float32)
           * row.scales[:, None].astype(jnp.float32))          # [cap, k]
    lanes = row.lanes.astype(jnp.int32)
    pos = row.tiles[:, None] * tile + lanes
    pos = jnp.where((lanes < tile) & (row.tiles[:, None] < t), pos, p)
    return jnp.zeros((p,), jnp.float32).at[pos].set(dec, mode="drop")


@dataclasses.dataclass(frozen=True)
class CommitCodec:
    """Commit/storage format for the flat engine's ``[n, P]`` slabs.

    ``f32``      — today's format: full-precision rows, no EF slot.
    ``int8_ef``  — tiled symmetric int8 rows + per-tile f32 scales, with a
                   ``[P]`` error-feedback residual on the commit stream.
    ``topk_ef``  — per-tile magnitude top-k applied before int8 quantization;
                   same slab layout (the int8 payload is mostly zeros, the
                   wire payload is k values + k in-tile indices per tile).
    """

    format: str = "f32"
    tile: int = TILE
    topk: int = 16  # survivors per tile (topk_ef only)

    def __post_init__(self):
        if self.format not in COMMIT_FORMATS:
            raise ValueError(
                f"commit_format {self.format!r} not in {COMMIT_FORMATS}"
            )
        if not 1 <= self.topk <= self.tile:
            raise ValueError(f"topk={self.topk} must be in [1, {self.tile}]")

    @property
    def compressed(self) -> bool:
        return self.format != "f32"

    def n_tiles(self, p: int) -> int:
        if p % self.tile:
            raise ValueError(f"P={p} not a multiple of tile={self.tile}")
        return p // self.tile

    # ------------------------------------------------------------- codec ops

    def sparsify(self, x: jnp.ndarray) -> jnp.ndarray:
        """The pre-quantization lane filter (identity except topk_ef)."""
        if self.format == "topk_ef":
            return topk_mask(x, self.topk, self.tile)
        return x

    def encode(self, x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
        """``[..., P] -> (q, scale)`` (sparsify then tiled int8)."""
        return quantize(self.sparsify(x), self.tile)

    def decode(self, q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
        return dequantize(q, scale, self.tile)

    def encode_commit(
        self, g: jnp.ndarray, ef: jnp.ndarray
    ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """Error-feedback commit encode of one ``[P]`` gradient row.

        Returns ``(q, scale, dec, ef_new)`` where ``dec = decode(q, scale)``
        and ``dec + ef_new == g + ef`` bitwise (see module docstring).
        """
        target = g.astype(jnp.float32) + ef
        q, scale = self.encode(target)
        dec = self.decode(q, scale)
        return q, scale, dec, target - dec

    # ------------------------------------------------------ sparse transport

    def _require_sparse(self):
        if self.format != "topk_ef":
            raise ValueError(
                f"SparseRow transport needs commit_format='topk_ef', "
                f"not {self.format!r} (other formats have dense payloads)")

    def sparse_cap(self, p: int, cap: Optional[int] = None) -> int:
        """Resolve a static touched-tile capacity (None = all tiles)."""
        self._require_sparse()
        t = self.n_tiles(p)
        if cap is None:
            return t
        if not 1 <= cap <= t:
            raise ValueError(f"sparse cap={cap} outside [1, {t}]")
        return cap

    def encode_sparse(self, x: jnp.ndarray, cap: Optional[int] = None,
                      include: Optional[jnp.ndarray] = None) -> SparseRow:
        """``[P] -> SparseRow`` (topk sparsify, tiled int8, index-carrying)."""
        cap = self.sparse_cap(x.shape[-1], cap)
        q, s = self.encode(x)
        return sparse_encode(q, s, cap, self.topk, include=include,
                             tile=self.tile)

    def sparse_encode_commit(
        self, g: jnp.ndarray, ef: jnp.ndarray, cap: Optional[int] = None,
        include: Optional[jnp.ndarray] = None,
    ) -> tuple[SparseRow, jnp.ndarray]:
        """Error-feedback commit encode of one ``[P]`` gradient row into a
        ``SparseRow``.  Returns ``(row, ef_new)``.

        The residual is computed against the decode of WHAT THE ROW
        CARRIES — so the bitwise EF invariant ``dec(row) + ef_new == g + ef``
        holds even when the static ``cap`` drops touched tiles (their full
        target re-enters EF, exactly like top-k dropped lanes).  When
        nothing is dropped this matches ``encode_commit`` bit-for-bit.
        """
        cap = self.sparse_cap(g.shape[-1], cap)
        target = g.astype(jnp.float32) + ef
        q, scale = self.encode(target)
        row = sparse_encode(q, scale, cap, self.topk, include=include,
                            tile=self.tile)
        dec = sparse_decode(row, target.shape[-1], self.tile)
        return row, target - dec

    def quant_bound(self, x: jnp.ndarray) -> jnp.ndarray:
        """Per-tile worst-case |dequantize(quantize(x)) - x| bound: scale/2 + slop.

        Rounding to the nearest int8 level is off by at most ``scale/2`` per
        lane — exactly, because the power-of-two scale makes the divide and
        multiply exact; the small extra term covers the one case where the
        floored ``max/127`` rounds a hair low and a max-magnitude lane clips
        at 127.  Since ``scale < 2 * max|tile|/127``, the bound is at most
        the classic ``max|tile|/127`` (+ slop).  (For ``topk_ef`` this bounds
        the error on *surviving* lanes; dropped lanes carry their full value
        into EF.)
        """
        xs = self.sparsify(x)
        scale = _tile_scale(_tiles(xs.astype(jnp.float32), self.tile))
        return scale * (0.5 + 4.0 * jnp.finfo(jnp.float32).eps * 127.0)

    # ----------------------------------------------------------- byte models

    def commit_wire_bytes(self, p: int,
                          tiles_touched: Optional[int] = None) -> int:
        """Bytes one per-arrival commit moves over the wire.

        ``tiles_touched`` (topk_ef only) switches to the real ``SparseRow``
        payload: per listed tile, k int8 values + k uint8 lane indices + one
        f32 scale + one i32 tile id, plus the i32 live count — O(k *
        tiles_touched) instead of the dense row's O(P).  ``None`` keeps the
        historical dense-row model (every tile shipped, positions implicit).
        """
        t = self.n_tiles(p)
        if self.format == "f32":
            return 4 * p
        if self.format == "int8_ef":
            return p + 4 * t               # int8 payload + f32 scale per tile
        if tiles_touched is not None:
            self._require_sparse()
            if not 0 <= tiles_touched <= t:
                raise ValueError(
                    f"tiles_touched={tiles_touched} outside [0, {t}]")
            return tiles_touched * (2 * self.topk + 8) + 4
        # dense topk_ef row: k (value int8 + in-tile index uint8) per tile
        # + scales
        return t * 2 * self.topk + 4 * t

    def slab_bytes(self, n: int, p: int) -> int:
        """Resident bytes of one ``[n, P]`` worker slab (+ its scale slab)."""
        if self.format == "f32":
            return 4 * n * p
        return n * p + 4 * n * self.n_tiles(p)

"""Round-mode server-algorithm registry on the flat slab layout.

The paper's point is that DuDe-ASGD is one *server update rule* among peers
(sync SGD, MIFA, FedBuff, the ASGD family).  This module is the single home
of those rules expressed on the engine's canonical flat layout — ``[P]``
vectors and ``[n, P]`` slabs in the segment-range split of a ``FlatSpec`` —
so the SAME math runs in both execution modes:

* the production train step (``launch/steps.py`` / ``api.Trainer``): one
  ``RoundAlgo`` per session, its server state living inside the single
  ``FlatTrainState`` and its round body running mesh-native (under the
  engine's P-axis ``shard_map`` when a mesh is given — every rule here is
  elementwise on P with worker-axis reductions local to each P-shard, so a
  sharded round moves zero bytes);
* the event-driven simulator (``core/simulator.py``): ``core/baselines.py``
  wraps the very same rule cores (``sync_direction`` / ``mifa_update`` /
  ``fedbuff_fold``) into per-arrival / per-round callbacks, making the
  simulator a thin scheduling shell over this registry.

A ``RoundAlgo`` consumes the per-round inputs of the semi-async SPMD driver
— the ``[n, P]`` fresh gradients plus the schedule's start/commit masks —
and produces the descent direction ``g`` and an ``applied`` gate:

  ``round(state, fresh, start_mask, commit_mask) -> (state, g, applied)``

``applied`` is a traced bool scalar gating the optimizer apply (FedBuff
holds the model until its buffer fills; everything else applies every
round).  The DuDe family does not go through ``round`` on the training hot
path: ``fused_apply=True`` tells the step builder to call
``DuDeEngine.round_apply`` instead, which fuses the round with the flat
optimizer apply in one shard_map (PR 3).  ``round`` is still provided for
every algo so equivalence tests and non-fused callers have one uniform
entry point.

Mask semantics per rule (all masks are ``[n]`` bool):

* ``dude`` / ``dude_accum`` — paper §3: ``start_mask`` latches the fresh
  gradient into ``inflight``, ``commit_mask`` folds ``inflight - g_workers``
  into ``g_bar`` (``DuDeEngine.round``).
* ``sync_sgd`` — ``commit_mask`` is the participation set; direction is the
  mean of participating workers' fresh gradients (Khaled & Richtarik 2023).
* ``mifa`` — participating workers (``commit_mask``) overwrite their row of
  the gradient memory; direction is the mean over ALL rows, stale entries
  included (Gu et al. 2021, no local updates).
* ``fedbuff`` — participating workers' fresh gradients fold into one ``[P]``
  accumulator; the model updates only when ``buffer_size`` gradients have
  arrived, with the buffered mean (Nguyen et al. 2022, K=1).

Alongside the round registry lives the ARRIVAL-granularity one:
``AsyncAlgo`` rules consume one worker's gradient per server iteration —
``arrival(state, worker, grad, tau) -> (state, g)`` — and carry the routing
discipline (greedy / uniform / shuffled) that the event loop
(``runtime/loop.py``) schedules.  ``dude`` maps to ``DuDeEngine.commit``;
the three ASGD disciplines are the identity rule under different routing;
the staleness-adaptive family (``dude_const`` / ``dude_hinge`` /
``dude_poly``) mixes the arriving gradient with the worker's stored slab row
by FedAsync's s(τ) weight before the DuDe commit — at s(τ)=1 it IS the dude
rule, bitwise.  These are what ``runtime.AsyncRunner`` and
``Trainer.run_async`` drive on the flat train state, and what
``core/baselines.py`` wraps for the simulator.  Covered by docs/engine.md
("The server-rule registry and the session API") and docs/async.md
("Arrival-granularity algorithms" / "Staleness-adaptive rules").
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec

from .engine import DuDeEngine, EngineState

Pytree = Any

__all__ = [
    "ROUND_ALGOS", "RoundAlgo", "make_round_algo",
    "ASYNC_ALGOS", "STALENESS_RULES", "STALENESS_ASYNC",
    "AsyncAlgo", "make_async_algo", "staleness_weight",
    "sync_direction", "mifa_update", "fedbuff_fold",
]

# every name the production driver / Trainer accepts for --algo (round mode)
ROUND_ALGOS = ("dude", "dude_accum", "sync_sgd", "mifa", "fedbuff")

# arrival-granularity rules (--async mode); dude appears in both registries
ASYNC_ALGOS = ("dude", "dude_const", "dude_hinge", "dude_poly",
               "vanilla_asgd", "uniform_asgd", "shuffled_asgd")

# FedAsync staleness weight vocabulary and the async algo names that use it
STALENESS_RULES = ("const", "hinge", "poly")
STALENESS_ASYNC = {"dude_const": "const", "dude_hinge": "hinge",
                   "dude_poly": "poly"}

# FedAsync / FLGo defaults for the s(tau) shapes
HINGE_A = 10.0
HINGE_B = 4.0
POLY_A = 0.5


def staleness_weight(rule: str, tau, *, hinge_a: float = HINGE_A,
                     hinge_b: float = HINGE_B, poly_a: float = POLY_A):
    """FedAsync's staleness weight s(τ) ∈ (0, 1] (Xie et al. 2019).

    ``const``: s(τ) = 1 (plain DuDe).  ``hinge``: s(τ) = 1 for τ <= b, else
    ``min(1, 1 / (a(τ - b)))`` — the min also closes the 1/0 hole just past
    the knee, so the weight is finite, in (0, 1], and monotone
    non-increasing for every τ >= 0.  ``poly``: s(τ) = (1 + τ)^(-a).
    Elementwise jnp on float32, so the rule runs inside the mesh-native
    arrival step; accepts scalars or arrays (the property tests sweep
    arrays).
    """
    tau = jnp.asarray(tau, jnp.float32)
    if rule == "const":
        return jnp.ones_like(tau)
    if rule == "hinge":
        a, b = jnp.float32(hinge_a), jnp.float32(hinge_b)
        return jnp.where(tau <= b, jnp.float32(1.0),
                         jnp.minimum(jnp.float32(1.0),
                                     jnp.float32(1.0) / (a * (tau - b))))
    if rule == "poly":
        return jnp.power(jnp.float32(1.0) + tau, -jnp.float32(poly_a))
    raise ValueError(
        f"unknown staleness rule {rule!r}; options: {STALENESS_RULES}")


# ------------------------------------------------------------- rule cores
#
# The pure math, shared verbatim with core/baselines.py (the simulator's
# per-arrival wrappers).  All operate on flat f32 slabs and are elementwise
# on P; worker-axis reductions are local to any contiguous P-shard.

def sync_direction(fresh: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Mean of the participating rows of ``fresh`` ``[n, P]`` -> ``[P]``."""
    m = mask.astype(jnp.float32)
    cnt = jnp.maximum(jnp.sum(m), 1.0)
    return jnp.sum(fresh.astype(jnp.float32) * m[:, None], axis=0) / cnt


def mifa_update(memory: jnp.ndarray, fresh: jnp.ndarray, mask: jnp.ndarray
                ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """MIFA gradient memory update: participating rows refresh, direction is
    the mean over all n rows (stale entries included)."""
    memory = jnp.where(mask[:, None], fresh.astype(jnp.float32), memory)
    return memory, jnp.mean(memory, axis=0)


def fedbuff_fold(acc: jnp.ndarray, count: jnp.ndarray, grad_sum: jnp.ndarray,
                 k: jnp.ndarray, buffer_size: int):
    """Fold ``k`` arrived gradients (summed into ``grad_sum``) into the
    FedBuff accumulator; flush when the buffer holds >= ``buffer_size``.

    Returns ``(acc', count', g, applied)`` — ``g`` is the buffered mean
    (meaningful only when ``applied``), and the accumulator resets on flush.
    Used per-arrival by the simulator (k=1, flush exactly at buffer_size, so
    the mean divides by buffer_size as in the paper) and per-round by the
    production step (k = |commit set|, which may overshoot the buffer within
    one round — the mean then divides by the actual count).
    """
    acc = acc + grad_sum.astype(jnp.float32)
    count = count + k.astype(jnp.int32)
    applied = count >= buffer_size
    g = acc / jnp.maximum(count, 1).astype(jnp.float32)
    zero = jnp.zeros((), jnp.int32)
    return (jnp.where(applied, jnp.zeros_like(acc), acc),
            jnp.where(applied, zero, count), g, applied)


# --------------------------------------------------------------- registry


@dataclasses.dataclass(frozen=True)
class RoundAlgo:
    """One server update rule bound to an engine, for the round-based
    production path.

    ``init()`` builds the rule's server state as flat slabs (an
    ``EngineState`` for the DuDe family; smaller slab tuples for the
    baselines) — it is the ``server`` field of the session's single
    ``FlatTrainState``.  ``round(state, fresh, sm, cm)`` advances it one
    semi-async round.  When ``fused_apply`` is set the step builder skips
    ``round`` and calls ``engine.round_apply`` (round + flat optimizer apply
    in one shard_map / Pallas pass) — the gate is then always-applied.
    """

    name: str
    engine: DuDeEngine
    fused_apply: bool
    init_fn: Callable[[], Pytree]
    # (state, fresh [n, P], start_mask, commit_mask)
    #   -> (state, g [P] f32, applied scalar bool)
    round_fn: Callable[..., tuple]
    # abstract server state for lowering; None = eval_shape(init_fn)
    state_shapes_fn: Callable[[], Pytree] = None

    def init(self) -> Pytree:
        return self.init_fn()

    def state_shapes(self) -> Pytree:
        """Abstract (ShapeDtypeStruct) server state, for lowering."""
        if self.state_shapes_fn is not None:
            return self.state_shapes_fn()
        return jax.eval_shape(self.init_fn)

    def round(self, state, fresh, start_mask, commit_mask):
        return self.round_fn(state, fresh,
                             start_mask.astype(bool), commit_mask.astype(bool))

    # -------------------------------------------------- shard_map plumbing

    def _shard(self, body, in_kinds: tuple, out_kinds: tuple):
        """Run ``body`` under the engine's P-axis shard_map when meshed.

        Kinds: ``"vec"`` = ``[.., P]`` sharded on the last axis, ``"row"`` =
        ``[n, P]`` sharded on P, ``"repl"`` = replicated.  Every rule body is
        elementwise on P (worker reductions stay inside the shard), so the
        sharded round is collective-free, exactly like the DuDe engine's.
        """
        eng = self.engine
        if eng.mesh is None:
            return body
        kind = {"vec": PartitionSpec(eng.paxes),
                "row": PartitionSpec(None, eng.paxes),
                "repl": PartitionSpec()}
        out = tuple(kind[k] for k in out_kinds)
        return shard_map(body, mesh=eng.mesh,
                         in_specs=tuple(kind[k] for k in in_kinds),
                         out_specs=out if len(out) > 1 else out[0],
                         check_rep=False)


def _make_dude(engine: DuDeEngine, name: str) -> RoundAlgo:
    def round_fn(state: EngineState, fresh, sm, cm):
        state, g_bar = engine.round(state, fresh, sm, cm)
        return state, g_bar, jnp.array(True)

    return RoundAlgo(name, engine, fused_apply=True,
                     init_fn=engine.init, round_fn=round_fn,
                     state_shapes_fn=engine.state_shapes)


def _make_sync(engine: DuDeEngine) -> RoundAlgo:
    def round_fn(state, fresh, sm, cm):
        body = algo._shard(sync_direction, ("row", "repl"), ("vec",))
        return state, body(fresh, cm), jnp.array(True)

    algo = RoundAlgo("sync_sgd", engine, fused_apply=False,
                     init_fn=lambda: (), round_fn=round_fn)
    return algo


def _make_mifa(engine: DuDeEngine) -> RoundAlgo:
    n, P = engine.n_workers, engine.P

    def init_fn():
        return jnp.zeros((n, P), jnp.float32)

    def round_fn(memory, fresh, sm, cm):
        body = algo._shard(mifa_update, ("row", "row", "repl"), ("row", "vec"))
        memory, g = body(memory, fresh, cm)
        return memory, g, jnp.array(True)

    algo = RoundAlgo("mifa", engine, fused_apply=False,
                     init_fn=init_fn, round_fn=round_fn)
    return algo


def _make_fedbuff(engine: DuDeEngine, buffer_size: int = 4) -> RoundAlgo:
    P = engine.P

    def init_fn():
        return (jnp.zeros((P,), jnp.float32), jnp.zeros((), jnp.int32))

    def masked_sum(fresh, cm):
        return jnp.sum(fresh.astype(jnp.float32)
                       * cm.astype(jnp.float32)[:, None], axis=0)

    def round_fn(state, fresh, sm, cm):
        acc, count = state
        body = algo._shard(masked_sum, ("row", "repl"), ("vec",))
        # scalar bookkeeping stays outside the shard_map (replicated); the
        # accumulator fold/reset is elementwise on the sharded [P] slab.
        acc, count, g, applied = fedbuff_fold(
            acc, count, body(fresh, cm), jnp.sum(cm.astype(jnp.int32)),
            buffer_size)
        return (acc, count), g, applied

    algo = RoundAlgo("fedbuff", engine, fused_apply=False,
                     init_fn=init_fn, round_fn=round_fn)
    return algo


def make_round_algo(name: str, engine: DuDeEngine,
                    buffer_size: int = 4) -> RoundAlgo:
    """Build the named server rule bound to ``engine``.

    The DuDe family requires the engine's ``accumulate`` flag to match the
    name (``dude_accum`` = the beyond-paper running-mean latch, reference
    backend only — enforced by ``DuDeEngine`` itself and, earlier, by
    ``api.TrainerConfig``).
    """
    if name in ("dude", "dude_accum"):
        want = name == "dude_accum"
        if engine.accumulate != want:
            raise ValueError(
                f"algo {name!r} needs an engine with accumulate={want}, "
                f"got accumulate={engine.accumulate}")
        return _make_dude(engine, name)
    if name == "sync_sgd":
        return _make_sync(engine)
    if name == "mifa":
        return _make_mifa(engine)
    if name == "fedbuff":
        return _make_fedbuff(engine, buffer_size=buffer_size)
    raise ValueError(f"unknown round algo {name!r}; options: {ROUND_ALGOS}")


# -------------------------------------------- arrival-granularity registry


@dataclasses.dataclass(frozen=True)
class AsyncAlgo:
    """One per-arrival server rule bound to an engine, for the fully-async
    path (``runtime.AsyncRunner`` / ``Trainer.run_async``).

    ``arrival(state, worker, grad, tau)`` consumes ONE worker's flat ``[P]``
    gradient (with its model staleness ``tau``, which only the
    staleness-adaptive rules read — it defaults to 0 for callers that
    predate it) and returns ``(state, g)`` — the descent direction the flat
    optimizer applies that same iteration.  The rule body is elementwise on
    P (``DuDeEngine.commit`` runs under the engine's P-axis ``shard_map``
    when meshed; the ASGD identity needs no collective at all; the
    staleness mix reads the worker's ``[n, P]`` row along the REPLICATED
    worker axis), so a sharded arrival step moves zero bytes, exactly like
    the round rules.

    ``route`` is the SCHEDULING half of the algorithm — who receives the
    post-update model — consumed by ``runtime.loop.drive_arrivals``:
    ``None`` (greedy: the arriving worker restarts on the freshest model,
    vanilla ASGD / DuDe), ``"uniform"`` (Koloskova et al. 2022) or
    ``"shuffled"`` (Islamov et al. 2024) routing.
    """

    name: str
    engine: DuDeEngine
    route: Any                        # None | "uniform" | "shuffled"
    init_fn: Callable[[], Pytree]
    # (state, worker i32 scalar, grad [P] f32, tau i32 scalar)
    #   -> (state, g [P] f32)
    arrival_fn: Callable[..., tuple]
    state_shapes_fn: Callable[[], Pytree] = None

    def init(self) -> Pytree:
        return self.init_fn()

    def state_shapes(self) -> Pytree:
        """Abstract (ShapeDtypeStruct) server state, for lowering."""
        if self.state_shapes_fn is not None:
            return self.state_shapes_fn()
        return jax.eval_shape(self.init_fn)

    def arrival(self, state, worker, grad, tau=0):
        return self.arrival_fn(state, jnp.asarray(worker, jnp.int32),
                               grad.astype(jnp.float32),
                               jnp.asarray(tau, jnp.int32))


def make_async_algo(name: str, engine: DuDeEngine) -> AsyncAlgo:
    """Build the named arrival-granularity rule bound to ``engine``.

    ``dude`` is the paper's Algorithm 1 server iteration
    (``DuDeEngine.commit``: fold ``(g - g_workers[w]) / n`` into ``g_bar``,
    remember ``g`` as worker ``w``'s latest) — greedy scheduling, full
    aggregation.  The three ASGD disciplines all descend along the raw
    arriving gradient and differ only in routing.  The staleness-adaptive
    family damps a stale arrival toward the worker's stored row before the
    commit:

        g_eff = s(τ)·g + (1 − s(τ))·g_workers[w]        (FedAsync mixing)

    so the fold becomes ``s(τ)·(g − g_workers[w]) / n`` — at s=1 the rule
    IS ``dude`` bitwise, and a maximally stale gradient barely perturbs the
    dual-delayed average.  The mix reads the worker's row in f32, so these
    rules require the uncompressed slab (``commit_format="f32"``, enforced
    here and at ``TrainerConfig`` build time).
    """
    if name == "dude" or name in STALENESS_ASYNC:
        if engine.accumulate:
            raise ValueError(
                f"async {name} runs per-arrival commits; the accumulate "
                "running-mean latch is a round-mode (dude_accum) feature")
        if name == "dude":
            def dude_arrival(state: EngineState, worker, grad, tau):
                return engine.commit(state, worker, grad)

            return AsyncAlgo("dude", engine, route=None,
                             init_fn=engine.init, arrival_fn=dude_arrival,
                             state_shapes_fn=engine.state_shapes)

        rule = STALENESS_ASYNC[name]
        if engine.codec.compressed:
            raise ValueError(
                f"async {name} mixes the arriving gradient with the stored "
                f"f32 slab row; it requires commit_format='f32', not "
                f"{engine.codec.format!r}")

        def staleness_arrival(state: EngineState, worker, grad, tau):
            s = staleness_weight(rule, tau)
            # row gather along the REPLICATED worker axis of the [n, P]
            # slab: with P-axis sharding this slices shard-locally, keeping
            # the arrival step collective-free (asserted by
            # tests/test_scenarios.py on the 8-device mesh)
            old = jax.lax.dynamic_index_in_dim(
                state.g_workers, worker, axis=0, keepdims=False
            ).astype(jnp.float32)
            g_eff = s * grad + (jnp.float32(1.0) - s) * old
            return engine.commit(state, worker, g_eff)

        return AsyncAlgo(name, engine, route=None,
                         init_fn=engine.init, arrival_fn=staleness_arrival,
                         state_shapes_fn=engine.state_shapes)
    if name in ("vanilla_asgd", "uniform_asgd", "shuffled_asgd"):
        route = {"vanilla_asgd": None, "uniform_asgd": "uniform",
                 "shuffled_asgd": "shuffled"}[name]

        def asgd_arrival(state, worker, grad, tau):
            return state, grad

        return AsyncAlgo(name, engine, route=route,
                         init_fn=lambda: (), arrival_fn=asgd_arrival)
    raise ValueError(f"unknown async algo {name!r}; options: {ASYNC_ALGOS}")

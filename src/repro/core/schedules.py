"""Worker speed models and asynchronous arrival schedules (host-side).

The paper (§5) models hardware heterogeneity with the fixed-computation-speed
model of Mishchenko et al. 2022: worker ``i`` always takes ``s_i`` time units
per stochastic gradient, with ``s_i ~ TruncatedNormal(mu=1, std)`` clipped to
positive values.  A higher ``std`` means more heterogeneity and hence larger
model delays ``tau``.

Everything in this module is plain numpy executed on the host.  The SPMD
production path (mode B in DESIGN.md) consumes the *round schedule* produced
here as small boolean mask arrays that are fed into the jitted train step; the
event-driven simulator (mode A) consumes the continuous-time event stream.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Iterator, Sequence

import numpy as np

__all__ = [
    "SpeedModel",
    "truncated_normal_speeds",
    "Event",
    "event_stream",
    "RoundSchedule",
    "make_round_schedule",
    "delay_stats",
]


@dataclasses.dataclass(frozen=True)
class SpeedModel:
    """Fixed per-gradient computation times for each worker."""

    times: np.ndarray  # [n] positive floats

    @property
    def n(self) -> int:
        return int(self.times.shape[0])

    def __post_init__(self):
        if np.any(self.times <= 0):
            raise ValueError("worker times must be positive")


def truncated_normal_speeds(
    n: int, mu: float = 1.0, std: float = 1.0, seed: int = 0, floor: float = 1e-2
) -> SpeedModel:
    """Draw s_i ~ TN(mu, std), redrawing until positive (paper §5)."""
    rng = np.random.default_rng(seed)
    out = np.empty(n, dtype=np.float64)
    for i in range(n):
        t = rng.normal(mu, std)
        while t <= floor:
            t = rng.normal(mu, std)
        out[i] = t
    return SpeedModel(times=out)


@dataclasses.dataclass(frozen=True)
class Event:
    """A worker finishing one stochastic-gradient computation.

    ``start_time``/``finish_time`` are continuous simulated wall-clock;
    ``server_iter`` is assigned by the consumer (one commit == one server
    iteration in the fully asynchronous Algorithm 1).
    """

    worker: int
    start_time: float
    finish_time: float


def event_stream(speeds: SpeedModel, max_events: int) -> Iterator[Event]:
    """Fully-asynchronous completion stream.

    Every worker starts computing at t=0; on completion it immediately receives
    the new model and starts the next job (the paper assumes zero
    communication/server time).  Yields events ordered by finish time.
    """
    heap: list[tuple[float, int, float]] = []  # (finish, worker, start)
    for i in range(speeds.n):
        heapq.heappush(heap, (speeds.times[i], i, 0.0))
    for _ in range(max_events):
        finish, worker, start = heapq.heappop(heap)
        yield Event(worker=worker, start_time=start, finish_time=finish)
        heapq.heappush(heap, (finish + speeds.times[worker], worker, finish))


@dataclasses.dataclass(frozen=True)
class RoundSchedule:
    """Round-based (semi-asynchronous, mode B) commit schedule.

    One *round* == one server iteration of the semi-async variant.  Per round
    ``r`` and worker ``i``:

    * ``start[r, i]``  — worker i begins a new gradient job this round; the
      job's gradient is computed against the round-``r`` model (latched into
      the in-flight buffer by the SPMD step).
    * ``commit[r, i]`` — worker i's in-flight gradient is committed this round
      (DuDe delta applied); by construction the committed gradient was started
      ``tau_i`` rounds earlier, so the model delay is physical, and its data
      was drawn at start, giving ``tau_i >= d_i + 1`` (paper Eq. 4).
    """

    start: np.ndarray  # [rounds, n] bool
    commit: np.ndarray  # [rounds, n] bool
    duration: np.ndarray  # [n] int, job length in rounds

    @property
    def rounds(self) -> int:
        return int(self.start.shape[0])

    @property
    def n(self) -> int:
        return int(self.start.shape[1])


def make_round_schedule(
    speeds: SpeedModel, rounds: int, round_time: float | None = None
) -> RoundSchedule:
    """Quantize the continuous speed model onto server rounds.

    ``round_time`` defaults to the fastest worker's time, so the fastest worker
    commits every round and a worker with ``s_i = k * round_time`` commits
    every ``ceil(k)`` rounds.
    """
    if round_time is None:
        round_time = float(np.min(speeds.times))
    dur = np.maximum(1, np.ceil(speeds.times / round_time).astype(np.int64))
    start = np.zeros((rounds, speeds.n), dtype=bool)
    commit = np.zeros((rounds, speeds.n), dtype=bool)
    for i in range(speeds.n):
        r = 0
        while r < rounds:
            start[r, i] = True
            fin = r + int(dur[i])
            if fin < rounds:
                commit[fin, i] = True
            r = fin
    return RoundSchedule(start=start, commit=commit, duration=dur)


def delay_stats(schedule: RoundSchedule) -> dict:
    """tau_max / tau_avg over the schedule (for EXPERIMENTS reporting)."""
    last_commit = np.zeros(schedule.n, dtype=np.int64)
    taus = []
    for r in range(schedule.rounds):
        for i in np.nonzero(schedule.commit[r])[0]:
            taus.append(r - last_commit[i])
            last_commit[i] = r
    taus = np.asarray(taus) if taus else np.zeros(1, dtype=np.int64)
    return {
        "tau_max": int(taus.max()),
        "tau_avg": float(taus.mean()),
        "commit_rate": float(schedule.commit.mean()),
    }

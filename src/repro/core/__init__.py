"""DuDe-ASGD core: the paper's contribution as composable JAX modules.

Public API:
  * DuDeConfig / DuDeState / dude_init / dude_commit / dude_round — Algorithm 1
    and the semi-asynchronous SPMD variant (see DESIGN.md modes A/B).
  * schedules — worker speed models and arrival schedules.
  * baselines — Table-1 comparison algorithms.
  * simulator — event-driven asynchronous-training harness.
"""

from .dude import DuDeConfig, DuDeState, dude_commit, dude_init, dude_round
from .schedules import (
    RoundSchedule,
    SpeedModel,
    delay_stats,
    event_stream,
    make_round_schedule,
    truncated_normal_speeds,
)
from .baselines import ALGO_NAMES, ServerAlgo, make_algo
from .simulator import SimResult, simulate

__all__ = [
    "DuDeConfig", "DuDeState", "dude_commit", "dude_init", "dude_round",
    "RoundSchedule", "SpeedModel", "delay_stats", "event_stream",
    "make_round_schedule", "truncated_normal_speeds",
    "ALGO_NAMES", "ServerAlgo", "make_algo", "SimResult", "simulate",
]

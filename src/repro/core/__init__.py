"""DuDe-ASGD core: the paper's contribution as composable JAX modules.

Public API:
  * DuDeConfig / DuDeState / dude_init / dude_commit / dude_round — Algorithm 1
    and the semi-asynchronous SPMD variant (see DESIGN.md modes A/B).
  * engine / flatten — the flat-buffer ServerEngine the above wrap: one padded
    [P]/[n, P] state layout, three interchangeable backends
    (reference / indexed / pallas).
  * schedules — worker speed models and arrival schedules.
  * algos — the RoundAlgo registry: every server rule (DuDe + Table-1
    round baselines) on the flat slab layout, runnable mesh-native by the
    production train step.
  * baselines — Table-1 comparison algorithms as simulator callbacks (thin
    wrappers over the algos rule cores).
  * simulator — event-driven asynchronous-training harness.
"""

from .compression import COMMIT_FORMATS, CommitCodec
from .dude import (
    DuDeConfig, DuDeState, dude_commit, dude_init, dude_round,
    dude_round_indexed, masks_to_indices,
)
from .engine import BACKENDS, DuDeEngine, EngineState, masks_to_indices_jnp
from .flatten import FlatSpec, make_flat_spec
from .schedules import (
    RoundSchedule,
    SpeedModel,
    delay_stats,
    event_stream,
    make_round_schedule,
    truncated_normal_speeds,
)
from .algos import (
    ASYNC_ALGOS, AsyncAlgo, ROUND_ALGOS, RoundAlgo, make_async_algo,
    make_round_algo,
)
from .baselines import ALGO_NAMES, ServerAlgo, make_algo
from .simulator import SimResult, simulate

__all__ = [
    "DuDeConfig", "DuDeState", "dude_commit", "dude_init", "dude_round",
    "dude_round_indexed", "masks_to_indices",
    "BACKENDS", "DuDeEngine", "EngineState", "masks_to_indices_jnp",
    "COMMIT_FORMATS", "CommitCodec",
    "FlatSpec", "make_flat_spec",
    "RoundSchedule", "SpeedModel", "delay_stats", "event_stream",
    "make_round_schedule", "truncated_normal_speeds",
    "ROUND_ALGOS", "RoundAlgo", "make_round_algo",
    "ASYNC_ALGOS", "AsyncAlgo", "make_async_algo",
    "ALGO_NAMES", "ServerAlgo", "make_algo", "SimResult", "simulate",
]

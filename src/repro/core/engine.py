"""ServerEngine: the DuDe server iteration on one flat buffer layout.

Every server-side algorithm in this repo ultimately streams over Theta(n * p)
buffer state.  ``DuDeEngine`` owns that state in ONE canonical layout —
``g_bar`` as a padded flat ``[P]`` f32 vector, ``g_workers``/``inflight`` as
``[n, P]`` slabs in the configured buffer dtype — and exposes the two paper
entry points (``commit`` for the fully-async mode, ``round`` for the
semi-async SPMD mode) over three interchangeable backends:

* ``"reference"`` — masked jnp sweep over all n rows; the paper-faithful
  oracle (identical math to the historical ``dude_round``), and the only
  backend supporting the beyond-paper ``accumulate`` variant.
* ``"indexed"``   — gather/scatter touching only the selected rows.  The
  traffic saving (~4kP instead of ~4nP bytes per round) requires a static
  bound k on the active set: set ``index_width`` (the schedule usually
  knows max |C_t|), or use ``round_indexed`` with host-narrowed arrays.
  With the default width n the mask path is correct but saves nothing.
* ``"pallas"``    — the fused TPU kernel (``kernels/dude_update.py``): one
  pass over all five streams, optionally folding the SGD parameter update
  into the same pass.  Runs under ``interpret=True`` on CPU.

Backends agree bit-for-bit on ``g_bar`` (all accumulate the commit delta in
f32) and on the buffers up to the shared buffer-dtype rounding; the
equivalence is enforced by ``tests/test_engine.py``.

``core/dude.py`` re-exports the historical pytree API (``dude_commit`` /
``dude_round`` / ``dude_round_indexed``) as thin ravel->engine->unravel
wrappers, so callers keep pytree ergonomics while the hot loop runs on flat
slabs.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from .flatten import FlatSpec, make_flat_spec
from ..kernels.dude_update import DEFAULT_TILE, dude_update_pallas

Pytree = Any

__all__ = ["BACKENDS", "EngineState", "DuDeEngine", "masks_to_indices_jnp"]

BACKENDS = ("reference", "indexed", "pallas")


class EngineState(NamedTuple):
    """Flat DuDe server state.  Field names mirror ``DuDeState``."""

    g_bar: jnp.ndarray      # [P] f32 running aggregated gradient (paper g~)
    g_workers: jnp.ndarray  # [n, P] latest committed gradient per worker
    inflight: jnp.ndarray   # [n, P] gradient latched at job start
    acc_count: jnp.ndarray  # [n] i32 rounds accumulated (accumulate mode)
    step: jnp.ndarray       # scalar i32 server iteration counter


def masks_to_indices_jnp(mask: jnp.ndarray, n: int) -> jnp.ndarray:
    """Traced bool mask [n] -> fixed-width [n] index array padded with n.

    Valid indices sort to the front; entries == n are dropped by the
    scatter's ``mode="drop"``.  Shape-static, so usable under jit (unlike
    host-side ``masks_to_indices``).
    """
    return jnp.sort(jnp.where(mask, jnp.arange(n, dtype=jnp.int32),
                              jnp.int32(n)))


@dataclasses.dataclass(frozen=True)
class DuDeEngine:
    """One DuDe server, one flat state layout, pluggable update backends."""

    spec: FlatSpec
    n_workers: int
    buffer_dtype: Any = jnp.float32
    accumulate: bool = False
    backend: str = "reference"
    interpret: Optional[bool] = None  # pallas only; None = auto (off on TPU)
    # indexed backend: static width of the in-graph index arrays built from
    # masks.  Must bound the max number of simultaneously starting/committing
    # workers — excess valid indices are silently dropped (valid indices sort
    # first, so the bound is on |C_t|, not on n).  None = n (always correct,
    # but the gather/scatter then touches all n rows and saves no traffic).
    index_width: Optional[int] = None

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(
                f"unknown backend {self.backend!r}; options: {BACKENDS}")
        if self.accumulate and self.backend != "reference":
            raise ValueError(
                "accumulate mode is only implemented by the reference "
                f"backend, not {self.backend!r}")
        if self.index_width is not None and not (
                1 <= self.index_width <= self.n_workers):
            raise ValueError(
                f"index_width={self.index_width} outside [1, n_workers]")

    @classmethod
    def for_tree(cls, grad_like: Pytree, n_workers: int, **kw) -> "DuDeEngine":
        """Engine whose flat layout matches ``grad_like``'s pytree layout."""
        return cls(spec=make_flat_spec(grad_like), n_workers=n_workers, **kw)

    # ---------------------------------------------------------- properties

    @property
    def P(self) -> int:
        return self.spec.padded_size

    @property
    def tile(self) -> int:
        # Interpret mode evaluates one Python kernel body per grid step, so
        # collapse to a single [n, P] program; on hardware use the largest
        # tile <= DEFAULT_TILE that divides P (P is a multiple of the pad
        # lane count, so this is always >= PAD_MULTIPLE).
        if self._interpret():
            return self.P
        return math.gcd(self.P, DEFAULT_TILE)

    def _interpret(self) -> bool:
        if self.interpret is not None:
            return self.interpret
        return jax.default_backend() != "tpu"

    # --------------------------------------------------------------- init

    def init(self) -> EngineState:
        n, P = self.n_workers, self.P
        return EngineState(
            g_bar=jnp.zeros((P,), jnp.float32),
            g_workers=jnp.zeros((n, P), self.buffer_dtype),
            inflight=jnp.zeros((n, P), self.buffer_dtype),
            acc_count=jnp.zeros((n,), jnp.int32),
            step=jnp.zeros((), jnp.int32),
        )

    # ------------------------------------------------------------- commit

    def commit(self, state: EngineState, worker: jnp.ndarray,
               grad: jnp.ndarray) -> tuple[EngineState, jnp.ndarray]:
        """Fully-async server iteration (Alg. 1 lines 4-6) on flat ``[P]``.

        O(P) work regardless of backend — there is nothing to fuse or index,
        so all three backends share this implementation.
        """
        g = grad.astype(jnp.float32)
        old = jax.lax.dynamic_index_in_dim(state.g_workers, worker, axis=0,
                                           keepdims=False)
        g_bar = state.g_bar + (g - old.astype(jnp.float32)) / self.n_workers
        g_workers = jax.lax.dynamic_update_index_in_dim(
            state.g_workers, g.astype(state.g_workers.dtype), worker, axis=0)
        st = state._replace(g_bar=g_bar, g_workers=g_workers,
                            step=state.step + 1)
        return st, g_bar

    # -------------------------------------------------------------- round

    def round(self, state: EngineState, fresh: jnp.ndarray,
              start_mask: jnp.ndarray, commit_mask: jnp.ndarray,
              params: Optional[jnp.ndarray] = None,
              eta: Optional[float] = None):
        """Semi-async SPMD round on flat slabs (paper §3 semantics).

        ``fresh`` is the ``[n, P]`` live-model gradient.  Returns
        ``(state, g_bar)``, or ``(state, g_bar, new_params)`` when a flat
        ``params`` vector and ``eta`` are given — the pallas backend folds
        that SGD apply into the same fused pass; the others apply it after.
        """
        if (params is None) != (eta is None):
            raise ValueError("params and eta must be given together")
        sm = start_mask.astype(bool)
        cm = commit_mask.astype(bool)
        new_params = None
        if self.backend == "pallas":
            g_bar, gw, infl, new_params = self._round_pallas(
                state, fresh, sm, cm, params, eta)
        elif self.backend == "indexed":
            n = self.n_workers
            w = self.index_width or n
            g_bar, gw, infl = self._round_indexed(
                state, fresh, masks_to_indices_jnp(sm, n)[:w],
                masks_to_indices_jnp(cm, n)[:w])
        else:
            g_bar, gw, infl = self._round_reference(state, fresh, sm, cm)
        if params is not None and new_params is None:
            new_params = (params.astype(jnp.float32)
                          - jnp.float32(eta) * g_bar).astype(params.dtype)
        st = EngineState(
            g_bar=g_bar, g_workers=gw, inflight=infl,
            acc_count=jnp.where(sm, 1, state.acc_count + 1).astype(jnp.int32),
            step=state.step + 1,
        )
        if params is None:
            return st, g_bar
        return st, g_bar, new_params

    def round_indexed(self, state: EngineState, fresh: jnp.ndarray,
                      start_idx: jnp.ndarray, commit_idx: jnp.ndarray
                      ) -> tuple[EngineState, jnp.ndarray]:
        """Round with host-precomputed padded index arrays (legacy entry
        point of the indexed backend; indices == n are dropped)."""
        g_bar, gw, infl = self._round_indexed(state, fresh, start_idx,
                                              commit_idx)
        st = EngineState(
            g_bar=g_bar, g_workers=gw, inflight=infl,
            acc_count=state.acc_count, step=state.step + 1,
        )
        return st, g_bar

    # ----------------------------------------------------------- backends

    def _round_reference(self, state, fresh, sm, cm):
        """Masked full sweep over all n rows (paper-faithful oracle)."""
        g32 = fresh.astype(jnp.float32)
        infl32 = state.inflight.astype(jnp.float32)
        gw32 = state.g_workers.astype(jnp.float32)
        delta = cm.astype(jnp.float32)[:, None] * (infl32 - gw32)
        g_bar = state.g_bar + jnp.sum(delta, axis=0) / self.n_workers
        bdt = state.g_workers.dtype
        gw = jnp.where(cm[:, None], infl32.astype(bdt), state.g_workers)
        if self.accumulate:
            # running mean over the job's rounds (beyond-paper variant)
            cnt = state.acc_count.astype(jnp.float32)
            w_new = (1.0 / jnp.where(sm, 1.0, cnt + 1.0))[:, None]
            infl = (infl32 * (1.0 - w_new) + g32 * w_new).astype(bdt)
        else:
            infl = jnp.where(sm[:, None], g32.astype(bdt), state.inflight)
        return g_bar, gw, infl

    def _round_indexed(self, state, fresh, start_idx, commit_idx):
        """Gather/scatter on the k selected rows only (~4kP HBM bytes)."""
        n = self.n_workers
        bdt = state.g_workers.dtype
        rows_in = jnp.take(state.inflight, commit_idx, axis=0, mode="fill",
                           fill_value=0).astype(jnp.float32)
        rows_gw = jnp.take(state.g_workers, commit_idx, axis=0, mode="fill",
                           fill_value=0).astype(jnp.float32)
        valid = (commit_idx < n).astype(jnp.float32)[:, None]
        g_bar = state.g_bar + jnp.sum((rows_in - rows_gw) * valid, axis=0) / n
        gw = state.g_workers.at[commit_idx].set(rows_in.astype(bdt),
                                                mode="drop")
        fresh_rows = jnp.take(fresh.astype(jnp.float32), start_idx, axis=0,
                              mode="fill", fill_value=0)
        infl = state.inflight.at[start_idx].set(fresh_rows.astype(bdt),
                                                mode="drop")
        return g_bar, gw, infl

    def _round_pallas(self, state, fresh, sm, cm, params, eta):
        """Fused single-pass kernel; optional in-pass SGD apply."""
        w = params if params is not None else jnp.zeros_like(state.g_bar)
        gw, infl, g_bar, w_new = dude_update_pallas(
            cm, sm, fresh.astype(jnp.float32), state.g_workers,
            state.inflight, state.g_bar, w,
            eta=float(eta) if eta is not None else 0.0,
            tile=self.tile, interpret=self._interpret(),
        )
        return g_bar, gw, infl, (w_new if params is not None else None)

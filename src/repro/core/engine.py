"""ServerEngine: the DuDe server iteration on one flat buffer layout.

Every server-side algorithm in this repo ultimately streams over Theta(n * p)
buffer state.  ``DuDeEngine`` owns that state in ONE canonical layout —
``g_bar`` as a padded flat ``[P]`` f32 vector, ``g_workers``/``inflight`` as
``[n, P]`` slabs in the configured buffer dtype — and exposes the two paper
entry points (``commit`` for the fully-async mode, ``round`` for the
semi-async SPMD mode), plus ``round_apply`` — the round fused with a flat
optimizer apply on ``[P]`` master params and slot slabs (the flat-state
training path) — over three interchangeable backends:

* ``"reference"`` — masked jnp sweep over all n rows; the paper-faithful
  oracle (identical math to the historical ``dude_round``), and the only
  backend supporting the beyond-paper ``accumulate`` variant.
* ``"indexed"``   — gather/scatter touching only the selected rows.  The
  traffic saving (~4kP instead of ~4nP bytes per round) requires a static
  bound k on the active set: set ``index_width`` (the schedule usually
  knows max |C_t|), or use ``round_indexed`` with host-narrowed arrays.
  With the default width n the mask path is correct but saves nothing.
* ``"pallas"``    — the fused TPU kernel (``kernels/dude_update.py``): one
  pass over all five streams, optionally folding the SGD parameter update
  into the same pass.  Runs under ``interpret=True`` on CPU.

Backends agree bit-for-bit on ``g_bar`` (all accumulate the commit delta in
f32) and on the buffers up to the shared buffer-dtype rounding; the
equivalence is enforced by ``tests/test_engine.py``.

Mesh-native mode: give the engine ``(mesh, axis_name)`` and every entry
point runs under ``shard_map`` with the P axis split into the contiguous
segment ranges of the spec's shard table (``FlatSpec.shard_ranges``) —
``g_bar`` as ``P(axis)``, the ``[n, P]`` slabs as ``P(None, axis)``, masks
and scalars replicated.  The round is elementwise on P (the worker-axis sum
is local to each P-shard), so a sharded round moves ZERO bytes across
devices; the fused Pallas backend runs per shard with
``tile = gcd(P/k, DEFAULT_TILE)``.  The spec must be built shard-aligned:
``make_flat_spec(tree, mesh_axis_size=k)`` with ``k`` the product of the
chosen mesh axes.  Sharded and unsharded engines agree bit-for-bit on
``g_bar`` (``tests/test_engine_sharded.py``).

``core/dude.py`` re-exports the historical pytree API (``dude_commit`` /
``dude_round`` / ``dude_round_indexed``) as thin ravel->engine->unravel
wrappers, so callers keep pytree ergonomics while the hot loop runs on flat
slabs.

Documented in docs/engine.md — "Backends", "Sharding the flat layout" and
"Flat training state" (``round_apply``); ``commit`` is the per-arrival hot
path of the async runtime (docs/async.md, "Arrival-granularity
algorithms").
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.experimental import checkify
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec

from .compression import (
    COMMIT_FORMATS, CommitCodec, SparseRow, touched_tiles,
)
from .flatten import FlatSpec, make_flat_spec
from ..kernels.dude_update import (
    DEFAULT_TILE, SLOT_STREAMS, dude_round_apply_pallas,
    dude_round_apply_q_pallas, dude_round_apply_sparse_pallas,
    dude_update_pallas,
)
from ..optim.transforms import FlatOptState, FlatOptimizer

Pytree = Any

__all__ = ["BACKENDS", "EngineState", "DuDeEngine", "masks_to_indices_jnp"]

BACKENDS = ("reference", "indexed", "pallas")

INDEX_CHECKS = ("debug", "checkify", "off")


class EngineState(NamedTuple):
    """Flat DuDe server state.  Field names mirror ``DuDeState``.

    The trailing three fields exist only under a compressed
    ``commit_format`` (``int8_ef`` / ``topk_ef``): the slabs then hold int8
    payloads, ``gw_scale``/``infl_scale`` hold their per-128-lane-tile f32
    scales, and ``ef`` carries the commit-stream error-feedback residual.
    Under ``"f32"`` they stay ``None`` — ``None`` leaves vanish from jax
    pytrees, so the f32 state keeps the exact historical flatten structure,
    checkpoint paths, and shardings (bit-for-bit compatibility).
    """

    g_bar: jnp.ndarray      # [P] f32 running aggregated gradient (paper g~)
    g_workers: jnp.ndarray  # [n, P] latest committed gradient per worker
    inflight: jnp.ndarray   # [n, P] gradient latched at job start
    acc_count: jnp.ndarray  # [n] i32 rounds accumulated (accumulate mode)
    step: jnp.ndarray       # scalar i32 server iteration counter
    gw_scale: Any = None    # [n, P/128] f32 scales of g_workers (compressed)
    infl_scale: Any = None  # [n, P/128] f32 scales of inflight (compressed)
    ef: Any = None          # [P] f32 commit-stream EF residual (compressed)
    # sparse_meta engines (topk_ef + SparseRow transport) additionally track
    # which 128-lane tiles of each slab row hold any nonzero payload — the
    # invariant "bitmap == touched_tiles(q row)" holds after every entry
    # point, so sparse commits/rounds may skip the untouched tiles exactly.
    gw_touched: Any = None  # [n, P/128] int8 touched-tile bitmap, g_workers
    in_touched: Any = None  # [n, P/128] int8 touched-tile bitmap, inflight
    # indexed backend: running count of commits/latches dropped because a
    # round's active set exceeded index_width (satellite of index_check;
    # surfaced in Trainer.step metrics as "engine_drops").
    drops: Any = None       # [] i32


def masks_to_indices_jnp(mask: jnp.ndarray, n: int) -> jnp.ndarray:
    """Traced bool mask [n] -> fixed-width [n] index array padded with n.

    Valid indices sort to the front; entries == n are dropped by the
    scatter's ``mode="drop"``.  Shape-static, so usable under jit (unlike
    host-side ``masks_to_indices``).
    """
    return jnp.sort(jnp.where(mask, jnp.arange(n, dtype=jnp.int32),
                              jnp.int32(n)))


@dataclasses.dataclass(frozen=True)
class DuDeEngine:
    """One DuDe server, one flat state layout, pluggable update backends."""

    spec: FlatSpec
    n_workers: int
    buffer_dtype: Any = jnp.float32
    accumulate: bool = False
    backend: str = "reference"
    interpret: Optional[bool] = None  # pallas only; None = auto (off on TPU)
    # indexed backend: static width of the in-graph index arrays built from
    # masks.  Must bound the max number of simultaneously starting/committing
    # workers — excess valid indices are dropped (valid indices sort first,
    # so the bound is on |C_t|, not on n).  None = n (always correct, but the
    # gather/scatter then touches all n rows and saves no traffic).  Overflow
    # is detected per round according to ``index_check``.
    index_width: Optional[int] = None
    # "debug"    — jax.debug.print a warning from inside the jitted round
    #              whenever a mask round has more active workers than
    #              index_width (commits silently dropped otherwise);
    # "checkify" — checkify.check instead: wrap the round with
    #              jax.experimental.checkify.checkify to surface the error
    #              as a real exception;
    # "off"      — no check (the seed's silent-drop behavior).
    index_check: str = "debug"
    # Mesh-native mode: run every entry point under shard_map with the P
    # axis sharded over ``axis_name`` (a mesh axis name or tuple of names;
    # None = all axes of ``mesh``).  Requires a shard-aligned spec:
    # make_flat_spec(tree, mesh_axis_size=<product of those axes>).
    mesh: Optional[Mesh] = None
    axis_name: Any = None
    # Slab storage / commit wire format (core/compression.py).  "f32" is the
    # historical full-precision layout; "int8_ef" / "topk_ef" store the
    # [n, P] slabs as int8 payloads + per-128-lane-tile f32 scale slabs and
    # add a [P] error-feedback residual on the commit stream.  The configured
    # buffer_dtype only applies to the f32 format.
    commit_format: str = "f32"
    # Sparse commit transport (topk_ef only): EngineState carries per-row
    # touched-tile bitmaps, commits may arrive as index-carrying SparseRows
    # scatter-decoded straight into the slab (commit_sparse /
    # encode_sparse_commit + sparse_fold), and the round backends fold only
    # the touched tiles of the committed rows into g_bar.  sparse_cap bounds
    # the static touched-tile slots of a SparseRow commit (None = all tiles
    # — always correct; smaller caps bound the wire bytes, overflow re-enters
    # through error feedback).  docs/engine.md "Sparse commit transport".
    sparse_meta: bool = False
    sparse_cap: Optional[int] = None

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(
                f"unknown backend {self.backend!r}; options: {BACKENDS}")
        if self.accumulate and self.backend != "reference":
            raise ValueError(
                "accumulate mode is only implemented by the reference "
                f"backend, not {self.backend!r}")
        if self.commit_format not in COMMIT_FORMATS:
            raise ValueError(
                f"unknown commit_format {self.commit_format!r}; "
                f"options: {COMMIT_FORMATS}")
        if self.accumulate and self.commit_format != "f32":
            raise ValueError(
                "accumulate mode re-averages the in-flight rows every round "
                "and cannot keep quantized slabs exact; it requires "
                "commit_format='f32'")
        if self.index_width is not None and not (
                1 <= self.index_width <= self.n_workers):
            raise ValueError(
                f"index_width={self.index_width} outside [1, n_workers]")
        if self.index_check not in INDEX_CHECKS:
            raise ValueError(
                f"unknown index_check {self.index_check!r}; "
                f"options: {INDEX_CHECKS}")
        if self.sparse_meta and self.commit_format != "topk_ef":
            raise ValueError(
                "sparse_meta (SparseRow commit transport) requires "
                f"commit_format='topk_ef', not {self.commit_format!r}")
        if self.sparse_cap is not None:
            if not self.sparse_meta:
                raise ValueError("sparse_cap requires sparse_meta=True")
            if not 1 <= self.sparse_cap <= self.n_tiles:
                raise ValueError(
                    f"sparse_cap={self.sparse_cap} outside "
                    f"[1, {self.n_tiles}]")
        if self.mesh is not None:
            missing = [a for a in self.paxes if a not in self.mesh.shape]
            if missing:
                raise ValueError(
                    f"axis_name {missing} not in mesh axes "
                    f"{tuple(self.mesh.axis_names)}")
            k = self.axis_size
            if self.P % k != 0:
                raise ValueError(
                    f"P={self.P} not divisible by the {k}-way P-axis mesh; "
                    f"build the spec with make_flat_spec(tree, "
                    f"mesh_axis_size={k})")

    @classmethod
    def for_tree(cls, grad_like: Pytree, n_workers: int, **kw) -> "DuDeEngine":
        """Engine whose flat layout matches ``grad_like``'s pytree layout."""
        mesh = kw.get("mesh")
        k = 1
        if mesh is not None:
            axes = kw.get("axis_name") or tuple(mesh.axis_names)
            if isinstance(axes, str):
                axes = (axes,)
            for a in axes:
                k *= mesh.shape[a]
        return cls(spec=make_flat_spec(grad_like, mesh_axis_size=k),
                   n_workers=n_workers, **kw)

    # ---------------------------------------------------------- properties

    @property
    def P(self) -> int:
        return self.spec.padded_size

    @property
    def codec(self) -> CommitCodec:
        return CommitCodec(format=self.commit_format)

    @property
    def compressed(self) -> bool:
        return self.commit_format != "f32"

    @property
    def n_tiles(self) -> int:
        """Scale tiles per row (P / 128; the scale-slab trailing dim)."""
        return self.codec.n_tiles(self.P)

    @property
    def cap_tiles(self) -> int:
        """Static touched-tile capacity of one ``SparseRow`` commit
        (``sparse_cap``, defaulting to all tiles)."""
        return self.codec.sparse_cap(self.P, self.sparse_cap)

    @property
    def paxes(self) -> tuple:
        """Mesh axis names carrying the P shard (empty when unsharded)."""
        if self.mesh is None:
            return ()
        if self.axis_name is None:
            return tuple(self.mesh.axis_names)
        if isinstance(self.axis_name, str):
            return (self.axis_name,)
        return tuple(self.axis_name)

    @property
    def axis_size(self) -> int:
        """Number of P-axis shards (1 when unsharded)."""
        k = 1
        for a in self.paxes:
            k *= self.mesh.shape[a]
        return k

    @property
    def shard_P(self) -> int:
        """Per-device slice of the P axis (== P when unsharded)."""
        return self.P // self.axis_size

    @property
    def tile(self) -> int:
        # Interpret mode evaluates one Python kernel body per grid step, so
        # collapse to a single [n, P/k] program; on hardware use the largest
        # tile <= DEFAULT_TILE that divides the local shard (P/k is a
        # multiple of the pad lane count, so this is always >= PAD_MULTIPLE).
        if self._interpret():
            return self.shard_P
        return math.gcd(self.shard_P, DEFAULT_TILE)

    def _interpret(self) -> bool:
        if self.interpret is not None:
            return self.interpret
        return jax.default_backend() != "tpu"

    # ----------------------------------------------------------- sharding

    def shardings(self) -> EngineState:
        """NamedShardings for ``EngineState`` on this engine's mesh."""
        if self.mesh is None:
            raise ValueError("engine has no mesh")
        from ..sharding.specs import engine_state_shardings
        return engine_state_shardings(self.spec, self.mesh, self.paxes,
                                      like=self.state_shapes())

    def tp_plan(self, param_sh: Pytree):
        """The TP-native exchange plan between this engine's P-shards and
        the given Megatron-TP param shardings (``flat_to_tp_plan`` on the
        engine's mesh and P-axis group; cached).  Feed it to
        ``spec.unravel_sharded`` / ``spec.ravel_stacked_sharded`` so the
        train step never materializes the full ``[P]`` vector."""
        if self.mesh is None:
            raise ValueError("engine has no mesh")
        return self.spec.tp_plan(self.mesh, param_sh, axes=self.paxes)

    def _pspecs(self):
        """(vec, row, repl, state) PartitionSpecs for shard_map plumbing.

        Scale slabs ``[n, P/128]`` shard their trailing dim over the same P
        axes — tile boundaries align with shard boundaries because P/k is a
        multiple of 128, so P/128 is a multiple of k.
        """
        vec = PartitionSpec(self.paxes)
        row = PartitionSpec(None, self.paxes)
        repl = PartitionSpec()
        kw = {}
        if self.compressed:
            kw.update(gw_scale=row, infl_scale=row, ef=vec)
        if self.sparse_meta:
            kw.update(gw_touched=row, in_touched=row)
        if self.backend == "indexed":
            kw.update(drops=repl)
        st = EngineState(vec, row, row, repl, repl, **kw)
        return vec, row, repl, st

    def _shmap(self, body, in_specs, out_specs):
        return shard_map(body, mesh=self.mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)

    # --------------------------------------------------------------- init

    def _extra_fields(self, n: int, t: int, make) -> dict:
        """The optional trailing ``EngineState`` fields this engine carries
        (``make(shape, dtype)`` builds each leaf — zeros or SDS)."""
        kw = {}
        if self.sparse_meta:
            kw.update(gw_touched=make((n, t), jnp.int8),
                      in_touched=make((n, t), jnp.int8))
        if self.backend == "indexed":
            kw.update(drops=make((), jnp.int32))
        return kw

    def init(self) -> EngineState:
        n, P = self.n_workers, self.P
        if self.compressed:
            t = self.n_tiles
            state = EngineState(
                g_bar=jnp.zeros((P,), jnp.float32),
                g_workers=jnp.zeros((n, P), jnp.int8),
                inflight=jnp.zeros((n, P), jnp.int8),
                acc_count=jnp.zeros((n,), jnp.int32),
                step=jnp.zeros((), jnp.int32),
                gw_scale=jnp.zeros((n, t), jnp.float32),
                infl_scale=jnp.zeros((n, t), jnp.float32),
                ef=jnp.zeros((P,), jnp.float32),
                **self._extra_fields(n, t, jnp.zeros),
            )
        else:
            state = EngineState(
                g_bar=jnp.zeros((P,), jnp.float32),
                g_workers=jnp.zeros((n, P), self.buffer_dtype),
                inflight=jnp.zeros((n, P), self.buffer_dtype),
                acc_count=jnp.zeros((n,), jnp.int32),
                step=jnp.zeros((), jnp.int32),
                **self._extra_fields(n, self.n_tiles, jnp.zeros),
            )
        if self.mesh is not None:
            state = jax.device_put(state, self.shardings())
        return state

    def state_shapes(self) -> EngineState:
        """Abstract ``EngineState`` (ShapeDtypeStructs) for lowering."""
        n, P = self.n_workers, self.P
        sds = jax.ShapeDtypeStruct
        if self.compressed:
            t = self.n_tiles
            return EngineState(
                g_bar=sds((P,), jnp.float32),
                g_workers=sds((n, P), jnp.int8),
                inflight=sds((n, P), jnp.int8),
                acc_count=sds((n,), jnp.int32),
                step=sds((), jnp.int32),
                gw_scale=sds((n, t), jnp.float32),
                infl_scale=sds((n, t), jnp.float32),
                ef=sds((P,), jnp.float32),
                **self._extra_fields(n, t, sds),
            )
        return EngineState(
            g_bar=sds((P,), jnp.float32),
            g_workers=sds((n, P), self.buffer_dtype),
            inflight=sds((n, P), self.buffer_dtype),
            acc_count=sds((n,), jnp.int32),
            step=sds((), jnp.int32),
            **self._extra_fields(n, self.n_tiles, sds),
        )

    # ------------------------------------------------------------- commit

    def commit(self, state: EngineState, worker: jnp.ndarray,
               grad: jnp.ndarray) -> tuple[EngineState, jnp.ndarray]:
        """Fully-async server iteration (Alg. 1 lines 4-6) on flat ``[P]``.

        O(P) work regardless of backend — there is nothing to fuse or index,
        so all three backends share this implementation.  Elementwise on P,
        so the sharded path is communication-free.

        Compressed formats quantize ``g + ef`` with error feedback and store
        the quantized row itself (payload + per-tile scales), so
        ``g_bar == mean_i dec(g_workers[i])`` holds exactly and
        ``dec + ef' == g + ef`` holds bitwise (core/compression.py).
        Per-shard encoding equals global encoding because scale tiles align
        with P-shard boundaries, so the sharded commit stays collective-free.
        """
        if self.compressed:
            return self._commit_q(state, worker, grad)

        def body(g_bar, g_workers, w, g):
            g = g.astype(jnp.float32)
            old = jax.lax.dynamic_index_in_dim(g_workers, w, axis=0,
                                               keepdims=False)
            g_bar = g_bar + (g - old.astype(jnp.float32)) / self.n_workers
            g_workers = jax.lax.dynamic_update_index_in_dim(
                g_workers, g.astype(g_workers.dtype), w, axis=0)
            return g_bar, g_workers

        if self.mesh is not None:
            vec, row, repl, _ = self._pspecs()
            body = self._shmap(body, in_specs=(vec, row, repl, vec),
                               out_specs=(vec, row))
        g_bar, g_workers = body(state.g_bar, state.g_workers, worker, grad)
        st = state._replace(g_bar=g_bar, g_workers=g_workers,
                            step=state.step + 1)
        return st, g_bar

    def _commit_q(self, state: EngineState, worker: jnp.ndarray,
                  grad: jnp.ndarray) -> tuple[EngineState, jnp.ndarray]:
        codec = self.codec
        sparse = state.gw_touched is not None

        def body(g_bar, gw_q, gw_s, ef, w, g, *targs):
            q, s, dec, ef_new = codec.encode_commit(g.astype(jnp.float32), ef)
            old_q = jax.lax.dynamic_index_in_dim(gw_q, w, axis=0,
                                                 keepdims=False)
            old_s = jax.lax.dynamic_index_in_dim(gw_s, w, axis=0,
                                                 keepdims=False)
            dec_old = codec.decode(old_q, old_s)
            g_bar = g_bar + (dec - dec_old) / self.n_workers
            gw_q = jax.lax.dynamic_update_index_in_dim(gw_q, q, w, axis=0)
            gw_s = jax.lax.dynamic_update_index_in_dim(gw_s, s, w, axis=0)
            out = (g_bar, gw_q, gw_s, ef_new)
            if sparse:
                # keep the invariant "bitmap == touched_tiles(q row)"
                gw_t = jax.lax.dynamic_update_index_in_dim(
                    targs[0], touched_tiles(q).astype(jnp.int8), w, axis=0)
                out += (gw_t,)
            return out

        targs = (state.gw_touched,) if sparse else ()
        if self.mesh is not None:
            vec, row, repl, _ = self._pspecs()
            body = self._shmap(
                body,
                in_specs=(vec, row, row, vec, repl, vec)
                + (row,) * len(targs),
                out_specs=(vec, row, row, vec) + (row,) * len(targs))
        out = body(state.g_bar, state.g_workers, state.gw_scale, state.ef,
                   worker, grad, *targs)
        st = state._replace(g_bar=out[0], g_workers=out[1], gw_scale=out[2],
                            ef=out[3], step=state.step + 1)
        if sparse:
            st = st._replace(gw_touched=out[4])
        return st, out[0]

    # -------------------------------------------- sparse commit transport

    def _require_sparse(self, state: EngineState):
        if not self.sparse_meta or state.gw_touched is None:
            raise ValueError(
                "SparseRow transport needs an engine built with "
                "sparse_meta=True (and a state initialized by it)")

    def encode_sparse_commit(self, state: EngineState, worker: jnp.ndarray,
                             grad: jnp.ndarray
                             ) -> tuple[EngineState, SparseRow]:
        """Sender half of the sparse commit: encode one worker's gradient as
        a ``SparseRow`` and advance the error-feedback residual.

        The row's "clear set" is the worker's current touched bitmap — every
        tile the slab holds nonzero for this worker is listed (possibly with
        an all-zero payload), so ``sparse_fold`` can overwrite it and the
        row-replace semantics of ``commit`` are preserved.  Dense O(P) math
        (it reads the full gradient), but the OUTPUT is the O(k * cap) wire
        row; pair with ``sparse_fold`` on the receiver.  ``step`` advances in
        the fold, not here.
        """
        self._require_sparse(state)
        prev = jax.lax.dynamic_index_in_dim(
            state.gw_touched, worker, axis=0, keepdims=False) != 0
        row, ef_new = self.codec.sparse_encode_commit(
            grad.astype(jnp.float32), state.ef, cap=self.cap_tiles,
            include=prev)
        return state._replace(ef=ef_new), row

    def sparse_fold(self, state: EngineState, worker: jnp.ndarray,
                    row: SparseRow) -> tuple[EngineState, jnp.ndarray]:
        """Receiver half: scatter-decode a ``SparseRow`` straight into the
        stored int8 slab row — zero dense ``[P]`` intermediates.

        Work is O(cap * 128): gather the old payload of exactly the listed
        tiles, scatter-add ``(dec_new - dec_old) / n`` into ``g_bar``, and
        scatter payload + scales + bitmap back.  ``g_bar`` matches the dense
        ``commit`` bit-for-bit (untouched tiles would contribute exact +0.0
        there); slab scales of never-listed tiles may go stale vs a dense
        commit, which is decode-invisible (their payload is zero).  Under a
        mesh the row is replicated — it IS the wire format, a few KB — and
        each P-shard folds only its own tiles via a global->local id shift.
        """
        self._require_sparse(state)
        n = self.n_workers
        qtile = self.codec.tile

        def body(g_bar, gw_q, gw_s, gw_t, w, tiles, lanes, vals, scales):
            p_loc = g_bar.shape[0]
            t_loc = p_loc // qtile
            off = jnp.int32(0)
            for a in self.paxes:
                off = off * self.mesh.shape[a] + jax.lax.axis_index(a)
            loc = tiles - off * t_loc
            live = (loc >= 0) & (loc < t_loc)   # pad sentinel (== T) too
            loc = jnp.where(live, loc, t_loc)
            cap, k = lanes.shape
            rows_i = jax.lax.broadcasted_iota(jnp.int32, (cap, k), 0)
            # new tile images [cap, 128]: pad lanes (== 128) are dropped
            img = jnp.zeros((cap, qtile), jnp.int8).at[
                rows_i, lanes.astype(jnp.int32)].set(vals, mode="drop")
            lpos = loc[:, None] * qtile + jax.lax.broadcasted_iota(
                jnp.int32, (cap, qtile), 1)
            lpos = jnp.where(live[:, None], lpos, p_loc)
            old = gw_q.at[w, lpos].get(mode="fill", fill_value=0)
            old_s = gw_s.at[w, loc].get(mode="fill", fill_value=0.0)
            dec_new = img.astype(jnp.float32) * scales[:, None]
            dec_old = old.astype(jnp.float32) * old_s[:, None]
            # gather / elementwise / scatter-SET — NOT a scatter-add: the
            # fold expression must be the exact elementwise graph the dense
            # commit runs (`g_bar + (dec - dec_old) / n`) so XLA gives both
            # the same fused lowering; an add-combining scatter rounds the
            # update separately and can differ in the last bit
            gb_old = g_bar.at[lpos].get(mode="fill", fill_value=0.0)
            g_bar = g_bar.at[lpos].set(gb_old + (dec_new - dec_old) / n,
                                       mode="drop")
            gw_q = gw_q.at[w, lpos].set(img, mode="drop")
            gw_s = gw_s.at[w, loc].set(scales, mode="drop")
            gw_t = gw_t.at[w, loc].set(
                jnp.any(img != 0, axis=-1).astype(jnp.int8), mode="drop")
            return g_bar, gw_q, gw_s, gw_t

        if self.mesh is not None:
            vec, rsp, repl, _ = self._pspecs()
            body = self._shmap(
                body,
                in_specs=(vec, rsp, rsp, rsp, repl, repl, repl, repl, repl),
                out_specs=(vec, rsp, rsp, rsp))
        g_bar, gw_q, gw_s, gw_t = body(
            state.g_bar, state.g_workers, state.gw_scale, state.gw_touched,
            worker, row.tiles, row.lanes, row.vals, row.scales)
        st = state._replace(g_bar=g_bar, g_workers=gw_q, gw_scale=gw_s,
                            gw_touched=gw_t, step=state.step + 1)
        return st, g_bar

    def commit_sparse(self, state: EngineState, worker: jnp.ndarray,
                      grad: jnp.ndarray) -> tuple[EngineState, jnp.ndarray]:
        """Sparse-transport twin of ``commit``: encode then fold.  ``g_bar``
        and the EF residual match the dense commit bit-for-bit whenever the
        touched set fits ``sparse_cap`` (overflow degrades gracefully — the
        dropped tiles' targets re-enter through error feedback)."""
        state, row = self.encode_sparse_commit(state, worker, grad)
        return self.sparse_fold(state, worker, row)

    # -------------------------------------------------------------- round

    def round(self, state: EngineState, fresh: jnp.ndarray,
              start_mask: jnp.ndarray, commit_mask: jnp.ndarray,
              params: Optional[jnp.ndarray] = None,
              eta: Optional[float] = None):
        """Semi-async SPMD round on flat slabs (paper §3 semantics).

        ``fresh`` is the ``[n, P]`` live-model gradient.  Returns
        ``(state, g_bar)``, or ``(state, g_bar, new_params)`` when a flat
        ``params`` vector and ``eta`` are given — the pallas backend folds
        that SGD apply into the same fused pass; the others apply it after.
        """
        if (params is None) != (eta is None):
            raise ValueError("params and eta must be given together")
        sm = start_mask.astype(bool)
        cm = commit_mask.astype(bool)
        self._index_overflow_check(sm, cm)
        g_bar, gw, infl, scales, touched, new_params = self._run_backend(
            state, fresh, sm, cm, params, eta)
        st = state._replace(
            g_bar=g_bar, g_workers=gw, inflight=infl,
            acc_count=jnp.where(sm, 1, state.acc_count + 1).astype(jnp.int32),
            step=state.step + 1,
        )
        if scales is not None:
            st = st._replace(gw_scale=scales[0], infl_scale=scales[1])
        if touched is not None:
            st = st._replace(gw_touched=touched[0], in_touched=touched[1])
        st = self._count_drops(st, sm, cm)
        if params is None:
            return st, g_bar
        return st, g_bar, new_params

    def round_indexed(self, state: EngineState, fresh: jnp.ndarray,
                      start_idx: jnp.ndarray, commit_idx: jnp.ndarray
                      ) -> tuple[EngineState, jnp.ndarray]:
        """Round with host-precomputed padded index arrays (legacy entry
        point of the indexed backend; indices == n are dropped)."""
        if self.accumulate:
            raise ValueError(
                "round_indexed cannot express the accumulate running-mean "
                "latch; use round() with the reference backend")

        if self.sparse_meta:
            def body(st, f, si, ci):
                return self._round_sparse_indexed(st, f, si, ci)
            out_arity = 7
        elif self.compressed:
            def body(st, f, si, ci):
                return self._round_indexed_q(st, f, si, ci)
            out_arity = 5
        else:
            def body(st, f, si, ci):
                return self._round_indexed(st, f, si, ci)
            out_arity = 3

        if self.mesh is not None:
            vec, row, repl, sspec = self._pspecs()
            out_specs = (vec, row, row) + (row,) * (out_arity - 3)
            body = self._shmap(body, in_specs=(sspec, row, repl, repl),
                               out_specs=out_specs)
        out = body(state, fresh, start_idx, commit_idx)
        g_bar, gw, infl = out[:3]
        # acc_count follows the same rule as round(): a worker starting a job
        # this round resets its counter, everyone else accumulates.
        sm = jnp.zeros((self.n_workers,), bool).at[start_idx].set(
            True, mode="drop")
        st = state._replace(
            g_bar=g_bar, g_workers=gw, inflight=infl,
            acc_count=jnp.where(sm, 1, state.acc_count + 1).astype(jnp.int32),
            step=state.step + 1,
        )
        if out_arity >= 5:
            st = st._replace(gw_scale=out[3], infl_scale=out[4])
        if out_arity == 7:
            st = st._replace(gw_touched=out[5], in_touched=out[6])
        return st, g_bar

    # -------------------------------------------------- fused round+apply

    def round_apply(self, state: EngineState, fresh: jnp.ndarray,
                    start_mask: jnp.ndarray, commit_mask: jnp.ndarray,
                    params: jnp.ndarray, opt_state: FlatOptState,
                    opt: FlatOptimizer):
        """DuDe round fused with the flat optimizer apply, under ONE
        shard_map.

        ``params`` is the flat ``[P]`` f32 master-parameter vector and
        ``opt_state`` the flat slot slabs (``optim.transforms``), both
        sharded exactly like ``g_bar``.  The optimizer step is elementwise
        on P (its only scalar input, the replicated step counter, rides
        along), so the whole server iteration — commit, latch, slot update,
        parameter step — moves ZERO bytes between devices.  The pallas
        backend streams the slots through the fused kernel
        (``dude_round_apply_pallas``); the other backends run the round and
        then ``opt.update`` inside the same shard_map body.

        Returns ``(state', g_bar, params', opt_state')``.
        """
        sm = start_mask.astype(bool)
        cm = commit_mask.astype(bool)
        self._index_overflow_check(sm, cm)
        t_new = opt_state.step + 1
        slots = opt_state.slots
        fused = self.backend == "pallas" and opt.name in SLOT_STREAMS
        codec = self.codec

        def body(st, f, a, b, w, t, sl):
            touched = ()
            if fused:
                bc = None
                if opt.name == "adamw":
                    hp = opt.hp
                    t32 = t.astype(jnp.float32)
                    bc = jnp.stack([1 - hp["b1"] ** t32, 1 - hp["b2"] ** t32])
                leaves, sdef = jax.tree_util.tree_flatten(sl)
                if self.sparse_meta:
                    (gw, gw_s, gw_t, infl, infl_s, in_t, g_bar, w_new,
                     new_leaves) = dude_round_apply_sparse_pallas(
                        b, a, self._sparse_blk(st, b),
                        f.astype(jnp.float32), st.g_workers, st.gw_scale,
                        st.gw_touched, st.inflight, st.infl_scale,
                        st.in_touched, st.g_bar, w, tuple(leaves), bc,
                        kind=opt.name, hp=opt.hparams, topk=codec.topk,
                        tile=self.tile, interpret=self._interpret())
                    scales = (gw_s, infl_s)
                    touched = (gw_t, in_t)
                elif self.compressed:
                    (gw, gw_s, infl, infl_s, g_bar, w_new,
                     new_leaves) = dude_round_apply_q_pallas(
                        b, a, f.astype(jnp.float32), st.g_workers,
                        st.gw_scale, st.inflight, st.infl_scale, st.g_bar,
                        w, tuple(leaves), bc, kind=opt.name, hp=opt.hparams,
                        fmt=codec.format, topk=codec.topk, tile=self.tile,
                        interpret=self._interpret())
                    scales = (gw_s, infl_s)
                else:
                    gw, infl, g_bar, w_new, new_leaves = \
                        dude_round_apply_pallas(
                            b, a, f.astype(jnp.float32), st.g_workers,
                            st.inflight, st.g_bar, w, tuple(leaves), bc,
                            kind=opt.name, hp=opt.hparams, tile=self.tile,
                            interpret=self._interpret())
                    scales = ()
                sl_new = jax.tree_util.tree_unflatten(sdef, list(new_leaves))
            else:
                if self.compressed:
                    out = self._round_plain_q(st, f, a, b)
                    g_bar, gw, infl = out[:3]
                    scales = out[3:5]
                    touched = out[5:7]   # () unless sparse_meta
                else:
                    g_bar, gw, infl = self._round_plain(st, f, a, b)
                    scales = ()
                w_new, sl_new = opt.update(w, g_bar, sl, t)
            return (g_bar, gw, infl, w_new, sl_new) + scales + touched

        n_touch = 2 if self.sparse_meta else 0
        if self.mesh is not None:
            vec, row, repl, sspec = self._pspecs()
            slot_specs = jax.tree.map(lambda _: vec, slots)
            scale_specs = (row, row) if self.compressed else ()
            body = self._shmap(
                body,
                in_specs=(sspec, row, repl, repl, vec, repl, slot_specs),
                out_specs=(vec, row, row, vec, slot_specs) + scale_specs
                + (row,) * n_touch)
        out = body(state, fresh, sm, cm, params, t_new, slots)
        g_bar, gw, infl, w_new, sl_new = out[:5]
        st = state._replace(
            g_bar=g_bar, g_workers=gw, inflight=infl,
            acc_count=jnp.where(sm, 1, state.acc_count + 1).astype(jnp.int32),
            step=state.step + 1,
        )
        if self.compressed:
            st = st._replace(gw_scale=out[5], infl_scale=out[6])
        if n_touch:
            st = st._replace(gw_touched=out[7], in_touched=out[8])
        st = self._count_drops(st, sm, cm)
        return st, g_bar, w_new, FlatOptState(t_new, sl_new)

    # ----------------------------------------------------- backend driver

    def _round_plain(self, st, f, a, b):
        """One round on the configured backend (no fused apply), from bool
        masks; returns ``(g_bar, g_workers, inflight)``."""
        if self.backend == "pallas":
            g_bar, gw, infl, _ = self._round_pallas(st, f, a, b, None, None)
            return g_bar, gw, infl
        if self.backend == "indexed":
            n = self.n_workers
            k = self.index_width or n
            return self._round_indexed(st, f, masks_to_indices_jnp(a, n)[:k],
                                       masks_to_indices_jnp(b, n)[:k])
        return self._round_reference(st, f, a, b)

    def _round_plain_q(self, st, f, a, b):
        """Compressed-slab twin of ``_round_plain``; returns
        ``(g_bar, gw_q, infl_q, gw_scale, infl_scale)``, extended with
        ``(gw_touched, in_touched)`` on sparse_meta engines."""
        if self.backend == "pallas":
            if self.sparse_meta:
                return self._round_pallas_sparse(st, f, a, b, None, None)[:7]
            out = self._round_pallas_q(st, f, a, b, None, None)
            return out[:5]
        if self.backend == "indexed":
            n = self.n_workers
            k = self.index_width or n
            si = masks_to_indices_jnp(a, n)[:k]
            ci = masks_to_indices_jnp(b, n)[:k]
            if self.sparse_meta:
                return self._round_sparse_indexed(st, f, si, ci)
            return self._round_indexed_q(st, f, si, ci)
        if self.sparse_meta:
            return self._round_sparse_reference(st, f, a, b)
        return self._round_reference_q(st, f, a, b)

    def _run_backend(self, state, fresh, sm, cm, params, eta):
        """Dispatch one round to the backend, under shard_map when meshed.

        The body is elementwise on P (masks/indices are replicated and the
        worker-axis reduction stays inside each P-shard; scale tiles align
        with shard boundaries), so the sharded round needs no collective at
        all.  Returns ``(g_bar, gw, infl, scales_or_None, touched_or_None,
        params_or_None)`` with ``scales = (gw_scale, infl_scale)`` under
        compressed formats and ``touched = (gw_touched, in_touched)`` on
        sparse_meta engines.
        """
        has_params = params is not None
        compressed = self.compressed
        sparse = self.sparse_meta

        def body(st, f, a, b, *wargs):
            w = wargs[0] if wargs else None
            if self.backend == "pallas":
                if sparse:
                    out = self._round_pallas_sparse(st, f, a, b, w, eta)
                    core, w_new = out[:7], out[7]
                elif compressed:
                    out = self._round_pallas_q(st, f, a, b, w, eta)
                    core, w_new = out[:5], out[5]
                else:
                    g_bar, gw, infl, w_new = self._round_pallas(
                        st, f, a, b, w, eta)
                    core = (g_bar, gw, infl)
            else:
                core = (self._round_plain_q(st, f, a, b) if compressed
                        else self._round_plain(st, f, a, b))
                w_new = None
                if w is not None:
                    w_new = (w.astype(jnp.float32)
                             - jnp.float32(eta) * core[0]).astype(w.dtype)
            return tuple(core) + ((w_new,) if wargs else ())

        wargs = (params,) if has_params else ()
        n_scales = 2 if compressed else 0
        n_touch = 2 if sparse else 0
        if self.mesh is not None:
            vec, row, repl, sspec = self._pspecs()
            body = self._shmap(
                body,
                in_specs=(sspec, row, repl, repl) + (vec,) * len(wargs),
                out_specs=(vec, row, row) + (row,) * (n_scales + n_touch)
                + (vec,) * len(wargs))
        out = body(state, fresh, sm, cm, *wargs)
        scales = (out[3], out[4]) if compressed else None
        touched = (out[5], out[6]) if sparse else None
        w_new = out[3 + n_scales + n_touch] if has_params else None
        return out[0], out[1], out[2], scales, touched, w_new

    def _index_overflow_check(self, sm, cm):
        """Satellite of the indexed backend: |C_t| > index_width silently
        drops real commits — surface it per ``index_check``."""
        if self.backend != "indexed" or self.index_check == "off":
            return
        width = self.index_width or self.n_workers
        if width >= self.n_workers:
            return  # full width can never drop
        n_active = jnp.maximum(jnp.sum(sm.astype(jnp.int32)),
                               jnp.sum(cm.astype(jnp.int32)))
        if self.index_check == "checkify":
            checkify.check(
                n_active <= width,
                "DuDeEngine(indexed): {na} active workers exceed "
                "index_width={w}; excess commits/latches are dropped",
                na=n_active, w=jnp.int32(width))
            return

        def warn(na):
            jax.debug.print(
                "WARNING: DuDeEngine(indexed): {na} active workers exceed "
                f"index_width={width}; excess commits/latches are DROPPED",
                na=na)

        jax.lax.cond(n_active > width, warn, lambda na: None, n_active)

    def _count_drops(self, st: EngineState, sm, cm) -> EngineState:
        """Indexed backend: accumulate how many active workers exceeded
        ``index_width`` this round (their latches/commits were dropped) into
        the structured ``drops`` counter — the queryable twin of
        ``_index_overflow_check``'s debug print, surfaced by the train step
        as the ``engine_drops`` metric."""
        if st.drops is None:
            return st
        width = self.index_width or self.n_workers
        over = (jnp.maximum(jnp.sum(sm.astype(jnp.int32)) - width, 0)
                + jnp.maximum(jnp.sum(cm.astype(jnp.int32)) - width, 0))
        return st._replace(drops=st.drops + over)

    # ----------------------------------------------------------- backends

    def _round_reference(self, state, fresh, sm, cm):
        """Masked full sweep over all n rows (paper-faithful oracle)."""
        g32 = fresh.astype(jnp.float32)
        infl32 = state.inflight.astype(jnp.float32)
        gw32 = state.g_workers.astype(jnp.float32)
        delta = cm.astype(jnp.float32)[:, None] * (infl32 - gw32)
        g_bar = state.g_bar + jnp.sum(delta, axis=0) / self.n_workers
        bdt = state.g_workers.dtype
        gw = jnp.where(cm[:, None], infl32.astype(bdt), state.g_workers)
        if self.accumulate:
            # running mean over the job's rounds (beyond-paper variant)
            cnt = state.acc_count.astype(jnp.float32)
            w_new = (1.0 / jnp.where(sm, 1.0, cnt + 1.0))[:, None]
            infl = (infl32 * (1.0 - w_new) + g32 * w_new).astype(bdt)
        else:
            infl = jnp.where(sm[:, None], g32.astype(bdt), state.inflight)
        return g_bar, gw, infl

    def _round_indexed(self, state, fresh, start_idx, commit_idx):
        """Gather/scatter on the k selected rows only (~4kP HBM bytes)."""
        n = self.n_workers
        bdt = state.g_workers.dtype
        rows_in = jnp.take(state.inflight, commit_idx, axis=0, mode="fill",
                           fill_value=0).astype(jnp.float32)
        rows_gw = jnp.take(state.g_workers, commit_idx, axis=0, mode="fill",
                           fill_value=0).astype(jnp.float32)
        valid = (commit_idx < n).astype(jnp.float32)[:, None]
        g_bar = state.g_bar + jnp.sum((rows_in - rows_gw) * valid, axis=0) / n
        gw = state.g_workers.at[commit_idx].set(rows_in.astype(bdt),
                                                mode="drop")
        fresh_rows = jnp.take(fresh.astype(jnp.float32), start_idx, axis=0,
                              mode="fill", fill_value=0)
        infl = state.inflight.at[start_idx].set(fresh_rows.astype(bdt),
                                                mode="drop")
        return g_bar, gw, infl

    def _round_pallas(self, state, fresh, sm, cm, params, eta):
        """Fused single-pass kernel; optional in-pass SGD apply.  Under
        shard_map the kernel sees the local ``[n, P/k]`` slabs and tiles
        them with ``gcd(P/k, DEFAULT_TILE)``."""
        w = params if params is not None else jnp.zeros_like(state.g_bar)
        gw, infl, g_bar, w_new = dude_update_pallas(
            cm, sm, fresh.astype(jnp.float32), state.g_workers,
            state.inflight, state.g_bar, w,
            eta=float(eta) if eta is not None else 0.0,
            tile=self.tile, interpret=self._interpret(),
        )
        return g_bar, gw, infl, (w_new if params is not None else None)

    # ------------------------------------------------ compressed backends

    def _round_reference_q(self, state, fresh, sm, cm):
        """Masked full sweep over quantized slabs: dequantize both slabs,
        fold the delta in f32, copy committed rows quantized (payload +
        scales, no re-quantization), latch fresh rows through the codec."""
        codec = self.codec
        infl32 = codec.decode(state.inflight, state.infl_scale)
        gw32 = codec.decode(state.g_workers, state.gw_scale)
        delta = cm.astype(jnp.float32)[:, None] * (infl32 - gw32)
        g_bar = state.g_bar + jnp.sum(delta, axis=0) / self.n_workers
        gw_q = jnp.where(cm[:, None], state.inflight, state.g_workers)
        gw_s = jnp.where(cm[:, None], state.infl_scale, state.gw_scale)
        q_f, s_f = codec.encode(fresh.astype(jnp.float32))
        infl_q = jnp.where(sm[:, None], q_f, state.inflight)
        infl_s = jnp.where(sm[:, None], s_f, state.infl_scale)
        return g_bar, gw_q, infl_q, gw_s, infl_s

    def _round_indexed_q(self, state, fresh, start_idx, commit_idx):
        """Gather/scatter twin on the k selected quantized rows only."""
        n = self.n_workers
        codec = self.codec
        rows_in_q = jnp.take(state.inflight, commit_idx, axis=0,
                             mode="fill", fill_value=0)
        rows_in_s = jnp.take(state.infl_scale, commit_idx, axis=0,
                             mode="fill", fill_value=0)
        rows_gw_q = jnp.take(state.g_workers, commit_idx, axis=0,
                             mode="fill", fill_value=0)
        rows_gw_s = jnp.take(state.gw_scale, commit_idx, axis=0,
                             mode="fill", fill_value=0)
        rows_in = codec.decode(rows_in_q, rows_in_s)
        rows_gw = codec.decode(rows_gw_q, rows_gw_s)
        valid = (commit_idx < n).astype(jnp.float32)[:, None]
        g_bar = state.g_bar + jnp.sum((rows_in - rows_gw) * valid, axis=0) / n
        gw_q = state.g_workers.at[commit_idx].set(rows_in_q, mode="drop")
        gw_s = state.gw_scale.at[commit_idx].set(rows_in_s, mode="drop")
        fresh_rows = jnp.take(fresh.astype(jnp.float32), start_idx, axis=0,
                              mode="fill", fill_value=0)
        q_f, s_f = codec.encode(fresh_rows)
        infl_q = state.inflight.at[start_idx].set(q_f, mode="drop")
        infl_s = state.infl_scale.at[start_idx].set(s_f, mode="drop")
        return g_bar, gw_q, infl_q, gw_s, infl_s

    def _round_pallas_q(self, state, fresh, sm, cm, params, eta):
        """Fused quantized kernel; optional in-pass SGD apply.  Returns
        ``(g_bar, gw_q, infl_q, gw_scale, infl_scale, params')``."""
        codec = self.codec
        w = params if params is not None else jnp.zeros_like(state.g_bar)
        gw_q, gw_s, infl_q, infl_s, g_bar, w_new, _ = \
            dude_round_apply_q_pallas(
                cm, sm, fresh.astype(jnp.float32), state.g_workers,
                state.gw_scale, state.inflight, state.infl_scale,
                state.g_bar, w, kind="sgd",
                hp=(("lr", float(eta) if eta is not None else 0.0),),
                fmt=codec.format, topk=codec.topk, tile=self.tile,
                interpret=self._interpret(),
            )
        return g_bar, gw_q, infl_q, gw_s, infl_s, \
            (w_new if params is not None else None)

    # --------------------------------------------------- sparse backends

    def _sparse_blk(self, st: EngineState, cm) -> jnp.ndarray:
        """Per-Pallas-block activity flags ``[P/tile] i32``: does any
        committing row touch any scale tile of the block in either slab?
        Computed OUTSIDE the kernel from the ``[n, P/128]`` bitmaps, so the
        gate costs O(n * P/128) metadata reads, never payload."""
        act = cm[:, None] & ((st.gw_touched | st.in_touched) != 0)
        any_t = jnp.any(act, axis=0)                     # [t_local]
        return jnp.any(any_t.reshape(-1, self.tile // self.codec.tile),
                       axis=-1).astype(jnp.int32)

    def _round_sparse_reference(self, state, fresh, sm, cm):
        """Tile-gated masked sweep — the plain-jnp oracle of the sparse
        round.  The fold touches only tiles live in either bitmap of a
        committing row; this is bit-for-bit the dense ``topk_ef`` sweep
        because untouched tiles hold zero payload and decode to exact +0.0
        (and ``g_bar`` is never -0.0).  Scale slabs copy densely — they are
        1/128 of the payload and keeping them bitwise-identical to the dense
        path removes the stale-scale caveat from the round entirely.
        Returns the 5-tuple plus ``(gw_touched, in_touched)``."""
        codec = self.codec
        n = self.n_workers
        qtile = codec.tile
        infl32 = codec.decode(state.inflight, state.infl_scale)
        gw32 = codec.decode(state.g_workers, state.gw_scale)
        act = cm[:, None] & ((state.gw_touched | state.in_touched) != 0)
        gate = jnp.broadcast_to(
            act[:, :, None], act.shape + (qtile,)).reshape(infl32.shape)
        delta = jnp.where(gate, infl32 - gw32, 0.0)
        g_bar = state.g_bar + jnp.sum(delta, axis=0) / n
        gw_q = jnp.where(cm[:, None], state.inflight, state.g_workers)
        gw_s = jnp.where(cm[:, None], state.infl_scale, state.gw_scale)
        gw_t = jnp.where(cm[:, None], state.in_touched, state.gw_touched)
        q_f, s_f = codec.encode(fresh.astype(jnp.float32))
        infl_q = jnp.where(sm[:, None], q_f, state.inflight)
        infl_s = jnp.where(sm[:, None], s_f, state.infl_scale)
        in_t = jnp.where(sm[:, None], touched_tiles(q_f).astype(jnp.int8),
                         state.in_touched)
        return g_bar, gw_q, infl_q, gw_s, infl_s, gw_t, in_t

    def _round_sparse_indexed(self, state, fresh, start_idx, commit_idx):
        """Gather/scatter sparse twin: gathers the k selected rows AND their
        bitmaps, gating the fold per gathered tile.  Bitwise equal to
        ``_round_indexed_q`` (same +0.0 argument as the reference twin)."""
        n = self.n_workers
        codec = self.codec
        qtile = codec.tile
        take = lambda a, i: jnp.take(a, i, axis=0, mode="fill", fill_value=0)
        rows_in_q = take(state.inflight, commit_idx)
        rows_in_s = take(state.infl_scale, commit_idx)
        rows_gw_q = take(state.g_workers, commit_idx)
        rows_gw_s = take(state.gw_scale, commit_idx)
        rows_in_t = take(state.in_touched, commit_idx)
        rows_gw_t = take(state.gw_touched, commit_idx)
        act = (rows_in_t | rows_gw_t) != 0
        gate = jnp.broadcast_to(
            act[:, :, None], act.shape + (qtile,)).reshape(rows_in_q.shape)
        diff = jnp.where(gate,
                         codec.decode(rows_in_q, rows_in_s)
                         - codec.decode(rows_gw_q, rows_gw_s), 0.0)
        valid = (commit_idx < n).astype(jnp.float32)[:, None]
        g_bar = state.g_bar + jnp.sum(diff * valid, axis=0) / n
        gw_q = state.g_workers.at[commit_idx].set(rows_in_q, mode="drop")
        gw_s = state.gw_scale.at[commit_idx].set(rows_in_s, mode="drop")
        gw_t = state.gw_touched.at[commit_idx].set(rows_in_t, mode="drop")
        fresh_rows = jnp.take(fresh.astype(jnp.float32), start_idx, axis=0,
                              mode="fill", fill_value=0)
        q_f, s_f = codec.encode(fresh_rows)
        infl_q = state.inflight.at[start_idx].set(q_f, mode="drop")
        infl_s = state.infl_scale.at[start_idx].set(s_f, mode="drop")
        in_t = state.in_touched.at[start_idx].set(
            touched_tiles(q_f).astype(jnp.int8), mode="drop")
        return g_bar, gw_q, infl_q, gw_s, infl_s, gw_t, in_t

    def _round_pallas_sparse(self, state, fresh, sm, cm, params, eta):
        """Touched-tile-gated fused kernel: the precomputed block activity
        array lets the kernel skip the dequant+fold of every block no
        committing row touches; the fresh latch, scale copies, bitmaps, and
        optimizer tail stay dense, so the result is bit-for-bit the dense
        ``topk_ef`` round.  Returns ``(g_bar, gw_q, infl_q, gw_scale,
        infl_scale, gw_touched, in_touched, params')``."""
        codec = self.codec
        w = params if params is not None else jnp.zeros_like(state.g_bar)
        (gw_q, gw_s, gw_t, infl_q, infl_s, in_t, g_bar, w_new, _) = \
            dude_round_apply_sparse_pallas(
                cm, sm, self._sparse_blk(state, cm),
                fresh.astype(jnp.float32), state.g_workers, state.gw_scale,
                state.gw_touched, state.inflight, state.infl_scale,
                state.in_touched, state.g_bar, w, kind="sgd",
                hp=(("lr", float(eta) if eta is not None else 0.0),),
                topk=codec.topk, tile=self.tile,
                interpret=self._interpret(),
            )
        return (g_bar, gw_q, infl_q, gw_s, infl_s, gw_t, in_t,
                w_new if params is not None else None)

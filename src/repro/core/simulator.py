"""Event-driven asynchronous-training simulator (DESIGN.md mode A).

Reproduces the paper's experimental protocol exactly: continuous-time worker
completions from the fixed-computation-speed model, zero communication time,
one server iteration per gradient arrival (fully async) or per round
(synchronous disciplines).  The numerical work (forward/backward, server
update) is jitted JAX; the event loop is host Python.

The simulator is model-agnostic: pass ``grad_fn(params, batch, rng) ->
(loss, grads)`` and a ``sample_fn(worker, rng) -> batch`` drawing from that
worker's (heterogeneous) local data.

Since the session-API redesign this file is a thin SCHEDULING shell: the
server math lives in the shared rule registry (``core/algos.py``, wrapped
for per-arrival delivery by ``core/baselines.py``), identical to what the
production train step runs mesh-native.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .baselines import ServerAlgo
from .schedules import SpeedModel

Pytree = Any

__all__ = ["SimResult", "simulate"]


@dataclasses.dataclass
class SimResult:
    name: str
    times: np.ndarray        # simulated wall-clock at each record
    iters: np.ndarray        # server iterations at each record
    losses: np.ndarray       # recorded metric (running train loss or eval)
    grad_norms: np.ndarray
    params: Pytree
    tau_max: int
    n_grads: int             # stochastic gradients computed (sample complexity)


def _record(eval_fn, params, running_loss):
    """Recorded metric: eval if an ``eval_fn`` is given, else the running
    train-loss EMA.  (The gradient is NOT an input — the signature used to
    carry an unused ``g`` from before grad norms were recorded separately.)"""
    if eval_fn is not None:
        return float(eval_fn(params))
    return float(running_loss)


def simulate(
    algo: ServerAlgo,
    speeds: SpeedModel,
    grad_fn: Callable,
    sample_fn: Callable,
    params0: Pytree,
    lr: float,
    total_iters: int,
    seed: int = 0,
    record_every: int = 10,
    eval_fn: Optional[Callable] = None,
    ema: float = 0.9,
    max_time: Optional[float] = None,
) -> SimResult:
    """Run one asynchronous training simulation.

    Workers compute gradients on the model version they last received; model
    versions are tracked explicitly so the dual delay (model staleness vs.
    data freshness) is physical, not emulated.
    """
    n = speeds.n
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)

    grad_fn = jax.jit(grad_fn)
    state = algo.init_state(jax.tree.map(jnp.zeros_like, params0))
    on_gradient = jax.jit(algo.on_gradient) if algo.on_gradient else None
    on_round = jax.jit(algo.on_round) if algo.on_round else None

    params = params0
    t_now = 0.0
    it = 0
    n_grads = 0
    running = None
    tau_max = 0
    times, iters, losses, gnorms = [], [], [], []

    def rec(g):
        gn = float(
            jnp.sqrt(
                sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(g))
            )
        )
        times.append(t_now)
        iters.append(it)
        losses.append(_record(eval_fn, params, running))
        gnorms.append(gn)

    if algo.scheduling == "rounds":
        # --- synchronous / round-based disciplines (sync SGD, MIFA) --------
        round_time = float(np.max(speeds.times))  # straggler-bound
        while it < total_iters and (max_time is None or t_now < max_time):
            key, *wkeys = jax.random.split(key, n + 1)
            grads, loss_acc = [], 0.0
            mask = rng.random(n) < algo.participate_p
            if not mask.any():
                mask[rng.integers(n)] = True
            for i in range(n):
                batch = sample_fn(i, rng)
                loss, g = grad_fn(params, batch, wkeys[i])
                grads.append(g)
                loss_acc += float(loss) * mask[i]
                n_grads += int(mask[i])
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *grads)
            state, params, g_dir = on_round(
                state, stacked, jnp.asarray(mask), params, lr
            )
            mean_loss = loss_acc / max(1, mask.sum())
            running = mean_loss if running is None else ema * running + (1 - ema) * mean_loss
            t_now += round_time
            it += 1
            tau_max = max(tau_max, 1)
            if it % record_every == 0:
                rec(g_dir)
        return SimResult(
            algo.name, np.array(times), np.array(iters), np.array(losses),
            np.array(gnorms), params, tau_max, n_grads,
        )

    # --- asynchronous disciplines (greedy / routed) ------------------------
    # Each worker holds the model version it will compute on.  version_iter[i]
    # tracks the server iteration at which that model was produced (for tau).
    worker_params = [params for _ in range(n)]
    version_iter = [0] * n
    heap: list[tuple[float, int]] = []  # (finish_time, worker)
    queues = [1 for _ in range(n)]  # pending models per worker (routed mode)
    shuffle_order: list[int] = []

    for i in range(n):
        heapq.heappush(heap, (speeds.times[i], i))

    def next_routed_worker() -> int:
        nonlocal shuffle_order
        if algo.route == "uniform":
            return int(rng.integers(n))
        if not shuffle_order:
            shuffle_order = list(rng.permutation(n))
        return int(shuffle_order.pop())

    # ``applied`` is mirrored host-side from the algo's static apply_period
    # (FedBuff flushes every buffer_size-th arrival, etc.) so the event loop
    # never blocks on a device round-trip per gradient arrival — the jitted
    # server updates stay queued on the async dispatch stream and only
    # synchronize at record points.
    pending = 0
    while it < total_iters and (max_time is None or t_now < max_time):
        t_now, i = heapq.heappop(heap)
        key, k1 = jax.random.split(key)
        batch = sample_fn(i, rng)
        loss, g = grad_fn(worker_params[i], batch, k1)
        n_grads += 1
        tau_max = max(tau_max, it + 1 - version_iter[i])
        state, params, _applied = on_gradient(state, jnp.int32(i), g, params, lr)
        pending += 1
        applied = pending >= algo.apply_period
        if applied:
            pending = 0
            it += 1
        # device-side EMA: no host sync per arrival, float()-ed only at record
        running = loss if running is None else ema * running + (1 - ema) * loss

        if algo.scheduling == "greedy":
            worker_params[i] = params
            version_iter[i] = it
            heapq.heappush(heap, (t_now + speeds.times[i], i))
        else:  # routed (Uniform / Shuffled ASGD)
            queues[i] -= 1
            j = next_routed_worker()
            worker_params[j] = params  # latest model enqueued for worker j
            version_iter[j] = it
            queues[j] += 1
            if queues[i] > 0:  # keep draining this worker's backlog
                heapq.heappush(heap, (t_now + speeds.times[i], i))
            if queues[j] == 1 and j != i:
                heapq.heappush(heap, (t_now + speeds.times[j], j))
            if not heap:  # all queues empty: route to a random idle worker
                j = int(rng.integers(n))
                queues[j] += 1
                heapq.heappush(heap, (t_now + speeds.times[j], j))

        if bool(applied) and it % record_every == 0:
            rec(g)

    return SimResult(
        algo.name, np.array(times), np.array(iters), np.array(losses),
        np.array(gnorms), params, tau_max, n_grads,
    )

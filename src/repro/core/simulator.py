"""Event-driven asynchronous-training simulator (DESIGN.md mode A).

Reproduces the paper's experimental protocol exactly: continuous-time worker
completions from the fixed-computation-speed model, zero communication time,
one server iteration per gradient arrival (fully async) or per round
(synchronous disciplines).  The numerical work (forward/backward, server
update) is jitted JAX; the event loop is host Python.

The simulator is model-agnostic: pass ``grad_fn(params, batch, rng) ->
(loss, grads)`` and a ``sample_fn(worker, rng) -> batch`` drawing from that
worker's (heterogeneous) local data.

Since the session-API redesign this file is a thin SCHEDULING shell: the
server math lives in the shared rule registry (``core/algos.py``, wrapped
for per-arrival delivery by ``core/baselines.py``), identical to what the
production train step runs mesh-native.  Since the async-runtime redesign
the scheduling itself is shared too: the fully-async branch is a
deterministic client of ``runtime.loop.drive_arrivals`` over a pluggable
``runtime.arrivals.ArrivalProcess`` (defaulting to the paper's
fixed-computation-speed model), the exact loop the production
``runtime.AsyncRunner`` drives — so one recorded ``ArrivalTrace`` replays
bit-for-bit through either (docs/async.md, "Simulator <-> runner
equivalence").
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..runtime.arrivals import ArrivalProcess, ArrivalTrace, FixedArrivals
from ..runtime.loop import drive_arrivals
from .baselines import ServerAlgo
from .schedules import SpeedModel

Pytree = Any

__all__ = ["SimResult", "simulate"]


@dataclasses.dataclass
class SimResult:
    name: str
    times: np.ndarray        # simulated wall-clock at each record
    iters: np.ndarray        # server iterations at each record
    losses: np.ndarray       # recorded metric (running train loss or eval)
    grad_norms: np.ndarray
    params: Pytree
    tau_max: int
    n_grads: int             # stochastic gradients computed (sample complexity)
    trace: Optional[ArrivalTrace] = None  # async runs: the arrival schedule


def _record(eval_fn, params, running_loss):
    """Recorded metric: eval if an ``eval_fn`` is given, else the running
    train-loss EMA.  (The gradient is NOT an input — the signature used to
    carry an unused ``g`` from before grad norms were recorded separately.)"""
    if eval_fn is not None:
        return float(eval_fn(params))
    return float(running_loss)


def simulate(
    algo: ServerAlgo,
    speeds: SpeedModel,
    grad_fn: Callable,
    sample_fn: Callable,
    params0: Pytree,
    lr: float,
    total_iters: int,
    seed: int = 0,
    record_every: int = 10,
    eval_fn: Optional[Callable] = None,
    ema: float = 0.9,
    max_time: Optional[float] = None,
    arrivals: Optional[ArrivalProcess] = None,
    max_in_flight: Optional[int] = None,
) -> SimResult:
    """Run one asynchronous training simulation.

    Workers compute gradients on the model version they last received; model
    versions are tracked explicitly so the dual delay (model staleness vs.
    data freshness) is physical, not emulated.  ``arrivals`` overrides the
    timing model (default: ``FixedArrivals.from_speeds(speeds)``, the
    paper's protocol) — pass a ``TraceArrivals`` to replay a recorded run.
    """
    n = speeds.n
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)

    grad_fn = jax.jit(grad_fn)
    state = algo.init_state(jax.tree.map(jnp.zeros_like, params0))
    on_gradient = jax.jit(algo.on_gradient) if algo.on_gradient else None
    on_round = jax.jit(algo.on_round) if algo.on_round else None

    params = params0
    t_now = 0.0
    it = 0
    n_grads = 0
    running = None
    times, iters, losses, gnorms = [], [], [], []

    def rec(g, t, it_now):
        gn = float(
            jnp.sqrt(
                sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(g))
            )
        )
        times.append(t)
        iters.append(it_now)
        losses.append(_record(eval_fn, params, running))
        gnorms.append(gn)

    if algo.scheduling == "rounds":
        # --- synchronous / round-based disciplines (sync SGD, MIFA) --------
        round_time = float(np.max(speeds.times))  # straggler-bound
        tau_max = 0
        while it < total_iters and (max_time is None or t_now < max_time):
            key, *wkeys = jax.random.split(key, n + 1)
            grads, loss_acc = [], 0.0
            mask = rng.random(n) < algo.participate_p
            if not mask.any():
                mask[rng.integers(n)] = True
            for i in range(n):
                batch = sample_fn(i, rng)
                loss, g = grad_fn(params, batch, wkeys[i])
                grads.append(g)
                loss_acc += float(loss) * mask[i]
                n_grads += int(mask[i])
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *grads)
            state, params, g_dir = on_round(
                state, stacked, jnp.asarray(mask), params, lr
            )
            mean_loss = loss_acc / max(1, mask.sum())
            running = mean_loss if running is None else ema * running + (1 - ema) * mean_loss
            t_now += round_time
            it += 1
            tau_max = max(tau_max, 1)
            if it % record_every == 0:
                rec(g_dir, t_now, it)
        return SimResult(
            algo.name, np.array(times), np.array(iters), np.array(losses),
            np.array(gnorms), params, tau_max, n_grads,
        )

    # --- asynchronous disciplines (greedy / routed) ------------------------
    # One shared event loop (runtime/loop.py) schedules dispatch/collect for
    # both this simulator and the production AsyncRunner.  Each worker holds
    # the model version it will compute on; the loop stamps versions so the
    # dual delay is physical.  ``applied`` is mirrored host-side from the
    # algo's static apply_period (FedBuff flushes every buffer_size-th
    # arrival, etc.) so the event loop never blocks on a device round-trip
    # per gradient arrival — the jitted server updates stay queued on the
    # async dispatch stream and only synchronize at record points.
    process = arrivals if arrivals is not None \
        else FixedArrivals.from_speeds(speeds)
    worker_params = [params for _ in range(n)]
    pending = 0

    def on_arrival(view) -> bool:
        nonlocal key, running, n_grads, pending, state, params
        i = view.worker
        key, k1 = jax.random.split(key)
        batch = sample_fn(i, rng)
        loss, g = grad_fn(worker_params[i], batch, k1)
        if view.completeness != 1.0:
            # partial-gradient client state: scale the pytree leaves by the
            # exact f32 completeness; elementwise f32 multiply commutes with
            # ravel, so the runner's flat-side scaling is bitwise identical
            cg = jnp.float32(view.completeness)
            g = jax.tree.map(lambda x: cg * x, g)
        n_grads += 1
        state, params, _applied = on_gradient(state, jnp.int32(i), g,
                                              params, lr)
        pending += 1
        applied = pending >= algo.apply_period
        if applied:
            pending = 0
        # device-side EMA: no host sync per arrival, float()-ed only at record
        running = loss if running is None else ema * running + (1 - ema) * loss
        # view.iters is the loop's applied-iteration count BEFORE this
        # arrival — the one source of truth for the iteration number
        if applied and (view.iters + 1) % record_every == 0:
            rec(g, view.t, view.iters + 1)
        return applied

    def deliver(j: int) -> None:
        worker_params[j] = params  # latest model enqueued for worker j

    route = algo.route if algo.scheduling == "routed" else None
    stats = drive_arrivals(process, total_iters, on_arrival, deliver,
                           route=route, rng=rng,
                           max_in_flight=max_in_flight, max_time=max_time)
    return SimResult(
        algo.name, np.array(times), np.array(iters), np.array(losses),
        np.array(gnorms), params, stats.tau_max, n_grads, trace=stats.trace,
    )

"""DuDe-ASGD core: dual-delayed asynchronous SGD with incremental aggregation.

This module implements the paper's contribution (Algorithm 1 + the
semi-asynchronous mini-batch variant, §3) as a composable, model-agnostic JAX
module operating on gradient pytrees.

Two entry points, matching DESIGN.md execution modes:

* ``dude_commit``      — one fully-asynchronous server iteration (mode A,
                         event-driven): worker ``j`` delivers a fresh gradient,
                         the server applies the incremental delta
                         ``g <- g + (G_j_new - G_j_old)/n``.
* ``dude_round``       — one semi-asynchronous SPMD round (mode B): every
                         worker computed a gradient of the live model this
                         round; ``start_mask`` latches gradients into in-flight
                         buffers (job start == model/data snapshot time) and
                         ``commit_mask`` applies the DuDe deltas of finishing
                         workers.  The dual delay is physical: a committed
                         gradient was latched ``tau`` rounds ago.

The public API keeps pytree-of-stacked-buffers state (``DuDeState``) so it
shards trivially over a mesh and checkpoints per-leaf, but since the
ServerEngine refactor the actual update math runs on ONE flat buffer layout:
each call ravels state + gradients into padded ``[P]``/``[n, P]`` slabs
(``core/flatten.py``), dispatches to a ``DuDeEngine`` backend
(``core/engine.py`` — ``"reference"`` masked sweep, ``"indexed"``
gather/scatter, or the fused ``"pallas"`` kernel), and unravels the result.
Under jit the ravel/unravel are pure layout ops that XLA fuses away.
"""

from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .engine import DuDeEngine, EngineState
from .flatten import make_flat_spec

Pytree = Any

__all__ = [
    "DuDeConfig", "DuDeState", "dude_init", "dude_commit", "dude_round",
    "dude_round_indexed", "masks_to_indices",
]


@dataclasses.dataclass(frozen=True)
class DuDeConfig:
    n_workers: int
    buffer_dtype: Any = jnp.float32
    # Beyond-paper: accumulate every round's gradient into the in-flight buffer
    # instead of only latching at job start (100% compute utilization).
    accumulate: bool = False


class DuDeState(NamedTuple):
    g_bar: Pytree       # f32 running aggregated gradient  (paper's  g~)
    g_workers: Pytree   # [n, ...] latest committed gradient per worker (G~_i)
    inflight: Pytree    # [n, ...] gradient latched at job start, awaiting commit
    acc_count: jnp.ndarray  # [n] rounds accumulated into inflight (accumulate mode)
    step: jnp.ndarray   # server iteration counter t


def _stack_like(tree: Pytree, n: int, dtype) -> Pytree:
    return jax.tree.map(
        lambda x: jnp.zeros((n,) + jnp.shape(x), dtype or jnp.asarray(x).dtype), tree
    )


def dude_init(grad_like: Pytree, cfg: DuDeConfig) -> DuDeState:
    """Zero-initialized state.

    The paper's initialization (every worker computes grad(w0) once, the server
    aggregates) is reproduced by running one synchronous first round/commit
    sweep; starting from zero buffers is equivalent to defining G~_i = 0 before
    each worker's first contribution and only changes iteration t=1.
    """
    n = cfg.n_workers
    return DuDeState(
        g_bar=jax.tree.map(lambda x: jnp.zeros_like(x, dtype=jnp.float32), grad_like),
        g_workers=_stack_like(grad_like, n, cfg.buffer_dtype),
        inflight=_stack_like(grad_like, n, cfg.buffer_dtype),
        acc_count=jnp.zeros((n,), jnp.int32),
        step=jnp.zeros((), jnp.int32),
    )


# ------------------------------------------------------- engine plumbing

@lru_cache(maxsize=None)
def _engine_cached(spec, n_workers, buffer_dtype, accumulate, backend,
                   interpret) -> DuDeEngine:
    return DuDeEngine(spec=spec, n_workers=n_workers,
                      buffer_dtype=buffer_dtype, accumulate=accumulate,
                      backend=backend, interpret=interpret)


def engine_for(state: DuDeState, cfg: DuDeConfig, backend: str = "reference",
               interpret: Optional[bool] = None) -> DuDeEngine:
    """The (cached) engine whose flat layout matches ``state.g_bar``."""
    spec = make_flat_spec(state.g_bar)
    return _engine_cached(spec, cfg.n_workers, cfg.buffer_dtype or jnp.float32,
                          cfg.accumulate, backend, interpret)


def _ravel_state(eng: DuDeEngine, state: DuDeState) -> EngineState:
    bdt = eng.buffer_dtype
    return EngineState(
        g_bar=eng.spec.ravel(state.g_bar, jnp.float32),
        g_workers=eng.spec.ravel_stacked(state.g_workers, bdt),
        inflight=eng.spec.ravel_stacked(state.inflight, bdt),
        acc_count=state.acc_count,
        step=state.step,
    )


def _unravel_state(eng: DuDeEngine, fstate: EngineState) -> DuDeState:
    return DuDeState(
        g_bar=eng.spec.unravel(fstate.g_bar),
        g_workers=eng.spec.unravel_stacked(fstate.g_workers, cast=False),
        inflight=eng.spec.unravel_stacked(fstate.inflight, cast=False),
        acc_count=fstate.acc_count,
        step=fstate.step,
    )


# ------------------------------------------------------------ public API

def dude_commit(
    state: DuDeState, worker: jnp.ndarray, grad: Pytree, cfg: DuDeConfig
) -> tuple[DuDeState, Pytree]:
    """Fully-asynchronous server iteration (Algorithm 1, lines 4-6).

    ``worker`` is a traced int32 scalar; ``grad`` the fresh stochastic gradient
    G_j^t.  Returns the new state and the aggregated direction g^t.
    """
    eng = engine_for(state, cfg)
    fstate, g_bar = eng.commit(_ravel_state(eng, state),
                               worker, eng.spec.ravel(grad))
    new_state = _unravel_state(eng, fstate)
    return new_state, new_state.g_bar


def dude_round(
    state: DuDeState,
    fresh_grads: Pytree,  # [n, ...] gradient of the live model per worker group
    start_mask: jnp.ndarray,  # [n] bool — worker starts a job this round
    commit_mask: jnp.ndarray,  # [n] bool — worker's in-flight gradient commits
    cfg: DuDeConfig,
    backend: str = "reference",
    interpret: Optional[bool] = None,
) -> tuple[DuDeState, Pytree]:
    """Semi-asynchronous SPMD round (paper §3, semi-async variant).

    Order of operations inside a round r:
      1. commit: workers finishing now deliver the gradient they latched at
         their job-start round (model delay = job duration, data drawn at
         start => tau_i >= d_i + 1 structurally).
      2. latch: workers starting now snapshot the *current* round's gradient
         into their in-flight buffer.
    The aggregated direction g^t changes only through committed deltas, exactly
    the incremental rule  g^t = g^{t-1} + (1/n) sum_{i in C_t} (G_i^new - G~_i).

    ``backend`` selects the engine update path ("reference" | "indexed" |
    "pallas"); all are semantically equivalent (tests/test_engine.py).
    """
    eng = engine_for(state, cfg, backend=backend, interpret=interpret)
    fstate, _ = eng.round(_ravel_state(eng, state),
                          eng.spec.ravel_stacked(fresh_grads),
                          start_mask, commit_mask)
    new_state = _unravel_state(eng, fstate)
    return new_state, new_state.g_bar


def dude_round_indexed(
    state: DuDeState,
    fresh_grads: Pytree,          # [n, ...]
    start_idx: jnp.ndarray,       # [k_s] int32, padded with n (out of range)
    commit_idx: jnp.ndarray,      # [k_c] int32, padded with n
    cfg: DuDeConfig,
) -> tuple[DuDeState, Pytree]:
    """Beyond-paper §Perf variant of ``dude_round``: identical semantics, but
    buffer updates touch ONLY the k committing/starting workers' rows via
    gather/scatter on the (unsharded) worker axis, instead of the masked
    full sweep that reads+writes all n rows.  HBM traffic for the DuDe state
    drops from ~4nP to ~4kP bytes per round (k = |C_t| ~= n/tau_avg).

    Padding convention: indices == n are dropped (scatter mode="drop").
    The host passes fixed-width index arrays so shapes stay static.
    """
    eng = engine_for(state, cfg, backend="indexed")
    fstate, _ = eng.round_indexed(_ravel_state(eng, state),
                                  eng.spec.ravel_stacked(fresh_grads),
                                  start_idx, commit_idx)
    new_state = _unravel_state(eng, fstate)
    return new_state, new_state.g_bar


def masks_to_indices(mask: np.ndarray, n: int, width: int) -> np.ndarray:
    """Host helper: bool mask [n] -> fixed-width index array padded with n."""
    idx = np.nonzero(mask)[0]
    out = np.full(width, n, dtype=np.int32)
    out[: min(len(idx), width)] = idx[:width]
    return out

"""DuDe-ASGD core: dual-delayed asynchronous SGD with incremental aggregation.

This module implements the paper's contribution (Algorithm 1 + the
semi-asynchronous mini-batch variant, §3) as a composable, model-agnostic JAX
module operating on gradient pytrees.

Two entry points, matching DESIGN.md execution modes:

* ``dude_commit``      — one fully-asynchronous server iteration (mode A,
                         event-driven): worker ``j`` delivers a fresh gradient,
                         the server applies the incremental delta
                         ``g <- g + (G_j_new - G_j_old)/n``.
* ``dude_round``       — one semi-asynchronous SPMD round (mode B): every
                         worker computed a gradient of the live model this
                         round; ``start_mask`` latches gradients into in-flight
                         buffers (job start == model/data snapshot time) and
                         ``commit_mask`` applies the DuDe deltas of finishing
                         workers.  The dual delay is physical: a committed
                         gradient was latched ``tau`` rounds ago.

State is a pytree-of-stacked-buffers so it shards trivially over a mesh (the
update is elementwise except for one mean over the worker axis).  Buffer dtype
is configurable (the Theta(n p) server memory is the paper's stated trade-off);
optional error-feedback compression lives in ``compression.py``.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

Pytree = Any

__all__ = ["DuDeConfig", "DuDeState", "dude_init", "dude_commit", "dude_round"]


@dataclasses.dataclass(frozen=True)
class DuDeConfig:
    n_workers: int
    buffer_dtype: Any = jnp.float32
    # Beyond-paper: accumulate every round's gradient into the in-flight buffer
    # instead of only latching at job start (100% compute utilization).
    accumulate: bool = False


class DuDeState(NamedTuple):
    g_bar: Pytree       # f32 running aggregated gradient  (paper's  g~)
    g_workers: Pytree   # [n, ...] latest committed gradient per worker (G~_i)
    inflight: Pytree    # [n, ...] gradient latched at job start, awaiting commit
    acc_count: jnp.ndarray  # [n] rounds accumulated into inflight (accumulate mode)
    step: jnp.ndarray   # server iteration counter t


def _stack_like(tree: Pytree, n: int, dtype) -> Pytree:
    return jax.tree.map(
        lambda x: jnp.zeros((n,) + jnp.shape(x), dtype or jnp.asarray(x).dtype), tree
    )


def dude_init(grad_like: Pytree, cfg: DuDeConfig) -> DuDeState:
    """Zero-initialized state.

    The paper's initialization (every worker computes grad(w0) once, the server
    aggregates) is reproduced by running one synchronous first round/commit
    sweep; starting from zero buffers is equivalent to defining G~_i = 0 before
    each worker's first contribution and only changes iteration t=1.
    """
    n = cfg.n_workers
    return DuDeState(
        g_bar=jax.tree.map(lambda x: jnp.zeros_like(x, dtype=jnp.float32), grad_like),
        g_workers=_stack_like(grad_like, n, cfg.buffer_dtype),
        inflight=_stack_like(grad_like, n, cfg.buffer_dtype),
        acc_count=jnp.zeros((n,), jnp.int32),
        step=jnp.zeros((), jnp.int32),
    )


def dude_commit(
    state: DuDeState, worker: jnp.ndarray, grad: Pytree, cfg: DuDeConfig
) -> tuple[DuDeState, Pytree]:
    """Fully-asynchronous server iteration (Algorithm 1, lines 4-6).

    ``worker`` is a traced int32 scalar; ``grad`` the fresh stochastic gradient
    G_j^t.  Returns the new state and the aggregated direction g^t.
    """
    n = cfg.n_workers

    def upd(gbar, gw, g):
        g = g.astype(jnp.float32)
        old = jax.lax.dynamic_index_in_dim(gw, worker, axis=0, keepdims=False)
        delta = (g - old.astype(jnp.float32)) / n
        gbar = gbar + delta
        gw = jax.lax.dynamic_update_index_in_dim(
            gw, g.astype(gw.dtype), worker, axis=0
        )
        return gbar, gw

    flat_bar, treedef = jax.tree.flatten(state.g_bar)
    flat_gw = treedef.flatten_up_to(state.g_workers)
    flat_g = treedef.flatten_up_to(grad)
    new_bar, new_gw = [], []
    for b, w, g in zip(flat_bar, flat_gw, flat_g):
        nb, nw = upd(b, w, g)
        new_bar.append(nb)
        new_gw.append(nw)
    g_bar = jax.tree.unflatten(treedef, new_bar)
    g_workers = jax.tree.unflatten(treedef, new_gw)
    st = DuDeState(
        g_bar=g_bar,
        g_workers=g_workers,
        inflight=state.inflight,
        acc_count=state.acc_count,
        step=state.step + 1,
    )
    return st, g_bar


def _bmask(mask: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Broadcast [n] mask against [n, ...] buffer."""
    return mask.reshape((-1,) + (1,) * (x.ndim - 1))


def dude_round(
    state: DuDeState,
    fresh_grads: Pytree,  # [n, ...] gradient of the live model per worker group
    start_mask: jnp.ndarray,  # [n] bool — worker starts a job this round
    commit_mask: jnp.ndarray,  # [n] bool — worker's in-flight gradient commits
    cfg: DuDeConfig,
) -> tuple[DuDeState, Pytree]:
    """Semi-asynchronous SPMD round (paper §3, semi-async variant).

    Order of operations inside a round r:
      1. commit: workers finishing now deliver the gradient they latched at
         their job-start round (model delay = job duration, data drawn at
         start => tau_i >= d_i + 1 structurally).
      2. latch: workers starting now snapshot the *current* round's gradient
         into their in-flight buffer.
    The aggregated direction g^t changes only through committed deltas, exactly
    the incremental rule  g^t = g^{t-1} + (1/n) sum_{i in C_t} (G_i^new - G~_i).
    """
    n = cfg.n_workers
    cm = commit_mask.astype(jnp.float32)
    sm = start_mask

    def upd(gbar, gw, infl, g):
        g32 = g.astype(jnp.float32)
        infl32 = infl.astype(jnp.float32)
        # 1. commit finishing workers
        delta = _bmask(cm, infl32) * (infl32 - gw.astype(jnp.float32))
        gbar = gbar + jnp.sum(delta, axis=0) / n
        gw = jnp.where(_bmask(commit_mask, gw), infl32.astype(gw.dtype), gw)
        # 2. latch/accumulate fresh gradients of starting workers
        if cfg.accumulate:
            # running mean over the job's rounds (beyond-paper variant)
            cnt = state.acc_count.astype(jnp.float32)
            newcnt = jnp.where(sm, 1.0, cnt + 1.0)
            w_new = 1.0 / newcnt
            mixed = infl32 * (1.0 - _bmask(w_new, infl32)) + g32 * _bmask(w_new, g32)
            infl = mixed.astype(infl.dtype)
        else:
            infl = jnp.where(_bmask(sm, infl), g32.astype(infl.dtype), infl)
        return gbar, gw, infl

    flat_bar, treedef = jax.tree.flatten(state.g_bar)
    flat_gw = treedef.flatten_up_to(state.g_workers)
    flat_in = treedef.flatten_up_to(state.inflight)
    flat_g = treedef.flatten_up_to(fresh_grads)
    nb, nw, ni = [], [], []
    for b, w, il, g in zip(flat_bar, flat_gw, flat_in, flat_g):
        b2, w2, i2 = upd(b, w, il, g)
        nb.append(b2)
        nw.append(w2)
        ni.append(i2)
    newcnt = jnp.where(sm, 1, state.acc_count + 1).astype(jnp.int32)
    st = DuDeState(
        g_bar=jax.tree.unflatten(treedef, nb),
        g_workers=jax.tree.unflatten(treedef, nw),
        inflight=jax.tree.unflatten(treedef, ni),
        acc_count=newcnt,
        step=state.step + 1,
    )
    return st, st.g_bar


def dude_round_indexed(
    state: DuDeState,
    fresh_grads: Pytree,          # [n, ...]
    start_idx: jnp.ndarray,       # [k_s] int32, padded with n (out of range)
    commit_idx: jnp.ndarray,      # [k_c] int32, padded with n
    cfg: DuDeConfig,
) -> tuple[DuDeState, Pytree]:
    """Beyond-paper §Perf variant of ``dude_round``: identical semantics, but
    buffer updates touch ONLY the k committing/starting workers' rows via
    gather/scatter on the (unsharded) worker axis, instead of the masked
    full sweep that reads+writes all n rows.  HBM traffic for the DuDe state
    drops from ~4nP to ~4kP bytes per round (k = |C_t| ~= n/tau_avg).

    Padding convention: indices == n are dropped (scatter mode="drop").
    The host passes fixed-width index arrays so shapes stay static.
    """
    n = cfg.n_workers

    def upd(gbar, gw, infl, g):
        g32 = g.astype(jnp.float32)
        # commit: delta for the selected rows only
        rows_in = jnp.take(infl, commit_idx, axis=0, mode="fill",
                           fill_value=0).astype(jnp.float32)
        rows_gw = jnp.take(gw, commit_idx, axis=0, mode="fill",
                           fill_value=0).astype(jnp.float32)
        valid = (commit_idx < n).astype(jnp.float32)
        delta = (rows_in - rows_gw) * valid.reshape((-1,) + (1,) * (gw.ndim - 1))
        gbar = gbar + jnp.sum(delta, axis=0) / n
        gw = gw.at[commit_idx].set(rows_in.astype(gw.dtype), mode="drop")
        # latch: selected fresh rows only
        fresh_rows = jnp.take(g32, start_idx, axis=0, mode="fill", fill_value=0)
        infl = infl.at[start_idx].set(fresh_rows.astype(infl.dtype), mode="drop")
        return gbar, gw, infl

    flat_bar, treedef = jax.tree.flatten(state.g_bar)
    flat_gw = treedef.flatten_up_to(state.g_workers)
    flat_in = treedef.flatten_up_to(state.inflight)
    flat_g = treedef.flatten_up_to(fresh_grads)
    nb, nw, ni = [], [], []
    for b, w, il, g in zip(flat_bar, flat_gw, flat_in, flat_g):
        b2, w2, i2 = upd(b, w, il, g)
        nb.append(b2)
        nw.append(w2)
        ni.append(i2)
    st = DuDeState(
        g_bar=jax.tree.unflatten(treedef, nb),
        g_workers=jax.tree.unflatten(treedef, nw),
        inflight=jax.tree.unflatten(treedef, ni),
        acc_count=state.acc_count,
        step=state.step + 1,
    )
    return st, st.g_bar


def masks_to_indices(mask: "np.ndarray", n: int, width: int):
    """Host helper: bool mask [n] -> fixed-width index array padded with n."""
    import numpy as np
    idx = np.nonzero(mask)[0]
    out = np.full(width, n, dtype=np.int32)
    out[: min(len(idx), width)] = idx[:width]
    return out

"""Pytree <-> flat-buffer ravel layer for the ServerEngine.

The DuDe server iteration is elementwise over Theta(n * p) buffer state, so
the engine stores all of it as padded flat slabs: ``g_bar`` as ``[P]`` and the
per-worker buffers as ``[n, P]``, where ``P`` is the total parameter count
rounded up to a lane multiple (so the fused Pallas kernel always sees
tileable shapes).  This module owns the mapping between gradient pytrees and
those slabs.

A ``FlatSpec`` is built once per (treedef, leaf shapes/dtypes) and cached: it
records the treedef plus a segment table (offset/size/shape/dtype per leaf)
so ravel is a cast+reshape+concat and unravel is a slice+reshape+cast — both
fuse into neighbouring ops under jit.  Padding is zero-filled and ignored on
unravel; zeros are a fixed point of every engine update, so the pad lanes
never contaminate real state.

Shard-aligned layout: ``make_flat_spec(tree, mesh_axis_size=k)`` pads ``P``
up to a multiple of ``k * PAD_MULTIPLE`` so the flat vector splits into ``k``
contiguous, equally sized, lane-aligned shards — one per device on a P-axis
mesh.  The split is purely positional (segment ranges, not leaf boundaries):
a shard may own the tail of one leaf and the head of the next, and all pad
lanes land in the trailing shard, so no shard ever needs remote elements.
``shard_ranges`` / ``shard_segments`` expose the resulting per-shard segment
table for sharding rules, checkpoint layouts, and debugging.

Documented in docs/engine.md — "Flat layout", "Segment table (FlatSpec)"
and "Sharding the flat layout".
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any

__all__ = ["FlatSpec", "make_flat_spec", "PAD_MULTIPLE"]

# Lane width of the TPU vector unit: padding P to a multiple of this keeps
# every backend (and the Pallas tile chooser) shape-happy.
PAD_MULTIPLE = 128


@dataclasses.dataclass(frozen=True)
class FlatSpec:
    """Segment table mapping one pytree layout to a padded flat vector."""

    treedef: Any
    shapes: tuple          # per-leaf shapes
    dtypes: tuple          # per-leaf dtypes (restored on unravel)
    sizes: tuple           # per-leaf element counts
    offsets: tuple         # per-leaf start offset into the flat vector
    size: int              # sum(sizes), before padding
    padded_size: int       # P: size rounded up to mesh_axis_size*PAD_MULTIPLE
    mesh_axis_size: int = 1  # k: number of contiguous P-axis shards

    # ----------------------------------------------------------- sharding

    @property
    def shard_size(self) -> int:
        """Elements per P-axis shard (``P / k``; a PAD_MULTIPLE multiple)."""
        return self.padded_size // self.mesh_axis_size

    def shard_ranges(self) -> tuple:
        """Per-shard ``(start, stop)`` offsets into the flat vector.  Shard
        ``s`` owns the contiguous slice ``[s*P/k, (s+1)*P/k)``; all pad lanes
        (offsets >= ``size``) fall in the trailing shard(s)."""
        w = self.shard_size
        return tuple((s * w, (s + 1) * w) for s in range(self.mesh_axis_size))

    def shard_segments(self, shard: int) -> tuple:
        """Segment table of one shard: ``(leaf_index, leaf_start, leaf_stop)``
        triples giving, in leaf-local element coordinates, the slice of each
        leaf that shard ``shard`` owns.  Pad lanes are not listed."""
        lo, hi = self.shard_ranges()[shard]
        out = []
        for i, (off, sz) in enumerate(zip(self.offsets, self.sizes)):
            a, b = max(lo, off), min(hi, off + sz)
            if a < b:
                out.append((i, a - off, b - off))
        return tuple(out)

    # ------------------------------------------------------------- ravel

    def ravel(self, tree: Pytree, dtype=jnp.float32) -> jnp.ndarray:
        """Pytree with leaves of ``self.shapes`` -> flat ``[P]`` in ``dtype``."""
        leaves = self.treedef.flatten_up_to(tree)
        flat = [jnp.asarray(x).astype(dtype).reshape(-1) for x in leaves]
        return self._pad(jnp.concatenate(flat) if flat else jnp.zeros((0,), dtype))

    def ravel_stacked(self, tree: Pytree, dtype=jnp.float32) -> jnp.ndarray:
        """Pytree with ``[n, *shape]`` leaves -> ``[n, P]`` in ``dtype``."""
        leaves = self.treedef.flatten_up_to(tree)
        n = jnp.shape(leaves[0])[0]
        flat = [jnp.asarray(x).astype(dtype).reshape(n, -1) for x in leaves]
        return self._pad(jnp.concatenate(flat, axis=-1), n)

    def _pad(self, flat: jnp.ndarray, n: int | None = None) -> jnp.ndarray:
        pad = self.padded_size - self.size
        if pad == 0:
            return flat
        widths = ((0, 0), (0, pad)) if n is not None else ((0, pad),)
        return jnp.pad(flat, widths)

    # ----------------------------------------------------------- unravel

    def unravel(self, flat: jnp.ndarray, cast: bool = True) -> Pytree:
        """Flat ``[P]`` -> pytree with the spec's shapes (and dtypes if
        ``cast``; otherwise leaves keep ``flat.dtype``)."""
        leaves = []
        for off, sz, shp, dt in zip(self.offsets, self.sizes, self.shapes,
                                    self.dtypes):
            x = flat[off:off + sz].reshape(shp)
            leaves.append(x.astype(dt) if cast else x)
        return jax.tree.unflatten(self.treedef, leaves)

    def unravel_stacked(self, flat: jnp.ndarray, cast: bool = True) -> Pytree:
        """``[n, P]`` -> pytree with ``[n, *shape]`` leaves."""
        n = flat.shape[0]
        leaves = []
        for off, sz, shp, dt in zip(self.offsets, self.sizes, self.shapes,
                                    self.dtypes):
            x = flat[:, off:off + sz].reshape((n,) + shp)
            leaves.append(x.astype(dt) if cast else x)
        return jax.tree.unflatten(self.treedef, leaves)


_SPEC_CACHE: dict = {}


def make_flat_spec(tree: Pytree, pad_multiple: int = PAD_MULTIPLE,
                   mesh_axis_size: int = 1) -> FlatSpec:
    """Build (or fetch from cache) the FlatSpec for ``tree``'s layout.

    ``tree`` may hold arrays or ShapeDtypeStructs; only structure, shapes and
    dtypes matter.  Safe to call at trace time — everything here is static.

    ``mesh_axis_size=k`` makes the layout shard-aligned: ``P`` is padded to a
    multiple of ``k * pad_multiple`` so the vector splits into ``k`` equal
    contiguous lane-aligned shards (see ``FlatSpec.shard_ranges``).
    """
    if mesh_axis_size < 1:
        raise ValueError(f"mesh_axis_size={mesh_axis_size} must be >= 1")
    leaves, treedef = jax.tree.flatten(tree)
    shapes = tuple(tuple(jnp.shape(x)) for x in leaves)
    dtypes = tuple(jnp.result_type(x) for x in leaves)
    key = (treedef, shapes, tuple(np.dtype(d).name for d in dtypes),
           pad_multiple, mesh_axis_size)
    spec = _SPEC_CACHE.get(key)
    if spec is not None:
        return spec
    sizes = tuple(int(np.prod(s, dtype=np.int64)) for s in shapes)
    offsets = tuple(int(o) for o in np.cumsum((0,) + sizes)[:-1])
    size = int(sum(sizes))
    chunk = pad_multiple * mesh_axis_size
    padded = max(chunk, -(-size // chunk) * chunk)
    spec = FlatSpec(treedef, shapes, dtypes, sizes, offsets, size, padded,
                    mesh_axis_size)
    _SPEC_CACHE[key] = spec
    return spec

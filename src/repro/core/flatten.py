"""Pytree <-> flat-buffer ravel layer for the ServerEngine.

The DuDe server iteration is elementwise over Theta(n * p) buffer state, so
the engine stores all of it as padded flat slabs: ``g_bar`` as ``[P]`` and the
per-worker buffers as ``[n, P]``, where ``P`` is the total parameter count
rounded up to a lane multiple (so the fused Pallas kernel always sees
tileable shapes).  This module owns the mapping between gradient pytrees and
those slabs.

A ``FlatSpec`` is built once per (treedef, leaf shapes/dtypes) and cached: it
records the treedef plus a segment table (offset/size/shape/dtype per leaf)
so ravel is a cast+reshape+concat and unravel is a slice+reshape+cast — both
fuse into neighbouring ops under jit.  Padding is zero-filled and ignored on
unravel; zeros are a fixed point of every engine update, so the pad lanes
never contaminate real state.

Shard-aligned layout: ``make_flat_spec(tree, mesh_axis_size=k)`` pads ``P``
up to a multiple of ``k * PAD_MULTIPLE`` so the flat vector splits into ``k``
contiguous, equally sized, lane-aligned shards — one per device on a P-axis
mesh.  The split is purely positional (segment ranges, not leaf boundaries):
a shard may own the tail of one leaf and the head of the next, and all pad
lanes land in the trailing shard, so no shard ever needs remote elements.
``shard_ranges`` / ``shard_segments`` expose the resulting per-shard segment
table for sharding rules, checkpoint layouts, and debugging.

TP-native exchange: ``unravel_sharded`` / ``ravel_stacked_sharded`` are the
mesh-native twins of ``unravel`` / ``ravel_stacked`` — they move leaves
between the segment-range P-shards and the params' Megatron-TP layout
WITHOUT ever materializing the full ``[P]`` vector (or ``[n, P]`` slab) on
any device.  The k windows of the flat vector circulate around a ppermute
ring; each device copies exactly its TP-block elements out of (into) each
passing window, positions precomputed in a static ``FlatTpPlan``
(``sharding.specs.flat_to_tp_plan``).  Bit-for-bit equal to the replicated
path: elements are copied, never re-reduced.

Documented in docs/engine.md — "Flat layout", "Segment table (FlatSpec)",
"Sharding the flat layout" and "TP-native unravel".
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec

Pytree = Any

__all__ = ["FlatSpec", "make_flat_spec", "PAD_MULTIPLE"]

# Lane width of the TPU vector unit: padding P to a multiple of this keeps
# every backend (and the Pallas tile chooser) shape-happy.
PAD_MULTIPLE = 128


@dataclasses.dataclass(frozen=True)
class FlatSpec:
    """Segment table mapping one pytree layout to a padded flat vector."""

    treedef: Any
    shapes: tuple          # per-leaf shapes
    dtypes: tuple          # per-leaf dtypes (restored on unravel)
    sizes: tuple           # per-leaf element counts
    offsets: tuple         # per-leaf start offset into the flat vector
    size: int              # sum(sizes), before padding
    padded_size: int       # P: size rounded up to mesh_axis_size*PAD_MULTIPLE
    mesh_axis_size: int = 1  # k: number of contiguous P-axis shards

    def __post_init__(self):
        # shard_segments memo: the per-shard table is pure spec geometry but
        # costs a Python loop over all leaves; the TP-native exchange plan
        # reads it per shard per build, so cache per spec instance.  Not a
        # dataclass field: eq/hash stay value-based.
        object.__setattr__(self, "_segments_cache", {})

    # ----------------------------------------------------------- sharding

    @property
    def shard_size(self) -> int:
        """Elements per P-axis shard (``P / k``; a PAD_MULTIPLE multiple)."""
        return self.padded_size // self.mesh_axis_size

    def shard_ranges(self) -> tuple:
        """Per-shard ``(start, stop)`` offsets into the flat vector.  Shard
        ``s`` owns the contiguous slice ``[s*P/k, (s+1)*P/k)``; all pad lanes
        (offsets >= ``size``) fall in the trailing shard(s)."""
        w = self.shard_size
        return tuple((s * w, (s + 1) * w) for s in range(self.mesh_axis_size))

    def shard_segments(self, shard: int) -> tuple:
        """Segment table of one shard: ``(leaf_index, leaf_start, leaf_stop)``
        triples giving, in leaf-local element coordinates, the slice of each
        leaf that shard ``shard`` owns.  Pad lanes are not listed.  Memoized
        per spec (the table is static geometry)."""
        hit = self._segments_cache.get(shard)
        if hit is not None:
            return hit
        lo, hi = self.shard_ranges()[shard]
        out = []
        for i, (off, sz) in enumerate(zip(self.offsets, self.sizes)):
            a, b = max(lo, off), min(hi, off + sz)
            if a < b:
                out.append((i, a - off, b - off))
        result = tuple(out)
        self._segments_cache[shard] = result
        return result

    # ------------------------------------------------------------- ravel

    def ravel(self, tree: Pytree, dtype=jnp.float32) -> jnp.ndarray:
        """Pytree with leaves of ``self.shapes`` -> flat ``[P]`` in ``dtype``."""
        leaves = self.treedef.flatten_up_to(tree)
        flat = [jnp.asarray(x).astype(dtype).reshape(-1) for x in leaves]
        return self._pad(jnp.concatenate(flat) if flat else jnp.zeros((0,), dtype))

    def ravel_stacked(self, tree: Pytree, dtype=jnp.float32) -> jnp.ndarray:
        """Pytree with ``[n, *shape]`` leaves -> ``[n, P]`` in ``dtype``."""
        leaves = self.treedef.flatten_up_to(tree)
        n = jnp.shape(leaves[0])[0]
        flat = [jnp.asarray(x).astype(dtype).reshape(n, -1) for x in leaves]
        return self._pad(jnp.concatenate(flat, axis=-1), n)

    def _pad(self, flat: jnp.ndarray, n: int | None = None) -> jnp.ndarray:
        pad = self.padded_size - self.size
        if pad == 0:
            return flat
        widths = ((0, 0), (0, pad)) if n is not None else ((0, pad),)
        return jnp.pad(flat, widths)

    # ----------------------------------------------------------- unravel

    def unravel(self, flat: jnp.ndarray, cast: bool = True) -> Pytree:
        """Flat ``[P]`` -> pytree with the spec's shapes (and dtypes if
        ``cast``; otherwise leaves keep ``flat.dtype``)."""
        leaves = []
        for off, sz, shp, dt in zip(self.offsets, self.sizes, self.shapes,
                                    self.dtypes):
            x = flat[off:off + sz].reshape(shp)
            leaves.append(x.astype(dt) if cast else x)
        return jax.tree.unflatten(self.treedef, leaves)

    def unravel_stacked(self, flat: jnp.ndarray, cast: bool = True) -> Pytree:
        """``[n, P]`` -> pytree with ``[n, *shape]`` leaves."""
        n = flat.shape[0]
        leaves = []
        for off, sz, shp, dt in zip(self.offsets, self.sizes, self.shapes,
                                    self.dtypes):
            x = flat[:, off:off + sz].reshape((n,) + shp)
            leaves.append(x.astype(dt) if cast else x)
        return jax.tree.unflatten(self.treedef, leaves)

    # ------------------------------------------------- TP-native exchange

    def tp_plan(self, mesh, param_sh: Pytree, axes: Any = None):
        """The static P-shards <-> TP-blocks exchange plan for this spec
        (``sharding.specs.flat_to_tp_plan``; cached)."""
        from ..sharding.specs import flat_to_tp_plan
        return flat_to_tp_plan(self, mesh, param_sh, axes=axes)

    def unravel_sharded(self, flat: jnp.ndarray, mesh, param_sh: Pytree = None,
                        *, axes: Any = None, plan=None,
                        cast: bool = True) -> Pytree:
        """Mesh-native ``unravel``: segment-range P-shards of ``flat`` ->
        leaves in their Megatron-TP layout, with NO device ever holding the
        full ``[P]`` vector.

        The k windows of the flat vector circulate around a ppermute ring
        (k-1 hops of ``[P/k]`` each); at every hop each device copies the
        block elements the passing window carries for it, at positions
        precomputed in the plan.  Values are copied, never combined, so the
        result is bit-for-bit ``unravel`` of the gathered vector.  Peak live
        bytes per device: ``plan.peak_bytes`` — O(P/k + sum of TP blocks)
        instead of the replicated path's O(P)."""
        if plan is None:
            plan = self.tp_plan(mesh, param_sh, axes=axes)
        if plan.k <= 1:
            return self.unravel(flat, cast=cast)
        Wh = plan.window >> _LO_BITS  # window rows of _LO lanes each
        sizes = dict(zip(plan.axes, plan.mesh_shape))

        def body(local):  # [W]: this device's window of the flat vector
            s = _lin_index(plan.axes, sizes)
            digs = [_leaf_digits(lf, sizes) for lf in plan.leaves]

            def take(accs, buf, w):
                # copy my block elements carried by window ``w``
                whi = w * Wh
                buf2 = buf.reshape(Wh, _LO)
                out = []
                for lf, (hi, lo), acc in zip(plan.leaves, digs, accs):
                    parts = []
                    for a, b in _chunks(lf.block_size):
                        row = hi[a:b] - whi
                        ok = (row >= 0) & (row < Wh)
                        vals = buf2[jnp.clip(row, 0, Wh - 1), lo[a:b]]
                        parts.append(jnp.where(ok, vals, acc[a:b]))
                    out.append(parts[0] if len(parts) == 1
                               else jnp.concatenate(parts))
                return tuple(out)

            accs = tuple(jnp.zeros((lf.block_size,), local.dtype)
                         for lf in plan.leaves)
            accs = take(accs, local, s)
            perm = [(i, (i - 1) % plan.k) for i in range(plan.k)]

            def hop(r, carry):
                buf, accs = carry
                buf = jax.lax.ppermute(buf, plan.axes, perm)
                return buf, take(accs, buf, (s + r) % plan.k)

            _, accs = jax.lax.fori_loop(1, plan.k, hop, (local, accs))
            outs = []
            for lf, acc in zip(plan.leaves, accs):
                x = acc.reshape(lf.block_shape)
                outs.append(x.astype(lf.dtype) if cast else x)
            return tuple(outs)

        fn = shard_map(
            body, mesh=mesh, in_specs=PartitionSpec(plan.axes),
            out_specs=tuple(PartitionSpec(*lf.entries) for lf in plan.leaves),
            check_rep=False)
        return jax.tree.unflatten(self.treedef, list(fn(flat)))

    def ravel_stacked_sharded(self, tree: Pytree, mesh,
                              param_sh: Pytree = None, dtype=jnp.float32,
                              *, axes: Any = None, plan=None) -> jnp.ndarray:
        """Mesh-native ``ravel_stacked``: ``[n, *shape]`` leaves in their TP
        layout -> the ``[n, P]`` slab in segment-range P-shards, with no
        replicated ``[n, P]`` (or full-leaf) intermediate.

        The reverse ring: each device's ``[n, P/k]`` window accumulator
        makes one lap, visiting every device; each device writes its block
        values into the positions the passing accumulator owns.  The flat
        positions of distinct (device, leaf) contributions are disjoint
        (replicated leaves contribute from their first replica only), so
        the writes are pure scatters — bit-for-bit ``ravel_stacked``,
        including signed zeros.  Pad lanes stay zero."""
        if plan is None:
            plan = self.tp_plan(mesh, param_sh, axes=axes)
        leaves = self.treedef.flatten_up_to(tree)
        if plan.k <= 1:
            return self.ravel_stacked(tree, dtype)
        n = int(jnp.shape(leaves[0])[0])
        W = plan.window
        Wh = W >> _LO_BITS
        sizes = dict(zip(plan.axes, plan.mesh_shape))

        def body(*blocks):  # per leaf: [n, *block_shape]
            s = _lin_index(plan.axes, sizes)
            digs = [_leaf_digits(lf, sizes) for lf in plan.leaves]
            masks = [_replica_mask(lf, plan.axes) for lf in plan.leaves]

            def contrib(acc, h):
                # write my block values owned by window ``h``
                whi = h * Wh
                acc3 = acc.reshape(n, Wh, _LO)
                for lf, (hi, lo), mk, blk in zip(plan.leaves, digs, masks,
                                                 blocks):
                    vals = blk.reshape((n, -1)).astype(dtype)
                    for a, b in _chunks(lf.block_size):
                        row = hi[a:b] - whi
                        row = jnp.where(mk & (row >= 0) & (row < Wh),
                                        row, Wh)
                        acc3 = acc3.at[:, row, lo[a:b]].set(vals[:, a:b],
                                                            mode="drop")
                return acc3.reshape(n, W)

            acc = contrib(jnp.zeros((n, W), dtype), (s - 1) % plan.k)
            perm = [(i, (i + 1) % plan.k) for i in range(plan.k)]

            def hop(r, acc):
                acc = jax.lax.ppermute(acc, plan.axes, perm)
                return contrib(acc, (s - r - 1) % plan.k)

            acc = jax.lax.fori_loop(1, plan.k, hop, acc)
            return acc

        fn = shard_map(
            body, mesh=mesh,
            in_specs=tuple(PartitionSpec(None, *lf.entries)
                           for lf in plan.leaves),
            out_specs=PartitionSpec(None, plan.axes), check_rep=False)
        return fn(*leaves)


# Window addressing is two int32 digits, ``pos == hi * _LO + lo``: a jit
# traced with x64 off canonicalizes every jaxpr literal/constant to int32 at
# LOWERING time regardless of the equation's aval, so int64 position vectors
# (and even small literals sitting next to an i64 tracer, or the axis-size
# constants jnp's own index normalization inserts) cannot cross the lowering
# of a >2^31-element spec.  With 128 lanes per row every digit stays below
# 2^31 for any P < 2^38 (~274 B params); ``flat_to_tp_plan`` rejects larger.
_LO_BITS = 7
_LO = 1 << _LO_BITS

# XLA caps a single gather/scatter at 2^31 indices; leaves past _CHUNK block
# elements (the 110B embedding on a small host mesh) exchange in static
# slices.  One chunk — the overwhelmingly common case — lowers identically
# to the unchunked op.
_CHUNK = 1 << 30


def _chunks(size: int):
    return [(a, min(a + _CHUNK, size)) for a in range(0, size, _CHUNK)]


def _lin_index(axes: tuple, sizes: dict) -> jnp.ndarray:
    """This device's linear P-shard index over ``axes`` (major -> minor),
    matching the shard order of ``PartitionSpec((axes,))``."""
    idx = jnp.asarray(0, jnp.int32)
    for a in axes:
        idx = idx * sizes[a] + jax.lax.axis_index(a)
    return idx


def _leaf_digits(lf, sizes: dict):
    """Digits ``(pos >> 7, pos & 127)`` of the global flat positions of this
    device's TP block of leaf ``lf`` (``pos = offset + sum_d (block_start_d +
    coord_d) * stride_d``, row-major ``[block_size]``), int32 throughout and
    fully traced — no materialized position constants, so the lowered module
    stays O(sum of block dims), not O(block elements).

    Every term's digits are formed from int32 pieces: splitting a stride
    ``m = (m >> 7)·128 + (m & 127)``, the high digit ``c·(m >> 7) +
    (c·(m & 127) >> 7)`` of a term is bounded by ``pos / 128 < 2^31``
    (``flat_to_tp_plan`` rejects ``P >= 2^38``), and the low digits sum to
    under ``rank·2^31`` before the final carry."""
    rank = len(lf.shape)

    def digits(c, m):  # digits of c*m: c int32 scalar/vector, m static < P
        t = c * np.int32(m & (_LO - 1))  # < dim * 128
        return c * np.int32(m >> _LO_BITS) + (t >> _LO_BITS), t & (_LO - 1)

    hi = jnp.asarray(lf.offset >> _LO_BITS, jnp.int32)
    lo = jnp.asarray(lf.offset & (_LO - 1), jnp.int32)
    for d in range(rank):
        bs = lf.block_shape[d]
        if bs * (lf.strides[d] & (_LO - 1)) > np.iinfo(np.int32).max:
            raise NotImplementedError(
                f"leaf dim {d} of shape {lf.shape}: dim * (stride % 128) "
                f"overflows int32 in the digit addressing")
        coords = jnp.arange(bs, dtype=jnp.int32)
        if lf.entries[d] is not None:
            bidx = jnp.asarray(0, jnp.int32)
            for a in lf.entries[d]:
                bidx = bidx * sizes[a] + jax.lax.axis_index(a)
            bhi, blo = digits(bidx, bs * lf.strides[d])  # block start
        else:
            bhi = blo = jnp.asarray(0, jnp.int32)
        chi, clo = digits(coords, lf.strides[d])
        shape = [1] * rank
        shape[d] = bs
        hi = hi + (bhi + chi).reshape(shape)
        lo = lo + (blo + clo).reshape(shape)
    hi = jnp.broadcast_to(hi + (lo >> _LO_BITS), lf.block_shape).reshape(-1)
    lo = jnp.broadcast_to(lo & (_LO - 1), lf.block_shape).reshape(-1)
    return hi, lo


def _replica_mask(lf, axes: tuple) -> jnp.ndarray:
    """True on the first replica of this leaf's TP block: a leaf replicated
    over some P-axis group axes exists on several devices, but only one may
    contribute it to the slab."""
    used = set(lf.tp_axes)
    m = None
    for a in axes:
        if a not in used:
            c = jax.lax.axis_index(a) == 0
            m = c if m is None else (m & c)
    return jnp.asarray(True) if m is None else m


_SPEC_CACHE: dict = {}


def make_flat_spec(tree: Pytree, pad_multiple: int = PAD_MULTIPLE,
                   mesh_axis_size: int = 1) -> FlatSpec:
    """Build (or fetch from cache) the FlatSpec for ``tree``'s layout.

    ``tree`` may hold arrays or ShapeDtypeStructs; only structure, shapes and
    dtypes matter.  Safe to call at trace time — everything here is static.

    ``mesh_axis_size=k`` makes the layout shard-aligned: ``P`` is padded to a
    multiple of ``k * pad_multiple`` so the vector splits into ``k`` equal
    contiguous lane-aligned shards (see ``FlatSpec.shard_ranges``).
    """
    if mesh_axis_size < 1:
        raise ValueError(f"mesh_axis_size={mesh_axis_size} must be >= 1")
    leaves, treedef = jax.tree.flatten(tree)
    shapes = tuple(tuple(jnp.shape(x)) for x in leaves)
    dtypes = tuple(jnp.result_type(x) for x in leaves)
    key = (treedef, shapes, tuple(np.dtype(d).name for d in dtypes),
           pad_multiple, mesh_axis_size)
    spec = _SPEC_CACHE.get(key)
    if spec is not None:
        return spec
    sizes = tuple(int(np.prod(s, dtype=np.int64)) for s in shapes)
    offsets = tuple(int(o) for o in np.cumsum((0,) + sizes)[:-1])
    size = int(sum(sizes))
    chunk = pad_multiple * mesh_axis_size
    padded = max(chunk, -(-size // chunk) * chunk)
    spec = FlatSpec(treedef, shapes, dtypes, sizes, offsets, size, padded,
                    mesh_axis_size)
    _SPEC_CACHE[key] = spec
    return spec

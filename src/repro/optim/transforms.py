"""Minimal pure-JAX optimizer transforms (no optax offline).

API: ``opt = sgd(lr)``; ``state = opt.init(params)``;
``params, state = opt.apply(params, direction, state)``.

The *direction* is whatever the server algorithm produces — for DuDe-ASGD it
is the dual-delayed aggregated gradient g^t, so optimizers compose with the
paper's protocol unchanged (the paper uses plain SGD; momentum/AdamW are
framework extensions applied on top of g^t).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Pytree = Any


class OptState(NamedTuple):
    step: jnp.ndarray
    slots: Pytree


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Pytree], OptState]
    apply: Callable[[Pytree, Pytree, OptState], tuple[Pytree, OptState]]
    name: str = "opt"


def sgd(lr: float) -> Optimizer:
    def init(params):
        return OptState(jnp.zeros((), jnp.int32), ())

    def apply(params, g, state):
        new = jax.tree.map(lambda p, d: p - lr * d.astype(p.dtype), params, g)
        return new, OptState(state.step + 1, ())

    return Optimizer(init, apply, "sgd")


def momentum_sgd(lr: float, beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        m = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return OptState(jnp.zeros((), jnp.int32), m)

    def apply(params, g, state):
        m = jax.tree.map(
            lambda mi, gi: beta * mi + gi.astype(jnp.float32), state.slots, g
        )
        d = (
            jax.tree.map(lambda mi, gi: beta * mi + gi.astype(jnp.float32), m, g)
            if nesterov else m
        )
        new = jax.tree.map(lambda p, di: p - lr * di.astype(p.dtype), params, d)
        return new, OptState(state.step + 1, m)

    return Optimizer(init, apply, "momentum")


def adamw(
    lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return OptState(
            jnp.zeros((), jnp.int32),
            {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)},
        )

    def apply(params, g, state):
        t = state.step + 1
        m = jax.tree.map(
            lambda mi, gi: b1 * mi + (1 - b1) * gi.astype(jnp.float32),
            state.slots["m"], g,
        )
        v = jax.tree.map(
            lambda vi, gi: b2 * vi + (1 - b2) * jnp.square(gi.astype(jnp.float32)),
            state.slots["v"], g,
        )
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def upd(p, mi, vi):
            mh = mi / bc1
            vh = vi / bc2
            step = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
            return p - (lr * step).astype(p.dtype)

        new = jax.tree.map(upd, params, m, v)
        return new, OptState(t, {"m": m, "v": v})

    return Optimizer(init, apply, "adamw")

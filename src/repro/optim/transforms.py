"""Minimal pure-JAX optimizer transforms (no optax offline).

API: ``opt = sgd(lr)``; ``state = opt.init(params)``;
``params, state = opt.apply(params, direction, state)``.

The *direction* is whatever the server algorithm produces — for DuDe-ASGD it
is the dual-delayed aggregated gradient g^t, so optimizers compose with the
paper's protocol unchanged (the paper uses plain SGD; momentum/AdamW are
framework extensions applied on top of g^t).

Flat twins
----------
Every pytree optimizer here has a **flat twin** operating on the engine's
padded ``[P]`` slab layout (``core/flatten.py``): master params are one f32
``[P]`` vector, slots are ``[P]`` slabs (momentum ``m``, AdamW ``{m, v}``),
and the update is purely elementwise on P — so it runs zero-collective under
the engine's P-axis ``shard_map`` and fuses into the DuDe round
(``DuDeEngine.round_apply``).  The twin's math mirrors the pytree apply
op-for-op: on f32 params the two paths agree bit-for-bit after
ravel/unravel (``tests/test_flat_state.py``).  Zero is a fixed point of all
three update rules, so the pad lanes of the slab never drift.

``FLAT_OPTIMIZERS`` maps each pytree optimizer name to its flat factory;
``flat_twin(opt)`` rebuilds the twin from the recorded hyperparameters.
``FlatTrainState`` bundles the flat master params, the flat optimizer state,
and the server rule's slabs (the engine's ``EngineState`` for the DuDe
family) — the whole training state in one P-axis-sharded layout, consumed
by the round step (``launch/steps.py``) and the per-arrival async runner
(``runtime/runner.py``) alike.

Documented in docs/engine.md — "Flat training state".
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Pytree = Any

__all__ = [
    "OptState", "Optimizer", "sgd", "momentum_sgd", "adamw",
    "FlatOptState", "FlatOptimizer", "FlatTrainState",
    "flat_sgd", "flat_momentum_sgd", "flat_adamw",
    "FLAT_OPTIMIZERS", "flat_twin",
]


class OptState(NamedTuple):
    step: jnp.ndarray
    slots: Pytree


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Pytree], OptState]
    apply: Callable[[Pytree, Pytree, OptState], tuple[Pytree, OptState]]
    name: str = "opt"
    # hyperparameters as a static (key, value) tuple so the flat twin can be
    # rebuilt from the pytree optimizer alone (``flat_twin``)
    hparams: tuple = ()


def sgd(lr: float) -> Optimizer:
    def init(params):
        return OptState(jnp.zeros((), jnp.int32), ())

    def apply(params, g, state):
        new = jax.tree.map(lambda p, d: p - lr * d.astype(p.dtype), params, g)
        return new, OptState(state.step + 1, ())

    return Optimizer(init, apply, "sgd", (("lr", lr),))


def momentum_sgd(lr: float, beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        m = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return OptState(jnp.zeros((), jnp.int32), m)

    def apply(params, g, state):
        m = jax.tree.map(
            lambda mi, gi: beta * mi + gi.astype(jnp.float32), state.slots, g
        )
        d = (
            jax.tree.map(lambda mi, gi: beta * mi + gi.astype(jnp.float32), m, g)
            if nesterov else m
        )
        new = jax.tree.map(lambda p, di: p - lr * di.astype(p.dtype), params, d)
        return new, OptState(state.step + 1, m)

    return Optimizer(init, apply, "momentum",
                     (("lr", lr), ("beta", beta), ("nesterov", nesterov)))


def adamw(
    lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return OptState(
            jnp.zeros((), jnp.int32),
            {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)},
        )

    def apply(params, g, state):
        t = state.step + 1
        m = jax.tree.map(
            lambda mi, gi: b1 * mi + (1 - b1) * gi.astype(jnp.float32),
            state.slots["m"], g,
        )
        v = jax.tree.map(
            lambda vi, gi: b2 * vi + (1 - b2) * jnp.square(gi.astype(jnp.float32)),
            state.slots["v"], g,
        )
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def upd(p, mi, vi):
            mh = mi / bc1
            vh = vi / bc2
            step = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
            return p - (lr * step).astype(p.dtype)

        new = jax.tree.map(upd, params, m, v)
        return new, OptState(t, {"m": m, "v": v})

    return Optimizer(init, apply, "adamw",
                     (("lr", lr), ("b1", b1), ("b2", b2), ("eps", eps),
                      ("weight_decay", weight_decay)))


# ---------------------------------------------------------------- flat twins


class FlatOptState(NamedTuple):
    """Optimizer state on the flat slab layout: ``slots`` holds only padded
    ``[P]`` f32 vectors (``()`` for sgd, ``m`` for momentum, ``{m, v}`` for
    AdamW), so it shards with the same segment-range P-axis rule as the
    engine slabs."""

    step: jnp.ndarray   # scalar i32, replicated
    slots: Pytree       # pytree of [P] f32 slabs


class FlatTrainState(NamedTuple):
    """The whole training state as P-axis-shardable flat slabs: f32 master
    params ``[P]``, flat optimizer slots, and the DuDe ``EngineState``.
    Built by ``launch.steps.init_flat_train_state``; sharded by
    ``sharding.specs.flat_train_state_shardings``."""

    params: jnp.ndarray  # [P] f32 flat master params
    opt: FlatOptState
    engine: Any          # core.engine.EngineState


@dataclasses.dataclass(frozen=True)
class FlatOptimizer:
    """Flat-slab optimizer: ``init``/``apply`` on ``[P]`` f32 vectors.

    ``update(params, g, slots, t)`` is the elementwise core (t = the step
    AFTER increment): it is what ``DuDeEngine.round_apply`` calls inside its
    ``shard_map`` body, and what the fused Pallas kernel mirrors stream-for-
    stream.  ``apply`` wraps it with the step-counter bump for standalone
    use.  Hyperparameters are a static (key, value) tuple so engines can
    read them at trace time (e.g. to parametrize the kernel).
    """

    name: str
    hparams: tuple = ()

    @property
    def hp(self) -> dict:
        return dict(self.hparams)

    def init_slots(self, params_flat: jnp.ndarray) -> Pytree:
        z = lambda: jnp.zeros_like(params_flat, jnp.float32)
        if self.name == "sgd":
            return ()
        if self.name == "momentum":
            return z()
        if self.name == "adamw":
            return {"m": z(), "v": z()}
        raise ValueError(f"unknown flat optimizer {self.name!r}")

    def init(self, params_flat: jnp.ndarray) -> FlatOptState:
        return FlatOptState(jnp.zeros((), jnp.int32),
                            self.init_slots(params_flat))

    def update(self, params: jnp.ndarray, g: jnp.ndarray, slots: Pytree,
               t: jnp.ndarray) -> tuple[jnp.ndarray, Pytree]:
        """One elementwise step on [P] slabs; mirrors the pytree apply
        op-for-op (bit-for-bit on f32 params)."""
        hp = self.hp
        g = g.astype(jnp.float32)
        if self.name == "sgd":
            return params - hp["lr"] * g, slots
        if self.name == "momentum":
            beta = hp["beta"]
            m = beta * slots + g
            d = beta * m + g if hp["nesterov"] else m
            return params - hp["lr"] * d, m
        if self.name == "adamw":
            b1, b2 = hp["b1"], hp["b2"]
            m = b1 * slots["m"] + (1 - b1) * g
            v = b2 * slots["v"] + (1 - b2) * jnp.square(g)
            bc1 = 1 - b1 ** t.astype(jnp.float32)
            bc2 = 1 - b2 ** t.astype(jnp.float32)
            step = (m / bc1) / (jnp.sqrt(v / bc2) + hp["eps"]) \
                + hp["weight_decay"] * params
            return params - hp["lr"] * step, {"m": m, "v": v}
        raise ValueError(f"unknown flat optimizer {self.name!r}")

    def apply(self, params: jnp.ndarray, g: jnp.ndarray,
              state: FlatOptState) -> tuple[jnp.ndarray, FlatOptState]:
        t = state.step + 1
        params, slots = self.update(params, g, state.slots, t)
        return params, FlatOptState(t, slots)


def flat_sgd(lr: float) -> FlatOptimizer:
    return FlatOptimizer("sgd", (("lr", lr),))


def flat_momentum_sgd(lr: float, beta: float = 0.9,
                      nesterov: bool = False) -> FlatOptimizer:
    return FlatOptimizer("momentum",
                         (("lr", lr), ("beta", beta), ("nesterov", nesterov)))


def flat_adamw(lr: float, b1: float = 0.9, b2: float = 0.999,
               eps: float = 1e-8, weight_decay: float = 0.0) -> FlatOptimizer:
    return FlatOptimizer("adamw",
                         (("lr", lr), ("b1", b1), ("b2", b2), ("eps", eps),
                          ("weight_decay", weight_decay)))


# registry: pytree optimizer name -> flat factory
FLAT_OPTIMIZERS = {
    "sgd": flat_sgd,
    "momentum": flat_momentum_sgd,
    "adamw": flat_adamw,
}


def flat_twin(opt) -> FlatOptimizer:
    """The flat-slab twin of a pytree ``Optimizer`` (or a ``FlatOptimizer``
    passed through unchanged), rebuilt from its recorded hyperparameters."""
    if isinstance(opt, FlatOptimizer):
        return opt
    try:
        factory = FLAT_OPTIMIZERS[opt.name]
    except KeyError:
        raise ValueError(
            f"optimizer {opt.name!r} has no flat twin; registered: "
            f"{tuple(FLAT_OPTIMIZERS)}") from None
    return factory(**dict(opt.hparams))

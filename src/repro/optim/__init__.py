from .transforms import (
    FLAT_OPTIMIZERS,
    FlatOptimizer,
    FlatOptState,
    FlatTrainState,
    Optimizer,
    OptState,
    adamw,
    flat_adamw,
    flat_momentum_sgd,
    flat_sgd,
    flat_twin,
    momentum_sgd,
    sgd,
)

__all__ = [
    "Optimizer", "OptState", "sgd", "momentum_sgd", "adamw",
    "FlatOptState", "FlatOptimizer", "FlatTrainState",
    "flat_sgd", "flat_momentum_sgd", "flat_adamw",
    "FLAT_OPTIMIZERS", "flat_twin",
]

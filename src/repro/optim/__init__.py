from .transforms import OptState, adamw, momentum_sgd, sgd

__all__ = ["OptState", "sgd", "momentum_sgd", "adamw"]

"""musicgen-large [audio] — 48L d_model=2048 32H (kv=32) d_ff=8192 vocab=2048
— decoder-only over EnCodec tokens.  [arXiv:2306.05284]

4 EnCodec codebooks (delay-pattern interleave abstracted as per-step sums of
4 codebook embeddings + 4 output heads).  Conditioning frontend (T5 text /
melody) is the sanctioned stub: 64 conditioning-frame embeddings prepended.
Full attention, no sub-quadratic claim => long_500k is SKIPPED for this arch
(DESIGN.md §4).
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    arch_type="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    head_dim=64,
    num_codebooks=4,
    frontend="audio",
    frontend_dim=768,
    num_prefix_tokens=64,
    n_workers=16,
    source="arXiv:2306.05284",
)

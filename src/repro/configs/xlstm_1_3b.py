"""xlstm-1.3b [ssm] — 48L d_model=2048 4H d_ff=0 vocab=50304 — sLSTM + mLSTM
blocks.  [arXiv:2405.04517]

Period-8 pattern (7 mLSTM : 1 sLSTM) following the paper's xLSTM[7:1] ratio;
48 layers = 6 scanned groups.  Attention-free => long_500k decodes natively
with O(state) memory.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    arch_type="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    block_pattern=("mlstm",) * 7 + ("slstm",),
    n_workers=16,
    source="arXiv:2405.04517",
)

"""llava-next-mistral-7b [vlm] — Mistral-7B language backbone of LLaVA-NeXT.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000 — anyres tiling.
[hf:llava-hf/llava-v1.6-mistral-7b-hf]

The vision side (SigLIP/CLIP ViT + anyres tile grid) is the sanctioned stub:
``input_specs`` supplies 1152-d patch embeddings (2 tiles x 576 patches); the
backbone owns the multimodal projector.  Mistral's 4096-token sliding window
makes long_500k decodable.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    arch_type="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    head_dim=128,
    rope_theta=1e6,
    sliding_window=4096,
    frontend="vision",
    frontend_dim=1152,
    num_prefix_tokens=1152,   # 2 anyres tiles x 576 patches
    n_workers=16,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)

"""zamba2-2.7b [hybrid] — 54L d_model=2560 32H (kv=32) d_ff=10240
vocab=32000, ssm_state=64 — Mamba2 blocks + shared attention block.
[arXiv:2411.15242]

Period-6 pattern: 5 Mamba2 blocks then one Mamba2 block followed by the
SHARED attention+MLP block (one weight set reused at all 9 occurrences —
zamba2's parameter-sharing trick).  54 layers = 9 scanned groups.
Mamba2 backbone => long_500k decodes natively.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    arch_type="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    head_dim=80,
    ssm_state=64,
    block_pattern=("mamba",) * 5 + ("mamba_shared_attn",),
    n_workers=16,
    source="arXiv:2411.15242",
)

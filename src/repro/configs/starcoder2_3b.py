"""starcoder2-3b [dense] — 30L d_model=3072 24H (GQA kv=2) d_ff=12288
vocab=49152 — GQA, RoPE.  [arXiv:2402.19173]

StarCoder2's native 4096 sliding window enables long_500k.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    arch_type="dense",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    d_ff=12288,
    vocab_size=49152,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1e5,
    sliding_window=4096,
    mlp_gated=False,  # starcoder2 uses a 2-matrix GELU MLP

    n_workers=16,
    source="arXiv:2402.19173",
)

"""Assigned-architecture registry: ``get_config(arch_id)``.

Each module defines CONFIG (exact assigned numbers, source cited) — the full
config is exercised via the multi-pod dry-run (ShapeDtypeStruct only); smoke
tests use ``CONFIG.smoke()``.
"""

from __future__ import annotations

from importlib import import_module

from ..models.config import ModelConfig

ARCH_IDS = (
    "llava_next_mistral_7b",
    "qwen1_5_110b",
    "xlstm_1_3b",
    "musicgen_large",
    "starcoder2_3b",
    "olmoe_1b_7b",
    "qwen2_0_5b",
    "zamba2_2_7b",
    "qwen3_1_7b",
    "kimi_k2_1t_a32b",
)

_ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}
_ALIASES.update({
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "qwen1.5-110b": "qwen1_5_110b",
    "xlstm-1.3b": "xlstm_1_3b",
    "musicgen-large": "musicgen_large",
    "starcoder2-3b": "starcoder2_3b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "qwen2-0.5b": "qwen2_0_5b",
    "zamba2-2.7b": "zamba2_2_7b",
    "qwen3-1.7b": "qwen3_1_7b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
})


def get_config(name: str) -> ModelConfig:
    key = _ALIASES.get(name, name)
    if key not in ARCH_IDS:
        raise ValueError(f"unknown arch {name!r}; options: {sorted(_ALIASES)}")
    return import_module(f"repro.configs.{key}").CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}

"""qwen2-0.5b [dense] — 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151936 — GQA, QKV bias, tied embeddings.  [arXiv:2407.10671]
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    arch_type="dense",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=151936,
    head_dim=64,
    qkv_bias=True,
    rope_theta=1e6,
    tie_embeddings=True,
    sliding_window=4096,
    n_workers=16,
    source="arXiv:2407.10671",
)

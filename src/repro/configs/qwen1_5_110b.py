"""qwen1.5-110b [dense] — 80L d_model=8192 64H (GQA kv=8) d_ff=49152
vocab=152064 — QKV bias.  [hf:Qwen/Qwen1.5-0.5B (family card), 110B variant]

The big-dense stressor for the mesh: 110B params => DuDe server state is the
dominant HBM term, so this arch defaults to n_workers=4 with bf16 buffers
(DESIGN.md §7).  sliding_window is a framework extension (off in the source
model) enabling the long_500k shape; EXPERIMENTS notes it as beyond-spec.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    arch_type="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=49152,
    vocab_size=152064,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1e6,
    sliding_window=8192,
    n_workers=4,
    source="hf:Qwen/Qwen1.5-0.5B",
)

"""kimi-k2-1t-a32b [moe] — 61L d_model=7168 64H (GQA kv=8) vocab=163840,
MoE 384 experts top-8 + 1 shared, expert d_ff=2048 — trillion-param
paper-table entry.  [arXiv:2501.kimi2]

Layer 0 is dense (first_k_dense_replace=1, d_ff 18432); 60 MoE layers scanned.
1T total / ~32B active params: the extreme memory + all-to-all stressor —
DuDe runs with n_workers=2, bf16 buffers; EXPERIMENTS §Dry-run reports the
per-device byte shortfalls honestly.  sliding_window is a framework extension
(beyond-spec) enabling long_500k.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    arch_type="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    d_ff=2048,
    moe_d_ff=2048,
    dense_d_ff=18432,
    vocab_size=163840,
    head_dim=112,
    prefix_layers=("attn",),
    block_pattern=("moe",),
    num_experts=384,
    experts_per_tok=8,
    num_shared_experts=1,
    qk_norm=True,
    sliding_window=8192,
    n_workers=2,
    source="arXiv:2501.kimi2",
)

"""olmoe-1b-7b [moe] — 16L d_model=2048 16H (kv=16) vocab=50304,
MoE 64 experts top-8, expert d_ff=1024 — qk-norm.  [arXiv:2409.02060]

Expert-parallel over the `model` axis (64/16 = 4 experts per device);
the dispatch/combine all-to-all is the MoE collective roofline term.
sliding_window is a framework extension enabling long_500k (beyond-spec).
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    arch_type="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1024,
    moe_d_ff=1024,
    vocab_size=50304,
    head_dim=128,
    qk_norm=True,
    block_pattern=("moe",),
    num_experts=64,
    experts_per_tok=8,
    sliding_window=4096,
    n_workers=16,
    source="arXiv:2409.02060",
)

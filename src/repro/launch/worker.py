"""Multi-host worker entrypoint: compute gradients for a remote server.

The client half of the multi-host runtime (docs/async.md "Multi-host
transport"): dials a ``launch/train.py --serve`` server, claims a range of
logical workers, and loops — decode the model snapshot the server ships,
draw the worker's local batch, compute one stochastic gradient, frame it
back as a commit.  No engine state lives here: the worker needs only the
model config (to build the same ``FlatSpec`` and loss), so a worker
process is cheap enough to run many logical workers.

Determinism: the batch and PRNG key of worker ``w``'s job ``j`` depend
only on ``(seed, w, j)`` (``runtime.runner.worker_key`` /
``worker_rng``), and the snapshot decode / gradient / ravel jits are the
same expressions the server's replay runs — so the single-process
``AsyncRunner`` replaying the recorded trace reproduces this process's
commits bit-for-bit.

Example (against the smoke server in the CI multi-host job)::

  PYTHONPATH=src python -m repro.launch.train --arch qwen2_0_5b --smoke \
      --async --serve 127.0.0.1:7781 --expect-links 2 \
      --commit-format topk_ef --sparse-transport --rounds 40 \
      --trace-out trace.json --replay-check &
  PYTHONPATH=src python -m repro.launch.worker --arch qwen2_0_5b --smoke \
      --connect 127.0.0.1:7781 --workers 0-1 &
  PYTHONPATH=src python -m repro.launch.worker --arch qwen2_0_5b --smoke \
      --connect 127.0.0.1:7781 --workers 2-3
"""

from __future__ import annotations

import argparse
import json
import time

import jax

from repro.configs import get_config
from repro.core.flatten import make_flat_spec
from repro.launch.sampling import make_worker_sample_fn
from repro.launch.steps import abstract_params
from repro.models import loss_fn
from repro.runtime.hostloop import run_worker
from repro.runtime.transport import connect
from repro.sharding import make_shard_hook


def parse_workers(spec: str) -> tuple:
    """``"0-3"`` (inclusive) or ``"0,2,5"`` -> logical worker ids."""
    if "-" in spec:
        lo, hi = (int(x) for x in spec.split("-"))
        return tuple(range(lo, hi + 1))
    return tuple(int(x) for x in spec.split(","))


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--connect", required=True, metavar="HOST:PORT",
                    help="the --serve address of the server process")
    ap.add_argument("--workers", required=True,
                    help='logical worker ids this process serves: "0-3" '
                         '(inclusive range) or "0,2,5"')
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--per-worker-batch", type=int, default=2)
    ap.add_argument("--heterogeneity", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0,
                    help="must match the server's --seed (fixes the "
                         "per-worker data distributions; gradient keys "
                         "come from the server's WELCOME seed)")
    ap.add_argument("--axis-size", type=int, default=1,
                    help="the server engine's P-axis mesh size (pads the "
                         "local FlatSpec identically; 1 for a meshless "
                         "server)")
    ap.add_argument("--timeout", type=float, default=30.0,
                    help="per send/recv socket timeout")
    ap.add_argument("--max-reconnects", type=int, default=3,
                    help="re-dial attempts after a dropped connection "
                         "(0 = die with the first drop)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    workers = parse_workers(args.workers)
    for w in workers:
        if not 0 <= w < cfg.n_workers:
            ap.error(f"worker {w} outside [0, {cfg.n_workers})")

    spec = make_flat_spec(abstract_params(cfg),
                          mesh_axis_size=args.axis_size)
    sample_fn = make_worker_sample_fn(
        cfg, seq_len=args.seq_len, per_worker_batch=args.per_worker_batch,
        heterogeneity=args.heterogeneity, seed=args.seed)
    # the same gradient the server's Trainer computes (meshless hook) — the
    # replay oracle depends on this being the identical jitted expression
    shard = make_shard_hook(None)

    def grad_fn(params, batch, key):
        (_, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg, shard=shard), has_aux=True
        )(params)
        return metrics["loss"], grads

    host, port = args.connect.rsplit(":", 1)
    print(f"[worker] {args.arch} workers={list(workers)} -> {args.connect}")
    t0 = time.time()
    stats = run_worker(
        lambda: connect(host, int(port), timeout=args.timeout),
        workers, grad_fn, sample_fn, spec,
        max_reconnects=args.max_reconnects)
    stats["workers"] = list(workers)
    stats["wall_s"] = round(time.time() - t0, 1)
    print(json.dumps(stats))


if __name__ == "__main__":
    main()

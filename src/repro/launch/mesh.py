"""Production meshes.

Target hardware: TPU v5e pods — 256 chips/pod (16x16), 2 pods = 512 chips.
Functions (not module constants) so importing never touches jax device state.
"""

from __future__ import annotations

import jax

HW = {
    "peak_flops_bf16": 197e12,   # per chip
    "hbm_bw": 819e9,             # bytes/s per chip
    "ici_bw": 50e9,              # bytes/s per link
    "hbm_bytes": 16e9,           # per chip
}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for CI-grade sharding tests (requires host-device override)."""
    return jax.make_mesh(shape, axes)


def mesh_num_devices(mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n

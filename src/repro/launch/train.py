"""Production training driver (DESIGN.md mode B): semi-async ROUND training
or event-driven PER-ARRIVAL training (``--async``) on whatever mesh is
available, through the one ``api.Trainer`` session — every server algorithm
in the ``core/algos.py`` registries runs the same mesh-native flat engine
state.

On the real cluster this runs under the 16x16 / 2x16x16 production meshes
(see dryrun.py for the lowering proof); on this CPU container it runs the
same code path on a 1-device mesh at reduced scale (or a host-platform
multi-device mesh via --mesh and XLA_FLAGS=--xla_force_host_platform_device_count=N).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2_0_5b --smoke \
      --rounds 50 --seq-len 64 --per-worker-batch 2 --algo dude
  # a Table-1 baseline through the same engine path:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2_0_5b --smoke \
      --rounds 50 --algo fedbuff
  # event-driven per-arrival training (docs/async.md): exponential
  # stragglers, one engine.commit + optimizer apply per gradient arrival
  PYTHONPATH=src python -m repro.launch.train --arch qwen2_0_5b --smoke \
      --async --arrival exp --rounds 50 --algo dude --trace-out trace.json
  # bit-exact replay of that run's arrival schedule:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2_0_5b --smoke \
      --async --arrival trace --trace-in trace.json --rounds 50 --algo dude
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import (CheckpointPolicy, ConfigError, Trainer,
                       TrainerConfig, TransportPolicy)
from repro.api.config import OPTIMIZERS
from repro.core import (
    ASYNC_ALGOS, BACKENDS, COMMIT_FORMATS, ROUND_ALGOS, delay_stats,
    make_round_schedule,
    truncated_normal_speeds,
)
from repro.launch.sampling import make_worker_sample_fn
from repro.runtime import (
    ARRIVAL_KINDS, SCENARIO_KINDS, ExponentialArrivals, FixedArrivals,
    make_arrivals,
)


def parse_mesh(spec: str):
    """``--mesh`` spec -> Mesh: "none" (default), or "DxM" for a
    (data, model) host mesh, e.g. "2x4" under an 8-device host platform."""
    if spec in ("none", ""):
        return None
    d, m = (int(x) for x in spec.split("x"))
    return jax.make_mesh((d, m), ("data", "model"))


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config variant (CPU-scale)")
    ap.add_argument("--rounds", type=int, default=100,
                    help="server iterations (rounds, or applied arrivals "
                         "under --async)")
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--per-worker-batch", type=int, default=2)
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--opt", default="sgd", choices=sorted(OPTIMIZERS))
    ap.add_argument("--algo", default="dude",
                    choices=sorted(set(ROUND_ALGOS) | set(ASYNC_ALGOS)),
                    help="server update rule (core/algos registries): round "
                         "rules drive the masked round step, arrival rules "
                         "need --async; 'dude' runs either way")
    ap.add_argument("--server-backend", default="reference",
                    choices=list(BACKENDS),
                    help="ServerEngine update path for the DuDe round "
                         "(pallas = fused kernel; interpret mode on CPU)")
    ap.add_argument("--commit-format", default="f32",
                    choices=list(COMMIT_FORMATS),
                    help="engine slab storage / commit wire format: f32, "
                         "int8_ef (tiled int8 + error feedback) or topk_ef "
                         "(per-tile magnitude top-k before int8) — "
                         "docs/engine.md 'Compressed slabs'")
    ap.add_argument("--sparse-transport", action="store_true",
                    help="topk_ef only: ship commits as index-carrying "
                         "SparseRows and fold only touched tiles — "
                         "O(k * tiles_touched) ingress instead of O(P) "
                         "(docs/engine.md 'Sparse commit transport')")
    ap.add_argument("--sparse-cap", type=int, default=None,
                    help="static touched-tile slots per SparseRow commit "
                         "(default: all tiles; smaller caps bound wire "
                         "bytes, overflow re-enters via error feedback)")
    ap.add_argument("--mesh", default="none",
                    help='"DxM" (data x model) host mesh, or "none"')
    ap.add_argument("--params-layout", default="replicated",
                    choices=["replicated", "tp"],
                    help="forward param feed: 'replicated' = one [P] "
                         "all-gather per step; 'tp' = TP-native exchange "
                         "from the P-shards (no full [P] on any device; "
                         "needs --mesh)")
    ap.add_argument("--fedbuff-buffer-size", type=int, default=4)
    # ------------------------------------------------- async runtime flags
    ap.add_argument("--async", dest="async_mode", action="store_true",
                    help="event-driven per-arrival training (AsyncRunner): "
                         "one engine.commit + flat optimizer apply per "
                         "gradient arrival (docs/async.md)")
    ap.add_argument("--arrival", default="fixed", choices=list(ARRIVAL_KINDS),
                    help="arrival process: 'fixed' = the paper's fixed-"
                         "speed model (from --speed-std), 'exp' = "
                         "exponential durations (stragglers in the tail), "
                         "'trace' = replay --trace-in")
    ap.add_argument("--arrival-mean", type=float, default=1.0,
                    help="exp arrivals: scale on the per-worker mean "
                         "durations (drawn from the speed model)")
    ap.add_argument("--trace-in", default=None,
                    help="ArrivalTrace JSON to replay (--arrival trace)")
    ap.add_argument("--trace-out", default=None,
                    help="record this run's ArrivalTrace JSON here")
    ap.add_argument("--scenario", default="none",
                    choices=list(SCENARIO_KINDS),
                    help="client-state scenario wrapped around the arrival "
                         "process (--async only): dropout = mid-round "
                         "disconnect + reconnect-from-stale-snapshot, "
                         "partial = partial-gradient completeness, "
                         "sin/lognormal/skew = availability cycles, chaos = "
                         "all of it (docs/async.md 'Client-state "
                         "scenarios'); trace replays carry their own "
                         "recorded client state")
    ap.add_argument("--max-in-flight", type=int, default=None,
                    help="bound on concurrent dispatched-but-unarrived "
                         "gradient jobs (back-pressure on simultaneously "
                         "stale work; default: all workers)")
    # ---------------------------------------------- multi-host server flags
    ap.add_argument("--serve", default=None, metavar="HOST:PORT",
                    help="multi-host server mode (needs --async): listen "
                         "here, accept --expect-links worker processes "
                         "(launch/worker.py), and drive the server "
                         "iteration from their commit frames "
                         "(docs/async.md 'Multi-host transport')")
    ap.add_argument("--expect-links", type=int, default=1,
                    help="worker PROCESSES to wait for before serving "
                         "(each may carry several logical workers)")
    ap.add_argument("--link-timeout", type=float, default=120.0,
                    help="seconds to wait for the initial links")
    ap.add_argument("--heartbeat-s", type=float, default=5.0,
                    help="PING a link silent this long")
    ap.add_argument("--dead-after-s", type=float, default=20.0,
                    help="declare a link dead after this much silence")
    ap.add_argument("--max-wall-s", type=float, default=None,
                    help="hard wall-clock bound on the serving loop")
    ap.add_argument("--replay-check", action="store_true",
                    help="after serving, replay the recorded trace through "
                         "the single-process AsyncRunner and assert the "
                         "final [P] params and per-arrival digests match "
                         "bit-for-bit")
    ap.add_argument("--speed-std", type=float, default=1.0,
                    help="worker speed heterogeneity (paper std)")
    ap.add_argument("--heterogeneity", type=float, default=1.0,
                    help="data distribution skew across workers")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    try:
        config = TrainerConfig(
            arch=args.arch, smoke=args.smoke, algo=args.algo,
            optimizer=args.opt, lr=args.lr,
            server_backend=args.server_backend,
            commit_format=args.commit_format,
            sparse_transport=args.sparse_transport,
            sparse_cap=args.sparse_cap,
            mesh=parse_mesh(args.mesh),
            params_layout=args.params_layout,
            fedbuff_buffer_size=args.fedbuff_buffer_size,
            max_in_flight=args.max_in_flight,
            scenario=args.scenario,
            seed=args.seed,
            checkpoint=CheckpointPolicy(directory=args.ckpt_dir,
                                        every=args.ckpt_every),
            transport=TransportPolicy(heartbeat_s=args.heartbeat_s,
                                      dead_after_s=args.dead_after_s),
        )
    except ConfigError as e:
        ap.error(str(e))
    if args.serve and not args.async_mode:
        ap.error("--serve needs --async (the multi-host loop is arrival-"
                 "granularity)")
    if args.scenario != "none" and not args.async_mode:
        ap.error("--scenario needs --async (client state is per-arrival)")

    if args.resume and args.ckpt_dir:
        trainer = Trainer.restore(args.ckpt_dir, config)
        print("[train] resumed (auto-format restore)")
    else:
        trainer = Trainer.create(config)
    cfg = trainer.cfg
    n = cfg.n_workers
    mode = "async" if args.async_mode else "rounds"
    print(f"[train] arch={cfg.name} algo={args.algo} mode={mode} workers={n} "
          f"devices={jax.device_count()} mesh={args.mesh} "
          f"server-backend={args.server_backend}")
    print(f"[train] params={trainer.param_count():,}")

    speeds = truncated_normal_speeds(n, std=args.speed_std, seed=args.seed + 1)
    # the one batch pipeline every mode shares — identical bytes for a given
    # (worker, rng) in the server, a remote worker process, and a replay
    sample_fn = make_worker_sample_fn(
        cfg, seq_len=args.seq_len, per_worker_batch=args.per_worker_batch,
        heterogeneity=args.heterogeneity, seed=args.seed)

    t0 = time.time()

    if args.serve:
        # ----------------------- multi-host serving (real worker links) ----
        from repro.runtime.hostloop import accept_links, poll_accept_fn
        from repro.runtime.transport import serve_listener
        host, port = args.serve.rsplit(":", 1)
        listener = serve_listener(host, int(port))
        print(f"[serve] listening on {args.serve}, waiting for "
              f"{args.expect_links} link(s)")
        links = accept_links(listener, args.expect_links,
                             timeout=args.link_timeout)
        res = trainer.serve_async(links, args.rounds,
                                  record_every=args.log_every,
                                  seed=args.seed,
                                  accept_fn=poll_accept_fn(listener),
                                  max_wall_s=args.max_wall_s)
        listener.close()
        for t, it, loss in zip(res.times, res.iters, res.losses):
            print(f"[arrival it={it:5d}] loss={loss:.4f}")
        if args.trace_out:
            res.trace.save(args.trace_out)
            print(f"[serve] wrote arrival trace -> {args.trace_out}")
        if args.ckpt_dir:
            print(f"[serve] checkpoint -> {trainer.save()}")
        replay_ok = None
        if args.replay_check:
            from repro.runtime import TraceArrivals
            fresh = Trainer.create(config)
            rep = fresh.run_async(
                TraceArrivals(res.trace), args.rounds, sample_fn,
                record_every=args.log_every, seed=args.seed,
                key_mode="worker", record_digests=True)
            params_ok = bool(np.array_equal(
                np.asarray(rep.state.params), np.asarray(res.state.params)))
            digest_ok = rep.digests == res.trace.digest
            replay_ok = params_ok and digest_ok
            print(f"[serve] replay-check: params_bitwise={params_ok} "
                  f"digests={digest_ok}")
        print(json.dumps({
            "arch": cfg.name, "algo": args.algo, "mode": "serve",
            "iters": int(res.stats.iters),
            "arrivals": int(res.stats.arrivals),
            "tau_max": int(res.tau_max),
            "dropouts": int(res.dropouts),
            "reconnects": int(res.reconnects),
            "dropped_workers": list(res.dropped_workers),
            "wire_sent": int(res.wire_sent), "wire_recv": int(res.wire_recv),
            "last_loss": float(res.losses[-1]) if len(res.losses) else None,
            "replay_ok": replay_ok,
            "wall_s": round(time.time() - t0, 1),
        }))
        if args.replay_check and not replay_ok:
            raise SystemExit("[serve] replay-check FAILED")
        return

    if args.async_mode:
        # --------------------------- event-driven per-arrival training ----
        if args.arrival == "fixed":
            process = FixedArrivals.from_speeds(speeds)
        elif args.arrival == "exp":
            process = ExponentialArrivals(
                n, mean=np.asarray(speeds.times) * args.arrival_mean,
                seed=args.seed + 2)
        else:
            if args.trace_in is None:
                ap.error("--arrival trace needs --trace-in")
            process = make_arrivals("trace", n, trace=args.trace_in)

        res = trainer.run_async(process, args.rounds, sample_fn,
                                record_every=args.log_every)
        for t, it, loss in zip(res.times, res.iters, res.losses):
            print(f"[arrival it={it:5d}] loss={loss:.4f} t_sim={t:.2f}")
        if args.trace_out:
            res.trace.save(args.trace_out)
            print(f"[train] wrote arrival trace -> {args.trace_out}")
        if args.ckpt_dir:
            # the runner owns the arrival loop, so the round-cadence
            # maybe_save() never fires mid-run; always persist the final
            # state when a checkpoint directory is configured
            print(f"[train] checkpoint -> {trainer.save()}")
        print(json.dumps({
            "arch": cfg.name, "algo": args.algo, "mode": "async",
            "arrival": args.arrival, "scenario": args.scenario,
            "iters": int(res.stats.iters),
            "arrivals": int(res.stats.arrivals),
            "tau_max": int(res.tau_max),
            "max_in_flight": int(res.stats.max_in_flight),
            "first_loss": float(res.losses[0]) if len(res.losses) else None,
            "last_loss": float(res.losses[-1]) if len(res.losses) else None,
            "wall_s": round(time.time() - t0, 1),
            **({"scenario_stats": res.trace.event_stats()}
               if res.trace is not None and res.trace.events else {}),
        }))
        return

    # ------------------------------------------- masked round training ----
    sch = make_round_schedule(speeds, args.rounds)
    print(f"[train] schedule: {delay_stats(sch)}")
    rng = np.random.default_rng(args.seed)

    def round_batch():
        per = [sample_fn(i, rng) for i in range(n)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *per)

    history = []
    for r in range(sch.rounds):
        metrics = trainer.step(round_batch(),
                               sch.start[r], sch.commit[r])
        loss = float(metrics["loss"])
        history.append(loss)
        if r % args.log_every == 0:
            print(f"[round {r:4d}] loss={loss:.4f} "
                  f"({(time.time() - t0) / (r + 1):.2f}s/round)")
        trainer.maybe_save()

    print(json.dumps({
        "arch": cfg.name, "algo": args.algo, "mode": "rounds",
        "rounds": sch.rounds,
        "first_loss": history[0], "last_loss": history[-1],
        "wall_s": round(time.time() - t0, 1),
    }))


if __name__ == "__main__":
    main()

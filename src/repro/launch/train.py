"""Production training driver (DESIGN.md mode B): round-based semi-async
training on whatever mesh is available, through the one ``api.Trainer``
session — every server algorithm in the registry (DuDe-ASGD and the
round-based Table-1 baselines) runs the same mesh-native flat train step.

On the real cluster this runs under the 16x16 / 2x16x16 production meshes
(see dryrun.py for the lowering proof); on this CPU container it runs the
same code path on a 1-device mesh at reduced scale (or a host-platform
multi-device mesh via --mesh and XLA_FLAGS=--xla_force_host_platform_device_count=N).

Example:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2_0_5b --smoke \
      --rounds 50 --seq-len 64 --per-worker-batch 2 --algo dude
  # a Table-1 baseline through the same engine path:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2_0_5b --smoke \
      --rounds 50 --algo fedbuff
"""

from __future__ import annotations

import argparse
import json
import sys
import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import CheckpointPolicy, ConfigError, Trainer, TrainerConfig
from repro.api.config import OPTIMIZERS
from repro.core import (
    BACKENDS, ROUND_ALGOS, delay_stats, make_round_schedule,
    truncated_normal_speeds,
)
from repro.data import make_token_sampler
from repro.models.stubs import make_prefix_embeddings


class _DeprecatedNoOp(argparse.Action):
    """A retired flag that still parses (one release) but only warns."""

    def __init__(self, option_strings, dest, **kw):
        super().__init__(option_strings, dest, nargs=0, **kw)

    def __call__(self, parser, namespace, values, option_string=None):
        msg = (f"{option_string} is deprecated and a no-op: the flat "
               "segment-range layout is the only train state now")
        warnings.warn(msg, DeprecationWarning)
        print(f"[train] WARNING: {msg}", file=sys.stderr)


def parse_mesh(spec: str):
    """``--mesh`` spec -> Mesh: "none" (default), or "DxM" for a
    (data, model) host mesh, e.g. "2x4" under an 8-device host platform."""
    if spec in ("none", ""):
        return None
    d, m = (int(x) for x in spec.split("x"))
    return jax.make_mesh((d, m), ("data", "model"))


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config variant (CPU-scale)")
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--per-worker-batch", type=int, default=2)
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--opt", default="sgd", choices=sorted(OPTIMIZERS))
    ap.add_argument("--algo", default="dude", choices=list(ROUND_ALGOS),
                    help="server update rule (core/algos registry): the "
                         "DuDe family or a round-based Table-1 baseline — "
                         "all run the same mesh-native flat train step")
    ap.add_argument("--server-backend", default="reference",
                    choices=list(BACKENDS),
                    help="ServerEngine update path for the DuDe round "
                         "(pallas = fused kernel; interpret mode on CPU)")
    ap.add_argument("--mesh", default="none",
                    help='"DxM" (data x model) host mesh, or "none"')
    ap.add_argument("--fedbuff-buffer-size", type=int, default=4)
    ap.add_argument("--flat-optimizer", action=_DeprecatedNoOp,
                    help="deprecated no-op: the flat segment-range layout "
                         "is now the only train state")
    ap.add_argument("--speed-std", type=float, default=1.0,
                    help="worker speed heterogeneity (paper std)")
    ap.add_argument("--heterogeneity", type=float, default=1.0,
                    help="data distribution skew across workers")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    try:
        config = TrainerConfig(
            arch=args.arch, smoke=args.smoke, algo=args.algo,
            optimizer=args.opt, lr=args.lr,
            server_backend=args.server_backend,
            mesh=parse_mesh(args.mesh),
            fedbuff_buffer_size=args.fedbuff_buffer_size,
            seed=args.seed,
            checkpoint=CheckpointPolicy(directory=args.ckpt_dir,
                                        every=args.ckpt_every),
        )
    except ConfigError as e:
        ap.error(str(e))

    if args.resume and args.ckpt_dir:
        trainer = Trainer.restore(args.ckpt_dir, config)
        print("[train] resumed (auto-format restore)")
    else:
        trainer = Trainer.create(config)
    cfg = trainer.cfg
    n = cfg.n_workers
    print(f"[train] arch={cfg.name} algo={args.algo} workers={n} "
          f"devices={jax.device_count()} mesh={args.mesh} "
          f"server-backend={args.server_backend}")
    print(f"[train] params={trainer.param_count():,}")

    speeds = truncated_normal_speeds(n, std=args.speed_std, seed=args.seed + 1)
    sch = make_round_schedule(speeds, args.rounds)
    print(f"[train] schedule: {delay_stats(sch)}")

    sampler = make_token_sampler(
        n, cfg.vocab_size, args.seq_len, args.per_worker_batch,
        heterogeneity=args.heterogeneity, seed=args.seed,
    )
    rng = np.random.default_rng(args.seed)
    key = jax.random.PRNGKey(args.seed)

    def round_batch():
        per = [sampler(i, rng) for i in range(n)]
        toks = np.stack([p["tokens"] for p in per])
        labs = np.stack([p["labels"] for p in per])
        if cfg.num_codebooks > 1:
            toks = np.repeat(toks[..., None], cfg.num_codebooks, -1)
            labs = np.repeat(labs[..., None], cfg.num_codebooks, -1)
        if cfg.num_prefix_tokens:
            pad = -np.ones((n, args.per_worker_batch, cfg.num_prefix_tokens)
                           + labs.shape[3:], labs.dtype)
            labs = np.concatenate([pad, labs], axis=2)
        batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labs)}
        if cfg.frontend:
            pe = make_prefix_embeddings(key, cfg, args.per_worker_batch)
            batch["prefix_emb"] = jnp.broadcast_to(pe[None], (n,) + pe.shape)
        return batch

    t0 = time.time()
    history = []
    for r in range(sch.rounds):
        metrics = trainer.step(round_batch(),
                               sch.start[r], sch.commit[r])
        loss = float(metrics["loss"])
        history.append(loss)
        if r % args.log_every == 0:
            print(f"[round {r:4d}] loss={loss:.4f} "
                  f"({(time.time() - t0) / (r + 1):.2f}s/round)")
        trainer.maybe_save()

    print(json.dumps({
        "arch": cfg.name, "algo": args.algo, "rounds": sch.rounds,
        "first_loss": history[0], "last_loss": history[-1],
        "wall_s": round(time.time() - t0, 1),
    }))


if __name__ == "__main__":
    main()

"""Production training driver (DESIGN.md mode B): round-based semi-async
DuDe-ASGD on whatever mesh is available.

On the real cluster this runs under the 16x16 / 2x16x16 production meshes
(see dryrun.py for the lowering proof); on this CPU container it runs the
same code path on a 1-device mesh at reduced scale.

Example:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2_0_5b --smoke \
      --rounds 50 --seq-len 64 --per-worker-batch 2 --algo dude
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (
    checkpoint_format, restore_checkpoint, restore_flat_from_pytree,
    restore_params_from_flat, save_checkpoint,
)
from repro.configs import get_config
from repro.core import (
    DuDeConfig, delay_stats, make_round_schedule, truncated_normal_speeds,
)
from repro.data import make_token_sampler
from repro.launch.steps import (
    TrainOptions, init_flat_train_state, make_engine, make_train_step,
)
from repro.models import lm_init, param_count
from repro.models.stubs import make_prefix_embeddings
from repro.optim import adamw, momentum_sgd, sgd


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config variant (CPU-scale)")
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--per-worker-batch", type=int, default=2)
    ap.add_argument("--lr", type=float, default=0.01)
    ap.add_argument("--opt", default="sgd", choices=["sgd", "momentum", "adamw"])
    ap.add_argument("--algo", default="dude", choices=["dude", "dude_accum"])
    ap.add_argument("--server-backend", default="reference",
                    choices=["reference", "indexed", "pallas"],
                    help="ServerEngine update path for the DuDe round "
                         "(pallas = fused kernel; interpret mode on CPU)")
    ap.add_argument("--flat-optimizer", action="store_true",
                    help="flat-state training: master params + optimizer "
                         "slots as [P] slabs in the engine layout, round "
                         "and apply fused into one zero-collective pass "
                         "(engine.round_apply); params are unraveled once "
                         "per step for the forward")
    ap.add_argument("--speed-std", type=float, default=1.0,
                    help="worker speed heterogeneity (paper std)")
    ap.add_argument("--heterogeneity", type=float, default=1.0,
                    help="data distribution skew across workers")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    if args.algo == "dude_accum" and args.server_backend != "reference":
        ap.error("--algo dude_accum requires --server-backend reference "
                 "(accumulate mode is reference-only)")

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    n = cfg.n_workers
    key = jax.random.PRNGKey(args.seed)

    print(f"[train] arch={cfg.name} workers={n} devices={jax.device_count()} "
          f"server-backend={args.server_backend}")
    params = lm_init(key, cfg)
    print(f"[train] params={param_count(params):,}")

    opt = {"sgd": sgd, "momentum": momentum_sgd, "adamw": adamw}[args.opt](args.lr)
    dude_cfg = DuDeConfig(n, cfg.dude_buffer_dtype if not args.smoke else jnp.float32,
                          accumulate=args.algo == "dude_accum")
    options = TrainOptions(backend=args.server_backend,
                           flat_optimizer=args.flat_optimizer)
    # flat ServerEngine state: [P] g_bar + [n, P] slabs (P-axis sharded when
    # a mesh is given — single-device here, so unsharded)
    engine = make_engine(cfg, None, dude_cfg, options)
    flat_state = opt_state = dude_state = None
    if args.flat_optimizer:
        # whole train state in the flat segment-range layout
        flat_state = init_flat_train_state(engine, opt, params)
    else:
        opt_state = opt.init(params)
        dude_state = engine.init()
    if args.resume and args.ckpt_dir:
        fmt = checkpoint_format(args.ckpt_dir)
        if args.flat_optimizer:
            flat_state = (
                restore_checkpoint(args.ckpt_dir, None, flat_state,
                                   flat_spec=engine.spec)
                if fmt == "flat" else
                restore_flat_from_pytree(args.ckpt_dir, None, flat_state,
                                         engine.spec))
        else:
            params = (restore_params_from_flat(args.ckpt_dir, None, params)
                      if fmt == "flat" else
                      restore_checkpoint(args.ckpt_dir, None, params))
        print(f"[train] resumed from {fmt} checkpoint")

    step = jax.jit(make_train_step(cfg, None, opt, dude_cfg,
                                   options=options, engine=engine))

    speeds = truncated_normal_speeds(n, std=args.speed_std, seed=args.seed + 1)
    sch = make_round_schedule(speeds, args.rounds)
    print(f"[train] schedule: {delay_stats(sch)}")

    sampler = make_token_sampler(
        n, cfg.vocab_size, args.seq_len, args.per_worker_batch,
        heterogeneity=args.heterogeneity, seed=args.seed,
    )
    rng = np.random.default_rng(args.seed)
    S_total = args.seq_len + cfg.num_prefix_tokens

    def round_batch():
        per = [sampler(i, rng) for i in range(n)]
        toks = np.stack([p["tokens"] for p in per])
        labs = np.stack([p["labels"] for p in per])
        if cfg.num_codebooks > 1:
            toks = np.repeat(toks[..., None], cfg.num_codebooks, -1)
            labs = np.repeat(labs[..., None], cfg.num_codebooks, -1)
        if cfg.num_prefix_tokens:
            pad = -np.ones((n, args.per_worker_batch, cfg.num_prefix_tokens)
                           + labs.shape[3:], labs.dtype)
            labs = np.concatenate([pad, labs], axis=2)
        batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labs)}
        if cfg.frontend:
            pe = make_prefix_embeddings(key, cfg, args.per_worker_batch)
            batch["prefix_emb"] = jnp.broadcast_to(pe[None], (n,) + pe.shape)
        return batch

    t0 = time.time()
    history = []
    for r in range(sch.rounds):
        sm = jnp.asarray(sch.start[r])
        cm = jnp.asarray(sch.commit[r])
        if args.flat_optimizer:
            flat_state, metrics = step(flat_state, round_batch(), sm, cm)
        else:
            params, opt_state, dude_state, metrics = step(
                params, opt_state, dude_state, round_batch(), sm, cm)
        loss = float(metrics["loss"])
        history.append(loss)
        if r % args.log_every == 0:
            print(f"[round {r:4d}] loss={loss:.4f} "
                  f"({(time.time() - t0) / (r + 1):.2f}s/round)")
        if args.ckpt_dir and args.ckpt_every and (r + 1) % args.ckpt_every == 0:
            if args.flat_optimizer:
                save_checkpoint(args.ckpt_dir, r + 1, flat_state,
                                flat_spec=engine.spec)
            else:
                save_checkpoint(args.ckpt_dir, r + 1, params)

    print(json.dumps({
        "arch": cfg.name, "rounds": sch.rounds,
        "first_loss": history[0], "last_loss": history[-1],
        "wall_s": round(time.time() - t0, 1),
    }))


if __name__ == "__main__":
    main()

"""The per-worker batch pipeline, shared by every launch entrypoint.

``make_worker_sample_fn`` builds the ``sample_fn(worker, rng) -> batch``
callable the async runtime consumes — token sampling from the
heterogeneous per-worker distributions plus the model-specific batch
shaping (codebook fan-out, prefix-label padding, frontend prefix
embeddings) that used to live inline in ``launch/train.py``.

It lives in its own module because multi-host runs need the IDENTICAL
pipeline in three places: the recording server, the remote worker process
(``launch/worker.py``), and the single-process replay — a batch drawn for
``(worker, job)`` must be bit-identical in all three or the trace-replay
oracle fails.  Everything here is driven only by ``(worker, rng)``: no
global state, no arrival-order dependence.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..data import make_token_sampler
from ..models.stubs import make_prefix_embeddings

__all__ = ["make_worker_sample_fn"]


def make_worker_sample_fn(cfg, *, seq_len: int, per_worker_batch: int,
                          heterogeneity: float = 1.0, seed: int = 0):
    """``sample_fn(worker, rng) -> batch`` for model config ``cfg``.

    ``rng`` supplies ALL randomness (the async runtime hands each call the
    stream matching its key_mode); ``seed`` only fixes the per-worker token
    distributions and the frontend prefix embeddings, which are
    deterministic per session.
    """
    sampler = make_token_sampler(
        cfg.n_workers, cfg.vocab_size, seq_len, per_worker_batch,
        heterogeneity=heterogeneity, seed=seed,
    )
    key = jax.random.PRNGKey(seed)

    def sample_fn(i, rng):
        per = sampler(i, rng)
        toks, labs = np.asarray(per["tokens"]), np.asarray(per["labels"])
        if cfg.num_codebooks > 1:
            toks = np.repeat(toks[..., None], cfg.num_codebooks, -1)
            labs = np.repeat(labs[..., None], cfg.num_codebooks, -1)
        if cfg.num_prefix_tokens:
            pad = -np.ones((per_worker_batch, cfg.num_prefix_tokens)
                           + labs.shape[2:], labs.dtype)
            labs = np.concatenate([pad, labs], axis=1)
        batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labs)}
        if cfg.frontend:
            batch["prefix_emb"] = make_prefix_embeddings(
                key, cfg, per_worker_batch)
        return batch

    return sample_fn

"""Post-optimization HLO analysis: collective-traffic accounting.

``compiled.cost_analysis()`` does not report collective bytes, and it counts
while-loop (lax.scan) bodies ONCE — so both collectives and scan-body traffic
must be scaled by trip counts.  This module parses ``compiled.as_text()``:

  1. split the module into computations,
  2. find collective instructions (+ shapes -> bytes),
  3. build the call graph (while bodies/conditions, fusions, calls),
  4. estimate while trip counts from the loop-condition's integer constant,
  5. DFS from ENTRY multiplying by enclosing trip counts.

Byte convention per op (documented in EXPERIMENTS §Roofline): bytes = max of
input/output tuple sizes — the payload that crosses links once under an
optimal ring schedule; all-reduce counted 2x (reduce-scatter + all-gather
phases).
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Any

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    """Total bytes of all array shapes appearing in ``text``."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo: str) -> dict[str, list[str]]:
    """Computation headers are unindented lines ending in '{' (instructions
    are indented); robust to arbitrarily nested tuple parameter lists."""
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        if not line:
            continue
        if line[0] not in " }" and line.rstrip().endswith("{"):
            m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-_]+)", line.strip())
            if m and m.group(1) != "HloModule":
                cur = m.group(1)
                comps[cur] = []
                continue
        stripped = line.strip()
        if stripped == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(stripped)
    return comps


def _entry_name(hlo: str) -> str:
    m = re.search(r"^ENTRY\s+%?([\w\.\-_]+)", hlo, re.M)
    if m:
        return m.group(1)
    raise ValueError("no ENTRY computation found")


def analyze_collectives(hlo: str, default_trip: int = 1) -> dict:
    """Returns {"per_op": {op: bytes}, "total_bytes": int, "counts": {...}}."""
    comps = _split_computations(hlo)
    entry = _entry_name(hlo)

    # direct collective bytes + call edges per computation
    direct: dict[str, dict[str, float]] = defaultdict(lambda: defaultdict(float))
    counts: dict[str, int] = defaultdict(int)
    edges: dict[str, list[tuple[str, float]]] = defaultdict(list)
    trip_cache: dict[str, float] = {}

    def trip_count(cond_name: str) -> float:
        if cond_name in trip_cache:
            return trip_cache[cond_name]
        best = default_trip
        for line in comps.get(cond_name, ()):
            for c in re.findall(r"constant\((\d+)\)", line):
                best = max(best, int(c))
        trip_cache[cond_name] = float(best)
        return float(best)

    for name, lines in comps.items():
        for line in lines:
            mo = re.search(r"=\s*(\([^)]*\)|[\w\[\],{}\.]+)\s+([\w\-]+)\(", line)
            if not mo:
                continue
            out_shape, op = mo.groups()
            base = op.replace("-start", "").replace("-done", "")
            if base in _COLLECTIVES:
                if op.endswith("-done"):
                    continue  # counted at -start
                out_b = _shape_bytes(out_shape)
                # operand shapes appear in the args for typed HLO; use max
                arg_b = _shape_bytes(line[mo.end():])
                payload = max(out_b, arg_b)
                if base == "all-reduce":
                    payload *= 2  # reduce-scatter + all-gather phases
                direct[name][base] += payload
                counts[base] += 1
            # call edges
            if base == "while":
                body = re.search(r"body=%?([\w\.\-_]+)", line)
                cond = re.search(r"condition=%?([\w\.\-_]+)", line)
                if body:
                    t = trip_count(cond.group(1)) if cond else default_trip
                    edges[name].append((body.group(1), t))
                if cond:
                    edges[name].append((cond.group(1), 1.0))
            else:
                for attr in ("calls", "to_apply", "branch_computations"):
                    for callee in re.findall(attr + r"=\{?%?([\w\.\-_,% ]+)\}?", line):
                        for c in callee.replace("%", "").split(","):
                            c = c.strip()
                            if c in comps:
                                edges[name].append((c, 1.0))

    per_op: dict[str, float] = defaultdict(float)
    visited: set[str] = set()

    def dfs(name: str, mult: float, depth: int = 0):
        if depth > 50:
            return
        visited.add(name)
        for op, b in direct.get(name, {}).items():
            per_op[op] += b * mult
        for callee, t in edges.get(name, ()):  # multiply through loops
            dfs(callee, mult * t, depth + 1)

    dfs(entry, 1.0)
    # computations with collectives not reached from ENTRY (edge-parsing gap):
    # count once rather than dropping silently.
    for name, ops in direct.items():
        if name not in visited:
            for op, b in ops.items():
                per_op[op] += b
    total = sum(per_op.values())
    return {
        "per_op": dict(per_op),
        "total_bytes": float(total),
        "counts": dict(counts),
    }


def full_p_tensors(hlo: str, p: int, exclude_dims: tuple = ()) -> list:
    """Shape literals in ``hlo`` with at least ``p`` elements — the
    replicated full-``[P]`` buffers the TP-native unravel must NOT produce.

    Post-SPMD-partitioning per-device HLO only shows per-device shapes, so
    any tensor of >= ``p`` elements means some op materialized the whole
    flat vector (or an equally large intermediate) on one device.  Returns
    the offending shape strings (deduplicated, sorted).  ``exclude_dims``
    skips shapes whose leading dim matches (e.g. a [n, B, S, V] logits
    buffer that legitimately exceeds P at tiny smoke scale)."""
    bad = set()
    for dt, dims in _SHAPE_RE.findall(hlo):
        if dt not in _DTYPE_BYTES or _DTYPE_BYTES[dt] == 0:
            continue
        sizes = [int(d) for d in dims.split(",") if d]
        n = 1
        for d in sizes:
            n *= d
        if n >= p and not (sizes and sizes[0] in exclude_dims):
            bad.add(f"{dt}[{dims}]")
    return sorted(bad)


# ops that legitimately carry a >= P-element buffer without COMPUTING a
# dense [P] value: plumbing (parameter/tuple/gte/copy/bitcast), state
# threading (while/conditional/call), and in-place writes into state slabs
# (scatter / dynamic-update-slice).
_P_CARRY_OPS = (
    "parameter", "tuple", "get-tuple-element", "copy", "copy-start",
    "copy-done", "bitcast", "while", "conditional", "call",
    "scatter", "dynamic-update-slice",
)

_INSTR_RE = re.compile(r"=\s*(\([^)]*\)|[\w\[\],{}\.]+)\s+([\w\-]+)\(")


def _max_array_elems(shape_text: str) -> int:
    """Largest single-array element count in a (possibly tuple) shape."""
    best = 0
    for dt, dims in _SHAPE_RE.findall(shape_text):
        if dt not in _DTYPE_BYTES or _DTYPE_BYTES[dt] == 0:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        best = max(best, n)
    return best


def dense_p_compute_ops(hlo: str, p: int,
                        allow: tuple = _P_CARRY_OPS) -> list:
    """Instructions that COMPUTE a dense >= ``p``-element array — the test
    for "no dense [P] intermediates" on sparse-transport programs, which
    (unlike ``full_p_tensors``) must keep carrying the [P]/[n, P] STATE
    slabs through parameters, tuples and scatters.

    An instruction offends when its output holds >= ``p`` elements and its
    op is not in ``allow`` (plumbing / state threading / in-place scatter
    writes).  Fusions are classified by their fused computation's ROOT op —
    a scatter-rooted fusion is a slab write, a loop fusion producing [P] is
    a dense compute.  Returns ``"op(root):shape"`` strings, deduplicated and
    sorted; empty means every >= p-element buffer is carried, never
    computed."""
    comps = _split_computations(hlo)
    roots: dict[str, str] = {}
    for name, lines in comps.items():
        for line in lines:
            if line.startswith("ROOT"):
                mo = _INSTR_RE.search(line)
                if mo:
                    roots[name] = mo.group(2)
    offenders = set()
    for name, lines in comps.items():
        for line in lines:
            mo = _INSTR_RE.search(line)
            if not mo:
                continue
            out_shape, op = mo.groups()
            if _max_array_elems(out_shape) < p:
                continue
            if op == "fusion":
                called = re.search(r"calls=%?([\w\.\-_]+)", line)
                root = roots.get(called.group(1), "") if called else ""
                if root in allow:
                    continue
                offenders.add(f"fusion({root}):{out_shape.strip()}")
            elif op not in allow:
                offenders.add(f"{op}:{out_shape.strip()}")
    return sorted(offenders)


def cost_analysis_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` normalized to one flat dict (newer jax
    returns a list with one dict per device)."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def memory_stats(compiled) -> dict:
    ma = compiled.memory_analysis()
    return {
        "argument_bytes": getattr(ma, "argument_size_in_bytes", 0),
        "output_bytes": getattr(ma, "output_size_in_bytes", 0),
        "temp_bytes": getattr(ma, "temp_size_in_bytes", 0),
        "alias_bytes": getattr(ma, "alias_size_in_bytes", 0),
        "generated_code_bytes": getattr(ma, "generated_code_size_in_bytes", 0),
    }

"""Multi-pod dry-run: prove every (architecture x input-shape x mesh) lowers,
compiles, and fits — and extract the roofline terms (deliverables e + g).

MUST set the device-count override before ANY other import (jax locks the
device count on first init).  Do not set this globally: smoke tests and
benches see 1 device.
"""

import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
).strip()

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.api import (  # noqa: E402
    ServeConfig, ServeSession, Trainer, TrainerConfig,
)
from repro.configs import ARCH_IDS, get_config           # noqa: E402
from repro.launch.costs import model_flops_6nd, param_counts, roofline  # noqa: E402
from repro.launch.hlo_analysis import (  # noqa: E402
    analyze_collectives, cost_analysis_dict, full_p_tensors, memory_stats,
)
from repro.launch.mesh import HW, make_production_mesh, mesh_num_devices  # noqa: E402
from repro.launch.steps import (                          # noqa: E402
    INPUT_SHAPES,
    shape_supported,
)


def _host_mesh(spec: str):
    """``"DxM"`` -> a (data, model) mesh over the FIRST D*M host devices —
    the CI-scale twin of the production mesh (the 512-device override is
    already in force, so any small shape fits)."""
    import numpy as np
    d, m = (int(x) for x in spec.split("x"))
    devs = np.asarray(jax.devices()[: d * m]).reshape(d, m)
    return jax.sharding.Mesh(devs, ("data", "model"))


def run_one(arch: str, shape_name: str, multi_pod: bool, *,
            parse_hlo: bool = True, optimized: bool = False,
            params_layout: str = "replicated",
            host_mesh: str | None = None) -> dict:
    cfg = get_config(arch)
    ok, why = shape_supported(cfg, shape_name)
    rec: dict = {
        "arch": cfg.name, "shape": shape_name,
        "mesh": host_mesh or ("2x16x16" if multi_pod else "16x16"),
        "params": param_counts(cfg),
        "params_layout": params_layout,
    }
    if not ok:
        rec.update({"status": "skipped", "reason": why})
        return rec

    mesh = (_host_mesh(host_mesh) if host_mesh
            else make_production_mesh(multi_pod=multi_pod))
    chips = mesh_num_devices(mesh)
    kind = INPUT_SHAPES[shape_name]["kind"]
    engine_P = None
    t0 = time.time()
    try:
        with mesh:
            if kind == "train":
                # the ONE session API: an abstract (shapes-only) Trainer
                # lowers the canonical flat train step with its shardings
                session = Trainer.abstract(TrainerConfig(
                    arch=cfg, mesh=mesh,
                    grad_dtype=jnp.bfloat16 if optimized else None,
                    constrain_grads=optimized,
                    params_layout=params_layout,
                ))
                engine_P = session.engine.P
                lowered = session.lower(shape_name)
            else:  # prefill / decode
                spec = INPUT_SHAPES[shape_name]
                session = ServeSession.abstract(ServeConfig(
                    arch=cfg, batch=spec["global_batch"],
                    max_len=spec["seq_len"], mesh=mesh,
                    use_window=(shape_name == "long_500k"
                                and cfg.sliding_window is not None),
                ))
                (args, shardings) = session.input_specs(shape_name)
                step = (session.prefill_fn if kind == "prefill"
                        else session.decode_fn)
                jitted = jax.jit(step, in_shardings=shardings,
                                 out_shardings=(None, shardings[2]),
                                 donate_argnums=(2,))
                lowered = jitted.lower(*args)

            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        rec["status"] = "ok"
        rec["t_lower_s"] = round(t_lower, 1)
        rec["t_compile_s"] = round(t_compile, 1)
        rec["memory"] = memory_stats(compiled)
        ca = cost_analysis_dict(compiled)
        rec["xla_cost"] = {
            "flops": float(ca.get("flops", -1)),
            "bytes": float(ca.get("bytes accessed", -1)),
        }
        if parse_hlo:
            hlo = compiled.as_text()
            rec["hlo_chars"] = len(hlo)
            coll = analyze_collectives(hlo)
            if params_layout == "tp" and engine_P is not None:
                # the TP-native contract: no op may materialize a
                # replicated [P]-sized buffer on any device
                bad = full_p_tensors(hlo, engine_P)
                rec["full_p_tensors"] = bad
                if bad:
                    rec["status"] = "FAILED"
                    rec["error"] = (
                        f"params_layout='tp' lowered {len(bad)} tensor "
                        f"shape(s) >= P={engine_P} elements: {bad[:5]}")
            del hlo
        else:
            coll = {"total_bytes": 0.0, "per_op": {}, "counts": {}}
        rec["collectives"] = coll
        rl = roofline(cfg, shape_name, chips, coll["total_bytes"], HW)
        rec["roofline"] = {
            "t_compute_s": rl.t_compute, "t_memory_s": rl.t_memory,
            "t_collective_s": rl.t_collective, "bottleneck": rl.bottleneck,
            "analytic_flops": rl.flops, "analytic_hbm_bytes": rl.hbm,
            "collective_bytes": rl.collective,
            "model_flops_6nd": rl.model_flops, "useful_ratio": rl.useful_ratio,
        }
    except Exception as e:  # a failure here is a bug in the system
        rec["status"] = "FAILED"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    finally:
        jax.clear_caches()
    return rec


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all",
                    choices=["all"] + list(INPUT_SHAPES))
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-hlo", action="store_true",
                    help="skip collective parsing (faster)")
    ap.add_argument("--optimized", action="store_true",
                    help="beyond-paper train options (bf16 grads, "
                         "reduce-scatter constraint) — §Perf variants")
    ap.add_argument("--params-layout", default="replicated",
                    choices=["replicated", "tp"],
                    help="'tp' feeds the forward from the P-shards via the "
                         "TP-native exchange and FAILS the run if the "
                         "lowered HLO contains any full-[P] tensor")
    ap.add_argument("--host-mesh", default=None, metavar="DxM",
                    help="lower on a small (data, model) host mesh (e.g. "
                         "2x4) instead of the production mesh — the CI "
                         "large-config smoke")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    if args.host_mesh:
        meshes = [False]  # the host mesh replaces the production meshes

    os.makedirs(args.out, exist_ok=True)
    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_tag = (f"host{args.host_mesh}" if args.host_mesh
                            else ("multi" if mp else "single"))
                tag = f"{arch}_{shape}_{mesh_tag}"
                if args.params_layout != "replicated":
                    tag += f"_{args.params_layout}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path) and not args.force:
                    print(f"[skip existing] {tag}")
                    continue
                print(f"[dryrun] {tag} ...", flush=True)
                rec = run_one(arch, shape, mp, parse_hlo=not args.no_hlo,
                              optimized=args.optimized,
                              params_layout=args.params_layout,
                              host_mesh=args.host_mesh)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    extra = (
                        f" compile={rec['t_compile_s']}s "
                        f"bottleneck={rec['roofline']['bottleneck']}"
                    )
                elif status == "FAILED":
                    n_fail += 1
                    extra = " " + rec["error"][:200]
                print(f"[{status}] {tag}{extra}", flush=True)
    print(f"done; failures={n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()

"""Analytic operator-level cost model: FLOPs and HBM bytes per (arch, shape).

Why analytic: XLA's ``cost_analysis`` counts ``lax.scan`` bodies once (verified
in tests/test_roofline_model.py), and our stacks scan over layer groups,
attention chunks, and recurrences.  The formulas below follow exact tensor
shapes (the same arithmetic XLA executes); the test suite validates them
against compiled cost_analysis on scan-free reduced configs.

Conventions:
  * FLOPs: 2*M*N*K per matmul; causal attention at 0.5 occupancy.
  * train FLOPs = fwd * (3 + 1 if remat)  (bwd = 2x fwd; remat refwds).
  * HBM bytes are GLOBAL (sum over devices); the roofline divides by chips.
  * DuDe traffic: the paper-faithful masked sweep reads+writes ALL n_workers
    buffers every round — the memory-term tax the §Perf pass attacks.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from .steps import INPUT_SHAPES

F32, BF16 = 4, 2


def _layer_kinds(cfg: ModelConfig) -> list[str]:
    return list(cfg.prefix_layers) + list(cfg.block_pattern) * cfg.n_groups


def _attn_flops(cfg, T, B, S, *, decode_cache: int | None = None) -> float:
    d, H, K, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    proj = 2 * T * (d * H * hd + 2 * d * K * hd + H * hd * d)
    if decode_cache is not None:
        attn = 2 * 2 * B * H * decode_cache * hd  # qk + av against the cache
    else:
        attn = 2 * 2 * B * H * S * S * hd * 0.5  # causal
    return proj + attn


def _mlp_flops(T, d, f, gated: bool = True) -> float:
    return (6 if gated else 4) * T * d * f  # up (+ gate) + down


def _moe_flops(cfg, T) -> float:
    d, E, k, f = cfg.d_model, cfg.num_experts, cfg.experts_per_tok, cfg.moe_d_ff
    router = 2 * T * d * E
    routed_tokens = cfg.capacity_factor * T * k
    expert = 6 * routed_tokens * d * f
    shared = 6 * T * d * f * cfg.num_shared_experts
    return router + expert + shared


def _mamba_flops(cfg, T, B, S) -> float:
    from ..models.transformer import mamba_cfg
    m = mamba_cfg(cfg)
    di, N, H, P, Q = m.d_inner, m.d_state, m.num_heads, m.head_dim, m.chunk
    in_p = 2 * T * cfg.d_model * (2 * di + 2 * N + H)
    conv = 4 * T * m.conv_dim * m.conv_width
    Qe = min(Q, S)
    ssd = 2 * B * S * Qe * (N + H * P) + 6 * B * S * H * P * N
    out_p = 2 * T * di * cfg.d_model
    return in_p + conv + ssd + out_p


def _mlstm_flops(cfg, T) -> float:
    from ..models.transformer import mlstm_cfg
    m = mlstm_cfg(cfg)
    di, H, hd = m.d_inner, m.num_heads, m.head_dim
    # block-diagonal qkv: 3 * 2 * T * di * hd (not di^2)
    proj = 2 * T * cfg.d_model * 2 * di + 3 * 2 * T * di * hd + 4 * T * di * H
    cell = 5 * T * H * hd * hd  # outer product + C update + Cq readout
    down = 2 * T * di * cfg.d_model
    return proj + cell + down


def _slstm_flops(cfg, T) -> float:
    from ..models.transformer import slstm_cfg
    s = slstm_cfg(cfg)
    d, hd = cfg.d_model, s.head_dim
    proj = 4 * 2 * T * d * d
    recur = 4 * 2 * T * d * hd
    ff = int(8 * d / 3 / 64) * 64 or 64
    return proj + recur + 6 * T * d * ff / 1.5  # up(2f) + down


def forward_flops(cfg: ModelConfig, shape_name: str) -> dict:
    spec = INPUT_SHAPES[shape_name]
    S, B = spec["seq_len"], spec["global_batch"]
    kind = spec["kind"]
    decode_cache = S if kind == "decode" else None
    S_eff = 1 if kind == "decode" else S
    T = B * S_eff
    per_kind = {
        "attn": lambda: _attn_flops(cfg, T, B, S_eff, decode_cache=decode_cache)
        + _mlp_flops(T, cfg.d_model, cfg.dense_d_ff or cfg.d_ff, cfg.mlp_gated),
        "moe": lambda: _attn_flops(cfg, T, B, S_eff, decode_cache=decode_cache)
        + _moe_flops(cfg, T),
        "mamba": lambda: _mamba_flops(cfg, T, B, S_eff),
        "mamba_shared_attn": lambda: _mamba_flops(cfg, T, B, S_eff)
        + _attn_flops(cfg, T, B, S_eff, decode_cache=decode_cache)
        + _mlp_flops(T, cfg.d_model, cfg.d_ff),
        "mlstm": lambda: _mlstm_flops(cfg, T),
        "slstm": lambda: _slstm_flops(cfg, T),
    }
    total = 0.0
    for k in _layer_kinds(cfg):
        total += per_kind[k]()
    head = 2 * T * cfg.d_model * cfg.vocab_size * max(1, cfg.num_codebooks)
    if cfg.frontend:
        total += 2 * T * cfg.frontend_dim * cfg.d_model  # projector
    return {"layers": total, "head": head, "total": total + head}


def param_counts(cfg: ModelConfig) -> dict:
    """Exact param counts from abstract init (no allocation)."""
    from ..models import lm_init
    shapes = jax.eval_shape(lambda: lm_init(jax.random.PRNGKey(0), cfg))
    leaves = jax.tree_util.tree_flatten_with_path(shapes)[0]
    total = emb = expert = 0
    for path, leaf in leaves:
        ps = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        n = 1
        for s in leaf.shape:
            n *= s
        total += n
        if "embedding" in ps or "/head/" in ps or ps.endswith("head/kernel"):
            emb += n
        if any(w in ps for w in ("wup", "wgate", "wdown")):
            expert += n
    active = total
    if cfg.num_experts:
        active = total - expert * (1 - cfg.experts_per_tok / cfg.num_experts)
    return {"total": total, "embedding": emb, "active": active}


def model_flops_6nd(cfg: ModelConfig, shape_name: str) -> float:
    """6*N*D reference (active params for MoE; D = tokens this step)."""
    spec = INPUT_SHAPES[shape_name]
    S, B = spec["seq_len"], spec["global_batch"]
    kind = spec["kind"]
    tokens = B * (1 if kind == "decode" else S)
    n = param_counts(cfg)["active"]
    mult = 6 if kind == "train" else 2
    return mult * n * tokens


def hbm_bytes(cfg: ModelConfig, shape_name: str, n_workers: int | None = None,
              buffer_bytes: int = BF16, *, dude_sweep: bool = True) -> dict:
    """Global HBM traffic per step (dominant terms)."""
    spec = INPUT_SHAPES[shape_name]
    S, B = spec["seq_len"], spec["global_batch"]
    kind = spec["kind"]
    n = n_workers or cfg.n_workers
    P = param_counts(cfg)["total"]
    big = cfg.name in ("qwen1.5-110b", "kimi-k2-1t-a32b")
    pbytes = BF16 if big else F32

    out: dict[str, float] = {}
    if kind == "train":
        T = B * S
        # params: fwd read + bwd read (+ remat refwd read); grads written [n,...]
        reads = 3 if cfg.remat else 2
        out["params"] = reads * P * pbytes + n * P * pbytes
        if dude_sweep:
            # paper-faithful masked sweep: r+w of both stacked buffers
            out["dude"] = 2 * 2 * n * P * buffer_bytes + 2 * P * F32 + 2 * P * pbytes
        else:
            # §Perf indexed commit: touch only committing workers (~1/tau_avg)
            out["dude"] = 2 * 2 * P * buffer_bytes + 2 * P * F32 + 2 * P * pbytes
        # attention score tiles (XLA chunked path materializes [B,H,S,chunk]
        # per step; total S^2 across chunks, fwd + bwd + remat refwd)
        att_heads = sum(
            1 for k in _layer_kinds(cfg)
            if k in ("attn", "moe", "mamba_shared_attn")
        )
        out["attn_scores"] = 3 * att_heads * B * cfg.num_heads * S * S * F32 * 0.5
        out["activations"] = 12 * len(_layer_kinds(cfg)) * T * cfg.d_model * BF16
    else:
        out["params"] = P * pbytes
        if kind == "prefill":
            att_heads = sum(
                1 for k in _layer_kinds(cfg)
                if k in ("attn", "moe", "mamba_shared_attn")
            )
            out["attn_scores"] = att_heads * B * cfg.num_heads * S * S * F32 * 0.5
            out["kv_write"] = att_heads * 2 * B * S * cfg.num_kv_heads * cfg.hd * BF16
            out["activations"] = 8 * len(_layer_kinds(cfg)) * B * S * cfg.d_model * BF16
        else:  # decode: read the whole cache (baseline reads full window)
            att_heads = sum(
                1 for k in _layer_kinds(cfg)
                if k in ("attn", "moe", "mamba_shared_attn")
            )
            out["kv_read"] = att_heads * 2 * B * S * cfg.num_kv_heads * cfg.hd * BF16
            ssm_layers = sum(
                1 for k in _layer_kinds(cfg)
                if k in ("mamba", "mamba_shared_attn", "mlstm", "slstm")
            )
            if ssm_layers:
                from ..models.transformer import mamba_cfg, mlstm_cfg
                st = 0
                for k in _layer_kinds(cfg):
                    if k.startswith("mamba"):
                        m = mamba_cfg(cfg)
                        st += B * m.num_heads * m.head_dim * m.d_state * F32
                    elif k == "mlstm":
                        m = mlstm_cfg(cfg)
                        st += B * m.num_heads * m.head_dim ** 2 * F32
                    elif k == "slstm":
                        st += 3 * B * cfg.d_model * F32
                out["ssm_state"] = 2 * st
    out["total"] = float(sum(out.values()))
    return out


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    arch: str
    shape: str
    chips: int
    flops: float
    hbm: float
    collective: float
    t_compute: float
    t_memory: float
    t_collective: float
    model_flops: float
    useful_ratio: float

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)


def roofline(cfg: ModelConfig, shape_name: str, chips: int,
             collective_bytes: float, hw: dict,
             n_workers: int | None = None, *, dude_sweep: bool = True) -> RooflineTerms:
    spec = INPUT_SHAPES[shape_name]
    kind = spec["kind"]
    fwd = forward_flops(cfg, shape_name)["total"]
    mult = (3 + (1 if cfg.remat else 0)) if kind == "train" else 1
    flops = fwd * mult
    hb = hbm_bytes(cfg, shape_name, n_workers, dude_sweep=dude_sweep)["total"]
    mf = model_flops_6nd(cfg, shape_name)
    return RooflineTerms(
        arch=cfg.name, shape=shape_name, chips=chips,
        flops=flops, hbm=hb, collective=collective_bytes,
        t_compute=flops / (chips * hw["peak_flops_bf16"]),
        t_memory=hb / (chips * hw["hbm_bw"]),
        t_collective=collective_bytes / (chips * hw["ici_bw"]),
        model_flops=mf,
        useful_ratio=mf / max(flops, 1.0),
    )

"""Jitted step builders for the production path (DESIGN.md mode B) and the
serving path, plus ShapeDtypeStruct ``input_specs`` for the dry-run.

train_step semantics (semi-async DuDe round):
  1. every worker group computes the gradient of the live model on its own
     heterogeneous shard — one vmapped backward, worker axis leading;
  2. ``dude_round`` latches starting workers' gradients and commits finishing
     workers' deltas (host-precomputed masks from the speed model);
  3. the optimizer applies the dual-delayed aggregated direction g^t.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..core.dude import DuDeConfig, DuDeState, dude_init, dude_round
from ..models import decode_step as model_decode_step
from ..models import forward, init_decode_caches, lm_init, loss_fn, prefill
from ..models.config import ModelConfig
from ..models.stubs import token_shape
from ..optim import sgd
from ..sharding import (
    batch_sharding,
    cache_shardings,
    dude_state_shardings,
    make_shard_hook,
    param_shardings,
)

Pytree = Any

INPUT_SHAPES = {
    "train_4k": {"seq_len": 4096, "global_batch": 256, "kind": "train"},
    "prefill_32k": {"seq_len": 32768, "global_batch": 32, "kind": "prefill"},
    "decode_32k": {"seq_len": 32768, "global_batch": 128, "kind": "decode"},
    "long_500k": {"seq_len": 524288, "global_batch": 1, "kind": "decode"},
}


def shape_supported(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    """long_500k only for sub-quadratic decode archs (DESIGN.md §4)."""
    if shape_name == "long_500k" and not cfg.supports_long_decode():
        return False, (
            f"{cfg.name}: full attention without sliding window — long_500k "
            "skipped (DESIGN.md §4)"
        )
    return True, ""


# ------------------------------------------------------------- step builders

@dataclasses.dataclass(frozen=True)
class TrainOptions:
    """Beyond-paper §Perf knobs (defaults == paper-faithful baseline)."""
    grad_dtype: Any = None        # cast per-worker grads (bf16 halves the
                                  # gradient all-reduce payload)
    constrain_grads: bool = False  # pin stacked grads to the DuDe-buffer
                                   # sharding so GSPMD emits reduce-scatter
                                   # instead of all-reduce + local slice.
                                   # NOTE: constrains the backward output
                                   # only — the flat ServerEngine slab inside
                                   # dude_round is laid out by GSPMD
                                   # (P-axis segment sharding is a ROADMAP
                                   # open item)
    backend: str = "reference"     # ServerEngine update path for the DuDe
                                   # round: reference | indexed | pallas


def make_train_step(cfg: ModelConfig, mesh=None, opt=None,
                    dude_cfg: Optional[DuDeConfig] = None,
                    options: TrainOptions = TrainOptions()) -> Callable:
    opt = opt or sgd(0.01)
    dude_cfg = dude_cfg or DuDeConfig(cfg.n_workers, cfg.dude_buffer_dtype)
    shard = make_shard_hook(mesh)

    buf_sh = None
    if options.constrain_grads and mesh is not None:
        params_abs = abstract_params(cfg)
        buf_sh = dude_state_shardings(params_abs, mesh,
                                      dude_cfg.n_workers)["g_workers"]

    def per_worker_grad(params, wbatch):
        (total, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, wbatch, cfg, shard=shard), has_aux=True
        )(params)
        if options.grad_dtype is not None:
            grads = jax.tree.map(
                lambda g: g.astype(options.grad_dtype), grads
            )
        return grads, metrics["loss"]

    def train_step(params, opt_state, dude_state: DuDeState, batch,
                   start_mask, commit_mask):
        grads, losses = jax.vmap(per_worker_grad, in_axes=(None, 0))(params, batch)
        if buf_sh is not None:
            grads = jax.tree.map(jax.lax.with_sharding_constraint, grads, buf_sh)
        dude_state, g = dude_round(dude_state, grads, start_mask, commit_mask,
                                   dude_cfg, backend=options.backend)
        params, opt_state = opt.apply(params, g, opt_state)
        return params, opt_state, dude_state, {"loss": jnp.mean(losses)}

    return train_step


def make_prefill_step(cfg: ModelConfig, mesh=None) -> Callable:
    shard = make_shard_hook(mesh)

    def prefill_step(params, batch, caches):
        return prefill(params, batch, caches, cfg, shard=shard)

    return prefill_step


def make_decode_step(cfg: ModelConfig, mesh=None, *, use_window: bool = False) -> Callable:
    shard = make_shard_hook(mesh)

    def serve_step(params, tokens, caches, index):
        return model_decode_step(params, tokens, caches, index, cfg,
                                 shard=shard, use_window=use_window)

    return serve_step


# ----------------------------------------------------- abstract state + specs

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def abstract_params(cfg: ModelConfig):
    key = jax.random.PRNGKey(0)
    shapes = jax.eval_shape(lambda: lm_init(key, cfg))
    # master params in f32 for <50B, bf16 at extreme scale (DESIGN.md §7)
    big = cfg.name in ("qwen1.5-110b", "kimi-k2-1t-a32b")
    dt = jnp.bfloat16 if big else jnp.float32
    return jax.tree.map(lambda s: _sds(s.shape, dt), shapes)


def abstract_train_state(cfg: ModelConfig, mesh, opt=None,
                         dude_cfg: Optional[DuDeConfig] = None):
    """Returns (arg_shapes, arg_shardings) for params/opt/dude state."""
    opt = opt or sgd(0.01)
    dude_cfg = dude_cfg or DuDeConfig(cfg.n_workers, cfg.dude_buffer_dtype)
    params = abstract_params(cfg)
    opt_state = jax.eval_shape(opt.init, params)
    dude_state = jax.eval_shape(partial(dude_init, cfg=dude_cfg), params)

    p_sh = param_shardings(params, mesh)
    d_sh_dict = dude_state_shardings(params, mesh, dude_cfg.n_workers)
    dude_sh = DuDeState(
        g_bar=d_sh_dict["g_bar"], g_workers=d_sh_dict["g_workers"],
        inflight=d_sh_dict["inflight"], acc_count=d_sh_dict["acc_count"],
        step=d_sh_dict["step"],
    )
    repl = NamedSharding(mesh, P())
    o_sh = jax.tree.map(lambda _: repl, opt_state)
    # momentum/adam slots shard like params
    if hasattr(opt_state, "slots") and opt_state.slots:
        o_sh = type(opt_state)(step=repl, slots=param_shardings(opt_state.slots, mesh))
    return (params, opt_state, dude_state), (p_sh, o_sh, dude_sh)


def train_batch_specs(cfg: ModelConfig, mesh, shape_name: str,
                      n_workers: Optional[int] = None):
    """ShapeDtypeStructs + shardings for the worker-stacked round batch."""
    spec = INPUT_SHAPES[shape_name]
    n = n_workers or cfg.n_workers
    S, GB = spec["seq_len"], spec["global_batch"]
    assert GB % n == 0, f"batch {GB} % workers {n}"
    b = GB // n
    ts = token_shape(cfg, b, S)
    tok_shape = (n,) + ts
    lab_shape = (n, b, S) + ((cfg.num_codebooks,) if cfg.num_codebooks > 1 else ())
    shapes = {
        "tokens": _sds(tok_shape, jnp.int32),
        "labels": _sds(lab_shape, jnp.int32),
    }
    shardings = {
        "tokens": batch_sharding(mesh, worker_stacked=True, extra_dims=len(ts) - 1,
                                 shape=tok_shape),
        "labels": batch_sharding(mesh, worker_stacked=True,
                                 extra_dims=len(lab_shape) - 2,
                                 shape=lab_shape),
    }
    if cfg.frontend:
        pshape = (n, b, cfg.num_prefix_tokens, cfg.frontend_dim)
        shapes["prefix_emb"] = _sds(pshape, jnp.bfloat16)
        shardings["prefix_emb"] = batch_sharding(mesh, worker_stacked=True,
                                                 extra_dims=2, shape=pshape)
    mask_sds = _sds((n,), jnp.bool_)
    repl = NamedSharding(mesh, P())
    return (shapes, mask_sds), (shardings, repl)


def serve_specs(cfg: ModelConfig, mesh, shape_name: str):
    """ShapeDtypeStructs + shardings for prefill/decode inputs."""
    spec = INPUT_SHAPES[shape_name]
    S, B = spec["seq_len"], spec["global_batch"]
    kind = spec["kind"]
    params = abstract_params(cfg)
    p_sh = param_shardings(params, mesh)
    caches = jax.eval_shape(
        partial(init_decode_caches, cfg, B, S, dtype=jnp.bfloat16)
    )
    c_sh = cache_shardings(caches, mesh)
    if kind == "prefill":
        ts = token_shape(cfg, B, S)
        batch = {"tokens": _sds(ts, jnp.int32)}
        b_sh = {"tokens": batch_sharding(mesh, worker_stacked=False,
                                         extra_dims=len(ts) - 1, shape=ts)}
        if cfg.frontend:
            batch["prefix_emb"] = _sds(
                (B, cfg.num_prefix_tokens, cfg.frontend_dim), jnp.bfloat16
            )
            b_sh["prefix_emb"] = batch_sharding(
                mesh, worker_stacked=False, extra_dims=2,
                shape=(B, cfg.num_prefix_tokens, cfg.frontend_dim))
        return (params, batch, caches), (p_sh, b_sh, c_sh)
    # decode: one token
    tshape = (B, 1) + ((cfg.num_codebooks,) if cfg.num_codebooks > 1 else ())
    tokens = _sds(tshape, jnp.int32)
    t_sh = batch_sharding(mesh, worker_stacked=False, extra_dims=len(tshape) - 1,
                          shape=tshape)
    index = _sds((), jnp.int32)
    i_sh = NamedSharding(mesh, P())
    return (params, tokens, caches, index), (p_sh, t_sh, c_sh, i_sh)

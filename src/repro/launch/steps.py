"""Jitted step builders for the production path (DESIGN.md mode B) and the
serving path, plus ShapeDtypeStruct ``input_specs`` for the dry-run.
(The session layer over these builders — one object, one state, one step
signature — is ``repro.api``; new callers should start there.)

train_step semantics (semi-async round):
  1. every worker group computes the gradient of the live model on its own
     heterogeneous shard — one vmapped backward, worker axis leading;
  2. the server rule (a ``core.algos.RoundAlgo``: the DuDe engine round, or
     a round-based Table-1 baseline on the same slabs) consumes the fresh
     ``[n, P]`` gradients and the host-precomputed start/commit masks;
  3. the flat optimizer applies the rule's direction g^t on the ``[P]``
     master params — fused into the round for the DuDe family
     (``engine.round_apply``), gated by the rule's ``applied`` flag
     otherwise (FedBuff holds the model while its buffer fills).

The canonical train state is the flat ``FlatTrainState`` (master params +
optimizer slots + server slabs, all padded ``[P]``/``[n, P]`` vectors),
sharded on the P axis by the segment ranges of the ``FlatSpec`` shard
table.  The stacked gradients are raveled to the same ``[n, P]`` layout
right after the vmapped backward; with ``constrain_grads`` the ravel
happens INSIDE a ``with_sharding_constraint`` pinned to the slab sharding,
so GSPMD emits a reduce-scatter straight into the shard each device owns
instead of all-reduce + local slice.  With ``params_layout="tp"`` the
params never leave their P-shards at all: the forward is fed through the
TP-native exchange (``FlatSpec.unravel_sharded``) and the gradients come
back through its reverse (``ravel_stacked_sharded``) — no device ever
holds the full ``[P]`` vector or a replicated ``[n, P]`` slab (docs/
engine.md, "TP-native unravel").  The legacy pytree-tuple signature and
the ``flat_optimizer=`` keyword shim are RETIRED: the flat step is the only
step (held tuple states convert once via ``flat_state_from_legacy``; see
the migration table in docs/api.md).  The per-arrival async path lives in
``runtime/runner.py`` over the same state.
"""

from __future__ import annotations

import dataclasses
import math
import warnings
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from ..core.algos import RoundAlgo, make_round_algo
from ..core.dude import DuDeConfig
from ..core.engine import DuDeEngine, EngineState
from ..core.flatten import make_flat_spec
from ..models import decode_step as model_decode_step
from ..models import forward, init_decode_caches, lm_init, loss_fn, prefill
from ..models.config import ModelConfig
from ..models.stubs import token_shape
from ..optim import FlatOptState, FlatTrainState, OptState, flat_twin, sgd
from ..sharding import (
    batch_sharding,
    cache_shardings,
    dude_state_shardings,
    flat_train_state_shardings,
    make_shard_hook,
    param_shardings,
)

Pytree = Any

INPUT_SHAPES = {
    "train_4k": {"seq_len": 4096, "global_batch": 256, "kind": "train"},
    "prefill_32k": {"seq_len": 32768, "global_batch": 32, "kind": "prefill"},
    "decode_32k": {"seq_len": 32768, "global_batch": 128, "kind": "decode"},
    "long_500k": {"seq_len": 524288, "global_batch": 1, "kind": "decode"},
}


def shape_supported(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    """long_500k only for sub-quadratic decode archs (DESIGN.md §4)."""
    if shape_name == "long_500k" and not cfg.supports_long_decode():
        return False, (
            f"{cfg.name}: full attention without sliding window — long_500k "
            "skipped (DESIGN.md §4)"
        )
    return True, ""


# ------------------------------------------------------------- step builders

PARAMS_LAYOUTS = ("replicated", "tp")


@dataclasses.dataclass(frozen=True)
class TrainOptions:
    """Beyond-paper §Perf knobs (defaults == paper-faithful baseline)."""
    grad_dtype: Any = None        # ravel the stacked grads in this dtype
                                  # (bf16 halves the gradient-reduction
                                  # payload feeding the DuDe buffers)
    constrain_grads: bool = False  # wrap the grad ravel in a
                                   # with_sharding_constraint pinned to the
                                   # engine's [n, P] slab sharding so GSPMD
                                   # emits reduce-scatter into the owned
                                   # shard instead of all-reduce + slice
    backend: str = "reference"     # ServerEngine update path for the DuDe
                                   # round: reference | indexed | pallas
    shard_engine: bool = True      # P-axis shard the EngineState over the
                                   # mesh and run the round under shard_map
                                   # (mesh-native engine); False keeps the
                                   # engine layout up to GSPMD
    params_layout: str = "replicated"  # how the forward gets its params:
                                   # "replicated" — one [P] all-gather per
                                   # step, then local slices (correctness
                                   # oracle; O(P) HBM per device);
                                   # "tp" — TP-native exchange straight
                                   # from the P-shards into the Megatron-TP
                                   # leaf layout, no full [P] anywhere
                                   # (needs a mesh-native engine)
    commit_format: str = "f32"     # slab storage / commit wire format:
                                   # "f32" | "int8_ef" | "topk_ef"
                                   # (core/compression.py; docs/engine.md
                                   # "Compressed slabs")
    sparse_transport: bool = False  # topk_ef only: carry commits as
                                   # index-carrying SparseRows and keep
                                   # touched-tile bitmaps on the engine
                                   # state, so commit ingress and the round
                                   # fold scale O(k * tiles_touched) instead
                                   # of O(P) (docs/engine.md "Sparse commit
                                   # transport")
    sparse_cap: Optional[int] = None  # static touched-tile slots per
                                   # SparseRow (None = all tiles; smaller
                                   # caps bound wire bytes, overflow
                                   # re-enters through error feedback)

    def __post_init__(self):
        if self.params_layout not in PARAMS_LAYOUTS:
            raise ValueError(
                f"unknown params_layout {self.params_layout!r}; "
                f"options: {PARAMS_LAYOUTS}")
        if self.sparse_transport and self.commit_format != "topk_ef":
            raise ValueError(
                "sparse_transport requires commit_format='topk_ef' (the "
                f"other formats have dense payloads), got "
                f"{self.commit_format!r}")
        if self.sparse_cap is not None and not self.sparse_transport:
            raise ValueError("sparse_cap requires sparse_transport=True")


def make_engine(cfg: ModelConfig, mesh=None,
                dude_cfg: Optional[DuDeConfig] = None,
                options: TrainOptions = TrainOptions()) -> DuDeEngine:
    """The ServerEngine the train step runs — mesh-native when a mesh is
    given and ``options.shard_engine``: the flat spec is built shard-aligned
    (``mesh_axis_size`` = total device count) and every round runs under
    shard_map with the P axis split by segment ranges across ALL mesh axes
    (the DuDe slabs are pure elementwise state, so the full mesh shards
    them regardless of the params' TP/FSDP layout)."""
    dude_cfg = dude_cfg or DuDeConfig(cfg.n_workers, cfg.dude_buffer_dtype)
    engine_mesh = mesh if (mesh is not None and options.shard_engine) else None
    paxes = None
    if engine_mesh is not None:
        # 'data' leads the P-axis hierarchy so the explicit gradient
        # reduce-scatter (constrain_grads) lands chunks in engine order
        paxes = tuple(sorted(engine_mesh.axis_names,
                             key=lambda a: (a != "data",)))
    return DuDeEngine.for_tree(
        abstract_params(cfg), dude_cfg.n_workers,
        buffer_dtype=dude_cfg.buffer_dtype or jnp.float32,
        accumulate=dude_cfg.accumulate, backend=options.backend,
        mesh=engine_mesh, axis_name=paxes,
        commit_format=options.commit_format,
        sparse_meta=options.sparse_transport,
        sparse_cap=options.sparse_cap,
    )


def make_train_step(cfg: ModelConfig, mesh=None, opt=None,
                    dude_cfg: Optional[DuDeConfig] = None,
                    options: TrainOptions = TrainOptions(),
                    engine: Optional[DuDeEngine] = None,
                    algo: Optional[RoundAlgo] = None) -> Callable:
    """The jitted round step, on the one canonical (flat) train state:

    ``(state: FlatTrainState, batch, sm, cm) -> (state, metrics)`` — master
    params and optimizer slots stay in the engine's segment-range ``[P]``
    layout; for the DuDe family the round and the apply fuse into one
    shard_map (``engine.round_apply``, zero-collective), for any other
    ``RoundAlgo`` from the registry (``sync_sgd`` / ``mifa`` / ``fedbuff``)
    the rule's round body runs mesh-native on the same slabs and its
    ``applied`` gate holds the optimizer when the rule says so.  The only
    gather left is the single params all-gather feeding ``spec.unravel``
    for the forward.

    (The legacy pytree-tuple signature and the ``flat_optimizer=`` keyword
    shim are retired; a held tuple state converts once through
    ``flat_state_from_legacy`` — see the docs/api.md migration table.)
    """
    opt = opt or sgd(0.01)
    dude_cfg = dude_cfg or DuDeConfig(cfg.n_workers, cfg.dude_buffer_dtype)
    engine = engine or make_engine(cfg, mesh, dude_cfg, options)
    algo = algo or make_round_algo(
        "dude_accum" if engine.accumulate else "dude", engine)
    shard = make_shard_hook(mesh)

    gdt = options.grad_dtype or jnp.float32
    tp_plan = None      # TP-native exchange plan (params_layout="tp")
    if options.params_layout == "tp":
        if mesh is None or engine.mesh is None:
            raise ValueError(
                "params_layout='tp' needs a mesh-native engine (pass a mesh "
                "and keep shard_engine=True); the replicated layout is the "
                "meshless fallback")
        tp_plan = engine.tp_plan(param_shardings(abstract_params(cfg), mesh))
    flat_sh = None      # [n, P] slab sharding for the raveled grads
    leaf_sh = None      # legacy per-leaf constraint (unsharded engine)
    rs_fn = None        # explicit reduce-scatter into the owned P-shard
    if options.constrain_grads and mesh is not None and tp_plan is None:
        if engine.mesh is not None:
            flat_sh = engine.shardings().g_workers
            if "data" in engine.paxes and mesh.shape["data"] > 1:
                rs_fn = _grad_reduce_scatter(mesh, engine.paxes)
        else:
            leaf_sh = dude_state_shardings(abstract_params(cfg), mesh,
                                           dude_cfg.n_workers)["g_workers"]
    D = mesh.shape["data"] if rs_fn is not None else 1

    def per_worker_grad(params, wbatch):
        (total, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, wbatch, cfg, shard=shard), has_aux=True
        )(params)
        return grads, metrics["loss"]

    def fresh_grads(params, batch):
        """Stacked backward -> [n, P] slab in the engine's grad layout.

        GSPMD's partitioner lowers "all-reduce then consume a shard" as
        all-reduce + dynamic-slice; to get a true reduce-scatter into the
        engine's P-shards, the data-axis reduction of the gradient is made
        EXPLICIT: split every worker's batch into its 'data'-axis slices
        at the vmap level (the backward then produces per-slice partial
        gradients that stay resident on their shard) and psum-scatter the
        raveled slab straight into the shard each device owns.
        """
        split = (D > 1 and all(x.ndim >= 2 and x.shape[1] % D == 0
                               for x in jax.tree.leaves(batch)))
        if D > 1 and not split:
            _warn_unsplittable(batch, D)
        vbatch = batch
        if split:
            vbatch = jax.tree.map(
                lambda x: jnp.swapaxes(
                    x.reshape((x.shape[0], D, x.shape[1] // D)
                              + x.shape[2:]), 0, 1
                ).reshape((D * x.shape[0], x.shape[1] // D) + x.shape[2:]),
                batch)
        grads, losses = jax.vmap(per_worker_grad, in_axes=(None, 0))(params, vbatch)
        if tp_plan is not None:
            # reverse TP-native exchange: TP-layout gradient leaves ->
            # [n, P] slab shards, no replicated [n, P] intermediate (the
            # data-axis reduction lands on the TP blocks at the shard_map
            # boundary, bounded by each leaf's segment)
            fresh = engine.spec.ravel_stacked_sharded(
                grads, mesh, dtype=gdt, plan=tp_plan)
            return fresh, losses
        if leaf_sh is not None:
            grads = jax.tree.map(jax.lax.with_sharding_constraint, grads, leaf_sh)
        # ravel INSIDE the constraint: the stacked backward output lands
        # directly in the engine's slab layout instead of whatever per-leaf
        # layout GSPMD would pick for the pytree.
        fresh = engine.spec.ravel_stacked(grads, gdt)
        if split:
            # [D*n, P] partial grads, rows resident per data-shard
            fresh = jax.lax.with_sharding_constraint(
                fresh, NamedSharding(mesh, P("data", None)))
            fresh = rs_fn(fresh)  # -> [n, P] in the engine slab sharding
        elif flat_sh is not None:
            fresh = jax.lax.with_sharding_constraint(fresh, flat_sh)
        return fresh, losses

    fopt = flat_twin(opt)
    repl_sh = None
    if mesh is not None:
        repl_sh = NamedSharding(mesh, P())

    def flat_train_step(state: FlatTrainState, batch,
                        start_mask, commit_mask):
        if tp_plan is not None:
            # TP-native path: each leaf's flat range is copied straight
            # out of the P-shards into its Megatron-TP layout via the
            # plan's ppermute ring — no device ever holds the full [P]
            # vector; the forward consumes the TP blocks in place.
            params = engine.spec.unravel_sharded(
                state.params, mesh, plan=tp_plan)
        else:
            pf = state.params
            if repl_sh is not None:
                # THE one all-gather per step: materialize the full [P]
                # vector once; every leaf slice below is then local, and
                # the forward consumes the leaves without further param
                # collectives (re-sharding them per-leaf here would turn
                # into FSDP-style per-layer re-gathers).
                pf = jax.lax.with_sharding_constraint(pf, repl_sh)
            # slice+reshape+cast to the per-leaf target dtypes recorded in
            # the FlatSpec (f32 masters feed a bf16 forward at large scale)
            params = engine.spec.unravel(pf)
        fresh, losses = fresh_grads(params, batch)
        if algo.fused_apply:
            srv_state, _, pf_new, opt_new = engine.round_apply(
                state.engine, fresh, start_mask, commit_mask,
                state.params, state.opt, fopt)
            applied = jnp.array(True)
        else:
            srv_state, g, applied = algo.round(
                state.engine, fresh, start_mask, commit_mask)
            # gated flat apply: slots/params/step only advance on rounds
            # the rule actually applies (FedBuff holds until its buffer
            # fills); everything stays elementwise on the sharded [P] slabs.
            t_new = state.opt.step + applied.astype(jnp.int32)
            pf_up, slots_up = fopt.update(state.params, g,
                                          state.opt.slots, t_new)
            pf_new = jnp.where(applied, pf_up, state.params)
            slots_new = jax.tree.map(
                lambda u, o: jnp.where(applied, u, o),
                slots_up, state.opt.slots)
            opt_new = FlatOptState(t_new, slots_new)
        metrics = {"loss": jnp.mean(losses),
                   "applied": applied.astype(jnp.float32)}
        # indexed backend: cumulative commits/latches dropped by the static
        # index_width bound — the in-graph jax.debug warning's structured
        # twin, so drops show up in every step's metrics, not just stderr
        if getattr(srv_state, "drops", None) is not None:
            metrics["engine_drops"] = srv_state.drops.astype(jnp.float32)
        return FlatTrainState(pf_new, opt_new, srv_state), metrics

    return flat_train_step


def flat_state_from_legacy(engine: DuDeEngine, opt, params: Pytree,
                           opt_state: OptState,
                           dude_state: EngineState) -> FlatTrainState:
    """Migration helper: a legacy ``(params, opt_state, dude_state)`` tuple
    -> the canonical ``FlatTrainState`` (master params raveled to f32
    ``[P]``, per-leaf optimizer slots raveled to the flat twin's slab
    layout, engine state adopted as-is).  The pytree-tuple step that
    PRODUCED such tuples is retired — convert once with this helper, then
    continue through ``api.Trainer`` / the flat step; the full old-call ->
    new-call mapping is the migration table in docs/api.md."""
    spec = engine.spec
    state = FlatTrainState(
        spec.ravel(params, jnp.float32),
        FlatOptState(opt_state.step,
                     _slots_to_flat(spec, opt.name, opt_state.slots)),
        dude_state)
    if engine.mesh is not None:
        sh = flat_train_state_shardings(engine.spec, engine.mesh,
                                        engine.paxes, state.opt,
                                        server_like=dude_state)
        state = jax.device_put(state, sh)
    return state


def _slots_to_flat(spec, opt_name: str, slots: Pytree) -> Pytree:
    """Per-leaf optimizer slots -> the flat twin's ``[P]`` slab layout."""
    if opt_name == "sgd":
        return ()
    if opt_name == "momentum":
        return spec.ravel(slots, jnp.float32)
    if opt_name == "adamw":
        return {"m": spec.ravel(slots["m"], jnp.float32),
                "v": spec.ravel(slots["v"], jnp.float32)}
    raise ValueError(f"optimizer {opt_name!r} has no flat slot layout")


_WARNED_UNSPLITTABLE: set = set()


def _warn_unsplittable(batch, D: int) -> None:
    """One-time warning when ``constrain_grads`` configured an explicit
    reduce-scatter but the batch cannot be split by the data-axis size: the
    step silently falls back to the all-reduce + slice lowering, and users
    tuning collective traffic should know which leaf blocked the split."""
    bad = tuple(tuple(jnp.shape(x)) for x in jax.tree.leaves(batch)
                if not (jnp.ndim(x) >= 2 and jnp.shape(x)[1] % D == 0))
    key = (bad, D)
    if key in _WARNED_UNSPLITTABLE:
        return
    _WARNED_UNSPLITTABLE.add(key)
    warnings.warn(
        f"constrain_grads: batch leaf shape(s) {list(bad)} have a per-worker "
        f"batch dim not divisible by the data-axis size {D}; the explicit "
        "gradient reduce-scatter is skipped this step shape (falling back "
        "to GSPMD's all-reduce + slice lowering)",
        RuntimeWarning, stacklevel=3)


def _grad_reduce_scatter(mesh, paxes: tuple) -> Callable:
    """shard_map reducing ``[D*n, P]`` per-slice partial gradients to the
    ``[n, P]`` round input, P-axis sharded exactly like the engine slabs.

    Rows arrive grouped slice-major (``row = d*n + i``), so each data-shard
    holds one ``[n, P]`` partial sum; ``psum_scatter`` over 'data' emits the
    reduce-scatter HLO (2(D-1)/D · nP bytes — half an all-reduce) and lands
    each device's P-chunk directly; the remaining P axes of ``paxes`` are
    carved out by a local slice (their copies are identical, no traffic).
    """
    assert paxes[0] == "data"
    D = mesh.shape["data"]
    rest = paxes[1:]

    def body(gv):  # [n, P] local partial sums (this shard's batch slice)
        g = jax.lax.psum_scatter(gv, "data", scatter_dimension=1,
                                 tiled=True) / D
        if rest:
            m = math.prod(mesh.shape[a] for a in rest)
            idx = jnp.int32(0)
            for a in rest:
                idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
            w = g.shape[1] // m
            g = jax.lax.dynamic_slice_in_dim(g, idx * w, w, axis=1)
        return g

    return shard_map(body, mesh=mesh, in_specs=P("data", None),
                     out_specs=P(None, paxes), check_rep=False)


def make_prefill_step(cfg: ModelConfig, mesh=None) -> Callable:
    shard = make_shard_hook(mesh)

    def prefill_step(params, batch, caches):
        return prefill(params, batch, caches, cfg, shard=shard)

    return prefill_step


def make_decode_step(cfg: ModelConfig, mesh=None, *, use_window: bool = False) -> Callable:
    shard = make_shard_hook(mesh)

    def serve_step(params, tokens, caches, index):
        return model_decode_step(params, tokens, caches, index, cfg,
                                 shard=shard, use_window=use_window)

    return serve_step


# ----------------------------------------------------- abstract state + specs

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def abstract_params(cfg: ModelConfig):
    key = jax.random.PRNGKey(0)
    shapes = jax.eval_shape(lambda: lm_init(key, cfg))
    # master params in f32 for <50B, bf16 at extreme scale (DESIGN.md §7)
    big = cfg.name in ("qwen1.5-110b", "kimi-k2-1t-a32b")
    dt = jnp.bfloat16 if big else jnp.float32
    return jax.tree.map(lambda s: _sds(s.shape, dt), shapes)


def abstract_train_state(cfg: ModelConfig, mesh, opt=None,
                         dude_cfg: Optional[DuDeConfig] = None,
                         options: TrainOptions = TrainOptions(),
                         engine: Optional[DuDeEngine] = None,
                         algo: Optional[RoundAlgo] = None):
    """Returns (state_shapes, state_shardings) for the train step's state:
    one ``FlatTrainState`` of ShapeDtypeStructs and its
    ``flat_train_state_shardings`` — every slab rides the engine's
    segment-range P-axis split, with the server entry shaped by the
    session's rule (an ``EngineState`` for the DuDe family, the rule's own
    slabs otherwise).  ``algo`` may be a ``RoundAlgo`` or an ``AsyncAlgo``
    — both expose ``state_shapes()``.  (The retired pytree-tuple shapes are
    gone with the pytree step; see docs/api.md.)
    """
    opt = opt or sgd(0.01)
    dude_cfg = dude_cfg or DuDeConfig(cfg.n_workers, cfg.dude_buffer_dtype)
    engine = engine or make_engine(cfg, mesh, dude_cfg, options)

    algo = algo or make_round_algo(
        "dude_accum" if engine.accumulate else "dude", engine)
    fopt = flat_twin(opt)
    pf = _sds((engine.P,), jnp.float32)
    fo_state = jax.eval_shape(fopt.init, pf)
    srv_shapes = algo.state_shapes()
    st_shapes = FlatTrainState(pf, fo_state, srv_shapes)
    st_sh = flat_train_state_shardings(engine.spec, mesh,
                                       engine.paxes or (), fo_state,
                                       server_like=srv_shapes)
    return st_shapes, st_sh


def init_flat_train_state(engine: DuDeEngine, opt, params: Pytree,
                          algo: Optional[RoundAlgo] = None
                          ) -> FlatTrainState:
    """Concrete ``FlatTrainState`` from pytree params: ravel the master
    params to the f32 ``[P]`` slab, zero-init the flat optimizer slots and
    the server state (the engine's ``EngineState`` by default, the given
    ``RoundAlgo``'s own slabs otherwise), and land everything on the
    engine's P-axis shardings when it is mesh-native."""
    fopt = flat_twin(opt)
    pf = engine.spec.ravel(params, jnp.float32)
    srv = algo.init() if algo is not None else engine.init()
    state = FlatTrainState(pf, fopt.init(pf), srv)
    if engine.mesh is not None:
        sh = flat_train_state_shardings(engine.spec, engine.mesh,
                                        engine.paxes, state.opt,
                                        server_like=srv)
        state = jax.device_put(state, sh)
    return state


def train_batch_specs(cfg: ModelConfig, mesh, shape_name: str,
                      n_workers: Optional[int] = None):
    """ShapeDtypeStructs + shardings for the worker-stacked round batch."""
    spec = INPUT_SHAPES[shape_name]
    n = n_workers or cfg.n_workers
    S, GB = spec["seq_len"], spec["global_batch"]
    assert GB % n == 0, f"batch {GB} % workers {n}"
    b = GB // n
    ts = token_shape(cfg, b, S)
    tok_shape = (n,) + ts
    lab_shape = (n, b, S) + ((cfg.num_codebooks,) if cfg.num_codebooks > 1 else ())
    shapes = {
        "tokens": _sds(tok_shape, jnp.int32),
        "labels": _sds(lab_shape, jnp.int32),
    }
    shardings = {
        "tokens": batch_sharding(mesh, worker_stacked=True, extra_dims=len(ts) - 1,
                                 shape=tok_shape),
        "labels": batch_sharding(mesh, worker_stacked=True,
                                 extra_dims=len(lab_shape) - 2,
                                 shape=lab_shape),
    }
    if cfg.frontend:
        pshape = (n, b, cfg.num_prefix_tokens, cfg.frontend_dim)
        shapes["prefix_emb"] = _sds(pshape, jnp.bfloat16)
        shardings["prefix_emb"] = batch_sharding(mesh, worker_stacked=True,
                                                 extra_dims=2, shape=pshape)
    mask_sds = _sds((n,), jnp.bool_)
    repl = NamedSharding(mesh, P())
    return (shapes, mask_sds), (shardings, repl)


def serve_specs(cfg: ModelConfig, mesh, shape_name: str):
    """ShapeDtypeStructs + shardings for prefill/decode inputs."""
    spec = INPUT_SHAPES[shape_name]
    S, B = spec["seq_len"], spec["global_batch"]
    kind = spec["kind"]
    params = abstract_params(cfg)
    p_sh = param_shardings(params, mesh)
    caches = jax.eval_shape(
        partial(init_decode_caches, cfg, B, S, dtype=jnp.bfloat16)
    )
    c_sh = cache_shardings(caches, mesh)
    if kind == "prefill":
        ts = token_shape(cfg, B, S)
        batch = {"tokens": _sds(ts, jnp.int32)}
        b_sh = {"tokens": batch_sharding(mesh, worker_stacked=False,
                                         extra_dims=len(ts) - 1, shape=ts)}
        if cfg.frontend:
            batch["prefix_emb"] = _sds(
                (B, cfg.num_prefix_tokens, cfg.frontend_dim), jnp.bfloat16
            )
            b_sh["prefix_emb"] = batch_sharding(
                mesh, worker_stacked=False, extra_dims=2,
                shape=(B, cfg.num_prefix_tokens, cfg.frontend_dim))
        return (params, batch, caches), (p_sh, b_sh, c_sh)
    # decode: one token
    tshape = (B, 1) + ((cfg.num_codebooks,) if cfg.num_codebooks > 1 else ())
    tokens = _sds(tshape, jnp.int32)
    t_sh = batch_sharding(mesh, worker_stacked=False, extra_dims=len(tshape) - 1,
                          shape=tshape)
    index = _sds((), jnp.int32)
    i_sh = NamedSharding(mesh, P())
    return (params, tokens, caches, index), (p_sh, t_sh, c_sh, i_sh)

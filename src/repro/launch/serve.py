"""Batched serving driver: prefill a batch of prompts, then decode with a
shared step — the production decode path (`serve_step`) exercised end-to-end.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2_0_5b --smoke \
      --batch 4 --prompt-len 32 --gen-len 16
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import init_decode_caches, lm_init
from repro.models.stubs import make_prefix_embeddings


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    key = jax.random.PRNGKey(args.seed)
    params = lm_init(key, cfg)

    B = args.batch
    max_len = cfg.num_prefix_tokens + args.prompt_len + args.gen_len
    caches = init_decode_caches(cfg, B, max_len,
                                dtype=jnp.float32 if args.smoke else jnp.bfloat16)

    tshape = (B, args.prompt_len) + (
        (cfg.num_codebooks,) if cfg.num_codebooks > 1 else ()
    )
    prompts = jax.random.randint(key, tshape, 0, cfg.vocab_size)
    batch = {"tokens": prompts}
    if cfg.frontend:
        batch["prefix_emb"] = make_prefix_embeddings(key, cfg, B)

    prefill_step = jax.jit(make_prefill_step(cfg))
    decode_step = jax.jit(make_decode_step(cfg), static_argnames=())

    t0 = time.time()
    logits, caches = prefill_step(params, batch, caches)
    t_prefill = time.time() - t0

    def sample(key, logits):
        return jax.random.categorical(key, logits / args.temperature, axis=-1)

    pos0 = cfg.num_prefix_tokens + args.prompt_len
    tok = sample(key, logits[:, 0])  # [B] or [B, n_cb]
    generated = [np.asarray(tok)]
    t0 = time.time()
    for t in range(args.gen_len - 1):
        key, sk = jax.random.split(key)
        step_tok = tok.reshape((B, 1) + tok.shape[1:])
        logits, caches = decode_step(params, step_tok, caches,
                                     jnp.int32(pos0 + t))
        tok = sample(sk, logits[:, 0])
        generated.append(np.asarray(tok))
    t_decode = time.time() - t0

    gen = np.stack(generated, axis=1)
    print(f"[serve] generated shape={gen.shape}")
    print(f"[serve] first sequences: {gen[:2, :8].tolist()}")
    print(json.dumps({
        "arch": cfg.name, "batch": B,
        "prefill_s": round(t_prefill, 3),
        "decode_tok_per_s": round(B * (args.gen_len - 1) / max(t_decode, 1e-9), 1),
    }))


if __name__ == "__main__":
    main()

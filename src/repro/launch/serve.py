"""Batched serving driver over the ``api.ServeSession``: prefill a batch of
prompts, then decode with the shared production serve step.  ``--ckpt-dir``
serves straight from a training checkpoint (flat or legacy pytree format,
auto-dispatched).

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2_0_5b --smoke \
      --batch 4 --prompt-len 32 --gen-len 16
"""

from __future__ import annotations

import argparse
import json
import time

import jax

from repro.api import ServeConfig, ServeSession
from repro.models.stubs import make_prefix_embeddings


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--ckpt-dir", default=None,
                    help="serve params from this checkpoint (either format)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    try:
        from repro.configs import get_config
        cfg_probe = get_config(args.arch)
        if args.smoke:
            cfg_probe = cfg_probe.smoke()
        config = ServeConfig(
            arch=args.arch, smoke=args.smoke, batch=args.batch,
            seed=args.seed,
            max_len=cfg_probe.num_prefix_tokens + args.prompt_len
            + args.gen_len)
    except ValueError as e:   # ConfigError or get_config's unknown-arch
        ap.error(str(e))

    session = ServeSession.create(config, ckpt_dir=args.ckpt_dir)
    cfg = session.cfg

    key = jax.random.PRNGKey(args.seed)
    B = args.batch
    tshape = (B, args.prompt_len) + (
        (cfg.num_codebooks,) if cfg.num_codebooks > 1 else ()
    )
    prompts = {"tokens": jax.random.randint(key, tshape, 0, cfg.vocab_size)}
    if cfg.frontend:
        prompts["prefix_emb"] = make_prefix_embeddings(key, cfg, B)

    t0 = time.time()
    logits = session.prefill(prompts)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    # decode continues from the prefilled caches (generate skips the
    # prefill when handed the prompt logits)
    t0 = time.time()
    gen = session.generate(prompts, args.gen_len,
                           temperature=args.temperature, key=key,
                           prompt_logits=logits)
    t_decode = time.time() - t0

    print(f"[serve] generated shape={gen.shape}")
    print(f"[serve] first sequences: {gen[:2, :8].tolist()}")
    print(json.dumps({
        "arch": cfg.name, "batch": B,
        "prefill_s": round(t_prefill, 3),
        "decode_tok_per_s": round(
            B * (args.gen_len - 1) / max(t_decode, 1e-9), 1),
    }))


if __name__ == "__main__":
    main()

"""Session configuration: everything a training session needs, validated in
ONE place.

``TrainerConfig`` is the single front door the driver, the examples, the
benchmarks and the dry-run all build from.  It owns every knob that used to
be scattered across argparse checks and step-builder keywords — model
architecture, server algorithm, optimizer, engine backend, mesh, gradient
dtype, checkpoint policy — and validates their interactions in
``__post_init__`` (e.g. the ``dude_accum`` x backend rule that previously
lived in ``launch/train.py``'s argparse), raising a typed ``ConfigError``
(a ``ValueError``) so callers can catch misconfiguration distinctly from
runtime failures.

The config is declarative: resolving it into live objects (ModelConfig,
DuDeConfig, TrainOptions, Optimizer, engine, RoundAlgo) is done by the
``model_config`` / ``dude_config`` / ``train_options`` /
``make_optimizer`` helpers that ``api.Trainer`` composes.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Union

import jax.numpy as jnp

from ..core.algos import ASYNC_ALGOS, ROUND_ALGOS, STALENESS_ASYNC
from ..core.compression import COMMIT_FORMATS
from ..core.dude import DuDeConfig
from ..core.engine import BACKENDS
from ..models.config import ModelConfig
from ..optim import Optimizer, adamw, momentum_sgd, sgd
from ..runtime.arrivals import SCENARIO_KINDS

__all__ = ["ConfigError", "CheckpointPolicy", "TransportPolicy",
           "TrainerConfig", "OPTIMIZERS"]

# name -> factory(lr) for the string form of ``TrainerConfig.optimizer``
OPTIMIZERS = {"sgd": sgd, "momentum": momentum_sgd, "adamw": adamw}


class ConfigError(ValueError):
    """A ``TrainerConfig`` / ``ServeConfig`` field combination is invalid.

    Raised at config construction time — before any device work — so the
    driver can surface it as a usage error rather than a mid-run crash."""


def _check_arch(arch) -> None:
    """A string ``arch`` must resolve through the registry — including the
    dashed aliases ``get_config`` accepts (e.g. ``"qwen2-0.5b"``)."""
    if isinstance(arch, ModelConfig):
        return
    from ..configs import get_config
    try:
        get_config(arch)
    except ValueError as e:
        raise ConfigError(str(e)) from None


@dataclasses.dataclass(frozen=True)
class CheckpointPolicy:
    """When and where a session checkpoints.

    ``directory`` None disables checkpointing entirely; ``every`` 0 disables
    the periodic save (explicit ``Trainer.save`` calls still work).  Saves
    are always written in the flat format with the spec's segment table;
    restores auto-dispatch on the stored format (``checkpoint_format``), so
    legacy pytree directories keep loading."""

    directory: Optional[str] = None
    every: int = 0

    def __post_init__(self):
        if self.every < 0:
            raise ConfigError(f"CheckpointPolicy.every={self.every} < 0")
        if self.every > 0 and self.directory is None:
            raise ConfigError(
                "CheckpointPolicy.every set without a directory")


@dataclasses.dataclass(frozen=True)
class TransportPolicy:
    """Multi-host transport knobs (``runtime/hostloop.py``).

    ``heartbeat_s`` is how long a link may stay silent before the server
    PINGs it; past ``dead_after_s`` it is declared dead (its logical
    workers become ``AsyncResult.dropouts``).  ``allow_reconnect`` lets a
    dropped worker process re-handshake mid-run and resume its in-flight
    job; ``timeout_s`` / ``retries`` / ``backoff_s`` shape each socket
    send/recv (exponential backoff between attempts)."""

    heartbeat_s: float = 5.0
    dead_after_s: float = 20.0
    poll_s: float = 0.05
    hello_timeout_s: float = 30.0
    timeout_s: float = 30.0
    retries: int = 5
    backoff_s: float = 0.05
    allow_reconnect: bool = True

    def __post_init__(self):
        for name in ("heartbeat_s", "dead_after_s", "poll_s",
                     "hello_timeout_s", "timeout_s", "backoff_s"):
            if getattr(self, name) <= 0:
                raise ConfigError(
                    f"TransportPolicy.{name}={getattr(self, name)} must be "
                    "> 0")
        if self.dead_after_s <= self.heartbeat_s:
            raise ConfigError(
                f"TransportPolicy.dead_after_s={self.dead_after_s} must "
                f"exceed heartbeat_s={self.heartbeat_s} (a PING needs time "
                "to be answered before the link is declared dead)")
        if self.retries < 0:
            raise ConfigError(f"TransportPolicy.retries={self.retries} < 0")


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    """One training session, fully specified.

    ``arch`` is a config-registry name (``repro.configs``) or a concrete
    ``ModelConfig``; ``smoke`` applies the registry's reduced CPU-scale
    variant.  ``algo`` picks the server rule from the ``core.algos``
    registries: a round rule (``ROUND_ALGOS`` — the DuDe family and the
    round-based Table-1 baselines, driven by ``trainer.step``) and/or an
    arrival rule (``ASYNC_ALGOS`` — async DuDe and the three ASGD routing
    disciplines, driven by ``trainer.run_async``); ``dude`` is in both.
    ``optimizer`` is a name from ``OPTIMIZERS`` (built with ``lr``) or a
    prebuilt ``Optimizer``.  ``mesh`` None means single-logical-device
    execution.  ``max_in_flight`` / ``arrival_queue_depth`` tune the async
    runtime (docs/async.md).
    """

    arch: Union[str, ModelConfig]
    smoke: bool = False
    algo: str = "dude"
    optimizer: Union[str, Optimizer] = "sgd"
    lr: float = 0.01
    server_backend: str = "reference"
    mesh: Any = None                    # jax.sharding.Mesh or None
    grad_dtype: Any = None              # ravel the stacked grads in this dtype
    constrain_grads: bool = False       # explicit reduce-scatter into P-shards
    shard_engine: bool = True           # mesh-native engine (P-axis shard_map)
    params_layout: str = "replicated"   # forward param feed: "replicated"
                                        # (one [P] all-gather per step) or
                                        # "tp" (TP-native exchange from the
                                        # P-shards; no full [P] anywhere —
                                        # needs mesh + shard_engine)
    buffer_dtype: Any = None            # engine slabs; None = arch default
                                        # (f32 under smoke); f32 format only
    commit_format: str = "f32"          # slab storage / commit wire format:
                                        # "f32" (historical full precision),
                                        # "int8_ef" (tiled int8 + per-128-
                                        # lane-tile scales + EF residual) or
                                        # "topk_ef" (per-tile magnitude
                                        # top-k before int8) — docs/engine.md
                                        # "Compressed slabs"
    sparse_transport: bool = False      # topk_ef only: SparseRow commit
                                        # transport + touched-tile engine
                                        # metadata (docs/engine.md "Sparse
                                        # commit transport")
    sparse_cap: Optional[int] = None    # static touched-tile slots per
                                        # SparseRow commit (None = all
                                        # tiles; overflow re-enters via EF)
    fedbuff_buffer_size: int = 4        # fedbuff only: gradients per flush
    max_in_flight: Optional[int] = None  # async runs: bound on CONCURRENT
                                         # dispatched-but-unarrived jobs
                                         # (back-pressure, not a hard tau
                                         # cap; None = all workers in
                                         # flight)
    arrival_queue_depth: int = 2        # async runs: host->device step queue
                                        # depth (2 = double buffering)
    scenario: str = "none"              # async runs: client-state scenario
                                        # wrapped around the arrival process
                                        # (runtime.make_scenario — dropout,
                                        # partial gradients, availability
                                        # cycles; docs/async.md
                                        # "Client-state scenarios")
    seed: int = 0
    checkpoint: CheckpointPolicy = CheckpointPolicy()
    transport: TransportPolicy = TransportPolicy()  # multi-host serving
                                                    # (trainer.serve_async)

    def __post_init__(self):
        if self.algo not in ROUND_ALGOS and self.algo not in ASYNC_ALGOS:
            raise ConfigError(
                f"unknown algo {self.algo!r}; round options: {ROUND_ALGOS}, "
                f"async options: {ASYNC_ALGOS}")
        if self.server_backend not in BACKENDS:
            raise ConfigError(
                f"unknown server_backend {self.server_backend!r}; "
                f"options: {BACKENDS}")
        # the rule that used to live in launch/train.py's argparse: the
        # beyond-paper accumulate latch exists only in the reference sweep
        if self.algo == "dude_accum" and self.server_backend != "reference":
            raise ConfigError(
                "algo 'dude_accum' requires server_backend 'reference' "
                "(the accumulate running-mean latch is reference-only); "
                f"got server_backend={self.server_backend!r}")
        if self.commit_format not in COMMIT_FORMATS:
            raise ConfigError(
                f"unknown commit_format {self.commit_format!r}; "
                f"options: {COMMIT_FORMATS}")
        if self.algo == "dude_accum" and self.commit_format != "f32":
            raise ConfigError(
                "algo 'dude_accum' requires commit_format 'f32' (the "
                "accumulate running-mean latch cannot keep quantized slabs "
                f"exact); got commit_format={self.commit_format!r}")
        if self.sparse_transport and self.commit_format != "topk_ef":
            raise ConfigError(
                "sparse_transport requires commit_format 'topk_ef' (the "
                "SparseRow wire format carries per-tile top-k survivors; "
                "f32/int8_ef payloads are dense); got "
                f"commit_format={self.commit_format!r}")
        if self.sparse_cap is not None:
            if not self.sparse_transport:
                raise ConfigError("sparse_cap requires sparse_transport=True")
            if self.sparse_cap < 1:
                raise ConfigError(f"sparse_cap={self.sparse_cap} < 1")
        if isinstance(self.optimizer, str) \
                and self.optimizer not in OPTIMIZERS:
            raise ConfigError(
                f"unknown optimizer {self.optimizer!r}; "
                f"options: {tuple(OPTIMIZERS)} (or pass an Optimizer)")
        if isinstance(self.optimizer, str) and not self.lr > 0:
            raise ConfigError(f"lr={self.lr} must be > 0")
        if self.fedbuff_buffer_size < 1:
            raise ConfigError(
                f"fedbuff_buffer_size={self.fedbuff_buffer_size} < 1")
        if self.max_in_flight is not None and self.max_in_flight < 1:
            raise ConfigError(
                f"max_in_flight={self.max_in_flight} < 1")
        if self.arrival_queue_depth < 1:
            raise ConfigError(
                f"arrival_queue_depth={self.arrival_queue_depth} < 1")
        if self.scenario not in SCENARIO_KINDS:
            raise ConfigError(
                f"unknown scenario {self.scenario!r}; "
                f"options: {SCENARIO_KINDS}")
        if self.algo in STALENESS_ASYNC and self.commit_format != "f32":
            raise ConfigError(
                f"algo {self.algo!r} mixes arrivals with the stored f32 "
                "slab row (FedAsync s(tau) damping); it requires "
                f"commit_format 'f32', got {self.commit_format!r}")
        from ..launch.steps import PARAMS_LAYOUTS
        if self.params_layout not in PARAMS_LAYOUTS:
            raise ConfigError(
                f"unknown params_layout {self.params_layout!r}; "
                f"options: {PARAMS_LAYOUTS}")
        if self.params_layout == "tp":
            if self.mesh is None:
                raise ConfigError(
                    "params_layout='tp' needs a mesh (the TP-native "
                    "exchange redistributes across the P-axis device "
                    "group); use 'replicated' for meshless runs")
            if not self.shard_engine:
                raise ConfigError(
                    "params_layout='tp' needs shard_engine=True — without "
                    "the mesh-native engine the flat state has no P-shards "
                    "to exchange from")
        _check_arch(self.arch)

    # ------------------------------------------------------- resolution

    @property
    def model_config(self) -> ModelConfig:
        if isinstance(self.arch, ModelConfig):
            return self.arch
        from ..configs import get_config
        cfg = get_config(self.arch)
        return cfg.smoke() if self.smoke else cfg

    @property
    def dude_config(self) -> DuDeConfig:
        cfg = self.model_config
        bdt = self.buffer_dtype
        if bdt is None:
            bdt = jnp.float32 if self.smoke else cfg.dude_buffer_dtype
        return DuDeConfig(cfg.n_workers, bdt,
                          accumulate=self.algo == "dude_accum")

    @property
    def train_options(self):
        from ..launch.steps import TrainOptions
        return TrainOptions(
            grad_dtype=self.grad_dtype,
            constrain_grads=self.constrain_grads,
            backend=self.server_backend,
            shard_engine=self.shard_engine,
            params_layout=self.params_layout,
            commit_format=self.commit_format,
            sparse_transport=self.sparse_transport,
            sparse_cap=self.sparse_cap,
        )

    def make_optimizer(self) -> Optimizer:
        if isinstance(self.optimizer, Optimizer):
            return self.optimizer
        return OPTIMIZERS[self.optimizer](self.lr)

"""The ``Trainer`` session: one object, one train state, one step signature.

``Trainer.create(config)`` resolves a ``TrainerConfig`` into a live session:
model config, mesh-native ``DuDeEngine``, the ``RoundAlgo`` server rule, the
flat optimizer twin, and ONE canonical train state — a ``FlatTrainState``
whose master params, optimizer slots and server slabs all live in the
engine's segment-range ``[P]`` layout (P-axis sharded when a mesh is given).
Every round algorithm in the registry — ``dude``, ``dude_accum``, and the
round-based Table-1 baselines ``sync_sgd`` / ``mifa`` / ``fedbuff`` — runs
through the same jitted step:

    metrics = trainer.step(batch, start_mask, commit_mask)

and every ARRIVAL algorithm (``dude``, ``vanilla_asgd``, ``uniform_asgd``,
``shuffled_asgd``) through the event-driven async runtime on the same
state:

    result = trainer.run_async(arrivals, total_iters, sample_fn)

There is no flat/pytree fork, no per-algo state tuple, and no caller-side
restore dispatch: ``trainer.save(dir)`` always writes the flat format with
the spec segment table, and ``Trainer.restore(ckpt_dir, config)`` reads
EITHER a flat or a legacy pytree directory (``checkpoint_format`` decides),
so old checkpoints keep loading through the one entry point.

``TrainerConfig.params_layout`` picks how the step feeds the forward:
``"replicated"`` re-materializes the full ``[P]`` master vector each step
(the correctness oracle), ``"tp"`` routes the P-shards straight into the
params' Megatron-TP layout through the ``FlatSpec`` exchange ring, so no
device ever holds the whole vector (docs/engine.md, "TP-native unravel").

For lowering-only work (dry-run, HLO analysis) ``Trainer.abstract(config)``
builds the same session without materializing any state;
``trainer.input_specs(shape_name)`` returns the (shapes, shardings) of the
full step signature, and ``trainer.step_fn`` is the unjitted step for
custom ``jax.jit`` wrapping (shardings, donation).
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..checkpoint import restore_train_state, save_checkpoint
from ..core.algos import (
    ASYNC_ALGOS, ROUND_ALGOS, AsyncAlgo, RoundAlgo, make_async_algo,
    make_round_algo,
)
from ..launch.steps import (
    abstract_train_state, init_flat_train_state, make_engine, make_train_step,
    train_batch_specs,
)
from ..models import lm_init
from ..optim import FlatTrainState, flat_twin
from .config import ConfigError, TrainerConfig

Pytree = Any

__all__ = ["Trainer"]


class Trainer:
    """A live training session over the single flat train state.

    Build with ``Trainer.create`` / ``Trainer.restore`` /
    ``Trainer.abstract`` — the bare constructor wires the session objects
    but does not initialize state.
    """

    def __init__(self, config: TrainerConfig):
        self.config = config
        self.cfg = config.model_config          # resolved ModelConfig
        self.opt = config.make_optimizer()
        self.fopt = flat_twin(self.opt)
        self.dude_cfg = config.dude_config
        self.options = config.train_options
        self.mesh = config.mesh
        self.engine = make_engine(self.cfg, self.mesh, self.dude_cfg,
                                  self.options)
        # one session may hold BOTH granularities of the same rule: a round
        # rule (trainer.step) and/or an arrival rule (trainer.run_async) —
        # ``dude`` has both, the ASGD disciplines are arrival-only,
        # dude_accum and the Table-1 round baselines are round-only.
        self.algo: Optional[RoundAlgo] = (
            make_round_algo(config.algo, self.engine,
                            buffer_size=config.fedbuff_buffer_size)
            if config.algo in ROUND_ALGOS else None)
        self.async_algo: Optional[AsyncAlgo] = (
            make_async_algo(config.algo, self.engine)
            if config.algo in ASYNC_ALGOS else None)
        self.state: Optional[FlatTrainState] = None
        self.rounds = 0                         # steps taken this session
        self._step_fn = None
        self._jitted = None
        self._runner = None

    # ------------------------------------------------------- constructors

    @classmethod
    def create(cls, config: TrainerConfig,
               params: Optional[Pytree] = None) -> "Trainer":
        """Fresh session: init params from ``config.seed`` (or adopt the
        given pytree) and build the flat train state on the engine's
        shardings."""
        t = cls(config)
        if params is None:
            params = lm_init(jax.random.PRNGKey(config.seed), t.cfg)
        t.state = init_flat_train_state(t.engine, t.opt, params,
                                        algo=t.server_rule)
        return t

    @classmethod
    def restore(cls, ckpt_dir: str, config: TrainerConfig,
                step: Optional[int] = None) -> "Trainer":
        """Resume a session from ``ckpt_dir`` — flat or legacy-pytree format,
        auto-dispatched; ``step`` None loads the latest.  The session's
        round counter resumes from the checkpoint step, so periodic saves
        continue the step sequence instead of rewinding it."""
        from ..checkpoint import latest_step
        t = cls(config)
        # restore into a zero-valued state (cheap: no lm_init of params that
        # the checkpoint immediately overwrites; slots/server slabs are
        # zero-init anyway, which is exactly what a legacy params-only
        # checkpoint should leave in place)
        t.state = t._shard(restore_train_state(ckpt_dir, step,
                                               t._zero_state(),
                                               t.engine.spec))
        t.rounds = step if step is not None else (latest_step(ckpt_dir) or 0)
        return t

    def _shard(self, state: FlatTrainState) -> FlatTrainState:
        """Land ``state`` on the session's P-axis shardings (checkpoint
        restores rebuild leaves host-side, dropping any mesh placement)."""
        if self.engine.mesh is None:
            return state
        from ..sharding import flat_train_state_shardings
        sh = flat_train_state_shardings(self.engine.spec, self.engine.mesh,
                                        self.engine.paxes, state.opt,
                                        server_like=state.engine)
        return jax.device_put(state, sh)

    @property
    def server_rule(self):
        """The rule shaping ``state.engine``: the round rule when the algo
        has one, else the arrival rule (both granularities of one name
        share the server state — e.g. dude's ``EngineState``)."""
        return self.algo if self.algo is not None else self.async_algo

    def _zero_state(self) -> FlatTrainState:
        """A zero-valued ``FlatTrainState`` on the session's shardings."""
        pf = jnp.zeros((self.engine.P,), jnp.float32)
        return self._shard(FlatTrainState(pf, self.fopt.init(pf),
                                          self.server_rule.init()))

    @classmethod
    def abstract(cls, config: TrainerConfig) -> "Trainer":
        """Shapes-only session (state stays None): for lowering, dry-runs
        and HLO analysis via ``input_specs`` / ``step_fn``."""
        return cls(config)

    # -------------------------------------------------------------- step

    @property
    def step_fn(self):
        """The unjitted canonical step, built once per session:
        ``(state, batch, start_mask, commit_mask) -> (state, metrics)``.
        A stable function object, so repeated ``jax.jit(trainer.step_fn)``
        calls hit one jit cache entry."""
        if self.algo is None:
            raise ConfigError(
                f"algo {self.config.algo!r} is arrival-granularity only; "
                "drive it with trainer.run_async (round options: "
                f"{ROUND_ALGOS})")
        if self._step_fn is None:
            self._step_fn = make_train_step(
                self.cfg, self.mesh, self.opt, self.dude_cfg,
                options=self.options, engine=self.engine, algo=self.algo)
        return self._step_fn

    def _jit(self):
        if self._jitted is None:
            self._jitted = jax.jit(self.step_fn, donate_argnums=(0,))
        return self._jitted

    def step(self, batch: Pytree, start_mask, commit_mask) -> dict:
        """Advance one semi-async round; updates ``self.state`` in place and
        returns the metrics dict (``loss``, ``applied``)."""
        if self.state is None:
            raise ConfigError(
                "abstract session has no state; use Trainer.create/restore")
        self.state, metrics = self._jit()(
            self.state, batch, jnp.asarray(start_mask),
            jnp.asarray(commit_mask))
        self.rounds += 1
        return metrics

    # ------------------------------------------------------------- async

    def run_async(self, arrivals, total_iters: int, sample_fn,
                  *, record_every: int = 10, eval_fn=None, ema: float = 0.9,
                  max_time: Optional[float] = None,
                  seed: Optional[int] = None, key_mode: str = "arrival",
                  record_digests: bool = False):
        """Drive ``total_iters`` per-arrival server iterations through the
        event-driven ``runtime.AsyncRunner`` — one ``engine.commit`` (or
        ASGD arrival rule) + flat optimizer apply per gradient arrival, on
        this session's train state.

        ``arrivals`` is a ``runtime.ArrivalProcess`` or a kind name
        (``"fixed"`` / ``"exp"``; ``"trace"`` needs a process built via
        ``runtime.make_arrivals`` or ``TraceArrivals``).  ``sample_fn(
        worker, rng) -> batch`` draws one worker's batch (leaves WITHOUT
        the round step's worker axis).  Updates ``self.state`` and advances
        ``self.rounds`` by the applied iterations; returns the
        ``runtime.AsyncResult`` (records, staleness stats, and the recorded
        ``ArrivalTrace`` for replay).  ``seed`` defaults to ``config.seed +
        self.rounds`` so segmented runs (repeated run_async calls on one
        session) continue the sampling/key stream instead of replaying it;
        pass it explicitly (e.g. the recording run's) for trace-replay
        equivalence.  When ``config.scenario`` is not ``"none"`` the
        arrival process is wrapped in the named client-state scenario
        (``runtime.make_scenario``: dropout/reconnect, partial gradients,
        availability cycles) — except trace replays and processes that are
        already a ``ClientStateProcess``, which carry their own client
        state.  See docs/async.md.
        """
        from ..runtime import make_arrivals, make_scenario
        from ..runtime.arrivals import ClientStateProcess, TraceArrivals
        from ..runtime.runner import AsyncRunner
        if self.async_algo is None:
            raise ConfigError(
                f"algo {self.config.algo!r} has no arrival-granularity "
                f"rule; async options: {ASYNC_ALGOS}")
        if self.state is None:
            raise ConfigError(
                "abstract session has no state; use Trainer.create/restore")
        if seed is None:
            seed = self.config.seed + self.rounds
        if isinstance(arrivals, str):
            # convenience fleet (unit/homogeneous durations), seeded per
            # segment so repeated runs draw fresh schedules; for the
            # speed-model-based heterogeneous fleet build the process
            # explicitly (as launch/train.py does)
            arrivals = make_arrivals(arrivals, self.cfg.n_workers, seed=seed)
        if self.config.scenario != "none" and not isinstance(
                arrivals, (TraceArrivals, ClientStateProcess)):
            arrivals = make_scenario(self.config.scenario, arrivals,
                                     seed=seed)
        if self._runner is None:
            self._runner = AsyncRunner(
                self.engine, self.async_algo, self.opt,
                self._model_grad_fn(),
                queue_depth=self.config.arrival_queue_depth,
                max_in_flight=self.config.max_in_flight)
        res = self._runner.run(
            arrivals, total_iters, sample_fn, self.state,
            seed=seed, record_every=record_every,
            eval_fn=eval_fn, ema=ema, max_time=max_time,
            key_mode=key_mode, record_digests=record_digests)
        self.state = res.state
        self.rounds += int(res.stats.iters)
        return res

    def serve_async(self, links, total_iters: int, *,
                    record_every: int = 10, eval_fn=None, ema: float = 0.9,
                    seed: Optional[int] = None, accept_fn=None,
                    max_wall_s: Optional[float] = None):
        """Multi-host twin of ``run_async``: drive ``total_iters`` server
        iterations from commit frames arriving on ``links`` (connected
        ``runtime.transport`` endpoints, e.g. ``runtime.accept_links``
        output) instead of a simulated arrival process.

        The transport knobs come from ``config.transport``
        (``TransportPolicy``); ``accept_fn`` (e.g.
        ``runtime.poll_accept_fn(listener)``) enables mid-run worker
        reconnects.  Mid-run server-side checkpointing follows the
        config's ``CheckpointPolicy`` — unlike the single-process runner,
        the hosted loop CAN save every ``every`` applied iterations because
        it owns the arrival loop.  Updates ``self.state``/``self.rounds``
        and returns the ``runtime.AsyncResult`` whose recorded trace
        replays bit-for-bit through ``run_async(TraceArrivals(trace), ...,
        key_mode="worker")``.  See docs/async.md ("Multi-host transport").
        """
        from ..runtime.hostloop import HostRunner
        from ..runtime.runner import AsyncRunner
        if self.async_algo is None:
            raise ConfigError(
                f"algo {self.config.algo!r} has no arrival-granularity "
                f"rule; async options: {ASYNC_ALGOS}")
        if self.state is None:
            raise ConfigError(
                "abstract session has no state; use Trainer.create/restore")
        if seed is None:
            seed = self.config.seed + self.rounds
        if self._runner is None:
            self._runner = AsyncRunner(
                self.engine, self.async_algo, self.opt,
                self._model_grad_fn(),
                queue_depth=self.config.arrival_queue_depth,
                max_in_flight=self.config.max_in_flight)
        tp = self.config.transport
        host = HostRunner(self._runner, heartbeat_s=tp.heartbeat_s,
                          dead_after_s=tp.dead_after_s, poll_s=tp.poll_s,
                          hello_timeout_s=tp.hello_timeout_s,
                          allow_reconnect=tp.allow_reconnect)
        pol = self.config.checkpoint
        ckpt_fn = None
        if pol.directory and pol.every:
            def ckpt_fn(state, it):
                save_checkpoint(pol.directory, self.rounds + it, state,
                                flat_spec=self.engine.spec)
        res = host.serve(links, total_iters, self.state, seed=seed,
                         record_every=record_every, eval_fn=eval_fn,
                         ema=ema, accept_fn=accept_fn,
                         checkpoint_every=pol.every or None,
                         checkpoint_fn=ckpt_fn, max_wall_s=max_wall_s)
        self.state = res.state
        self.rounds += int(res.stats.iters)
        return res

    def _model_grad_fn(self):
        """One worker's stochastic gradient of the session's model:
        ``(params_pytree, batch, key) -> (loss, grads_pytree)`` (the
        ``simulate``/``AsyncRunner`` contract; ``key`` rides for parity
        with data pipelines that consume it)."""
        from ..models import loss_fn
        from ..sharding import make_shard_hook
        cfg, shard = self.cfg, make_shard_hook(self.mesh)

        def grad_fn(params, batch, key):
            (_, metrics), grads = jax.value_and_grad(
                lambda p: loss_fn(p, batch, cfg, shard=shard), has_aux=True
            )(params)
            return metrics["loss"], grads

        return grad_fn

    # ------------------------------------------------------------- views

    def params(self) -> Pytree:
        """The master params, unraveled to the model's pytree layout (per-
        leaf target dtypes from the spec's segment table)."""
        return self.engine.spec.unravel(self.state.params)

    def param_count(self) -> int:
        return self.engine.spec.size

    # ------------------------------------------------------- checkpoints

    def save(self, directory: Optional[str] = None,
             step: Optional[int] = None) -> str:
        """Write a flat-format checkpoint (spec segment table embedded);
        defaults: the config's checkpoint directory, the session round."""
        directory = directory or self.config.checkpoint.directory
        if directory is None:
            raise ConfigError("no checkpoint directory configured or given")
        return save_checkpoint(directory, self.rounds if step is None
                               else step, self.state,
                               flat_spec=self.engine.spec)

    def maybe_save(self) -> Optional[str]:
        """Periodic save per the config's ``CheckpointPolicy`` (no-op unless
        ``every`` divides the current round)."""
        pol = self.config.checkpoint
        if pol.directory and pol.every and self.rounds % pol.every == 0:
            return self.save()
        return None

    # ------------------------------------------------- lowering plumbing

    def state_specs(self):
        """(ShapeDtypeStructs, shardings) of the ``FlatTrainState``."""
        return abstract_train_state(self.cfg, self.mesh, self.opt,
                                    self.dude_cfg, options=self.options,
                                    engine=self.engine, algo=self.server_rule)

    def input_specs(self, shape_name: str = "train_4k"):
        """Shapes and shardings of the FULL step signature
        ``(state, batch, start_mask, commit_mask)`` — feeds
        ``launch/dryrun.py`` / ``launch/hlo_analysis.py`` unchanged."""
        st_shapes, st_sh = self.state_specs()
        (b_shapes, mask_sds), (b_sh, mask_sh) = train_batch_specs(
            self.cfg, self.mesh, shape_name)
        return ((st_shapes, b_shapes, mask_sds, mask_sds),
                (st_sh, b_sh, mask_sh, mask_sh))

    def lower(self, shape_name: str = "train_4k", donate: bool = True):
        """Lower the jitted step at the named input shape with the session's
        shardings (the dry-run's compile-and-fit proof)."""
        shapes, shardings = self.input_specs(shape_name)
        jitted = jax.jit(
            self.step_fn,
            in_shardings=shardings,
            out_shardings=(shardings[0], None),
            donate_argnums=(0,) if donate else (),
        )
        return jitted.lower(*shapes)

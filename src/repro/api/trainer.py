"""The ``Trainer`` session: one object, one train state, one step signature.

``Trainer.create(config)`` resolves a ``TrainerConfig`` into a live session:
model config, mesh-native ``DuDeEngine``, the ``RoundAlgo`` server rule, the
flat optimizer twin, and ONE canonical train state — a ``FlatTrainState``
whose master params, optimizer slots and server slabs all live in the
engine's segment-range ``[P]`` layout (P-axis sharded when a mesh is given).
Every algorithm in the registry — ``dude``, ``dude_accum``, and the
round-based Table-1 baselines ``sync_sgd`` / ``mifa`` / ``fedbuff`` — runs
through the same jitted step:

    metrics = trainer.step(batch, start_mask, commit_mask)

There is no flat/pytree fork, no per-algo state tuple, and no caller-side
restore dispatch: ``trainer.save(dir)`` always writes the flat format with
the spec segment table, and ``Trainer.restore(ckpt_dir, config)`` reads
EITHER a flat or a legacy pytree directory (``checkpoint_format`` decides),
so old checkpoints keep loading through the one entry point.

For lowering-only work (dry-run, HLO analysis) ``Trainer.abstract(config)``
builds the same session without materializing any state;
``trainer.input_specs(shape_name)`` returns the (shapes, shardings) of the
full step signature, and ``trainer.step_fn`` is the unjitted step for
custom ``jax.jit`` wrapping (shardings, donation).
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..checkpoint import restore_train_state, save_checkpoint
from ..core.algos import RoundAlgo, make_round_algo
from ..launch.steps import (
    abstract_train_state, init_flat_train_state, make_engine, make_train_step,
    train_batch_specs,
)
from ..models import lm_init
from ..optim import FlatTrainState, flat_twin
from .config import ConfigError, TrainerConfig

Pytree = Any

__all__ = ["Trainer"]


class Trainer:
    """A live training session over the single flat train state.

    Build with ``Trainer.create`` / ``Trainer.restore`` /
    ``Trainer.abstract`` — the bare constructor wires the session objects
    but does not initialize state.
    """

    def __init__(self, config: TrainerConfig):
        self.config = config
        self.cfg = config.model_config          # resolved ModelConfig
        self.opt = config.make_optimizer()
        self.fopt = flat_twin(self.opt)
        self.dude_cfg = config.dude_config
        self.options = config.train_options
        self.mesh = config.mesh
        self.engine = make_engine(self.cfg, self.mesh, self.dude_cfg,
                                  self.options)
        self.algo: RoundAlgo = make_round_algo(
            config.algo, self.engine,
            buffer_size=config.fedbuff_buffer_size)
        self.state: Optional[FlatTrainState] = None
        self.rounds = 0                         # steps taken this session
        self._step_fn = None
        self._jitted = None

    # ------------------------------------------------------- constructors

    @classmethod
    def create(cls, config: TrainerConfig,
               params: Optional[Pytree] = None) -> "Trainer":
        """Fresh session: init params from ``config.seed`` (or adopt the
        given pytree) and build the flat train state on the engine's
        shardings."""
        t = cls(config)
        if params is None:
            params = lm_init(jax.random.PRNGKey(config.seed), t.cfg)
        t.state = init_flat_train_state(t.engine, t.opt, params, algo=t.algo)
        return t

    @classmethod
    def restore(cls, ckpt_dir: str, config: TrainerConfig,
                step: Optional[int] = None) -> "Trainer":
        """Resume a session from ``ckpt_dir`` — flat or legacy-pytree format,
        auto-dispatched; ``step`` None loads the latest.  The session's
        round counter resumes from the checkpoint step, so periodic saves
        continue the step sequence instead of rewinding it."""
        from ..checkpoint import latest_step
        t = cls(config)
        # restore into a zero-valued state (cheap: no lm_init of params that
        # the checkpoint immediately overwrites; slots/server slabs are
        # zero-init anyway, which is exactly what a legacy params-only
        # checkpoint should leave in place)
        t.state = t._shard(restore_train_state(ckpt_dir, step,
                                               t._zero_state(),
                                               t.engine.spec))
        t.rounds = step if step is not None else (latest_step(ckpt_dir) or 0)
        return t

    def _shard(self, state: FlatTrainState) -> FlatTrainState:
        """Land ``state`` on the session's P-axis shardings (checkpoint
        restores rebuild leaves host-side, dropping any mesh placement)."""
        if self.engine.mesh is None:
            return state
        from ..sharding import flat_train_state_shardings
        sh = flat_train_state_shardings(self.engine.spec, self.engine.mesh,
                                        self.engine.paxes, state.opt,
                                        server_like=state.engine)
        return jax.device_put(state, sh)

    def _zero_state(self) -> FlatTrainState:
        """A zero-valued ``FlatTrainState`` on the session's shardings."""
        pf = jnp.zeros((self.engine.P,), jnp.float32)
        return self._shard(FlatTrainState(pf, self.fopt.init(pf),
                                          self.algo.init()))

    @classmethod
    def abstract(cls, config: TrainerConfig) -> "Trainer":
        """Shapes-only session (state stays None): for lowering, dry-runs
        and HLO analysis via ``input_specs`` / ``step_fn``."""
        return cls(config)

    # -------------------------------------------------------------- step

    @property
    def step_fn(self):
        """The unjitted canonical step, built once per session:
        ``(state, batch, start_mask, commit_mask) -> (state, metrics)``.
        A stable function object, so repeated ``jax.jit(trainer.step_fn)``
        calls hit one jit cache entry."""
        if self._step_fn is None:
            self._step_fn = make_train_step(
                self.cfg, self.mesh, self.opt, self.dude_cfg,
                options=self.options, engine=self.engine, algo=self.algo)
        return self._step_fn

    def _jit(self):
        if self._jitted is None:
            self._jitted = jax.jit(self.step_fn, donate_argnums=(0,))
        return self._jitted

    def step(self, batch: Pytree, start_mask, commit_mask) -> dict:
        """Advance one semi-async round; updates ``self.state`` in place and
        returns the metrics dict (``loss``, ``applied``)."""
        if self.state is None:
            raise ConfigError(
                "abstract session has no state; use Trainer.create/restore")
        self.state, metrics = self._jit()(
            self.state, batch, jnp.asarray(start_mask),
            jnp.asarray(commit_mask))
        self.rounds += 1
        return metrics

    # ------------------------------------------------------------- views

    def params(self) -> Pytree:
        """The master params, unraveled to the model's pytree layout (per-
        leaf target dtypes from the spec's segment table)."""
        return self.engine.spec.unravel(self.state.params)

    def param_count(self) -> int:
        return self.engine.spec.size

    # ------------------------------------------------------- checkpoints

    def save(self, directory: Optional[str] = None,
             step: Optional[int] = None) -> str:
        """Write a flat-format checkpoint (spec segment table embedded);
        defaults: the config's checkpoint directory, the session round."""
        directory = directory or self.config.checkpoint.directory
        if directory is None:
            raise ConfigError("no checkpoint directory configured or given")
        return save_checkpoint(directory, self.rounds if step is None
                               else step, self.state,
                               flat_spec=self.engine.spec)

    def maybe_save(self) -> Optional[str]:
        """Periodic save per the config's ``CheckpointPolicy`` (no-op unless
        ``every`` divides the current round)."""
        pol = self.config.checkpoint
        if pol.directory and pol.every and self.rounds % pol.every == 0:
            return self.save()
        return None

    # ------------------------------------------------- lowering plumbing

    def state_specs(self):
        """(ShapeDtypeStructs, shardings) of the ``FlatTrainState``."""
        return abstract_train_state(self.cfg, self.mesh, self.opt,
                                    self.dude_cfg, options=self.options,
                                    engine=self.engine, algo=self.algo)

    def input_specs(self, shape_name: str = "train_4k"):
        """Shapes and shardings of the FULL step signature
        ``(state, batch, start_mask, commit_mask)`` — feeds
        ``launch/dryrun.py`` / ``launch/hlo_analysis.py`` unchanged."""
        st_shapes, st_sh = self.state_specs()
        (b_shapes, mask_sds), (b_sh, mask_sh) = train_batch_specs(
            self.cfg, self.mesh, shape_name)
        return ((st_shapes, b_shapes, mask_sds, mask_sds),
                (st_sh, b_sh, mask_sh, mask_sh))

    def lower(self, shape_name: str = "train_4k", donate: bool = True):
        """Lower the jitted step at the named input shape with the session's
        shardings (the dry-run's compile-and-fit proof)."""
        shapes, shardings = self.input_specs(shape_name)
        jitted = jax.jit(
            self.step_fn,
            in_shardings=shardings,
            out_shardings=(shardings[0], None),
            donate_argnums=(0,) if donate else (),
        )
        return jitted.lower(*shapes)

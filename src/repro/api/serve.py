"""``ServeSession``: the serving-side twin of ``Trainer``.

One object owns the serving state (params + KV caches) and the two jitted
entry points of the production serve path — ``prefill`` and ``decode`` —
plus a ``generate`` convenience loop (sample-and-feed-back) that
``launch/serve.py`` and the examples drive.  Params come from an explicit
pytree, from a checkpoint directory (flat OR legacy pytree format,
auto-dispatched through ``checkpoint.restore_params``), or from a fresh
``lm_init`` — so a model trained through ``Trainer`` serves from its
checkpoint with no format plumbing in between.

``input_specs(shape_name)`` mirrors ``Trainer.input_specs`` for the serve
shapes (``prefill_32k`` / ``decode_32k`` / ``long_500k``), feeding
``launch/dryrun.py`` / ``hlo_analysis`` unchanged.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..launch.steps import make_decode_step, make_prefill_step, serve_specs
from ..models import init_decode_caches, lm_init
from ..models.config import ModelConfig
from .config import ConfigError, _check_arch

Pytree = Any

__all__ = ["ServeConfig", "ServeSession"]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """One serving session: architecture, batch geometry, cache policy."""

    arch: Union[str, ModelConfig]
    smoke: bool = False
    batch: int = 4
    max_len: int = 1024                # KV-cache capacity (incl. prefix)
    cache_dtype: Any = None            # None = f32 under smoke, bf16 else
    mesh: Any = None
    use_window: bool = False           # sliding-window decode kernel
    seed: int = 0

    def __post_init__(self):
        if self.batch < 1:
            raise ConfigError(f"batch={self.batch} < 1")
        if self.max_len < 1:
            raise ConfigError(f"max_len={self.max_len} < 1")
        _check_arch(self.arch)

    @property
    def model_config(self) -> ModelConfig:
        if isinstance(self.arch, ModelConfig):
            return self.arch
        from ..configs import get_config
        cfg = get_config(self.arch)
        return cfg.smoke() if self.smoke else cfg

    @property
    def resolved_cache_dtype(self):
        if self.cache_dtype is not None:
            return self.cache_dtype
        return jnp.float32 if self.smoke else jnp.bfloat16


class ServeSession:
    """Prefill/decode over one set of params and caches."""

    def __init__(self, config: ServeConfig):
        self.config = config
        self.cfg = config.model_config
        self.mesh = config.mesh
        self.params: Optional[Pytree] = None
        self.caches: Optional[Pytree] = None
        self.position = 0               # next decode position
        # unjitted steps exposed for custom lowering (dryrun/hlo_analysis)
        self.prefill_fn = make_prefill_step(self.cfg, self.mesh)
        self.decode_fn = make_decode_step(self.cfg, self.mesh,
                                          use_window=config.use_window)
        self._prefill = jax.jit(self.prefill_fn)
        self._decode = jax.jit(self.decode_fn)

    # ------------------------------------------------------- constructors

    @classmethod
    def create(cls, config: ServeConfig, params: Optional[Pytree] = None,
               ckpt_dir: Optional[str] = None,
               ckpt_step: Optional[int] = None) -> "ServeSession":
        """Live session.  Params resolution order: explicit pytree >
        checkpoint directory (flat or legacy format) > fresh ``lm_init``."""
        s = cls(config)
        if params is None and ckpt_dir is not None:
            from ..checkpoint import restore_params
            like = jax.eval_shape(
                lambda: lm_init(jax.random.PRNGKey(0), s.cfg))
            params = restore_params(ckpt_dir, ckpt_step, like)
        if params is None:
            params = lm_init(jax.random.PRNGKey(config.seed), s.cfg)
        s.params = params
        s.reset()
        return s

    @classmethod
    def abstract(cls, config: ServeConfig) -> "ServeSession":
        """Shapes-only session for lowering (``input_specs``)."""
        return cls(config)

    def reset(self):
        """Fresh KV caches (a new batch of sequences); position rewinds."""
        self.caches = init_decode_caches(
            self.cfg, self.config.batch, self.config.max_len,
            dtype=self.config.resolved_cache_dtype)
        self.position = 0

    # ------------------------------------------------------- entry points

    def prefill(self, batch: Pytree):
        """Run the prompt through the model, filling the caches.  Returns
        the logits at every prompt position."""
        if self.params is None:
            raise ConfigError(
                "abstract session has no params; use ServeSession.create")
        logits, self.caches = self._prefill(self.params, batch, self.caches)
        self.position = self.cfg.num_prefix_tokens \
            + int(batch["tokens"].shape[1])
        return logits

    def decode(self, tokens):
        """One decode step at the session's current position; advances it."""
        logits, self.caches = self._decode(self.params, tokens, self.caches,
                                           jnp.int32(self.position))
        self.position += 1
        return logits

    def generate(self, prompts: Pytree, gen_len: int,
                 temperature: float = 1.0,
                 key: Optional[jax.Array] = None,
                 prompt_logits=None) -> np.ndarray:
        """Prefill then sample ``gen_len`` tokens autoregressively.
        ``prompts`` is the prefill batch dict (``tokens`` [B, S] plus any
        frontend inputs).  Returns the sampled tokens ``[B, gen_len, ...]``.
        ``prompt_logits`` skips the prefill (the caller already ran it on
        this session's caches) and samples the first token from them.
        """
        key = jax.random.PRNGKey(self.config.seed) if key is None else key
        B = prompts["tokens"].shape[0]
        logits = (self.prefill(prompts) if prompt_logits is None
                  else prompt_logits)

        def sample(k, lg):
            return jax.random.categorical(k, lg / temperature, axis=-1)

        tok = sample(key, logits[:, 0])
        out = [np.asarray(tok)]
        for _ in range(gen_len - 1):
            key, sk = jax.random.split(key)
            step_tok = tok.reshape((B, 1) + tok.shape[1:])
            logits = self.decode(step_tok)
            tok = sample(sk, logits[:, 0])
            out.append(np.asarray(tok))
        return np.stack(out, axis=1)

    # ------------------------------------------------- lowering plumbing

    def input_specs(self, shape_name: str):
        """(shapes, shardings) of the prefill/decode step signature at the
        named serve shape — feeds dryrun/hlo_analysis unchanged."""
        return serve_specs(self.cfg, self.mesh, shape_name)

"""Session-layer API: one front door for training and serving.

* ``TrainerConfig`` — every knob of a training session, validated in one
  place (typed ``ConfigError``).
* ``Trainer`` — ``create``/``restore``/``abstract`` a session over the ONE
  canonical train state (``FlatTrainState``); single step signature
  ``trainer.step(batch, start_mask, commit_mask) -> metrics`` for every
  server algorithm in the ``core.algos`` registry; auto-format
  checkpointing (``save``/``restore`` dispatch on the stored format).
* ``ServeSession`` / ``ServeConfig`` — the serving twin: prefill/decode/
  generate over one params+caches state, loadable straight from a Trainer
  checkpoint.
"""

from .config import (CheckpointPolicy, ConfigError, OPTIMIZERS,
                     TrainerConfig, TransportPolicy)
from .serve import ServeConfig, ServeSession
from .trainer import Trainer

__all__ = [
    "CheckpointPolicy", "ConfigError", "OPTIMIZERS", "TrainerConfig",
    "TransportPolicy", "Trainer", "ServeConfig", "ServeSession",
]

"""Sharding rules: parameter / DuDe-state / batch / cache PartitionSpecs.

Layout (DESIGN.md §5):
  * Params: Megatron-TP over ``model`` on heads/ffn/experts/vocab dims ×
    FSDP over ``data`` on the complementary dim; replicated over ``pod``.
  * DuDe buffers (g~, G~_i, in-flight): leading worker dim — unsharded on a
    single pod, ``pod``-sharded multi-pod (pods are worker-group boundaries);
    parameter dims shard like the params (full-mesh elementwise state).
  * Round batch [n_workers, B/n, S]: worker dim ``pod``-sharded (multi-pod)
    or replicated; per-worker batch over ``data``.
  * KV caches: batch over ``data`` (+``pod``), sequence over ``model``
    (flash-decode / long-context layout; head-count agnostic).

Every rule checks divisibility against the mesh and silently drops an axis
that does not divide (replication is always correct, just more memory).
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core.engine import EngineState
from ..core.flatten import FlatSpec
from ..optim.transforms import FlatOptState, FlatTrainState

Pytree = Any

# param names whose rank-2 kernel is "down-like": (model, data) instead of
# (data, model) — keeps each matmul's contracting dim sharded consistently.
_DOWN_LIKE = ("wo", "down", "out_proj", "ff_down")


def _axsize(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _fit(mesh: Mesh, spec_entries, shape):
    """Drop axes that don't divide their dim."""
    out = []
    for dim, ax in zip(shape, spec_entries):
        if ax is None:
            out.append(None)
        elif dim % _axsize(mesh, ax) == 0:
            out.append(ax)
        else:
            out.append(None)
    return P(*out)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_spec(pathstr: str, shape, mesh: Mesh, *, stacked: bool = False,
               fsdp="data") -> P:
    """PartitionSpec for one parameter leaf.

    ``stacked`` — leaf lives under stack/groups and has a leading n_groups dim.
    ``fsdp`` — axis (or axes tuple) carrying the FSDP shard of each kernel;
    multi-pod perf option M1 uses ('pod', 'data').
    """
    if stacked:
        inner = param_spec(pathstr, shape[1:], mesh, stacked=False, fsdp=fsdp)
        return P(None, *inner)

    name = pathstr.rsplit("/", 1)[-1]
    parent = pathstr.split("/")[-2] if "/" in pathstr else ""
    rank = len(shape)

    if name == "embedding":  # [V, d]
        return _fit(mesh, ("model", fsdp), shape)
    if name in ("wup", "wgate"):  # MoE experts [E, d, f]
        return _fit(mesh, ("model", fsdp, None), shape)
    if name == "wdown":  # [E, f, d]
        return _fit(mesh, ("model", None, fsdp), shape)
    if name == "conv":  # [W, C] depthwise conv kernels
        return _fit(mesh, (None, "model"), shape)
    if name in ("ri", "rf", "rz", "ro") or (
        name in ("wq", "wk", "wv") and rank == 3
    ):  # block-diagonal per-head weights [H, hd, hd] (sLSTM rec, mLSTM qkv)
        return _fit(mesh, (None, None, "model"), shape)
    if name == "kernel":
        if rank != 2:
            return P(*([None] * rank))
        if any(d in pathstr for d in _DOWN_LIKE):
            return _fit(mesh, ("model", fsdp), shape)
        return _fit(mesh, (fsdp, "model"), shape)
    if name == "bias" and rank == 1:
        if any(d in pathstr for d in _DOWN_LIKE):
            return _fit(mesh, (fsdp,), shape)
        return _fit(mesh, ("model",), shape)
    # norms, gates, A_log, D, dt_bias, conv_bias, scales: replicate
    return P(*([None] * rank))


def _is_stacked(pathstr: str) -> bool:
    """Leaf lives under a stacked layer-group (leading n_layers dim).  The
    model's param tree has ``groups`` at the ROOT ("groups/0/attn/..."), so
    a bare substring test for "/groups/" misses it — and a prefixed tree
    (e.g. AdamW slots under "m/...") would disagree with the params."""
    return pathstr.startswith("groups/") or "/groups/" in pathstr


def param_shardings(params: Pytree, mesh: Mesh, *, pod_fsdp: bool = False) -> Pytree:
    fsdp = ("pod", "data") if (pod_fsdp and "pod" in mesh.shape) else "data"
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        ps = _path_str(path)
        out.append(NamedSharding(
            mesh, param_spec(ps, leaf.shape, mesh, stacked=_is_stacked(ps),
                             fsdp=fsdp)))
    return jax.tree_util.tree_unflatten(treedef, out)


def slot_shardings(params: Pytree, slots: Pytree, mesh: Mesh) -> Pytree:
    """Shardings for pytree optimizer slots: every slot leaf shards exactly
    like its parameter.

    Slot trees are params-shaped (momentum ``m``) or a dict of params-shaped
    trees (AdamW ``{"m": ..., "v": ...}``).  Running ``param_shardings``
    directly on the latter would prefix every path with ``m/``/``v/`` and
    leave the name-pattern rules one component off, so slot subtrees that
    structurally match ``params`` reuse the param shardings verbatim —
    mismatch is impossible by construction (asserted per optimizer in
    ``tests/test_flat_state.py``)."""
    p_struct = jax.tree_util.tree_structure(params)
    p_sh = param_shardings(params, mesh)
    if jax.tree_util.tree_structure(slots) == p_struct:
        return p_sh
    if isinstance(slots, dict) and slots and all(
            jax.tree_util.tree_structure(v) == p_struct
            for v in slots.values()):
        return {k: p_sh for k in slots}
    return param_shardings(slots, mesh)


def dude_state_shardings(params: Pytree, mesh: Mesh, n_workers: int) -> dict:
    """Shardings for DuDeState: g_bar like params, stacked buffers with a
    leading worker dim (pod-sharded when divisible)."""
    multi_pod = "pod" in mesh.shape
    worker_ax = "pod" if (multi_pod and n_workers % mesh.shape["pod"] == 0) else None

    def one(path, leaf, extra_axis):
        ps = _path_str(path)
        inner = param_spec(ps, leaf.shape, mesh, stacked=_is_stacked(ps))
        if extra_axis is False:
            return NamedSharding(mesh, inner)
        return NamedSharding(mesh, P(worker_ax, *inner))

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    gbar = jax.tree_util.tree_unflatten(
        treedef, [one(p, l, False) for p, l in flat]
    )
    buf = jax.tree_util.tree_unflatten(
        treedef, [one(p, l, True) for p, l in flat]
    )
    scalar = NamedSharding(mesh, P())
    vec = NamedSharding(mesh, P())
    return {
        "g_bar": gbar, "g_workers": buf, "inflight": buf,
        "acc_count": vec, "step": scalar,
    }


def engine_state_shardings(spec: FlatSpec, mesh: Mesh,
                           axes: Any = None) -> EngineState:
    """NamedShardings for the flat ``EngineState`` of a ServerEngine.

    The P axis is split into the contiguous segment ranges of the spec's
    shard table (``FlatSpec.shard_ranges``): ``g_bar`` is ``P(axes)``, the
    ``[n, P]`` slabs are ``P(None, axes)`` (worker axis replicated — workers
    are rows, P-shards are columns), ``acc_count``/``step`` replicated.

    ``axes`` — mesh axis name(s) carrying the P shard; None = all mesh axes.
    Following the module's convention, an axis product that does not divide
    ``spec.padded_size`` drops to replication (build the spec with
    ``make_flat_spec(tree, mesh_axis_size=k)`` to guarantee divisibility).
    """
    if axes is None:
        axes = tuple(mesh.axis_names)
    elif isinstance(axes, str):
        axes = (axes,)
    axes = tuple(axes)
    k = _axsize(mesh, axes)
    if not axes or k <= 1 or spec.padded_size % k != 0:
        vec, row = P(), P()
    else:
        vec, row = P(axes), P(None, axes)
    return EngineState(
        g_bar=NamedSharding(mesh, vec),
        g_workers=NamedSharding(mesh, row),
        inflight=NamedSharding(mesh, row),
        acc_count=NamedSharding(mesh, P()),
        step=NamedSharding(mesh, P()),
    )


def flat_vec_sharding(spec: FlatSpec, mesh: Mesh, axes: Any = None
                      ) -> NamedSharding:
    """The NamedSharding of ONE flat ``[P]`` slab (the ``g_bar`` rule):
    segment-range P-axis split over ``axes`` (None = all mesh axes),
    dropping to replication when the axis product does not divide ``P``.
    Used by the async runtime to land per-arrival raveled gradients and
    worker param snapshots directly in the engine's layout.  A single-leaf
    view of the structural ``flat_slab_shardings`` rule, so the fallback
    logic exists once."""
    return flat_slab_shardings(
        jax.ShapeDtypeStruct((spec.padded_size,), jnp.float32),
        spec, mesh, axes)


def flat_slab_shardings(state_like: Pytree, spec: FlatSpec, mesh: Mesh,
                        axes: Any = None) -> Pytree:
    """Structural P-axis shardings for ANY pytree of flat slabs: every leaf
    whose trailing dim equals ``spec.padded_size`` shards on that dim by the
    spec's segment ranges (``[P]`` like ``g_bar``, ``[n, P]`` like the worker
    slabs); everything else (counters, masks) replicates.  This is how the
    server state of a non-DuDe ``RoundAlgo`` (MIFA memory, FedBuff
    accumulator) rides the engine's layout inside one ``FlatTrainState``."""
    if axes is None:
        axes = tuple(mesh.axis_names)
    elif isinstance(axes, str):
        axes = (axes,)
    axes = tuple(axes)
    k = _axsize(mesh, axes)
    sharded = axes and k > 1 and spec.padded_size % k == 0

    def one(leaf):
        shape = tuple(jnp.shape(leaf))
        if sharded and shape and shape[-1] == spec.padded_size:
            return NamedSharding(mesh, P(*((None,) * (len(shape) - 1)
                                           + (axes,))))
        return NamedSharding(mesh, P())

    return jax.tree.map(one, state_like)


def flat_train_state_shardings(spec: FlatSpec, mesh: Mesh, axes: Any = None,
                               opt_state_like: Any = None,
                               server_like: Any = None) -> FlatTrainState:
    """NamedShardings for a ``FlatTrainState`` on ``mesh``.

    Everything rides the engine's segment-range P-axis split: the ``[P]``
    master params and every ``[P]`` optimizer slot slab shard like ``g_bar``
    (``P(axes)``), the step counter is replicated, and the server state uses
    ``engine_state_shardings`` (``server_like`` None or an ``EngineState`` —
    the DuDe family) or the structural ``flat_slab_shardings`` rule (any
    other ``RoundAlgo`` state).  ``opt_state_like`` supplies the slot tree
    structure (arrays or ShapeDtypeStructs; ``None`` means no slots)."""
    if server_like is None or isinstance(server_like, EngineState):
        srv_sh = engine_state_shardings(spec, mesh, axes)
        vec = srv_sh.g_bar
    else:
        srv_sh = flat_slab_shardings(server_like, spec, mesh, axes)
        vec = flat_slab_shardings(jax.ShapeDtypeStruct((spec.padded_size,),
                                                       jnp.float32),
                                  spec, mesh, axes)
    repl = NamedSharding(mesh, P())
    slots = opt_state_like.slots if opt_state_like is not None else ()
    return FlatTrainState(
        params=vec,
        opt=FlatOptState(step=repl,
                         slots=jax.tree.map(lambda _: vec, slots)),
        engine=srv_sh,
    )


def dp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def batch_sharding(mesh: Mesh, *, worker_stacked: bool, extra_dims: int = 1,
                   shape=None):
    """Sharding for token batches.

    worker_stacked: [n_workers, B/n, S?] — worker dim over 'pod' (if present),
    per-worker batch over 'data'.  Otherwise [B, ...] over all dp axes.
    Axes that do not divide their dim (e.g. batch=1 at long_500k) are dropped.
    """
    if worker_stacked:
        wax = "pod" if "pod" in mesh.shape else None
        spec = (wax, "data") + (None,) * extra_dims
    else:
        dp = dp_axes(mesh)
        # try the full dp product; fall back to 'data' alone; else replicate
        if shape is not None and shape[0] % _axsize(mesh, dp) != 0:
            dp = "data" if shape[0] % _axsize(mesh, "data") == 0 else None
        spec = (dp,) + (None,) * extra_dims
    if shape is not None:
        fitted = []
        for dim, ax in zip(shape, spec):
            fitted.append(ax if (ax is None or dim % _axsize(mesh, ax) == 0) else None)
        spec = tuple(fitted) + spec[len(shape):]
    return NamedSharding(mesh, P(*spec))


def cache_shardings(caches: Pytree, mesh: Mesh) -> Pytree:
    """KV caches [(G,) B, S, K, hd] — batch over dp, sequence over model.
    SSM states [(G,) B, H, ...] — batch over dp, heads over model."""
    dp = dp_axes(mesh)

    def one(path, leaf):
        ps = _path_str(path)
        shape = leaf.shape
        stacked = ps.startswith("groups/") or "/groups/" in ps
        lead = (None,) if stacked else ()
        body = shape[1:] if stacked else shape
        name = ps.rsplit("/", 1)[-1]
        if name in ("k", "v") and len(body) == 4:  # [B, S, K, hd]
            ent = (dp, "model", None, None)
        elif name == "ssm" and len(body) == 4:  # [B, H, P, N]
            ent = (dp, "model", None, None)
        elif name == "C" and len(body) == 4:  # mLSTM [B, H, hd, hd]
            if body[1] % _axsize(mesh, "model") == 0:
                ent = (dp, "model", None, None)
            else:  # few big heads: shard the matrix-memory rows instead
                ent = (dp, None, "model", None)
        elif name == "conv" and len(body) == 3:  # [B, W-1, C]
            ent = (dp, None, "model")
        elif len(body) >= 2:
            ent = (dp,) + (None,) * (len(body) - 1)
        elif len(body) == 1:
            ent = (dp,)
        else:
            ent = ()
        # divisibility fit on the body
        fitted = []
        for dim, ax in zip(body, ent):
            fitted.append(ax if dim % _axsize(mesh, ax) == 0 else None)
        return NamedSharding(mesh, P(*(lead + tuple(fitted))))

    flat, treedef = jax.tree_util.tree_flatten_with_path(caches)
    return jax.tree_util.tree_unflatten(treedef, [one(p, l) for p, l in flat])


def make_shard_hook(mesh: Optional[Mesh]):
    """Activation sharding-constraint hook passed into the model."""
    if mesh is None:
        return lambda x, name: x
    dp = dp_axes(mesh)
    specs = {
        "act_resid": lambda s: P(dp, *([None] * (len(s) - 1))),
        "act_heads": lambda s: P(dp, None, "model", None),
        "act_kv": lambda s: P(dp, None, "model" if s[2] % _axsize(mesh, "model") == 0 else None, None),
        "logits": lambda s: P(dp, *([None] * (len(s) - 2)), "model"),
    }

    def hook(x, name):
        fn = specs.get(name)
        if fn is None:
            return x
        spec = fn(x.shape)
        fitted = []
        for dim, ax in zip(x.shape, spec):
            fitted.append(ax if dim % _axsize(mesh, ax) == 0 else None)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*fitted)))

    return hook

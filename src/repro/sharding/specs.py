"""Sharding rules: parameter / DuDe-state / batch / cache PartitionSpecs.

Layout (DESIGN.md §5):
  * Params: Megatron-TP over ``model`` on heads/ffn/experts/vocab dims ×
    FSDP over ``data`` on the complementary dim; replicated over ``pod``.
  * DuDe buffers (g~, G~_i, in-flight): leading worker dim — unsharded on a
    single pod, ``pod``-sharded multi-pod (pods are worker-group boundaries);
    parameter dims shard like the params (full-mesh elementwise state).
  * Round batch [n_workers, B/n, S]: worker dim ``pod``-sharded (multi-pod)
    or replicated; per-worker batch over ``data``.
  * KV caches: batch over ``data`` (+``pod``), sequence over ``model``
    (flash-decode / long-context layout; head-count agnostic).

Every rule checks divisibility against the mesh and silently drops an axis
that does not divide (replication is always correct, just more memory).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core.engine import EngineState
from ..core.flatten import FlatSpec
from ..optim.transforms import FlatOptState, FlatTrainState

Pytree = Any

# param names whose rank-2 kernel is "down-like": (model, data) instead of
# (data, model) — keeps each matmul's contracting dim sharded consistently.
_DOWN_LIKE = ("wo", "down", "out_proj", "ff_down")


def _axsize(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _fit(mesh: Mesh, spec_entries, shape):
    """Drop axes that don't divide their dim."""
    out = []
    for dim, ax in zip(shape, spec_entries):
        if ax is None:
            out.append(None)
        elif dim % _axsize(mesh, ax) == 0:
            out.append(ax)
        else:
            out.append(None)
    return P(*out)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_spec(pathstr: str, shape, mesh: Mesh, *, stacked: bool = False,
               fsdp="data") -> P:
    """PartitionSpec for one parameter leaf.

    ``stacked`` — leaf lives under stack/groups and has a leading n_groups dim.
    ``fsdp`` — axis (or axes tuple) carrying the FSDP shard of each kernel;
    multi-pod perf option M1 uses ('pod', 'data').
    """
    if stacked:
        inner = param_spec(pathstr, shape[1:], mesh, stacked=False, fsdp=fsdp)
        return P(None, *inner)

    name = pathstr.rsplit("/", 1)[-1]
    parent = pathstr.split("/")[-2] if "/" in pathstr else ""
    rank = len(shape)

    if name == "embedding":  # [V, d]
        return _fit(mesh, ("model", fsdp), shape)
    if name in ("wup", "wgate"):  # MoE experts [E, d, f]
        return _fit(mesh, ("model", fsdp, None), shape)
    if name == "wdown":  # [E, f, d]
        return _fit(mesh, ("model", None, fsdp), shape)
    if name == "conv":  # [W, C] depthwise conv kernels
        return _fit(mesh, (None, "model"), shape)
    if name in ("ri", "rf", "rz", "ro") or (
        name in ("wq", "wk", "wv") and rank == 3
    ):  # block-diagonal per-head weights [H, hd, hd] (sLSTM rec, mLSTM qkv)
        return _fit(mesh, (None, None, "model"), shape)
    if name == "kernel":
        if rank != 2:
            return P(*([None] * rank))
        if any(d in pathstr for d in _DOWN_LIKE):
            return _fit(mesh, ("model", fsdp), shape)
        return _fit(mesh, (fsdp, "model"), shape)
    if name == "bias" and rank == 1:
        if any(d in pathstr for d in _DOWN_LIKE):
            return _fit(mesh, (fsdp,), shape)
        return _fit(mesh, ("model",), shape)
    # norms, gates, A_log, D, dt_bias, conv_bias, scales: replicate
    return P(*([None] * rank))


def _is_stacked(pathstr: str) -> bool:
    """Leaf lives under a stacked layer-group (leading n_layers dim).  The
    model's param tree has ``groups`` at the ROOT ("groups/0/attn/..."), so
    a bare substring test for "/groups/" misses it — and a prefixed tree
    (e.g. AdamW slots under "m/...") would disagree with the params."""
    return pathstr.startswith("groups/") or "/groups/" in pathstr


def param_shardings(params: Pytree, mesh: Mesh, *, pod_fsdp: bool = False) -> Pytree:
    fsdp = ("pod", "data") if (pod_fsdp and "pod" in mesh.shape) else "data"
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        ps = _path_str(path)
        out.append(NamedSharding(
            mesh, param_spec(ps, leaf.shape, mesh, stacked=_is_stacked(ps),
                             fsdp=fsdp)))
    return jax.tree_util.tree_unflatten(treedef, out)


def slot_shardings(params: Pytree, slots: Pytree, mesh: Mesh) -> Pytree:
    """Shardings for pytree optimizer slots: every slot leaf shards exactly
    like its parameter.

    Slot trees are params-shaped (momentum ``m``) or a dict of params-shaped
    trees (AdamW ``{"m": ..., "v": ...}``).  Running ``param_shardings``
    directly on the latter would prefix every path with ``m/``/``v/`` and
    leave the name-pattern rules one component off, so slot subtrees that
    structurally match ``params`` reuse the param shardings verbatim —
    mismatch is impossible by construction (asserted per optimizer in
    ``tests/test_flat_state.py``)."""
    p_struct = jax.tree_util.tree_structure(params)
    p_sh = param_shardings(params, mesh)
    if jax.tree_util.tree_structure(slots) == p_struct:
        return p_sh
    if isinstance(slots, dict) and slots and all(
            jax.tree_util.tree_structure(v) == p_struct
            for v in slots.values()):
        return {k: p_sh for k in slots}
    return param_shardings(slots, mesh)


def dude_state_shardings(params: Pytree, mesh: Mesh, n_workers: int) -> dict:
    """Shardings for DuDeState: g_bar like params, stacked buffers with a
    leading worker dim (pod-sharded when divisible)."""
    multi_pod = "pod" in mesh.shape
    worker_ax = "pod" if (multi_pod and n_workers % mesh.shape["pod"] == 0) else None

    def one(path, leaf, extra_axis):
        ps = _path_str(path)
        inner = param_spec(ps, leaf.shape, mesh, stacked=_is_stacked(ps))
        if extra_axis is False:
            return NamedSharding(mesh, inner)
        return NamedSharding(mesh, P(worker_ax, *inner))

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    gbar = jax.tree_util.tree_unflatten(
        treedef, [one(p, l, False) for p, l in flat]
    )
    buf = jax.tree_util.tree_unflatten(
        treedef, [one(p, l, True) for p, l in flat]
    )
    scalar = NamedSharding(mesh, P())
    vec = NamedSharding(mesh, P())
    return {
        "g_bar": gbar, "g_workers": buf, "inflight": buf,
        "acc_count": vec, "step": scalar,
    }


def engine_state_shardings(spec: FlatSpec, mesh: Mesh, axes: Any = None,
                           like: Any = None) -> EngineState:
    """NamedShardings for the flat ``EngineState`` of a ServerEngine.

    The P axis is split into the contiguous segment ranges of the spec's
    shard table (``FlatSpec.shard_ranges``): ``g_bar`` is ``P(axes)``, the
    ``[n, P]`` slabs are ``P(None, axes)`` (worker axis replicated — workers
    are rows, P-shards are columns), ``acc_count``/``step`` replicated.

    ``axes`` — mesh axis name(s) carrying the P shard; None = all mesh axes.
    Following the module's convention, an axis product that does not divide
    ``spec.padded_size`` drops to replication (build the spec with
    ``make_flat_spec(tree, mesh_axis_size=k)`` to guarantee divisibility).

    ``like`` — an ``EngineState`` of arrays/ShapeDtypeStructs whose
    None-ness the result mirrors.  Compressed commit formats
    (``core/compression.py``) populate the trailing slots: the ``[n, P/128]``
    scale slabs shard their trailing dim like the ``[n, P]`` rows (tile
    boundaries align with shard boundaries because ``P/k`` is a multiple of
    128) and the ``[P]`` EF residual shards like ``g_bar``.  Sparse-transport
    engines (``sparse_meta``) add the ``[n, P/128]`` touched-tile bitmaps —
    sharded exactly like the scale slabs, so every P-shard owns the metadata
    of its own tiles — and the indexed backend adds the replicated scalar
    ``drops`` counter.  With ``like`` omitted (or an f32 state) those fields
    stay ``None``, preserving the historical 5-field structure exactly.
    """
    if axes is None:
        axes = tuple(mesh.axis_names)
    elif isinstance(axes, str):
        axes = (axes,)
    axes = tuple(axes)
    k = _axsize(mesh, axes)
    if not axes or k <= 1 or spec.padded_size % k != 0:
        vec, row = P(), P()
    else:
        vec, row = P(axes), P(None, axes)
    compressed = like is not None and like.ef is not None
    has = lambda f: like is not None and getattr(like, f, None) is not None
    return EngineState(
        g_bar=NamedSharding(mesh, vec),
        g_workers=NamedSharding(mesh, row),
        inflight=NamedSharding(mesh, row),
        acc_count=NamedSharding(mesh, P()),
        step=NamedSharding(mesh, P()),
        gw_scale=NamedSharding(mesh, row) if compressed else None,
        infl_scale=NamedSharding(mesh, row) if compressed else None,
        ef=NamedSharding(mesh, vec) if compressed else None,
        gw_touched=NamedSharding(mesh, row) if has("gw_touched") else None,
        in_touched=NamedSharding(mesh, row) if has("in_touched") else None,
        drops=NamedSharding(mesh, P()) if has("drops") else None,
    )


def flat_vec_sharding(spec: FlatSpec, mesh: Mesh, axes: Any = None
                      ) -> NamedSharding:
    """The NamedSharding of ONE flat ``[P]`` slab (the ``g_bar`` rule):
    segment-range P-axis split over ``axes`` (None = all mesh axes),
    dropping to replication when the axis product does not divide ``P``.
    Used by the async runtime to land per-arrival raveled gradients and
    worker param snapshots directly in the engine's layout.  A single-leaf
    view of the structural ``flat_slab_shardings`` rule, so the fallback
    logic exists once."""
    return flat_slab_shardings(
        jax.ShapeDtypeStruct((spec.padded_size,), jnp.float32),
        spec, mesh, axes)


def flat_slab_shardings(state_like: Pytree, spec: FlatSpec, mesh: Mesh,
                        axes: Any = None) -> Pytree:
    """Structural P-axis shardings for ANY pytree of flat slabs: every leaf
    whose trailing dim equals ``spec.padded_size`` shards on that dim by the
    spec's segment ranges (``[P]`` like ``g_bar``, ``[n, P]`` like the worker
    slabs); everything else (counters, masks) replicates.  This is how the
    server state of a non-DuDe ``RoundAlgo`` (MIFA memory, FedBuff
    accumulator) rides the engine's layout inside one ``FlatTrainState``."""
    if axes is None:
        axes = tuple(mesh.axis_names)
    elif isinstance(axes, str):
        axes = (axes,)
    axes = tuple(axes)
    k = _axsize(mesh, axes)
    sharded = axes and k > 1 and spec.padded_size % k == 0

    def one(leaf):
        shape = tuple(jnp.shape(leaf))
        if sharded and shape and shape[-1] == spec.padded_size:
            return NamedSharding(mesh, P(*((None,) * (len(shape) - 1)
                                           + (axes,))))
        return NamedSharding(mesh, P())

    return jax.tree.map(one, state_like)


def flat_train_state_shardings(spec: FlatSpec, mesh: Mesh, axes: Any = None,
                               opt_state_like: Any = None,
                               server_like: Any = None) -> FlatTrainState:
    """NamedShardings for a ``FlatTrainState`` on ``mesh``.

    Everything rides the engine's segment-range P-axis split: the ``[P]``
    master params and every ``[P]`` optimizer slot slab shard like ``g_bar``
    (``P(axes)``), the step counter is replicated, and the server state uses
    ``engine_state_shardings`` (``server_like`` None or an ``EngineState`` —
    the DuDe family) or the structural ``flat_slab_shardings`` rule (any
    other ``RoundAlgo`` state).  ``opt_state_like`` supplies the slot tree
    structure (arrays or ShapeDtypeStructs; ``None`` means no slots)."""
    if server_like is None or isinstance(server_like, EngineState):
        srv_sh = engine_state_shardings(spec, mesh, axes, like=server_like)
        vec = srv_sh.g_bar
    else:
        srv_sh = flat_slab_shardings(server_like, spec, mesh, axes)
        vec = flat_slab_shardings(jax.ShapeDtypeStruct((spec.padded_size,),
                                                       jnp.float32),
                                  spec, mesh, axes)
    repl = NamedSharding(mesh, P())
    slots = opt_state_like.slots if opt_state_like is not None else ()
    return FlatTrainState(
        params=vec,
        opt=FlatOptState(step=repl,
                         slots=jax.tree.map(lambda _: vec, slots)),
        engine=srv_sh,
    )


# ------------------------------------------------- TP-native unravel plan

@dataclasses.dataclass(frozen=True)
class LeafExchange:
    """Static exchange recipe for ONE leaf of a TP-native unravel.

    ``entries`` is the leaf's resolved Megatron-TP PartitionSpec, one entry
    per dim: ``None`` (replicated dim) or a tuple of mesh axis names.
    ``block_shape`` is the per-device TP block (``shape[d] / prod(entries[d])``
    per dim) and ``strides`` the row-major element strides of the FULL leaf —
    together they place every block element at its global flat offset.
    ``segments`` is the per-(shard, leaf) table from ``FlatSpec
    .shard_segments``: which P-shards hold a piece of this leaf, in
    leaf-local coordinates — the bound on what any exchange for this leaf
    may touch."""

    index: int
    offset: int
    size: int
    shape: tuple
    dtype: Any
    entries: tuple        # per-dim: None | tuple of mesh axis names
    block_shape: tuple
    strides: tuple
    segments: tuple       # ((shard, leaf_lo, leaf_hi), ...)

    @property
    def block_size(self) -> int:
        return int(np.prod(self.block_shape, dtype=np.int64))

    @property
    def tp_axes(self) -> tuple:
        """Mesh axes this leaf's layout actually uses (replicated over the
        rest of the P-axis group)."""
        out = []
        for e in self.entries:
            if e is not None:
                out.extend(e)
        return tuple(out)


@dataclasses.dataclass(frozen=True)
class FlatTpPlan:
    """Static per-(shard, leaf) exchange plan: flat P-shards <-> TP blocks.

    Consumed by ``FlatSpec.unravel_sharded`` / ``ravel_stacked_sharded``
    (core/flatten.py): the flat vector stays split into its ``k`` contiguous
    segment-range windows of ``window`` elements, one per device of the
    P-axis group ``axes``; the windows circulate around a ppermute ring and
    each device copies exactly its TP-block elements out of (into) each
    passing window.  No collective ever carries more than one ``[window]``
    buffer, and no device materializes the full ``[P]`` vector or a full
    leaf.  Built by ``flat_to_tp_plan`` and cached per (spec, mesh, axes,
    leaf specs)."""

    axes: tuple           # P-axis mesh axes, shard-linear (major -> minor)
    mesh_shape: tuple     # sizes of those axes
    k: int                # number of P-shards == ring length
    window: int           # elements per P-shard (spec.padded_size / k)
    leaves: tuple         # LeafExchange per spec leaf
    needs_i64: bool       # flat offsets exceed int32 (>2 GiB of elements);
                          # informational — the rings address windows in two
                          # int32 digits (pos>>7, pos&127) at every scale, so
                          # no int64 ever enters the traced index math

    # ------------------------------------------------ analytics (for the
    # ------------------------------------------------ bench and the docs)

    @property
    def full_vector_bytes(self) -> int:
        """Per-device bytes of the replicated-path [P] f32 materialization."""
        return 4 * self.window * self.k

    @property
    def window_bytes(self) -> int:
        return 4 * self.window

    @property
    def block_bytes(self) -> int:
        """Per-device bytes of all TP blocks in f32 staging."""
        return sum(4 * lf.block_size for lf in self.leaves)

    @property
    def index_bytes(self) -> int:
        """Per-device bytes of the gather-position digit vectors (hi + lo,
        both int32, at every scale)."""
        return sum(8 * lf.block_size for lf in self.leaves)

    @property
    def peak_bytes(self) -> int:
        """Per-device peak live bytes of a TP-native unravel: own window +
        one circulating window + every TP block (f32) + position vectors.
        The replicated path peaks at ``full_vector_bytes`` instead."""
        return 2 * self.window_bytes + self.block_bytes + self.index_bytes

    @property
    def ring_bytes(self) -> int:
        """Per-device bytes moved by the ring (k-1 window hops)."""
        return (self.k - 1) * self.window_bytes

    def max_leaf_segment_bytes(self) -> int:
        """f32 bytes of the largest per-(shard, leaf) segment — the bound on
        any single leaf's per-window gather."""
        best = 0
        for lf in self.leaves:
            for _, a, b in lf.segments:
                best = max(best, 4 * (b - a))
        return best


_TP_PLAN_CACHE: dict = {}


def _leaf_pspec_entries(sh, rank: int) -> tuple:
    """NamedSharding | PartitionSpec -> per-dim entries, padded to rank."""
    ps = sh.spec if isinstance(sh, NamedSharding) else sh
    entries = list(tuple(ps)) + [None] * (rank - len(tuple(ps)))
    out = []
    for e in entries[:rank]:
        if e is None:
            out.append(None)
        elif isinstance(e, str):
            out.append((e,))
        else:
            out.append(tuple(e))
    return tuple(out)


def flat_to_tp_plan(spec: FlatSpec, mesh: Mesh, param_sh: Pytree,
                    axes: Any = None) -> FlatTpPlan:
    """The TP-native unravel rule: a static exchange plan mapping the flat
    vector's segment-range P-shards to the params' Megatron-TP layout.

    ``param_sh`` is the ``param_shardings`` pytree (NamedShardings or raw
    PartitionSpecs) for the SAME tree layout as ``spec``; ``axes`` the mesh
    axes carrying the P shard (None = all mesh axes, 'data' leading, i.e.
    the engine's ``paxes`` convention).  Every leaf spec must (a) only use
    axes from the P-axis group — the exchange redistributes within that
    group — and (b) divide its dims; a non-dividing axis drops to
    replication (the module-wide ``_fit`` convention).

    The plan is static: per leaf it records the TP block shape, the full
    leaf's element strides, and the per-(shard, leaf) segment table from
    ``FlatSpec.shard_segments`` — everything ``unravel_sharded`` needs to
    copy block elements straight out of the circulating windows.  Cached on
    (spec, mesh, axes, leaf specs)."""
    if axes is None:
        axes = tuple(sorted(mesh.axis_names, key=lambda a: (a != "data",)))
    elif isinstance(axes, str):
        axes = (axes,)
    axes = tuple(axes)
    for a in axes:
        if a not in mesh.shape:
            raise ValueError(f"axis {a!r} not in mesh {tuple(mesh.axis_names)}")
    k = _axsize(mesh, axes)
    if k < 1 or spec.padded_size % k != 0:
        raise ValueError(
            f"P={spec.padded_size} not divisible into {k} shards over "
            f"axes {axes}; build the spec with mesh_axis_size={k}")

    sh_leaves = spec.treedef.flatten_up_to(param_sh)
    if len(sh_leaves) != len(spec.shapes):
        raise ValueError(
            f"param_sh has {len(sh_leaves)} leaves, spec has "
            f"{len(spec.shapes)}")
    entries_key = tuple(_leaf_pspec_entries(sh, len(shp))
                        for sh, shp in zip(sh_leaves, spec.shapes))
    key = (spec, mesh, axes, entries_key)
    plan = _TP_PLAN_CACHE.get(key)
    if plan is not None:
        return plan

    window = spec.padded_size // k
    # per-leaf segment tables: invert the per-shard tables (uses the
    # memoized FlatSpec.shard_segments when the shard counts agree)
    per_leaf_segs: dict = {i: [] for i in range(len(spec.shapes))}
    if k == spec.mesh_axis_size:
        for s in range(k):
            for i, a, b in spec.shard_segments(s):
                per_leaf_segs[i].append((s, a, b))
    else:
        for s in range(k):
            lo, hi = s * window, (s + 1) * window
            for i, (off, sz) in enumerate(zip(spec.offsets, spec.sizes)):
                a, b = max(lo, off), min(hi, off + sz)
                if a < b:
                    per_leaf_segs[i].append((s, a - off, b - off))

    leaves = []
    for i, (shp, ents) in enumerate(zip(spec.shapes, entries_key)):
        fitted = []
        bshp = []
        for d, e in zip(shp, ents):
            if e is not None:
                bad = [a for a in e if a not in axes]
                if bad:
                    raise ValueError(
                        f"leaf {i} spec uses axes {bad} outside the P-axis "
                        f"group {axes}")
            m = _axsize(mesh, e)
            if e is None or d % m != 0:
                fitted.append(None)
                bshp.append(d)
            else:
                fitted.append(e)
                bshp.append(d // m)
        strides = []
        s = 1
        for d in reversed(shp):
            strides.insert(0, s)
            s *= int(d)
        leaves.append(LeafExchange(
            index=i, offset=spec.offsets[i], size=spec.sizes[i], shape=shp,
            dtype=spec.dtypes[i], entries=tuple(fitted),
            block_shape=tuple(bshp), strides=tuple(strides),
            segments=tuple(per_leaf_segs[i])))

    if window % 128:
        raise ValueError(
            f"TP-native exchange needs 128-lane-aligned windows; got "
            f"window={window} (pad the spec with pad_multiple=128)")
    if spec.padded_size > (np.iinfo(np.int32).max << 7):
        raise NotImplementedError(
            f"padded_size={spec.padded_size} exceeds 2^38: the two-digit "
            f"int32 window addressing (128 lanes per row) tops out at "
            f"~274 B params")
    plan = FlatTpPlan(
        axes=axes, mesh_shape=tuple(mesh.shape[a] for a in axes), k=k,
        window=window, leaves=tuple(leaves),
        needs_i64=spec.padded_size > np.iinfo(np.int32).max)
    _TP_PLAN_CACHE[key] = plan
    return plan


def dp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def batch_sharding(mesh: Mesh, *, worker_stacked: bool, extra_dims: int = 1,
                   shape=None):
    """Sharding for token batches.

    worker_stacked: [n_workers, B/n, S?] — worker dim over 'pod' (if present),
    per-worker batch over 'data'.  Otherwise [B, ...] over all dp axes.
    Axes that do not divide their dim (e.g. batch=1 at long_500k) are dropped.
    """
    if worker_stacked:
        wax = "pod" if "pod" in mesh.shape else None
        spec = (wax, "data") + (None,) * extra_dims
    else:
        dp = dp_axes(mesh)
        # try the full dp product; fall back to 'data' alone; else replicate
        if shape is not None and shape[0] % _axsize(mesh, dp) != 0:
            dp = "data" if shape[0] % _axsize(mesh, "data") == 0 else None
        spec = (dp,) + (None,) * extra_dims
    if shape is not None:
        fitted = []
        for dim, ax in zip(shape, spec):
            fitted.append(ax if (ax is None or dim % _axsize(mesh, ax) == 0) else None)
        spec = tuple(fitted) + spec[len(shape):]
    return NamedSharding(mesh, P(*spec))


def cache_shardings(caches: Pytree, mesh: Mesh) -> Pytree:
    """KV caches [(G,) B, S, K, hd] — batch over dp, sequence over model.
    SSM states [(G,) B, H, ...] — batch over dp, heads over model."""
    dp = dp_axes(mesh)

    def one(path, leaf):
        ps = _path_str(path)
        shape = leaf.shape
        stacked = ps.startswith("groups/") or "/groups/" in ps
        lead = (None,) if stacked else ()
        body = shape[1:] if stacked else shape
        name = ps.rsplit("/", 1)[-1]
        if name in ("k", "v") and len(body) == 4:  # [B, S, K, hd]
            ent = (dp, "model", None, None)
        elif name == "ssm" and len(body) == 4:  # [B, H, P, N]
            ent = (dp, "model", None, None)
        elif name == "C" and len(body) == 4:  # mLSTM [B, H, hd, hd]
            if body[1] % _axsize(mesh, "model") == 0:
                ent = (dp, "model", None, None)
            else:  # few big heads: shard the matrix-memory rows instead
                ent = (dp, None, "model", None)
        elif name == "conv" and len(body) == 3:  # [B, W-1, C]
            ent = (dp, None, "model")
        elif len(body) >= 2:
            ent = (dp,) + (None,) * (len(body) - 1)
        elif len(body) == 1:
            ent = (dp,)
        else:
            ent = ()
        # divisibility fit on the body
        fitted = []
        for dim, ax in zip(body, ent):
            fitted.append(ax if dim % _axsize(mesh, ax) == 0 else None)
        return NamedSharding(mesh, P(*(lead + tuple(fitted))))

    flat, treedef = jax.tree_util.tree_flatten_with_path(caches)
    return jax.tree_util.tree_unflatten(treedef, [one(p, l) for p, l in flat])


def make_shard_hook(mesh: Optional[Mesh]):
    """Activation sharding-constraint hook passed into the model."""
    if mesh is None:
        return lambda x, name: x
    dp = dp_axes(mesh)
    specs = {
        "act_resid": lambda s: P(dp, *([None] * (len(s) - 1))),
        "act_heads": lambda s: P(dp, None, "model", None),
        "act_kv": lambda s: P(dp, None, "model" if s[2] % _axsize(mesh, "model") == 0 else None, None),
        "logits": lambda s: P(dp, *([None] * (len(s) - 2)), "model"),
    }

    def hook(x, name):
        fn = specs.get(name)
        if fn is None:
            return x
        spec = fn(x.shape)
        fitted = []
        for dim, ax in zip(x.shape, spec):
            fitted.append(ax if dim % _axsize(mesh, ax) == 0 else None)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*fitted)))

    return hook

from .specs import (
    FlatTpPlan,
    LeafExchange,
    batch_sharding,
    cache_shardings,
    dp_axes,
    dude_state_shardings,
    engine_state_shardings,
    flat_slab_shardings,
    flat_to_tp_plan,
    flat_train_state_shardings,
    flat_vec_sharding,
    make_shard_hook,
    param_shardings,
    param_spec,
    slot_shardings,
)

__all__ = [
    "param_spec", "param_shardings", "slot_shardings",
    "dude_state_shardings", "engine_state_shardings",
    "flat_slab_shardings", "flat_train_state_shardings",
    "flat_vec_sharding",
    "FlatTpPlan", "LeafExchange", "flat_to_tp_plan",
    "batch_sharding", "cache_shardings",
    "make_shard_hook", "dp_axes",
]

"""The one asynchronous event loop: dispatch/collect over an ArrivalProcess.

Both execution modes of this repo run per-arrival training off THIS loop —
the event-driven simulator (``core/simulator.py``, pytree math) and the
production ``AsyncRunner`` (``runtime/runner.py``, flat slab math) — so the
arrival semantics (heap ordering, routing draws, staleness bookkeeping,
in-flight bounding) exist exactly once and the two modes are bit-for-bit
comparable on a recorded trace (``tests/test_runtime.py``).

The loop is host-only and deterministic given (a) the process's duration
draws and (b) the caller-supplied ``rng`` consumed by the routing
disciplines.  Per arrival it:

1. pops the earliest ``(t_arrive, worker)`` job off the in-flight heap,
2. calls ``on_arrival(view)`` — the caller computes the gradient on the
   model version that worker holds and applies the server update, returning
   whether the model version advanced (``applied``),
3. routes the post-update model: greedy (``route=None``) hands it back to
   the arriving worker; ``uniform``/``shuffled`` hand it to a sampled
   worker's queue (Koloskova et al. 2022 / Islamov et al. 2024 semantics,
   unchanged from the historical simulator loop),
4. dispatches the next job(s), gated by ``max_in_flight``: dispatches beyond
   the bound queue in FIFO order and start when an arrival frees a slot —
   bounding CONCURRENT jobs (back-pressure, fewer simultaneously stale
   gradients), not per-job staleness: a straggler's job still ages while
   the other slots recycle.

Every run records its ``ArrivalTrace``; replaying it through
``TraceArrivals`` reproduces the identical event sequence (verified against
the source trace at the end of a replay run).  Documented in docs/async.md
("The event loop" / "Staleness accounting").
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Optional

import numpy as np

from .arrivals import (Arrival, ArrivalProcess, ArrivalTrace, ClientEvent,
                       TraceArrivals)

__all__ = ["ArrivalView", "LoopStats", "drive_arrivals"]

ROUTES = (None, "uniform", "shuffled")


@dataclasses.dataclass(frozen=True)
class ArrivalView:
    """What ``on_arrival`` sees: one worker arriving with a gradient.

    ``iters`` is the number of APPLIED server iterations before this
    arrival; ``tau`` the model staleness ``iters + 1 - version(worker)``
    (the paper's model delay: how many server iterations elapsed since the
    arriving gradient's model version was produced).  ``completeness`` is
    the client-state partial-gradient fraction (1.0 unless the run's
    process is a ``ClientStateProcess`` or a v3 trace replay): the caller
    must scale the arriving gradient by it before the server update.
    """

    seq: int        # arrival index, 0-based
    worker: int
    t: float        # arrival time (simulated clock)
    tau: int
    iters: int
    completeness: float = 1.0


@dataclasses.dataclass(frozen=True)
class LoopStats:
    """What one driven run did: counts, staleness, and the recorded trace."""

    arrivals: int
    iters: int           # applied server iterations
    tau_max: int
    t_end: float
    max_in_flight: int   # max simultaneously computing jobs observed
    trace: ArrivalTrace


def drive_arrivals(
    process: ArrivalProcess,
    total_iters: int,
    on_arrival: Callable[[ArrivalView], bool],
    deliver: Callable[[int], None],
    *,
    route: Optional[str] = None,
    rng: Optional[np.random.Generator] = None,
    max_in_flight: Optional[int] = None,
    max_time: Optional[float] = None,
) -> LoopStats:
    """Drive per-arrival training until ``total_iters`` server iterations.

    ``on_arrival(view) -> applied`` computes the gradient of the arriving
    worker (on the model version it holds) and applies the server update;
    ``deliver(worker)`` hands the CURRENT model to ``worker`` (the loop then
    stamps that worker's model version).  ``rng`` feeds the routing draws
    and must be the same generator the caller samples batches from — draw
    order is part of the arrival semantics a trace replay must reproduce.
    """
    if route not in ROUTES:
        raise ValueError(f"unknown route {route!r}; options: {ROUTES}")
    if max_in_flight is not None and max_in_flight < 1:
        raise ValueError(f"max_in_flight={max_in_flight} must be >= 1")
    if route is not None and rng is None:
        raise ValueError(f"route={route!r} needs an rng for its draws")
    n = process.n
    process.reset()

    heap: list = []            # (t_arrive, worker, t_dispatch)
    pending: list = []         # FIFO of workers waiting for an in-flight slot
    queues = [1] * n           # pending models per worker (routed mode)
    version_iter = [0] * n     # server iter that produced each worker's model
    shuffle_order: list = []
    arrivals: list = []
    events: list = []          # per-arrival ClientEvent (or None)
    it = 0
    t_now = 0.0
    tau_max = 0
    seq = 0
    inflight_max = 0

    def dispatch(w: int, t: float) -> None:
        nonlocal inflight_max
        if max_in_flight is not None and len(heap) >= max_in_flight:
            pending.append(w)
            return
        heapq.heappush(heap, (t + process.duration_at(w, t), w, t))
        inflight_max = max(inflight_max, len(heap))

    def drain(t: float) -> None:
        while pending and (max_in_flight is None
                           or len(heap) < max_in_flight):
            dispatch(pending.pop(0), t)

    def next_routed_worker() -> int:
        nonlocal shuffle_order
        if route == "uniform":
            return int(rng.integers(n))
        if not shuffle_order:
            shuffle_order = list(rng.permutation(n))
        return int(shuffle_order.pop())

    for i in range(n):
        dispatch(i, 0.0)

    while heap and it < total_iters and (max_time is None
                                         or t_now < max_time):
        t_now, i, t_disp = heapq.heappop(heap)
        if not np.isfinite(t_now):
            break  # only never-arriving jobs left (exhausted trace replay)
        # the pop freed an in-flight slot: the pending FIFO takes it FIRST,
        # so the arriving worker's own re-dispatch (below) queues behind
        # earlier waiters instead of starving them at the bound
        drain(t_now)
        arrivals.append(Arrival(seq, i, t_disp, t_now))
        ev = process.client_event(i)
        events.append(ev)
        tau = it + 1 - version_iter[i]
        tau_max = max(tau_max, tau)
        applied = bool(on_arrival(ArrivalView(
            seq, i, t_now, tau, it,
            completeness=1.0 if ev is None else ev.completeness)))
        seq += 1
        if applied:
            it += 1

        if route is None:  # greedy: worker restarts on the freshest model
            deliver(i)
            version_iter[i] = it
            dispatch(i, t_now)
        else:  # routed: the new model goes to a sampled worker's queue
            queues[i] -= 1
            j = next_routed_worker()
            deliver(j)
            version_iter[j] = it
            queues[j] += 1
            if queues[i] > 0:  # keep draining this worker's backlog
                dispatch(i, t_now)
            if queues[j] == 1 and j != i:
                dispatch(j, t_now)
            if not heap and not pending:  # all idle: route to a random worker
                j = int(rng.integers(n))
                queues[j] += 1
                dispatch(j, t_now)

    # a process without client state yields all-None events -> no v3 rows;
    # otherwise normalize stray Nones to the default event so the trace
    # stays one row per arrival
    trace = ArrivalTrace.from_arrivals(
        n, arrivals,
        events=None if all(e is None for e in events)
        else [ClientEvent() if e is None else e for e in events])
    if isinstance(process, TraceArrivals):
        _check_replay(trace, process.trace)
    return LoopStats(arrivals=seq, iters=it, tau_max=tau_max, t_end=t_now,
                     max_in_flight=inflight_max, trace=trace)


def _check_replay(got: ArrivalTrace, want: ArrivalTrace) -> None:
    """A replay run must re-enact the source trace event for event."""
    m = len(got)
    if m > len(want):
        raise AssertionError(
            f"replay produced {m} arrivals but the trace records only "
            f"{len(want)}")
    if not (np.array_equal(got.worker, want.worker[:m])
            and np.allclose(got.t_arrive, want.t_arrive[:m])):
        k = int(np.argmax((got.worker != want.worker[:m])
                          | ~np.isclose(got.t_arrive, want.t_arrive[:m])))
        raise AssertionError(
            f"trace replay diverged at arrival {k}: got worker "
            f"{int(got.worker[k])} @ t={float(got.t_arrive[k]):.6g}, trace "
            f"says worker {int(want.worker[k])} @ "
            f"t={float(want.t_arrive[k]):.6g} — was the replay run "
            "configured with the recording run's route/rng?")
    if want.events is not None:
        if got.events is None:
            raise AssertionError(
                "replay of a v3 trace produced no client events")
        for k in range(m):
            if got.events[k].completeness != want.events[k].completeness:
                raise AssertionError(
                    f"trace replay diverged at arrival {k}: completeness "
                    f"{got.events[k].completeness} != recorded "
                    f"{want.events[k].completeness}")

"""Arrival processes and client-state scenarios (host-side timing models).

The asynchronous algorithms in this repo are distinguished by their arrival
*process* — the continuous-time stream of worker completions — not by their
server math (AsGrad, Islamov et al. 2023).  This module makes that process a
first-class, pluggable object: an ``ArrivalProcess`` draws the compute
DURATION of each dispatched gradient job, and the event loop
(``runtime/loop.py``) turns those draws into a deterministic dispatch/collect
event stream.  Three base processes ship:

* ``FixedArrivals`` — the paper's fixed-computation-speed model (worker ``i``
  always takes ``times[i]``); ``from_speeds`` adapts a ``SpeedModel``.
* ``ExponentialArrivals`` — i.i.d. exponential durations per worker; the
  heavy upper tail produces natural stragglers.
* ``TraceArrivals`` — bit-exact replay of an ``ArrivalTrace`` recorded by a
  previous run (simulator or runner): the recorded durations are re-served
  per worker in dispatch order, so the deterministic event loop reproduces
  the identical arrival sequence.

On top of the bases sits the **client-state scenario engine**:
``ClientStateProcess`` wraps any base process and composes the failure modes
federated deployments actually exhibit (FLGo's system simulator is the
model): time-varying availability (``SinAvailability``,
``LognormalAvailability``, label-skew-correlated ``SkewAvailability``),
mid-round dropout with reconnect-from-stale-snapshot, partial-gradient
completeness, and lognormal responsiveness jitter.  Every job's client-state
outcome is summarized in a ``ClientEvent`` that the loop records into the
``ArrivalTrace`` (schema v3), so chaos runs replay bit-for-bit: the trace
carries both the timing AND the per-arrival completeness that scaled the
gradient.  ``make_scenario`` is the CLI/Trainer-facing factory behind
``--scenario``.

Everything here is plain numpy on the host.  Documented in docs/async.md
("Client-state scenarios").
"""

from __future__ import annotations

import collections
import dataclasses
import json
import math
from typing import Optional, Sequence

import numpy as np

__all__ = [
    "ARRIVAL_KINDS", "SCENARIO_KINDS", "TRACE_SCHEMA",
    "Arrival", "ArrivalTrace", "ClientEvent",
    "ArrivalProcess", "FixedArrivals", "ExponentialArrivals", "TraceArrivals",
    "AvailabilityModel", "SinAvailability", "LognormalAvailability",
    "SkewAvailability", "ClientStateProcess",
    "make_arrivals", "make_scenario",
]

# the --arrival CLI vocabulary (launch/train.py)
ARRIVAL_KINDS = ("fixed", "exp", "trace")

# the --scenario CLI vocabulary (launch/train.py); "none" is the identity
SCENARIO_KINDS = ("none", "dropout", "partial", "sin", "lognormal", "skew",
                  "chaos")

# ArrivalTrace JSON schema version.  v1 (implicit — files with no "schema"
# key) carried only (n, worker, t_dispatch, t_arrive); v2 added the explicit
# "schema" field and the optional per-arrival commit "digest" list that
# multi-host runs record (runtime/hostloop.py); v3 adds the optional
# per-arrival client-state "events" rows (completeness, drops, wait, outage)
# written when the run used a ClientStateProcess.  Traces outlive the code
# that wrote them, so load() upgrades v1/v2 in place and REJECTS unknown
# versions with a clear error instead of misparsing them.
TRACE_SCHEMA = 3


def _config_error_type():
    # ConfigError lives in api/config.py, two layers above this module;
    # import at call time so the runtime layer stays import-light and free
    # of cycles.  ConfigError subclasses ValueError, so callers that catch
    # the old plain ValueError keep working.
    from ..api.config import ConfigError
    return ConfigError


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One collect event: worker ``worker``'s job, dispatched at
    ``t_dispatch``, arrives at the server at ``t_arrive``."""

    seq: int            # global arrival index (0-based)
    worker: int
    t_dispatch: float
    t_arrive: float

    @property
    def duration(self) -> float:
        return self.t_arrive - self.t_dispatch


@dataclasses.dataclass(frozen=True)
class ClientEvent:
    """Client-state outcome of one gradient job (one per arrival).

    ``completeness`` is the fraction of the local batch work the client
    finished before submitting (the server scales the gradient by it — the
    value is an exact float32 so replay is bitwise); ``drops`` counts
    mid-compute disconnects the job survived (each one restarted the SAME
    job from the worker's stale snapshot, the hostloop resync semantics);
    ``wait`` is the availability wait before compute started and ``outage``
    the total lost-compute + offline time of the drops, both in loop-time
    units.
    """

    completeness: float = 1.0
    drops: int = 0
    wait: float = 0.0
    outage: float = 0.0

    def to_row(self) -> list:
        return [self.completeness, self.drops, self.wait, self.outage]

    @classmethod
    def from_row(cls, row) -> "ClientEvent":
        return cls(completeness=float(row[0]), drops=int(row[1]),
                   wait=float(row[2]), outage=float(row[3]))


@dataclasses.dataclass(frozen=True)
class ArrivalTrace:
    """A recorded arrival schedule — the ground truth for trace-replay.

    Stores the per-arrival ``(worker, t_dispatch, t_arrive)`` triples in
    arrival order, plus (schema v3) the per-arrival ``ClientEvent`` when the
    recording run used a ``ClientStateProcess``.  Replay does not re-enact
    these rows directly: each worker's jobs are sequential, so the
    per-worker sequence of *durations* (and events) fully determines the
    event evolution under the deterministic loop, and ``TraceArrivals``
    re-serves exactly those.
    """

    n: int
    worker: np.ndarray      # [m] int32, arrival order
    t_dispatch: np.ndarray  # [m] float64
    t_arrive: np.ndarray    # [m] float64
    # per-arrival commit digests (core.compression.commit_digest hex strings)
    # recorded by real multi-host runs; None on simulated traces.  Replay
    # recomputes them (AsyncRunner record_digests) to localize divergence.
    digest: Optional[tuple] = None
    # per-arrival ClientEvent rows (schema v3); None when the recording run
    # had no client-state scenario (plain arrival processes).
    events: Optional[tuple] = None

    def __len__(self) -> int:
        return int(self.worker.shape[0])

    def __getitem__(self, k: int) -> Arrival:
        return Arrival(k, int(self.worker[k]), float(self.t_dispatch[k]),
                       float(self.t_arrive[k]))

    @classmethod
    def from_arrivals(cls, n: int, arrivals: Sequence[Arrival],
                      digests: Optional[Sequence[str]] = None,
                      events: Optional[Sequence[ClientEvent]] = None,
                      ) -> "ArrivalTrace":
        if digests is not None and len(digests) != len(arrivals):
            raise ValueError(
                f"{len(digests)} digests for {len(arrivals)} arrivals")
        if events is not None and len(events) != len(arrivals):
            raise ValueError(
                f"{len(events)} client events for {len(arrivals)} arrivals")
        return cls(
            n=n,
            worker=np.asarray([a.worker for a in arrivals], np.int32),
            t_dispatch=np.asarray([a.t_dispatch for a in arrivals]),
            t_arrive=np.asarray([a.t_arrive for a in arrivals]),
            digest=None if digests is None else tuple(digests),
            events=None if events is None else tuple(events),
        )

    def durations_per_worker(self) -> list:
        """Per-worker FIFO of job durations, in that worker's job order."""
        out = [[] for _ in range(self.n)]
        for k in range(len(self)):
            out[int(self.worker[k])].append(
                float(self.t_arrive[k]) - float(self.t_dispatch[k]))
        return out

    def events_per_worker(self) -> Optional[list]:
        """Per-worker FIFO of ClientEvents, aligned with
        ``durations_per_worker`` (same per-worker job order)."""
        if self.events is None:
            return None
        out = [[] for _ in range(self.n)]
        for k in range(len(self)):
            out[int(self.worker[k])].append(self.events[k])
        return out

    def event_stats(self) -> dict:
        """Aggregate client-state telemetry over the recorded events
        (empty dict when the trace carries none)."""
        if self.events is None:
            return {}
        comp = [e.completeness for e in self.events]
        return {
            "events": len(self.events),
            "dropouts": int(sum(e.drops for e in self.events)),
            "partial_jobs": int(sum(1 for c in comp if c < 1.0)),
            "mean_completeness": float(np.mean(comp)) if comp else 1.0,
            "wait_time": float(sum(e.wait for e in self.events)),
            "outage_time": float(sum(e.outage for e in self.events)),
        }

    # ------------------------------------------------------- persistence

    def save(self, path: str) -> str:
        d = {
            "schema": TRACE_SCHEMA,
            "n": self.n,
            "worker": [int(w) for w in self.worker],
            "t_dispatch": [float(t) for t in self.t_dispatch],
            "t_arrive": [float(t) for t in self.t_arrive],
        }
        if self.digest is not None:
            d["digest"] = list(self.digest)
        if self.events is not None:
            d["events"] = [e.to_row() for e in self.events]
        with open(path, "w") as f:
            json.dump(d, f)
        return path

    @classmethod
    def load(cls, path: str) -> "ArrivalTrace":
        with open(path) as f:
            d = json.load(f)
        # v1 files predate the schema field: upgrade in place (no digests,
        # no events); v2 files carry no events.
        schema = int(d.get("schema", 1))
        if schema < 1 or schema > TRACE_SCHEMA:
            raise ValueError(
                f"{path}: ArrivalTrace schema {schema} is not supported by "
                f"this build (reads v1..v{TRACE_SCHEMA}); re-record the "
                "trace or upgrade the repro package")
        digest = d.get("digest")
        events = d.get("events")
        return cls(n=int(d["n"]),
                   worker=np.asarray(d["worker"], np.int32),
                   t_dispatch=np.asarray(d["t_dispatch"]),
                   t_arrive=np.asarray(d["t_arrive"]),
                   digest=None if digest is None else tuple(digest),
                   events=None if events is None else tuple(
                       ClientEvent.from_row(r) for r in events))


class ArrivalProcess:
    """Timing model of gradient computation: ``duration(worker)`` draws how
    long the job dispatched NOW on ``worker`` will take.  Stateful processes
    (rng streams, trace cursors) restart from ``reset()`` — the event loop
    calls it once per run, so one process object can drive many runs."""

    n: int

    def reset(self) -> None:  # pragma: no cover - trivial default
        pass

    def duration(self, worker: int) -> float:
        raise NotImplementedError

    def duration_at(self, worker: int, t: float) -> float:
        """Duration of a job dispatched at absolute loop time ``t``.  The
        event loop calls this hook; the default ignores ``t`` (stationary
        processes).  Time-varying processes (availability cycles) override
        it."""
        return self.duration(worker)

    def client_event(self, worker: int) -> Optional[ClientEvent]:
        """Client-state outcome of ``worker``'s arriving job, or None for
        plain timing processes.  The loop pops this once per arrival; jobs
        per worker are strictly sequential, so a per-worker FIFO filled at
        dispatch time and drained here stays aligned."""
        return None


class FixedArrivals(ArrivalProcess):
    """Fixed-computation-speed model (paper §5): worker ``i`` always takes
    ``times[i]`` per gradient.  With equal times this is a fixed-rate
    round-robin arrival stream."""

    def __init__(self, times):
        times = np.asarray(times, np.float64)
        if times.ndim != 1 or np.any(times <= 0):
            raise ValueError("times must be a 1-D array of positive floats")
        self.times = times
        self.n = int(times.shape[0])

    @classmethod
    def from_speeds(cls, speeds) -> "FixedArrivals":
        """Adapt a ``core.schedules.SpeedModel`` (anything with ``.times``)."""
        return cls(np.asarray(speeds.times))

    def duration(self, worker: int) -> float:
        return float(self.times[worker])


class ExponentialArrivals(ArrivalProcess):
    """I.i.d. exponential job durations: worker ``i``'s jobs take
    ``Exp(mean=means[i])``.  The exponential's heavy upper tail produces the
    straggler pattern the paper's delay analysis targets — occasional jobs
    many times the mean — without a separate straggler knob.  A scalar
    ``mean`` gives a homogeneous fleet; pass a vector to skew it."""

    def __init__(self, n: int, mean=1.0, seed: int = 0, floor: float = 1e-6):
        means = np.broadcast_to(np.asarray(mean, np.float64), (n,)).copy()
        if np.any(means <= 0):
            raise ValueError("mean durations must be positive")
        self.n = int(n)
        self.means = means
        self.seed = int(seed)
        self.floor = float(floor)
        self.reset()

    def reset(self) -> None:
        self._rng = np.random.default_rng(self.seed)

    def duration(self, worker: int) -> float:
        return max(self.floor,
                   float(self._rng.exponential(self.means[worker])))


class TraceArrivals(ArrivalProcess):
    """Replay of a recorded ``ArrivalTrace``.

    Serves each worker's recorded durations (and, for v3 traces, client
    events) back in dispatch order; the deterministic event loop then
    reproduces the recorded arrival sequence exactly (same order, same
    times, same completeness) — asserted per run by the loop when it
    finishes, and end-to-end by ``tests/test_runtime.py`` /
    ``tests/test_scenarios.py`` (simulator and runner produce bit-identical
    parameters from one trace).  A worker whose recorded jobs are exhausted
    gets an INFINITE duration: the recording run dispatched that trailing
    job too but it never arrived inside the recorded window, so in replay
    it never arrives either (the loop stops when only never-arriving jobs
    remain).
    """

    def __init__(self, trace: ArrivalTrace):
        self.trace = trace
        self.n = trace.n
        self.reset()

    def reset(self) -> None:
        self._cursor = [0] * self.n
        self._durations = self.trace.durations_per_worker()
        self._events = self.trace.events_per_worker()
        self._ecursor = [0] * self.n

    def duration(self, worker: int) -> float:
        c = self._cursor[worker]
        if c >= len(self._durations[worker]):
            return float("inf")  # dispatched beyond the recorded window
        self._cursor[worker] = c + 1
        return self._durations[worker][c]

    def client_event(self, worker: int) -> Optional[ClientEvent]:
        if self._events is None:
            return None
        c = self._ecursor[worker]
        self._ecursor[worker] = c + 1
        return self._events[worker][c]


# --------------------------------------------------------------------------
# availability models (when is a client willing to START a job)


class AvailabilityModel:
    """Availability policy: ``wait(worker, t, rng)`` returns how long a job
    dispatched to ``worker`` at loop time ``t`` waits before the client is
    online and compute starts (0.0 = immediately available).  Draws come
    from the per-worker ``rng`` stream the ``ClientStateProcess`` owns, so
    waits depend only on (seed, worker, job index) — replayable."""

    def wait(self, worker: int, t: float, rng) -> float:
        raise NotImplementedError


class SinAvailability(AvailabilityModel):
    """Sin-cycle availability (FLGo system simulator idiom): worker ``w``
    is online at time ``t`` with probability

        p_w(t) = lo + (hi - lo) * (1 + sin(2π(t/period + phase_w))) / 2

    i.e. a diurnal cycle between ``lo`` and ``hi``, phase-shifted per worker
    by the golden ratio so the fleet never synchronizes.  ``wait`` draws
    slotted Bernoulli checks every ``slot`` time units until one passes."""

    def __init__(self, period: float = 8.0, slot: float = 0.25,
                 lo: float = 0.05, hi: float = 1.0):
        if period <= 0 or slot <= 0:
            raise ValueError("period and slot must be positive")
        if not (0.0 <= lo <= hi <= 1.0) or hi == 0.0:
            raise ValueError("need 0 <= lo <= hi <= 1 with hi > 0")
        self.period = float(period)
        self.slot = float(slot)
        self.lo = float(lo)
        self.hi = float(hi)

    def wait(self, worker: int, t: float, rng) -> float:
        ph = (worker * 0.6180339887498949) % 1.0
        wait = 0.0
        while True:
            p = self.lo + (self.hi - self.lo) * 0.5 * (
                1.0 + math.sin(2.0 * math.pi * ((t + wait) / self.period + ph)))
            if rng.random() < p:
                return wait
            wait += self.slot


class LognormalAvailability(AvailabilityModel):
    """Static per-worker availability with a lognormal population (FLGo's
    ``lognormal`` mode): worker ``w`` draws ``x_w ~ LogNormal(0, sigma)``
    once (from its own seed stream, independent of job order) and is online
    each ``slot`` with probability ``p_w = x_w / (1 + x_w)`` ∈ (0, 1).
    Larger ``sigma`` widens the availability spread across the fleet."""

    def __init__(self, sigma: float = 1.0, slot: float = 0.5, seed: int = 0):
        if sigma < 0 or slot <= 0:
            raise ValueError("sigma must be >= 0 and slot > 0")
        self.sigma = float(sigma)
        self.slot = float(slot)
        self.seed = int(seed)
        self._p: dict = {}

    def prob(self, worker: int) -> float:
        p = self._p.get(worker)
        if p is None:
            x = float(np.random.default_rng(
                np.random.SeedSequence([self.seed, int(worker)])
            ).lognormal(0.0, self.sigma))
            p = self._p[worker] = x / (1.0 + x)
        return p

    def wait(self, worker: int, t: float, rng) -> float:
        return self.slot * float(rng.geometric(self.prob(worker)) - 1)


class SkewAvailability(AvailabilityModel):
    """Label-skew-correlated availability: workers holding the most skewed
    data are online the least, the adversarial pattern for heterogeneity
    claims (the rare data lives on the flakiest clients).  ``skew`` is a
    per-worker score in [0, 1]; worker ``w`` is online each ``slot`` with
    probability ``clip(1 - beta * skew_w, p_min, 1)``."""

    def __init__(self, skew, beta: float = 0.8, slot: float = 0.5,
                 p_min: float = 0.1):
        skew = np.asarray(skew, np.float64)
        if skew.ndim != 1 or not np.all(np.isfinite(skew)):
            raise ValueError("skew must be a 1-D array of finite scores")
        if np.any(skew < 0) or np.any(skew > 1):
            raise ValueError("skew scores must lie in [0, 1]")
        if not (0.0 < p_min <= 1.0) or beta < 0 or slot <= 0:
            raise ValueError("need 0 < p_min <= 1, beta >= 0, slot > 0")
        self.skew = skew
        self.slot = float(slot)
        self.p = np.clip(1.0 - float(beta) * skew, p_min, 1.0)

    def wait(self, worker: int, t: float, rng) -> float:
        return self.slot * float(rng.geometric(self.p[worker]) - 1)


# --------------------------------------------------------------------------
# client-state scenario engine


class ClientStateProcess(ArrivalProcess):
    """Composable client-state scenario wrapped around a base process.

    Each dispatched job runs the client-state machine (see docs/async.md):

        dispatched → [wait: availability] → computing
        computing  → (dropout_rate) dropped → offline Exp(reconnect_mean)
                   → reconnect with the STALE snapshot → recompute same job
        computing  → done, completeness c ∈ [partial_min, 1]

    The returned duration is ``wait + outage + c · d · jitter`` where ``d``
    is the base draw, ``jitter ~ LogNormal(0, responsiveness_sigma)``, and
    ``outage`` sums each drop's lost compute plus its offline time.  A drop
    with ``reconnect_mean=None`` kills the worker (infinite duration — the
    hostloop dropout accounting).  Dropout/reconnect deliberately keeps the
    SAME job on the SAME dispatch snapshot, matching the hostloop resync
    path: the server re-sends the worker's stale snapshot row, so replaying
    the extended duration is bit-exact server-side.

    All draws come from per-worker ``SeedSequence([seed, w])`` streams, so a
    job's outcome depends only on (seed, worker, job index) — never on how
    other workers' arrivals interleave — which is what makes recorded traces
    replay bit-for-bit.  The per-job ``ClientEvent`` is queued at dispatch
    and popped by the loop at arrival (jobs per worker are sequential).
    """

    def __init__(self, base: ArrivalProcess, *, seed: int = 0,
                 availability: Optional[AvailabilityModel] = None,
                 dropout_rate: float = 0.0,
                 reconnect_mean: Optional[float] = None,
                 partial_min: float = 1.0,
                 responsiveness_sigma: float = 0.0):
        if not isinstance(base, ArrivalProcess):
            raise ValueError(f"base must be an ArrivalProcess, got {base!r}")
        if availability is not None and not isinstance(availability,
                                                       AvailabilityModel):
            raise ValueError(
                f"availability must be an AvailabilityModel, "
                f"got {availability!r}")
        if not (0.0 <= dropout_rate < 1.0):
            raise ValueError(
                f"dropout_rate must lie in [0, 1), got {dropout_rate}")
        if reconnect_mean is not None and reconnect_mean <= 0:
            raise ValueError(
                f"reconnect_mean must be positive or None, "
                f"got {reconnect_mean}")
        if not (0.0 < partial_min <= 1.0):
            raise ValueError(
                f"partial_min must lie in (0, 1], got {partial_min}")
        if responsiveness_sigma < 0:
            raise ValueError(
                f"responsiveness_sigma must be >= 0, "
                f"got {responsiveness_sigma}")
        self.base = base
        self.n = base.n
        self.seed = int(seed)
        self.availability = availability
        self.dropout_rate = float(dropout_rate)
        self.reconnect_mean = (None if reconnect_mean is None
                               else float(reconnect_mean))
        self.partial_min = float(partial_min)
        self.responsiveness_sigma = float(responsiveness_sigma)
        self.reset()

    def reset(self) -> None:
        self.base.reset()
        self._rngs = [np.random.default_rng(np.random.SeedSequence(
            [self.seed, w])) for w in range(self.n)]
        self._events = [collections.deque() for _ in range(self.n)]

    def duration(self, worker: int) -> float:
        return self.duration_at(worker, 0.0)

    def duration_at(self, worker: int, t: float) -> float:
        rng = self._rngs[worker]
        wait = 0.0
        if self.availability is not None:
            wait = float(self.availability.wait(worker, t, rng))
        d = float(self.base.duration_at(worker, t + wait))
        if not math.isfinite(d):
            # base exhausted (trace replay past the window): job never
            # arrives, its queued event is never popped.
            self._events[worker].append(ClientEvent(wait=wait))
            return d
        if self.responsiveness_sigma > 0.0:
            d *= float(rng.lognormal(0.0, self.responsiveness_sigma))
        completeness = 1.0
        if self.partial_min < 1.0:
            # exact float32 so the trace row, the runner's flat scale and
            # the simulator's pytree scale all use the identical constant
            completeness = float(np.float32(
                rng.uniform(self.partial_min, 1.0)))
            d *= completeness
        drops, outage = 0, 0.0
        if self.dropout_rate > 0.0:
            while rng.random() < self.dropout_rate:
                drops += 1
                lost = float(rng.uniform(0.0, 1.0)) * d
                if self.reconnect_mean is None:
                    # permanent dropout: the worker dies mid-compute and the
                    # job (and every later one) never arrives
                    self._events[worker].append(ClientEvent(
                        completeness, drops, wait, float("inf")))
                    return float("inf")
                outage += lost + float(rng.exponential(self.reconnect_mean))
        self._events[worker].append(ClientEvent(
            completeness=completeness, drops=drops, wait=wait, outage=outage))
        return wait + outage + d

    def client_event(self, worker: int) -> Optional[ClientEvent]:
        return self._events[worker].popleft()


# --------------------------------------------------------------------------
# factories


def make_arrivals(kind: str, n: int, *, times=None, mean=1.0, seed: int = 0,
                  trace: Optional[str] = None) -> ArrivalProcess:
    """CLI-facing factory for ``--arrival {fixed,exp,trace}``.

    ``fixed`` uses ``times`` (defaults to all-ones), ``exp`` draws
    ``Exp(mean)`` durations with ``seed``, ``trace`` loads the
    ``ArrivalTrace`` JSON at ``trace``.  Rejects unknown kinds and invalid
    arguments with the typed ``ConfigError`` from ``api/config.py`` (a
    ``ValueError`` subclass) so misconfiguration fails at build time, not
    deep inside the event loop.
    """
    ConfigError = _config_error_type()
    if kind == "fixed":
        try:
            return FixedArrivals(np.ones(n) if times is None else times)
        except ValueError as e:
            raise ConfigError(f"arrival kind 'fixed': {e}") from None
    if kind == "exp":
        try:
            return ExponentialArrivals(n, mean=mean, seed=seed)
        except ValueError as e:
            raise ConfigError(f"arrival kind 'exp': {e}") from None
    if kind == "trace":
        if trace is None:
            raise ConfigError("arrival kind 'trace' needs a trace path")
        t = ArrivalTrace.load(trace)
        if t.n != n:
            raise ConfigError(f"trace has n={t.n} workers, run has n={n}")
        return TraceArrivals(t)
    raise ConfigError(
        f"unknown arrival kind {kind!r}; options: {ARRIVAL_KINDS}")


# per-kind option vocabulary of make_scenario; values are the defaults
_SCENARIO_DEFAULTS = {
    "none": {},
    "dropout": {"dropout_rate": 0.15, "reconnect_mean": 2.0},
    "partial": {"partial_min": 0.25},
    "sin": {"period": 8.0, "slot": 0.25, "lo": 0.05, "hi": 1.0},
    "lognormal": {"sigma": 1.0, "slot": 0.5},
    "skew": {"skew": None, "beta": 0.8, "slot": 0.5, "p_min": 0.1},
    "chaos": {"dropout_rate": 0.1, "reconnect_mean": 2.0, "partial_min": 0.5,
              "responsiveness_sigma": 0.5, "period": 6.0},
}


def make_scenario(kind: str, base: ArrivalProcess, *, seed: int = 0,
                  **kw) -> ArrivalProcess:
    """CLI/Trainer-facing factory for ``--scenario``: wrap ``base`` in the
    named client-state scenario.

    ``none`` returns ``base`` unchanged; ``dropout`` adds mid-round
    disconnect + reconnect-from-stale-snapshot; ``partial`` submits
    partial-completeness gradients; ``sin`` / ``lognormal`` / ``skew`` gate
    job starts on the matching availability model (``skew`` defaults to a
    linear 0..1 skew score across workers); ``chaos`` composes dropout,
    partial gradients, responsiveness jitter and a sin cycle.  Unknown kinds,
    unknown options and invalid values raise the typed ``ConfigError``.
    """
    ConfigError = _config_error_type()
    if kind not in SCENARIO_KINDS:
        raise ConfigError(
            f"unknown scenario kind {kind!r}; options: {SCENARIO_KINDS}")
    defaults = _SCENARIO_DEFAULTS[kind]
    unknown = sorted(set(kw) - set(defaults))
    if unknown:
        raise ConfigError(
            f"scenario {kind!r} got unknown option(s) {unknown}; "
            f"accepts {sorted(defaults)}")
    if kind == "none":
        return base
    opts = {**defaults, **kw}
    try:
        if kind in ("dropout", "partial"):
            return ClientStateProcess(base, seed=seed, **opts)
        if kind == "sin":
            return ClientStateProcess(
                base, seed=seed, availability=SinAvailability(**opts))
        if kind == "lognormal":
            return ClientStateProcess(
                base, seed=seed,
                availability=LognormalAvailability(seed=seed, **opts))
        if kind == "skew":
            skew = opts.pop("skew")
            if skew is None:
                skew = np.linspace(0.0, 1.0, base.n)
            return ClientStateProcess(
                base, seed=seed, availability=SkewAvailability(skew, **opts))
        # chaos
        period = opts.pop("period")
        return ClientStateProcess(
            base, seed=seed, availability=SinAvailability(period=period),
            **opts)
    except ValueError as e:
        if isinstance(e, ConfigError):
            raise
        raise ConfigError(f"scenario {kind!r}: {e}") from None

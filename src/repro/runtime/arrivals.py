"""Arrival processes: who finishes a gradient, and when (host-side).

The asynchronous algorithms in this repo are distinguished by their arrival
*process* — the continuous-time stream of worker completions — not by their
server math (AsGrad, Islamov et al. 2023).  This module makes that process a
first-class, pluggable object: an ``ArrivalProcess`` draws the compute
DURATION of each dispatched gradient job, and the event loop
(``runtime/loop.py``) turns those draws into a deterministic dispatch/collect
event stream.  Three processes ship:

* ``FixedArrivals`` — the paper's fixed-computation-speed model (worker ``i``
  always takes ``times[i]``); ``from_speeds`` adapts a ``SpeedModel``.
* ``ExponentialArrivals`` — i.i.d. exponential durations per worker; the
  heavy upper tail produces natural stragglers.
* ``TraceArrivals`` — bit-exact replay of an ``ArrivalTrace`` recorded by a
  previous run (simulator or runner): the recorded durations are re-served
  per worker in dispatch order, so the deterministic event loop reproduces
  the identical arrival sequence.

Everything here is plain numpy on the host.  Documented in docs/async.md
("Arrival processes").
"""

from __future__ import annotations

import dataclasses
import json
from typing import Optional, Sequence

import numpy as np

__all__ = [
    "ARRIVAL_KINDS", "TRACE_SCHEMA", "Arrival", "ArrivalTrace",
    "ArrivalProcess", "FixedArrivals", "ExponentialArrivals", "TraceArrivals",
    "make_arrivals",
]

# the --arrival CLI vocabulary (launch/train.py)
ARRIVAL_KINDS = ("fixed", "exp", "trace")

# ArrivalTrace JSON schema version.  v1 (implicit — files with no "schema"
# key) carried only (n, worker, t_dispatch, t_arrive); v2 adds the explicit
# "schema" field and the optional per-arrival commit "digest" list that
# multi-host runs record (runtime/hostloop.py).  Traces now outlive the
# code that wrote them, so load() upgrades v1 in place and REJECTS unknown
# versions with a clear error instead of misparsing them.
TRACE_SCHEMA = 2


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One collect event: worker ``worker``'s job, dispatched at
    ``t_dispatch``, arrives at the server at ``t_arrive``."""

    seq: int            # global arrival index (0-based)
    worker: int
    t_dispatch: float
    t_arrive: float

    @property
    def duration(self) -> float:
        return self.t_arrive - self.t_dispatch


@dataclasses.dataclass(frozen=True)
class ArrivalTrace:
    """A recorded arrival schedule — the ground truth for trace-replay.

    Stores the per-arrival ``(worker, t_dispatch, t_arrive)`` triples in
    arrival order.  Replay does not re-enact these rows directly: each
    worker's jobs are sequential, so the per-worker sequence of *durations*
    fully determines the event evolution under the deterministic loop, and
    ``TraceArrivals`` re-serves exactly those durations.
    """

    n: int
    worker: np.ndarray      # [m] int32, arrival order
    t_dispatch: np.ndarray  # [m] float64
    t_arrive: np.ndarray    # [m] float64
    # per-arrival commit digests (core.compression.commit_digest hex strings)
    # recorded by real multi-host runs; None on simulated traces.  Replay
    # recomputes them (AsyncRunner record_digests) to localize divergence.
    digest: Optional[tuple] = None

    def __len__(self) -> int:
        return int(self.worker.shape[0])

    def __getitem__(self, k: int) -> Arrival:
        return Arrival(k, int(self.worker[k]), float(self.t_dispatch[k]),
                       float(self.t_arrive[k]))

    @classmethod
    def from_arrivals(cls, n: int, arrivals: Sequence[Arrival],
                      digests: Optional[Sequence[str]] = None
                      ) -> "ArrivalTrace":
        if digests is not None and len(digests) != len(arrivals):
            raise ValueError(
                f"{len(digests)} digests for {len(arrivals)} arrivals")
        return cls(
            n=n,
            worker=np.asarray([a.worker for a in arrivals], np.int32),
            t_dispatch=np.asarray([a.t_dispatch for a in arrivals]),
            t_arrive=np.asarray([a.t_arrive for a in arrivals]),
            digest=None if digests is None else tuple(digests),
        )

    def durations_per_worker(self) -> list:
        """Per-worker FIFO of job durations, in that worker's job order."""
        out = [[] for _ in range(self.n)]
        for k in range(len(self)):
            out[int(self.worker[k])].append(
                float(self.t_arrive[k]) - float(self.t_dispatch[k]))
        return out

    # ------------------------------------------------------- persistence

    def save(self, path: str) -> str:
        d = {
            "schema": TRACE_SCHEMA,
            "n": self.n,
            "worker": [int(w) for w in self.worker],
            "t_dispatch": [float(t) for t in self.t_dispatch],
            "t_arrive": [float(t) for t in self.t_arrive],
        }
        if self.digest is not None:
            d["digest"] = list(self.digest)
        with open(path, "w") as f:
            json.dump(d, f)
        return path

    @classmethod
    def load(cls, path: str) -> "ArrivalTrace":
        with open(path) as f:
            d = json.load(f)
        # v1 files predate the schema field: upgrade in place (no digests)
        schema = int(d.get("schema", 1))
        if schema < 1 or schema > TRACE_SCHEMA:
            raise ValueError(
                f"{path}: ArrivalTrace schema {schema} is not supported by "
                f"this build (reads v1..v{TRACE_SCHEMA}); re-record the "
                "trace or upgrade the repro package")
        digest = d.get("digest")
        return cls(n=int(d["n"]),
                   worker=np.asarray(d["worker"], np.int32),
                   t_dispatch=np.asarray(d["t_dispatch"]),
                   t_arrive=np.asarray(d["t_arrive"]),
                   digest=None if digest is None else tuple(digest))


class ArrivalProcess:
    """Timing model of gradient computation: ``duration(worker)`` draws how
    long the job dispatched NOW on ``worker`` will take.  Stateful processes
    (rng streams, trace cursors) restart from ``reset()`` — the event loop
    calls it once per run, so one process object can drive many runs."""

    n: int

    def reset(self) -> None:  # pragma: no cover - trivial default
        pass

    def duration(self, worker: int) -> float:
        raise NotImplementedError


class FixedArrivals(ArrivalProcess):
    """Fixed-computation-speed model (paper §5): worker ``i`` always takes
    ``times[i]`` per gradient.  With equal times this is a fixed-rate
    round-robin arrival stream."""

    def __init__(self, times):
        times = np.asarray(times, np.float64)
        if times.ndim != 1 or np.any(times <= 0):
            raise ValueError("times must be a 1-D array of positive floats")
        self.times = times
        self.n = int(times.shape[0])

    @classmethod
    def from_speeds(cls, speeds) -> "FixedArrivals":
        """Adapt a ``core.schedules.SpeedModel`` (anything with ``.times``)."""
        return cls(np.asarray(speeds.times))

    def duration(self, worker: int) -> float:
        return float(self.times[worker])


class ExponentialArrivals(ArrivalProcess):
    """I.i.d. exponential job durations: worker ``i``'s jobs take
    ``Exp(mean=means[i])``.  The exponential's heavy upper tail produces the
    straggler pattern the paper's delay analysis targets — occasional jobs
    many times the mean — without a separate straggler knob.  A scalar
    ``mean`` gives a homogeneous fleet; pass a vector to skew it."""

    def __init__(self, n: int, mean=1.0, seed: int = 0, floor: float = 1e-6):
        means = np.broadcast_to(np.asarray(mean, np.float64), (n,)).copy()
        if np.any(means <= 0):
            raise ValueError("mean durations must be positive")
        self.n = int(n)
        self.means = means
        self.seed = int(seed)
        self.floor = float(floor)
        self.reset()

    def reset(self) -> None:
        self._rng = np.random.default_rng(self.seed)

    def duration(self, worker: int) -> float:
        return max(self.floor,
                   float(self._rng.exponential(self.means[worker])))


class TraceArrivals(ArrivalProcess):
    """Replay of a recorded ``ArrivalTrace``.

    Serves each worker's recorded durations back in dispatch order; the
    deterministic event loop then reproduces the recorded arrival sequence
    exactly (same order, same times) — asserted per run by the loop when it
    finishes, and end-to-end by ``tests/test_runtime.py`` (simulator and
    runner produce bit-identical parameters from one trace).  A worker whose
    recorded jobs are exhausted gets an INFINITE duration: the recording run
    dispatched that trailing job too but it never arrived inside the
    recorded window, so in replay it never arrives either (the loop stops
    when only never-arriving jobs remain).
    """

    def __init__(self, trace: ArrivalTrace):
        self.trace = trace
        self.n = trace.n
        self.reset()

    def reset(self) -> None:
        self._cursor = [0] * self.n
        self._durations = self.trace.durations_per_worker()

    def duration(self, worker: int) -> float:
        c = self._cursor[worker]
        if c >= len(self._durations[worker]):
            return float("inf")  # dispatched beyond the recorded window
        self._cursor[worker] = c + 1
        return self._durations[worker][c]


def make_arrivals(kind: str, n: int, *, times=None, mean=1.0, seed: int = 0,
                  trace: Optional[str] = None) -> ArrivalProcess:
    """CLI-facing factory for ``--arrival {fixed,exp,trace}``.

    ``fixed`` uses ``times`` (defaults to all-ones), ``exp`` draws
    ``Exp(mean)`` durations with ``seed``, ``trace`` loads the
    ``ArrivalTrace`` JSON at ``trace``.
    """
    if kind == "fixed":
        return FixedArrivals(np.ones(n) if times is None else times)
    if kind == "exp":
        return ExponentialArrivals(n, mean=mean, seed=seed)
    if kind == "trace":
        if trace is None:
            raise ValueError("arrival kind 'trace' needs a trace path")
        t = ArrivalTrace.load(trace)
        if t.n != n:
            raise ValueError(f"trace has n={t.n} workers, run has n={n}")
        return TraceArrivals(t)
    raise ValueError(f"unknown arrival kind {kind!r}; options: {ARRIVAL_KINDS}")

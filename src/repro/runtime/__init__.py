"""repro.runtime — the event-driven asynchronous training runtime.

Five layers, documented in docs/async.md:

* ``arrivals`` — pluggable ``ArrivalProcess`` timing models (fixed-rate,
  exponential stragglers, trace replay), the client-state scenario engine
  (``ClientStateProcess`` + availability models, behind ``make_scenario`` /
  ``--scenario``) and the recordable ``ArrivalTrace`` (schema v3 with
  per-arrival ``ClientEvent`` rows);
* ``loop`` — the ONE dispatch/collect event loop (routing disciplines,
  staleness bookkeeping, bounded in-flight depth) shared by the simulator
  and the production runner;
* ``runner`` — ``AsyncRunner``: per-arrival ``commit`` + flat optimizer
  apply on the P-axis-sharded ``FlatTrainState``, with a double-buffered
  host->device queue;
* ``transport`` — the framed wire protocol (commit rows worker -> server,
  delta snapshots server -> worker) over sockets or the in-process twin;
* ``hostloop`` — ``HostRunner`` / ``run_worker``: the multi-host server
  loop driven by socket readiness, replayable bit-for-bit through
  ``AsyncRunner``.

``runner`` and ``hostloop`` are exported lazily: they import ``repro.core``
(engines, algos), which itself imports ``runtime.loop`` from the simulator —
eager re-export here would close that cycle during ``repro.core``'s own
import.  ``transport`` is eager (it only touches ``core.compression``).
"""

from .arrivals import (
    ARRIVAL_KINDS, SCENARIO_KINDS, TRACE_SCHEMA, Arrival, ArrivalProcess,
    ArrivalTrace, AvailabilityModel, ClientEvent, ClientStateProcess,
    ExponentialArrivals, FixedArrivals, LognormalAvailability,
    SinAvailability, SkewAvailability, TraceArrivals, make_arrivals,
    make_scenario,
)
from .loop import ArrivalView, LoopStats, drive_arrivals

__all__ = [
    "ARRIVAL_KINDS", "SCENARIO_KINDS", "TRACE_SCHEMA", "Arrival",
    "ArrivalProcess", "ArrivalTrace",
    "AvailabilityModel", "ClientEvent", "ClientStateProcess",
    "LognormalAvailability", "SinAvailability", "SkewAvailability",
    "ExponentialArrivals", "FixedArrivals", "TraceArrivals", "make_arrivals",
    "make_scenario",
    "ArrivalView", "LoopStats", "drive_arrivals",
    "AsyncResult", "AsyncRunner", "DeviceQueue",
    "worker_key", "worker_rng",
    "HostRunner", "run_worker", "accept_links", "poll_accept_fn",
    "SocketTransport", "InProcTransport", "connect", "serve_listener",
]

_RUNNER_EXPORTS = ("AsyncResult", "AsyncRunner", "DeviceQueue",
                   "worker_key", "worker_rng")
_HOSTLOOP_EXPORTS = ("HostRunner", "run_worker", "accept_links",
                     "poll_accept_fn")
_TRANSPORT_EXPORTS = ("SocketTransport", "InProcTransport", "connect",
                      "serve_listener")


def __getattr__(name):  # PEP 562: break the core <-> runtime import cycle
    if name in _RUNNER_EXPORTS:
        from . import runner
        return getattr(runner, name)
    if name in _HOSTLOOP_EXPORTS:
        from . import hostloop
        return getattr(hostloop, name)
    if name in _TRANSPORT_EXPORTS:
        from . import transport
        return getattr(transport, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

"""repro.runtime — the event-driven asynchronous training runtime.

Three layers, documented in docs/async.md:

* ``arrivals`` — pluggable ``ArrivalProcess`` timing models (fixed-rate,
  exponential stragglers, trace replay) and the recordable ``ArrivalTrace``;
* ``loop`` — the ONE dispatch/collect event loop (routing disciplines,
  staleness bookkeeping, bounded in-flight depth) shared by the simulator
  and the production runner;
* ``runner`` — ``AsyncRunner``: per-arrival ``commit`` + flat optimizer
  apply on the P-axis-sharded ``FlatTrainState``, with a double-buffered
  host->device queue.

``runner`` is exported lazily: it imports ``repro.core`` (engines, algos),
which itself imports ``runtime.loop`` from the simulator — eager re-export
here would close that cycle during ``repro.core``'s own import.
"""

from .arrivals import (
    ARRIVAL_KINDS, Arrival, ArrivalProcess, ArrivalTrace,
    ExponentialArrivals, FixedArrivals, TraceArrivals, make_arrivals,
)
from .loop import ArrivalView, LoopStats, drive_arrivals

__all__ = [
    "ARRIVAL_KINDS", "Arrival", "ArrivalProcess", "ArrivalTrace",
    "ExponentialArrivals", "FixedArrivals", "TraceArrivals", "make_arrivals",
    "ArrivalView", "LoopStats", "drive_arrivals",
    "AsyncResult", "AsyncRunner", "DeviceQueue",
]

_RUNNER_EXPORTS = ("AsyncResult", "AsyncRunner", "DeviceQueue")


def __getattr__(name):  # PEP 562: break the core <-> runtime import cycle
    if name in _RUNNER_EXPORTS:
        from . import runner
        return getattr(runner, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

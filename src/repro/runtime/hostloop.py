"""Multi-host event loop: the server iteration driven by socket readiness.

``runtime/loop.py`` drives per-arrival training off a SIMULATED clock; this
module drives the identical per-arrival math (``AsyncRunner``'s
``_RunSession``) off a REAL one: worker processes compute gradients on the
model snapshots the server ships them and push commits over the framed
transport (``runtime/transport.py``); the server folds each commit the
instant its frame arrives.  DuDe-ASGD's dual-delayed fold is what makes
this correct under any physical delay distribution — the server math never
assumes anything about WHEN a gradient arrives, only which model version
produced it (AsGrad's framing: the algorithm is distinguished by its
arrival process, which here is finally a real wire).

Protocol (all frames are ``runtime/transport.py`` frames)::

    worker -> server   hello     {workers: [ids]}            handshake
    server -> worker   welcome   {n, P, fmt, tile, topk, cap, axis, seed,
                                  key_mode} + [base f32 [P]]
    server -> worker   snapshot  {w, j, it} + delta payload  dispatch job j
    worker -> server   commit    {w, j, loss, dg} + [gflat f32 [P]]
    either -> either   ping / pong                           heartbeat
    server -> worker   bye                                   run finished

Determinism contract (the replay oracle): the server runs its session with
``key_mode="worker"``, so job ``j`` of worker ``w`` is keyed
``fold_in(fold_in(key(seed), w), j)`` and sampled from the per-worker
``SeedSequence([seed, w])`` stream — quantities a remote process computes
without global knowledge.  Each live arrival gets the canonical trace
stamps ``t_arrive = seq + 1`` and ``t_dispatch = previous arrival-of-w's
t_arrive`` (0 for the first), which is exactly the event evolution
``drive_arrivals`` reconstructs under greedy routing — so replaying the
recorded ``ArrivalTrace`` through the single-process ``AsyncRunner`` with
``key_mode="worker"`` recomputes every gradient, every fold, and the final
``[P]`` params BIT-FOR-BIT (and the per-arrival digests localize any
divergence).  ``tests/test_transport.py`` asserts this end to end.

Failure semantics:

* every recv carries a deadline; links that stay silent past
  ``heartbeat_s`` get a PING, past ``dead_after_s`` are declared dead;
* EOF (``TransportClosed``) is an immediate dropout: the link's logical
  workers stop arriving, counted in ``AsyncResult.dropouts`` /
  ``dropped_workers``; the run CONTINUES on the surviving links (greedy
  routing never blocks on a dead worker);
* a reconnecting process re-handshakes through ``accept_fn``; each of its
  logical workers is re-sent the EXACT snapshot it held when it died (the
  session keeps per-worker snapshots) plus its in-flight job index, so the
  retried job computes the gradient the replay expects and tau bookkeeping
  continues unbroken.

Documented in docs/async.md ("Multi-host transport").
"""

from __future__ import annotations

import threading
import time
from collections import deque
from typing import Callable, Optional, Sequence

import jax
import numpy as np

from ..core.compression import CommitCodec, commit_digest, sparse_decode
from .arrivals import Arrival, ArrivalTrace
from .loop import ArrivalView, LoopStats
from .runner import AsyncResult, AsyncRunner, worker_key, worker_rng
from .transport import (SocketTransport, TransportClosed, TransportError,
                        TransportTimeout, commit_header,
                        sparse_row_from_arrays)

__all__ = ["HostRunner", "run_worker", "accept_links", "poll_accept_fn"]


# --------------------------------------------------------------- server side

def accept_links(listener, n_links: int, *, timeout: float = 60.0,
                 transport_timeout: float = 30.0) -> list:
    """Accept ``n_links`` connections off a ``serve_listener`` socket."""
    import socket as _socket
    out: list = []
    deadline = time.monotonic() + timeout
    while len(out) < n_links:
        try:
            sock, _ = listener.accept()
            out.append(SocketTransport(sock, timeout=transport_timeout))
        except (BlockingIOError, InterruptedError, _socket.timeout):
            if time.monotonic() > deadline:
                raise TransportTimeout(
                    f"only {len(out)}/{n_links} links connected "
                    f"within {timeout:.0f}s") from None
            time.sleep(0.02)
    return out


def poll_accept_fn(listener, *, transport_timeout: float = 30.0) -> Callable:
    """Non-blocking accept poll for mid-run reconnects (``accept_fn``)."""
    def accept():
        try:
            sock, _ = listener.accept()
            return SocketTransport(sock, timeout=transport_timeout)
        except OSError:
            return None
    return accept


class _Link:
    """One connected worker process: a transport + its logical worker ids."""

    def __init__(self, transport, workers: tuple):
        self.t = transport
        self.workers = workers
        now = time.monotonic()
        self.last_heard = now
        self.last_ping = now


class HostRunner:
    """The multi-host twin of ``AsyncRunner.run``: same session math, real
    arrivals.

    ``runner`` supplies the engine/algo/optimizer jits (gradients are NOT
    computed here — they arrive in commit frames); the transport policy
    knobs bound how long a silent link lives.  ``serve`` is the entry
    point; it returns the same ``AsyncResult`` a simulated run would, with
    the robustness counters filled in.
    """

    def __init__(self, runner: AsyncRunner, *, heartbeat_s: float = 5.0,
                 dead_after_s: float = 20.0, poll_s: float = 0.05,
                 hello_timeout_s: float = 30.0, allow_reconnect: bool = True):
        if dead_after_s <= heartbeat_s:
            raise ValueError(
                f"dead_after_s={dead_after_s} must exceed "
                f"heartbeat_s={heartbeat_s} (a PING needs time to answer)")
        if runner.algo.route is not None:
            raise ValueError(
                "multi-host serving needs the greedy route (route=None); "
                f"algo {runner.algo.name!r} routes {runner.algo.route!r}")
        self.runner = runner
        self.heartbeat_s = heartbeat_s
        self.dead_after_s = dead_after_s
        self.poll_s = poll_s
        self.hello_timeout_s = hello_timeout_s
        self.allow_reconnect = allow_reconnect

    # ------------------------------------------------------------ handshake

    def _welcome_meta(self, seed: int) -> dict:
        eng = self.runner.engine
        codec: CommitCodec = eng.codec
        return {
            "n": eng.n_workers, "P": eng.P, "fmt": codec.format,
            "tile": codec.tile, "topk": codec.topk,
            "cap": eng.cap_tiles if eng.sparse_meta else 0,
            "axis": eng.axis_size, "seed": int(seed), "key_mode": "worker",
        }

    def _handshake(self, transport, claimed: set, n: int) -> tuple:
        msg = transport.recv(timeout=self.hello_timeout_s)
        if msg.kind != "hello":
            raise TransportError(
                f"expected hello, got {msg.kind!r} (bad client?)")
        workers = tuple(int(w) for w in msg.meta.get("workers", ()))
        if not workers:
            raise TransportError("hello claims no workers")
        for w in workers:
            if not 0 <= w < n:
                raise TransportError(
                    f"hello claims worker {w}, engine has n={n}")
            if w in claimed:
                raise TransportError(
                    f"worker {w} is already attached to a live link")
        return workers

    # ---------------------------------------------------------------- serve

    def serve(self, links: Sequence, total_iters: int, state, *,
              seed: int = 0, record_every: int = 10,
              eval_fn: Optional[Callable] = None, ema: float = 0.9,
              accept_fn: Optional[Callable] = None,
              checkpoint_every: Optional[int] = None,
              checkpoint_fn: Optional[Callable] = None,
              max_wall_s: Optional[float] = None) -> AsyncResult:
        """Drive ``total_iters`` server iterations from live commit frames.

        ``links`` are connected transports that have NOT yet said hello
        (``accept_links`` output); their hellos must claim every engine
        worker exactly once.  ``accept_fn`` (optional, e.g.
        ``poll_accept_fn``) is polled for reconnecting processes.
        ``checkpoint_fn(state, it)`` fires every ``checkpoint_every``
        applied iterations — mid-run server-side checkpointing, which the
        single-process runner's round-cadence hooks cannot do.
        """
        runner = self.runner
        n = runner.engine.n_workers
        sess = runner.session(state, None, seed=seed,
                              record_every=record_every, eval_fn=eval_fn,
                              ema=ema, key_mode="worker",
                              record_digests=True)
        base_np = np.asarray(sess.base if sess.base is not None
                             else state.params, np.float32)
        welcome = self._welcome_meta(seed)

        live: list = []
        all_links: list = []   # every transport ever attached (byte totals)
        worker_link: dict = {}
        dropped: set = set()
        never_attached = set(range(n))
        version_iter = [0] * n
        last_arrive = [0.0] * n
        arrivals: list = []
        it = 0
        seq = 0
        tau_max = 0
        inflight_max = 0
        dropouts = 0
        reconnects = 0
        t_start = time.monotonic()

        def attach(transport, *, rejoin: bool) -> None:
            nonlocal inflight_max, reconnects
            workers = self._handshake(transport, set(worker_link), n)
            if rejoin:
                for w in workers:
                    if w in dropped or w in never_attached:
                        continue
                    raise TransportError(
                        f"worker {w} reconnecting but was never dropped")
            link = _Link(transport, workers)
            transport.send("welcome", welcome, [base_np])
            for w in workers:
                worker_link[w] = link
                if w in dropped:  # true rejoin (not a late first join)
                    reconnects += 1
                dropped.discard(w)
                never_attached.discard(w)
                # dispatch: job = collected commits of w (a lost in-flight
                # job is RETRIED at the same index); payload = the snapshot
                # w held at its last delivery — what the replay's gradient
                # for this job will be computed on
                transport.send("snapshot",
                               {"w": w, "j": sess.arrived[w], "it": it},
                               sess.snapshot_arrays(w))
            live.append(link)
            all_links.append(transport)
            inflight_max = max(inflight_max, len(worker_link))

        def drop(link, reason: str) -> None:
            nonlocal dropouts
            if link not in live:
                return
            live.remove(link)
            for w in link.workers:
                if worker_link.get(w) is link:
                    del worker_link[w]
                    dropped.add(w)
                    dropouts += 1
            try:
                link.t.close()
            except Exception:
                pass

        def handle(link, msg) -> bool:
            """Process one frame; True iff it applied a server iteration."""
            nonlocal it, seq, tau_max
            if msg.kind == "ping":
                link.t.send("pong")
                return False
            if msg.kind in ("pong", "busy"):
                return False
            if msg.kind == "bye":
                drop(link, "client said bye")
                return False
            if msg.kind != "commit":
                raise TransportError(
                    f"unexpected {msg.kind!r} frame on an attached link")
            w, j = int(msg.meta["w"]), int(msg.meta["j"])
            if worker_link.get(w) is not link:
                raise TransportError(
                    f"commit for worker {w} from a link that does not "
                    f"own it")
            if j < sess.arrived[w]:
                return False  # duplicate from a link presumed dead — drop
            if j > sess.arrived[w]:
                raise TransportError(
                    f"worker {w} commits job {j}, server expected "
                    f"{sess.arrived[w]} (protocol desync)")
            (gflat,) = msg.arrays
            dg = commit_digest(gflat)
            if msg.meta.get("dg", dg) != dg:
                raise TransportError(
                    f"commit digest mismatch for worker {w} job {j}: "
                    f"frame says {msg.meta['dg']}, payload hashes to {dg} "
                    "(corrupt frame or diverged worker)")
            t_arr = float(seq + 1)
            tau = it + 1 - version_iter[w]
            tau_max = max(tau_max, tau)
            arrivals.append(Arrival(seq, w, last_arrive[w], t_arr))
            last_arrive[w] = t_arr
            sess.commit(ArrivalView(seq, w, t_arr, tau, it),
                        float(msg.meta["loss"]), gflat)
            seq += 1
            it += 1
            if checkpoint_fn is not None and checkpoint_every and \
                    it % checkpoint_every == 0:
                checkpoint_fn(sess.state, it)
            if it < total_iters:
                # greedy delivery: the arriving worker restarts on the
                # freshest model (same bookkeeping as drive_arrivals)
                sess.deliver(w)
                version_iter[w] = it
                link.t.send("snapshot", {"w": w, "j": sess.arrived[w],
                                         "it": it},
                            sess.snapshot_arrays(w))
            return True

        try:
            for transport in links:
                attach(transport, rejoin=False)
            if worker_link and set(range(n)) - set(worker_link):
                missing = sorted(set(range(n)) - set(worker_link))
                raise TransportError(
                    f"initial links leave workers {missing} unattached — "
                    "every engine worker needs exactly one link")

            while it < total_iters:
                if max_wall_s is not None and \
                        time.monotonic() - t_start > max_wall_s:
                    break
                if accept_fn is not None and self.allow_reconnect and \
                        (dropped or never_attached):
                    fresh = accept_fn()
                    if fresh is not None:
                        try:
                            attach(fresh, rejoin=True)
                        except (TransportError, TransportTimeout):
                            fresh.close()
                if not live:
                    if accept_fn is None or not self.allow_reconnect:
                        break  # nobody left and nobody can come back
                    time.sleep(self.poll_s)
                    continue
                def pump(link, timeout) -> bool:
                    """Read + handle at most one frame off ``link``;
                    True iff a frame was processed."""
                    try:
                        msg = link.t.recv(timeout=timeout)
                    except TransportTimeout:
                        return False
                    except TransportClosed:
                        drop(link, "EOF")
                        return False
                    link.last_heard = time.monotonic()
                    try:
                        handle(link, msg)
                    except TransportClosed:
                        drop(link, "send failed")
                    return True

                # single link: block the full poll; several: short slices
                per_recv = self.poll_s if len(live) == 1 else 0.002
                for link in list(live):
                    if it >= total_iters:
                        break
                    if pump(link, per_recv):
                        # drain the backlog that queued up while the fold
                        # ran — heartbeats trapped behind a slow commit
                        # must reach last_heard before the death check
                        while link in live and it < total_iters and \
                                pump(link, 0.001):
                            pass
                # heartbeat maintenance runs EVERY pass (not just idle
                # ones): when surviving links saturate the server with
                # commits, a silent link must still age out on schedule —
                # the last_heard age test keeps busy links unpinged
                for link in list(live):
                    silent = time.monotonic() - link.last_heard
                    if silent > self.dead_after_s:
                        # one last-chance read: a link whose frames are
                        # waiting unread (the reader was starved by long
                        # folds) is not dead, just unheard
                        if pump(link, 0.001):
                            continue
                        drop(link, f"silent {silent:.1f}s (heartbeat)")
                    elif silent > self.heartbeat_s and \
                            time.monotonic() - link.last_ping > \
                            self.heartbeat_s:
                        link.last_ping = time.monotonic()
                        try:
                            link.t.send("ping")
                        except (TransportClosed, TransportTimeout):
                            drop(link, "ping failed")
        finally:
            for link in list(live):
                try:
                    link.t.send("bye")
                except (TransportError, OSError):
                    pass
            # linger on normal completion: a worker mid-compute when the
            # run finished will still push one last (discarded) commit
            # before it reads the BYE — keep its link readable so that
            # send succeeds and it exits cleanly instead of on EOF
            if it >= total_iters:
                deadline = time.monotonic() + 2.0
                while live and time.monotonic() < deadline:
                    for link in list(live):
                        try:
                            msg = link.t.recv(timeout=0.02)
                            if msg.kind == "bye":
                                raise TransportClosed("client left")
                        except TransportTimeout:
                            pass
                        except (TransportClosed, TransportError):
                            live.remove(link)
                            try:
                                link.t.close()
                            except Exception:
                                pass
            for link in list(live):
                try:
                    link.t.close()
                except Exception:
                    pass
            sess.queue.flush()

        trace = ArrivalTrace.from_arrivals(n, arrivals, digests=sess.digests)
        stats = LoopStats(arrivals=seq, iters=it, tau_max=tau_max,
                          t_end=float(seq), max_in_flight=inflight_max,
                          trace=trace)
        res = sess.result(stats)
        # socket totals for the server end (handshakes + snapshots +
        # commits, framed) over every link that ever attached; the
        # session's commit-row accounting stays in wire_rows/payload_bytes
        res.wire_sent = sum(t.wire_sent for t in all_links)
        res.wire_recv = sum(t.wire_recv for t in all_links)
        res.dropouts = dropouts
        res.reconnects = reconnects
        res.dropped_workers = tuple(sorted(dropped))
        return res


# --------------------------------------------------------------- client side

class _Bye(Exception):
    pass


def run_worker(transport_factory: Callable, workers: Sequence[int],
               grad_fn: Callable, sample_fn: Callable, spec, *,
               poll_s: float = 0.2, heartbeat_s: float = 5.0,
               max_reconnects: int = 0,
               reconnect_backoff_s: float = 0.5) -> dict:
    """One worker process: serve ``workers``' gradient jobs until BYE.

    ``transport_factory() -> transport`` dials the server (called again on
    reconnect, up to ``max_reconnects`` times after a drop);
    ``grad_fn(params, batch, key) -> (loss, grads)`` and ``sample_fn(w,
    rng) -> batch`` are the SAME callables a single-process run would use;
    ``spec`` the engine's ``FlatSpec`` (built locally from the model
    config — validated against the server's WELCOME).  Snapshot decode and
    gradient ravel run the same jitted expressions as the server's replay,
    so the committed bytes are bit-identical to what the replay recomputes.

    Sampling streams survive reconnects: job indices the server re-issues
    reuse the cached last batch, skipped-ahead indices fast-forward the
    per-worker rng — so a resumed worker stays aligned with the replay's
    draw order.  Returns ``{"commits", "reconnects", "wire_sent",
    "wire_recv"}``.
    """
    import jax.numpy as jnp

    workers = tuple(int(w) for w in workers)
    commits = 0
    reconnects = 0
    wire_sent = 0
    wire_recv = 0
    jits: dict = {}
    rngs: dict = {}
    drawn = {w: 0 for w in workers}
    last_batch: dict = {}

    def build(meta, base_np):
        """Per-run jits, built once from the first WELCOME."""
        P = int(meta["P"])
        if spec.padded_size != P:
            raise TransportError(
                f"local FlatSpec has P={spec.padded_size}, server says {P} "
                "— model config or mesh axis size mismatch")
        fmt = meta["fmt"]
        codec = CommitCodec(format=fmt, tile=int(meta["tile"]),
                            topk=int(meta["topk"]))
        base = jnp.asarray(base_np)
        # textually identical to the runner's _snap_unravel/_unravel/_ravel
        # jits -> identical lowering -> bit-identical reconstruction
        if fmt == "topk_ef":
            unsnap = jax.jit(lambda row: spec.unravel(
                base + sparse_decode(row, P)))

            def decode(arrays):
                return unsnap(sparse_row_from_arrays(arrays))
        elif codec.compressed:
            unsnap = jax.jit(lambda q, s: spec.unravel(
                base + codec.decode(q, s)))

            def decode(arrays):
                return unsnap(*arrays)
        else:
            unsnap = jax.jit(spec.unravel)

            def decode(arrays):
                return unsnap(arrays[0])
        jits["decode"] = decode
        jits["grad"] = jax.jit(grad_fn)
        jits["ravel"] = jax.jit(lambda g: spec.ravel(g, jnp.float32))
        jits["seed"] = int(meta["seed"])
        for w in workers:
            rngs.setdefault(w, worker_rng(jits["seed"], w))

    def batch_for(w, j):
        if w in last_batch and last_batch[w][0] == j:
            return last_batch[w][1]  # server retried the in-flight job
        if j < drawn[w]:
            raise TransportError(
                f"worker {w} asked to rewind to job {j} "
                f"(already drew {drawn[w]} batches)")
        while drawn[w] < j:  # fresh process rejoining mid-run: fast-forward
            sample_fn(w, rngs[w])
            drawn[w] += 1
        batch = sample_fn(w, rngs[w])
        drawn[w] += 1
        last_batch[w] = (j, batch)
        return batch

    def session(transport):
        nonlocal commits
        pending: deque = deque()
        transport.send("hello", {"workers": list(workers)})
        msg = transport.recv(timeout=60.0)
        if msg.kind != "welcome":
            raise TransportError(f"expected welcome, got {msg.kind!r}")
        if not jits:
            build(msg.meta, msg.arrays[0])

        # heartbeat THREAD, not inline pings: a gradient compute (or the
        # first jit compile) can legitimately outlast the server's
        # dead_after_s, and the main thread cannot ping mid-compute — the
        # transport's send lock keeps ping frames out of commit streams
        stop_hb = threading.Event()

        def _heartbeat():
            while not stop_hb.wait(heartbeat_s):
                try:
                    transport.send("ping")
                except TransportError:
                    return

        hb = threading.Thread(target=_heartbeat, daemon=True)
        hb.start()

        def handle(msg):
            if msg.kind == "bye":
                raise _Bye
            if msg.kind == "ping":
                transport.send("pong")
            elif msg.kind == "snapshot":
                pending.append((int(msg.meta["w"]), int(msg.meta["j"]),
                                msg.arrays))
            # pong / anything else: heartbeat only

        try:
            while True:
                # drain frames; block only when there is no job to compute
                try:
                    while True:
                        msg = transport.recv(timeout=0.001 if pending
                                             else poll_s)
                        handle(msg)
                except TransportTimeout:
                    pass
                if not pending:
                    continue
                w, j, arrays = pending.popleft()
                params = jits["decode"](arrays)
                key = worker_key(jits["seed"], w, j)
                loss, g = jits["grad"](params, batch_for(w, j), key)
                gflat = np.asarray(jits["ravel"](g), np.float32)
                transport.send("commit",
                               commit_header(w, j, float(loss),
                                             commit_digest(gflat)),
                               [gflat])
                commits += 1
        finally:
            stop_hb.set()

    attempts = 0
    while True:
        transport = transport_factory()
        try:
            session(transport)
        except _Bye:
            try:
                transport.send("bye")
            except TransportError:
                pass
            wire_sent += transport.wire_sent
            wire_recv += transport.wire_recv
            transport.close()
            break
        except (TransportClosed, TransportTimeout):
            wire_sent += transport.wire_sent
            wire_recv += transport.wire_recv
            try:
                transport.close()
            except Exception:
                pass
            if attempts >= max_reconnects:
                raise
            attempts += 1
            reconnects += 1
            time.sleep(reconnect_backoff_s * attempts)
    return {"commits": commits, "reconnects": reconnects,
            "wire_sent": wire_sent, "wire_recv": wire_recv}
